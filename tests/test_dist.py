"""Distributed multi-start MOO-STAGE (repro.dist): the merge/determinism/
fault-injection suite.

Pins the DESIGN.md §8 contract:

* shard planning is remainder-exact (Σ worker budgets == global budget)
  and W=1 is the identity plan;
* the Pareto-union merge is associative, commutative, idempotent, and
  independent of worker arrival order (bit-identical merged objectives
  under any permutation — process pools complete out of order);
* merged accounting is the sum of shard accounting and the merged
  RunResult JSON round-trips exactly;
* ``stage_dist(executor="serial", n_workers=1)`` is byte-identical to a
  registry ``stage_batch`` run (wall-clock zeroed);
* a raising worker is reported in diagnostics and the survivors' union
  is returned; a budget-tripped worker merges as ``exhausted=True``;
* at equal global budget, ``stage_dist(W=4, process)`` reaches PHV >=
  single-process ``stage_batch(n_starts=4)`` on spec_tiny seeds 0/1/2.
"""

import itertools
import json
import os

import numpy as np
import pytest

from repro.core import dominates, spec_tiny
from repro.dist import (merge_results, n_rounds, plan_shards, retry_seed,
                        spawn_seeds, split_evenly)
from repro.dist import worker as dist_worker
from repro.noc import Budget, NocProblem, RunResult, run

#: few-second stage_batch knobs shared by the whole suite
SMALL = dict(iters_max=2, n_swaps=4, n_link_moves=4, max_local_steps=5)


@pytest.fixture(scope="module")
def tiny_problem() -> NocProblem:
    return NocProblem(spec=spec_tiny(), traffic="BFS", case="case3")


@pytest.fixture(scope="module")
def worker_results(tiny_problem) -> list[RunResult]:
    """Three REAL worker RunResults — one per shard of a W=3 plan —
    exactly what the coordinator's merge consumes."""
    shards = plan_shards(tiny_problem, Budget(max_evals=360, seed=7), 3)
    out = []
    for s in shards:
        raw = dist_worker.run_shard(s.problem.to_json(), s.budget.to_json(),
                                    s.budget.seed, dict(SMALL, n_starts=1),
                                    worker_id=s.worker_id)
        out.append(RunResult.from_json(raw))
    return out


def _payload(res: RunResult) -> str:
    """Canonical payload JSON: wall-clock zeroed; header fields that
    necessarily name the driver (optimizer/config/extra) excluded."""
    j = res.to_json()
    j["history"] = [[0.0] + row[1:] for row in j["history"]]
    keep = ("problem", "budget", "obj_idx", "designs", "objs", "history",
            "n_evals", "n_calls", "exhausted")
    return json.dumps({k: j[k] for k in keep}, sort_keys=True)


def _pareto_sig(res: RunResult) -> tuple:
    """(design keys, objective bytes) — the merge-invariant Pareto part."""
    return (tuple(d.key() for d in res.designs),
            np.asarray(res.objs, dtype=np.float64).tobytes())


# ---------------------------------------------------------------------------
# Shard planning
# ---------------------------------------------------------------------------
def test_split_evenly_remainder_exact():
    for total, k in ((10, 3), (7, 7), (5, 8), (0, 4), (1000, 7)):
        parts = split_evenly(total, k)
        assert sum(parts) == total and len(parts) == k
        assert max(parts) - min(parts) <= 1
    assert split_evenly(None, 3) == [None, None, None]
    with pytest.raises(ValueError, match="k must be"):
        split_evenly(10, 0)
    with pytest.raises(ValueError, match="total must be"):
        split_evenly(-1, 2)


def test_spawn_seeds_identity_and_determinism():
    # W=1 passes the root seed through — the serial-equivalence anchor.
    assert spawn_seeds(3, 1) == [3]
    s1 = spawn_seeds(3, 4)
    assert s1 == spawn_seeds(3, 4)            # deterministic in the root
    assert len(set(s1)) == 4                  # distinct streams
    assert s1 != spawn_seeds(4, 4)            # root seed matters
    with pytest.raises(ValueError, match="n_workers"):
        spawn_seeds(0, 0)


def test_plan_shards_budget_sums(tiny_problem):
    for w, me, mc in ((4, 1000, None), (3, 100, 17), (5, 3, 3)):
        shards = plan_shards(tiny_problem, Budget(max_evals=me, max_calls=mc,
                                                  seed=5), w)
        assert [s.worker_id for s in shards] == list(range(w))
        assert sum(s.budget.max_evals for s in shards) == me
        if mc is None:
            assert all(s.budget.max_calls is None for s in shards)
        else:
            assert sum(s.budget.max_calls for s in shards) == mc
        assert [s.budget.seed for s in shards] == spawn_seeds(5, w)
        assert all(s.problem is tiny_problem for s in shards)
    ident = plan_shards(tiny_problem, Budget(max_evals=50, seed=9), 1)[0]
    assert ident.budget == Budget(max_evals=50, seed=9)


def test_n_rounds():
    assert n_rounds(12, 5) == 3 and n_rounds(12, 12) == 1
    assert n_rounds(12, 100) == 1
    with pytest.raises(ValueError, match="sync_every"):
        n_rounds(12, 0)


# ---------------------------------------------------------------------------
# Merge semantics
# ---------------------------------------------------------------------------
def test_merge_commutative_bit_identical_under_any_order(worker_results):
    """Acceptance: merged Pareto objectives (and designs, history, and
    accounting) are bit-identical under ANY permutation of worker result
    arrival order."""
    ref = merge_results(list(worker_results))
    ref_payload = _payload(ref)
    ref_spans = ref.extra["history_spans"]
    for perm in itertools.permutations(worker_results):
        m = merge_results(list(perm))
        assert _pareto_sig(m) == _pareto_sig(ref)
        assert _payload(m) == ref_payload
        assert m.extra["history_spans"] == ref_spans


def test_merge_associative(worker_results):
    a, b, c = worker_results
    flat = merge_results([a, b, c])
    left = merge_results([merge_results([a, b]), c])
    right = merge_results([a, merge_results([b, c])])
    assert _payload(left) == _payload(flat) == _payload(right)
    assert _pareto_sig(left) == _pareto_sig(flat) == _pareto_sig(right)
    # Nested merges flatten their history spans to the same tagging.
    assert (left.extra["history_spans"] == flat.extra["history_spans"]
            == right.extra["history_spans"])


def test_merge_idempotent(worker_results):
    a = worker_results[0]
    # Singleton merge is the identity (payload AND headers).
    solo = merge_results([a])
    assert _payload(solo) == _payload(a)
    assert solo.extra == a.extra
    # Merging a result with a copy of itself (re-tagged: ids must be
    # unique) adds nothing to the Pareto union. Compared as SETS: the
    # singleton merge passes the worker's front through in insertion
    # order, while a >=2-input merge canonical-sorts (that sort is the
    # order-independence mechanism), so ordered equality is not promised.
    twin = RunResult.from_json(a.to_json())
    twin.extra["worker_id"] = 99
    both = merge_results([a, twin])

    def _rows(res):
        objs = np.asarray(res.objs, np.float64)
        return sorted((d.key(), objs[i].tobytes())
                      for i, d in enumerate(res.designs))

    assert _rows(both) == _rows(merge_results([a]))
    # A merge of a merge changes nothing.
    m = merge_results(list(worker_results))
    assert _payload(merge_results([m])) == _payload(m)


def test_merge_accounting_is_sum_of_shards(worker_results):
    """Satellite: merged accounting equals the sum of shard accounting."""
    m = merge_results(list(worker_results))
    assert m.n_evals == sum(r.n_evals for r in worker_results)
    assert m.n_calls == sum(r.n_calls for r in worker_results)
    assert m.wall_s == max(r.wall_s for r in worker_results)
    assert m.exhausted == any(r.exhausted for r in worker_results)
    total_rows = sum(np.asarray(r.history).shape[0] for r in worker_results)
    assert np.asarray(m.history).shape == (total_rows, 4)
    # Spans partition the merged history, in worker-id order, one per input.
    spans = m.extra["history_spans"]
    assert [w for w, _, _ in spans] == [0, 1, 2]
    assert spans[0][1] == 0 and spans[-1][2] == total_rows
    for (w1, a1, b1), (w2, a2, b2) in zip(spans, spans[1:]):
        assert b1 == a2
    for (w, a, b), r in zip(spans, worker_results):
        np.testing.assert_array_equal(m.history[a:b], r.history)


def test_merge_result_is_mutually_nondominated(worker_results):
    m = merge_results(list(worker_results))
    assert len(m.designs) >= 1
    sub = np.asarray(m.objs)[:, list(m.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])
    # Every merged design came from some worker and every worker row is
    # dominated-or-present (union semantics: nothing invented, nothing
    # non-dominated lost).
    all_keys = {d.key() for r in worker_results for d in r.designs}
    assert {d.key() for d in m.designs} <= all_keys


def test_merged_runresult_json_roundtrip_exact(worker_results, tmp_path):
    """Satellite: merged RunResult JSON round-trips exactly."""
    m = merge_results(list(worker_results))
    path = tmp_path / "merged.json"
    m.save(path)
    back = RunResult.load(path)
    assert _payload(back) == _payload(m)
    assert np.array_equal(np.asarray(back.objs), np.asarray(m.objs))
    assert [d.key() for d in back.designs] == [d.key() for d in m.designs]
    assert np.array_equal(back.history, m.history, equal_nan=True)
    assert back.extra["history_spans"] == m.extra["history_spans"]
    # And a second round trip is stable byte-for-byte.
    assert json.dumps(back.to_json()) == json.dumps(m.to_json())


def test_merge_input_validation(worker_results):
    a, b = worker_results[:2]
    with pytest.raises(ValueError, match="at least one"):
        merge_results([])
    bad = RunResult.from_json(a.to_json())
    bad.obj_idx = (0, 1)
    with pytest.raises(ValueError, match="objective subsets"):
        merge_results([b, bad])
    dup = RunResult.from_json(b.to_json())  # same worker_id as b
    with pytest.raises(ValueError, match="unique"):
        merge_results([b, dup])


# ---------------------------------------------------------------------------
# The distributed driver
# ---------------------------------------------------------------------------
def test_stage_dist_serial_w1_byte_identical_to_stage_batch(tiny_problem):
    """Satellite: the W=1 serial run reproduces a registry ``stage_batch``
    run byte-for-byte — problem, budget, designs, objectives, history
    (wall-clock zeroed), accounting, and exhaustion all identical; only
    the driver-naming headers (optimizer/config/extra) differ."""
    budget = Budget(max_evals=150, seed=3)
    ref = run(tiny_problem, "stage_batch", budget=budget,
              config=dict(SMALL, n_starts=1))
    dist = run(tiny_problem, "stage_dist", budget=budget,
               config=dict(SMALL, n_workers=1, executor="serial", n_starts=1))
    assert dist.optimizer == "stage_dist"
    assert _payload(dist) == _payload(ref)
    assert dist.phv() == ref.phv()
    # Same bytes again on a rerun: the dist driver inherits the registry's
    # seeded-determinism pin.
    dist2 = run(tiny_problem, "stage_dist", budget=budget,
                config=dict(SMALL, n_workers=1, executor="serial",
                            n_starts=1))
    assert _payload(dist2) == _payload(dist)


def test_stage_dist_executors_agree(tiny_problem):
    """The executor chooses WHERE shards run, never the result: serial and
    per-jax-device runs of the same plan produce identical payloads."""
    budget = Budget(max_evals=240, seed=0)
    cfg = dict(SMALL, n_workers=3, executor="serial")
    ser = run(tiny_problem, "stage_dist", budget=budget, config=cfg)
    jx = run(tiny_problem, "stage_dist", budget=budget,
             config=dict(cfg, executor="jax"))
    assert _payload(jx) == _payload(ser)
    assert _pareto_sig(jx) == _pareto_sig(ser)
    assert ser.extra["worker_seeds"] == spawn_seeds(0, 3)


def test_stage_dist_worker_failure_is_survivable(tiny_problem, monkeypatch):
    """Satellite: a raising worker lands in diagnostics and the merged
    Pareto set of the SURVIVING workers comes back instead of a crash."""
    real = dist_worker.run_shard
    seeds_seen = []

    def flaky(problem_json, budget_json, seed, config_json=None,
              worker_id=0):
        if worker_id == 1:
            seeds_seen.append(seed)
            raise RuntimeError("simulated worker crash")
        return real(problem_json, budget_json, seed, config_json,
                    worker_id=worker_id)

    monkeypatch.setattr(dist_worker, "run_shard", flaky)
    res = run(tiny_problem, "stage_dist", budget=Budget(max_evals=360, seed=7),
              config=dict(SMALL, n_workers=3, executor="serial"))
    fails = res.extra["worker_failures"]
    # Default max_retries=1: attempt 0 plus one reseeded retry, both
    # recorded as structured per-attempt records.
    assert [(f["worker_id"], f["round"], f["attempt"], f["phase"])
            for f in fails] == [(1, 0, 0, "run"), (1, 0, 1, "run")]
    assert all(f["error"] == "RuntimeError: simulated worker crash"
               for f in fails)
    # Satellite: records carry the worker's actual stack, not just the
    # one-line message.
    assert all('raise RuntimeError("simulated worker crash")'
               in f["traceback"] for f in fails)
    # The retry was a DIFFERENT trajectory: reseeded via retry_seed.
    assert seeds_seen == [seeds_seen[0],
                          retry_seed(seeds_seen[0], 1)]
    assert len(res.designs) >= 1 and np.isfinite(res.phv())
    # Survivors only: both surviving workers' spans present, none for 1.
    assert [w for w, _, _ in res.extra["history_spans"]] == [0, 2]
    # Accounting covers exactly the survivors.
    assert res.n_evals == sum(w["n_evals"] for w in res.extra["workers"])

    def always_fail(*a, **k):
        raise RuntimeError("boom")

    monkeypatch.setattr(dist_worker, "run_shard", always_fail)
    with pytest.raises(RuntimeError, match="all 2 workers failed"):
        run(tiny_problem, "stage_dist", budget=Budget(max_evals=100),
            config=dict(SMALL, n_workers=2, executor="serial"))


def test_stage_dist_budget_trip_merges_exhausted(tiny_problem):
    """Satellite: a worker that hits its shard budget (the native check or
    the BudgetedEvaluator guard on max_calls) merges as exhausted=True."""
    res = run(tiny_problem, "stage_dist", budget=Budget(max_evals=60, seed=0),
              config=dict(SMALL, n_workers=2, executor="serial"))
    assert res.exhausted
    assert all(w["exhausted"] for w in res.extra["workers"])
    # max_calls trips the BudgetedEvaluator guard mid-driver; the worker
    # returns its best-so-far set and the merge carries the flag.
    res2 = run(tiny_problem, "stage_dist",
               budget=Budget(max_calls=6, seed=0),
               config=dict(SMALL, n_workers=2, executor="serial"))
    assert res2.exhausted and len(res2.designs) >= 1
    # Synced rounds enforce max_calls too (the guard wraps each round's
    # evaluator); a tripped round is forfeited but the run completes.
    res3 = run(tiny_problem, "stage_dist",
               budget=Budget(max_calls=8, seed=0),
               config=dict(SMALL, n_workers=2, executor="serial",
                           sync_every=1))
    assert res3.exhausted
    assert res3.n_calls <= 8 + 2  # cap + one in-flight dispatch per worker


def test_stage_dist_sync_deterministic_and_budgeted(tiny_problem):
    """Surrogate-sync rounds: deterministic for a fixed seed, budget held
    to the global cap + one dispatch per worker, histories tagged with
    unique per-(worker, round) ids."""
    budget = Budget(max_evals=300, seed=1)
    cfg = dict(SMALL, n_workers=2, executor="serial", sync_every=1,
               iters_max=3)
    r1 = run(tiny_problem, "stage_dist", budget=budget, config=cfg)
    r2 = run(tiny_problem, "stage_dist", budget=budget, config=cfg)
    assert _payload(r1) == _payload(r2)
    assert r1.extra["history_spans"] == r2.extra["history_spans"]
    # One neighborhood is <= 2*(n_swaps + n_link_moves) candidates; each
    # worker's final spending round may overshoot by one such dispatch
    # plus its mesh anchor and starts evaluation (the cumulative round
    # budgeting absorbs every earlier round's overshoot).
    per_worker = 2 * (SMALL["n_swaps"] + SMALL["n_link_moves"]) + 2
    assert r1.n_evals <= 300 + 2 * per_worker
    wids = [w for w, _, _ in r1.extra["history_spans"]]
    assert len(wids) == len(set(wids))
    sub = np.asarray(r1.objs)[:, list(r1.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])


def test_stage_dist_sync_worker_failure_drops_later_rounds(
        tiny_problem, monkeypatch):
    """A worker failing in round r is reported and excluded from rounds
    r+1.. while its earlier rounds still merge."""
    real = dist_worker.run_shard_round

    calls = []

    def flaky(problem_json, budget_json, seed, config_json=None,
              worker_id=0, starts_json=None, train_x=None, train_y=None,
              global_json=None):
        from repro.dist.sync import ROUND_TAG_STRIDE

        wid, rnd = divmod(worker_id, ROUND_TAG_STRIDE)
        calls.append((wid, rnd))
        if wid == 1 and rnd == 1:
            raise RuntimeError("dies in round 1")
        return real(problem_json, budget_json, seed, config_json,
                    worker_id=worker_id, starts_json=starts_json,
                    train_x=train_x, train_y=train_y,
                    global_json=global_json)

    monkeypatch.setattr(dist_worker, "run_shard_round", flaky)
    res = run(tiny_problem, "stage_dist", budget=Budget(max_evals=300, seed=2),
              config=dict(SMALL, n_workers=2, executor="serial",
                          sync_every=1, iters_max=3))
    fails = res.extra["worker_failures"]
    assert [(f["worker_id"], f["round"], f["attempt"]) for f in fails] \
        == [(1, 1, 0), (1, 1, 1)]         # attempt 0 + one reseeded retry
    assert all(f["error"] == "RuntimeError: dies in round 1"
               and f["phase"] == "run" for f in fails)
    assert (1, 2) not in calls            # dropped from the last round
    assert (0, 2) in calls                # survivor kept going
    assert len(res.designs) >= 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_stage_dist_workers_flag(capsys, tmp_path):
    from repro.noc import cli

    out = tmp_path / "dist.json"
    rc = cli.main([
        "run", "--spec", "tiny", "--optimizer", "stage_dist",
        "--workers", "2", "--max-evals", "120", "--seed", "0",
        "--set", "iters_max=1", "--set", "n_swaps=3",
        "--set", "n_link_moves=3", "--set", "max_local_steps=3",
        "--out", str(out), "--quiet"])
    assert rc == 0
    saved = RunResult.load(out)
    assert saved.optimizer == "stage_dist"
    assert saved.config["n_workers"] == 2
    with pytest.raises(SystemExit, match="only applies"):
        cli.main(["run", "--optimizer", "stage", "--workers", "2"])


# ---------------------------------------------------------------------------
# Package / skip audit (PR 1 importorskip guards)
# ---------------------------------------------------------------------------
def test_dist_exists_and_legacy_skips_are_retargeted():
    """Satellite: ``repro.dist`` exists (PR 5) and ``repro.dist.sharding``
    landed (PR 9) — the substrate/dryrun suites must run it for real, with
    no lingering importorskip that would silently skip them. The one
    still-unbuilt submodule (mesh_layout) keeps its honest guard."""
    import importlib.util

    import repro.dist  # must import cleanly — the package is real now

    assert callable(repro.dist.run_dist)
    assert importlib.util.find_spec("repro.dist.sharding") is not None, (
        "repro.dist.sharding went missing again (the PR-9 bugfix regressed)")
    import repro.dist.sharding as shd
    assert callable(shd.param_specs) and callable(shd.named)
    here = os.path.dirname(os.path.abspath(__file__))
    for fname in ("test_substrate.py", "test_dryrun.py"):
        src = open(os.path.join(here, fname)).read()
        assert "importorskip" not in src, (
            f"{fname} still guards on a module that exists — un-skip it")
    # mesh_layout is the one remaining unbuilt submodule: its guard must
    # target it specifically (never the bare package, whose skip became a
    # no-op the moment repro.dist landed) and it must really be absent.
    src = open(os.path.join(here, "test_bridge.py")).read()
    assert '"repro.dist.mesh_layout"' in src
    assert '"repro.dist"' not in src
    assert importlib.util.find_spec("repro.dist.mesh_layout") is None, (
        "repro.dist.mesh_layout exists now — un-skip test_bridge.py")


# ---------------------------------------------------------------------------
# Equal-budget PHV acceptance (process executor)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stage_dist_process_phv_matches_stage_batch(tiny_problem, seed):
    """Acceptance: stage_dist(W=4, process executor) at equal global
    budget reaches PHV on par with single-process stage_batch(n_starts=4)
    on spec_tiny — the sharded search loses nothing at this scale."""
    budget = Budget(max_evals=2000, seed=seed)
    # Both drivers at their registry defaults (iters_max=12, n_swaps=24,
    # n_link_moves=24): W=4 one-chain process workers vs the 4-chain
    # single-process driver. sync_every=6 gives two planned
    # surrogate/front-sync rounds, then extra budget-draining rounds that
    # intensify around the pooled front. At this operating point the
    # union front + restart rounds put the sharded fleet at or slightly
    # above the single process's lockstep sharing on most pinned seeds
    # (+0.001..+0.002 PHV); individual seeds land within noise of parity,
    # so the gate is a small tolerance, not strict dominance — per-seed
    # margins here are knife-edge accept-chain luck, not coordination
    # quality.
    sb = run(tiny_problem, "stage_batch", budget=budget,
             config=dict(n_starts=4))
    sd = run(tiny_problem, "stage_dist", budget=budget,
             config=dict(n_workers=4, executor="process", n_starts=1,
                         sync_every=6))
    assert sd.extra["executor"] == "process"
    assert sd.phv() >= sb.phv() - 0.005, (
        f"seed {seed}: dist {sd.phv():.6f} << batch {sb.phv():.6f}")
    # Equal-budget discipline: the sharded run spends what the plan allows
    # (global cap + at most one in-flight dispatch per worker, plus the
    # worker's mesh anchor and starts evaluation).
    assert sd.n_evals <= 2000 + 4 * (2 * (24 + 24) + 2)
