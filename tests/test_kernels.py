"""Per-kernel correctness: pallas_call(interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.link_util import walk_accumulate
from repro.kernels.minplus import minplus
from repro.kernels.ssd import ssd


# ------------------------------------------------------------------ minplus
@pytest.mark.parametrize(
    "bsz,n",
    [(1, 8), (2, 16), (1, 36), (2, 64), (1, 70),
     # odd / prime / above-one-block sizes exercising the +INF padding
     (1, 33), (3, 37), (1, 129)],
)
def test_minplus_matches_ref(bsz, n):
    rng = np.random.default_rng(n)
    a = rng.uniform(0, 10, size=(bsz, n, n)).astype(np.float32)
    b = rng.uniform(0, 10, size=(bsz, n, n)).astype(np.float32)
    got = minplus(jnp.asarray(a), jnp.asarray(b), interpret=True)
    want = ref.minplus_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_minplus_with_inf_edges():
    from repro.kernels.minplus import INF
    rng = np.random.default_rng(0)
    a = rng.uniform(1, 5, size=(1, 12, 12)).astype(np.float32)
    a[0, rng.uniform(size=(12, 12)) < 0.5] = INF
    np.fill_diagonal(a[0], 0.0)
    got = minplus(jnp.asarray(a), jnp.asarray(a), interpret=True)
    want = ref.minplus_ref(jnp.asarray(a), jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_routing_backend_switch_pallas_matches_jnp():
    """routing_tables_batched(backend="pallas") == the jnp oracle on an
    odd-N (36-tile) spec — the evaluator's TPU hot path, interpreted."""
    import numpy as np_
    from repro.core import random_design, spec_36
    from repro.core import routing
    from repro.core.objectives import design_cost, make_consts

    spec = spec_36()
    c = make_consts(spec)
    rng = np_.random.default_rng(2)
    adjs = jnp.asarray(np_.stack(
        [spec.mesh_design().adj, random_design(spec, rng).adj]))
    costs = jax.vmap(lambda a: design_cost(c, a))(adjs)
    dist_j, nh_j = routing.routing_tables_batched(
        costs, c.apsp_iters, backend="jnp")
    dist_p, nh_p = routing.routing_tables_batched(
        costs, c.apsp_iters, backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(dist_p), np.asarray(dist_j),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nh_p), np.asarray(nh_j))


def test_evaluator_backend_switch_matches_jnp():
    """Evaluator(backend="pallas", interpret=True) reproduces the jnp
    objective rows end-to-end (validity masking included)."""
    from repro.core import Evaluator, random_design, spec_tiny, traffic_matrix

    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    rng = np.random.default_rng(1)
    designs = [spec.mesh_design()] + [random_design(spec, rng)
                                      for _ in range(3)]
    objs_j = Evaluator(spec, f, backend="jnp").batch(designs)
    objs_p = Evaluator(spec, f, backend="pallas", interpret=True).batch(designs)
    np.testing.assert_allclose(objs_p, objs_j, rtol=1e-5, atol=1e-6)


@pytest.mark.interpret
@pytest.mark.parametrize("n", [7, 33, 129])
def test_minplus_apsp_interpret_matches_jnp_oracle(n):
    """Full APSP (repeated blocked min-plus squaring) through the Pallas
    interpreter on CPU vs the vmapped jnp oracle, at odd / padded N — the
    evaluator's whole pallas routing path runs in tier-1, not just on TPU."""
    from repro.core import routing

    rng = np.random.default_rng(n)
    cost = rng.uniform(1, 5, size=(2, n, n)).astype(np.float32)
    cost[rng.uniform(size=cost.shape) < 0.6] = routing.INF  # sparse graphs
    for b in range(cost.shape[0]):
        np.fill_diagonal(cost[b], 0.0)
    n_iters = routing.apsp_iters(n)
    want = routing.apsp_batched(jnp.asarray(cost), n_iters, backend="jnp")
    got = routing.apsp_batched(jnp.asarray(cost), n_iters, backend="pallas",
                               interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.interpret
def test_forest_kernel_interpret_smoke():
    """The blocked forest-traversal kernel runs under the interpreter on a
    multi-block batch (full conformance lives in test_forest_conformance)."""
    from repro.core.forest import RegressionForest

    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(200, 5))
    y = x[:, 0] - x[:, 3] + 0.1 * rng.normal(size=200)
    model = RegressionForest(n_trees=8, max_depth=6, seed=0).fit(x, y)
    xq = rng.uniform(-1, 1, size=(300, 5))
    got = model.predict(xq, backend="pallas", interpret=True)
    np.testing.assert_allclose(got, model.predict(xq, backend="numpy"),
                               rtol=0, atol=1e-6)


def test_minplus_apsp_converges_to_routing_apsp():
    from repro.core import spec_tiny, traffic_matrix
    from repro.core import routing
    from repro.core.objectives import make_consts
    from repro.kernels.ops import apsp as ops_apsp

    spec = spec_tiny()
    c = make_consts(spec)
    d = spec.mesh_design()
    full = jnp.asarray(d.adj) | c.vadj
    n = spec.n_tiles
    cost = jnp.where(full, c.router_stages + c.link_delay, routing.INF)
    cost = jnp.where(jnp.eye(n, dtype=bool), 0.0, cost)
    want = routing.apsp(cost, c.apsp_iters)
    got = ops_apsp(cost[None], c.apsp_iters, interpret=True)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


# ------------------------------------------------------------- link-util
@pytest.mark.parametrize("spec_name", ["tiny", "s16"])
def test_walk_accumulate_matches_ref(spec_name):
    from repro.core import spec_16, spec_tiny, traffic_matrix
    from repro.core import routing
    from repro.core.objectives import make_consts

    spec = {"tiny": spec_tiny, "s16": spec_16}[spec_name]()
    c = make_consts(spec)
    d = spec.mesh_design()
    full = jnp.asarray(d.adj) | c.vadj
    n = spec.n_tiles
    cost = jnp.where(full, c.router_stages + c.link_delay, routing.INF)
    cost = jnp.where(jnp.eye(n, dtype=bool), 0.0, cost)
    dist, nh = routing.routing_tables(cost, c.apsp_iters)
    f = traffic_matrix(spec, "BFS")
    fs = jnp.asarray(f[d.perm][:, d.perm] * (1 - np.eye(n)), jnp.float32)

    hops_k, dsum_k, util_k, visits_k = walk_accumulate(
        nh, fs, c.link_delay, max_hops=c.max_hops, interpret=True
    )
    hops_r, dsum_r, util_r, visits_r = ref.walk_accumulate_ref(
        nh, fs, c.link_delay, max_hops=c.max_hops
    )
    np.testing.assert_allclose(np.asarray(hops_k), np.asarray(hops_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(dsum_k), np.asarray(dsum_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(util_k), np.asarray(util_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(visits_k), np.asarray(visits_r), rtol=1e-4, atol=1e-5)

    # third corner of the conformance triangle: the scalar-loop numpy
    # oracle must agree with both the jnp scatter-add port and the kernel
    # (mirrors the minplus/forest numpy-jnp-pallas triangles).
    hops_n, dsum_n, util_n, visits_n = ref.walk_accumulate_np(
        nh, fs, c.link_delay, max_hops=c.max_hops
    )
    np.testing.assert_allclose(hops_n, np.asarray(hops_r), atol=1e-5)
    np.testing.assert_allclose(dsum_n, np.asarray(dsum_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(util_n, np.asarray(util_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(visits_n, np.asarray(visits_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(util_n, np.asarray(util_k), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,s,dh,causal,window",
    [
        (1, 4, 4, 128, 32, True, None),     # MHA causal
        (2, 4, 2, 128, 16, True, None),     # GQA
        (1, 8, 1, 256, 32, True, None),     # MQA, multi k-block
        (1, 4, 4, 128, 32, False, None),    # bidirectional (encoder)
        (1, 4, 2, 256, 32, True, 64),       # sliding window
    ],
)
def test_flash_attention_matches_ref(b, h, kh, s, dh, causal, window, dtype):
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (b, h, s, dh), dtype)
    k = jax.random.normal(keys[1], (b, kh, s, dh), dtype)
    v = jax.random.normal(keys[2], (b, kh, s, dh), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


# ---------------------------------------------------------------------- ssd
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [(1, 64, 2, 16, 8, 16), (2, 128, 4, 32, 16, 64), (1, 128, 1, 8, 4, 32)],
)
def test_ssd_kernel_matches_sequential_ref(b, s, h, p, n, chunk):
    keys = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(keys[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    bm = jax.random.normal(keys[3], (b, s, n), jnp.float32) * 0.5
    cm = jax.random.normal(keys[4], (b, s, n), jnp.float32) * 0.5
    d = jnp.ones((h,)) * 0.5
    got = ssd(x, dt, a, bm, cm, d, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_ref_matches_sequential():
    keys = jax.random.split(jax.random.PRNGKey(2), 5)
    b, s, h, p, n = 2, 128, 2, 16, 8
    x = jax.random.normal(keys[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    bm = jax.random.normal(keys[3], (b, s, n)) * 0.5
    cm = jax.random.normal(keys[4], (b, s, n)) * 0.5
    d = jnp.full((h,), 0.25)
    got = ref.ssd_chunked_ref(x, dt, a, bm, cm, d, chunk=32)
    want = ref.ssd_ref(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_gradients_flow_through_chunked_ref():
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    b, s, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(keys[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h))) * 0.1
    a = -jnp.exp(jax.random.normal(keys[2], (h,)) * 0.3)
    bm = jax.random.normal(keys[3], (b, s, n)) * 0.5
    cm = jax.random.normal(keys[4], (b, s, n)) * 0.5
    d = jnp.full((h,), 0.25)

    def loss(x_):
        return jnp.sum(ref.ssd_chunked_ref(x_, dt, a, bm, cm, d, chunk=16) ** 2)

    g = jax.grad(loss)(x)
    assert np.all(np.isfinite(np.asarray(g)))
    # Check against the sequential formulation's gradient.
    def loss_seq(x_):
        return jnp.sum(ref.ssd_ref(x_, dt, a, bm, cm, d) ** 2)
    g2 = jax.grad(loss_seq)(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g2), rtol=1e-3, atol=1e-3)
