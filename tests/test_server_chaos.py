"""Service chaos acceptance (DESIGN.md §10): the multi-tenant service
survives worker aborts, hung shards, slow tenants, scripted admission
rejections, and a SIGKILL'd server — and the survivors' results are
byte-identical to an undisturbed run's.

The headline pin: 4 workers, 4 concurrent tenants, one worker abort +
one hung shard scripted into round 0, the server SIGKILLed mid-run and
restarted against the same journal — every request terminates ``done``,
every result's canonical payload matches the uninterrupted reference,
the failed dispatches are charged to exactly the owning requests'
ledgers, and a duplicate submitted to the restarted server is served
from cache with ``n_evals == 0``.
"""

import json

import pytest

from repro.core import spec_tiny
from repro.dist.state import ROUND_TAG_STRIDE
from repro.noc import Budget, NocProblem, RunResult
from repro.noc.server import (Client, NocService, ServiceConfig,
                              SubprocessClient)

SMALL = dict(iters_max=2, n_swaps=4, n_link_moves=4, max_local_steps=5)
REQ_CFG = dict(SMALL, n_workers=2, sync_every=1)

#: round-0 chaos: request seq 0 loses worker 0 to a hard abort, request
#: seq 1's worker 1 hangs past the shard deadline. Wave meta tags are
#: ``seq * ROUND_TAG_STRIDE + worker_id`` — the script targets exactly
#: one request each, and the reseeded retry (attempt 1) runs clean.
CHAOS_FAULTS = (
    {"kind": "abort", "worker_id": 0 * ROUND_TAG_STRIDE + 0,
     "round": 0, "attempt": 0},
    {"kind": "hang", "worker_id": 1 * ROUND_TAG_STRIDE + 1,
     "round": 0, "attempt": 0, "hang_s": 6.0},
)
FLEET = dict(n_workers=4, shard_timeout_s=5.0, max_retries=1)


@pytest.fixture(scope="module")
def tiny_problem() -> NocProblem:
    return NocProblem(spec=spec_tiny(), traffic="BFS", case="case3")


def _payload(res: RunResult) -> str:
    j = res.to_json()
    j["history"] = [[0.0] + row[1:] for row in j["history"]]
    keep = ("problem", "budget", "obj_idx", "designs", "objs", "history",
            "n_evals", "n_calls", "exhausted")
    return json.dumps({k: j[k] for k in keep}, sort_keys=True)


def _submit_tenants(client, problem, n=4):
    """n tenants, one request each (seeds 0..n-1), admission order."""
    ids = {}
    for seed in range(n):
        ack = client.submit(problem.to_json(),
                            Budget(max_evals=120, seed=seed).to_json(),
                            dict(REQ_CFG), tenant=f"t{seed}")
        assert ack["status"] == "queued", ack
        ids[seed] = ack["id"]
    return ids


def test_chaos_kill_and_restart_is_byte_identical(tiny_problem, tmp_path):
    # ---- reference: same fleet, same fault script, never killed -------
    with Client(NocService(ServiceConfig(
            faults=CHAOS_FAULTS, **FLEET))) as ref_client:
        ref_ids = _submit_tenants(ref_client, tiny_problem)
        ref_client.drain()
        ref = {s: _payload(ref_client.result(rid))
               for s, rid in ref_ids.items()}

    # ---- chaos: same script over a real process, SIGKILLed mid-run ----
    jdir = str(tmp_path / "journal")
    c1 = SubprocessClient(jdir, faults=CHAOS_FAULTS, **FLEET)
    ids = _submit_tenants(c1, tiny_problem)
    c1.step()
    c1.step()                  # requests mid-flight, checkpoints on disk
    c1.kill()                  # no flush, no goodbye

    c2 = SubprocessClient(jdir, faults=CHAOS_FAULTS, **FLEET)
    c2.drain()
    try:
        # every request terminated, full results, byte-identical
        results = {}
        for seed, rid in ids.items():
            st = c2.status(rid)
            assert st["status"] in ("done", "partial"), st
            assert st["status"] == "done"
            results[seed] = c2.result(rid)
            assert _payload(results[seed]) == ref[seed]

        # ledgers exact: the abort charged tenant t0's request, the hung
        # shard charged t1's — nobody else's
        f0 = results[0].extra["worker_failures"]
        assert [f["worker_id"] for f in f0] == [0]
        assert f0[0]["phase"] == "run" and f0[0]["round"] == 0
        assert "injected abort" in f0[0]["error"]
        f1 = results[1].extra["worker_failures"]
        assert [f["worker_id"] for f in f1] == [1]
        assert f1[0]["phase"] == "timeout" and f1[0]["round"] == 0
        assert results[2].extra["worker_failures"] == []
        assert results[3].extra["worker_failures"] == []

        # a duplicate against the restarted server: served from cache,
        # zero evals — the original request paid
        dup = c2.submit(tiny_problem.to_json(),
                        Budget(max_evals=120, seed=0).to_json(),
                        dict(REQ_CFG), tenant="t9")
        assert dup["cache_hit"] is True
        hit = c2.result(dup["id"])
        assert hit.n_evals == 0 and hit.extra["cache_hit"] is True
        hj = hit.to_json()
        assert json.dumps(hj["designs"]) == \
            json.dumps(results[0].to_json()["designs"])
    finally:
        c2.close()


def test_kill_server_fault_dies_and_recovers(tiny_problem, tmp_path):
    """The scripted ``kill_server`` fault really dies the serve process
    (after the wave's journal hits disk); a restart against the same
    journal finishes the request identically to an unfaulted run."""
    from repro.noc.server import ServerDied

    with Client.local(n_workers=2) as ref_client:
        bj = Budget(max_evals=120, seed=0).to_json()
        rid = ref_client.submit(tiny_problem.to_json(), bj,
                                dict(REQ_CFG))["id"]
        ref_client.drain()
        want = _payload(ref_client.result(rid))

    jdir = str(tmp_path / "journal")
    c1 = SubprocessClient(jdir, n_workers=2,
                          faults=({"kind": "kill_server", "wave": 1},))
    rid = c1.submit(tiny_problem.to_json(), bj, dict(REQ_CFG))["id"]
    with pytest.raises(ServerDied):
        c1.drain()
    c1.close()

    with SubprocessClient(jdir, n_workers=2) as c2:
        c2.drain()
        assert c2.status(rid)["status"] == "done"
        assert _payload(c2.result(rid)) == want


def test_slow_tenant_degrades_only_itself(tiny_problem):
    """An injected slow tenant blows its own deadline and is finalized
    partial; the fast tenant's result is untouched by the chaos."""
    with Client.local(n_workers=2) as plain:
        bj = Budget(max_evals=120, seed=0).to_json()
        rid = plain.submit(tiny_problem.to_json(), bj, dict(REQ_CFG))["id"]
        plain.drain()
        want = _payload(plain.result(rid))

    faults = ({"kind": "slow_tenant", "tenant": "slow", "wave": 0,
               "hang_s": 0.4},)
    with Client.local(n_workers=2, faults=faults) as c:
        slow = c.submit(tiny_problem.to_json(),
                        Budget(max_evals=120, seed=7).to_json(),
                        dict(REQ_CFG), tenant="slow", deadline_s=0.5)
        fast = c.submit(tiny_problem.to_json(), bj, dict(REQ_CFG),
                        tenant="fast")
        c.drain()
        st = c.status(slow["id"])
        assert st["status"] == "partial" and st["error"] == "deadline"
        res = c.result(slow["id"])
        assert res.extra["partial"] is True
        assert c.status(fast["id"])["status"] == "done"
        assert _payload(c.result(fast["id"])) == want


def test_reject_admission_fault(tiny_problem):
    faults = ({"kind": "reject_admission", "tenant": "mallory"},)
    with Client.local(n_workers=1, faults=faults) as c:
        bj = Budget(max_evals=60, seed=0).to_json()
        rej = c.submit(tiny_problem.to_json(), bj, dict(SMALL),
                       tenant="mallory")
        assert rej["error"]["code"] == "injected_rejection"
        ok = c.submit(tiny_problem.to_json(), bj, dict(SMALL),
                      tenant="alice")
        assert ok["status"] == "queued"
