"""Incremental-evaluation (delta) path: bit-equality against the full
rebuild oracle across random move chains, the blocked dense kernels, the
spec_large tier, and the Evaluator integration.

The delta path must be *bit-equal* to a from-scratch recompute — every
finite hop cost is a small integer (exact in f32/f64) and the shadow
tie-breaker perturbations are a pure function of (n, slot pair), so any
correct shortest-path scheme lands on identical tables. These tests pin
that contract; see DESIGN.md §13 and the notes in core/routing.py.

Property tests need ``hypothesis``; without it they are skipped and the
deterministic seeded chains still run (same pattern as test_pareto)."""

import numpy as np
import pytest

from repro.core import Evaluator, PhvContext, APP_NAMES
from repro.core import routing
from repro.core.local_search import local_search
from repro.core.objectives import CASES, design_cost_np
from repro.core.problem import (sample_neighbor_moves, spec_16, spec_large,
                                spec_tiny)
from repro.core.traffic import avg_traffic

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - property tests skip without it
    st = None


def _iters(n):
    return routing.apsp_iters(n)


def _link_weight(spec, add):
    return float(np.float32(spec.router_stages)
                 + np.float32(spec.link_delay[add[0], add[1]]))


def _chain_check(spec, seed, steps, *, delta_kw=None, require_delta=True):
    """Drive a random link-move chain through delta_link_move and assert
    every HostTables field bit-equal to a scratch host_tables rebuild."""
    rng = np.random.default_rng(seed)
    d = spec.mesh_design()
    t = routing.host_tables(design_cost_np(spec, d.adj), _iters(spec.n_tiles))
    n_delta = 0
    for _ in range(steps):
        mv = sample_neighbor_moves(spec, d, rng, 0, 4)
        if mv.rem.shape[0] == 0:
            continue
        rem, add = tuple(mv.rem[0]), tuple(mv.add[0])
        t2 = routing.delta_link_move(t, rem, add, _link_weight(spec, add),
                                     **(delta_kw or {}))
        d = mv.materialize(0)
        ref = routing.host_tables(design_cost_np(spec, d.adj),
                                  _iters(spec.n_tiles))
        if t2 is None:
            t2 = ref
        else:
            n_delta += 1
            for f in ref._fields:
                assert np.array_equal(getattr(t2, f), getattr(ref, f)), f
        t = t2
    if require_delta:
        assert n_delta > 0  # the chain actually exercised the delta path
    return n_delta


# ------------------------------------------------------- oracle parity
@pytest.mark.parametrize("spec_fn", [spec_tiny, spec_16])
def test_host_tables_bit_equal_device_oracle(spec_fn):
    spec = spec_fn()
    cost = design_cost_np(spec, spec.mesh_design().adj)
    t = routing.host_tables(cost, _iters(spec.n_tiles))
    dist, nh = routing.routing_tables(cost, _iters(spec.n_tiles))
    assert np.array_equal(t.dist, np.asarray(dist))
    assert np.array_equal(t.nh, np.asarray(nh))
    # The shadow metric floors back onto the true f32 distances exactly.
    assert np.array_equal(np.floor(t.dist_t).astype(np.float32), t.dist)


@pytest.mark.parametrize("spec_fn,seed,steps",
                         [(spec_tiny, 0, 40), (spec_16, 1, 40)])
def test_delta_chain_bit_equal_full_rebuild(spec_fn, seed, steps):
    _chain_check(spec_fn(), seed, steps)


def test_delta_chain_bit_equal_spec_large():
    # 256-tile tier: the motivating scale for the delta path.
    _chain_check(spec_large(), 2, 6)


def test_delta_disconnect_then_reconnect():
    """Removing a bridge floods INF into the tables; re-adding it must
    restore them. max_dirty_frac=1.0 forces the delta path through both
    halves instead of falling back."""
    spec = spec_tiny()
    n = spec.n_tiles
    it = _iters(n)
    # A sparse planar layer: a single chain 0-1, 1-2, 2-3 on layer 0 (the
    # vertical TSVs connect the two layers, so 0-1 is a bridge for pairs
    # split across {0} x {1,2,3} columns of each layer).
    adj = np.zeros((n, n), dtype=bool)
    for a, b in [(0, 1), (1, 2), (2, 3)]:
        adj[a, b] = adj[b, a] = True
    t = routing.host_tables(design_cost_np(spec, adj), it)
    assert np.all(t.dist < routing.INF / 2)  # connected to start

    # Move 1: remove (0,1), add (0,2). Mid-move — after the removal phase,
    # before the addition — slot 0 (plus its TSV partner) is cut off from
    # the rest: INF floods those entries, then the added edge pulls them
    # back to finite values.
    t1 = routing.delta_link_move(t, (0, 1), (0, 2),
                                 float(np.float32(spec.router_stages)
                                       + np.float32(spec.link_delay[0, 2])),
                                 max_dirty_frac=1.0)
    adj1 = adj.copy()
    adj1[0, 1] = adj1[1, 0] = False
    adj1[0, 2] = adj1[2, 0] = True
    ref1 = routing.host_tables(design_cost_np(spec, adj1), it)
    assert t1 is not None
    for f in ref1._fields:
        assert np.array_equal(getattr(t1, f), getattr(ref1, f)), f

    # Move 2: the inverse — the chain must land back on the original
    # tables bit-for-bit (same graph => same shadow metric => same floor).
    w01 = float(np.float32(spec.router_stages)
                + np.float32(spec.link_delay[0, 1]))
    t2 = routing.delta_link_move(t1, (0, 2), (0, 1), w01, max_dirty_frac=1.0)
    assert t2 is not None
    for f in t._fields:
        assert np.array_equal(getattr(t2, f), getattr(t, f)), f


def test_delta_fallback_contract():
    """max_dirty_frac=0.0 rejects any move that dirties an entry — the
    caller must get None, never silently-wrong tables."""
    spec = spec_tiny()
    n_delta = _chain_check(spec, 3, 10, delta_kw={"max_dirty_frac": 0.0},
                           require_delta=False)
    assert n_delta == 0


# ------------------------------------------------------- blocked kernels
def test_min_plus_blocked_bit_equal_broadcast():
    rng = np.random.default_rng(5)
    for n, bk in [(7, 2), (37, 8), (64, 64), (33, 128)]:
        a = rng.integers(0, 30, size=(n, n)).astype(np.float32)
        a[rng.random((n, n)) < 0.3] = routing.INF
        b = rng.integers(0, 30, size=(n, n)).astype(np.float32)
        ref = np.asarray(routing.min_plus(a, b))
        got = np.asarray(routing.min_plus_blocked(a, b, block_k=bk))
        assert np.array_equal(got, ref), (n, bk)


def test_blocked_device_path_matches_host_above_dense_nmax():
    """N=300 > DENSE_NMAX: apsp/next_hop dispatch to the k-/j-blocked scan
    paths; they must be bit-equal to the independent host mirrors."""
    n = 300
    rng = np.random.default_rng(6)
    cost = np.full((n, n), routing.INF, dtype=np.float32)
    np.fill_diagonal(cost, 0.0)
    ring = np.arange(n)
    w_ring = rng.integers(1, 30, size=n).astype(np.float32)
    cost[ring, (ring + 1) % n] = w_ring
    cost[(ring + 1) % n, ring] = w_ring
    ii = rng.integers(0, n, size=400)
    jj = rng.integers(0, n, size=400)
    keep = ii != jj
    w = rng.integers(1, 30, size=400).astype(np.float32)
    cost[ii[keep], jj[keep]] = w[keep]
    cost[jj[keep], ii[keep]] = w[keep]
    it = _iters(n)
    t = routing.host_tables(cost, it)
    dist = np.asarray(routing.apsp(cost, it))
    assert np.array_equal(dist, t.dist)
    nh = np.asarray(routing.next_hop(cost, dist))
    assert np.array_equal(nh, t.nh)


def test_pow2_block_bounds():
    for n in [8, 64, 256, 1024, 4096]:
        b = routing._pow2_block(n)
        assert b & (b - 1) == 0 and 4 <= b <= 128
        assert 4 * n * n * b <= routing._BLOCK_BUDGET_BYTES or b == 4


@pytest.mark.slow
def test_1024_tile_blocked_apsp_memory_safe():
    """The 1024-tile stretch tier: blocked APSP must run without an
    (N, N, N) intermediate (4 GiB at f32) and agree with the host path
    on sampled rows."""
    from repro.core.problem import spec_1024

    spec = spec_1024()
    cost = design_cost_np(spec, spec.mesh_design().adj)
    it = _iters(spec.n_tiles)
    dist = np.asarray(routing.apsp(cost, it))
    t = routing.host_tables(cost, it)
    assert np.array_equal(dist, t.dist)


# ------------------------------------------------------- Evaluator wiring
def test_batch_moves_delta_bit_equal_dense():
    spec = spec_16()
    f = avg_traffic(spec, list(APP_NAMES))
    ev_on = Evaluator(spec, f, delta="on")
    ev_off = Evaluator(spec, f, delta="off")
    rng = np.random.default_rng(7)
    d = spec.mesh_design()
    for step in range(4):
        mv = sample_neighbor_moves(spec, d, rng, 5, 5)
        o_on = ev_on.batch_moves(mv)
        o_off = ev_off.batch_moves(mv)
        assert np.array_equal(o_on, o_off), step
        j = int(np.argmin(o_on[:, 2]))
        d = mv.materialize(j)
        ev_on.note_accept(mv, j)
    assert ev_on.delta_stats["delta"] + ev_on.delta_stats["fallback"] > 0
    assert ev_on.delta_stats["swap"] > 0


def test_local_search_trajectory_invariant_to_delta():
    spec = spec_tiny()
    f = avg_traffic(spec, list(APP_NAMES))

    def run(mode):
        ev = Evaluator(spec, f, delta=mode)
        ctx = PhvContext(ev(spec.mesh_design()), CASES["case3"])
        return local_search(spec, ev, ctx, spec.mesh_design(),
                            np.random.default_rng(11),
                            n_swaps=4, n_link_moves=4, max_steps=5)

    r_off, r_on = run("off"), run("on")
    assert np.array_equal(np.asarray(r_off.traj_objs),
                          np.asarray(r_on.traj_objs))
    assert r_off.n_steps == r_on.n_steps


def test_delta_auto_threshold_and_knob_validation():
    spec = spec_16()
    f = avg_traffic(spec, list(APP_NAMES))
    assert not Evaluator(spec, f).delta_on        # 16 < DELTA_AUTO_MIN_TILES
    assert Evaluator(spec, f, delta="on").delta_on
    with pytest.raises(ValueError):
        Evaluator(spec, f, delta="sometimes")
    sl = spec_large()
    ev = Evaluator(sl, avg_traffic(sl, list(APP_NAMES)))
    assert ev.delta_on                            # 256-tile tier: auto-on
    assert ev.max_batch >= 1                      # N-aware batch shrink


def test_spec_large_smoke():
    """The 256-tile tier is a well-formed problem instance."""
    sl = spec_large()
    assert sl.n_tiles == 256
    d = sl.mesh_design()
    # Planar link budget: the mesh seed respects the spec's own budget.
    assert d.adj.sum() // 2 <= sl.n_links
    ev = Evaluator(sl, avg_traffic(sl, list(APP_NAMES)))
    objs = ev(d)
    assert np.all(np.isfinite(objs))


# ------------------------------------------------------- property tests
def _given_chains(max_examples):
    def deco(fn):
        if st is None:
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            return stub
        return settings(max_examples=max_examples, deadline=None)(
            given(st.integers(0, 2 ** 31 - 1), st.integers(1, 12))(fn))
    return deco


@_given_chains(max_examples=15)
def test_delta_chain_property(seed, steps):
    _chain_check(spec_tiny(), seed, steps, require_delta=False)
