"""Flit simulator sanity + the Fig. 4 monotonicity it exists to provide."""

import numpy as np
import pytest

from repro.core import (Evaluator, random_design, spec_16, spec_tiny,
                        traffic_matrix)
from repro.core import netsim


def test_low_load_delivers_offered_traffic():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BP")
    r = netsim.simulate(spec, spec.mesh_design(), f, inj_scale=0.2,
                        cycles=2000, warmup=400, seed=0)
    # At light load, accepted throughput ~= offered (already scale-adjusted).
    assert r["throughput"] == pytest.approx(r["offered"], rel=0.25)
    assert np.isfinite(r["mean_latency"])
    # Latency at least the router pipeline of a 1-hop path.
    assert r["mean_latency"] >= spec.router_stages


def test_saturation_throughput_below_offered():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BP")
    st = netsim.saturation_throughput(spec, spec.mesh_design(), f, cycles=800)
    assert 0 < st < 32.0


def test_fig4_direction_lower_util_higher_throughput():
    """Designs with clearly lower (U-bar, sigma) should not have clearly
    worse saturation throughput — the Fig. 4 inverse relation."""
    spec = spec_16()
    f = traffic_matrix(spec, "BFS")
    ev = Evaluator(spec, f)
    rng = np.random.default_rng(1)
    designs = [spec.mesh_design()] + [random_design(spec, rng) for _ in range(6)]
    objs = ev.batch(designs)
    ok = np.isfinite(objs).all(axis=1)
    designs = [d for d, o in zip(designs, ok) if o]
    objs = objs[ok]
    score = objs[:, 0] + objs[:, 1]  # U-bar + sigma
    ths = np.array([
        netsim.saturation_throughput(spec, d, f, scales=(8.0, 16.0), cycles=900)
        for d in designs
    ])
    # Rank correlation between -(U+sigma) and throughput should be positive.
    a = np.argsort(np.argsort(-score))
    b = np.argsort(np.argsort(ths))
    n = len(ths)
    rho = 1 - 6 * np.sum((a - b) ** 2) / (n * (n**2 - 1))
    assert rho > 0.0
