"""Flit simulator sanity + the Fig. 4 monotonicity it exists to provide,
plus golden-equivalence pins of the vectorized engine against the legacy
reference loop (same seed -> identical statistics)."""

import numpy as np
import pytest

from repro.core import (Evaluator, random_design, spec_16, spec_tiny,
                        traffic_matrix)
from repro.core import netsim


def _assert_same_result(got: dict, want: dict):
    assert got["delivered"] == want["delivered"]
    for k in ("throughput", "offered", "mean_latency", "p99_latency"):
        g, w = float(got[k]), float(want[k])
        if np.isinf(w):
            assert np.isinf(g)
        else:
            assert g == pytest.approx(w, rel=1e-12, abs=1e-12), k


@pytest.mark.parametrize("spec_fn,app", [(spec_tiny, "BP"), (spec_16, "BFS")])
@pytest.mark.parametrize("load", ["light", "saturated"])
def test_vectorized_engine_matches_reference_loop(spec_fn, app, load):
    """Same seed -> same delivered count and latency stats as the legacy
    per-cycle/per-edge Python loop, on mesh and irregular designs."""
    spec = spec_fn()
    f = traffic_matrix(spec, app)
    scale = 0.4 if load == "light" else 12.0 / max(f.sum(), 1e-9)
    rng = np.random.default_rng(5)
    for d in (spec.mesh_design(), random_design(spec, rng)):
        for seed in (0, 3):
            got = netsim.simulate(spec, d, f, inj_scale=scale,
                                  cycles=600, warmup=120, seed=seed)
            want = netsim.simulate_reference(spec, d, f, inj_scale=scale,
                                             cycles=600, warmup=120,
                                             seed=seed)
            _assert_same_result(got, want)


def test_simulate_batch_matches_individual_runs():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BP")
    rng = np.random.default_rng(9)
    designs = [spec.mesh_design(), random_design(spec, rng)]
    scales, seeds = (0.5, 2.0), (0, 4)
    r = netsim.simulate_batch(spec, designs, f, scales=scales, seeds=seeds,
                              cycles=400, warmup=100)
    assert r["throughput"].shape == (2, 2, 2)
    for di, d in enumerate(designs):
        for si, s in enumerate(scales):
            for ki, seed in enumerate(seeds):
                want = netsim.simulate(spec, d, f, inj_scale=s, cycles=400,
                                       warmup=100, seed=seed)
                got = {k: v[di, si, ki] for k, v in r.items()}
                _assert_same_result(got, want)


def test_zero_traffic_returns_idle_network():
    """rate.sum() == 0 used to NaN the injection distribution and crash."""
    spec = spec_tiny()
    z = np.zeros((spec.n_tiles, spec.n_tiles))
    for fn in (netsim.simulate, netsim.simulate_reference):
        r = fn(spec, spec.mesh_design(), z, cycles=300, warmup=50)
        assert r["delivered"] == 0
        assert r["offered"] == 0.0
        assert r["throughput"] == 0.0
        assert np.isinf(r["mean_latency"]) and np.isinf(r["p99_latency"])


def test_host_tables_match_jnp_routing_oracle():
    """The simulator's NumPy next-hop tables must stay bit-identical to the
    routing.py jnp oracle the analytical objectives use — the docstring's
    'same tables' claim, pinned."""
    import jax.numpy as jnp

    from repro.core import routing
    from repro.core.objectives import design_cost, make_consts

    rng = np.random.default_rng(11)
    for spec in (spec_tiny(), spec_16()):
        c = make_consts(spec)
        for d in (spec.mesh_design(), random_design(spec, rng)):
            cost = design_cost(c, jnp.asarray(d.adj))
            dist_j, nh_j = routing.routing_tables(cost, c.apsp_iters)
            tab = netsim._design_tables(spec, d)
            np.testing.assert_array_equal(tab["nh"], np.asarray(nh_j))
            np.testing.assert_array_equal(
                tab["reach"], np.asarray(dist_j) < netsim.INF / 2)


def test_disconnected_design_raises_instead_of_corrupting():
    """Unroutable traffic must fail loudly (the reference loop KeyErrors);
    the batched engine must never index ring buffers with edge_id == -1."""
    spec = spec_tiny()
    f = traffic_matrix(spec, "BP")
    d = spec.mesh_design()
    d.adj[:] = False  # only vertical links remain: disjoint column pairs
    with pytest.raises(ValueError, match="disconnected"):
        netsim.simulate(spec, d, f, cycles=100, warmup=20)


def test_next_hop_tables_are_cached_per_spec_design():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BP")
    d = spec.mesh_design()
    netsim.clear_caches()
    nh1 = netsim._next_hops(spec, d)
    # Sweeping scales/seeds must reuse the cached tables, not rebuild them.
    netsim.saturation_throughput(spec, d, f, cycles=200)
    netsim.simulated_edp(spec, d, f, energy=1.0, cycles=200)
    assert netsim._next_hops(spec, d) is nh1
    assert len(netsim._NH_CACHE) == 1
    # A different design gets its own entry.
    netsim._next_hops(spec, random_design(spec, np.random.default_rng(0)))
    assert len(netsim._NH_CACHE) == 2


def test_low_load_delivers_offered_traffic():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BP")
    r = netsim.simulate(spec, spec.mesh_design(), f, inj_scale=0.2,
                        cycles=2000, warmup=400, seed=0)
    # At light load, accepted throughput ~= offered (already scale-adjusted).
    assert r["throughput"] == pytest.approx(r["offered"], rel=0.25)
    assert np.isfinite(r["mean_latency"])
    # Latency at least the router pipeline of a 1-hop path.
    assert r["mean_latency"] >= spec.router_stages


def test_saturation_throughput_below_offered():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BP")
    st = netsim.saturation_throughput(spec, spec.mesh_design(), f, cycles=800)
    assert 0 < st < 32.0


def test_fig4_direction_lower_util_higher_throughput():
    """Designs with clearly lower (U-bar, sigma) should not have clearly
    worse saturation throughput — the Fig. 4 inverse relation."""
    spec = spec_16()
    f = traffic_matrix(spec, "BFS")
    ev = Evaluator(spec, f)
    rng = np.random.default_rng(1)
    designs = [spec.mesh_design()] + [random_design(spec, rng) for _ in range(6)]
    objs = ev.batch(designs)
    ok = np.isfinite(objs).all(axis=1)
    designs = [d for d, o in zip(designs, ok) if o]
    objs = objs[ok]
    score = objs[:, 0] + objs[:, 1]  # U-bar + sigma
    ths = np.array([
        netsim.saturation_throughput(spec, d, f, scales=(8.0, 16.0), cycles=900)
        for d in designs
    ])
    # Rank correlation between -(U+sigma) and throughput should be positive.
    a = np.argsort(np.argsort(-score))
    b = np.argsort(np.argsort(ths))
    n = len(ths)
    rho = 1 - 6 * np.sum((a - b) ** 2) / (n * (n**2 - 1))
    assert rho > 0.0


def test_nh_cache_is_byte_bounded(monkeypatch):
    """The table cache evicts by *bytes*, not just entry count: with a
    budget that fits one entry, inserting a second evicts the LRU one and
    the byte counter tracks the survivors exactly."""
    spec = spec_tiny()
    netsim.clear_caches()
    d0 = spec.mesh_design()
    e0 = netsim._design_tables(spec, d0)
    assert netsim._nh_cache_nbytes == e0["nbytes"] > 0
    # Budget = exactly one entry's bytes -> the next insert must evict d0.
    monkeypatch.setattr(netsim, "_NH_CACHE_MAX_BYTES", e0["nbytes"])
    d1 = random_design(spec, np.random.default_rng(1))
    e1 = netsim._design_tables(spec, d1)
    assert len(netsim._NH_CACHE) == 1
    assert netsim._nh_cache_nbytes == e1["nbytes"]
    # The most recent entry always survives, even when it alone exceeds
    # the budget (the bound never empties the cache).
    monkeypatch.setattr(netsim, "_NH_CACHE_MAX_BYTES", 0)
    d2 = random_design(spec, np.random.default_rng(2))
    e2 = netsim._design_tables(spec, d2)
    assert len(netsim._NH_CACHE) == 1
    assert netsim._nh_cache_nbytes == e2["nbytes"]
    netsim.clear_caches()
    assert netsim._nh_cache_nbytes == 0 and len(netsim._NH_CACHE) == 0
