"""Benchmark-regression gate: the pure comparison logic of
benchmarks/check_regression.py (the CLI wraps this)."""

import importlib

check_regression = importlib.import_module("benchmarks.check_regression")
compare = check_regression.compare


def test_compare_passes_within_threshold():
    base = {"a_us": 100.0, "b_us": 50.0}
    fresh = {"a_us": 120.0, "b_us": 74.0}
    assert compare(base, fresh, 1.5, tracked=("a_us", "b_us")) == []


def test_compare_flags_slowdown():
    base = {"a_us": 100.0}
    fresh = {"a_us": 151.0}
    problems = compare(base, fresh, 1.5, tracked=("a_us",))
    assert len(problems) == 1 and "a_us" in problems[0]


def test_compare_missing_fresh_key_fails_and_new_baseline_key_skips():
    base = {"a_us": 100.0}
    fresh = {}
    assert len(compare(base, fresh, 1.5, tracked=("a_us",))) == 1
    # tracked key absent from the baseline (older baseline) is skipped
    assert compare({}, {"a_us": 1e9}, 1.5, tracked=("a_us",)) == []


def test_tracked_keys_exist_in_committed_baseline():
    import json
    with open(check_regression.BASELINE) as fh:
        baseline = json.load(fh)
    for key in check_regression.TRACKED:
        assert key in baseline, key
