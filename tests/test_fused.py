"""Fused meta-search scoring (core.fused + kernels/stage_fused) and the
device PHV twin (core.phv_jnp).

Conformance contract (DESIGN.md §12): the fused path computes features in
f32, so at large specs a feature can land within f32 rounding of a forest
threshold and flip a branch — both trajectories are valid surrogate
ascents. At spec_tiny the margins are wide and the parity tests here pin
EXACT agreement: same accepted moves, same designs, same training rows.
The Pallas tail is pinned bit-equal to the jnp tail it replaces (same f32
compares, same first-max tie-break as np.argmax)."""

import numpy as np
import pytest

from repro.core import (CASES, Evaluator, PhvContext, random_design,
                        spec_16, spec_tiny, traffic_matrix)
from repro.core.features import design_features_batch
from repro.core.forest import RegressionForest
from repro.core.fused import (META_BACKENDS, MetaScorer, check_meta_backend,
                              _fused_consts)
from repro.core.pareto import hypervolume_with_batch
from repro.core.phv_jnp import hypervolume_with_batch_jnp
from repro.core.problem import sample_neighbor_moves, sample_neighbors
from repro.core.stage import _meta_greedy, _meta_greedy_host, stage_batch


def _fit_forest(spec, n=60, seed=0):
    """Forest fitted on real featurized designs (realistic thresholds)."""
    rng = np.random.default_rng(seed)
    designs = [random_design(spec, rng) for _ in range(n)]
    x = design_features_batch(spec, designs)
    y = rng.normal(size=n) + x[:, 0]
    return RegressionForest(seed=seed, n_trees=8, max_depth=5).fit(x, y)


# ---------------------------------------------------------------- moves rep
def test_neighbor_moves_match_materialized_designs():
    """materialize_all() reproduces the legacy sample_neighbors stream:
    same rng consumption, same designs in the same (swaps-first) order."""
    spec = spec_tiny()
    for seed in range(4):
        d = random_design(spec, np.random.default_rng(seed))
        moves = sample_neighbor_moves(spec, d, np.random.default_rng(seed + 9),
                                      n_swaps=8, n_link_moves=8)
        legacy = sample_neighbors(spec, d, np.random.default_rng(seed + 9),
                                  n_swaps=8, n_link_moves=8)
        assert len(moves) == len(legacy)
        for j, dl in enumerate(legacy):
            dm = moves.materialize(j)
            assert np.array_equal(dm.perm, dl.perm)
            assert np.array_equal(dm.adj, dl.adj)


def test_meta_backend_validation():
    for b in META_BACKENDS:
        check_meta_backend(b)
    check_meta_backend(None, allow_none=True)
    with pytest.raises(ValueError):
        check_meta_backend("nope")
    with pytest.raises(ValueError):
        check_meta_backend(None)
    # MetaScorer is the device arm only.
    spec = spec_tiny()
    with pytest.raises(ValueError):
        MetaScorer(spec, _fit_forest(spec), backend="host")


# ------------------------------------------------------------ feature twin
@pytest.mark.parametrize("spec_fn", [spec_tiny, spec_16])
def test_fused_features_conform_to_host(spec_fn):
    """Fused f32 featurization of base+move candidates matches the host f64
    design_features_batch of the materialized designs to f32 tolerance."""
    import jax.numpy as jnp

    from repro.core.fused import _fused_features

    spec = spec_fn()
    rng = np.random.default_rng(0)
    d = random_design(spec, rng)
    moves = sample_neighbor_moves(spec, d, rng, n_swaps=6, n_link_moves=6)
    sc = MetaScorer(spec, _fit_forest(spec))
    sa, sb, er, ea = sc._encode(moves)
    base_perm, base_lm, scalars = sc._base_state(d)
    got = np.asarray(_fused_features(sc.c, base_perm, base_lm, scalars,
                                     jnp.asarray(sa), jnp.asarray(sb),
                                     jnp.asarray(er), jnp.asarray(ea)))
    want = design_features_batch(spec, moves.materialize_all())
    b = len(moves)
    np.testing.assert_allclose(got[:b], want, rtol=3e-5, atol=3e-6)
    # Identity-padded tail rows reproduce the base design's features.
    base_feats = design_features_batch(spec, [d])[0]
    for row in got[b:]:
        np.testing.assert_allclose(row, base_feats, rtol=3e-5, atol=3e-6)


def test_score_moves_matches_host_predict():
    """score_moves == argmax of predict(features(materialized designs)),
    and score_base == predict on the base design (spec_tiny, f32 exact)."""
    spec = spec_tiny()
    model = _fit_forest(spec)
    sc = MetaScorer(spec, model)
    rng = np.random.default_rng(3)
    for _ in range(5):
        d = random_design(spec, rng)
        moves = sample_neighbor_moves(spec, d, rng, n_swaps=8, n_link_moves=8)
        if not len(moves):
            continue
        j, vj = sc.score_moves(moves)
        want = model.predict(
            design_features_batch(spec, moves.materialize_all()))
        assert j == int(np.argmax(want))
        assert vj == pytest.approx(float(want.max()), rel=1e-6)
        assert sc.score_base(d) == pytest.approx(
            float(model.predict(design_features_batch(spec, [d]))[0]),
            rel=1e-6)


# ------------------------------------------------------------- meta parity
def test_meta_greedy_fused_matches_host_spec_tiny():
    """Full greedy ascent parity at spec_tiny: identical accepted designs
    for host and fused backends across seeds (identical rng streams)."""
    spec = spec_tiny()
    model = _fit_forest(spec)
    for seed in range(5):
        d0 = random_design(spec, np.random.default_rng(seed))
        d_host = _meta_greedy_host(spec, model, d0,
                                   np.random.default_rng(100 + seed),
                                   n_swaps=8, n_link_moves=8, max_steps=10)
        d_fused = _meta_greedy(spec, model, d0,
                               np.random.default_rng(100 + seed),
                               n_swaps=8, n_link_moves=8, max_steps=10,
                               backend="fused")
        assert d_host.key() == d_fused.key()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_stage_batch_meta_backend_parity_tiny(seed):
    """End-to-end stage_batch equality host vs fused at spec_tiny: same
    global Pareto set (hence equal PHV), same surrogate training rows —
    the equal-PHV-at-equal-budget leg of the PR-9 acceptance check."""
    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    outs = {}
    for mb in ("host", "fused"):
        res = stage_batch(spec, f, n_starts=2, seed=seed, iters_max=3,
                          n_swaps=6, n_link_moves=6, max_local_steps=10,
                          meta_backend=mb)
        outs[mb] = res
    h, g = outs["host"], outs["fused"]
    assert sorted(d.key() for d in h.global_set.designs) == \
        sorted(d.key() for d in g.global_set.designs)
    # Equal eval budget: both arms visited the same number of designs.
    # Full row-for-row trajectory equality is NOT asserted here — CART
    # thresholds land exactly on discrete training feature values, so a
    # 1-ulp f32-vs-f64 difference can flip a knife-edge accept mid-run
    # without changing the front (single-call trajectory parity is pinned
    # separately by test_meta_greedy_fused_matches_host_spec_tiny).
    assert h.x_train.shape == g.x_train.shape
    assert h.y_train.shape == g.y_train.shape


# --------------------------------------------------------- jit-cache churn
def test_score_jit_one_compile_per_padded_shape():
    """Neighborhood sizes that pad to the same power of two share one
    compile — the fused scorer cannot retrace per neighborhood (the PR-4
    shape-cache discipline)."""
    from repro.core import fused as fused_mod

    spec = spec_tiny()
    model = _fit_forest(spec)
    sc = MetaScorer(spec, model)
    rng = np.random.default_rng(0)
    d = random_design(spec, rng)

    fn = fused_mod._SCORE_JIT
    before = fn._cache_size()
    sizes = []
    for ns, nl in [(5, 4), (4, 4), (6, 2), (3, 5), (7, 1)]:
        moves = sample_neighbor_moves(spec, d, rng, n_swaps=ns,
                                      n_link_moves=nl)
        sizes.append(len(moves))
        sc.score_moves(moves)
    pads = {1 << max(0, (s - 1).bit_length()) for s in sizes}
    assert fn._cache_size() - before <= len(pads)
    # And repeating the largest neighborhood adds nothing.
    mid = fn._cache_size()
    for _ in range(3):
        sc.score_moves(sample_neighbor_moves(spec, d, rng, n_swaps=7,
                                             n_link_moves=1))
    assert fn._cache_size() == mid


# -------------------------------------------------------------- pallas arm
@pytest.mark.interpret
@pytest.mark.parametrize("nsl", [(1, 0), (3, 2), (8, 8), (24, 24)])
def test_pallas_score_interpret_matches_jnp(nsl):
    """fused-pallas (interpret) returns the same (argmax, value) as the jnp
    tail at odd / padded / multi-block batch sizes."""
    spec = spec_tiny()
    model = _fit_forest(spec)
    sc_j = MetaScorer(spec, model, backend="fused")
    sc_p = MetaScorer(spec, model, backend="fused-pallas", interpret=True)
    assert sc_p.pallas  # interpret mode always resolves to the kernel
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)
    ns, nl = nsl
    for _ in range(3):
        d = random_design(spec, np.random.default_rng(11))
        mv_a = sample_neighbor_moves(spec, d, rng_a, n_swaps=ns,
                                     n_link_moves=nl)
        mv_b = sample_neighbor_moves(spec, d, rng_b, n_swaps=ns,
                                     n_link_moves=nl)
        if not len(mv_a):
            continue
        j_j, v_j = sc_j.score_moves(mv_a)
        j_p, v_p = sc_p.score_moves(mv_b)
        assert j_p == j_j
        assert v_p == pytest.approx(v_j, rel=1e-6, abs=1e-7)
    assert sc_p.pallas  # no silent fallback happened


@pytest.mark.interpret
def test_meta_greedy_pallas_matches_fused():
    """backend='fused-pallas' (interpret) walks the same trajectory as
    'fused' — the kernel argmax semantics match the host prefix argmax."""
    spec = spec_tiny()
    model = _fit_forest(spec)
    d0 = random_design(spec, np.random.default_rng(2))
    d_f = _meta_greedy(spec, model, d0, np.random.default_rng(42),
                       n_swaps=8, n_link_moves=8, max_steps=8,
                       backend="fused")
    sc = MetaScorer(spec, model, backend="fused-pallas", interpret=True)
    d_p = _meta_greedy(spec, model, d0, np.random.default_rng(42),
                       n_swaps=8, n_link_moves=8, max_steps=8,
                       backend="fused-pallas", scorer=sc)
    assert d_f.key() == d_p.key()


def test_pallas_off_tpu_falls_back_to_jnp_tail():
    """Explicit fused-pallas without interpret on a TPU-less host resolves
    to the jnp tail at construction (forest backend fallback contract)."""
    import jax

    spec = spec_tiny()
    sc = MetaScorer(spec, _fit_forest(spec), backend="fused-pallas")
    on_tpu = jax.default_backend() == "tpu"
    assert sc.pallas == on_tpu


# ------------------------------------------------------------ PHV jnp twin
@pytest.mark.parametrize("m", [1, 2, 3, 4])
def test_phv_jnp_twin_conforms(m):
    """Device twin vs host f64 oracle at m=1..4, including dominated rows,
    duplicates, and candidates beyond ref."""
    rng = np.random.default_rng(m)
    ref = np.full(m, 1.6)
    pts = rng.uniform(0.2, 1.5, size=(9, m))
    pts = np.vstack([pts, pts[:2]])           # duplicates
    cands = rng.uniform(0.1, 1.9, size=(13, m))  # some beyond ref
    want = hypervolume_with_batch(pts, cands, ref)
    got = hypervolume_with_batch_jnp(pts, cands, ref)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_phv_jnp_twin_empty_set():
    ref = np.full(3, 1.6)
    cands = np.random.default_rng(0).uniform(0.2, 1.5, size=(5, 3))
    want = hypervolume_with_batch(np.zeros((0, 3)), cands, ref)
    got = hypervolume_with_batch_jnp(np.zeros((0, 3)), cands, ref)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_phv_context_backend_knob():
    """PhvContext(phv_backend='jnp') routes phv_with_batch through the twin
    (f32-close to host) while scalar phv stays host-exact; bad names raise
    at construction."""
    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    ev = Evaluator(spec, f)
    mesh_objs = ev(spec.mesh_design())
    with pytest.raises(ValueError):
        PhvContext(mesh_objs, CASES["case3"], phv_backend="cuda")
    ctx_h = PhvContext(mesh_objs, CASES["case3"])
    ctx_j = PhvContext(mesh_objs, CASES["case3"], phv_backend="jnp")
    rng = np.random.default_rng(1)
    objs = ev.batch([random_design(spec, rng) for _ in range(6)])
    want = ctx_h.phv_with_batch(objs[:4], objs[4:])
    got = ctx_j.phv_with_batch(objs[:4], objs[4:])
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)
    assert ctx_j.phv(objs) == ctx_h.phv(objs)  # scalar path is shared


# ------------------------------------------------------------- spmd parity
def test_spmd_evaluator_matches_serial():
    """Evaluator built under spmd_scope (1 device here) is bit-equal to the
    plain path — sharding the batch axis reorders no reductions."""
    from repro.core.evaluate import make_spmd_mesh, spmd_scope

    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    rng = np.random.default_rng(5)
    designs = [random_design(spec, rng) for _ in range(6)]
    ev = Evaluator(spec, f)
    with spmd_scope(make_spmd_mesh()):
        ev_s = Evaluator(spec, f)
    assert ev_s._spmd_fn is not None and ev._spmd_fn is None
    a, aux_a = ev.batch_aux(designs)
    b, aux_b = ev_s.batch_aux(designs)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(aux_a["net_lat"], aux_b["net_lat"])


@pytest.mark.slow
def test_spmd_multi_device_subprocess():
    """4 host devices (XLA_FLAGS) — the spmd evaluator and the 'spmd' dist
    executor both reproduce the serial numbers exactly."""
    import os
    import subprocess
    import sys

    code = """
import numpy as np
from repro.core import Evaluator, random_design, spec_tiny, traffic_matrix
from repro.core.evaluate import make_spmd_mesh, spmd_scope
import jax
assert jax.device_count() == 4, jax.device_count()
spec = spec_tiny()
f = traffic_matrix(spec, "BFS")
rng = np.random.default_rng(0)
designs = [random_design(spec, rng) for _ in range(6)]
want = Evaluator(spec, f).batch(designs)
with spmd_scope(make_spmd_mesh()):
    ev = Evaluator(spec, f)
got = ev.batch(designs)  # pads 6 -> 8, divisible by 4 devices
np.testing.assert_array_equal(want, got)
print("SPMD-OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=".",
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SPMD-OK" in out.stdout


def test_dist_spmd_executor_matches_serial():
    """run_dist(executor='spmd') reproduces executor='serial' exactly on a
    single device (in-order shards, one mesh program per dispatch)."""
    from repro.dist import run_dist
    from repro.noc.api import Budget, NocProblem
    from repro.noc.optimizers import StageDistConfig

    problem = NocProblem(spec_tiny(), traffic="BFS")
    budget = Budget(max_evals=60, seed=0)
    cfg_s = StageDistConfig(n_workers=2, executor="serial", iters_max=2,
                            n_swaps=4, n_link_moves=4, max_local_steps=6)
    cfg_m = StageDistConfig(n_workers=2, executor="spmd", iters_max=2,
                            n_swaps=4, n_link_moves=4, max_local_steps=6)
    r_s = run_dist(problem, budget, cfg_s)
    r_m = run_dist(problem, budget, cfg_m)
    np.testing.assert_array_equal(r_s.objs, r_m.objs)
    assert r_s.n_evals == r_m.n_evals
