"""The multi-tenant NoC-optimization service (DESIGN.md §10).

Contract under test, layer by layer:

* admission — malformed problems/budgets/configs are rejected at the
  door as structured ``{"error": {"code", "message"}}`` dicts, never by
  crashing a worker; bounded queue + per-tenant caps are backpressure.
* cache — the canonical request key is invariant to JSON dict ordering,
  float spelling, and omitted back-compat defaults; a duplicate request
  is served at submit time with ``n_evals == 0``; a different seed is a
  different request; partial results never enter the cache.
* degradation — deadlines and cancellation finalize a running request
  as its best-so-far front with ``extra["partial"] = True``.
* equality — one service request is byte-identical (canonical payload,
  wall zeroed) to the same run through ``run(..., "stage_dist")``.
* journal — stale ``tmp.*`` sweep parity, completed-checkpoint gc, and
  the crash-recovery matrix (result-committed-but-status-unflipped is
  adopted as done; a mid-write crash leaves only a swept tmp).
"""

import json
import os

import pytest

from repro.core import spec_tiny
from repro.noc import Budget, NocProblem, RunResult, run
from repro.noc.optimizers import StageDistConfig
from repro.noc.server import (Client, NocService, RequestJournal,
                              ServiceConfig, canonical_request_key,
                              normalize_config, serve_stdio,
                              validate_request)

SMALL = dict(iters_max=2, n_swaps=4, n_link_moves=4, max_local_steps=5)


@pytest.fixture(scope="module")
def tiny_problem() -> NocProblem:
    return NocProblem(spec=spec_tiny(), traffic="BFS", case="case3")


def _payload(res: RunResult) -> str:
    """Canonical payload (the test_dist canon): wall zeroed everywhere —
    history column 0 is a wall-clock stamp — header fields excluded."""
    j = res.to_json()
    j["history"] = [[0.0] + row[1:] for row in j["history"]]
    keep = ("problem", "budget", "obj_idx", "designs", "objs", "history",
            "n_evals", "n_calls", "exhausted")
    return json.dumps({k: j[k] for k in keep}, sort_keys=True)


def _norm(problem, budget=None, **cfg):
    """Admission pipeline shorthand → (normalized cfg, key)."""
    b = budget if budget is not None else Budget(max_evals=60, seed=0)
    c = normalize_config(StageDistConfig(**cfg), executor="serial",
                         shard_timeout_s=None, max_retries=1,
                         retry_backoff_s=0.0)
    return c, canonical_request_key(problem, b, c)


# ==========================================================================
# admission control + backpressure
# ==========================================================================
def test_admission_structured_errors(tiny_problem):
    pj = tiny_problem.to_json()
    bj = Budget(max_evals=60, seed=0).to_json()
    with Client.local(n_workers=1) as c:
        assert c.submit("nope", bj)["error"]["code"] == "invalid_problem"
        assert c.submit({"spec": {"nx": -3}}, bj
                        )["error"]["code"] == "invalid_problem"
        assert c.submit(pj, [1, 2])["error"]["code"] == "invalid_budget"
        # unbounded budgets would hold fleet slots forever
        unbounded = c.submit(pj, {"max_evals": None, "max_calls": None,
                                  "seed": 0})
        assert unbounded["error"]["code"] == "invalid_budget"
        assert "bounded" in unbounded["error"]["message"]
        assert c.submit(pj, bj, {"sync_every": "lots"}
                        )["error"]["code"] == "invalid_config"
        owned = c.submit(pj, bj, {"checkpoint_dir": "/tmp/x"})
        assert owned["error"]["code"] == "invalid_config"
        assert "service-owned" in owned["error"]["message"]
        assert c.submit(pj, bj, deadline_s=-1.0
                        )["error"]["code"] == "invalid_deadline"
        ok = c.submit(pj, bj, dict(SMALL), request_id="r0")
        assert ok == {"id": "r0", "status": "queued", "cache_hit": False}
        assert c.submit(pj, bj, request_id="r0"
                        )["error"]["code"] == "duplicate_id"
        # unknown ids are structured errors on every query surface
        for resp in (c.status("ghost"), c.result("ghost"), c.cancel("ghost")):
            assert resp["error"]["code"] == "unknown_request"


def test_backpressure_queue_and_tenant_caps(tiny_problem):
    pj = tiny_problem.to_json()
    cfg = ServiceConfig(n_workers=1, max_queue=2, max_inflight_per_tenant=1)
    with Client(NocService(cfg)) as c:
        def sub(seed, tenant):
            return c.submit(pj, Budget(max_evals=60, seed=seed).to_json(),
                            dict(SMALL), tenant=tenant)

        assert sub(0, "alice")["status"] == "queued"
        # per-tenant cap fires before the queue bound
        assert sub(1, "alice")["error"]["code"] == "tenant_cap"
        assert sub(1, "bob")["status"] == "queued"
        assert sub(2, "carol")["error"]["code"] == "queue_full"
        c.drain()                       # completion frees the slots
        assert sub(2, "carol")["status"] == "queued"


# ==========================================================================
# canonical request key + result cache
# ==========================================================================
def test_key_invariant_to_dict_ordering(tiny_problem):
    pj = tiny_problem.to_json()
    shuffled = json.loads(json.dumps(
        {k: pj[k] for k in reversed(list(pj))}))
    p1, b1, c1 = validate_request(pj, {"max_evals": 60, "seed": 0})
    p2, b2, c2 = validate_request(shuffled, {"seed": 0, "max_evals": 60})
    assert canonical_request_key(p1, b1, c1) == \
        canonical_request_key(p2, b2, c2)


def test_key_invariant_to_float_spelling(tiny_problem):
    # "60", "60.0" and "6e1" are the same budget — JSON spelling must
    # not split the cache.
    keys = set()
    for text in ('{"max_evals": 60, "seed": 0}',
                 '{"max_evals": 60.0, "seed": 0}',
                 '{"max_evals": 6e1, "seed": 0}'):
        _, b, c = validate_request(tiny_problem.to_json(), json.loads(text))
        keys.add(canonical_request_key(tiny_problem, b, c))
    assert len(keys) == 1


def test_key_invariant_to_backcompat_defaults(tiny_problem):
    pj = tiny_problem.to_json()
    bare = {k: v for k, v in pj.items()
            if k not in ("backend", "forest_backend")}
    p1, b1, c1 = validate_request(pj, {"max_evals": 60, "seed": 0}, {})
    p2, b2, c2 = validate_request(bare, {"max_evals": 60, "seed": 0},
                                  {"n_workers": 4})   # 4 is the default
    assert canonical_request_key(p1, b1, c1) == \
        canonical_request_key(p2, b2, c2)


def test_key_distinguishes_seed_and_trajectory(tiny_problem):
    _, k0 = _norm(tiny_problem, Budget(max_evals=60, seed=0))
    _, k1 = _norm(tiny_problem, Budget(max_evals=60, seed=1))
    _, k2 = _norm(tiny_problem, Budget(max_evals=60, seed=0), iters_max=7)
    assert len({k0, k1, k2}) == 3
    # fleet knobs change where a request runs, never what it returns
    c_a = normalize_config(StageDistConfig(), executor="serial",
                           shard_timeout_s=None, max_retries=1,
                           retry_backoff_s=0.0)
    c_b = normalize_config(StageDistConfig(), executor="jax",
                           shard_timeout_s=9.0, max_retries=3,
                           retry_backoff_s=0.5)
    b = Budget(max_evals=60, seed=0)
    assert canonical_request_key(tiny_problem, b, c_a) == \
        canonical_request_key(tiny_problem, b, c_b)


def test_duplicate_served_from_cache(tiny_problem):
    pj = tiny_problem.to_json()
    bj = Budget(max_evals=60, seed=0).to_json()
    with Client.local(n_workers=2) as c:
        first = c.submit(pj, bj, dict(SMALL))
        c.drain()
        orig = c.result(first["id"])
        # dict-reordered + float-spelled duplicate: served at the door
        dup = c.submit({k: pj[k] for k in reversed(list(pj))},
                       json.loads('{"max_evals": 6e1, "seed": 0}'),
                       dict(SMALL))
        assert dup["status"] == "done" and dup["cache_hit"] is True
        hit = c.result(dup["id"])
        assert hit.n_evals == 0 and hit.n_calls == 0 and hit.wall_s == 0.0
        assert hit.extra["cache_hit"] is True
        hj, oj = hit.to_json(), orig.to_json()
        assert hj["designs"] == oj["designs"] and hj["objs"] == oj["objs"]
        # a different seed is a different request — no hit
        miss = c.submit(pj, Budget(max_evals=60, seed=1).to_json(),
                        dict(SMALL))
        assert miss["cache_hit"] is False and miss["status"] == "queued"


# ==========================================================================
# deadlines, cancellation, graceful degradation
# ==========================================================================
def test_deadline_finalizes_partial(tiny_problem):
    pj = tiny_problem.to_json()
    with Client.local(n_workers=2) as c:
        ack = c.submit(pj, Budget(max_evals=10_000, seed=0).to_json(),
                       dict(SMALL, iters_max=50), deadline_s=1e-3)
        c.drain()
        st = c.status(ack["id"])
        assert st["status"] == "partial" and st["error"] == "deadline"
        res = c.result(ack["id"])
        assert res.extra["partial"] is True and res.extra["note"] == "deadline"
        assert res.exhausted is True
        # partial results never enter the cache: a full-budget twin
        # must not be served a truncated front
        dup = c.submit(pj, Budget(max_evals=10_000, seed=0).to_json(),
                       dict(SMALL, iters_max=50))
        assert dup["cache_hit"] is False


def test_cancel_queued_and_running(tiny_problem):
    pj = tiny_problem.to_json()
    with Client.local(n_workers=2) as c:
        q = c.submit(pj, Budget(max_evals=60, seed=0).to_json(), dict(SMALL))
        assert c.cancel(q["id"])["status"] == "cancelled"
        assert c.result(q["id"])["error"]["code"] == "request_failed"
        r = c.submit(pj, Budget(max_evals=60, seed=1).to_json(), dict(SMALL))
        c.step()                               # one wave: now running
        st = c.cancel(r["id"])
        assert st["status"] == "partial" and st["error"] == "cancelled"
        res = c.result(r["id"])
        assert isinstance(res, RunResult) and res.extra["partial"] is True
        assert len(res.designs) >= 1           # best-so-far, not empty
        assert not c.step()                    # slots reclaimed: idle


# ==========================================================================
# equality with the single-request driver
# ==========================================================================
def test_service_request_matches_run_dist(tiny_problem):
    cfg = dict(SMALL, n_workers=2, sync_every=1)
    budget = Budget(max_evals=120, seed=0)
    ref = run(tiny_problem, "stage_dist", budget=budget, config=cfg)
    with Client.local(n_workers=2) as c:
        ack = c.submit(tiny_problem.to_json(), budget.to_json(), cfg)
        c.drain()
        svc = c.result(ack["id"])
    assert _payload(svc) == _payload(ref)


# ==========================================================================
# journal: sweep parity, gc, crash-recovery matrix
# ==========================================================================
def test_journal_sweeps_stale_tmp_everywhere(tmp_path):
    root = tmp_path / "journal"
    j = RequestJournal(str(root))
    j.save_request({"id": "r0", "seq": 0, "status": "queued"})
    # a crash mid-write leaves tmp orphans in the root and in req dirs
    (root / "tmp.abc.request.json").write_text("{torn")
    (root / "req_000000" / "tmp.def.result.json").write_text("{torn")
    j2 = RequestJournal(str(root))
    assert not list(root.glob("**/tmp.*"))
    assert j2.load_request(0)["id"] == "r0"     # real record untouched


def test_journal_gc_keeps_last_k(tmp_path):
    j = RequestJournal(str(tmp_path / "journal"))
    for seq in range(5):
        status = "done" if seq < 4 else "running"
        j.save_request({"id": f"r{seq}", "seq": seq, "status": status})
        os.makedirs(j.rounds_dir(seq), exist_ok=True)
    removed = j.gc_completed(keep=2)
    assert removed == [0, 1]
    # terminal 2, 3 keep their rounds; running 4 is never touched
    assert [seq for seq in range(5)
            if os.path.isdir(j.rounds_dir(seq))] == [2, 3, 4]
    assert j.gc_completed(keep=2) == []          # idempotent
    # records + results survive gc — they are the cache
    assert j.load_request(0)["id"] == "r0"


def test_service_gcs_completed_checkpoints(tiny_problem, tmp_path):
    cfg = ServiceConfig(n_workers=1, journal_dir=str(tmp_path / "j"),
                        keep_completed=1, max_inflight_per_tenant=3)
    with Client(NocService(cfg)) as c:
        pj = tiny_problem.to_json()
        for seed in range(3):
            ack = c.submit(pj, Budget(max_evals=60, seed=seed).to_json(),
                           dict(SMALL))
            assert ack["status"] == "queued", ack
        c.drain()
        j = c.service.journal
        kept = [seq for seq in j.seqs() if os.path.isdir(j.rounds_dir(seq))]
        assert kept == [2]                       # only the newest
        assert all(j.load_result(seq) is not None for seq in range(3))


def test_recovery_matrix_in_process(tiny_problem, tmp_path):
    """queued→requeue, running+ckpt→restore, done→cache; the resumed
    service's results are byte-identical to the uninterrupted run's."""
    pj = tiny_problem.to_json()
    budgets = [Budget(max_evals=120, seed=s) for s in (0, 1, 2)]
    cfg = dict(SMALL, sync_every=1, n_workers=2)

    ref = {}
    with Client.local(n_workers=2, max_inflight_per_tenant=3) as c:
        for b in budgets:
            ack = c.submit(pj, b.to_json(), cfg)
            ref[b.seed] = ack["id"]
        c.drain()
        ref = {s: _payload(c.result(rid)) for s, rid in ref.items()}

    jdir = str(tmp_path / "j")
    svc = NocService(ServiceConfig(n_workers=2, journal_dir=jdir,
                                   max_inflight_per_tenant=3))
    ids = {}
    for b in budgets[:2]:
        ids[b.seed] = svc.submit(pj, b.to_json(), cfg)["id"]
    svc.step()                                   # seeds 0,1 now running
    svc.shutdown()                               # "crash" at a wave boundary

    svc2 = NocService(ServiceConfig(n_workers=2, journal_dir=jdir,
                                    max_inflight_per_tenant=3))
    # a request admitted before the crash but never started: queued
    ids[2] = svc2.submit(pj, budgets[2].to_json(), cfg)["id"]
    assert svc2.status(ids[0])["status"] == "running"
    assert svc2.status(ids[0])["rounds_done"] >= 1   # restored, not reset
    svc2.run_until_idle()
    for s in (0, 1, 2):
        assert _payload(svc2.result(ids[s])) == ref[s]
    svc2.shutdown()


def test_recovery_adopts_committed_result(tiny_problem, tmp_path):
    """Crash between the result write (the commit point) and the status
    flip: recovery adopts the request as completed, replaying nothing."""
    jdir = str(tmp_path / "j")
    pj = tiny_problem.to_json()
    bj = Budget(max_evals=60, seed=0).to_json()
    with Client(NocService(ServiceConfig(
            n_workers=1, journal_dir=jdir))) as c:
        rid = c.submit(pj, bj, dict(SMALL))["id"]
        c.drain()
        want = _payload(c.result(rid))
        j = c.service.journal
        rec = j.load_request(0)
        rec["status"] = "running"                # un-flip: simulate the crash
        j.save_request(rec)

    svc2 = NocService(ServiceConfig(n_workers=1, journal_dir=jdir))
    assert svc2.status(rid)["status"] == "done"
    assert _payload(svc2.result(rid)) == want
    # ... and the adopted result re-seeds the cache
    dup = svc2.submit(pj, bj, dict(SMALL))
    assert dup["cache_hit"] is True
    svc2.shutdown()


def test_crash_mid_request_write_recovers(tiny_problem, tmp_path):
    """A server killed mid-``request.json`` write leaves a tmp orphan and
    the previous record — recovery sweeps the tmp and resumes from the
    last durable state."""
    jdir = str(tmp_path / "j")
    pj = tiny_problem.to_json()
    svc = NocService(ServiceConfig(n_workers=1, journal_dir=jdir))
    rid = svc.submit(pj, Budget(max_evals=60, seed=0).to_json(),
                     dict(SMALL))["id"]
    svc.step()
    svc.shutdown()
    # torn write: a tmp the atomic rename never happened for
    j = RequestJournal(jdir)
    torn = os.path.join(j.req_dir(0), "tmp.xyz.request.json")
    with open(torn, "w") as fh:
        fh.write('{"id": "r0", "status": "don')

    svc2 = NocService(ServiceConfig(n_workers=1, journal_dir=jdir))
    assert not os.path.exists(torn)
    assert svc2.status(rid)["status"] == "running"
    svc2.run_until_idle()
    assert svc2.status(rid)["status"] == "done"
    svc2.shutdown()


# ==========================================================================
# stdio protocol plumbing
# ==========================================================================
def test_serve_stdio_protocol(tiny_problem):
    import io

    pj, bj = tiny_problem.to_json(), Budget(max_evals=60, seed=0).to_json()
    lines = [
        "this is not json",
        json.dumps({"op": "frobnicate"}),
        json.dumps({"op": "submit", "problem": pj, "budget": bj,
                    "config": dict(SMALL), "request_id": "r0"}),
        json.dumps({"op": "drain"}),
        json.dumps({"op": "result", "id": "r0"}),
        json.dumps({"op": "shutdown"}),
        json.dumps({"op": "status"}),            # after shutdown: unread
    ]
    out = io.StringIO()
    serve_stdio(NocService(ServiceConfig(n_workers=1)),
                stdin=io.StringIO("\n".join(lines) + "\n"), stdout=out)
    got = [json.loads(line) for line in out.getvalue().splitlines()]
    assert [g.get("error", {}).get("code") for g in got[:2]] == \
        ["bad_json", "unknown_op"]
    assert got[2] == {"id": "r0", "status": "queued", "cache_hit": False}
    assert got[3]["by_status"] == {"done": 1}
    assert RunResult.from_json(got[4]["result"]).n_evals > 0
    assert got[5] == {"ok": True}
    assert len(got) == 6                         # loop ended at shutdown
