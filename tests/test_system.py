"""End-to-end behaviour tests for the paper's system.

1. The full §6 loop at container scale: MOO-STAGE on a small heterogeneous
   system produces designs that beat the 3D mesh on EDP, and the throughput
   proxy (falling U-bar/sigma) is confirmed by the independent flit-level
   simulator (the paper's Fig. 4 protocol).
2. The application-agnostic claim (§6.4): a design optimized on aggregate
   traffic stays close to application-specific designs.
"""

import numpy as np
import pytest

from repro.core import (CASES, Evaluator, PhvContext, spec_16, spec_tiny,
                        traffic_matrix)
from repro.core import netsim
from repro.core.agnostic import (OptimizeBudget, optimize_for_traffic,
                                 run_agnostic_study, summarize)
from repro.core.stage import moo_stage


def test_end_to_end_stage_beats_mesh_and_netsim_confirms():
    spec = spec_16()
    f = traffic_matrix(spec, "BFS")
    ev = Evaluator(spec, f)
    mesh = spec.mesh_design()
    ctx = PhvContext(ev(mesh), CASES["case3"])
    res = moo_stage(spec, ev, ctx, mesh, seed=0, iters_max=3, n_swaps=12,
                    n_link_moves=12, max_local_steps=20)
    edps = [ev.edp(d) for d in res.global_set.designs]
    best = res.global_set.designs[int(np.argmin(edps))]
    assert min(edps) < ev.edp(mesh)  # analytic EDP improves over mesh

    # Independent validation (netsim): the optimized design should reach at
    # least the mesh's saturation throughput (it was optimized for U/sigma).
    st_mesh = netsim.saturation_throughput(spec, mesh, f, cycles=1200)
    st_best = netsim.saturation_throughput(spec, best, f, cycles=1200)
    assert st_best >= 0.85 * st_mesh

    # And its objectives really do have lower U-bar (the proxy the paper
    # validates in Fig. 4).
    assert ev(best)[0] <= ev(mesh)[0]


def test_application_agnostic_small():
    spec = spec_tiny()
    apps = ("BFS", "HS", "NW")
    budget = OptimizeBudget(iters_max=2, n_swaps=8, n_link_moves=8,
                            max_local_steps=10)
    result = run_agnostic_study(spec, apps, "case3", budget)
    s = summarize(result)
    # Cross-application degradation exists but is bounded (paper: a few %;
    # we allow a loose bound at this tiny scale and budget).
    assert s["app_specific_avg_degradation"] < 1.0
    assert result["table"].shape == (3, 3)
    np.testing.assert_allclose(np.diag(result["table"]), 1.0, atol=1e-9)
    # AVG NoC is within a factor of the app-specific NoCs on average.
    assert s["avg_noc_degradation"] < 1.0


def test_case4_thermal_only_runs():
    spec = spec_tiny()
    f = traffic_matrix(spec, "PF")
    d, objs, ev = optimize_for_traffic(
        spec, f, "case4", OptimizeBudget(iters_max=2, max_local_steps=8)
    )
    mesh_t = ev(spec.mesh_design())[4]
    assert objs[4] <= mesh_t  # thermal-only optimization cools the chip
