"""Flat struct-of-arrays forest: golden equivalence against the recursive
traversal (``predict_reference``), degenerate fits, backends, and the
batched feature extractor.

Property tests need ``hypothesis``; without it they are skipped and the
unit tests still run (same pattern as test_pareto)."""

import numpy as np
import pytest

from repro.core import random_design, spec_tiny
from repro.core.features import (FEATURE_NAMES, design_features,
                                 design_features_batch)
from repro.core.forest import RegressionForest, resolve_forest_backend

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - property tests skip without it
    st = None


def _fit(n=200, f=5, seed=0, **kw):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(n, f))
    y = x[:, 0] * 2 + np.sin(3 * x[:, 1]) + 0.1 * rng.normal(size=n)
    return RegressionForest(seed=seed, **kw).fit(x, y), rng


# ------------------------------------------------------------------ golden
def test_flat_predict_bit_equal_reference():
    model, rng = _fit(n=400, f=7, n_trees=12, max_depth=7)
    xq = rng.uniform(-1.5, 1.5, size=(513, 7))  # odd batch, extrapolation
    ref = model.predict_reference(xq)
    assert np.array_equal(model.predict(xq, backend="numpy"), ref)


def test_flat_predict_bit_equal_both_batch_layouts():
    # The numpy path switches layout at 1024 samples — check both sides.
    model, rng = _fit(n=300, f=4, n_trees=8)
    xq = rng.uniform(-1, 1, size=(1500, 4))
    ref = model.predict_reference(xq)
    assert np.array_equal(model.predict(xq[:64], backend="numpy"), ref[:64])
    assert np.array_equal(model.predict(xq, backend="numpy"), ref)


def test_jnp_predict_close_to_reference():
    model, rng = _fit(n=300, f=6, n_trees=10)
    xq = rng.uniform(-1, 1, size=(200, 6))
    ref = model.predict_reference(xq)
    out = model.predict(xq, backend="jnp")
    # f32 traversal: tiny numeric drift; a threshold-rounding branch flip
    # would show up as an O(leaf-gap) outlier.
    assert np.allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_single_node_trees():
    model, rng = _fit(n=100, f=3, n_trees=5, max_depth=0)
    xq = rng.uniform(-1, 1, size=(17, 3))
    ref = model.predict_reference(xq)
    assert np.array_equal(model.predict(xq, backend="numpy"), ref)
    assert model._flat["depth"] == 0
    assert np.allclose(model.predict(xq, backend="jnp"), ref, rtol=1e-6)


def test_constant_y_degenerate_fit():
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(50, 4))
    model = RegressionForest(n_trees=6, seed=1).fit(x, np.full(50, 3.25))
    xq = rng.uniform(size=(9, 4))
    assert np.array_equal(model.predict(xq), np.full(9, 3.25))
    assert np.array_equal(model.predict_reference(xq), np.full(9, 3.25))


def test_backend_validation_and_resolution():
    with pytest.raises(ValueError):
        RegressionForest(backend="bogus")
    with pytest.raises(ValueError):
        resolve_forest_backend("bogus")
    assert resolve_forest_backend("numpy") == "numpy"
    assert resolve_forest_backend("jnp") == "jnp"
    assert resolve_forest_backend("auto", batch=4096) in ("numpy", "jnp",
                                                          "pallas")
    # "pallas" is a first-class backend (third leg of the conformance
    # triangle); its off-TPU fallback is pinned in test_forest_conformance.
    assert RegressionForest(backend="pallas").backend == "pallas"
    assert resolve_forest_backend("pallas", interpret=True) == "pallas"


def test_single_sample_and_1d_input():
    model, rng = _fit()
    xq = rng.uniform(-1, 1, size=5)
    a = model.predict(xq)           # 1-D input is promoted like before
    b = model.predict_reference(xq)
    assert a.shape == (1,) and np.array_equal(a, b)


# -------------------------------------------------------------- properties
def given_forest_cases(max_examples):
    """Property decorator when hypothesis is available, skip otherwise."""
    def deco(fn):
        if st is None:
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            return stub
        cases = st.tuples(
            st.integers(0, 2**31 - 1),           # seed
            st.integers(2, 60),                  # n_train
            st.integers(1, 6),                   # n_features
            st.integers(1, 8),                   # n_trees
            st.integers(0, 6),                   # max_depth
            st.booleans(),                       # constant labels
        )
        return settings(max_examples=max_examples, deadline=None)(
            given(cases)(fn))
    return deco


@given_forest_cases(max_examples=30)
def test_property_flat_equals_reference(case):
    seed, n, f, trees, depth, const = case
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f))
    y = np.zeros(n) if const else rng.normal(size=n)
    model = RegressionForest(n_trees=trees, max_depth=depth,
                             seed=seed % 1000).fit(x, y)
    xq = rng.normal(size=(33, f))
    assert np.array_equal(model.predict(xq, backend="numpy"),
                          model.predict_reference(xq))


# ------------------------------------------------------- batched features
def test_design_features_batch_matches_scalar():
    spec = spec_tiny()
    rng = np.random.default_rng(3)
    designs = [spec.mesh_design()] + [random_design(spec, rng) for _ in range(12)]
    batch = design_features_batch(spec, designs)
    assert batch.shape == (13, len(FEATURE_NAMES))
    scalar = np.stack([design_features(spec, d) for d in designs])
    assert np.allclose(batch, scalar, rtol=1e-9, atol=1e-12)


def test_design_features_batch_empty():
    spec = spec_tiny()
    assert design_features_batch(spec, []).shape == (0, len(FEATURE_NAMES))
