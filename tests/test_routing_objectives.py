"""Routing (APSP/next-hop/walk) vs networkx oracle + objective sanity."""

import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro.core import (Evaluator, random_design, spec_16, spec_64, spec_tiny,
                        traffic_matrix)
from repro.core import routing
from repro.core.objectives import make_consts, peak_temperature_celsius


def _cost_matrix(spec, d):
    c = make_consts(spec)
    full = jnp.asarray(d.adj) | c.vadj
    n = spec.n_tiles
    cost = jnp.where(full, c.router_stages + c.link_delay, routing.INF)
    return jnp.where(jnp.eye(n, dtype=bool), 0.0, cost), c


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_apsp_matches_networkx(seed):
    spec = spec_16()
    rng = np.random.default_rng(seed)
    d = random_design(spec, rng)
    cost, c = _cost_matrix(spec, d)
    dist = np.asarray(routing.apsp(cost, c.apsp_iters))

    g = nx.Graph()
    cost_np = np.asarray(cost)
    n = spec.n_tiles
    for a in range(n):
        for b in range(a + 1, n):
            if cost_np[a, b] < routing.INF / 2:
                g.add_edge(a, b, weight=float(cost_np[a, b]))
    if not nx.is_connected(g):
        pytest.skip("random design disconnected; covered by validity test")
    ref = dict(nx.all_pairs_dijkstra_path_length(g))
    for a in range(n):
        for b in range(n):
            assert dist[a, b] == pytest.approx(ref[a][b], rel=1e-5)


def test_walk_consistent_with_dist():
    """Along walked paths, total cost r*h + delay must equal the APSP dist."""
    spec = spec_16()
    d = spec.mesh_design()
    cost, c = _cost_matrix(spec, d)
    dist, nh = routing.routing_tables(cost, c.apsp_iters)
    f = jnp.ones((spec.n_tiles, spec.n_tiles), jnp.float32)
    hops, delay, util, visits, all_done = routing.walk_paths(
        nh, c.link_delay, f, c.max_hops
    )
    assert bool(all_done)
    total = spec.router_stages * np.asarray(hops) + np.asarray(delay)
    np.testing.assert_allclose(total, np.asarray(dist), rtol=1e-5)


def test_walk_utilization_conservation():
    """Total f-weighted link traversals == sum over pairs f_ij * hops_ij."""
    spec = spec_tiny()
    d = spec.mesh_design()
    cost, c = _cost_matrix(spec, d)
    dist, nh = routing.routing_tables(cost, c.apsp_iters)
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.uniform(size=(8, 8)) * (1 - np.eye(8)), jnp.float32)
    hops, delay, util, visits, all_done = routing.walk_paths(
        nh, c.link_delay, f, c.max_hops
    )
    assert float(jnp.sum(util)) == pytest.approx(
        float(jnp.sum(f * hops)), rel=1e-5
    )
    # Router visits = link traversals + one destination visit per unit f.
    assert float(jnp.sum(visits)) == pytest.approx(
        float(jnp.sum(f * hops) + jnp.sum(f)), rel=1e-5
    )


def test_mesh_objectives_valid_and_positive():
    for spec in (spec_tiny(), spec_16(), spec_64()):
        f = traffic_matrix(spec, "BP")
        ev = Evaluator(spec, f)
        objs = ev(spec.mesh_design())
        assert np.all(np.isfinite(objs)) and np.all(objs > 0)


def test_batch_matches_single():
    spec = spec_tiny()
    f = traffic_matrix(spec, "HS")
    ev = Evaluator(spec, f)
    rng = np.random.default_rng(3)
    ds = [spec.mesh_design()] + [random_design(spec, rng) for _ in range(5)]
    batch = ev.batch(ds)
    for d, row in zip(ds, batch):
        np.testing.assert_allclose(ev(d), row, rtol=1e-6)


def test_disconnected_design_marked_invalid():
    spec = spec_tiny()
    d = spec.mesh_design()
    # Remove every planar link touching slot 0 and give them elsewhere; slot 0
    # keeps only its vertical link; then drop links touching slot 4 (its
    # vertical partner) too -> stack {0,4} isolated.
    adj = np.zeros_like(d.adj)
    # Connect only slots {1,2,3} and {5,6,7} planar rings, budget-filling.
    pairs = [(1, 2), (2, 3), (1, 3), (5, 6), (6, 7), (5, 7), (1, 2), (5, 6)]
    cnt = 0
    for a, b in pairs:
        if not adj[a, b] and cnt < spec.n_planar_links:
            adj[a, b] = adj[b, a] = True
            cnt += 1
    d.adj = adj
    f = traffic_matrix(spec, "BP")
    ev = Evaluator(spec, f)
    objs = ev(d)
    assert not np.all(np.isfinite(objs)) or np.all(objs >= 1e8)


def test_thermal_prefers_power_near_sink():
    """Eq. 5: within one vertical stack, hot cores near the sink give a lower
    peak temperature than hot cores far from it (the paper's §6.5 Het-therm
    observation: GPUs move toward the sink)."""
    from repro.core.problem import SystemSpec
    spec = SystemSpec(nx=1, ny=1, n_layers=4, n_cpu=1, n_llc=2, n_gpu=1)
    c = make_consts(spec)
    # core powers: CPU(id 0)=2.0, LLC(ids 1,2)=0.8, GPU(id 3)=3.0.
    hot_at_sink = np.array([3, 0, 1, 2], dtype=np.int32)
    hot_on_top = np.array([1, 2, 0, 3], dtype=np.int32)
    assert peak_temperature_celsius(c, hot_at_sink) < peak_temperature_celsius(
        c, hot_on_top
    )


def test_energy_increases_with_longer_links():
    """Replacing a short link by a long link (same endpoints' layer) must not
    decrease link energy contribution for the same routes."""
    spec = spec_16()
    f = traffic_matrix(spec, "GAU")
    ev = Evaluator(spec, f)
    mesh = spec.mesh_design()
    o = ev(mesh)
    assert o[3] > 0
