"""Pod-level bridge: ICI-torus layout optimization + autoshard genome."""

import numpy as np
import pytest

# repro.dist exists now (distributed multi-start MOO-STAGE, PR 5) but the
# pod-level bridge modules these tests exercise are still unbuilt — skip on
# the specific submodule, not the package (tests/test_dist.py audits this).
pytest.importorskip(
    "repro.dist.mesh_layout",
    reason="repro.dist.mesh_layout (pod-level bridge) not built yet")

from repro.dist.autoshard import Genome
from repro.dist.mesh_layout import (LayoutEvaluator, Torus,
                                    _torus_path_links, collective_traffic,
                                    optimize_layout, synthetic_traffic)


def test_torus_path_lengths_respect_wraparound():
    t = Torus(4, 4)
    # neighbors: 1 hop
    assert len(_torus_path_links(t, 0, 1)) == 1
    assert len(_torus_path_links(t, 0, 4)) == 1
    # wraparound: 0 -> 3 in a row is 1 hop on a torus
    assert len(_torus_path_links(t, 0, 3)) == 1
    # diagonal opposite: 2 + 2
    assert len(_torus_path_links(t, 0, 10)) == 4
    assert _torus_path_links(t, 5, 5) == []


def test_torus_link_utilization_conserves_traffic():
    t = Torus(4, 4)
    f = synthetic_traffic(4, 4, tp_bytes=100.0, dp_bytes=10.0)
    ev = LayoutEvaluator(t, f)
    objs = ev(np.arange(16))
    # identity layout: every ring pair is a physical neighbor -> lat == 1 hop
    assert objs[3] == pytest.approx(1.0)
    # mean * n_links == total f-weighted hops == total traffic (1 hop each)
    assert objs[0] * t.n_links() == pytest.approx(f.sum())


def test_random_layout_worse_than_identity():
    t = Torus(4, 4)
    f = synthetic_traffic(4, 4, tp_bytes=100.0, dp_bytes=10.0)
    ev = LayoutEvaluator(t, f)
    ident = ev(np.arange(16))
    rng = np.random.default_rng(0)
    rand = np.mean([ev(rng.permutation(16)) for _ in range(5)], axis=0)
    assert rand[3] > ident[3]          # more hops
    assert rand[2] >= ident[2] - 1e-9  # no better max-link utilization


def test_optimize_layout_recovers_from_random_start():
    t = Torus(4, 4)
    f = synthetic_traffic(4, 4, tp_bytes=100.0, dp_bytes=10.0)
    ev = LayoutEvaluator(t, f)
    rng = np.random.default_rng(1)
    start = rng.permutation(16)
    start_objs = ev(start)
    res = optimize_layout(ev, seed=0, iters_max=3, n_neighbors=24,
                          max_steps=30)
    # The Pareto representative must not be worse than the random start on
    # the bottleneck (max link utilization).
    assert res.best_objs[2] <= start_objs[2] + 1e-9
    assert sorted(res.best_perm.tolist()) == list(range(16))


def test_collective_traffic_parses_groups():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = bf16[64,32]{1,0} all-gather(%y), replica_groups=[2,2]<=[4], dimensions={0}
"""
    f = collective_traffic(hlo, 4)
    assert f.shape == (4, 4)
    # all-reduce ring over {0,1,2,3}: consecutive pairs incl. wrap get bytes
    assert f[0, 1] > 0 and f[2, 3] > 0 and f[3, 0] > 0
    # iota groups {0,1} and {2,3} from the all-gather
    assert f[1, 0] > 0
    assert f.sum() > 0
    np.testing.assert_allclose(f, f.T)


def test_genome_policy_roundtrip_and_neighbors():
    g = Genome()
    pol = g.to_policy()
    assert pol.rules()["heads"] == ("model",)
    assert pol.microbatches == 16
    nbs = g.neighbors()
    assert len(nbs) >= 10
    assert all(n != g for n in nbs)
    g2 = [n for n in nbs if n.microbatches == 4][0]
    assert g2.to_policy().microbatches == 4
    feats = g.features()
    assert feats.shape == (7,)
