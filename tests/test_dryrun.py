"""Dry-run machinery: HLO collective parsing units + one real multi-pod cell
lowered in a subprocess (the 512-device env must not leak into this
process's JAX)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.launch import hlo


def test_parse_collectives_shapes_and_kinds():
    text = """
  %ar.1 = f32[1024,16]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.2 = bf16[64,512]{1,0} all-gather(%p1), replica_groups=[2,8]<=[16], dimensions={0}
  %rs.3 = f32[128]{0} reduce-scatter(%p2), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp.4 = u32[32]{0} collective-permute(%p3), source_target_pairs={{0,1},{1,0}}
  %a2a.5 = s8[256,4]{1,0} all-to-all(%p4), replica_groups=[4,4]<=[16]
"""
    c = hlo.parse_collectives(text)
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["result_bytes"] == 1024 * 16 * 4
    assert c["all-reduce"]["wire_bytes"] == 2 * 1024 * 16 * 4
    assert c["all-gather"]["result_bytes"] == 64 * 512 * 2
    assert c["all-gather"]["wire_bytes"] == 64 * 512 * 2
    # reduce-scatter: operand = result x group size (8)
    assert c["reduce-scatter"]["wire_bytes"] == 128 * 4 * 8
    assert c["collective-permute"]["count"] == 1
    assert c["all-to-all"]["result_bytes"] == 256 * 4
    assert hlo.wire_bytes(c) > 0


def test_parse_ignores_non_collectives():
    text = "%dot.1 = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}"
    c = hlo.parse_collectives(text)
    assert hlo.wire_bytes(c) == 0


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """One real cell on both production meshes, via `python -m` exactly as
    the deliverable specifies. whisper-base compiles fastest."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-base", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "OK" in proc.stdout
    # Artifacts recorded for both meshes.
    base = os.path.join("experiments", "dryrun")
    for mesh in ("pod16x16", "pod2x16x16"):
        path = os.path.join(base, f"whisper-base__decode_32k__{mesh}.json")
        assert os.path.exists(path)
        rec = json.load(open(path))
        assert rec["status"] == "ok"
        assert rec["memory"]["temp_size_in_bytes"] > 0


def test_dryrun_artifacts_complete_and_green():
    """The full sweep (run via `python -m repro.launch.dryrun --all`) must
    have produced one artifact per (arch x shape x mesh) cell, all ok/skip."""
    base = os.path.join("experiments", "dryrun")
    if not os.path.isdir(base) or len(os.listdir(base)) < 80:
        pytest.skip("full sweep artifacts not present (run dryrun --all)")
    from repro.configs import ARCH_NAMES, SHAPES
    n_ok = n_skip = 0
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            for mesh in ("pod16x16", "pod2x16x16"):
                path = os.path.join(base, f"{arch}__{shape}__{mesh}.json")
                assert os.path.exists(path), f"missing {path}"
                rec = json.load(open(path))
                assert rec["status"] == "ok" or rec["status"].startswith(
                    "skip"), f"{path}: {rec['status']}"
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"].startswith("skip")
    assert n_ok >= 64 and n_ok + n_skip == 80
