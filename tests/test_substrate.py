"""Substrate tests: data pipeline, optimizer, gradient compression,
checkpointing (atomic/async/elastic), trainer fault tolerance, serving."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.serve import Engine, ServeConfig
from repro.train import OptConfig, TrainConfig, Trainer
from repro.train import grad_compress, optimizer


# ------------------------------------------------------------------- data
def test_pipeline_deterministic_and_resumable():
    d1 = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4, seed=3))
    d2 = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=4, seed=3))
    for step in (0, 7, 123):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["targets"], b2["targets"])
    # Different steps differ.
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])


def test_pipeline_is_learnable_bigram():
    cfg = DataConfig(vocab=64, seq_len=256, global_batch=2, seed=0,
                     determinism=0.9)
    data = SyntheticLM(cfg)
    b = data.batch(0)
    # Empirically, the deterministic successor should be hit ~90% of the time
    hits = tot = 0
    for row_t, row_y, row_m in zip(b["tokens"], b["targets"], b["mask"]):
        for t, y, m in zip(row_t, row_y, row_m):
            if m and t != cfg.bos:
                tot += 1
                hits += int(data.successor[t] == y)
    assert hits / tot > 0.8
    assert 0 < data.entropy_floor() < np.log(cfg.vocab)


# -------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([4.0, -3.0])}
    state = optimizer.init_state(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)

    def loss(p):
        return jnp.sum((p["w"] - 1.0) ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, stats = optimizer.apply(cfg, params, g, state)
    assert float(loss(params)) < 1e-2
    assert float(stats["grad_norm"]) >= 0


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    state = optimizer.init_state(params)
    cfg = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup_steps=0)
    g = {"w": jnp.full(3, 1e6)}
    p2, state, stats = optimizer.apply(cfg, params, g, state)
    assert float(stats["grad_norm"]) > 1e5
    assert np.all(np.abs(np.asarray(p2["w"])) < 10.0)  # clipped update


# -------------------------------------------------------- grad compression
def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512) * 0.01)
    err = jnp.zeros(512)
    q, scale, new_err = grad_compress.quantize(g, err)
    assert q.dtype == jnp.int8
    deq = np.asarray(q, np.float32) * float(scale)
    np.testing.assert_allclose(deq + np.asarray(new_err), np.asarray(g),
                               atol=1e-7)
    assert np.max(np.abs(np.asarray(new_err))) <= float(scale) * 0.51


def test_error_feedback_preserves_signal():
    """Over many steps, sum of dequantized gradients tracks the true sum."""
    rng = np.random.default_rng(1)
    err = jnp.zeros(64)
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    last_scale = 0.0
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(64) * 0.1)
        q, scale, err = grad_compress.quantize(g, err)
        true_sum += np.asarray(g)
        deq_sum += np.asarray(q, np.float32) * float(scale)
        last_scale = float(scale)
    np.testing.assert_allclose(deq_sum, true_sum, atol=2 * last_scale)


# ----------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    mgr.save(10, tree, blocking=True)
    mgr.save(20, tree, blocking=True)
    mgr.save(30, tree, blocking=True)
    assert mgr.all_steps() == [20, 30]  # keep=2 gc'd step 10
    # A stale tmp file (simulated crash mid-save) is ignored.
    open(os.path.join(str(tmp_path), "tmp.99"), "w").write("junk")
    restored, step = mgr.restore(jax.eval_shape(lambda: tree))
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore against explicit shardings (the elastic-resume path)."""
    mesh = make_host_mesh()
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree, blocking=True)
    specs = {"w": jax.sharding.PartitionSpec("data", None)}
    restored, _ = mgr.restore(
        jax.eval_shape(lambda: tree), shardings=shd.named(mesh, specs))
    assert restored["w"].sharding.spec == specs["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# ----------------------------------------------------------------- trainer
def _small_setup(tmp_path, steps=24, grad_compress_on=False):
    cfg = get_config("yi-6b", smoke=True).scaled(
        remat=False, compute_dtype=jnp.float32)
    model = build(cfg)
    mesh = make_host_mesh()
    policy = shd.Policy(microbatches=1, grad_compress=grad_compress_on)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8, seed=0))
    opt = OptConfig(lr=1e-2, warmup_steps=5, total_steps=steps,
                    weight_decay=0.0)
    tcfg = TrainConfig(steps=steps, ckpt_dir=str(tmp_path), ckpt_every=8,
                       seed=0)
    return Trainer(model, mesh, policy, opt, data, tcfg)


def test_trainer_loss_decreases(tmp_path):
    tr = _small_setup(tmp_path / "a", steps=30)
    out = tr.run()
    losses = [l for _, l in out["losses"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
    assert out["final_step"] == 30


def test_trainer_crash_restart_resumes_trajectory(tmp_path):
    # Uninterrupted reference run.
    ref = _small_setup(tmp_path / "ref", steps=20)
    ref_out = ref.run()
    ref_losses = dict(ref_out["losses"])

    # Crash at step 12 (after the step-8 checkpoint), then restart.
    tr1 = _small_setup(tmp_path / "crash", steps=20)
    out1 = tr1.run(crash_at=12)
    assert out1["crashed_at"] == 12
    tr2 = _small_setup(tmp_path / "crash", steps=20)
    out2 = tr2.run()
    # Resumed from step 8 checkpoint; losses from there match the reference.
    resumed = dict(out2["losses"])
    assert min(resumed) == 8  # resumed at the checkpoint step
    for s in range(10, 20):
        assert resumed[s] == pytest.approx(ref_losses[s], rel=1e-4), \
            f"divergence at step {s}"


def test_trainer_straggler_detection(tmp_path):
    tr = _small_setup(tmp_path / "strag", steps=14)
    orig = tr.data.batch

    def slow_batch(step):
        if step == 9:
            time.sleep(1.0)
        return orig(step)

    tr.data.batch = slow_batch
    out = tr.run()
    assert any(s == 9 for s, _, _ in out["straggler_events"]), \
        f"straggler at step 9 not detected: {out['straggler_events']}"


def test_trainer_grad_compress_converges(tmp_path):
    tr = _small_setup(tmp_path / "gc", steps=30, grad_compress_on=True)
    out = tr.run()
    losses = [l for _, l in out["losses"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3


# ----------------------------------------------------------------- serving
def test_engine_generates_deterministic_tokens():
    cfg = get_config("yi-6b", smoke=True).scaled(
        remat=False, compute_dtype=jnp.float32)
    model = build(cfg)
    mesh = make_host_mesh()
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, mesh, shd.Policy(), params,
                 ServeConfig(max_new_tokens=8, max_len=64))
    prompts = np.array([[5, 6, 7, 8], [9, 10, 11, 12]], dtype=np.int32)
    out1 = eng.generate(prompts)
    out2 = eng.generate(prompts)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(out1, out2)
    assert np.all(out1 >= 0) and np.all(out1 < cfg.vocab)


# ---------------------------------------------------------------- sharding
def test_param_specs_divisibility_fallback():
    mesh = make_host_mesh()  # (1, 1) on this container -> everything fits
    cfg = get_config("whisper-base", smoke=True)
    model = build(cfg)
    abstract = model.abstract_params()
    specs = shd.param_specs(mesh, shd.Policy(), abstract)
    leaves = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert leaves  # produced a spec per leaf without error


def test_spec_from_logical_drops_nondivisible():
    import jax.sharding as jsh
    devs = jax.devices()
    mesh = jax.sharding.Mesh(np.array(devs).reshape(1, 1), ("data", "model"))
    pol = shd.Policy()
    # vocab 51865 is not divisible by any axis > 1; on this 1x1 mesh the
    # axis trivially fits, so instead check the helper logic directly.
    assert shd._fit(mesh, 7, ("model",), set()) in ((), ("model",))
