"""The §3 traffic study statistics, asserted for every application x size."""

import numpy as np
import pytest

from repro.core import (APP_NAMES, avg_traffic, spec_36, spec_64,
                        traffic_matrix)
from repro.core.traffic import traffic_stats


@pytest.mark.parametrize("spec_fn", [spec_36, spec_64])
@pytest.mark.parametrize("app", APP_NAMES)
def test_traffic_matches_paper_observations(spec_fn, app):
    spec = spec_fn()
    f = traffic_matrix(spec, app)
    s = traffic_stats(spec, f)
    # >80% of traffic is LLC-associated (paper Fig. 2).
    assert s["llc_share"] > 0.80
    # One master CPU carries the majority of CPU traffic (paper §3).
    assert s["master_cpu_share"] > 0.5
    # GPU->LLC traffic is near-uniform across GPUs (coefficient of variation).
    assert s["gpu_llc_cv"] < 0.5
    # No self traffic, non-negative.
    assert np.all(np.diag(f) == 0) and np.all(f >= 0)


def test_apps_are_similar_but_not_identical():
    spec = spec_64()
    mats = [traffic_matrix(spec, a) for a in APP_NAMES]
    normed = [m / m.sum() for m in mats]
    # Pairwise cosine similarity: high (architecture-dominated traffic)...
    sims = []
    for i in range(len(normed)):
        for j in range(i + 1, len(normed)):
            a, b = normed[i].ravel(), normed[j].ravel()
            sims.append(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
    assert min(sims) > 0.8
    # ...but not literally the same matrices.
    assert max(
        np.abs(normed[0] - normed[k]).max() for k in range(1, len(normed))
    ) > 1e-6


def test_avg_traffic_is_normalized_mixture():
    spec = spec_36()
    apps = list(APP_NAMES[:4])
    m = avg_traffic(spec, apps)
    assert m.shape == (spec.n_tiles, spec.n_tiles)
    assert np.all(m >= 0)
    s = traffic_stats(spec, m)
    assert s["llc_share"] > 0.80


def test_traffic_deterministic():
    spec = spec_64()
    a = traffic_matrix(spec, "BFS")
    b = traffic_matrix(spec, "BFS")
    np.testing.assert_array_equal(a, b)
