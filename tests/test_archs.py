"""Per-architecture smoke tests: REDUCED configs of the same family run one
forward/train step on CPU; output shapes + no NaNs. (The FULL configs are
exercised only via the dry-run — ShapeDtypeStructs, no allocation.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build

BATCH, SEQ = 2, 32


def _batch_for(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(ks[0], (BATCH, SEQ, cfg.d_model),
                                        jnp.float32),
            "tokens": jax.random.randint(ks[1], (BATCH, 16), 0, cfg.vocab),
            "targets": jax.random.randint(ks[2], (BATCH, 16), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(ks[1], (BATCH, SEQ), 0, cfg.vocab),
        "targets": jax.random.randint(ks[2], (BATCH, SEQ), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch_for(cfg, key)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert leaves, f"{arch}: no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), \
            f"{arch}: non-finite grad"
    # Loss near ln(vocab) at init (uniform predictions).
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.5


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    if cfg.family == "encdec":
        cache = model.init_cache(BATCH, 16, SEQ, jnp.float32)
        # encoder K/V must be populated for cross attention; run prefill.
        frames = jax.random.normal(key, (BATCH, SEQ, cfg.d_model), jnp.float32)
        tokens = jax.random.randint(key, (BATCH, 2), 0, cfg.vocab)
        logits, cache = model.prefill(params, frames, tokens, 16)
    else:
        cache = model.init_cache(BATCH, SEQ, jnp.float32)
    tok = jax.random.randint(key, (BATCH, 1), 0, cfg.vocab)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["yi-6b", "gemma3-1b", "qwen3-moe-30b-a3b"])
def test_decode_matches_prefill_logits(arch):
    """KV-cache correctness: teacher-forced decode reproduces the full
    forward's next-token logits."""
    # capacity_factor high enough that no token is ever dropped — capacity
    # dispatch otherwise makes full-pass vs per-token routing legitimately
    # differ (the usual train/serve MoE asymmetry).
    cfg = get_config(arch, smoke=True).scaled(remat=False,
                                              compute_dtype=jnp.float32,
                                              capacity_factor=64.0)
    model = build(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    s = 12
    tokens = jax.random.randint(key, (1, s), 0, cfg.vocab)

    from repro.models import transformer
    x, _, _ = transformer.forward_full(cfg, params, tokens)
    full_logits = transformer._logits(cfg, params, x)  # (1, s, V)

    cache = model.init_cache(1, s + 4, jnp.float32)
    outs = []
    step = jax.jit(model.decode_step)
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i : i + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        got, np.asarray(full_logits, np.float32), rtol=2e-3, atol=2e-3
    )


def test_ssm_decode_matches_full_forward():
    """Mamba2 stateful decode vs full-sequence SSD forward."""
    cfg = get_config("mamba2-1.3b", smoke=True).scaled(
        remat=False, compute_dtype=jnp.float32)
    model = build(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    s = 10
    tokens = jax.random.randint(key, (1, s), 0, cfg.vocab)

    from repro.models import transformer
    x, _, _ = transformer.forward_full(cfg, params, tokens)
    full_logits = transformer._logits(cfg, params, x)

    cache = model.init_cache(1, s, jnp.float32)
    outs = []
    step = jax.jit(model.decode_step)
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i : i + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        got, np.asarray(full_logits, np.float32), rtol=5e-3, atol=5e-3
    )


def test_hybrid_decode_matches_full_forward():
    cfg = get_config("zamba2-2.7b", smoke=True).scaled(
        remat=False, compute_dtype=jnp.float32)
    model = build(cfg)
    key = jax.random.PRNGKey(4)
    params = model.init(key)
    s = 8
    tokens = jax.random.randint(key, (1, s), 0, cfg.vocab)

    from repro.models import transformer
    x, _, _ = transformer.forward_full(cfg, params, tokens)
    full_logits = transformer._logits(cfg, params, x)

    cache = model.init_cache(1, s, jnp.float32)
    outs = []
    step = jax.jit(model.decode_step)
    for i in range(s):
        lg, cache = step(params, cache, tokens[:, i : i + 1])
        outs.append(np.asarray(lg[:, 0], np.float32))
    got = np.stack(outs, axis=1)
    np.testing.assert_allclose(
        got, np.asarray(full_logits, np.float32), rtol=5e-3, atol=5e-3
    )


def test_gemma_sliding_window_differs_from_full():
    """The 5:1 local:global schedule must actually change the computation."""
    cfg = get_config("gemma3-1b", smoke=True).scaled(
        remat=False, compute_dtype=jnp.float32)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(5))
    tokens = jax.random.randint(jax.random.PRNGKey(6), (1, 24), 0, cfg.vocab)
    from repro.models import transformer
    x1, _, _ = transformer.forward_full(cfg, params, tokens)
    cfg_full = cfg.scaled(sliding_window=0, global_every=0)
    x2, _, _ = transformer.forward_full(cfg_full, params, tokens)
    assert not np.allclose(np.asarray(x1), np.asarray(x2), atol=1e-5)


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-2.7b", "yi-6b"])
def test_prefill_then_decode_matches_pure_decode(arch):
    """Full-sequence prefill must leave the cache in exactly the state that
    step-by-step decoding of the same prompt would."""
    cfg = get_config(arch, smoke=True).scaled(remat=False,
                                              compute_dtype=jnp.float32)
    model = build(cfg)
    key = jax.random.PRNGKey(7)
    params = model.init(key)
    s, extra = 8, 4
    tokens = jax.random.randint(key, (1, s + extra), 0, cfg.vocab)

    # Path 1: prefill the first s tokens, then decode the rest.
    logits_p, cache = model.prefill(params, tokens[:, :s], s + extra)
    out1 = [np.asarray(logits_p[:, -1], np.float32)]
    for i in range(extra):
        lg, cache = model.decode_step(params, cache, tokens[:, s + i: s + i + 1])
        out1.append(np.asarray(lg[:, 0], np.float32))

    # Path 2: decode everything token by token.
    cache2 = model.init_cache(1, s + extra, jnp.float32)
    out2 = []
    for i in range(s + extra):
        lg, cache2 = model.decode_step(params, cache2, tokens[:, i : i + 1])
        out2.append(np.asarray(lg[:, 0], np.float32))

    np.testing.assert_allclose(np.stack(out1), np.stack(out2[s - 1:]),
                               rtol=5e-3, atol=5e-3)
