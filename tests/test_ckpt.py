"""Atomic-write protocol + stale-temp hygiene (repro.ckpt) and the
distributed round checkpointer built on it (repro.dist.ckpt).

The stale-tmp satellite: a process that dies between writing
``tmp.<name>`` and renaming it leaves the temp file forever; restore
already ignored it, but the disk leak compounds across crash-loops.
``sweep_stale_tmp`` removes the orphans and every checkpoint store sweeps
on open.
"""

import json
import os

import numpy as np
import pytest

from repro.ckpt import atomic_replace, atomic_write_json, sweep_stale_tmp
from repro.dist.ckpt import ROUND_STATE_FORMAT, RoundCheckpointer


# ---------------------------------------------------------------------------
# Atomic write helpers
# ---------------------------------------------------------------------------
def test_atomic_write_json_round_trips(tmp_path):
    path = tmp_path / "state.json"
    obj = {"a": [1, 2.5, None], "b": {"nested": "x"}}
    atomic_write_json(str(path), obj)
    with open(path) as fh:
        assert json.load(fh) == obj
    # No temp residue after a successful write.
    assert [n for n in os.listdir(tmp_path) if n.startswith("tmp.")] == []


def test_atomic_replace_crash_leaves_old_file_intact(tmp_path):
    path = tmp_path / "state.json"
    atomic_write_json(str(path), {"round": 1})

    def dies(fh):
        fh.write(b"partial garbage")
        raise RuntimeError("simulated crash mid-write")

    with pytest.raises(RuntimeError, match="mid-write"):
        atomic_replace(str(path), dies)
    # The old file is untouched; the wreck is a tmp.* orphan.
    with open(path) as fh:
        assert json.load(fh) == {"round": 1}
    assert [n for n in os.listdir(tmp_path)
            if n.startswith("tmp.")] == ["tmp.state.json"]
    # ... which the sweep removes.
    assert sweep_stale_tmp(str(tmp_path)) == ["tmp.state.json"]
    assert [n for n in os.listdir(tmp_path) if n.startswith("tmp.")] == []


def test_sweep_spares_non_tmp_files(tmp_path):
    (tmp_path / "tmp.orphan").write_text("x")
    (tmp_path / "round_000001.json").write_text("{}")
    (tmp_path / "tmpnotdot").write_text("x")  # no "tmp." prefix: kept
    assert sweep_stale_tmp(str(tmp_path)) == ["tmp.orphan"]
    assert sorted(os.listdir(tmp_path)) == ["round_000001.json", "tmpnotdot"]


# ---------------------------------------------------------------------------
# CheckpointManager sweeps on open (the satellite's original home)
# ---------------------------------------------------------------------------
def test_checkpoint_manager_sweeps_stale_tmp_on_init(tmp_path):
    jax = pytest.importorskip("jax")  # noqa: F841 — manager needs jax trees
    from repro.ckpt import CheckpointManager

    d = tmp_path / "ckpt"
    d.mkdir()
    (d / "tmp.00000007").write_bytes(b"dead prior process")
    mgr = CheckpointManager(str(d), keep=2)
    assert [n for n in os.listdir(d) if n.startswith("tmp.")] == []
    # Saves still work and gc keeps sweeping.
    (d / "tmp.00000009").write_bytes(b"another orphan")
    mgr.save(1, {"w": np.ones(3)}, blocking=True)
    assert [n for n in os.listdir(d) if n.startswith("tmp.")] == []
    restored, step = mgr.restore({"w": np.zeros(3)})
    assert step == 1 and np.array_equal(restored["w"], np.ones(3))


# ---------------------------------------------------------------------------
# RoundCheckpointer
# ---------------------------------------------------------------------------
def test_round_checkpointer_save_load_gc(tmp_path):
    ck = RoundCheckpointer(str(tmp_path), keep=2)
    for r in range(4):
        ck.save_round(r, {"alive": [0, 1], "spent_evals": {"0": r}})
    # keep=2: only the last two rounds survive gc.
    assert ck.rounds() == [2, 3]
    assert ck.latest_round() == 3
    state = ck.load_round()
    assert state["round"] == 3 and state["format"] == ROUND_STATE_FORMAT
    assert state["spent_evals"] == {"0": 3}
    assert ck.load_round(2)["spent_evals"] == {"0": 2}
    assert ck.n_saves == 4 and ck.save_s > 0.0


def test_round_checkpointer_sweeps_and_validates(tmp_path):
    (tmp_path / "tmp.round_000000.json").write_text("dead write")
    ck = RoundCheckpointer(str(tmp_path))
    assert [n for n in os.listdir(tmp_path) if n.startswith("tmp.")] == []
    # Empty dir: resume is a loud error, not a silent fresh start.
    with pytest.raises(FileNotFoundError, match="nothing to resume"):
        ck.load_round()
    # Unknown format: refused, not misread.
    with open(tmp_path / "round_000005.json", "w") as fh:
        json.dump({"format": 999, "round": 5}, fh)
    with pytest.raises(ValueError, match="format"):
        ck.load_round(5)
    with pytest.raises(ValueError, match="keep"):
        RoundCheckpointer(str(tmp_path), keep=0)
