"""repro.workloads — model-derived NoC traffic (DESIGN.md §11).

Contract under test:

* generators — every (model x phase) scenario yields a matrix in the
  `core/traffic.py` convention: non-negative, zero diagonal, normalized
  to the phase intensity, deterministic, and structurally distinct per
  scenario (MoE training is GPU<->GPU all-to-all heavy, serving decode is
  many-to-few LLC reads).
* mapping — the logical (data, model) mesh tiles the GPU set exactly and
  places shards/home-LLCs inside the spec's id ranges.
* problem plumbing — ``NocProblem(traffic={"model": ...})`` normalizes,
  JSON round-trips, and hashes stably through ``canonical_request_key``
  (dict order / mesh spelling invariant, phase-sensitive).
* admission — malformed traffic (NaN / negative / zero-sum matrices,
  unknown model or phase) is rejected at submit as a structured
  ``invalid_traffic`` error, never by crashing a worker.
* phase scoring — phase-weighted EDP is the weighted mean of per-phase
  EDPs; the trace link report is finite and peaks on a real link.
"""

import json

import numpy as np
import pytest

from repro.core import spec_16, spec_64, spec_tiny
from repro.core.traffic import TrafficValidationError
from repro.noc import Budget, NocProblem
from repro.workloads import (LLM_STUDY_SCENARIOS, PHASE_APP_NAMES,
                             PHASE_INTENSITY, PHASES, derive_mesh,
                             normalize_model_traffic, parse_scenario,
                             phase_weighted_edp, place_model,
                             scenario_matrix, trace_for, trace_link_report)
from repro.workloads.mapping import WorkloadMesh

SMALL = dict(iters_max=1, n_swaps=4, n_link_moves=4, max_local_steps=5)


# ==========================================================================
# traffic generators
# ==========================================================================
def test_scenario_registry_covers_every_model_phase():
    assert len(PHASE_APP_NAMES) == len(set(PHASE_APP_NAMES)) >= 50
    for name in PHASE_APP_NAMES:
        arch, phase = parse_scenario(name)
        assert phase in PHASES


@pytest.mark.parametrize("scenario", LLM_STUDY_SCENARIOS)
def test_generator_invariants(scenario):
    spec = spec_64()
    arch, phase = parse_scenario(scenario)
    f = scenario_matrix(spec, arch, phase)
    assert f.shape == (spec.n_tiles, spec.n_tiles)
    assert np.all(np.isfinite(f)) and np.all(f >= 0)
    np.testing.assert_allclose(np.diag(f), 0.0)
    np.testing.assert_allclose(f.sum(), PHASE_INTENSITY[phase], rtol=1e-9)
    # byte-deterministic: the cache key contract depends on it
    again = scenario_matrix(spec, arch, phase)
    assert np.array_equal(f, again)


def test_study_scenarios_pairwise_distinct():
    spec = spec_64()
    mats = [scenario_matrix(spec, *parse_scenario(s))
            for s in LLM_STUDY_SCENARIOS]
    assert len(LLM_STUDY_SCENARIOS) >= 6
    for i in range(len(mats)):
        for j in range(i + 1, len(mats)):
            a = mats[i] / mats[i].sum()
            b = mats[j] / mats[j].sum()
            assert np.abs(a - b).sum() > 1e-3, (
                f"{LLM_STUDY_SCENARIOS[i]} ~ {LLM_STUDY_SCENARIOS[j]}")


def _class_shares(spec, f):
    """Fraction of total volume per (src-class, dst-class) pair."""
    c, m = spec.n_cpu, spec.n_llc
    bounds = [(0, c), (c, c + m), (c + m, spec.n_tiles)]
    names = ("cpu", "llc", "gpu")
    tot = f.sum()
    return {(names[i], names[j]):
            f[a:b, p:q].sum() / tot
            for i, (a, b) in enumerate(bounds)
            for j, (p, q) in enumerate(bounds)}


def test_phase_structure_signatures():
    """Each workload class concentrates traffic where the model says it
    should: MoE training is more GPU<->GPU than dense (all-to-all on top
    of the TP rings); serving decode is many-to-few KV reads, so the
    LLC->GPU share dominates and beats every training phase's."""
    spec = spec_64()
    dense = _class_shares(spec, scenario_matrix(spec, "yi-6b", "train.fwd"))
    moe = _class_shares(
        spec, scenario_matrix(spec, "qwen3-moe-30b-a3b", "train.fwd"))
    decode = _class_shares(
        spec, scenario_matrix(spec, "qwen3-moe-30b-a3b", "serve.decode"))

    assert moe["gpu", "gpu"] > dense["gpu", "gpu"] > 0.5
    assert decode["llc", "gpu"] > 0.5          # KV-cache reads dominate
    assert decode["llc", "gpu"] > dense["llc", "gpu"]
    assert decode["llc", "gpu"] > moe["llc", "gpu"]


def test_generator_scales_down_to_every_spec():
    for spec in (spec_64(), spec_16(), spec_tiny()):
        f = scenario_matrix(spec, "yi-6b", "serve.decode")
        assert f.shape == (spec.n_tiles, spec.n_tiles)
        np.testing.assert_allclose(
            f.sum(), PHASE_INTENSITY["serve.decode"], rtol=1e-9)


# ==========================================================================
# mapping
# ==========================================================================
def test_derive_mesh_tiles_gpus():
    for spec in (spec_64(), spec_16(), spec_tiny()):
        mesh = derive_mesh_for(spec, "yi-6b")
        assert mesh.data * mesh.model == spec.n_gpu


def derive_mesh_for(spec, arch):
    from repro.configs import get_config
    return derive_mesh(get_config(arch), spec.n_gpu)


def test_place_model_id_ranges():
    spec = spec_64()
    mesh = derive_mesh_for(spec, "yi-6b")
    mp = place_model(spec, mesh)
    c, m = spec.n_cpu, spec.n_llc
    assert sorted(mp.gpu_ids.ravel().tolist()) == list(
        range(c + m, spec.n_tiles))
    assert np.all((mp.home_llc >= c) & (mp.home_llc < c + m))
    assert 0 <= mp.master_cpu < c


def test_place_model_rejects_non_tiling_mesh():
    with pytest.raises(ValueError):
        place_model(spec_64(), WorkloadMesh(data=3, model=7))


# ==========================================================================
# NocProblem plumbing: normalization, JSON, cache keys, validation
# ==========================================================================
def _key(problem, seed=0):
    from repro.noc.optimizers import StageDistConfig
    from repro.noc.server import canonical_request_key, normalize_config

    cfg = normalize_config(StageDistConfig(), executor="serial",
                           shard_timeout_s=None, max_retries=1,
                           retry_backoff_s=0.0)
    return canonical_request_key(problem, Budget(max_evals=60, seed=seed),
                                 cfg)


def test_model_traffic_normalizes_and_round_trips():
    spec = spec_tiny()
    p = NocProblem(spec=spec, traffic={"model": "yi-6b"})
    assert p.traffic == {"model": "yi-6b", "phase": "train.fwd",
                         "mesh": (1, 5)}
    back = NocProblem.from_json(json.loads(json.dumps(p.to_json())))
    assert back == p
    f = p.traffic_matrix()
    np.testing.assert_allclose(
        f.sum(), PHASE_INTENSITY["train.fwd"], rtol=1e-9)


def test_model_traffic_cache_key_stable_and_phase_sensitive():
    spec = spec_tiny()
    base = NocProblem(spec=spec, traffic={"model": "yi-6b",
                                          "phase": "serve.decode"})
    # explicit default mesh and reordered keys hash identically
    spelled = NocProblem(spec=spec, traffic={"mesh": [1, 5],
                                             "phase": "serve.decode",
                                             "model": "yi-6b"})
    assert _key(base) == _key(spelled)
    other_phase = NocProblem(spec=spec, traffic={"model": "yi-6b",
                                                 "phase": "serve.prefill"})
    assert _key(base) != _key(other_phase)


def test_model_traffic_rejects_bad_specs():
    spec = spec_tiny()
    for bad in (
        {"model": "not-a-model"},
        {"model": "yi-6b", "phase": "train.nope"},
        {"model": "yi-6b", "mesh": [2, 2]},          # does not tile 5 GPUs
        {"model": "yi-6b", "mesh": [1, 5, 1]},
        {"model": "yi-6b", "unexpected": 1},
        {"phase": "train.fwd"},                      # model is required
    ):
        with pytest.raises(TrafficValidationError):
            NocProblem(spec=spec, traffic=bad)
    with pytest.raises(TrafficValidationError):
        normalize_model_traffic(spec, {"model": "yi-6b", "mesh": [0, 5]})


def test_matrix_traffic_rejects_degenerate():
    spec = spec_tiny()
    n = spec.n_tiles
    good = np.full((n, n), 1.0 / (n * n))
    NocProblem(spec=spec, traffic=good)  # sanity: dense matrices admit
    for bad in (
        np.full((n, n), np.nan),
        -good,
        np.zeros((n, n)),
        np.ones((n + 1, n + 1)),
    ):
        with pytest.raises(TrafficValidationError):
            NocProblem(spec=spec, traffic=bad)
    with pytest.raises(TrafficValidationError):
        NocProblem(spec=spec, traffic="NOT_AN_APP")
    with pytest.raises(TrafficValidationError):
        NocProblem(spec=spec, traffic=("BFS", "NOT_AN_APP"))


# ==========================================================================
# server admission
# ==========================================================================
def test_admission_rejects_invalid_traffic():
    from repro.noc.server import Client

    spec = spec_tiny()
    n = spec.n_tiles
    bj = Budget(max_evals=60, seed=0).to_json()
    ok = NocProblem(spec=spec, traffic="BFS").to_json()
    with Client.local(n_workers=1) as c:
        for traffic in (
            {"model": "not-a-model"},
            {"model": "yi-6b", "phase": "train.nope"},
            {"matrix": np.full((n, n), np.nan).tolist()},
            {"matrix": (-np.ones((n, n))).tolist()},
            {"matrix": np.zeros((n, n)).tolist()},
        ):
            pj = dict(ok, traffic=traffic)
            resp = c.submit(pj, bj)
            assert resp["error"]["code"] == "invalid_traffic", traffic


def test_server_runs_model_traffic_end_to_end():
    from repro.noc.server import Client

    pj = NocProblem(spec=spec_tiny(),
                    traffic={"model": "yi-6b",
                             "phase": "serve.decode"}).to_json()
    bj = Budget(max_evals=60, seed=0).to_json()
    with Client.local(n_workers=1) as c:
        assert c.submit(pj, bj, dict(SMALL), request_id="m0")[
            "status"] == "queued"
        c.drain()
        assert c.status("m0")["status"] == "done"
        res = c.result("m0")
        assert len(res.designs) >= 1
        # identical resubmission is a cache hit at the door
        dup = c.submit(pj, bj, dict(SMALL))
        assert dup["cache_hit"] is True


# ==========================================================================
# phase traces
# ==========================================================================
def test_phase_weighted_edp_is_weighted_mean():
    spec = spec_tiny()
    design = spec.mesh_design()
    trace = trace_for("qwen3-moe-30b-a3b", "serving")
    pw = phase_weighted_edp(spec, design, trace)
    assert set(pw["per_phase"]) == {"serve.prefill", "serve.decode"}
    want = (sum(pw["weights"][p] * pw["per_phase"][p]
                for p in pw["per_phase"])
            / sum(pw["weights"].values()))
    assert pw["edp"] == pytest.approx(want)
    assert np.isfinite(pw["edp"]) and pw["edp"] > 0


def test_trace_link_report_peaks_on_a_real_link():
    spec = spec_tiny()
    design = spec.mesh_design()
    trace = trace_for("yi-6b", "training")
    rep = trace_link_report(spec, design, trace)
    (a, b), peak = rep["max_link"]
    assert a != b and peak > 0
    assert np.all(np.isfinite(rep["util"]))
    np.testing.assert_allclose(rep["util"], rep["util"].T, atol=1e-9)
    assert rep["mean"] >= 0 and rep["std"] >= 0


# ==========================================================================
# CLI
# ==========================================================================
def test_cli_model_traffic_run(capsys):
    from repro.noc import cli

    rc = cli.main([
        "run", "--spec", "tiny", "--traffic", "model:yi-6b:serve.decode",
        "--max-evals", "60", "--seed", "0",
        "--set", "iters_max=1", "--set", "n_swaps=4",
        "--set", "n_link_moves=4", "--set", "max_local_steps=5",
    ])
    assert rc == 0
    assert "pareto=" in capsys.readouterr().out
