"""Deterministic fault injection + deadline/retry dispatch (DESIGN.md §9).

Every degradation path of the distributed layer is driven here through
:class:`repro.dist.FaultInjector` — scripted crashes, hard child aborts
(a real ``BrokenProcessPool``), hangs against per-shard deadlines, and
corrupt payloads against coordinator-side validation — and every path's
failure record, retry reseeding, and pool-rebuild accounting is pinned.
"""

import pytest

from repro.dist import (CORRUPT_PAYLOAD, FaultInjector, InjectedFault,
                        check_faults, execute_shards, retry_seed, round_seed)
from repro.dist.faults import call_with_faults
from repro.dist.worker import ShardPool
from repro.noc.optimizers import StageDistConfig


# ---------------------------------------------------------------------------
# retry_seed
# ---------------------------------------------------------------------------
def test_retry_seed_identity_and_divergence():
    # Attempt 0 is the dispatch seed itself: the no-fault path never moves.
    assert retry_seed(123, 0) == 123
    # Later attempts are fresh trajectories, distinct from each other...
    seeds = [retry_seed(123, a) for a in range(4)]
    assert len(set(seeds)) == 4
    # ...and distinct from the round-seed stream of the same base seed
    # (the tagged spawn key prevents a retry replaying a later round).
    assert retry_seed(123, 1) != round_seed(123, 1)
    assert retry_seed(123, 2) != round_seed(123, 2)
    # Deterministic in (seed, attempt).
    assert retry_seed(123, 3) == retry_seed(123, 3)
    with pytest.raises(ValueError, match="attempt"):
        retry_seed(123, -1)


# ---------------------------------------------------------------------------
# Fault script validation + matching
# ---------------------------------------------------------------------------
def test_check_faults_rejects_malformed_scripts():
    with pytest.raises(ValueError, match="kind"):
        check_faults([{"kind": "meteor"}])
    with pytest.raises(ValueError, match="round"):
        check_faults([{"kind": "crash", "round": -1}])
    with pytest.raises(ValueError, match="worker_id"):
        check_faults([{"kind": "crash", "worker_id": -2}])
    with pytest.raises(ValueError, match="hang_s"):
        check_faults([{"kind": "hang", "hang_s": -0.5}])
    with pytest.raises(ValueError, match="unknown fault keys"):
        check_faults([{"kind": "crash", "wroker_id": 1}])
    with pytest.raises(ValueError, match="dict"):
        check_faults(["crash"])
    check_faults([])  # empty script is fine
    with pytest.raises(ValueError, match="p_crash"):
        FaultInjector(p_crash=1.5)


def test_injector_matching_semantics():
    inj = FaultInjector(faults=(
        {"kind": "crash", "worker_id": 1, "round": 2, "attempt": 0},
        {"kind": "hang", "round": 1, "hang_s": 3.0},   # wildcard worker
        {"kind": "kill_coordinator", "round": 2},
    ))
    assert inj.match(1, 2, 0)["kind"] == "crash"
    assert inj.match(1, 2, 1) is None          # attempt must match exactly
    assert inj.match(0, 2, 0) is None          # other worker: clean
    assert inj.match(0, 1, 0)["kind"] == "hang"   # wildcard hits everyone
    assert inj.match(7, 1, 0)["kind"] == "hang"
    # kill_coordinator never matches a worker dispatch...
    assert inj.match(1, 2, 0)["kind"] != "kill_coordinator"
    # ...it fires at the round boundary.
    assert inj.kills_coordinator(2) and not inj.kills_coordinator(1)


def test_injector_random_mode_is_deterministic():
    inj = FaultInjector(p_crash=0.5, seed=7)
    grid = [(w, r, a) for w in range(4) for r in range(3) for a in range(2)]
    hits = [inj.match(*pos) is not None for pos in grid]
    assert hits == [FaultInjector(p_crash=0.5, seed=7).match(*pos) is not None
                    for pos in grid]          # same script, same chaos
    assert any(hits) and not all(hits)        # p=0.5 actually varies
    assert not any(FaultInjector(p_crash=0.0, seed=7).match(*p) for p in grid)


def test_abort_degrades_to_crash_in_process():
    # In the coordinator process there is no survivable hard-death; the
    # degradation is explicit in the exception text.
    inj = FaultInjector(faults=({"kind": "abort", "round": 0},))
    with pytest.raises(InjectedFault, match="degraded to crash"):
        call_with_faults(inj, 0, 0, 0, int, ("5",))


# ---------------------------------------------------------------------------
# execute_shards: in-process retry/deadline/validation paths
# ---------------------------------------------------------------------------
def _ok(x):
    return {"value": x}


def _check(payload):
    if "value" not in payload:
        raise ValueError(f"not a shard payload: {payload}")


def test_serial_crash_is_retried_with_fresh_seed():
    inj = FaultInjector(faults=(
        {"kind": "crash", "worker_id": 1, "round": 0, "attempt": 0},))
    results, failures = execute_shards(
        _ok, [("a",), ("b",)], "serial", meta=[(0, 0), (1, 0)],
        max_retries=1, injector=inj, validate=_check,
        retry_args=lambda orig, attempt: (f"{orig[0]}-retry{attempt}",))
    # Shard 1 failed attempt 0, succeeded on the reseeded attempt 1.
    assert results == {0: {"value": "a"}, 1: {"value": "b-retry1"}}
    [rec] = failures[1]
    assert (rec["worker_id"], rec["round"], rec["attempt"]) == (1, 0, 0)
    assert rec["phase"] == "run" and "injected crash" in rec["error"]
    assert "InjectedFault" in rec["traceback"]
    assert 0 not in failures


def test_serial_retries_are_bounded():
    inj = FaultInjector(p_crash=1.0)           # everything always crashes
    results, failures = execute_shards(
        _ok, [("a",)], "serial", max_retries=2, injector=inj)
    assert results == {}                       # attempts exhausted
    assert [r["attempt"] for r in failures[0]] == [0, 1, 2]
    assert all(r["phase"] == "run" for r in failures[0])


def test_serial_corrupt_payload_is_rejected_then_retried():
    inj = FaultInjector(faults=(
        {"kind": "corrupt", "round": 0, "attempt": 0},))
    results, failures = execute_shards(
        _ok, [("a",)], "serial", max_retries=1, injector=inj,
        validate=_check)
    assert results == {0: {"value": "a"}}      # retry ran clean
    [rec] = failures[0]
    assert rec["phase"] == "validate"
    assert str(CORRUPT_PAYLOAD["__corrupt__"]) in rec["error"] \
        or "not a shard payload" in rec["error"]


def test_serial_posthoc_deadline_discards_overrunning_shard():
    inj = FaultInjector(faults=(
        {"kind": "hang", "round": 0, "attempt": 0, "hang_s": 0.3},))
    results, failures = execute_shards(
        _ok, [("a",)], "serial", timeout_s=0.05, max_retries=1,
        injector=inj, validate=_check)
    assert results == {0: {"value": "a"}}      # clean retry made it
    [rec] = failures[0]
    assert rec["phase"] == "timeout" and "post-hoc" in rec["error"]


def test_failure_records_carry_traceback_not_just_message():
    """Satellite: the record has the worker's actual stack."""

    def boom(_):
        raise KeyError("the-inner-detail")

    _, failures = execute_shards(boom, [("a",)], "serial")
    [rec] = failures[0]
    assert rec["error"].startswith("KeyError")
    assert "in boom" in rec["traceback"]       # the raising frame, by name
    assert 'raise KeyError("the-inner-detail")' in rec["traceback"]


@pytest.mark.parametrize("executor", ["serial", "jax"])
def test_inline_hang_trips_cooperative_deadline(executor):
    """Regression: serial/jax enforce shard_timeout_s *preemptively*.

    The injected hang burns the whole deadline before the shard's search
    starts, so a post-hoc-only check (the old contract) would let the
    shard run its full budget to completion and only then discard the
    payload. The cooperative guard instead aborts at the first evaluator
    dispatch past the deadline — pinned by the distinct error text."""
    from repro.core import spec_tiny
    from repro.dist.worker import run_shard
    from repro.noc import Budget, NocProblem

    problem = NocProblem(spec=spec_tiny(), traffic="BFS", case="case3")
    inj = FaultInjector(faults=(
        {"kind": "hang", "round": 0, "attempt": 0, "hang_s": 0.4},))
    results, failures = execute_shards(
        run_shard,
        [(problem.to_json(), Budget(max_evals=200, seed=0).to_json(), 0)],
        executor, timeout_s=0.2, injector=inj)
    assert results == {}
    [rec] = failures[0]
    assert rec["phase"] == "timeout"
    assert "cooperative deadline exceeded" in rec["error"]
    assert "ShardDeadlineExceeded" in rec["traceback"]


def test_deadline_guard_is_inert_without_overrun():
    """A met deadline never perturbs the run: identical payloads with
    and without a (generous) cooperative deadline armed, up to wall
    clocks (wall_s and the history timestamp column)."""
    from repro.core import spec_tiny
    from repro.dist.worker import run_shard
    from repro.noc import Budget, NocProblem

    problem = NocProblem(spec=spec_tiny(), traffic="BFS", case="case3")
    task = [(problem.to_json(), Budget(max_evals=60, seed=2).to_json(), 2)]
    plain, f0 = execute_shards(run_shard, task, "serial")
    timed, f1 = execute_shards(run_shard, task, "serial", timeout_s=600.0)
    assert f0 == {} and f1 == {}
    for res in (plain, timed):
        res[0]["wall_s"] = 0.0
        res[0]["history"] = [row[1:] for row in res[0]["history"]]
    assert plain == timed


# ---------------------------------------------------------------------------
# execute_shards: process executor — real aborts, preemptive deadlines
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_process_abort_breaks_pool_then_rebuild_and_retry_succeeds():
    inj = FaultInjector(faults=(
        {"kind": "abort", "worker_id": 0, "round": 0, "attempt": 0},))
    with ShardPool(1) as pool:
        results, failures = execute_shards(
            int, [("5",)], "process", pool=pool, meta=[(0, 0)],
            max_retries=1, injector=inj)
        assert pool.rebuilds == 1              # the abort poisoned the pool
    assert results == {0: 5}                   # clean retry on the rebuilt pool
    [rec] = failures[0]
    assert rec["phase"] == "pool" and rec["attempt"] == 0
    assert "BrokenProcessPool" in rec["error"]


@pytest.mark.slow
def test_process_hang_trips_preemptive_deadline():
    inj = FaultInjector(faults=(
        {"kind": "hang", "worker_id": 0, "round": 0, "attempt": 0,
         "hang_s": 120.0},))
    with ShardPool(2) as pool:
        # Prewarm so the deadline measures the hang, not child startup.
        warm, _ = execute_shards(int, [("1",), ("2",)], "process", pool=pool)
        assert warm == {0: 1, 1: 2}
        results, failures = execute_shards(
            int, [("5",), ("7",)], "process", pool=pool,
            meta=[(0, 0), (1, 0)], timeout_s=10.0, max_retries=1,
            injector=inj)
        assert pool.rebuilds == 1              # hung child had to be killed
    assert results[0] == 5 and results[1] == 7  # both made it eventually
    recs = failures[0]
    assert recs[0]["phase"] == "timeout" and "deadline" in recs[0]["error"]
    # Shard 1 either finished before the trip or was rebuilt collateral.
    for rec in failures.get(1, []):
        assert rec["phase"] == "pool"


# ---------------------------------------------------------------------------
# StageDistConfig knob validation (construction-time, satellite)
# ---------------------------------------------------------------------------
def test_stage_dist_config_validates_resilience_knobs():
    StageDistConfig(shard_timeout_s=5.0, max_retries=0, retry_backoff_s=1.0)
    with pytest.raises(ValueError, match="shard_timeout_s"):
        StageDistConfig(shard_timeout_s=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        StageDistConfig(max_retries=-1)
    with pytest.raises(ValueError, match="retry_backoff_s"):
        StageDistConfig(retry_backoff_s=-0.1)
    with pytest.raises(ValueError, match="resume.*checkpoint_dir"):
        StageDistConfig(resume=True)
    with pytest.raises(ValueError, match="sync_every"):
        StageDistConfig(checkpoint_dir="/tmp/x", sync_every=0)
    with pytest.raises(ValueError, match="fault kind"):
        StageDistConfig(faults=({"kind": "meteor"},))
    cfg = StageDistConfig(checkpoint_dir="/tmp/x", sync_every=1,
                          faults=[{"kind": "kill_coordinator", "round": 1}])
    assert isinstance(cfg.faults, tuple)       # normalized for hashability
