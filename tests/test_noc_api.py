"""Unified ``repro.noc`` API: registry coverage, budget accounting,
serialization round trips, the CLI smoke tier, and the hardened move
validation (real exceptions instead of ``-O``-stripped asserts)."""

import json

import numpy as np
import pytest

from repro.core import (CASES, Design, Evaluator, PhvContext, dominates,
                        spec_tiny, traffic_matrix)
from repro.core.amosa import amosa
from repro.noc import (Budget, NocProblem, RunResult, design_from_json,
                       design_to_json, get_optimizer, named_spec,
                       optimizer_names, run)

ALL_OPTIMIZERS = ("amosa", "local", "nsga2", "pcbb", "stage", "stage_batch",
                  "stage_dist")

#: small-budget configs that exercise every optimizer in a few seconds
SMALL_CONFIGS = {
    "stage": dict(iters_max=2, n_swaps=4, n_link_moves=4, max_local_steps=5),
    "stage_batch": dict(n_starts=2, iters_max=2, n_swaps=4, n_link_moves=4,
                        max_local_steps=5),
    "stage_dist": dict(n_workers=2, executor="serial", iters_max=2,
                       n_swaps=4, n_link_moves=4, max_local_steps=5),
    "amosa": dict(t_max=0.5, t_min=0.05, alpha=0.7, iters_per_temp=8),
    "nsga2": dict(pop_size=8, generations=2),
    "local": dict(n_starts=2, n_swaps=4, n_link_moves=4, max_steps=4),
    "pcbb": dict(max_expansions=30, link_descent_steps=2,
                 n_random_rollouts=1),
}


@pytest.fixture(scope="module")
def tiny_problem():
    problem = NocProblem(spec=spec_tiny(), traffic="BFS", case="case3")
    ev = problem.evaluator()
    ctx = problem.context(ev)
    return problem, ev, ctx


def test_registry_contains_every_optimizer():
    assert optimizer_names() == ALL_OPTIMIZERS
    for name in ALL_OPTIMIZERS:
        entry = get_optimizer(name)
        assert entry.name == name and callable(entry.run_fn)
    with pytest.raises(ValueError, match="unknown optimizer"):
        get_optimizer("gradient_descent")


@pytest.mark.parametrize("name", ALL_OPTIMIZERS)
def test_every_optimizer_returns_roundtrippable_runresult(
        tiny_problem, name, tmp_path):
    """Acceptance: every registry optimizer runs under a shared Budget and
    its RunResult JSON round-trips to identical Pareto objectives."""
    problem, ev, ctx = tiny_problem
    if get_optimizer(name).owns_result:
        # Coordinator drivers (stage_dist) run on per-worker evaluators and
        # refuse ev=/ctx= injection — run them standalone.
        res = run(problem, name, budget=Budget(max_evals=400, seed=0),
                  config=SMALL_CONFIGS[name])
    else:
        budget = Budget(max_evals=ev.n_evals + 400, seed=0)
        res = run(problem, name, budget=budget, config=SMALL_CONFIGS[name],
                  ev=ev, ctx=ctx)
    assert isinstance(res, RunResult) and res.optimizer == name
    assert len(res.designs) >= 1 and res.n_evals > 0 and res.n_calls > 0
    assert np.isfinite(res.phv())
    # Pareto set: mutually non-dominated under the active objective subset,
    # structurally valid designs.
    sub = np.asarray(res.objs)[:, list(res.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])
    spec = problem.spec
    for d in res.designs:
        assert sorted(d.perm.tolist()) == list(range(spec.n_tiles))
        assert int(np.triu(d.adj).sum()) == spec.n_planar_links
        assert np.array_equal(d.adj, d.adj.T)
    # Exact JSON round trip (file and in-memory); saved files are strict
    # RFC JSON (no bare NaN/Infinity tokens — history phv is NaN here).
    path = tmp_path / f"{name}.json"
    res.save(path)
    json.loads(path.read_text())  # stdlib accepts lax too; check tokens:
    for token in ("NaN", "Infinity"):
        assert token not in path.read_text()
    back = RunResult.load(path)
    assert np.array_equal(np.asarray(back.objs), np.asarray(res.objs))
    assert back.obj_idx == res.obj_idx
    assert [d.key() for d in back.designs] == [d.key() for d in res.designs]
    # equal_nan: the history's phv column is NaN unless track_phv was on.
    assert np.array_equal(back.history, res.history, equal_nan=True)


def test_runresult_nonfinite_extra_roundtrips(tmp_path):
    """Non-finite diagnostics in ``extra`` survive save/load (NaN -> null
    -> NaN, inf -> "inf" -> inf) and the file stays strict JSON."""
    res = RunResult(
        optimizer="stage", problem={}, budget={},
        config={"iters_max": np.int64(3), "scale": np.float64(1.5)},
        obj_idx=(0, 1), designs=[], objs=np.zeros((0, 5)),
        n_evals=0, n_calls=0, wall_s=0.0, history=np.zeros((0, 4)),
        extra={"phv": float("nan"), "bound": float("inf"),
               "scores": [1.5, float("-inf")]})
    path = tmp_path / "nonfinite.json"
    res.save(path)
    assert "NaN" not in path.read_text()
    back = RunResult.load(path)
    assert np.isnan(back.extra["phv"]) and np.isnan(back.phv())
    assert back.extra["bound"] == float("inf")
    assert back.extra["scores"] == [1.5, float("-inf")]
    assert back.config == {"iters_max": 3, "scale": 1.5}


def _canonical_run_json(res: RunResult) -> str:
    """RunResult JSON with the only nondeterministic fields — wall-clock
    seconds (``wall_s`` and the history rows' wall column) — zeroed; every
    other byte must reproduce for a fixed (problem, budget, seed)."""
    j = res.to_json()
    j["wall_s"] = 0.0
    j["history"] = [[0.0] + row[1:] for row in j["history"]]
    return json.dumps(j, sort_keys=True)


@pytest.mark.parametrize("name", ["stage", "stage_batch"])
@pytest.mark.parametrize("forest_backend", ["numpy", "jnp"])
def test_registry_run_seeded_determinism(name, forest_backend):
    """Two registry runs with the same (NocProblem, Budget, seed) produce
    byte-identical RunResult JSON (wall-clock excluded) for both surrogate
    backends — the reproducibility contract the ROADMAP's distributed
    multi-start item merges workers on."""
    problem = NocProblem(spec=spec_tiny(), traffic="BFS", case="case3",
                         forest_backend=forest_backend)
    budget = Budget(max_evals=150, seed=3)
    first, second = (
        _canonical_run_json(run(problem, name, budget=budget,
                                config=SMALL_CONFIGS[name]))
        for _ in range(2))
    assert problem.forest_backend in first  # knob serialized with the run
    assert first == second


def test_forest_backend_validated_at_construction():
    """A bad forest_backend fails fast — at NocProblem/config construction,
    not at the first surrogate refit after evaluations were spent."""
    from repro.noc import StageBatchConfig, StageConfig

    with pytest.raises(ValueError, match="forest_backend"):
        NocProblem(spec=spec_tiny(), traffic="BFS", forest_backend="bogus")
    with pytest.raises(ValueError, match="forest_backend"):
        StageConfig(forest_backend="bogus")
    with pytest.raises(ValueError, match="forest_backend"):
        StageBatchConfig(forest_backend="bogus")
    assert StageConfig(forest_backend="pallas").forest_backend == "pallas"
    assert StageConfig().forest_backend is None  # inherit the problem's


def test_stage_dist_config_validated_and_injection_refused(tiny_problem):
    """StageDistConfig fails fast on bad knobs, and the owns-result driver
    refuses the single-process ev=/ctx=/callback= conveniences instead of
    silently mis-accounting them."""
    from repro.noc import StageDistConfig

    with pytest.raises(ValueError, match="executor"):
        StageDistConfig(executor="threads")
    with pytest.raises(ValueError, match="n_workers"):
        StageDistConfig(n_workers=0)
    with pytest.raises(ValueError, match="sync_every"):
        StageDistConfig(sync_every=-1)
    with pytest.raises(ValueError, match="forest_backend"):
        StageDistConfig(forest_backend="bogus")
    problem, ev, ctx = tiny_problem
    with pytest.raises(ValueError, match="owns its RunResult"):
        run(problem, "stage_dist", budget=Budget(max_evals=50),
            config=SMALL_CONFIGS["stage_dist"], ev=ev, ctx=ctx)
    with pytest.raises(ValueError, match="owns its RunResult"):
        run(problem, "stage_dist", budget=Budget(max_evals=50),
            config=SMALL_CONFIGS["stage_dist"], callback=print)


def test_run_with_prespent_budget_reports_exhausted(tiny_problem):
    """A budget already consumed at entry yields an empty result that is
    consistently flagged exhausted=True for every driver (nothing was
    evaluated by this run beyond what the guard allowed)."""
    problem, ev, ctx = tiny_problem
    for name in ("stage", "amosa", "local"):
        before = ev.n_evals
        res = run(problem, name, budget=Budget(max_evals=before, seed=0),
                  config=SMALL_CONFIGS[name], ev=ev, ctx=ctx)
        assert res.exhausted and res.n_evals == 0
        assert len(res.designs) == 0 and res.phv() == 0.0


def test_design_json_roundtrip_exact(tiny_problem):
    problem, ev, ctx = tiny_problem
    rng = np.random.default_rng(7)
    from repro.core import random_design

    for _ in range(3):
        d = random_design(problem.spec, rng)
        back = design_from_json(json.loads(json.dumps(design_to_json(d))))
        assert back.key() == d.key()


def test_problem_json_roundtrip():
    spec = spec_tiny()
    for traffic in ("BFS", ("BFS", "BP"),
                    traffic_matrix(spec, "BFS") * 0.5):
        p = NocProblem(spec=spec, traffic=traffic, case="case2")
        q = NocProblem.from_json(json.loads(json.dumps(p.to_json())))
        assert q.spec == p.spec and q.case == p.case
        assert np.allclose(q.traffic_matrix(), p.traffic_matrix())


def test_named_spec_and_bad_inputs():
    assert named_spec("tiny") == spec_tiny()
    with pytest.raises(ValueError, match="unknown spec"):
        named_spec("128")
    with pytest.raises(ValueError, match="unknown case"):
        NocProblem(spec=spec_tiny(), traffic="BFS", case="case9")


def test_problem_eq_and_hash_with_matrix_traffic():
    """Explicit-matrix problems must compare and hash (the generated
    dataclass __eq__ would crash on ndarrays) — cache/dedup keys for the
    distributed fan-out."""
    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    p1 = NocProblem(spec=spec, traffic=f.copy())
    p2 = NocProblem(spec=spec, traffic=f.copy())
    p3 = NocProblem(spec=spec, traffic=f * 2.0)
    assert p1 == p2 and hash(p1) == hash(p2)
    assert p1 != p3
    assert NocProblem(spec=spec, traffic="BFS") != p1
    assert len({p1, p2, p3}) == 2


# ---------------------------------------------------------------------------
# Evaluation accounting
# ---------------------------------------------------------------------------
def test_evaluator_counts_requested_designs_only():
    """n_evals counts requested designs — padding to the next power of two
    and max_batch chunking are invisible; n_calls counts dispatches."""
    spec = spec_tiny()
    ev = Evaluator(spec, traffic_matrix(spec, "BFS"), max_batch=4)
    mesh = spec.mesh_design()
    ev.batch([mesh] * 3)                  # pads to 4
    assert ev.n_evals == 3 and ev.n_calls == 1
    ev.batch([mesh] * 10)                 # chunks 4 + 4 + 2 (padded to 2)
    assert ev.n_evals == 13 and ev.n_calls == 4
    ev.batch([])                          # empty: no dispatch, no evals
    assert ev.n_evals == 13 and ev.n_calls == 4
    ev(mesh)                              # single-design path
    assert ev.n_evals == 14 and ev.n_calls == 5


def test_registry_budget_agrees_with_legacy_driver_counts():
    """Acceptance: a registry run at Budget(max_evals=B) spends exactly the
    evaluations the legacy driver call spends, and finds the same Pareto
    objectives."""
    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    B = 150
    ev = Evaluator(spec, f)
    ctx = PhvContext(ev(spec.mesh_design()), CASES["case3"])
    legacy = amosa(spec, ev, ctx, spec.mesh_design(), seed=0, t_max=0.5,
                   t_min=0.05, alpha=0.7, iters_per_temp=10, max_evals=B)

    problem = NocProblem(spec=spec, traffic="BFS", case="case3")
    res = run(problem, "amosa", budget=Budget(max_evals=B, seed=0),
              config=dict(t_max=0.5, t_min=0.05, alpha=0.7,
                          iters_per_temp=10))
    assert res.n_evals == ev.n_evals
    assert np.array_equal(np.sort(np.asarray(res.objs), axis=0),
                          np.sort(legacy.objs, axis=0))


def test_budget_guard_backstops_pcbb(tiny_problem):
    """PCBB has no native max_evals — the uniform guard stops it and the
    recorder's best-so-far Pareto set is returned."""
    problem, ev, ctx = tiny_problem
    cap = ev.n_evals + 40
    res = run(problem, "pcbb", budget=Budget(max_evals=cap, seed=0),
              config=dict(max_expansions=500), ev=ev, ctx=ctx)
    assert res.exhausted
    assert len(res.designs) >= 1
    # Overshoot bounded by the single dispatch in flight when the guard fired.
    assert ev.n_evals <= cap + 8


def test_budget_guard_max_calls(tiny_problem):
    problem, ev, ctx = tiny_problem
    res = run(problem, "nsga2",
              budget=Budget(max_calls=ev.n_calls + 2, seed=0),
              config=dict(pop_size=8, generations=10), ev=ev, ctx=ctx)
    assert res.exhausted and res.n_calls <= 3


def test_run_callback_streams_telemetry(tiny_problem):
    problem, ev, ctx = tiny_problem
    events = []
    run(problem, "local", budget=Budget(seed=1),
        config=dict(n_starts=1, n_swaps=4, n_link_moves=4, max_steps=3),
        callback=events.append, ev=ev, ctx=ctx)
    assert events, "callback never fired"
    evs = [e["n_evals"] for e in events]
    assert evs == sorted(evs)
    assert all({"n_evals", "n_calls", "best_edp", "wall_s"} <= set(e)
               for e in events)


# ---------------------------------------------------------------------------
# AMOSA adaptive speculative block
# ---------------------------------------------------------------------------
def test_amosa_adaptive_block_budget_pinned(tiny_problem):
    """Adaptive blocks clip to the remaining budget: a budget-bound chain
    spends max_evals exactly (no speculative overshoot), and the archive
    stays mutually non-dominated."""
    problem, ev, ctx = tiny_problem
    spec = problem.spec
    start = ev.n_evals
    B = start + 120
    arch = amosa(spec, ev, ctx, spec.mesh_design(), seed=3, t_max=1.0,
                 t_min=1e-6, alpha=0.7, iters_per_temp=10, max_evals=B,
                 adaptive_block=True, block_max=16)
    assert ev.n_evals == B, "adaptive blocks must land exactly on the budget"
    sub = arch.objs[:, list(ctx.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])


def test_amosa_default_block_unchanged(tiny_problem):
    """block_size=1 (the default) keeps exact sequential accounting — the
    adaptive machinery must not perturb the legacy path."""
    problem, ev, ctx = tiny_problem
    spec = problem.spec
    start = ev.n_evals
    B = start + 60
    a1 = amosa(spec, ev, ctx, spec.mesh_design(), seed=11, t_max=0.5,
               t_min=1e-6, alpha=0.7, iters_per_temp=10, max_evals=B)
    assert ev.n_evals == B
    B2 = ev.n_evals + 60
    a2 = amosa(spec, ev, ctx, spec.mesh_design(), seed=11, t_max=0.5,
               t_min=1e-6, alpha=0.7, iters_per_temp=10, max_evals=B2,
               block_size=1, adaptive_block=False)
    assert np.array_equal(np.sort(a1.objs, axis=0), np.sort(a2.objs, axis=0))
    with pytest.raises(ValueError, match="block_size"):
        amosa(spec, ev, ctx, spec.mesh_design(), block_size=0)


# ---------------------------------------------------------------------------
# Move validation — real exceptions, not -O-stripped asserts
# ---------------------------------------------------------------------------
def test_move_link_validation_raises():
    spec = spec_tiny()
    mesh = spec.mesh_design()
    from repro.core.problem import absent_planar_pairs, existing_planar_links

    links = existing_planar_links(spec, mesh.adj)
    holes = absent_planar_pairs(spec, mesh.adj)
    # Valid move works.
    moved = mesh.move_link(links[0], holes[0])
    assert int(np.triu(moved.adj).sum()) == spec.n_planar_links
    # Removing a non-existent link.
    with pytest.raises(ValueError, match="non-existent"):
        mesh.move_link(holes[0], holes[1])
    # Adding an already-present link.
    with pytest.raises(ValueError, match="already-present"):
        mesh.move_link(links[0], links[1])
    # Self-links.
    with pytest.raises(ValueError, match="self-link"):
        mesh.move_link(links[0], (2, 2))
    with pytest.raises(ValueError, match="differ"):
        mesh.swap_tiles(1, 1)
    # The original design is untouched by a failed move.
    assert mesh.key() == spec.mesh_design().key()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_run_smoke(capsys):
    """Tier-1 gate: the CLI smoke run (registry dispatch + budget
    enforcement + JSON round trip) must pass."""
    from repro.noc import cli

    assert cli.main(["run", "--smoke", "--quiet"]) == 0
    assert "smoke ok" in capsys.readouterr().out
