"""Crash-safe round checkpoints + interrupt/resume determinism
(DESIGN.md §9).

The contract under test: a synced ``stage_dist`` run with
``checkpoint_dir`` set persists its complete coordinator state after
every round; killing the coordinator mid-run (via the deterministic
``kill_coordinator`` fault) and resuming with ``resume=True`` produces a
merged RunResult whose canonical payload is byte-identical to the
uninterrupted run's. Plus the chaos acceptance pin: a W=4 run surviving
a hung shard, a crashing worker, and a coordinator kill still completes
within budget, reports every failure, and merges a union front no worse
than any survivor's.
"""

import json
import math

import numpy as np
import pytest

from repro.core import spec_tiny
from repro.dist import CoordinatorKilled
from repro.noc import Budget, NocProblem, RunResult, run

SMALL = dict(iters_max=2, n_swaps=4, n_link_moves=4, max_local_steps=5)


@pytest.fixture(scope="module")
def tiny_problem() -> NocProblem:
    return NocProblem(spec=spec_tiny(), traffic="BFS", case="case3")


def _payload(res: RunResult) -> str:
    """Canonical payload JSON (same canon as test_dist.py): wall-clock
    zeroed; driver-naming header fields (optimizer/config/extra)
    excluded — config legitimately differs (faults/checkpoint knobs)."""
    j = res.to_json()
    j["history"] = [[0.0] + row[1:] for row in j["history"]]
    keep = ("problem", "budget", "obj_idx", "designs", "objs", "history",
            "n_evals", "n_calls", "exhausted")
    return json.dumps({k: j[k] for k in keep}, sort_keys=True)


def _interrupt_then_resume(problem, budget, cfg, kill_round, ckpt_dir,
                           resume_cfg=None):
    """Run with a scripted coordinator kill after ``kill_round``, then
    resume from the checkpoint; returns the resumed RunResult."""
    with pytest.raises(CoordinatorKilled, match="checkpoint saved"):
        run(problem, "stage_dist", budget=budget,
            config=dict(cfg, faults=(
                {"kind": "kill_coordinator", "round": kill_round},)),
            checkpoint_dir=ckpt_dir)
    return run(problem, "stage_dist", budget=budget,
               config=dict(resume_cfg if resume_cfg is not None else cfg),
               checkpoint_dir=ckpt_dir, resume=True)


# ---------------------------------------------------------------------------
# Interrupt/resume byte-identity (the tentpole's core pin)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kill_round", [0, 1])
def test_serial_resume_is_byte_identical(tiny_problem, tmp_path, kill_round):
    budget = Budget(max_evals=300, seed=1)
    cfg = dict(SMALL, n_workers=2, executor="serial", sync_every=1,
               iters_max=3)
    ref = run(tiny_problem, "stage_dist", budget=budget, config=cfg)
    res = _interrupt_then_resume(tiny_problem, budget, cfg, kill_round,
                                 str(tmp_path / f"ck{kill_round}"))
    assert _payload(res) == _payload(ref)
    assert res.extra["history_spans"] == ref.extra["history_spans"]
    assert res.extra["resumed_from_round"] == kill_round
    ck = res.extra["checkpoint"]
    assert ck["n_saves"] >= 1 and ck["save_s"] >= 0.0


@pytest.mark.slow
def test_process_resume_is_byte_identical(tiny_problem, tmp_path):
    budget = Budget(max_evals=300, seed=1)
    cfg = dict(SMALL, n_workers=2, executor="process", sync_every=1,
               iters_max=3)
    ref = run(tiny_problem, "stage_dist", budget=budget, config=cfg)
    res = _interrupt_then_resume(tiny_problem, budget, cfg, 1,
                                 str(tmp_path / "ck"))
    assert _payload(res) == _payload(ref)
    # Executor is NOT part of the run identity: a serial resume of a
    # process-interrupted run continues the same trajectory.
    res2 = _interrupt_then_resume(
        tiny_problem, budget, cfg, 1, str(tmp_path / "ck2"),
        resume_cfg=dict(cfg, executor="serial"))
    assert _payload(res2) == _payload(ref)


def test_resume_refuses_mismatched_run(tiny_problem, tmp_path):
    budget = Budget(max_evals=200, seed=3)
    cfg = dict(SMALL, n_workers=2, executor="serial", sync_every=1)
    run(tiny_problem, "stage_dist", budget=budget, config=cfg,
        checkpoint_dir=str(tmp_path))
    # Different seed => different run identity: refuse, don't merge.
    with pytest.raises(ValueError, match="different run"):
        run(tiny_problem, "stage_dist", budget=Budget(max_evals=200, seed=4),
            config=cfg, checkpoint_dir=str(tmp_path), resume=True)
    # Different trajectory config (n_workers) is a different run too.
    with pytest.raises(ValueError, match="different run"):
        run(tiny_problem, "stage_dist", budget=budget,
            config=dict(cfg, n_workers=3),
            checkpoint_dir=str(tmp_path), resume=True)


def test_resume_of_completed_run_is_a_noop_replay(tiny_problem, tmp_path):
    """Resuming a checkpoint whose run already finished must return the
    finished state unchanged — not dispatch extra rounds the
    uninterrupted run would never have run."""
    budget = Budget(max_evals=200, seed=5)
    cfg = dict(SMALL, n_workers=2, executor="serial", sync_every=1)
    ref = run(tiny_problem, "stage_dist", budget=budget, config=cfg,
              checkpoint_dir=str(tmp_path))
    res = run(tiny_problem, "stage_dist", budget=budget, config=cfg,
              checkpoint_dir=str(tmp_path), resume=True)
    assert _payload(res) == _payload(ref)


def test_checkpoint_requires_sync_rounds(tiny_problem):
    with pytest.raises(ValueError, match="sync_every"):
        run(tiny_problem, "stage_dist", budget=Budget(max_evals=50),
            config=dict(SMALL, n_workers=2, sync_every=0),
            checkpoint_dir="/tmp/nope")
    # Non-coordinator optimizers have no round checkpoints at all.
    with pytest.raises(ValueError, match="does not support"):
        run(tiny_problem, "stage", budget=Budget(max_evals=50),
            checkpoint_dir="/tmp/nope")


def test_no_fault_path_unchanged_by_checkpointing(tiny_problem, tmp_path):
    """Observability must not perturb the search: the checkpointed run's
    payload equals the plain run's (PR 5 determinism pins intact)."""
    budget = Budget(max_evals=250, seed=2)
    cfg = dict(SMALL, n_workers=2, executor="serial", sync_every=1)
    plain = run(tiny_problem, "stage_dist", budget=budget, config=cfg)
    ckpt = run(tiny_problem, "stage_dist", budget=budget, config=cfg,
               checkpoint_dir=str(tmp_path))
    assert _payload(ckpt) == _payload(plain)
    assert plain.extra["worker_failures"] == []
    assert plain.extra["pool_rebuilds"] == 0


# ---------------------------------------------------------------------------
# Chaos acceptance pin (ISSUE: 1 hang + 1 crash + 1 coordinator kill, W=4)
# ---------------------------------------------------------------------------
def test_chaos_run_survives_and_reports_everything(tiny_problem, tmp_path):
    budget = Budget(max_evals=400, seed=9)
    # The deadline must sit between a legitimate shard round's wall time
    # (sub-second to a few seconds on a loaded machine) and the injected
    # hang — generous on both sides so the only deadline trip is the
    # scripted one.
    cfg = dict(SMALL, n_workers=4, executor="serial", sync_every=1,
               iters_max=3, shard_timeout_s=8.0, max_retries=1)
    faults = (
        # Worker 2 hangs past the deadline on round 0 attempt 0; its
        # reseeded retry runs clean.
        {"kind": "hang", "worker_id": 2, "round": 0, "attempt": 0,
         "hang_s": 8.5},
        # Worker 1 crashes BOTH attempts of round 1: retries exhausted,
        # dropped from later rounds.
        {"kind": "crash", "worker_id": 1, "round": 1, "attempt": 0},
        {"kind": "crash", "worker_id": 1, "round": 1, "attempt": 1},
        # And the coordinator dies after round 1's checkpoint.
        {"kind": "kill_coordinator", "round": 1},
    )
    with pytest.raises(CoordinatorKilled):
        run(tiny_problem, "stage_dist", budget=budget,
            config=dict(cfg, faults=faults), checkpoint_dir=str(tmp_path))
    res = run(tiny_problem, "stage_dist", budget=budget, config=cfg,
              checkpoint_dir=str(tmp_path), resume=True)

    # Completed within the global eval budget (+ the documented per-worker
    # in-flight overshoot; lost attempts are unaccounted by design).
    per_worker = 2 * (SMALL["n_swaps"] + SMALL["n_link_moves"]) + 2
    assert res.n_evals <= 400 + 4 * per_worker
    assert res.extra["resumed_from_round"] == 1

    # Every injected degradation shows up in the failure ledger.
    fails = res.extra["worker_failures"]
    assert [(f["worker_id"], f["round"], f["attempt"], f["phase"])
            for f in fails] == [
        (2, 0, 0, "timeout"),       # the hang, caught post-hoc
        (1, 1, 0, "run"),           # the crash...
        (1, 1, 1, "run"),           # ...and its doomed retry
    ]
    assert all(f["traceback"] or f["phase"] == "timeout" for f in fails)

    # Worker 1's round-0 span survives; nothing of its round 2 exists.
    span_tags = [w for w, _, _ in res.extra["history_spans"]]
    from repro.dist.sync import ROUND_TAG_STRIDE
    assert (1 * ROUND_TAG_STRIDE + 0) in span_tags
    assert (1 * ROUND_TAG_STRIDE + 2) not in span_tags

    # The merged front is the union of the survivors: its PHV is never
    # worse than any single surviving worker's own.
    worker_phvs = [w["phv"] for w in res.extra["workers"]
                   if not math.isnan(w["phv"])]
    assert worker_phvs and res.phv() >= max(worker_phvs) - 1e-12
    assert len(res.designs) >= 1 and np.isfinite(res.phv())
