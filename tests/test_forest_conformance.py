"""Cross-backend forest conformance: the three-way triangle
numpy (f64 oracle) <-> jnp (jitted f32 twin) <-> pallas (blocked kernel,
interpret mode on CPU).

The numpy path is bit-equal to the recursive reference (pinned in
test_forest.py); the jnp and pallas paths share identical f32 compare
semantics, so they must agree to reduction-order noise with each other and
to f32 threshold rounding (<= 1e-6 here) with the oracle. Edge shapes:
1-row batches, batches not divisible by the kernel block size, single-node
(leaf-only) trees, max-depth trees, and padded node tails.

Property tests need ``hypothesis``; without it they are skipped and the
unit tests still run (same pattern as test_forest.py)."""

import warnings

import numpy as np
import pytest

from repro.core import forest as forest_mod
from repro.core.forest import RegressionForest, resolve_forest_backend

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - property tests skip without it
    st = None

pytestmark = pytest.mark.interpret


def _fit(n=200, f=5, seed=0, **kw):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, size=(n, f))
    y = x[:, 0] * 2 + np.sin(3 * x[:, 1]) + 0.1 * rng.normal(size=n)
    return RegressionForest(seed=seed, **kw).fit(x, y), rng


def _assert_triangle(model, xq, atol_oracle=1e-6, atol_twin=1e-6):
    """All three backends agree on ``xq``: pallas(interpret) within
    ``atol_oracle`` of the f64 numpy oracle and within ``atol_twin`` of the
    jnp twin (identical f32 branch decisions by construction)."""
    ref = model.predict(xq, backend="numpy")
    jnp_out = model.predict(xq, backend="jnp")
    pal = model.predict(xq, backend="pallas", interpret=True)
    assert pal.shape == ref.shape == jnp_out.shape
    np.testing.assert_allclose(pal, ref, rtol=0, atol=atol_oracle)
    np.testing.assert_allclose(pal, jnp_out, rtol=0, atol=atol_twin)


# ------------------------------------------------------------- batch shapes
@pytest.mark.parametrize(
    "batch",
    [1,            # single row
     5,            # tiny odd
     127, 129,     # one off the 128 kernel block on each side
     128,          # exactly one block
     500,          # non-divisible multi-block
     1025],        # above the numpy path's 1024 layout switch
)
def test_conformance_over_batch_shapes(batch):
    model, rng = _fit(n=300, f=6, n_trees=10, max_depth=7)
    xq = rng.uniform(-1.5, 1.5, size=(batch, 6))  # extrapolation included
    _assert_triangle(model, xq)


def test_conformance_1d_input_promotes_like_other_backends():
    model, rng = _fit()
    xq = rng.uniform(-1, 1, size=5)
    pal = model.predict(xq, backend="pallas", interpret=True)
    assert pal.shape == (1,)
    np.testing.assert_allclose(pal, model.predict(xq, backend="numpy"),
                               rtol=0, atol=1e-6)


# ------------------------------------------------------------- tree shapes
def test_single_node_trees():
    """max_depth=0: every tree is one leaf, the level loop unrolls to
    nothing and the kernel reduces the root values."""
    model, rng = _fit(n=100, f=3, n_trees=5, max_depth=0)
    assert model._flat["depth"] == 0
    _assert_triangle(model, rng.uniform(-1, 1, size=(17, 3)))


def test_max_depth_trees():
    """min_leaf=1 on dense data grows trees to the depth cap — the deepest
    unrolled traversal the repo's configs can produce."""
    model, rng = _fit(n=256, f=4, n_trees=6, max_depth=16, min_leaf=1)
    assert model._flat["depth"] >= 10
    _assert_triangle(model, rng.uniform(-1, 1, size=(77, 4)))


def test_mixed_size_trees_pad_node_tails():
    """Bootstrap variation gives per-tree node counts below the padded M;
    the short trees' tails are self-looping filler the traversal must never
    enter from a real root."""
    model, rng = _fit(n=60, f=5, n_trees=12, max_depth=6, min_leaf=1)
    feature = model._flat["feature"]
    sizes = [(row != -1).sum() for row in feature]  # split-node counts
    assert len(set(sizes)) > 1  # genuinely ragged before padding
    _assert_triangle(model, rng.uniform(-1, 1, size=(33, 5)))


def test_kernel_tolerates_extra_padded_tail_and_small_blocks():
    """Direct kernel call: growing M with explicit self-loop filler nodes
    must not change predictions, at any batch block size (incl. blocks that
    do not divide the batch)."""
    import jax.numpy as jnp

    from repro.kernels.forest import forest_predict

    model, rng = _fit(n=200, f=5, n_trees=7, max_depth=5)
    fl = model._flat
    t, m = fl["feature"].shape
    pad = 7
    thr = np.zeros((t, m + pad), np.float32)
    thr[:, :m] = fl["threshold"]
    feat = np.zeros((t, m + pad), np.int32)
    feat[:, :m] = np.maximum(fl["feature"], 0)
    val = np.zeros((t, m + pad), np.float32)
    val[:, :m] = fl["value"]
    child = np.tile(np.repeat(np.arange(m + pad, dtype=np.int32), 2), (t, 1))
    child[:, 0:2 * m:2] = fl["left"]
    child[:, 1:2 * m:2] = fl["right"]

    xq = rng.uniform(-1, 1, size=(50, 5))
    xn = ((xq - model._xm) / model._xs).astype(np.float32)
    ref = model.predict(xq, backend="numpy")
    for block_b in (8, 32, 128):
        out = forest_predict(jnp.asarray(thr), jnp.asarray(feat),
                             jnp.asarray(child), jnp.asarray(val),
                             jnp.asarray(xn), depth=fl["depth"],
                             block_b=block_b, interpret=True)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=0, atol=1e-6)


def test_constant_labels_degenerate_fit():
    rng = np.random.default_rng(0)
    x = rng.uniform(size=(50, 4))
    model = RegressionForest(n_trees=6, seed=1).fit(x, np.full(50, 3.25))
    xq = rng.uniform(size=(9, 4))
    out = model.predict(xq, backend="pallas", interpret=True)
    np.testing.assert_allclose(out, np.full(9, 3.25), rtol=0, atol=1e-6)


# ------------------------------------------------------- fallback contract
def test_pallas_resolves_off_tpu_with_one_time_warning(monkeypatch):
    """On a host without a TPU an explicit "pallas" (no interpret) must
    resolve to "jnp" — never fail inside jit — and warn exactly once
    (same contract as core.routing's backend resolution)."""
    import jax

    if jax.default_backend() == "tpu":  # pragma: no cover - CPU container
        pytest.skip("fallback only exists off-TPU")
    monkeypatch.setattr(forest_mod, "_PALLAS_FALLBACK_WARNED", False)
    with pytest.warns(UserWarning, match="falling back to 'jnp'"):
        assert resolve_forest_backend("pallas") == "jnp"
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a second warning would raise
        assert resolve_forest_backend("pallas") == "jnp"
    # interpret mode runs the kernel anywhere — no fallback, no warning.
    monkeypatch.setattr(forest_mod, "_PALLAS_FALLBACK_WARNED", False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_forest_backend("pallas", interpret=True) == "pallas"


def test_pallas_forest_predict_falls_back_off_tpu(monkeypatch):
    """predict(backend="pallas") without interpret goes through the
    fallback and returns exactly the jnp twin's output."""
    import jax

    if jax.default_backend() == "tpu":  # pragma: no cover - CPU container
        pytest.skip("fallback only exists off-TPU")
    monkeypatch.setattr(forest_mod, "_PALLAS_FALLBACK_WARNED", False)
    model, rng = _fit(n=120, f=4, n_trees=6)
    xq = rng.uniform(-1, 1, size=(21, 4))
    with pytest.warns(UserWarning, match="falling back to 'jnp'"):
        out = model.predict(xq, backend="pallas")
    np.testing.assert_array_equal(out, model.predict(xq, backend="jnp"))


def test_on_device_kernel_failure_disables_pallas(monkeypatch):
    """If the kernel itself fails on real hardware (e.g. Mosaic rejects a
    lowering), the predict falls back to the jnp twin, warns once, and the
    process-wide resolution stops picking pallas — "auto" on TPU must never
    crash an optimizer run mid-search. interpret failures still raise (they
    are test bugs, not platform limitations)."""
    from repro.kernels import forest as kforest

    monkeypatch.setattr(forest_mod, "_PALLAS_DISABLED", False)
    monkeypatch.setattr(forest_mod, "_PALLAS_FALLBACK_WARNED", False)
    model, rng = _fit(n=80, f=4, n_trees=5)
    xq = rng.uniform(-1, 1, size=(9, 4))
    want = model.predict(xq, backend="jnp")

    def boom(*a, **k):
        raise RuntimeError("Mosaic lowering failed")

    monkeypatch.setattr(kforest, "forest_predict", boom)
    with pytest.warns(UserWarning, match="disabling"):
        out = model._predict_pallas(model._normalize(xq), interpret=False)
    np.testing.assert_array_equal(out, want)
    assert forest_mod._PALLAS_DISABLED
    # Resolution now routes pallas to jnp silently, without re-warning.
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_forest_backend("pallas") == "jnp"
    # interpret mode keeps raising — and stays resolvable for tests.
    assert resolve_forest_backend("pallas", interpret=True) == "pallas"
    with pytest.raises(RuntimeError, match="Mosaic"):
        model._predict_pallas(model._normalize(xq), interpret=True)
    monkeypatch.setattr(forest_mod, "_PALLAS_DISABLED", False)


# -------------------------------------------------------------- properties
def given_forest_cases(max_examples):
    """Property decorator when hypothesis is available, skip otherwise
    (mirrors tests/test_forest.py)."""
    def deco(fn):
        if st is None:
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            return stub
        cases = st.tuples(
            st.integers(0, 2**31 - 1),           # seed
            st.integers(2, 60),                  # n_train
            st.integers(1, 6),                   # n_features
            st.integers(1, 8),                   # n_trees
            st.integers(0, 6),                   # max_depth
            st.integers(1, 140),                 # query batch
        )
        return settings(max_examples=max_examples, deadline=None)(
            given(cases)(fn))
    return deco


@given_forest_cases(max_examples=20)
def test_property_pallas_equals_jnp_twin(case):
    """pallas(interpret) and jnp make identical f32 branch decisions, so
    they agree to reduction-order noise on arbitrary forests/batches."""
    seed, n, f, trees, depth, batch = case
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f))
    y = rng.normal(size=n)
    model = RegressionForest(n_trees=trees, max_depth=depth,
                             seed=seed % 1000).fit(x, y)
    xq = rng.normal(size=(batch, f))
    np.testing.assert_allclose(
        model.predict(xq, backend="pallas", interpret=True),
        model.predict(xq, backend="jnp"), rtol=0, atol=1e-6)
