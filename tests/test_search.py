"""Search algorithms: Alg. 1 invariants, MOO-STAGE, AMOSA, NSGA-II, PCBB,
and the regression forest."""

import numpy as np
import pytest

from repro.core import (CASES, Design, Evaluator, PhvContext, dominates,
                        random_design, spec_16, spec_tiny, traffic_matrix)
from repro.core.amosa import amosa
from repro.core.forest import RegressionForest
from repro.core.local_search import SearchHistory, local_search
from repro.core.nsga2 import nsga2
from repro.core.pcbb import pcbb
from repro.core.stage import moo_stage


@pytest.fixture(scope="module")
def small_problem():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    ev = Evaluator(spec, f)
    ctx = PhvContext(ev(spec.mesh_design()), CASES["case3"])
    return spec, f, ev, ctx


def test_local_search_improves_phv(small_problem):
    spec, f, ev, ctx = small_problem
    rng = np.random.default_rng(0)
    mesh = spec.mesh_design()
    start_phv = ctx.phv(ev(mesh)[None])
    res = local_search(spec, ev, ctx, mesh, rng, n_swaps=8, n_link_moves=8,
                       max_steps=15)
    assert res.phv >= start_phv
    # Local set is mutually non-dominated under the active objectives.
    sub = res.local.objs[:, list(ctx.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])
    # Trajectory starts at the start design.
    assert res.traj[0].key() == mesh.key()


def test_moo_stage_beats_mesh(small_problem):
    spec, f, ev, ctx = small_problem
    mesh = spec.mesh_design()
    res = moo_stage(spec, ev, ctx, mesh, seed=0, iters_max=3,
                    n_swaps=8, n_link_moves=8, max_local_steps=12)
    assert len(res.global_set.designs) >= 1
    assert ctx.phv(res.global_set.objs) >= ctx.phv(ev(mesh)[None])
    # Designs remain structurally valid: perm is a permutation, link budget kept.
    for d in res.global_set.designs:
        assert sorted(d.perm.tolist()) == list(range(spec.n_tiles))
        assert int(np.triu(d.adj).sum()) == spec.n_planar_links
        assert np.array_equal(d.adj, d.adj.T)


def test_moo_stage_history_monotone(small_problem):
    spec, f, ev, ctx = small_problem
    hist = SearchHistory(ev, ctx)
    moo_stage(spec, ev, ctx, spec.mesh_design(), seed=1, iters_max=2,
              n_swaps=8, n_link_moves=8, max_local_steps=10, history=hist)
    arr = hist.as_array()
    if arr.shape[0] > 1:
        assert np.all(np.diff(arr[:, 2]) <= 1e-12)   # best EDP non-increasing
        assert np.all(np.diff(arr[:, 1]) >= 0)       # evals non-decreasing


def test_amosa_archive_nondominated(small_problem):
    spec, f, ev, ctx = small_problem
    arch = amosa(spec, ev, ctx, spec.mesh_design(), seed=0, t_max=0.5,
                 t_min=0.05, alpha=0.7, iters_per_temp=10, max_evals=200)
    sub = arch.objs[:, list(ctx.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])


def test_nsga2_runs_and_improves(small_problem):
    spec, f, ev, ctx = small_problem
    mesh = spec.mesh_design()
    ps = nsga2(spec, ev, ctx, mesh, seed=0, pop_size=8, generations=5)
    assert len(ps.designs) >= 1
    assert ctx.phv(ps.objs) >= ctx.phv(ev(mesh)[None]) - 1e-9


def test_pcbb_finds_design_better_or_equal_mesh(small_problem):
    spec, f, ev, ctx = small_problem
    res = pcbb(spec, ev, ctx, seed=0, max_expansions=500)
    mesh_scal = float(ctx.normalize(ev(spec.mesh_design())).mean())
    best_scal = float(ctx.normalize(res.best_objs).mean())
    assert best_scal <= mesh_scal + 1e-9
    assert res.nodes_expanded > 0


def test_regression_forest_fits_smooth_function():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(400, 5))
    y = x[:, 0] * 2 + np.sin(3 * x[:, 1]) + 0.5 * x[:, 2] ** 2
    model = RegressionForest(n_trees=16, max_depth=8, seed=0).fit(x, y)
    pred = model.predict(x)
    sse = float(np.mean((pred - y) ** 2))
    var = float(np.var(y))
    assert sse < 0.2 * var  # explains >80% variance in-sample
    # Generalizes reasonably.
    xt = rng.uniform(-1, 1, size=(200, 5))
    yt = xt[:, 0] * 2 + np.sin(3 * xt[:, 1]) + 0.5 * xt[:, 2] ** 2
    sse_t = float(np.mean((model.predict(xt) - yt) ** 2))
    assert sse_t < 0.5 * float(np.var(yt))


def test_neighbor_moves_preserve_invariants(small_problem):
    spec, f, ev, ctx = small_problem
    from repro.core import sample_neighbors
    rng = np.random.default_rng(0)
    d = random_design(spec, rng)
    for nb in sample_neighbors(spec, d, rng, 10, 10):
        assert sorted(nb.perm.tolist()) == list(range(spec.n_tiles))
        assert int(np.triu(nb.adj).sum()) == spec.n_planar_links
        # planar links only connect same-layer slots
        iu = np.triu_indices(spec.n_tiles, 1)
        on = nb.adj[iu]
        assert np.all(spec.planar_pair_mask[iu][on])
