"""Search algorithms: Alg. 1 invariants, MOO-STAGE, AMOSA, NSGA-II, PCBB,
and the regression forest."""

import numpy as np
import pytest

from repro.core import (CASES, Design, Evaluator, PhvContext, dominates,
                        random_design, spec_16, spec_tiny, traffic_matrix)
from repro.core.amosa import amosa
from repro.core.forest import RegressionForest
from repro.core.local_search import (SearchHistory, local_search,
                                     local_search_batch)
from repro.core.nsga2 import _fast_nondominated_rank, nsga2, rank_and_crowding
from repro.core.pcbb import pcbb
from repro.core.stage import moo_stage, stage_batch


@pytest.fixture(scope="module")
def small_problem():
    spec = spec_tiny()
    f = traffic_matrix(spec, "BFS")
    ev = Evaluator(spec, f)
    ctx = PhvContext(ev(spec.mesh_design()), CASES["case3"])
    return spec, f, ev, ctx


def test_local_search_improves_phv(small_problem):
    spec, f, ev, ctx = small_problem
    rng = np.random.default_rng(0)
    mesh = spec.mesh_design()
    start_phv = ctx.phv(ev(mesh)[None])
    res = local_search(spec, ev, ctx, mesh, rng, n_swaps=8, n_link_moves=8,
                       max_steps=15)
    assert res.phv >= start_phv
    # Local set is mutually non-dominated under the active objectives.
    sub = res.local.objs[:, list(ctx.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])
    # Trajectory starts at the start design.
    assert res.traj[0].key() == mesh.key()


def test_moo_stage_beats_mesh(small_problem):
    spec, f, ev, ctx = small_problem
    mesh = spec.mesh_design()
    res = moo_stage(spec, ev, ctx, mesh, seed=0, iters_max=3,
                    n_swaps=8, n_link_moves=8, max_local_steps=12)
    assert len(res.global_set.designs) >= 1
    assert ctx.phv(res.global_set.objs) >= ctx.phv(ev(mesh)[None])
    # Designs remain structurally valid: perm is a permutation, link budget kept.
    for d in res.global_set.designs:
        assert sorted(d.perm.tolist()) == list(range(spec.n_tiles))
        assert int(np.triu(d.adj).sum()) == spec.n_planar_links
        assert np.array_equal(d.adj, d.adj.T)


def test_moo_stage_history_monotone(small_problem):
    spec, f, ev, ctx = small_problem
    hist = SearchHistory(ev, ctx)
    moo_stage(spec, ev, ctx, spec.mesh_design(), seed=1, iters_max=2,
              n_swaps=8, n_link_moves=8, max_local_steps=10, history=hist)
    arr = hist.as_array()
    if arr.shape[0] > 1:
        assert np.all(np.diff(arr[:, 2]) <= 1e-12)   # best EDP non-increasing
        assert np.all(np.diff(arr[:, 1]) >= 0)       # evals non-decreasing


def test_amosa_archive_nondominated(small_problem):
    spec, f, ev, ctx = small_problem
    arch = amosa(spec, ev, ctx, spec.mesh_design(), seed=0, t_max=0.5,
                 t_min=0.05, alpha=0.7, iters_per_temp=10, max_evals=200)
    sub = arch.objs[:, list(ctx.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])


def test_nsga2_runs_and_improves(small_problem):
    spec, f, ev, ctx = small_problem
    mesh = spec.mesh_design()
    ps = nsga2(spec, ev, ctx, mesh, seed=0, pop_size=8, generations=5)
    assert len(ps.designs) >= 1
    assert ctx.phv(ps.objs) >= ctx.phv(ev(mesh)[None]) - 1e-9


def test_pcbb_finds_design_better_or_equal_mesh(small_problem):
    spec, f, ev, ctx = small_problem
    res = pcbb(spec, ev, ctx, seed=0, max_expansions=500)
    mesh_scal = float(ctx.normalize(ev(spec.mesh_design())).mean())
    best_scal = float(ctx.normalize(res.best_objs).mean())
    assert best_scal <= mesh_scal + 1e-9
    assert res.nodes_expanded > 0


def test_regression_forest_fits_smooth_function():
    rng = np.random.default_rng(0)
    x = rng.uniform(-1, 1, size=(400, 5))
    y = x[:, 0] * 2 + np.sin(3 * x[:, 1]) + 0.5 * x[:, 2] ** 2
    model = RegressionForest(n_trees=16, max_depth=8, seed=0).fit(x, y)
    pred = model.predict(x)
    sse = float(np.mean((pred - y) ** 2))
    var = float(np.var(y))
    assert sse < 0.2 * var  # explains >80% variance in-sample
    # Generalizes reasonably.
    xt = rng.uniform(-1, 1, size=(200, 5))
    yt = xt[:, 0] * 2 + np.sin(3 * xt[:, 1]) + 0.5 * xt[:, 2] ** 2
    sse_t = float(np.mean((model.predict(xt) - yt) ** 2))
    assert sse_t < 0.5 * float(np.var(yt))


def test_local_search_batch_lockstep(small_problem):
    spec, f, ev, ctx = small_problem
    rng = np.random.default_rng(0)
    mesh = spec.mesh_design()
    starts = [mesh, random_design(spec, rng), random_design(spec, rng)]
    calls_before, evals_before = ev.n_calls, ev.n_evals
    results = local_search_batch(spec, ev, ctx, starts, rng,
                                 n_swaps=6, n_link_moves=6, max_steps=8)
    assert len(results) == 3
    for res, d0 in zip(results, starts):
        assert res.traj[0].key() == d0.key()
        assert res.phv >= 0
        sub = res.local.objs[:, list(ctx.obj_idx)]
        for i in range(sub.shape[0]):
            for j in range(sub.shape[0]):
                if i != j:
                    assert not dominates(sub[i], sub[j])
    # Lockstep batching: far fewer XLA dispatches than evaluations.
    assert ev.n_calls - calls_before <= 1 + 8
    assert ev.n_evals - evals_before > 3 * 8


def test_local_search_batch_respects_budget(small_problem):
    spec, f, ev, ctx = small_problem
    rng = np.random.default_rng(1)
    budget = ev.n_evals + 40
    results = local_search_batch(
        spec, ev, ctx, [spec.mesh_design()] * 2, rng,
        n_swaps=6, n_link_moves=6, max_steps=50, max_evals=budget)
    # May overshoot by at most one lockstep round (2 chains x 12 cands).
    assert ev.n_evals <= budget + 2 * 12
    assert len(results) == 2


def test_stage_batch_multistart_phv_beats_single_start(small_problem):
    """Acceptance: at equal evaluation budget, the 4-chain driver's global
    Pareto set has PHV >= the single-start run's."""
    spec, f, ev, ctx = small_problem
    budget = 2000
    kw = dict(seed=0, iters_max=30, n_swaps=8, n_link_moves=8,
              max_local_steps=1000, max_evals=budget)
    r1 = stage_batch(spec, f, n_starts=1, **kw)
    r4 = stage_batch(spec, f, n_starts=4, **kw)
    assert r1.n_evals <= budget + 64 and r4.n_evals <= budget + 64
    p1 = ctx.phv(r1.global_set.objs)
    p4 = ctx.phv(r4.global_set.objs)
    assert p4 >= p1
    assert r4.n_starts == 4
    # Global set stays mutually non-dominated and structurally valid.
    sub = r4.global_set.objs[:, list(ctx.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])
    for d in r4.global_set.designs:
        assert sorted(d.perm.tolist()) == list(range(spec.n_tiles))
        assert int(np.triu(d.adj).sum()) == spec.n_planar_links


def test_nondominated_rank_duplicate_rows_deterministic():
    """Regression: duplicate objective rows are tie-broken by index, and a
    dominated point never shares a rank with one of its dominators."""
    objs = np.array([
        [0.0, 0.0],
        [0.0, 0.0],   # exact duplicate of row 0
        [1.0, 1.0],   # dominated by both duplicates
        [0.0, 2.0],   # incomparable to row 2
    ])
    rank = _fast_nondominated_rank(objs)
    assert rank[0] < rank[1] < rank[2]
    n = objs.shape[0]
    for i in range(n):
        for j in range(n):
            if dominates(objs[i], objs[j]) or (
                    i < j and np.array_equal(objs[i], objs[j])):
                assert rank[i] < rank[j]


def test_rank_and_crowding_jnp_matches_numpy():
    rng = np.random.default_rng(0)
    for _ in range(10):
        n = int(rng.integers(2, 32))
        m = int(rng.integers(1, 5))
        objs = rng.integers(0, 4, size=(n, m)).astype(np.float64)
        r_np, c_np = rank_and_crowding(objs, "numpy")
        r_j, c_j = rank_and_crowding(objs, "jnp")
        assert np.array_equal(r_np, r_j)
        fin = np.isfinite(c_np)
        assert np.array_equal(fin, np.isfinite(c_j))
        assert np.allclose(c_np[fin], c_j[fin], rtol=1e-5, atol=1e-6)


def test_amosa_speculative_block_still_nondominated(small_problem):
    spec, f, ev, ctx = small_problem
    arch = amosa(spec, ev, ctx, spec.mesh_design(), seed=3, t_max=0.5,
                 t_min=0.05, alpha=0.7, iters_per_temp=10, max_evals=150,
                 block_size=8)
    sub = arch.objs[:, list(ctx.obj_idx)]
    for i in range(sub.shape[0]):
        for j in range(sub.shape[0]):
            if i != j:
                assert not dominates(sub[i], sub[j])


def test_neighbor_moves_preserve_invariants(small_problem):
    spec, f, ev, ctx = small_problem
    from repro.core import sample_neighbors
    rng = np.random.default_rng(0)
    d = random_design(spec, rng)
    for nb in sample_neighbors(spec, d, rng, 10, 10):
        assert sorted(nb.perm.tolist()) == list(range(spec.n_tiles))
        assert int(np.triu(nb.adj).sum()) == spec.n_planar_links
        # planar links only connect same-layer slots
        iu = np.triu_indices(spec.n_tiles, 1)
        on = nb.adj[iu]
        assert np.all(spec.planar_pair_mask[iu][on])
