"""Property + unit tests for dominance and Pareto hypervolume (HSO)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pareto import (PhvContext, dominates, hypervolume,
                               pareto_filter, pareto_mask)


def _point_sets(max_m=4, max_n=8):
    return st.integers(1, max_m).flatmap(
        lambda m: st.lists(
            st.lists(st.floats(0.0, 1.0, allow_nan=False, width=32),
                     min_size=m, max_size=m),
            min_size=1, max_size=max_n,
        )
    )


def test_dominates_basic():
    assert dominates(np.array([1.0, 1.0]), np.array([2.0, 2.0]))
    assert dominates(np.array([1.0, 2.0]), np.array([1.0, 3.0]))
    assert not dominates(np.array([1.0, 3.0]), np.array([2.0, 2.0]))
    assert not dominates(np.array([1.0, 1.0]), np.array([1.0, 1.0]))


@given(_point_sets())
@settings(max_examples=60, deadline=None)
def test_pareto_mask_properties(pts):
    pts = np.array(pts, dtype=np.float64)
    mask = pareto_mask(pts)
    assert mask.any()
    front = pts[mask]
    # No front member dominates another.
    for i in range(front.shape[0]):
        for j in range(front.shape[0]):
            if i != j:
                assert not dominates(front[i], front[j])
    # Every excluded point is dominated by (or duplicates) a front member.
    for i in np.flatnonzero(~mask):
        assert any(
            dominates(g, pts[i]) or np.array_equal(g, pts[i]) for g in front
        )


def test_hypervolume_box():
    # Single point: rectangle volume.
    ref = np.array([1.0, 1.0, 1.0])
    p = np.array([[0.25, 0.5, 0.75]])
    assert hypervolume(p, ref) == pytest.approx(0.75 * 0.5 * 0.25)


def test_hypervolume_two_points_2d():
    ref = np.array([1.0, 1.0])
    pts = np.array([[0.2, 0.6], [0.5, 0.3]])
    # Union of two rectangles: .8*.4 + .5*.7 - .5*.4
    assert hypervolume(pts, ref) == pytest.approx(0.8 * 0.4 + 0.5 * 0.7 - 0.5 * 0.4)


@given(_point_sets())
@settings(max_examples=40, deadline=None)
def test_hv_dominated_point_is_free(pts):
    pts = np.array(pts, dtype=np.float64)
    ref = np.full(pts.shape[1], 1.5)
    base = hypervolume(pts, ref)
    worst = pts.max(axis=0) + 0.1  # dominated by every point
    assert hypervolume(np.vstack([pts, worst]), ref) == pytest.approx(base)


@given(_point_sets())
@settings(max_examples=40, deadline=None)
def test_hv_monotone_under_improvement(pts):
    pts = np.array(pts, dtype=np.float64)
    ref = np.full(pts.shape[1], 1.5)
    base = hypervolume(pts, ref)
    better = pts.min(axis=0) - 0.1  # dominates every point
    hv2 = hypervolume(np.vstack([pts, better]), ref)
    assert hv2 >= base - 1e-12


@given(_point_sets())
@settings(max_examples=30, deadline=None)
def test_hv_clipping_beyond_ref(pts):
    pts = np.array(pts, dtype=np.float64)
    ref = np.full(pts.shape[1], 0.5)
    hv = hypervolume(pts, ref)
    assert 0.0 <= hv <= 0.5 ** pts.shape[1] + 1e-9


def test_phv_context_mesh_normalization():
    mesh = np.array([2.0, 4.0, 8.0, 16.0, 32.0])
    ctx = PhvContext(mesh, (0, 1, 2, 3), ref_scale=1.6)
    # Mesh normalizes to all-ones; hv = 0.6^4.
    assert ctx.phv(mesh[None]) == pytest.approx(0.6 ** 4)
    # A design 20% better in every objective adds volume.
    assert ctx.phv(mesh[None] * 0.8) > ctx.phv(mesh[None])
    # phv_with == phv of the union.
    a, b = mesh * 0.9, mesh * 1.05
    assert ctx.phv_with(a[None], b) == pytest.approx(ctx.phv(np.vstack([a, b])))
