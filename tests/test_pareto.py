"""Property + unit tests for dominance and Pareto hypervolume (HSO).

The property tests need ``hypothesis``; when it is not installed they are
skipped and the unit tests still run.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - property tests skip without it
    st = None

from repro.core.pareto import (PhvContext, dominates, hypervolume,
                               hypervolume_with_batch, pareto_filter,
                               pareto_mask)

if st is not None:
    def _point_sets(max_m=4, max_n=8):
        return st.integers(1, max_m).flatmap(
            lambda m: st.lists(
                st.lists(st.floats(0.0, 1.0, allow_nan=False, width=32),
                         min_size=m, max_size=m),
                min_size=1, max_size=max_n,
            )
        )


def given_point_sets(max_examples):
    """@given(_point_sets()) when hypothesis is available, skip otherwise."""
    def deco(fn):
        if st is None:
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = fn.__name__
            return stub
        return settings(max_examples=max_examples, deadline=None)(
            given(_point_sets())(fn))
    return deco


def test_dominates_basic():
    assert dominates(np.array([1.0, 1.0]), np.array([2.0, 2.0]))
    assert dominates(np.array([1.0, 2.0]), np.array([1.0, 3.0]))
    assert not dominates(np.array([1.0, 3.0]), np.array([2.0, 2.0]))
    assert not dominates(np.array([1.0, 1.0]), np.array([1.0, 1.0]))


@given_point_sets(max_examples=60)
def test_pareto_mask_properties(pts):
    pts = np.array(pts, dtype=np.float64)
    mask = pareto_mask(pts)
    assert mask.any()
    front = pts[mask]
    # No front member dominates another.
    for i in range(front.shape[0]):
        for j in range(front.shape[0]):
            if i != j:
                assert not dominates(front[i], front[j])
    # Every excluded point is dominated by (or duplicates) a front member.
    for i in np.flatnonzero(~mask):
        assert any(
            dominates(g, pts[i]) or np.array_equal(g, pts[i]) for g in front
        )


def test_hypervolume_box():
    # Single point: rectangle volume.
    ref = np.array([1.0, 1.0, 1.0])
    p = np.array([[0.25, 0.5, 0.75]])
    assert hypervolume(p, ref) == pytest.approx(0.75 * 0.5 * 0.25)


def test_hypervolume_two_points_2d():
    ref = np.array([1.0, 1.0])
    pts = np.array([[0.2, 0.6], [0.5, 0.3]])
    # Union of two rectangles: .8*.4 + .5*.7 - .5*.4
    assert hypervolume(pts, ref) == pytest.approx(0.8 * 0.4 + 0.5 * 0.7 - 0.5 * 0.4)


def test_hv2d_staircase_handles_dominated_and_duplicate_points():
    ref = np.array([1.0, 1.0])
    pts = np.array([[0.2, 0.6], [0.5, 0.3], [0.5, 0.3], [0.6, 0.9],
                    [0.2, 0.8]])
    # Dominated/duplicate rows add nothing to the staircase.
    assert hypervolume(pts, ref) == pytest.approx(
        hypervolume(pts[:2], ref))


@given_point_sets(max_examples=40)
def test_hv_dominated_point_is_free(pts):
    pts = np.array(pts, dtype=np.float64)
    ref = np.full(pts.shape[1], 1.5)
    base = hypervolume(pts, ref)
    worst = pts.max(axis=0) + 0.1  # dominated by every point
    assert hypervolume(np.vstack([pts, worst]), ref) == pytest.approx(base)


@given_point_sets(max_examples=40)
def test_hv_monotone_under_improvement(pts):
    pts = np.array(pts, dtype=np.float64)
    ref = np.full(pts.shape[1], 1.5)
    base = hypervolume(pts, ref)
    better = pts.min(axis=0) - 0.1  # dominates every point
    hv2 = hypervolume(np.vstack([pts, better]), ref)
    assert hv2 >= base - 1e-12


@given_point_sets(max_examples=30)
def test_hv_clipping_beyond_ref(pts):
    pts = np.array(pts, dtype=np.float64)
    ref = np.full(pts.shape[1], 0.5)
    hv = hypervolume(pts, ref)
    assert 0.0 <= hv <= 0.5 ** pts.shape[1] + 1e-9


@given_point_sets(max_examples=40)
def test_hv_with_batch_matches_union_hv(pts):
    """The batched incremental scorer equals HV of the explicit union."""
    pts = np.array(pts, dtype=np.float64)
    m = pts.shape[1]
    ref = np.full(m, 1.5)
    rng = np.random.default_rng(pts.shape[0] * 7 + m)
    cands = rng.uniform(0.0, 1.8, size=(6, m))
    cands[0] = pts[0]            # duplicate of a set member
    cands[1] = pts[0] + 0.05     # dominated by a set member
    want = [hypervolume(np.vstack([pts, c[None]]), ref) for c in cands]
    got = hypervolume_with_batch(pts, cands, ref)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)


def test_hv_with_batch_empty_set_and_beyond_ref_candidates():
    ref = np.full(3, 1.0)
    cands = np.array([[0.5, 0.5, 0.5], [2.0, 2.0, 2.0]])
    got = hypervolume_with_batch(np.zeros((0, 3)), cands, ref)
    np.testing.assert_allclose(got, [0.125, 0.0])


def test_phv_context_mesh_normalization():
    mesh = np.array([2.0, 4.0, 8.0, 16.0, 32.0])
    ctx = PhvContext(mesh, (0, 1, 2, 3), ref_scale=1.6)
    # Mesh normalizes to all-ones; hv = 0.6^4.
    assert ctx.phv(mesh[None]) == pytest.approx(0.6 ** 4)
    # A design 20% better in every objective adds volume.
    assert ctx.phv(mesh[None] * 0.8) > ctx.phv(mesh[None])
    # phv_with == phv of the union.
    a, b = mesh * 0.9, mesh * 1.05
    assert ctx.phv_with(a[None], b) == pytest.approx(ctx.phv(np.vstack([a, b])))


def test_phv_with_batch_matches_scalar_loop():
    """ctx.phv_with_batch == [ctx.phv_with(S, d) for d] incl. INF rows."""
    mesh = np.array([2.0, 4.0, 8.0, 16.0, 32.0])
    for case in [(0, 1), (0, 1, 2), (0, 1, 2, 3), (0, 1, 2, 3, 4)]:
        ctx = PhvContext(mesh, case)
        rng = np.random.default_rng(len(case))
        S = mesh[None] * rng.uniform(0.7, 1.3, size=(8, 1))
        cands = mesh[None] * rng.uniform(0.6, 1.8, size=(12, 1))
        cands[3] = 1e9  # invalid (disconnected) design row
        want = np.array([ctx.phv_with(S, c) for c in cands])
        got = ctx.phv_with_batch(S, cands)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)
    # Empty working set.
    ctx = PhvContext(mesh, (0, 1))
    got = ctx.phv_with_batch(np.zeros((0, 5)), mesh[None] * 0.9)
    assert got.shape == (1,)
    assert got[0] == pytest.approx(ctx.phv(mesh[None] * 0.9))


# ------------------------------------------------------------- archive
def test_pareto_mask_signed_zero_dedup():
    """Regression: -0.0 and 0.0 rows are the same point — exactly one
    survives (keep-first), not both."""
    pts = np.array([[0.0, 1.0], [-0.0, 1.0], [1.0, 0.0], [1.0, -0.0]])
    mask = pareto_mask(pts)
    assert mask.tolist() == [True, False, True, False]


def _archive_reference_front(stream):
    """Front of an insertion stream per the historical stacked-mask
    semantics: repeatedly stack survivors + next point, re-mask."""
    from repro.core.pareto import pareto_mask as pm
    front = np.zeros((0, stream.shape[1]))
    tags: list = []
    for i, p in enumerate(stream):
        cand = np.vstack([front, p[None]])
        mask = pm(cand)
        keep_tags = [t for t, m in zip(tags + [i], mask) if m]
        front, tags = cand[mask], keep_tags
    return front, tags


def test_archive_matches_stacked_pareto_mask():
    """ParetoArchive.insert reproduces the stacked pareto_mask semantics
    byte-for-byte: same surviving rows, same order, same tags."""
    from repro.core.pareto import ParetoArchive

    rng = np.random.default_rng(17)
    for k in (2, 3, 4):
        for trial in range(5):
            stream = rng.integers(0, 6, size=(60, k)).astype(np.float64)
            stream[rng.random(60) < 0.1] *= -0.0  # signed-zero rows too
            arch = ParetoArchive(k)
            for i, p in enumerate(stream):
                arch.insert(p, tag=i)
            ref_front, ref_tags = _archive_reference_front(stream)
            assert np.array_equal(arch.points, ref_front), (k, trial)
            assert arch.tags == ref_tags, (k, trial)


def test_archive_insert_reports_evictions():
    from repro.core.pareto import ParetoArchive

    arch = ParetoArchive(2)
    assert arch.insert([1.0, 3.0], tag="a") == (True, [])
    assert arch.insert([3.0, 1.0], tag="b") == (True, [])
    # Dominated / duplicate candidates are rejected.
    assert arch.insert([2.0, 4.0], tag="c") == (False, [])
    assert arch.insert([1.0, 3.0], tag="d") == (False, [])
    assert arch.insert([-0.0 * 1.0 + 1.0, 3.0], tag="d2")[0] is False
    # A dominator evicts both members.
    acc, ev = arch.insert([0.5, 0.5], tag="e")
    assert acc and sorted(ev) == ["a", "b"]
    assert len(arch) == 1 and arch.tags == ["e"]


def test_archive_from_front_roundtrip():
    from repro.core.pareto import ParetoArchive

    rng = np.random.default_rng(23)
    stream = rng.integers(0, 8, size=(40, 3)).astype(np.float64)
    arch = ParetoArchive(3)
    arch.insert_many(stream)
    re = ParetoArchive.from_front(arch.points, tags=list(arch.tags))
    assert np.array_equal(re.points, arch.points)
    assert re.tags == arch.tags
    # Seeded archive keeps behaving like the original.
    p = np.min(stream, axis=0) - 1.0
    acc1, _ = arch.insert(p)
    acc2, _ = re.insert(p)
    assert acc1 and acc2 and np.array_equal(re.points, arch.points)
    # Empty seed is valid.
    empty = ParetoArchive.from_front(np.zeros((0, 3)))
    assert len(empty) == 0 and empty.insert([1.0, 1.0, 1.0])[0]
