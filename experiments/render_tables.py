"""Render EXPERIMENTS.md placeholder tables from artifacts.

Usage: python experiments/render_tables.py   (from the repo root)
Replaces ROOFLINE_TABLE_PLACEHOLDER and PERF_TABLE_PLACEHOLDER in
EXPERIMENTS.md with tables generated from experiments/roofline/*.json and
experiments/perf_log.json."""

import glob
import json
import os

ORDER_A = ["mistral-large-123b", "gemma3-1b", "deepseek-coder-33b", "yi-6b",
           "qwen3-moe-30b-a3b", "moonshot-v1-16b-a3b", "zamba2-2.7b",
           "mamba2-1.3b", "whisper-base", "chameleon-34b"]
ORDER_S = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def roofline_table() -> str:
    rows = [json.load(open(p)) for p in glob.glob("experiments/roofline/*.json")]
    rows.sort(key=lambda c: (ORDER_A.index(c["arch"]), ORDER_S.index(c["shape"])))
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| MODEL_FLOPS | useful | roofline_frac | lever |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for c in rows:
        lever = c["lever"].split(";")[0][:60]
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_s']:.2e} "
            f"| {c['memory_s']:.2e} | {c['collective_s']:.2e} "
            f"| {c['dominant']} | {c['model_flops']:.2e} "
            f"| {c['useful_ratio']:.2f} | {c['roofline_fraction']:.3f} "
            f"| {lever} |")
    return "\n".join(out)


def perf_table() -> str:
    if not os.path.exists("experiments/perf_log.json"):
        return "(perf log missing)"
    logs = json.load(open("experiments/perf_log.json"))
    base = {}
    for l in logs:
        if l["experiment"].endswith("0_baseline"):
            base[(l["arch"], l["shape"])] = l
    out = ["| exp | cell | compute_s | memory_s | collective_s | temp GB "
           "| Δdominant vs baseline | verdict |",
           "|---|---|---|---|---|---|---|---|"]
    for l in sorted(logs, key=lambda x: x["experiment"]):
        b = base.get((l["arch"], l["shape"]))
        dom = b["dominant"] if b else l["dominant"]
        key = f"{dom}_s"
        delta = ""
        verdict = "baseline"
        if b and l is not b and b[key] > 0:
            d = (l[key] / b[key] - 1) * 100
            delta = f"{d:+.1f}% {dom}"
            improved = d < -5
            mem_blowup = l["temp_bytes"] > max(1.5 * b["temp_bytes"], 16e9)
            verdict = ("refuted(mem)" if improved and mem_blowup
                       else "confirmed" if improved
                       else "refuted")
        out.append(
            f"| {l['experiment']} | {l['arch']}×{l['shape']} "
            f"| {l['compute_s']:.2e} | {l['memory_s']:.2e} "
            f"| {l['collective_s']:.2e} | {l['temp_bytes']/1e9:.1f} "
            f"| {delta} | {verdict} |")
    return "\n".join(out)


def main():
    text = open("EXPERIMENTS.md").read()
    text = text.replace("ROOFLINE_TABLE_PLACEHOLDER", roofline_table())
    text = text.replace("PERF_TABLE_PLACEHOLDER", perf_table())
    open("EXPERIMENTS.md", "w").write(text)
    print("tables rendered")


if __name__ == "__main__":
    main()
