"""Batched serving demo: prefill a batch of prompts through a small
yi-6b-family model and greedily decode continuations with the KV-cache
engine (the same decode_step the decode_32k/long_500k dry-run cells lower).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.serve import Engine, ServeConfig


def main():
    cfg = get_config("yi-6b", smoke=True).scaled(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=1024, vocab=4096, compute_dtype=jnp.float32, remat=False)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    engine = Engine(model, mesh, shd.Policy(), params,
                    ServeConfig(max_new_tokens=24, max_len=128))

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(8, 16)).astype(np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts)
    dt = time.perf_counter() - t0
    total_new = out.size
    print(f"batch {prompts.shape[0]}, prompt len {prompts.shape[1]}, "
          f"{out.shape[1]} new tokens each")
    print(f"first continuation: {out[0].tolist()}")
    print(f"throughput: {total_new/dt:.1f} tok/s on {jax.devices()[0].platform}")
    # Determinism check (greedy): same prompts -> same tokens.
    assert np.array_equal(out, engine.generate(prompts))
    print("greedy decode deterministic: OK")


if __name__ == "__main__":
    main()
