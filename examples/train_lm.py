"""End-to-end training driver: a ~100M-parameter gemma3-family model on the
synthetic bigram corpus, with checkpointing/restart and the full sharded
train step (the same code path the multi-pod dry-run lowers).

    PYTHONPATH=src python examples/train_lm.py --steps 300     # full demo
    PYTHONPATH=src python examples/train_lm.py --steps 20 --tiny  # quick

On the CPU container a ~100M model runs ~1 step/s at the default sizes;
--tiny drops to a ~10M model for smoke runs. Kill it at any point and rerun:
it resumes from the latest atomic checkpoint.
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.train import OptConfig, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("gemma3-1b")
    if args.tiny:
        cfg = base.scaled(n_layers=4, d_model=256, n_heads=4, n_kv_heads=1,
                          head_dim=64, d_ff=1024, vocab=2048,
                          sliding_window=128, compute_dtype=jnp.float32)
    else:
        # ~100M params: 8L x 512d, 32k vocab (tied embeddings).
        cfg = base.scaled(n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
                          head_dim=64, d_ff=2048, vocab=32768,
                          sliding_window=256, compute_dtype=jnp.float32)
    from repro.models.common import ModelConfig  # noqa: F401 (docs)
    model = build(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name}-style, {n_params/1e6:.1f}M params")

    mesh = make_host_mesh()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=512,
                                  global_batch=4, seed=0))
    print(f"synthetic-bigram entropy floor: {data.entropy_floor():.3f} nats")

    trainer = Trainer(
        model, mesh, shd.Policy(microbatches=1),
        OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                  weight_decay=0.01),
        data,
        TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50),
    )
    out = trainer.run()
    losses = out["losses"]
    print(f"step {losses[0][0]} loss {losses[0][1]:.3f}  ->  "
          f"step {losses[-1][0]} loss {losses[-1][1]:.3f} "
          f"(floor {data.entropy_floor():.3f})")
    if out["straggler_events"]:
        print("straggler events:", out["straggler_events"])


if __name__ == "__main__":
    main()
