"""The paper's headline experiment (Figs. 9/11): application-agnostic NoCs.

Optimizes an application-specific NoC per application plus leave-one-out
AVG NoCs, cross-evaluates EDP, and prints the degradation table. The study
runs through the unified ``repro.noc`` API (every per-application
optimization is a registry run of "stage"); the equivalent CLI is

    PYTHONPATH=src python -m repro.noc agnostic --spec 16 --apps BFS,BP,...

    PYTHONPATH=src python examples/agnostic_noc.py [--full]

``--llm`` asks the question the paper could not: does the agnostic claim
survive LLM-era traffic? Paper apps and model-derived phase scenarios
(repro.workloads, DESIGN.md §11) are cross-executed against each other,
and one design is scored over a whole serving trace (phase-weighted EDP +
per-link utilization via the link-util kernel path).
"""

import argparse

import numpy as np

from repro.core import APP_NAMES, spec_16, spec_36, spec_tiny
from repro.noc import OptimizeBudget, run_agnostic_study, summarize


def main_llm():
    from repro.workloads import (format_cross_table, phase_weighted_edp,
                                 run_cross_workload_study, trace_for,
                                 trace_link_report)

    spec = spec_tiny()
    scenarios = ("yi-6b:train.fwd", "qwen3-moe-30b-a3b:train.fwd",
                 "qwen3-moe-30b-a3b:serve.decode")
    budget = OptimizeBudget(iters_max=2, n_swaps=6, n_link_moves=6,
                            max_local_steps=10)
    res = run_cross_workload_study(spec, ("BFS", "BP"), scenarios,
                                   "case3", budget)
    print("normalized EDP (row: NoC optimized for; col: workload executed):")
    print(format_cross_table(res))

    # Score the paper-apps-AVG NoC over the whole MoE serving trace and
    # show where its traffic concentrates (phases + link-util kernel path).
    d = res["designs"]["AVG:paper"]
    trace = trace_for("qwen3-moe-30b-a3b", "serving")
    pw = phase_weighted_edp(spec, d, trace)
    rep = trace_link_report(spec, d, trace)
    print()
    print("AVG:paper NoC on the qwen3-moe serving trace:")
    for name, e in pw["per_phase"].items():
        print(f"  {name:>15s}  edp={e:.4g}  (weight {pw['weights'][name]:g})")
    print(f"  phase-weighted edp={pw['edp']:.4g}")
    (a, b), peak = rep["max_link"]
    print(f"  hottest link: slots {a}<->{b} util={peak:.4f} "
          f"(mean {rep['mean']:.4f}, std {rep['std']:.4f})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 10 apps on the 36-tile system (slow)")
    ap.add_argument("--llm", action="store_true",
                    help="cross-execute paper apps vs model-derived LLM "
                         "traffic (smoke-scale, tiny spec)")
    args = ap.parse_args()

    if args.llm:
        return main_llm()

    spec = spec_36() if args.full else spec_16()
    apps = APP_NAMES if args.full else APP_NAMES[:5]
    budget = OptimizeBudget(iters_max=3, n_swaps=12, n_link_moves=12,
                            max_local_steps=25)
    res = run_agnostic_study(spec, apps, "case3", budget)

    print("normalized EDP (row: NoC optimized for; col: app executed):")
    hdr = "          " + " ".join(f"{a:>6s}" for a in apps)
    print(hdr)
    for i, a in enumerate(apps):
        print(f"{a:>8s}  " + " ".join(f"{v:6.3f}" for v in res["table"][i]))
    print(f"{'AVG':>8s}  " + " ".join(f"{v:6.3f}" for v in res["avg_row"]))

    s = summarize(res)
    print()
    print(f"single-app NoC degradation: avg "
          f"{s['app_specific_avg_degradation']*100:.1f}%, worst "
          f"{s['app_specific_worst_degradation']*100:.1f}%")
    print(f"AVG (leave-one-out) NoC degradation: avg "
          f"{s['avg_noc_degradation']*100:.1f}%, worst "
          f"{s['avg_noc_worst']*100:.1f}%")
    print("(paper, full budget: 64-tile 3.2%/1.1%; 36-tile 3.8%/1.8%)")


if __name__ == "__main__":
    main()
