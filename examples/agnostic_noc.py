"""The paper's headline experiment (Figs. 9/11): application-agnostic NoCs.

Optimizes an application-specific NoC per application plus leave-one-out
AVG NoCs, cross-evaluates EDP, and prints the degradation table. The study
runs through the unified ``repro.noc`` API (every per-application
optimization is a registry run of "stage"); the equivalent CLI is

    PYTHONPATH=src python -m repro.noc agnostic --spec 16 --apps BFS,BP,...

    PYTHONPATH=src python examples/agnostic_noc.py [--full]
"""

import argparse

import numpy as np

from repro.core import APP_NAMES, spec_16, spec_36
from repro.noc import OptimizeBudget, run_agnostic_study, summarize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all 10 apps on the 36-tile system (slow)")
    args = ap.parse_args()

    spec = spec_36() if args.full else spec_16()
    apps = APP_NAMES if args.full else APP_NAMES[:5]
    budget = OptimizeBudget(iters_max=3, n_swaps=12, n_link_moves=12,
                            max_local_steps=25)
    res = run_agnostic_study(spec, apps, "case3", budget)

    print("normalized EDP (row: NoC optimized for; col: app executed):")
    hdr = "          " + " ".join(f"{a:>6s}" for a in apps)
    print(hdr)
    for i, a in enumerate(apps):
        print(f"{a:>8s}  " + " ".join(f"{v:6.3f}" for v in res["table"][i]))
    print(f"{'AVG':>8s}  " + " ".join(f"{v:6.3f}" for v in res["avg_row"]))

    s = summarize(res)
    print()
    print(f"single-app NoC degradation: avg "
          f"{s['app_specific_avg_degradation']*100:.1f}%, worst "
          f"{s['app_specific_worst_degradation']*100:.1f}%")
    print(f"AVG (leave-one-out) NoC degradation: avg "
          f"{s['avg_noc_degradation']*100:.1f}%, worst "
          f"{s['avg_noc_worst']*100:.1f}%")
    print("(paper, full budget: 64-tile 3.2%/1.1%; 36-tile 3.8%/1.8%)")


if __name__ == "__main__":
    main()
