import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Pod-level bridge demo: MOO-STAGE places logical devices on the ICI torus.

1. Lower + compile gemma3-1b train_4k on the 16x16 production mesh.
2. Read the collective schedule off the compiled HLO -> logical-device
   traffic matrix (the pod's 'gem5 trace').
3. Optimize the placement permutation with MOO-STAGE (same objectives as
   the paper's Eqs. 2-4 over ICI links) from a RANDOM start, and compare
   identity / random / optimized layouts.
4. Architecture-agnostic check (paper §6.4 analogue): the layout optimized
   on one arch's traffic is evaluated on another arch's traffic.

    PYTHONPATH=src python examples/pod_layout.py
"""

import numpy as np

from repro.dist import sharding as shd
from repro.dist.mesh_layout import (LayoutEvaluator, Torus,
                                    collective_traffic, optimize_layout)
from repro.launch.dryrun import build_lowered
from repro.launch.mesh import make_production_mesh


def traffic_for(arch: str, shape: str = "train_4k") -> np.ndarray:
    mesh = make_production_mesh(multi_pod=False)
    policy = shd.default_policy_for("train")
    lowered, _ = build_lowered(arch, shape, mesh, policy)
    text = lowered.compile().as_text()
    f = collective_traffic(text, 256)
    print(f"  {arch}: {np.count_nonzero(f)} communicating pairs, "
          f"{f.sum()/1e9:.2f} GB ring traffic")
    return f


def main():
    t = Torus(16, 16)
    print("extracting collective traffic from compiled HLO...")
    f_gemma = traffic_for("gemma3-1b")
    ev = LayoutEvaluator(t, f_gemma)

    ident = np.arange(256)
    o_ident = ev(ident)
    rng = np.random.default_rng(0)
    rand = rng.permutation(256)
    o_rand = ev(rand)
    print(f"identity layout: max-link {o_ident[2]/1e6:.1f} MB, "
          f"avg hops {o_ident[3]:.2f}")
    print(f"random layout:   max-link {o_rand[2]/1e6:.1f} MB, "
          f"avg hops {o_rand[3]:.2f}")

    print("MOO-STAGE layout search (from random start)...")
    res = optimize_layout(ev, seed=0, iters_max=4, n_neighbors=32,
                          max_steps=40)
    o_opt = res.best_objs
    print(f"optimized layout: max-link {o_opt[2]/1e6:.1f} MB, "
          f"avg hops {o_opt[3]:.2f} "
          f"({(1-o_opt[2]/o_rand[2])*100:.0f}% below random start)")

    # Architecture-agnostic: evaluate gemma-optimized layout on yi traffic.
    f_yi = traffic_for("yi-6b")
    ev_yi = LayoutEvaluator(t, f_yi)
    cross = ev_yi(res.best_perm)
    own = optimize_layout(ev_yi, seed=0, iters_max=3, n_neighbors=32,
                          max_steps=30).best_objs
    deg = (cross[2] / own[2] - 1) * 100
    print(f"arch-agnostic check: gemma-optimized layout on yi-6b traffic: "
          f"max-link within {deg:.1f}% of yi-specific layout")
    print("(the paper's application-agnostic claim, at pod scale: collective"
          " traffic is architecture-dominated, so layouts transfer)")


if __name__ == "__main__":
    main()
