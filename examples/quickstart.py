"""Quickstart: design a 3D heterogeneous NoC with MOO-STAGE (the paper's
core loop, container-sized).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (CASES, Evaluator, PhvContext, spec_16, spec_36,
                        traffic_matrix)
from repro.core import netsim
from repro.core.objectives import OBJ_NAMES
from repro.core.stage import moo_stage


def main():
    spec = spec_36()  # 36 tiles: 4 CPUs, 8 LLCs, 24 GPUs, four 3x3 layers
    f = traffic_matrix(spec, "BFS")
    ev = Evaluator(spec, f)
    mesh = spec.mesh_design()
    mesh_objs = ev(mesh)
    ctx = PhvContext(mesh_objs, CASES["case3"])  # {U, sigma, Lat, E}

    print("3D-mesh baseline:",
          {n: round(float(v), 4) for n, v in zip(OBJ_NAMES, mesh_objs)})

    res = moo_stage(spec, ev, ctx, mesh, seed=0, iters_max=4,
                    n_swaps=16, n_link_moves=16, max_local_steps=40)
    objs = res.global_set.objs
    edps = objs[:, 2] * objs[:, 3]
    best = int(np.argmin(edps))
    d = res.global_set.designs[best]

    print(f"MOO-STAGE explored {ev.n_evals} designs, Pareto set size "
          f"{len(res.global_set.designs)}")
    print("best-EDP design:",
          {n: round(float(v), 4) for n, v in zip(OBJ_NAMES, objs[best])})
    print(f"EDP: mesh {ev.edp(mesh):.2f} -> optimized {ev.edp(d):.2f} "
          f"({(1 - ev.edp(d)/ev.edp(mesh))*100:.1f}% better)")

    # Paper Fig. 7-style structure: links/layer + LLC placement depth.
    layer = spec.layer_of_slot
    iu = np.triu_indices(spec.n_tiles, 1)
    links_per_layer = np.bincount(layer[iu[0]][d.adj[iu]],
                                  minlength=spec.n_layers)
    llc_layers = layer[np.isin(d.perm, np.arange(spec.n_cpu,
                                                 spec.n_cpu + spec.n_llc))]
    print("links per layer (sink first):", links_per_layer.tolist())
    print("LLC tiles per layer:",
          np.bincount(llc_layers, minlength=spec.n_layers).tolist())

    st_mesh = netsim.saturation_throughput(spec, mesh, f, cycles=1500)
    st_best = netsim.saturation_throughput(spec, d, f, cycles=1500)
    print(f"flit-sim saturation throughput: mesh {st_mesh:.2f} -> "
          f"optimized {st_best:.2f} flits/cycle")


if __name__ == "__main__":
    main()
