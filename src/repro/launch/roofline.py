import os
if __name__ == "__main__":  # entrypoint only — never poison library importers
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis from the compiled dry-run artifacts (TPU v5e target).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs        / (chips x 197e12 FLOP/s bf16)
    memory     = HLO_bytes        / (chips x 819e9  B/s HBM)
    collective = collective_bytes / (chips x 50e9   B/s ICI link)

cost_analysis() undercounts while-loop bodies (a lax.scan body is costed
once regardless of trip count), so the driver derives per-layer costs
COMPOSITIONALLY: the step is re-lowered with cfg.unroll_layers=True at two
small depths L1 < L2; the per-layer delta extrapolates to the real depth:

    term(L) = term(L2) + (L - L2) * (term(L2) - term(L1)) / (L2 - L1)

Every cell also records MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE)
and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat and
redundancy waste), the dominant term, and a one-line lever on the dominant
term. Output: experiments/roofline/<cell>.json + a markdown table."""

import argparse
import dataclasses
import json

import numpy as np

from ..configs import ARCH_NAMES, SHAPES, applicable, get_config
from ..dist import sharding as shd
from . import hlo
from .dryrun import build_lowered, run_cell
from .mesh import make_production_mesh

from .constants import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "roofline")


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    wire_per_dev: float
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    lever: str = ""

    def finalize(self):
        self.compute_s = self.flops_per_dev / PEAK_FLOPS
        self.memory_s = self.bytes_per_dev / HBM_BW
        self.collective_s = self.wire_per_dev / LINK_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        total_hlo_flops = self.flops_per_dev * self.chips
        self.useful_ratio = (self.model_flops / total_hlo_flops
                             if total_hlo_flops > 0 else 0.0)
        # Fraction of the compute roofline the step achieves if it runs at
        # the max of the three terms (the bound the hillclimb pushes).
        bound = max(terms.values())
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        self.roofline_fraction = ideal / bound if bound > 0 else 0.0
        self.lever = {
            "compute": "reduce recompute (remat policy) / fuse; compute term "
                       "is irreducible once useful_ratio ~ 1",
            "memory": "increase arithmetic intensity: larger per-device "
                      "tiles, fused attention kernel, bf16 cache",
            "collective": "reshard to cut all-gather volume / int8 gradient "
                          "compression / overlap with microbatch compute",
        }[self.dominant]
        return self


def _measure(arch: str, shape_name: str, multi_pod: bool,
             policy: shd.Policy | None, l1: int, l2: int,
             cfg_overrides: dict | None = None) -> dict:
    """Per-layer compositional costs via unrolled small-depth lowers.

    Costs are measured at microbatches=1: the microbatch lax.scan is a while
    loop whose body cost_analysis counts once, so measuring inside it would
    hide (k-1)/k of the work. Total FLOPs/bytes are microbatch-invariant;
    the deployed policy still uses accumulation for memory fit (the small
    per-microbatch reduce overhead is noted in EXPERIMENTS.md §Roofline)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    policy = policy or shd.default_policy_for(SHAPES[shape_name].kind)
    policy = _dc.replace(policy, microbatches=1)
    mesh = make_production_mesh(multi_pod=multi_pod)

    def cost_at(n_layers: int) -> tuple[float, float, float]:
        over = dict(cfg_overrides or {})
        over.update({"n_layers": n_layers, "unroll_layers": True})
        if cfg.family == "encdec":
            over["encoder_layers"] = n_layers
        lowered, _ = build_lowered(arch, shape_name, mesh, policy, over)
        compiled = lowered.compile()
        c = compiled.cost_analysis() or {}
        if isinstance(c, (list, tuple)):  # older jax: list of one dict
            c = c[0] if c else {}
        coll = hlo.parse_collectives(compiled.as_text())
        return (float(c.get("flops", 0)), float(c.get("bytes accessed", 0)),
                hlo.wire_bytes(coll))

    f1, b1, w1 = cost_at(l1)
    f2, b2, w2 = cost_at(l2)
    dl = l2 - l1
    real_l = cfg.n_layers
    return {
        "flops": f2 + (real_l - l2) * (f2 - f1) / dl,
        "bytes": b2 + (real_l - l2) * (b2 - b1) / dl,
        "wire": w2 + (real_l - l2) * (w2 - w1) / dl,
    }


def analyze_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                 policy: shd.Policy | None = None,
                 cfg_overrides: dict | None = None,
                 l1: int = 1, l2: int = 3, save: bool = True) -> CellRoofline | None:
    if not applicable(arch, shape_name):
        return None
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    if cfg.family == "hybrid":
        # depth deltas in whole sites (attn_every mamba layers + shared attn)
        l1, l2 = cfg.attn_every, 2 * cfg.attn_every
    est = _measure(arch, shape_name, multi_pod, policy, l1, l2, cfg_overrides)

    n_params = cfg.active_param_count() if cfg.family == "moe" \
        else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_params * tokens
    else:  # decode: one token per sequence
        model_flops = 2.0 * n_params * shape.global_batch

    cell = CellRoofline(
        arch=arch, shape=shape_name,
        mesh="pod2x16x16" if multi_pod else "pod16x16",
        chips=chips,
        flops_per_dev=est["flops"],
        bytes_per_dev=est["bytes"],
        wire_per_dev=est["wire"],
        model_flops=model_flops,
    ).finalize()
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        name = f"{arch}__{shape_name}__{cell.mesh}.json"
        with open(os.path.join(OUT_DIR, name), "w") as fh:
            json.dump(dataclasses.asdict(cell), fh, indent=1)
    return cell


def table(cells: list[CellRoofline]) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful | roofline_frac |\n|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for c in cells:
        rows.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | {c.memory_s:.3e} "
            f"| {c.collective_s:.3e} | {c.dominant} | {c.useful_ratio:.2f} "
            f"| {c.roofline_fraction:.2f} |"
        )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    cells = []
    targets = ([(args.arch, args.shape)] if not args.all
               else [(a, s) for a in ARCH_NAMES for s in SHAPES])
    for arch, shape in targets:
        c = analyze_cell(arch, shape)
        if c is None:
            print(f"{arch:22s} {shape:12s} skipped (inapplicable)")
            continue
        cells.append(c)
        print(f"{arch:22s} {shape:12s} dom={c.dominant:10s} "
              f"comp {c.compute_s:.2e}s mem {c.memory_s:.2e}s "
              f"coll {c.collective_s:.2e}s useful {c.useful_ratio:.2f} "
              f"roofline {c.roofline_fraction:.2f}", flush=True)
    print()
    print(table(cells))


if __name__ == "__main__":
    main()
