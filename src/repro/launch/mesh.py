"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. Shapes target TPU v5e: a 16x16 pod (256 chips,
axes data x model) and a 2-pod system (512 chips, pod x data x model)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (tests/examples on CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
