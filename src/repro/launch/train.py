"""Production training launcher.

    python -m repro.launch.train --arch yi-6b --smoke --steps 100

Wires: config registry -> model -> sharding policy (per-shape defaults or
§Perf-optimized: SP + microbatching) -> fault-tolerant Trainer (atomic
checkpoints, restart-from-latest, straggler watchdog). On a real fleet this
process runs per host under `jax.distributed.initialize()` (flag below);
on the CPU container use --smoke for the reduced config.

XLA flags for collective/compute overlap on TPU are set here (latency-
hiding scheduler) — they are harmless no-ops on CPU."""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU containers)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence parallelism (EXPERIMENTS.md §Perf C3)")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    args = ap.parse_args()

    # Compute/communication overlap on TPU (no-op elsewhere).
    os.environ.setdefault(
        "LIBTPU_INIT_ARGS",
        "--xla_tpu_enable_async_collective_fusion=true "
        "--xla_tpu_enable_latency_hiding_scheduler=true",
    )

    import jax
    if args.distributed:
        jax.distributed.initialize()

    from ..configs import get_config
    from ..data import DataConfig, SyntheticLM
    from ..dist import sharding as shd
    from ..models import build
    from ..train import OptConfig, TrainConfig, Trainer
    from .mesh import make_host_mesh

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        import jax.numpy as jnp
        cfg = cfg.scaled(compute_dtype=jnp.float32)
    model = build(cfg)
    mesh = make_host_mesh()
    policy = shd.Policy(
        microbatches=args.microbatches,
        grad_compress=args.grad_compress,
    )
    if args.seq_shard:
        policy = policy.with_logical(seq=("model",))

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                  global_batch=args.global_batch))
    trainer = Trainer(
        model, mesh, policy,
        OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                  total_steps=args.steps),
        data,
        TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=max(args.steps // 4, 10)),
    )
    out = trainer.run()
    print(f"[train] {args.arch}: step {out['final_step']} "
          f"loss {out['final_loss']:.4f} "
          f"(data floor {data.entropy_floor():.4f}); "
          f"stragglers: {len(out['straggler_events'])}")


if __name__ == "__main__":
    main()
