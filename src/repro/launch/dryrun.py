import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (The two lines above MUST precede any other import: jax freezes the host
# platform device count at first initialization. Everything below is free.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input-shape) cell, lower + compile the step
function (train_step for train shapes, prefill/serve_step for inference
shapes) against ShapeDtypeStruct inputs on BOTH production meshes:

    single-pod  (16, 16)      axes (data, model)          256 chips
    multi-pod   (2, 16, 16)   axes (pod, data, model)     512 chips

and record memory_analysis() (fits/doesn't), cost_analysis() (FLOPs/bytes),
and the parsed collective schedule to experiments/dryrun/<cell>.json.
Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the framework. Usage:

    python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from ..configs import (ARCH_NAMES, SHAPES, applicable, cell_status,
                       get_config, input_specs)
from ..dist import sharding as shd
from ..models.model import build
from ..train.optimizer import OptConfig
from ..train.train_step import make_decode_fn, make_prefill_fn, make_train_fns
from . import hlo
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def build_lowered(arch: str, shape_name: str, mesh, policy: shd.Policy,
                  cfg_overrides: dict | None = None):
    """Lower the cell's step function against ShapeDtypeStructs (no alloc)."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    shape = SHAPES[shape_name]
    model = build(cfg)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        init_state, jitted_step, _ = make_train_fns(
            model, mesh, policy, OptConfig())
        state_sds = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(0)))
        step = jitted_step(state_sds, specs)
        return step.lower(state_sds, specs), cfg

    params_sds = model.abstract_params()
    if shape.kind == "prefill":
        fn = make_prefill_fn(model, mesh, policy)(params_sds, specs)
        return fn.lower(params_sds, specs), cfg

    # decode
    fn = make_decode_fn(model, mesh, policy)(
        params_sds, specs["cache"], specs["token"])
    return fn.lower(params_sds, specs["cache"], specs["token"]), cfg


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             policy: shd.Policy | None = None,
             cfg_overrides: dict | None = None,
             save: bool = True) -> dict:
    policy = policy or shd.default_policy_for(SHAPES[shape_name].kind)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": cell_status(arch, shape_name)}
    if not applicable(arch, shape_name):
        if save:
            _save(rec)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.perf_counter()
        lowered, cfg = build_lowered(arch, shape_name, mesh, policy,
                                     cfg_overrides)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: list of one dict
            cost = cost[0] if cost else {}
        text = compiled.as_text()
        coll = hlo.parse_collectives(text)
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            flops=float(cost.get("flops", -1)),
            bytes_accessed=float(cost.get("bytes accessed", -1)),
            memory=_memory_dict(compiled),
            collectives=coll,
            wire_bytes=hlo.wire_bytes(coll),
            n_devices=int(np.prod(list(mesh.shape.values()))),
            hlo_chars=len(text),
        )
    except Exception as e:
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    if save:
        _save(rec)
    return rec


def _save(rec: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(OUT_DIR, name), "w") as fh:
        json.dump(rec, fh, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()

    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    cells: list[tuple[str, str]]
    if args.all:
        cells = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape, or --all"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            rec = run_cell(arch, shape_name, multi_pod=mp)
            tag = "MULTI " if mp else "single"
            if rec["status"] == "ok":
                mem = rec["memory"].get("temp_size_in_bytes", 0) + \
                    rec["memory"].get("argument_size_in_bytes", 0)
                print(f"[{tag}] {arch:22s} {shape_name:12s} OK   "
                      f"lower {rec['lower_s']:6.1f}s compile {rec['compile_s']:6.1f}s  "
                      f"flops/dev {rec['flops']:.3e}  "
                      f"bytes/dev {mem/1e9:7.2f} GB  "
                      f"wire {rec['wire_bytes']/1e9:8.3f} GB", flush=True)
            elif rec["status"].startswith("skip"):
                print(f"[{tag}] {arch:22s} {shape_name:12s} SKIP ({rec['status']})",
                      flush=True)
            else:
                n_fail += 1
                print(f"[{tag}] {arch:22s} {shape_name:12s} FAIL {rec['error']}",
                      flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")
    print("dry-run complete: all cells lowered + compiled")


if __name__ == "__main__":
    main()
