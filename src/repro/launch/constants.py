"""TPU v5e roofline constants (import-safe: no env mutation, no jax)."""

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # B/s per chip
LINK_BW = 50e9           # B/s per ICI link (per chip, one link)
