import os
if __name__ == "__main__":  # entrypoint only — never poison library importers
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Performance hillclimbing driver (EXPERIMENTS.md §Perf).

Each experiment is one hypothesis -> change -> re-lower -> re-analyse cycle
on one of the three chosen cells. Experiments are named; every run appends
{cell, experiment, hypothesis, policy/config delta, roofline terms before/
after, temp memory} to experiments/perf_log.json. The §Perf narrative in
EXPERIMENTS.md is generated from this log.

    python -m repro.launch.perf --list
    python -m repro.launch.perf --run <name> [...]
    python -m repro.launch.perf --all
"""

import argparse
import dataclasses
import json

import numpy as np

from ..dist import sharding as shd
from .dryrun import run_cell
from .roofline import analyze_cell

LOG = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "perf_log.json")


def _terms(arch, shape, policy=None, cfg_overrides=None) -> dict:
    c = analyze_cell(arch, shape, policy=policy, cfg_overrides=cfg_overrides,
                     save=False)
    rec = run_cell(arch, shape, multi_pod=False, policy=policy,
                   cfg_overrides=cfg_overrides, save=False)
    temp = rec.get("memory", {}).get("temp_size_in_bytes", -1)
    return {
        "compute_s": c.compute_s, "memory_s": c.memory_s,
        "collective_s": c.collective_s, "dominant": c.dominant,
        "useful_ratio": c.useful_ratio,
        "roofline_fraction": c.roofline_fraction,
        "temp_bytes": temp, "status": rec["status"],
    }


@dataclasses.dataclass
class Experiment:
    name: str
    arch: str
    shape: str
    hypothesis: str
    policy: shd.Policy | None = None          # None -> cell default
    cfg_overrides: dict | None = None
    note: str = ""


def experiments() -> dict[str, Experiment]:
    exps = [
        # ------------ cell A: mistral-large-123b x train_4k (worst frac) --
        Experiment(
            "A0_baseline", "mistral-large-123b", "train_4k",
            "baseline (paper-faithful defaults: FSDP+TP, remat, 16 ubatch)",
        ),
        Experiment(
            "A1_no_remat", "mistral-large-123b", "train_4k",
            "remat recomputes the whole fwd (~+33% matmul flops and "
            "re-reads activations); 16 microbatches already cap live "
            "activations at ~1/16, so remat off should cut the compute "
            "term ~25% and HLO bytes, at acceptable temp growth",
            cfg_overrides={"remat": False},
        ),
        Experiment(
            "A2_int8_grads", "mistral-large-123b", "train_4k",
            "gradient all-reduce dominates the collective term at 123B "
            "params f32; int8 compression cuts grad wire bytes 4x so the "
            "collective term should drop toward the TP all-gather floor",
            policy=dataclasses.replace(
                shd.Policy(microbatches=16, grad_compress=True)),
            note="wire-byte credit modeled at 4x on the data-axis grad "
                 "reduction (int8 payload); error feedback keeps convergence "
                 "(tested in test_substrate)",
        ),
        Experiment(
            "A3_seq_shard", "mistral-large-123b", "train_4k",
            "residual-stream activations are replicated across 'model'; "
            "sequence-sharding them (Megatron-SP) cuts activation HBM "
            "traffic and the all-gathers around attention/mlp boundaries",
            policy=shd.Policy(microbatches=16).with_logical(
                seq=("model",)),
        ),
        Experiment(
            "A4_sp_ubatch32", "mistral-large-123b", "train_4k",
            "A3 showed SP halves the compute+memory terms but temp stays "
            "21GB; doubling microbatches to 32 halves live activations "
            "again -> expect <16GB fit with A3's roofline terms intact",
            policy=shd.Policy(microbatches=32).with_logical(
                seq=("model",)),
        ),
        # ------------ cell B: qwen3-moe x decode_32k (most collective) ----
        Experiment(
            "B0_baseline", "qwen3-moe-30b-a3b", "decode_32k",
            "baseline (EP over 'model', batch over 'data')",
        ),
        Experiment(
            "B1_no_ep_decode", "qwen3-moe-30b-a3b", "decode_32k",
            "at decode batch 128, the EP dispatch/combine all-to-alls and "
            "expert all-gathers dominate; dropping EP (experts replicated, "
            "28GB bf16... won't fit at f32 -> expect FAIL or memory blowup; "
            "refutation experiment)",
            policy=shd.Policy().with_logical(experts=()),
        ),
        Experiment(
            "B2_moe_groups_batch", "qwen3-moe-30b-a3b", "decode_32k",
            "shard the MoE *group* axis over 'data' only and keep expert "
            "weights EP; routing one token-group per data shard minimizes "
            "dispatch tensor resharding",
            policy=shd.Policy().with_logical(seq=()),
            cfg_overrides=None,
            note="group sharding is already batch-major; this isolates the "
                 "seq-axis constraint effect",
        ),
        Experiment(
            "B3_bf16_dispatch", "qwen3-moe-30b-a3b", "decode_32k",
            "dispatch/combine one-hots are f32 in the einsum path at "
            "decode; forcing bf16 compute halves the all-to-all payload",
            cfg_overrides={"compute_dtype": "bfloat16"},
            note="compute_dtype is already bf16 by default; this experiment "
                 "documents the no-op (confirmed control)",
        ),
        Experiment(
            "B4_ep_only_no_tp", "qwen3-moe-30b-a3b", "decode_32k",
            "B0's collective term (~1.4s) is weight-sized, not token-sized: "
            "GSPMD gathers TP-sharded attention/expert weights at decode "
            "batch 128. Turning TP OFF for attention+vocab (weights "
            "replicated, ~2GB) while keeping EP should collapse the "
            "collective term to the token all-to-all",
            policy=shd.Policy().with_logical(
                heads=(), kv_heads=(), heads_flat=(), vocab=(), mlp=()),
        ),
        # ------------ cell C: yi-6b x train_4k (paper-representative) -----
        Experiment(
            "C0_baseline", "yi-6b", "train_4k",
            "baseline — the cell used for the paper-faithful autoshard/"
            "layout demonstrations",
        ),
        Experiment(
            "C1_no_remat", "yi-6b", "train_4k",
            "same hypothesis as A1 at 6B scale: compute term -25%, memory "
            "bytes down (no re-read of layer inputs)",
            cfg_overrides={"remat": False},
        ),
        Experiment(
            "C2_no_fsdp", "yi-6b", "train_4k",
            "at 6B params / 256 chips, FSDP's per-layer weight all-gathers "
            "may cost more wire than replicating params (6B*4B = 24GB "
            "replicated per DATA shard is 1.5GB/chip after TP) — dropping "
            "FSDP trades memory for collective volume",
            policy=dataclasses.replace(shd.Policy(microbatches=16),
                                       fsdp_axes=()),
        ),
        Experiment(
            "C3_sp", "yi-6b", "train_4k",
            "sequence-shard the residual stream over 'model' (SP): "
            "activation traffic /16 between blocks",
            policy=shd.Policy(microbatches=16).with_logical(seq=("model",)),
        ),
    ]
    return {e.name: e for e in exps}


def run_experiment(e: Experiment) -> dict:
    over = dict(e.cfg_overrides or {})
    if over.get("compute_dtype") == "bfloat16":
        import jax.numpy as jnp
        over["compute_dtype"] = jnp.bfloat16
    res = _terms(e.arch, e.shape, e.policy, over or None)
    rec = {
        "experiment": e.name, "arch": e.arch, "shape": e.shape,
        "hypothesis": e.hypothesis, "note": e.note, **res,
    }
    logs = []
    if os.path.exists(LOG):
        logs = json.load(open(LOG))
    logs = [l for l in logs if l["experiment"] != e.name] + [rec]
    os.makedirs(os.path.dirname(LOG), exist_ok=True)
    json.dump(logs, open(LOG, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--run", nargs="*", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    exps = experiments()
    if args.list:
        for name, e in exps.items():
            print(f"{name:22s} {e.arch} x {e.shape}: {e.hypothesis[:60]}")
        return
    names = list(exps) if args.all else (args.run or [])
    for name in names:
        e = exps[name]
        print(f"== {name}: {e.arch} x {e.shape}", flush=True)
        rec = run_experiment(e)
        print(f"   comp {rec['compute_s']:.3e}s mem {rec['memory_s']:.3e}s "
              f"coll {rec['collective_s']:.3e}s dom={rec['dominant']} "
              f"temp {rec['temp_bytes']/1e9:.2f}GB status={rec['status']}",
              flush=True)


if __name__ == "__main__":
    main()
