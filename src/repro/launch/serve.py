"""Production serving launcher: batched greedy generation with the KV-cache
engine.

    python -m repro.launch.serve --arch yi-6b --smoke --batch 4 --new 16

Decode-shape policies follow the §Perf B4 finding: at decode, attention
weights are replicated (TP off) while MoE experts stay expert-parallel —
pass --tp to force TP back on."""

from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--tp", action="store_true",
                    help="keep tensor parallelism at decode (default: EP-only"
                         " per EXPERIMENTS.md §Perf B4)")
    args = ap.parse_args()

    os.environ.setdefault(
        "LIBTPU_INIT_ARGS", "--xla_tpu_enable_latency_hiding_scheduler=true")

    import jax
    import jax.numpy as jnp
    import numpy as np

    from ..configs import get_config
    from ..dist import sharding as shd
    from ..models import build
    from ..serve import Engine, ServeConfig
    from .mesh import make_host_mesh

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.smoke:
        cfg = cfg.scaled(compute_dtype=jnp.float32, remat=False)
    if cfg.family == "encdec":
        raise SystemExit("use a decoder-only arch for this launcher "
                         "(whisper serving needs audio frames)")
    model = build(cfg)
    mesh = make_host_mesh()
    policy = shd.Policy() if args.tp else shd.Policy().with_logical(
        heads=(), kv_heads=(), heads_flat=(), vocab=(), mlp=())

    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, mesh, policy, params,
                    ServeConfig(max_new_tokens=args.new,
                                max_len=args.prompt_len + args.new + 8))
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    import time
    t0 = time.perf_counter()
    out = engine.generate(prompts)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.arch}: batch {args.batch}, {args.new} new tokens "
          f"each, {out.size/dt:.1f} tok/s")
    print(f"[serve] sample: {out[0][:12].tolist()}")


if __name__ == "__main__":
    main()
