"""HLO text analysis: collective extraction for the roofline terms.

compiled.cost_analysis() has no collective-byte accounting, so we parse the
post-SPMD HLO: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute is counted with its RESULT shape (per-device), its
participant-group size, and a ring-algorithm wire-byte estimate:

    all-reduce        2 x R          (reduce-scatter + all-gather phases)
    all-gather        R              (result is the gathered, full tensor)
    reduce-scatter    R x n          (operand is the full tensor)
    all-to-all        R
    collective-permute R

The (n-1)/n ring factor is folded to 1 (n >= 16 everywhere we care).
Collectives inside while bodies appear once in the text — the roofline
driver accounts for per-layer trip counts compositionally (roofline.py)."""

from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?P<result>.+?)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[\d+,(\d+)\]")


def _shape_bytes(result: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(result):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """{kind: {count, result_bytes, wire_bytes}} over the module text."""
    out = {k: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0}
           for k in _KINDS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or ".done" in line:
            continue
        kind = m.group("kind")
        if f"{kind}-done" in line:
            continue
        rbytes = _shape_bytes(m.group("result"))
        gm = _GROUPS_RE.search(line)
        im = _IOTA_RE.search(line)
        n = (len(gm.group(1).split(",")) if gm
             else int(im.group(1)) if im else 1)
        if kind == "all-reduce":
            wire = 2.0 * rbytes
        elif kind == "reduce-scatter":
            wire = float(rbytes * n)
        else:
            wire = float(rbytes)
        out[kind]["count"] += 1
        out[kind]["result_bytes"] += rbytes
        out[kind]["wire_bytes"] += wire
    return out


def wire_bytes(parsed: dict) -> float:
    return float(sum(v["wire_bytes"] for v in parsed.values()))


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}[.(]", hlo_text))
