"""Resumable per-request sync-round state machine (DESIGN.md §8–§10).

:class:`SyncRunState` is the coordinator state of ONE round-based
``stage_dist`` run — everything :func:`repro.dist.sync.run_synced` used
to keep in closure variables, factored into an explicit object with a
build/absorb/snapshot/restore protocol:

``build_round(r)``
    Pure planning: the ``run_shard_round`` argument tuples for round
    ``r`` plus the worker ids they belong to (or ``None`` when the run
    is over, or an empty dispatch when every alive worker's cumulative
    budget slice is already spent and the round should be skipped).
``absorb_round(r, dispatched, results, failures)``
    Pool the surviving payloads (sorted worker order — completion order
    must not leak into the shared state), charge budgets, extend the
    failure ledger, drop workers whose retries were exhausted, refresh
    the pooled front; returns whether the run wants another round.
``snapshot(done)`` / ``restore(state)``
    The crash-safe round-checkpoint payload (exact format of PR 6's
    :class:`~repro.dist.ckpt.RoundCheckpointer` files) and its inverse;
    ``restore`` validates the run identity and refuses mismatched runs.

The split is what lets one process drive MANY of these machines over one
shared worker fleet (:mod:`repro.noc.server`): the machine never
dispatches anything itself — the caller owns executors, deadlines,
retries, and fault injection — so requests at different rounds
interleave freely, each advancing whenever *its* round results arrive.
:func:`repro.dist.sync.run_synced` is now the single-machine driver of
exactly this protocol, which keeps the PR 6 interrupt/resume pins (byte
identity, mismatch refusal) pinning the shared implementation.
"""

from __future__ import annotations

import numpy as np

from repro.core.local_search import ParetoSet
from repro.noc.api import Budget, NocProblem, RunResult, design_to_json

from .plan import plan_shards, retry_seed, round_seed, split_evenly

#: history tags are ``worker_id * ROUND_TAG_STRIDE + round`` — unique per
#: (worker, round) and worker-major when sorted. Also the hard cap on
#: rounds (unreachable in practice: every dispatched round costs >= 1
#: evaluation, so rounds are bounded by the eval budget long before it).
ROUND_TAG_STRIDE = 100_000

#: config fields that shape the search trajectory — the run identity a
#: resume must match. Deliberately excludes the knobs that may legally
#: differ between the interrupted and the resuming invocation: executor
#: (where shards run, not what they compute), fault scripts (the resume
#: drops the kill), timeout/retry tuning, and checkpoint_dir/resume
#: themselves.
TRAJECTORY_FIELDS = ("n_workers", "sync_every", "iters_max", "n_starts",
                     "n_swaps", "n_link_moves", "max_local_steps",
                     "forest_kwargs", "forest_backend")


def n_rounds(iters_max: int, sync_every: int) -> int:
    """Planned sync rounds: ceil(iters_max / sync_every). Extra
    budget-draining rounds may follow (see repro.dist.sync)."""
    if sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {sync_every}")
    return -(-iters_max // sync_every)


def reseed_round_args(orig_args: tuple, attempt: int) -> tuple:
    """Retry dispatch for attempt ``attempt``: same shard, fresh
    trajectory — only the seed (arg 2, which ``run_shard_round`` folds
    into the budget) changes, via :func:`repro.dist.plan.retry_seed`."""
    return (orig_args[:2] + (retry_seed(orig_args[2], attempt),)
            + orig_args[3:])


class SyncRunState:
    """Coordinator state of one round-based distributed run.

    ``cfg`` is read by attribute (a :class:`repro.noc.optimizers.
    StageDistConfig` or anything exposing the same fields) — this module
    never imports the registry. Construction plans the shards, splits
    the per-worker budgets across the planned rounds, and derives the
    fleet-diversified round-0 starts; nothing is dispatched.
    """

    def __init__(self, problem: NocProblem, budget: Budget, cfg):
        self.problem = problem
        self.budget = budget
        self.cfg = cfg
        self.R = n_rounds(cfg.iters_max, cfg.sync_every)
        self.shards = plan_shards(problem, budget, cfg.n_workers)
        self.round_evals = {s.worker_id: split_evenly(s.budget.max_evals,
                                                     self.R)
                            for s in self.shards}
        self.round_calls = {s.worker_id: split_evenly(s.budget.max_calls,
                                                      self.R)
                            for s in self.shards}
        self.shard_budget = {s.worker_id: s.budget for s in self.shards}
        self.spent_evals = {s.worker_id: 0 for s in self.shards}
        self.spent_calls = {s.worker_id: 0 for s in self.shards}
        self.stage_cfg = {
            "n_starts": cfg.n_starts, "n_swaps": cfg.n_swaps,
            "n_link_moves": cfg.n_link_moves,
            "max_local_steps": cfg.max_local_steps,
            "forest_kwargs": cfg.forest_kwargs,
            "forest_backend": cfg.forest_backend,
        }
        self.problem_json = problem.to_json()
        self.plan_id = {f: getattr(cfg, f) for f in TRAJECTORY_FIELDS}

        self.pooled_x: list[list[float]] = []
        self.pooled_y: list[float] = []
        # The pooled front: the Pareto union of everything any worker
        # found so far, fed back as each next round's global_init.
        self.pooled_front: dict | None = None
        # Round-0 starts mirror stage_batch's chain diversification
        # across the whole fleet: global chain j (worker i, chain k)
        # starts from the mesh perturbed by 2·j random moves, drawn from
        # the root seed. Without this every worker's chain 0 would
        # re-explore the mesh basin W times over — exactly the
        # duplicated work sharding must avoid.
        from repro.core.problem import sample_neighbors

        start_rng = np.random.default_rng(budget.seed)
        base = problem.mesh()
        self.starts_by_wid: dict[int, list[dict] | None] = {}
        for s in self.shards:
            chain_starts = []
            for k in range(cfg.n_starts):
                j = s.worker_id * cfg.n_starts + k
                d = base
                for _ in range(2 * j):
                    nb = sample_neighbors(problem.spec, d, start_rng, 1, 1)
                    if nb:
                        d = nb[int(start_rng.integers(len(nb)))]
                chain_starts.append(design_to_json(d))
            self.starts_by_wid[s.worker_id] = chain_starts
        self.alive = [s.worker_id for s in self.shards]
        self.results: list[RunResult] = []
        self.failures: list[dict] = []
        #: round index the next build_round call should use.
        self.next_round = 0
        #: the run has decided to stop (no further rounds may dispatch —
        #: a resume of a finished run must not invent extra rounds).
        self.finished = False
        #: round restored from, for diagnostics (None = fresh run).
        self.resumed_from: int | None = None

    # ------------------------------------------------------------- persist
    def snapshot(self, done: bool) -> dict:
        """Complete coordinator state after a round — everything this
        machine mutates, plus the run identity. ``done`` records whether
        the run had decided to stop (a resume must not dispatch extra
        rounds the uninterrupted run would not have)."""
        return {
            "problem": self.problem_json,
            "budget": self.budget.to_json(),
            "plan": self.plan_id,
            "done": bool(done),
            "alive": list(self.alive),
            "spent_evals": {str(w): v for w, v in self.spent_evals.items()},
            "spent_calls": {str(w): v for w, v in self.spent_calls.items()},
            "starts_by_wid": {str(w): v
                              for w, v in self.starts_by_wid.items()},
            "pooled_x": self.pooled_x,
            "pooled_y": self.pooled_y,
            "pooled_front": self.pooled_front,
            "results": [rr.to_json() for rr in self.results],
            "failures": self.failures,
        }

    def restore(self, state: dict) -> int:
        """Load a :meth:`snapshot` back; validates the run identity and
        returns the restored round index. The machine continues at
        ``next_round = restored + 1``."""
        if (state["problem"] != self.problem_json
                or state["budget"] != self.budget.to_json()
                or state["plan"] != self.plan_id):
            raise ValueError(
                "checkpoint belongs to a different run (problem/budget/"
                "trajectory-config mismatch); refusing to resume")
        self.alive = [int(w) for w in state["alive"]]
        self.spent_evals = {int(w): int(v)
                            for w, v in state["spent_evals"].items()}
        self.spent_calls = {int(w): int(v)
                            for w, v in state["spent_calls"].items()}
        self.starts_by_wid = {int(w): v
                              for w, v in state["starts_by_wid"].items()}
        self.pooled_x = state["pooled_x"]
        self.pooled_y = state["pooled_y"]
        self.pooled_front = state["pooled_front"]
        self.results = [RunResult.from_json(j) for j in state["results"]]
        self.failures = list(state["failures"])
        self.resumed_from = int(state["round"])
        self.next_round = self.resumed_from + 1
        self.finished = bool(state.get("done", False))
        return self.resumed_from

    # -------------------------------------------------------------- rounds
    @property
    def done(self) -> bool:
        """No further rounds may dispatch: the run decided to stop, every
        worker is dead, or the round-tag cap was hit. Callers check this
        BEFORE build_round — a done machine gets no further checkpoint
        saves (exactly the pre-refactor loop condition)."""
        return (self.finished or not self.alive
                or self.next_round >= ROUND_TAG_STRIDE)

    def _room(self, wid: int, r: int) -> tuple[int | None, int | None]:
        """Cumulative remaining (evals, calls) for worker ``wid`` at
        round ``r``; extra rounds (r >= R) draw on the full shard."""
        def one(slices, spent, total):
            if total is None:
                return None
            cum = total if r >= self.R else sum(slices[wid][:r + 1])
            return max(0, cum - spent[wid])
        return (one(self.round_evals, self.spent_evals,
                    self.shard_budget[wid].max_evals),
                one(self.round_calls, self.spent_calls,
                    self.shard_budget[wid].max_calls))

    def build_round(self, r: int) -> tuple[list[tuple], list[int]] | None:
        """Argument tuples for round ``r``'s ``run_shard_round``
        dispatches plus the worker ids they belong to, in worker order.

        Returns ``None`` when the run is over (finished, no workers
        alive, round cap hit, or an extra round with no finite eval
        budget to drain). An empty dispatch list means "skip": a planned
        round whose every alive worker's cumulative slice is already
        overspent — later rounds' larger cumulative targets reopen room,
        so the caller should advance to ``r + 1`` without absorbing.
        """
        cfg = self.cfg
        if self.finished or not self.alive or r >= ROUND_TAG_STRIDE:
            self.finished = True
            return None
        planned = r < self.R
        if not planned and self.budget.max_evals is None:
            self.finished = True
            return None  # extra rounds only drain a finite eval budget
        iters_r = (min(cfg.sync_every, cfg.iters_max - r * cfg.sync_every)
                   if planned else cfg.sync_every)
        tasks: list[tuple] = []
        dispatched: list[int] = []
        round_cfg = dict(self.stage_cfg, iters_max=iters_r)
        for wid in self.alive:
            evals_r, calls_r = self._room(wid, r)
            if evals_r == 0 or calls_r == 0:
                continue  # budget fully consumed by earlier rounds
            b = Budget(max_evals=evals_r, max_calls=calls_r,
                       seed=round_seed(self.shard_budget[wid].seed, r))
            starts = self.starts_by_wid[wid]
            if (not planned and self.pooled_front
                    and self.pooled_front["designs"]):
                # Extra rounds intensify: restart every chain from an
                # elite of the pooled front (cycled across workers and
                # rounds for coverage) instead of the meta/random
                # restarts the worker checkpointed — late budget is
                # better spent polishing the union front than opening
                # new basins, which is exactly where the single-process
                # driver's chains sit by this point of a run.
                elite = self.pooled_front["designs"]
                starts = [elite[(wid + k * cfg.n_workers + (r - self.R))
                                % len(elite)]
                          for k in range(cfg.n_starts)]
            dispatched.append(wid)
            tasks.append((
                self.problem_json, b.to_json(), b.seed,
                round_cfg,
                wid * ROUND_TAG_STRIDE + r,        # unique history tag
                starts,
                self.pooled_x or None, self.pooled_y or None,
                self.pooled_front,
            ))
        if not tasks and not planned:
            # In extra rounds room IS the whole remaining shard, so
            # nobody-dispatchable means truly done.
            self.finished = True
            return None
        return tasks, dispatched

    def absorb_round(self, r: int, dispatched: list[int],
                     round_results: dict[int, dict],
                     round_failures: dict[int, list[dict]]) -> bool:
        """Pool round ``r``'s survivors into the shared state; returns
        whether the run wants another round. ``round_results`` /
        ``round_failures`` are keyed by *dispatch index* (position in
        ``dispatched``), exactly as ``execute_shards`` returns them."""
        planned = r < self.R
        # Every failed attempt is reported; a worker is dropped only if
        # it exhausted its attempts (index absent from round_results).
        dropped = []
        for idx in sorted(round_failures):
            self.failures.extend(round_failures[idx])
            if idx not in round_results:
                dropped.append(dispatched[idx])
        # Pool in sorted (worker) order — the shared training set and
        # front must be independent of worker completion order for the
        # next round to be deterministic.
        round_spent = 0
        for idx in sorted(round_results):
            wid = dispatched[idx]
            payload = round_results[idx]
            rr = RunResult.from_json(payload["result"])
            self.spent_evals[wid] += int(rr.n_evals)
            self.spent_calls[wid] += int(rr.n_calls)
            round_spent += int(rr.n_evals)
            self.results.append(rr)
            self.pooled_x.extend(payload["x_train"])
            self.pooled_y.extend(payload["y_train"])
            if payload["next_starts"]:
                self.starts_by_wid[wid] = payload["next_starts"]
        self.alive = [w for w in self.alive if w not in dropped]
        # Refresh the pooled front from every surviving result so far
        # (workers echo the injected front back inside their global
        # sets, so rebuilding from scratch is a pure union, no double
        # counting).
        front = ParetoSet.empty()
        for rr in self.results:
            front = front.merged_with(
                list(rr.designs), np.asarray(rr.objs, dtype=np.float64),
                rr.obj_idx)
        self.pooled_front = {
            "designs": [design_to_json(d) for d in front.designs],
            "objs": np.asarray(front.objs, dtype=np.float64).tolist(),
        }
        # An unplanned round that spent only its mesh anchors made no
        # search progress — further rounds would loop on anchors forever.
        # NOTE: an empty `alive` does NOT flip `cont` here (the next
        # build_round returns None for it) — this keeps the checkpoint
        # `done` flag bit-identical to the pre-refactor coordinator.
        cont = not (not planned and round_spent <= len(dispatched))
        self.finished = not cont
        self.next_round = r + 1
        return cont

    def skip_round(self, r: int) -> bool:
        """Advance past a round with an empty dispatch (every alive
        worker's cumulative slice overspent). Planned rounds continue —
        later rounds reopen room; extra rounds end the run."""
        planned = r < self.R
        self.finished = not planned
        self.next_round = r + 1
        return planned
