"""Worker side of distributed multi-start MOO-STAGE + the executor matrix.

:func:`run_shard` is a *pure function of JSON*: ``(problem_json,
budget_json, seed) -> RunResult_json``. It rebuilds the problem, runs the
registry ``stage_batch`` driver under the shard budget, and returns the
serialized result — nothing about it depends on coordinator state, which
is what lets the same function execute in-process, in a
``ProcessPoolExecutor`` child, or pinned to a JAX device.

Executor matrix (DESIGN.md §8):

``serial``
    In-order, in-process loop. The reproducibility anchor: the W=1 serial
    run is pinned byte-identical to a registry ``stage_batch`` run, and
    serial W>1 produces the same merged result as ``process`` (same
    shards, same seeds — the executor only chooses *where* a shard runs).
``process``
    ``concurrent.futures.ProcessPoolExecutor`` with the **spawn** start
    method — fork after JAX has initialized its runtime threads can
    deadlock, so children pay a fresh interpreter + import instead.
``jax``
    One shard per JAX device, round-robin, each executed under
    ``jax.default_device(dev)`` so its XLA dispatches land on its own
    accelerator. On a single-device host this degrades to ``serial``
    (documented, not hidden).

Failures are collected, not raised: :func:`execute_shards` returns
``(results, failures)`` and the coordinator merges the survivors,
reporting the failures in ``RunResult.extra`` diagnostics.
"""

from __future__ import annotations

import contextlib
import dataclasses
import multiprocessing
from concurrent.futures import ProcessPoolExecutor

from repro.noc.api import Budget, NocProblem, RunResult

EXECUTORS = ("serial", "process", "jax")


def check_executor(executor: str) -> None:
    if executor not in EXECUTORS:
        raise ValueError(
            f"executor must be one of {EXECUTORS}, got {executor!r}")


# --------------------------------------------------------------------------
# The pure worker functions (module-level: picklable by reference)
# --------------------------------------------------------------------------
def run_shard(problem_json: dict, budget_json: dict, seed: int,
              config_json: dict | None = None, worker_id: int = 0) -> dict:
    """Run one shard: registry ``stage_batch`` on the deserialized problem
    under the shard budget, seeded with ``seed``. Returns the RunResult
    JSON with the worker id tagged into ``extra`` (the merge orders
    histories by it).

    Calls :func:`repro.noc.api.run` exactly as a direct registry call
    would (fresh evaluator, ctx built inside the budget guard) — a W=1
    shard at the root seed is therefore byte-identical to ``run(problem,
    "stage_batch", budget)``.
    """
    from repro.noc.api import run

    problem = NocProblem.from_json(problem_json)
    budget = dataclasses.replace(Budget.from_json(budget_json),
                                 seed=int(seed))
    res = run(problem, "stage_batch", budget=budget, config=config_json)
    res.extra["worker_id"] = int(worker_id)
    return res.to_json()


def run_shard_round(problem_json: dict, budget_json: dict, seed: int,
                    config_json: dict | None = None, worker_id: int = 0,
                    starts_json: list[dict] | None = None,
                    train_x: list | None = None,
                    train_y: list | None = None,
                    global_json: dict | None = None) -> dict:
    """One surrogate-sync round of a shard (repro.dist.sync).

    Like :func:`run_shard`, but resumes the worker's chains from
    ``starts_json``, warm-starts the surrogate from the coordinator's
    pooled ``(train_x, train_y)`` rows, and seeds the global
    non-dominated set from the pooled front ``global_json`` (designs +
    objective rows — they cost no evaluations, and make the chains
    maximize marginal PHV over what the whole fleet already found).
    Returns a composite dict::

        {"result":      RunResult JSON (this round's search),
         "x_train":     new surrogate rows this round produced,
         "y_train":     their labels,
         "next_starts": designs to resume the chains from next round}
    """
    import numpy as np

    from repro.core.local_search import ParetoSet, SearchHistory
    from repro.core.stage import StageBatchResult, stage_batch
    from repro.noc.api import (BudgetedEvaluator, BudgetExhausted,
                               design_from_json, design_to_json)
    from repro.noc.optimizers import StageBatchConfig

    problem = NocProblem.from_json(problem_json)
    budget = dataclasses.replace(Budget.from_json(budget_json),
                                 seed=int(seed))
    cfg = StageBatchConfig(**(config_json or {}))
    starts = ([design_from_json(s) for s in starts_json]
              if starts_json else None)
    train_init = None
    if train_x is not None and len(train_x):
        train_init = (np.asarray(train_x, dtype=np.float64),
                      np.asarray(train_y, dtype=np.float64))
    global_init = None
    if global_json is not None and global_json.get("designs"):
        global_init = ParetoSet(
            [design_from_json(d) for d in global_json["designs"]],
            np.asarray(global_json["objs"], dtype=np.float64))

    # The guard mirrors api.run's uniform budget enforcement: max_evals
    # duplicates stage_batch's native loop-top checks (same threshold —
    # it can only fire when the round budget is pre-spent), but max_calls
    # has no native check and must be enforced here. A guard trip forfeits
    # the round's (unfinished) search — the coordinator keeps earlier
    # rounds and flags the merged run exhausted.
    ev = problem.evaluator()
    guarded = BudgetedEvaluator(ev, budget)
    res: StageBatchResult | None = None
    ctx = history = None
    try:
        ctx = problem.context(guarded)  # mesh anchor: 1 guarded eval
        history = SearchHistory(ev, ctx)
        res = stage_batch(
            problem.spec, problem.traffic_matrix(), n_starts=cfg.n_starts,
            seed=budget.seed, case=problem.case, iters_max=cfg.iters_max,
            n_swaps=cfg.n_swaps, n_link_moves=cfg.n_link_moves,
            max_local_steps=cfg.max_local_steps,
            forest_kwargs=cfg.forest_kwargs,
            forest_backend=(cfg.forest_backend
                            if cfg.forest_backend is not None
                            else problem.forest_backend),
            max_evals=budget.max_evals, ev=guarded, ctx=ctx, history=history,
            starts=starts, train_init=train_init, global_init=global_init,
            checkpoint_restarts=True,
        )
    except BudgetExhausted:
        pass
    exhausted = res is None
    if budget.max_evals is not None and ev.n_evals >= budget.max_evals:
        exhausted = True
    if budget.max_calls is not None and ev.n_calls >= budget.max_calls:
        exhausted = True
    if res is None:
        # Guard tripped: the round's unfinished search is forfeited, but
        # any partial history records (real evaluations) are kept.
        res = StageBatchResult(
            global_set=ParetoSet.empty(), history=history, eval_errors=[],
            n_local_searches=0, n_starts=cfg.n_starts, n_evals=ev.n_evals,
            converged=False)
    rr = RunResult(
        optimizer="stage_batch",
        problem=problem.to_json(),
        budget=budget.to_json(),
        config=dataclasses.asdict(cfg),
        obj_idx=tuple(ctx.obj_idx) if ctx is not None else problem.obj_idx,
        designs=list(res.global_set.designs),
        objs=np.asarray(res.global_set.objs, dtype=np.float64),
        n_evals=ev.n_evals,
        n_calls=ev.n_calls,
        wall_s=0.0,
        history=(history.as_array() if history is not None
                 else np.zeros((0, 4))),
        extra={"worker_id": int(worker_id), "converged": res.converged,
               "n_local_searches": res.n_local_searches,
               "phv": (ctx.phv(res.global_set.objs)
                       if ctx is not None else 0.0)},
        exhausted=exhausted,
    )
    return {
        "result": rr.to_json(),
        "x_train": np.asarray(res.x_train, dtype=np.float64).tolist(),
        "y_train": np.asarray(res.y_train, dtype=np.float64).tolist(),
        "next_starts": [design_to_json(d) for d in res.next_starts],
    }


# --------------------------------------------------------------------------
# Executors
# --------------------------------------------------------------------------
@contextlib.contextmanager
def shard_pool(executor: str, n_workers: int):
    """Reusable process pool for multi-round dispatch (repro.dist.sync):
    spawn-started children pay interpreter + JAX import once, not once
    per round. Yields None for the in-process executors."""
    check_executor(executor)
    if executor != "process":
        yield None
        return
    mp_ctx = multiprocessing.get_context("spawn")
    with ProcessPoolExecutor(max_workers=max(1, n_workers),
                             mp_context=mp_ctx) as pool:
        yield pool


def execute_shards(fn, arg_tuples: list[tuple], executor: str = "serial",
                   pool=None) -> tuple[dict[int, dict], dict[int, str]]:
    """Run ``fn(*args)`` for every entry of ``arg_tuples`` under the
    chosen executor. Entry ``i`` is shard ``i``; returns ``(results,
    failures)`` keyed by shard index — a raising shard lands in
    ``failures`` as ``"ExcType: message"`` instead of aborting the rest
    (fault isolation; the coordinator merges the survivors).

    ``pool`` (from :func:`shard_pool`) reuses one process pool across
    calls; without it the ``process`` executor builds a one-shot pool.
    """
    check_executor(executor)
    results: dict[int, dict] = {}
    failures: dict[int, str] = {}

    if executor == "process":
        with contextlib.ExitStack() as stack:
            if pool is None:
                pool = stack.enter_context(
                    shard_pool(executor, len(arg_tuples)))
            futures = {i: pool.submit(fn, *args)
                       for i, args in enumerate(arg_tuples)}
            for i, fut in futures.items():
                try:
                    results[i] = fut.result()
                except Exception as exc:  # noqa: BLE001 — fault isolation
                    failures[i] = f"{type(exc).__name__}: {exc}"
        return results, failures

    if executor == "jax":
        import jax

        devices = jax.devices()
        for i, args in enumerate(arg_tuples):
            dev = devices[i % len(devices)]
            try:
                with jax.default_device(dev):
                    results[i] = fn(*args)
            except Exception as exc:  # noqa: BLE001
                failures[i] = f"{type(exc).__name__}: {exc}"
        return results, failures

    for i, args in enumerate(arg_tuples):  # serial
        try:
            results[i] = fn(*args)
        except Exception as exc:  # noqa: BLE001
            failures[i] = f"{type(exc).__name__}: {exc}"
    return results, failures
