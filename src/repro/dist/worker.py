"""Worker side of distributed multi-start MOO-STAGE + the executor matrix.

:func:`run_shard` is a *pure function of JSON*: ``(problem_json,
budget_json, seed) -> RunResult_json``. It rebuilds the problem, runs the
registry ``stage_batch`` driver under the shard budget, and returns the
serialized result — nothing about it depends on coordinator state, which
is what lets the same function execute in-process, in a
``ProcessPoolExecutor`` child, or pinned to a JAX device.

Executor matrix (DESIGN.md §8):

``serial``
    In-order, in-process loop. The reproducibility anchor: the W=1 serial
    run is pinned byte-identical to a registry ``stage_batch`` run, and
    serial W>1 produces the same merged result as ``process`` (same
    shards, same seeds — the executor only chooses *where* a shard runs).
``process``
    ``concurrent.futures.ProcessPoolExecutor`` with the **spawn** start
    method — fork after JAX has initialized its runtime threads can
    deadlock, so children pay a fresh interpreter + import instead.
``jax``
    One shard per JAX device, round-robin, each executed under
    ``jax.default_device(dev)`` so its XLA dispatches land on its own
    accelerator. On a single-device host this degrades to ``serial``
    (documented, not hidden).
``spmd``
    Shards run in order, but each shard's evaluator executes its chain
    batches as ONE multi-device ``shard_map`` program over every visible
    device (``repro.core.evaluate.spmd_scope``) — data-parallel over the
    candidate batch instead of parallel over shards. Numerically identical
    to ``serial`` (batch sharding splits independent per-design programs);
    on a single-device host it degrades to ``serial`` plus the shard_map
    partitioning overhead.

Failures are collected, not raised: :func:`execute_shards` returns
``(results, failures)`` and the coordinator merges the survivors,
reporting every failed attempt as a structured record (worker id, round,
attempt, phase, error, traceback) in ``RunResult.extra`` diagnostics.
Deadlines, bounded reseeded retries, and spawn-pool rebuilds live here
too — the resilience contract is DESIGN.md §9.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import multiprocessing
import time
import traceback
from concurrent.futures import ProcessPoolExecutor

from repro.dist.faults import call_with_faults
from repro.noc.api import Budget, NocProblem, RunResult

EXECUTORS = ("serial", "process", "jax", "spmd")


# --------------------------------------------------------------------------
# Cooperative in-process deadlines
# --------------------------------------------------------------------------
class ShardDeadlineExceeded(RuntimeError):
    """A shard tripped its cooperative deadline mid-search (serial/jax).

    In-process executors cannot preempt their own frame the way the
    process executor's ``fut.result(timeout=...)`` + pool-kill can, so
    the deadline is enforced *cooperatively*: :func:`_execute_inline`
    arms a monotonic deadline in :data:`_DEADLINE` before dispatching,
    and the worker wraps its evaluator in :class:`_DeadlineGuard`, which
    raises this before every evaluation batch once the deadline passes.
    Every search driver funnels all evaluation through
    ``Evaluator.batch_aux``, so overrun is bounded by a single batch
    instead of the rest of the round.
    """


_DEADLINE: contextvars.ContextVar[float | None] = contextvars.ContextVar(
    "repro_dist_shard_deadline", default=None)


class _DeadlineGuard:
    """Evaluator proxy that trips :class:`ShardDeadlineExceeded` once the
    armed deadline passes. Mirrors the Evaluator surface the same way
    :class:`repro.noc.api.BudgetedEvaluator` does — everything funnels
    through ``batch_aux``; reads (``n_evals``/``n_calls``/...) delegate
    untouched, so wrapping never changes a run that meets its deadline."""

    def __init__(self, ev, deadline: float):
        self._ev = ev
        self._deadline = deadline

    def _check(self) -> None:
        now = time.monotonic()
        if now > self._deadline:
            raise ShardDeadlineExceeded(
                f"cooperative deadline exceeded {now - self._deadline:.3f}s "
                "before an evaluation batch (in-process executors check the "
                "shard deadline between evaluator dispatches)")

    def batch_aux(self, designs):
        if designs:
            self._check()
        return self._ev.batch_aux(designs)

    def batch(self, designs):
        return self.batch_aux(designs)[0]

    def __call__(self, d):
        return self.batch([d])[0]

    def edp(self, d):
        self._check()
        return self._ev.edp(d)

    def __getattr__(self, name: str):
        return getattr(self._ev, name)


def deadline_wrap(ev):
    """Wrap ``ev`` in a :class:`_DeadlineGuard` when a cooperative
    deadline is armed for this dispatch; identity otherwise."""
    deadline = _DEADLINE.get()
    return ev if deadline is None else _DeadlineGuard(ev, deadline)


def check_executor(executor: str) -> None:
    if executor not in EXECUTORS:
        raise ValueError(
            f"executor must be one of {EXECUTORS}, got {executor!r}")


# --------------------------------------------------------------------------
# The pure worker functions (module-level: picklable by reference)
# --------------------------------------------------------------------------
def run_shard(problem_json: dict, budget_json: dict, seed: int,
              config_json: dict | None = None, worker_id: int = 0) -> dict:
    """Run one shard: registry ``stage_batch`` on the deserialized problem
    under the shard budget, seeded with ``seed``. Returns the RunResult
    JSON with the worker id tagged into ``extra`` (the merge orders
    histories by it).

    Calls :func:`repro.noc.api.run` exactly as a direct registry call
    would (fresh evaluator, ctx built inside the budget guard) — a W=1
    shard at the root seed is therefore byte-identical to ``run(problem,
    "stage_batch", budget)``.
    """
    from repro.noc.api import run

    problem = NocProblem.from_json(problem_json)
    budget = dataclasses.replace(Budget.from_json(budget_json),
                                 seed=int(seed))
    # With a cooperative deadline armed, inject a guarded copy of the
    # evaluator api.run would have built itself — same fresh evaluator,
    # every dispatch now also checks the shard deadline.
    ev = (deadline_wrap(problem.evaluator())
          if _DEADLINE.get() is not None else None)
    res = run(problem, "stage_batch", budget=budget, config=config_json,
              ev=ev)
    res.extra["worker_id"] = int(worker_id)
    return res.to_json()


def run_shard_round(problem_json: dict, budget_json: dict, seed: int,
                    config_json: dict | None = None, worker_id: int = 0,
                    starts_json: list[dict] | None = None,
                    train_x: list | None = None,
                    train_y: list | None = None,
                    global_json: dict | None = None) -> dict:
    """One surrogate-sync round of a shard (repro.dist.sync).

    Like :func:`run_shard`, but resumes the worker's chains from
    ``starts_json``, warm-starts the surrogate from the coordinator's
    pooled ``(train_x, train_y)`` rows, and seeds the global
    non-dominated set from the pooled front ``global_json`` (designs +
    objective rows — they cost no evaluations, and make the chains
    maximize marginal PHV over what the whole fleet already found).
    Returns a composite dict::

        {"result":      RunResult JSON (this round's search),
         "x_train":     new surrogate rows this round produced,
         "y_train":     their labels,
         "next_starts": designs to resume the chains from next round}
    """
    import numpy as np

    from repro.core.local_search import ParetoSet, SearchHistory
    from repro.core.stage import StageBatchResult, stage_batch
    from repro.noc.api import (BudgetedEvaluator, BudgetExhausted,
                               design_from_json, design_to_json)
    from repro.noc.optimizers import StageBatchConfig

    problem = NocProblem.from_json(problem_json)
    budget = dataclasses.replace(Budget.from_json(budget_json),
                                 seed=int(seed))
    cfg = StageBatchConfig(**(config_json or {}))
    starts = ([design_from_json(s) for s in starts_json]
              if starts_json else None)
    train_init = None
    if train_x is not None and len(train_x):
        train_init = (np.asarray(train_x, dtype=np.float64),
                      np.asarray(train_y, dtype=np.float64))
    global_init = None
    if global_json is not None and global_json.get("designs"):
        global_init = ParetoSet(
            [design_from_json(d) for d in global_json["designs"]],
            np.asarray(global_json["objs"], dtype=np.float64))

    # The guard mirrors api.run's uniform budget enforcement: max_evals
    # duplicates stage_batch's native loop-top checks (same threshold —
    # it can only fire when the round budget is pre-spent), but max_calls
    # has no native check and must be enforced here. A guard trip forfeits
    # the round's (unfinished) search — the coordinator keeps earlier
    # rounds and flags the merged run exhausted.
    ev = problem.evaluator()
    guarded = BudgetedEvaluator(deadline_wrap(ev), budget)
    res: StageBatchResult | None = None
    ctx = history = None
    try:
        ctx = problem.context(guarded)  # mesh anchor: 1 guarded eval
        history = SearchHistory(ev, ctx)
        res = stage_batch(
            problem.spec, problem.traffic_matrix(), n_starts=cfg.n_starts,
            seed=budget.seed, case=problem.case, iters_max=cfg.iters_max,
            n_swaps=cfg.n_swaps, n_link_moves=cfg.n_link_moves,
            max_local_steps=cfg.max_local_steps,
            forest_kwargs=cfg.forest_kwargs,
            forest_backend=(cfg.forest_backend
                            if cfg.forest_backend is not None
                            else problem.forest_backend),
            meta_backend=cfg.meta_backend,
            max_evals=budget.max_evals, ev=guarded, ctx=ctx, history=history,
            starts=starts, train_init=train_init, global_init=global_init,
            checkpoint_restarts=True,
        )
    except BudgetExhausted:
        pass
    exhausted = res is None
    if budget.max_evals is not None and ev.n_evals >= budget.max_evals:
        exhausted = True
    if budget.max_calls is not None and ev.n_calls >= budget.max_calls:
        exhausted = True
    if res is None:
        # Guard tripped: the round's unfinished search is forfeited, but
        # any partial history records (real evaluations) are kept.
        res = StageBatchResult(
            global_set=ParetoSet.empty(), history=history, eval_errors=[],
            n_local_searches=0, n_starts=cfg.n_starts, n_evals=ev.n_evals,
            converged=False)
    rr = RunResult(
        optimizer="stage_batch",
        problem=problem.to_json(),
        budget=budget.to_json(),
        config=dataclasses.asdict(cfg),
        obj_idx=tuple(ctx.obj_idx) if ctx is not None else problem.obj_idx,
        designs=list(res.global_set.designs),
        objs=np.asarray(res.global_set.objs, dtype=np.float64),
        n_evals=ev.n_evals,
        n_calls=ev.n_calls,
        wall_s=0.0,
        history=(history.as_array() if history is not None
                 else np.zeros((0, 4))),
        extra={"worker_id": int(worker_id), "converged": res.converged,
               "n_local_searches": res.n_local_searches,
               "phv": (ctx.phv(res.global_set.objs)
                       if ctx is not None else 0.0)},
        exhausted=exhausted,
    )
    return {
        "result": rr.to_json(),
        "x_train": np.asarray(res.x_train, dtype=np.float64).tolist(),
        "y_train": np.asarray(res.y_train, dtype=np.float64).tolist(),
        "next_starts": [design_to_json(d) for d in res.next_starts],
    }


def validate_result_payload(payload) -> None:
    """Structural check on a ``run_shard`` payload (a RunResult JSON)
    before the coordinator merges it — the corrupt-payload defense for
    the no-sync path (phase ``"validate"`` on rejection)."""
    if not isinstance(payload, dict):
        raise ValueError(
            f"shard payload must be a dict, got {type(payload).__name__}")
    missing = {"designs", "objs", "n_evals", "history"} - set(payload)
    if missing:
        raise ValueError(
            f"shard payload is not a RunResult JSON; missing {sorted(missing)}")


# --------------------------------------------------------------------------
# Executors
# --------------------------------------------------------------------------
class _ShardTimeout(RuntimeError):
    """An in-process shard overran its deadline between cooperative
    checks (post-hoc backstop — see :class:`ShardDeadlineExceeded`)."""


class _ValidationFailed(RuntimeError):
    """A shard returned a payload the coordinator's validator rejected."""


class ShardPool:
    """Rebuildable handle around a spawn ``ProcessPoolExecutor``.

    A hung or hard-died child poisons a process pool: a hang occupies a
    slot forever, an ``os._exit``/segfault marks the whole pool broken.
    Either way the only recovery is *kill the children and start over* —
    :meth:`rebuild` does exactly that (``rebuilds`` counts how often, for
    ``RunResult.extra`` diagnostics). Spawn start method throughout: fork
    after JAX initializes its runtime threads can deadlock, so children
    pay a fresh interpreter + import instead.
    """

    def __init__(self, n_workers: int):
        self.n_workers = max(1, int(n_workers))
        self.rebuilds = 0
        self._pool = self._make()

    def _make(self) -> ProcessPoolExecutor:
        return ProcessPoolExecutor(
            max_workers=self.n_workers,
            mp_context=multiprocessing.get_context("spawn"))

    def submit(self, fn, *args):
        return self._pool.submit(fn, *args)

    def kill(self) -> None:
        """Tear the pool down without waiting on its children — the only
        way out when one of them is hung."""
        procs = list(getattr(self._pool, "_processes", None or {}).values())
        self._pool.shutdown(wait=False, cancel_futures=True)
        for p in procs:
            try:
                p.terminate()
            except (OSError, ValueError):
                pass
        for p in procs:
            try:
                p.join(timeout=5.0)
            except (OSError, ValueError, AssertionError):
                pass

    def rebuild(self) -> None:
        self.kill()
        self._pool = self._make()
        self.rebuilds += 1

    def shutdown(self) -> None:
        try:
            self._pool.shutdown(wait=True, cancel_futures=True)
        except Exception:  # noqa: BLE001 — a broken pool may refuse politely
            self.kill()

    def __enter__(self):
        return self

    def __exit__(self, *exc_info):
        self.shutdown()
        return False


@contextlib.contextmanager
def shard_pool(executor: str, n_workers: int):
    """Reusable process pool for multi-round dispatch (repro.dist.sync):
    spawn-started children pay interpreter + JAX import once, not once
    per round. Yields a :class:`ShardPool` for ``process``, None for the
    in-process executors."""
    check_executor(executor)
    if executor != "process":
        yield None
        return
    with ShardPool(n_workers) as pool:
        yield pool


def _failure_record(worker_id: int, round_idx: int, attempt: int,
                    phase: str, exc) -> dict:
    """Structured failure record (DESIGN.md §9). ``phase`` is where the
    dispatch died: ``"run"`` (worker raised), ``"timeout"`` (deadline),
    ``"pool"`` (process pool broke — culprit unattributable), or
    ``"validate"`` (payload rejected by the coordinator)."""
    if isinstance(exc, BaseException):
        error = f"{type(exc).__name__}: {exc}"
        cause = getattr(exc, "__cause__", None)
        if cause is not None and type(cause).__name__ == "_RemoteTraceback":
            tb = str(cause)  # the child's stack, smuggled across the pickle
        else:
            tb = "".join(traceback.format_exception(exc))
    else:
        error = str(exc)
        tb = ""
    return {"worker_id": int(worker_id), "round": int(round_idx),
            "attempt": int(attempt), "phase": str(phase),
            "error": error, "traceback": tb}


def _record_failure(failures: dict, idx: int, rec: dict) -> None:
    failures.setdefault(idx, []).append(rec)


def _run_validated(payload, validate):
    if validate is not None:
        try:
            validate(payload)
        except Exception as exc:  # noqa: BLE001 — any rejection counts
            raise _ValidationFailed(str(exc)) from exc
    return payload


def execute_shards(fn, arg_tuples: list[tuple], executor: str = "serial",
                   pool=None, *, meta: list[tuple[int, int]] | None = None,
                   timeout_s: float | None = None, max_retries: int = 0,
                   backoff_s: float = 0.0, retry_args=None, injector=None,
                   validate=None) -> tuple[dict[int, dict],
                                           dict[int, list[dict]]]:
    """Run ``fn(*args)`` for every entry of ``arg_tuples`` under the
    chosen executor, with per-shard deadlines and bounded retries.

    Entry ``i`` is shard ``i``; returns ``(results, failures)`` keyed by
    shard index. Every failed *attempt* appends a structured record (see
    :func:`_failure_record`) to ``failures[i]`` — so an index present in
    both maps means "succeeded after retries", and an index only in
    ``failures`` is a shard that exhausted its attempts (the coordinator
    merges the survivors; fault isolation, not abort).

    Knobs (all keyword-only; defaults reproduce the legacy contract):

    ``meta``
        ``(worker_id, round_idx)`` per shard, for failure records and
        fault matching. Defaults to ``(i, 0)``.
    ``timeout_s``
        Per-shard wall-clock deadline. Under ``process`` it is enforced
        *preemptively* — ``fut.result(timeout=...)`` measured from wave
        dispatch, and a trip kills + rebuilds the pool (the hung child
        holds a slot; there is no gentler eviction). ``serial``/``jax``
        cannot preempt their own frame, so they enforce the deadline
        *cooperatively*: the armed :data:`_DEADLINE` makes the worker's
        evaluator raise :class:`ShardDeadlineExceeded` before the first
        evaluation batch past the deadline — overrun is bounded by one
        batch, not the rest of the shard — with a post-hoc elapsed check
        as backstop for overruns between evaluator dispatches. Either
        trip is charged as a ``"timeout"`` failure (DESIGN.md §9).
    ``max_retries`` / ``backoff_s``
        Up to ``max_retries`` re-dispatches per shard, sleeping
        ``backoff_s * 2**(attempt-1)`` before attempt ``attempt``.
    ``retry_args``
        ``(orig_args, attempt) -> new_args`` — re-derives the dispatch
        for attempt ``attempt`` (the coordinator reseeds via
        :func:`repro.dist.plan.retry_seed`, so a retry samples a fresh
        trajectory instead of replaying the crash). Default: retry the
        identical args.
    ``injector``
        :class:`repro.dist.faults.FaultInjector` wrapped around the
        worker boundary via ``call_with_faults`` (inside the child for
        ``process``, so aborts/hangs are physically real).
    ``validate``
        Coordinator-side payload check; a raise becomes a ``"validate"``
        failure (retriable — this is the corrupt-payload defense).

    ``pool`` (a :class:`ShardPool` from :func:`shard_pool`) reuses one
    process pool across calls; without it the ``process`` executor
    builds a one-shot pool. On pool breakage every in-flight shard is
    charged a ``"pool"`` failure (the culprit is unattributable) and
    re-dispatched against the rebuilt pool if it has attempts left.
    """
    check_executor(executor)
    if meta is None:
        meta = [(i, 0) for i in range(len(arg_tuples))]
    if max_retries < 0:
        raise ValueError(f"max_retries must be >= 0, got {max_retries}")

    if executor == "process":
        return _execute_process(fn, arg_tuples, pool, meta, timeout_s,
                                max_retries, backoff_s, retry_args,
                                injector, validate)
    return _execute_inline(fn, arg_tuples, executor, meta, timeout_s,
                           max_retries, backoff_s, retry_args, injector,
                           validate)


def _execute_inline(fn, arg_tuples, executor, meta, timeout_s, max_retries,
                    backoff_s, retry_args, injector, validate):
    """serial/jax/spmd: in-process dispatch with an inline retry loop."""
    if executor == "jax":
        import jax
        devices = jax.devices()
    spmd_cm = None
    if executor == "spmd":
        from repro.core.evaluate import make_spmd_mesh, spmd_scope

        mesh = make_spmd_mesh()
        # Evaluators read the ambient mesh at construction, which happens
        # inside fn (problem.evaluator()) — so the scope must wrap dispatch.
        spmd_cm = lambda: spmd_scope(mesh)
    results: dict[int, dict] = {}
    failures: dict[int, list[dict]] = {}
    for i, orig_args in enumerate(arg_tuples):
        wid, rnd = meta[i]
        args = orig_args
        for attempt in range(max_retries + 1):
            if attempt > 0:
                if backoff_s > 0:
                    time.sleep(backoff_s * (2 ** (attempt - 1)))
                if retry_args is not None:
                    args = retry_args(orig_args, attempt)
            t0 = time.monotonic()
            token = (_DEADLINE.set(t0 + timeout_s)
                     if timeout_s is not None else None)
            try:
                if executor == "jax":
                    with jax.default_device(devices[i % len(devices)]):
                        payload = call_with_faults(
                            injector, wid, rnd, attempt, fn, args)
                elif spmd_cm is not None:
                    with spmd_cm():
                        payload = call_with_faults(
                            injector, wid, rnd, attempt, fn, args)
                else:
                    payload = call_with_faults(
                        injector, wid, rnd, attempt, fn, args)
                elapsed = time.monotonic() - t0
                if timeout_s is not None and elapsed > timeout_s:
                    # Backstop for shards that overran between evaluator
                    # dispatches (e.g. the final surrogate refit): the
                    # cooperative guard can only fire at an evaluation.
                    raise _ShardTimeout(
                        f"shard ran {elapsed:.3f}s, deadline {timeout_s}s "
                        "(post-hoc backstop: the overrun fell between "
                        "cooperative deadline checks)")
                results[i] = _run_validated(payload, validate)
                break
            except Exception as exc:  # noqa: BLE001 — fault isolation
                phase = ("timeout" if isinstance(
                             exc, (_ShardTimeout, ShardDeadlineExceeded))
                         else "validate" if isinstance(exc, _ValidationFailed)
                         else "run")
                _record_failure(failures, i,
                                _failure_record(wid, rnd, attempt, phase, exc))
            finally:
                if token is not None:
                    _DEADLINE.reset(token)
    return results, failures


def _execute_process(fn, arg_tuples, pool, meta, timeout_s, max_retries,
                     backoff_s, retry_args, injector, validate):
    """process: wave dispatch with preemptive deadlines + pool rebuild."""
    from concurrent.futures import TimeoutError as FutTimeout
    from concurrent.futures.process import BrokenProcessPool

    results: dict[int, dict] = {}
    failures: dict[int, list[dict]] = {}
    own_pool = pool is None
    if own_pool:
        pool = ShardPool(len(arg_tuples))
    try:
        wave = [(i, 0, arg_tuples[i]) for i in range(len(arg_tuples))]
        while wave:
            delay = max((backoff_s * (2 ** (a - 1))
                         for _, a, _ in wave if a > 0), default=0.0)
            if delay > 0:
                time.sleep(delay)
            t0 = time.monotonic()
            futs = []
            for i, attempt, args in wave:
                wid, rnd = meta[i]
                futs.append((i, attempt, args, pool.submit(
                    call_with_faults, injector, wid, rnd, attempt, fn, args)))
            next_wave = []

            def _retry(i, attempt, args):
                if attempt < max_retries:
                    new_args = (retry_args(arg_tuples[i], attempt + 1)
                                if retry_args is not None else args)
                    next_wave.append((i, attempt + 1, new_args))

            disrupted = None  # reason string once the pool must be rebuilt
            for i, attempt, args, fut in futs:
                wid, rnd = meta[i]
                if disrupted is not None and not fut.done():
                    # Collateral of the rebuild-to-come: this shard was
                    # in flight when the pool got poisoned.
                    _record_failure(failures, i, _failure_record(
                        wid, rnd, attempt, "pool", disrupted))
                    _retry(i, attempt, args)
                    continue
                try:
                    if timeout_s is None:
                        payload = fut.result()
                    else:
                        remaining = t0 + timeout_s - time.monotonic()
                        payload = fut.result(timeout=max(0.0, remaining))
                    results[i] = _run_validated(payload, validate)
                except FutTimeout:
                    exc = _ShardTimeout(
                        f"shard exceeded its {timeout_s}s deadline; pool "
                        "killed and rebuilt")
                    _record_failure(failures, i, _failure_record(
                        wid, rnd, attempt, "timeout", exc))
                    _retry(i, attempt, args)
                    disrupted = (f"pool rebuilt after worker {wid} tripped "
                                 f"its {timeout_s}s deadline")
                except BrokenProcessPool as exc:
                    _record_failure(failures, i, _failure_record(
                        wid, rnd, attempt, "pool", exc))
                    _retry(i, attempt, args)
                    disrupted = f"{type(exc).__name__}: {exc}"
                except _ValidationFailed as exc:
                    _record_failure(failures, i, _failure_record(
                        wid, rnd, attempt, "validate", exc))
                    _retry(i, attempt, args)
                except Exception as exc:  # noqa: BLE001 — fault isolation
                    _record_failure(failures, i, _failure_record(
                        wid, rnd, attempt, "run", exc))
                    _retry(i, attempt, args)
            if disrupted is not None:
                pool.rebuild()
            wave = next_wave
    finally:
        if own_pool:
            pool.shutdown()
    return results, failures
