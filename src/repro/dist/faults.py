"""Deterministic fault injection for the distributed layer (DESIGN.md §9).

Every degradation path the resilience substrate promises — deadline
trips, bounded retries, spawn-pool rebuilds, corrupt-payload rejection,
mid-run coordinator death — is pinned by tests *through this module*
rather than asserted in prose. A :class:`FaultInjector` is plain data
(a tuple of fault dicts plus an optional seeded random crash rate), so
it pickles into spawn children and serializes into a
``StageDistConfig`` / ``RunResult.config`` unchanged.

Fault dicts::

    {"kind": "crash",   "worker_id": 1, "round": 0, "attempt": 0}
    {"kind": "abort",   "worker_id": 1, "round": 0, "attempt": 0}
    {"kind": "hang",    "worker_id": 2, "round": 1, "attempt": 0,
     "hang_s": 3.0}
    {"kind": "corrupt", "worker_id": 0, "round": 0, "attempt": 0}
    {"kind": "kill_coordinator", "round": 1}

``worker_id: None`` (or omitted) matches every worker; ``round`` and
``attempt`` default to 0 and must match exactly — which is what makes a
fault a *scripted point event*: the retry of a crashed attempt (a new
``attempt``) runs clean unless another fault targets it.

Kinds:

``crash``
    Raise :class:`InjectedFault` in place of the shard function — an
    ordinary worker exception (retriable, recorded with traceback).
``abort``
    A *hard* death. In a spawn child: ``os._exit`` — the real
    ``BrokenProcessPool`` path, poisoning the pool exactly like a
    segfault. In-process executors have no survivable equivalent, so it
    degrades to ``crash`` (documented, not hidden).
``hang``
    Sleep ``hang_s`` seconds before running the shard — drives the
    deadline path: preemptive ``fut.result(timeout=)`` + pool rebuild
    under the process executor, post-hoc elapsed check in-process.
``corrupt``
    Return a mangled payload instead of running the shard — drives the
    coordinator-side payload validation (phase ``"validate"``).
``kill_coordinator``
    Consulted by :func:`repro.dist.sync.run_synced` at the round
    boundary *after* the round checkpoint is saved: raises
    :class:`CoordinatorKilled`, the seam the interrupt/resume
    determinism tests pull.

Service-level kinds (consumed by :mod:`repro.noc.server`, never matched
against worker dispatches)::

    {"kind": "reject_admission", "tenant": "t0", "request": None}
    {"kind": "slow_tenant",      "tenant": "t1", "wave": 2, "hang_s": 3.0}
    {"kind": "kill_server",      "wave": 1}

``reject_admission``
    Matched at submit time (``tenant``/``request`` — ``None`` matches
    any): the admission layer returns its structured rejection error,
    driving the client-visible error path without crafting a malformed
    problem.
``slow_tenant``
    Adds ``hang_s`` to the matched tenant's shard dispatches in wave
    ``wave`` — a slow tenant exercises per-request deadline degradation
    and must *not* stall other tenants' rounds.
``kill_server``
    Consulted by the service after wave ``wave``'s journal + checkpoints
    hit disk: raises :class:`ServerKilled`, the seam the service
    crash-recovery tests pull (mirror of ``kill_coordinator``).

The seeded random mode (``p_crash`` > 0) draws one uniform per
``(seed, worker_id, round, attempt)`` position via ``SeedSequence`` —
deterministic chaos, independent of dispatch order.
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import os
import time

import numpy as np

FAULT_KINDS = ("crash", "abort", "hang", "corrupt", "kill_coordinator")

#: kinds acted out by the service layer (repro.noc.server), not at the
#: worker boundary — FaultInjector.match() skips them like
#: kill_coordinator, so a mixed script threads through both layers.
SERVICE_FAULT_KINDS = ("reject_admission", "slow_tenant", "kill_server")

_WORKER_ONLY_KEYS = {"worker_id", "round", "attempt"}
_SERVICE_ONLY_KEYS = {"tenant", "request", "wave"}

#: payload returned by a "corrupt" fault — fails any structural
#: validation (it is not a RunResult / round payload), which is the point.
CORRUPT_PAYLOAD = {"__corrupt__": "injected payload corruption"}


class InjectedFault(RuntimeError):
    """The exception a scripted ``crash`` (or in-process ``abort``) raises."""


class CoordinatorKilled(RuntimeError):
    """Raised at a sync-round boundary by a ``kill_coordinator`` fault —
    stands in for the coordinator process dying after the round's
    checkpoint hit disk. Resume with ``StageDistConfig(resume=True)``."""


class ServerKilled(RuntimeError):
    """Raised at a service wave boundary by a ``kill_server`` fault —
    stands in for the server process dying after the wave's journal and
    per-request checkpoints hit disk. Restarting the service against the
    same journal directory resumes every in-flight request."""


def check_faults(faults) -> None:
    """Validate a fault list at config construction (not mid-run, after
    evaluation budget has been spent on the rounds before the typo)."""
    all_kinds = FAULT_KINDS + SERVICE_FAULT_KINDS
    for f in faults or ():
        if not isinstance(f, dict):
            raise ValueError(f"each fault must be a dict, got {type(f).__name__}")
        kind = f.get("kind")
        if kind not in all_kinds:
            raise ValueError(
                f"fault kind must be one of {all_kinds}, got {kind!r}")
        service = kind in SERVICE_FAULT_KINDS
        for key in ("round", "attempt", "wave"):
            if int(f.get(key, 0)) < 0:
                raise ValueError(f"fault {key} must be >= 0, got {f[key]}")
        if f.get("worker_id") is not None and int(f["worker_id"]) < 0:
            raise ValueError(
                f"fault worker_id must be >= 0 or None, got {f['worker_id']}")
        for key in ("tenant", "request"):
            if f.get(key) is not None and not isinstance(f[key], str):
                raise ValueError(
                    f"fault {key} must be a string or None, got {f[key]!r}")
        if float(f.get("hang_s", 0.0)) < 0:
            raise ValueError(f"fault hang_s must be >= 0, got {f['hang_s']}")
        allowed = {"kind", "hang_s"} | (
            _SERVICE_ONLY_KEYS if service else _WORKER_ONLY_KEYS)
        unknown = set(f) - allowed
        if unknown:
            raise ValueError(f"unknown fault keys {sorted(unknown)} in {f}")


@dataclasses.dataclass(frozen=True)
class FaultInjector:
    """Plain-data fault script: scripted point faults plus an optional
    seeded random crash rate. Picklable (crosses the spawn boundary) and
    JSON-trivial (lives inside ``StageDistConfig.faults``)."""

    faults: tuple = ()
    p_crash: float = 0.0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults or ()))
        check_faults(self.faults)
        if not 0.0 <= self.p_crash <= 1.0:
            raise ValueError(f"p_crash must be in [0, 1], got {self.p_crash}")

    # ------------------------------------------------------------ matching
    def match(self, worker_id: int, round_idx: int, attempt: int) -> dict | None:
        """First scripted fault targeting this (worker, round, attempt)
        dispatch, or a synthesized crash from the seeded random mode."""
        for f in self.faults:
            if (f["kind"] == "kill_coordinator"
                    or f["kind"] in SERVICE_FAULT_KINDS):
                continue
            wid = f.get("worker_id")
            if wid is not None and int(wid) != int(worker_id):
                continue
            if int(f.get("round", 0)) != int(round_idx):
                continue
            if int(f.get("attempt", 0)) != int(attempt):
                continue
            return f
        if self.p_crash > 0.0:
            ss = np.random.SeedSequence(
                [int(self.seed), int(worker_id), int(round_idx), int(attempt)])
            if np.random.default_rng(ss).random() < self.p_crash:
                return {"kind": "crash", "worker_id": int(worker_id),
                        "round": int(round_idx), "attempt": int(attempt)}
        return None

    def kills_coordinator(self, round_idx: int) -> bool:
        return any(f["kind"] == "kill_coordinator"
                   and int(f.get("round", 0)) == int(round_idx)
                   for f in self.faults)

    # --------------------------------------------------- service matching
    def _match_service(self, kind: str, tenant: str,
                       request: str) -> dict | None:
        for f in self.faults:
            if f["kind"] != kind:
                continue
            if f.get("tenant") is not None and f["tenant"] != str(tenant):
                continue
            if f.get("request") is not None and f["request"] != str(request):
                continue
            return f
        return None

    def rejects_admission(self, tenant: str, request: str) -> dict | None:
        """Scripted ``reject_admission`` targeting this submit (``tenant``
        / ``request`` keys, ``None`` = any), consulted by the service's
        admission layer before validation."""
        return self._match_service("reject_admission", tenant, request)

    def slow_tenant_delay(self, tenant: str, request: str,
                          wave: int) -> float:
        """Seconds of injected per-dispatch delay for this tenant's
        shards in service wave ``wave`` (0.0 when unmatched)."""
        for f in self.faults:
            if f["kind"] != "slow_tenant":
                continue
            if f.get("tenant") is not None and f["tenant"] != str(tenant):
                continue
            if f.get("request") is not None and f["request"] != str(request):
                continue
            if int(f.get("wave", 0)) != int(wave):
                continue
            return float(f.get("hang_s", 0.0))
        return 0.0

    def kills_server(self, wave: int) -> bool:
        """True when a ``kill_server`` fault targets service wave
        ``wave`` — consulted after the wave's journal/checkpoints save."""
        return any(f["kind"] == "kill_server"
                   and int(f.get("wave", 0)) == int(wave)
                   for f in self.faults)


def call_with_faults(injector: FaultInjector | None, worker_id: int,
                     round_idx: int, attempt: int, fn, args: tuple):
    """Run ``fn(*args)`` under the injector — THE worker-boundary wrapper.

    Module-level so the process executor can pickle it by reference and
    act faults out *inside the child* (an ``abort`` really breaks the
    pool; a ``hang`` really occupies a pool slot until the coordinator's
    deadline kills it). ``injector=None`` is the zero-overhead no-fault
    path: a plain ``fn(*args)``.
    """
    if injector is not None:
        act = injector.match(worker_id, round_idx, attempt)
        if act is not None:
            kind = act["kind"]
            where = (f"worker {worker_id}, round {round_idx}, "
                     f"attempt {attempt}")
            if kind == "crash":
                raise InjectedFault(f"injected crash ({where})")
            if kind == "abort":
                if multiprocessing.parent_process() is not None:
                    os._exit(134)  # hard child death -> BrokenProcessPool
                raise InjectedFault(
                    f"injected abort ({where}); in-process executors have "
                    "no survivable hard-death, degraded to crash")
            if kind == "corrupt":
                return dict(CORRUPT_PAYLOAD)
            if kind == "hang":
                time.sleep(float(act.get("hang_s", 0.0)))
    return fn(*args)
