"""Deterministic fault injection for the distributed layer (DESIGN.md §9).

Every degradation path the resilience substrate promises — deadline
trips, bounded retries, spawn-pool rebuilds, corrupt-payload rejection,
mid-run coordinator death — is pinned by tests *through this module*
rather than asserted in prose. A :class:`FaultInjector` is plain data
(a tuple of fault dicts plus an optional seeded random crash rate), so
it pickles into spawn children and serializes into a
``StageDistConfig`` / ``RunResult.config`` unchanged.

Fault dicts::

    {"kind": "crash",   "worker_id": 1, "round": 0, "attempt": 0}
    {"kind": "abort",   "worker_id": 1, "round": 0, "attempt": 0}
    {"kind": "hang",    "worker_id": 2, "round": 1, "attempt": 0,
     "hang_s": 3.0}
    {"kind": "corrupt", "worker_id": 0, "round": 0, "attempt": 0}
    {"kind": "kill_coordinator", "round": 1}

``worker_id: None`` (or omitted) matches every worker; ``round`` and
``attempt`` default to 0 and must match exactly — which is what makes a
fault a *scripted point event*: the retry of a crashed attempt (a new
``attempt``) runs clean unless another fault targets it.

Kinds:

``crash``
    Raise :class:`InjectedFault` in place of the shard function — an
    ordinary worker exception (retriable, recorded with traceback).
``abort``
    A *hard* death. In a spawn child: ``os._exit`` — the real
    ``BrokenProcessPool`` path, poisoning the pool exactly like a
    segfault. In-process executors have no survivable equivalent, so it
    degrades to ``crash`` (documented, not hidden).
``hang``
    Sleep ``hang_s`` seconds before running the shard — drives the
    deadline path: preemptive ``fut.result(timeout=)`` + pool rebuild
    under the process executor, post-hoc elapsed check in-process.
``corrupt``
    Return a mangled payload instead of running the shard — drives the
    coordinator-side payload validation (phase ``"validate"``).
``kill_coordinator``
    Consulted by :func:`repro.dist.sync.run_synced` at the round
    boundary *after* the round checkpoint is saved: raises
    :class:`CoordinatorKilled`, the seam the interrupt/resume
    determinism tests pull.

The seeded random mode (``p_crash`` > 0) draws one uniform per
``(seed, worker_id, round, attempt)`` position via ``SeedSequence`` —
deterministic chaos, independent of dispatch order.
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import os
import time

import numpy as np

FAULT_KINDS = ("crash", "abort", "hang", "corrupt", "kill_coordinator")

#: payload returned by a "corrupt" fault — fails any structural
#: validation (it is not a RunResult / round payload), which is the point.
CORRUPT_PAYLOAD = {"__corrupt__": "injected payload corruption"}


class InjectedFault(RuntimeError):
    """The exception a scripted ``crash`` (or in-process ``abort``) raises."""


class CoordinatorKilled(RuntimeError):
    """Raised at a sync-round boundary by a ``kill_coordinator`` fault —
    stands in for the coordinator process dying after the round's
    checkpoint hit disk. Resume with ``StageDistConfig(resume=True)``."""


def check_faults(faults) -> None:
    """Validate a fault list at config construction (not mid-run, after
    evaluation budget has been spent on the rounds before the typo)."""
    for f in faults or ():
        if not isinstance(f, dict):
            raise ValueError(f"each fault must be a dict, got {type(f).__name__}")
        kind = f.get("kind")
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {kind!r}")
        for key in ("round", "attempt"):
            if int(f.get(key, 0)) < 0:
                raise ValueError(f"fault {key} must be >= 0, got {f[key]}")
        if f.get("worker_id") is not None and int(f["worker_id"]) < 0:
            raise ValueError(
                f"fault worker_id must be >= 0 or None, got {f['worker_id']}")
        if float(f.get("hang_s", 0.0)) < 0:
            raise ValueError(f"fault hang_s must be >= 0, got {f['hang_s']}")
        unknown = set(f) - {"kind", "worker_id", "round", "attempt", "hang_s"}
        if unknown:
            raise ValueError(f"unknown fault keys {sorted(unknown)} in {f}")


@dataclasses.dataclass(frozen=True)
class FaultInjector:
    """Plain-data fault script: scripted point faults plus an optional
    seeded random crash rate. Picklable (crosses the spawn boundary) and
    JSON-trivial (lives inside ``StageDistConfig.faults``)."""

    faults: tuple = ()
    p_crash: float = 0.0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults or ()))
        check_faults(self.faults)
        if not 0.0 <= self.p_crash <= 1.0:
            raise ValueError(f"p_crash must be in [0, 1], got {self.p_crash}")

    # ------------------------------------------------------------ matching
    def match(self, worker_id: int, round_idx: int, attempt: int) -> dict | None:
        """First scripted fault targeting this (worker, round, attempt)
        dispatch, or a synthesized crash from the seeded random mode."""
        for f in self.faults:
            if f["kind"] == "kill_coordinator":
                continue
            wid = f.get("worker_id")
            if wid is not None and int(wid) != int(worker_id):
                continue
            if int(f.get("round", 0)) != int(round_idx):
                continue
            if int(f.get("attempt", 0)) != int(attempt):
                continue
            return f
        if self.p_crash > 0.0:
            ss = np.random.SeedSequence(
                [int(self.seed), int(worker_id), int(round_idx), int(attempt)])
            if np.random.default_rng(ss).random() < self.p_crash:
                return {"kind": "crash", "worker_id": int(worker_id),
                        "round": int(round_idx), "attempt": int(attempt)}
        return None

    def kills_coordinator(self, round_idx: int) -> bool:
        return any(f["kind"] == "kill_coordinator"
                   and int(f.get("round", 0)) == int(round_idx)
                   for f in self.faults)


def call_with_faults(injector: FaultInjector | None, worker_id: int,
                     round_idx: int, attempt: int, fn, args: tuple):
    """Run ``fn(*args)`` under the injector — THE worker-boundary wrapper.

    Module-level so the process executor can pickle it by reference and
    act faults out *inside the child* (an ``abort`` really breaks the
    pool; a ``hang`` really occupies a pool slot until the coordinator's
    deadline kills it). ``injector=None`` is the zero-overhead no-fault
    path: a plain ``fn(*args)``.
    """
    if injector is not None:
        act = injector.match(worker_id, round_idx, attempt)
        if act is not None:
            kind = act["kind"]
            where = (f"worker {worker_id}, round {round_idx}, "
                     f"attempt {attempt}")
            if kind == "crash":
                raise InjectedFault(f"injected crash ({where})")
            if kind == "abort":
                if multiprocessing.parent_process() is not None:
                    os._exit(134)  # hard child death -> BrokenProcessPool
                raise InjectedFault(
                    f"injected abort ({where}); in-process executors have "
                    "no survivable hard-death, degraded to crash")
            if kind == "corrupt":
                return dict(CORRUPT_PAYLOAD)
            if kind == "hang":
                time.sleep(float(act.get("hang_s", 0.0)))
    return fn(*args)
