"""``repro.dist`` — distributed multi-start MOO-STAGE (DESIGN.md §8).

Shards one global ``(NocProblem, Budget)`` across W workers
(:mod:`~repro.dist.plan`), executes each shard as a pure JSON-boundary
function (:mod:`~repro.dist.worker` — in-process, process pool, or
per-JAX-device), merges the worker ``RunResult``s by worker-order-
independent Pareto union (:mod:`~repro.dist.merge`), and optionally pools
surrogate training rows between rounds (:mod:`~repro.dist.sync`).

Entry point: :func:`run_dist` — registered in the optimizer registry as
``"stage_dist"`` (``repro.noc run --optimizer stage_dist --workers K``).

Fault tolerance (DESIGN.md §9): every failed dispatch attempt — worker
exception, deadline trip, pool breakage, rejected payload — is recorded
as a structured record in the merged result's
``extra["worker_failures"]``; shards get bounded, reseeded retries; the
spawn pool is rebuilt on breakage; synced runs can checkpoint coordinator
state each round and resume after a crash. The coordinator returns the
Pareto union of the survivors; only a run with *zero* surviving workers
raises.
"""

from __future__ import annotations

import dataclasses
import time

from repro.noc.api import Budget, NocProblem, RunResult

from .ckpt import RoundCheckpointer
from .faults import (CORRUPT_PAYLOAD, SERVICE_FAULT_KINDS, CoordinatorKilled,
                     FaultInjector, InjectedFault, ServerKilled, check_faults)
from .merge import merge_results, merged_pareto
from .plan import (Shard, plan_shards, retry_seed, round_seed, spawn_seeds,
                   split_evenly)
from .state import SyncRunState
from .sync import n_rounds, run_synced, validate_round_payload
from .worker import (EXECUTORS, ShardPool, check_executor, execute_shards,
                     run_shard, shard_pool)

__all__ = [
    "CORRUPT_PAYLOAD", "CoordinatorKilled", "EXECUTORS", "FaultInjector",
    "InjectedFault", "RoundCheckpointer", "SERVICE_FAULT_KINDS",
    "ServerKilled", "Shard", "ShardPool", "SyncRunState", "check_executor",
    "check_faults", "execute_shards", "merge_results", "merged_pareto",
    "n_rounds", "package_dist_result", "plan_shards", "retry_seed",
    "round_seed", "run_dist", "run_shard", "run_synced", "shard_pool",
    "spawn_seeds", "split_evenly", "validate_round_payload",
]


def _stage_config_json(cfg) -> dict:
    """The worker-side ``StageBatchConfig`` overrides carried by a
    :class:`~repro.noc.optimizers.StageDistConfig`."""
    return {
        "n_starts": cfg.n_starts, "iters_max": cfg.iters_max,
        "n_swaps": cfg.n_swaps, "n_link_moves": cfg.n_link_moves,
        "max_local_steps": cfg.max_local_steps,
        "forest_kwargs": cfg.forest_kwargs,
        "forest_backend": cfg.forest_backend,
        "meta_backend": cfg.meta_backend,
    }


def run_dist(problem: NocProblem, budget: Budget, cfg) -> RunResult:
    """Coordinate one distributed multi-start run; returns the merged
    :class:`RunResult` (optimizer ``"stage_dist"``).

    ``cfg`` is a :class:`repro.noc.optimizers.StageDistConfig` (read by
    attribute — this module never imports the registry, the registry
    imports us lazily). With ``sync_every == 0`` every worker runs its
    whole shard independently (one ``stage_batch`` registry run each);
    with ``sync_every > 0`` the run proceeds in surrogate-sync rounds
    (see :mod:`repro.dist.sync`).

    The W=1 ``serial`` run is the identity: one shard carrying the root
    seed and the full budget through the same ``api.run`` path a direct
    registry ``stage_batch`` call takes — byte-identical payload, pinned
    by tests/test_dist.py.
    """
    from . import worker as _worker  # attribute lookup at call time so
    #                                  monkeypatched run_shard is honored

    check_executor(cfg.executor)
    t0 = time.perf_counter()
    shards = plan_shards(problem, budget, cfg.n_workers)

    dist_info: dict = {"pool_rebuilds": 0, "resumed_from_round": None,
                       "checkpoint": None}
    if cfg.sync_every > 0:
        results, failure_rows, dist_info = run_synced(problem, budget, cfg)
    else:
        stage_cfg = _stage_config_json(cfg)
        tasks = [(s.problem.to_json(), s.budget.to_json(), s.budget.seed,
                  stage_cfg, s.worker_id) for s in shards]
        faults = tuple(getattr(cfg, "faults", ()) or ())
        injector = FaultInjector(faults=faults) if faults else None

        def _reseed(orig_args, attempt):
            # Same shard, fresh trajectory: only the dispatch seed moves.
            return (orig_args[:2] + (retry_seed(orig_args[2], attempt),)
                    + orig_args[3:])

        with _worker.shard_pool(cfg.executor, cfg.n_workers) as pool:
            raw, failures = _worker.execute_shards(
                _worker.run_shard, tasks, cfg.executor, pool=pool,
                meta=[(s.worker_id, 0) for s in shards],
                timeout_s=getattr(cfg, "shard_timeout_s", None),
                max_retries=int(getattr(cfg, "max_retries", 0) or 0),
                backoff_s=float(getattr(cfg, "retry_backoff_s", 0.0) or 0.0),
                retry_args=_reseed, injector=injector,
                validate=_worker.validate_result_payload)
            if isinstance(pool, _worker.ShardPool):
                dist_info["pool_rebuilds"] = pool.rebuilds
        results = [RunResult.from_json(raw[i]) for i in sorted(raw)]
        failure_rows = [rec for i in sorted(failures)
                        for rec in failures[i]]

    return package_dist_result(
        problem, budget, cfg, results, failure_rows, dist_info,
        [s.budget.seed for s in shards], time.perf_counter() - t0)


def package_dist_result(problem: NocProblem, budget: Budget, cfg,
                        results: list[RunResult], failure_rows: list[dict],
                        dist_info: dict, worker_seeds: list[int],
                        wall_s: float, *, partial: bool = False) -> RunResult:
    """Merge surviving worker results into the final ``"stage_dist"``
    :class:`RunResult` — the packaging tail shared by :func:`run_dist`
    and the request state machines of :mod:`repro.noc.server`.

    ``partial=True`` is the graceful-degradation path (deadline trip or
    cancellation): instead of raising when nothing survived, it returns
    the best-so-far front — possibly empty — flagged
    ``extra["partial"] = True`` and ``exhausted=True`` (the budget was
    truncated from outside, same contract as running it dry)."""
    import numpy as np

    if not results:
        if not partial:
            raise RuntimeError(
                f"all {cfg.n_workers} workers failed: {failure_rows}")
        merged = RunResult(
            optimizer="stage_dist", problem=problem.to_json(),
            budget=budget.to_json(), config=dataclasses.asdict(cfg),
            obj_idx=tuple(problem.obj_idx), designs=[],
            objs=np.zeros((0, len(problem.obj_idx))),
            n_evals=0, n_calls=0, wall_s=0.0, history=np.zeros((0, 4)),
            extra={"phv": 0.0}, exhausted=True)
    elif len(results) > 1:
        # The merged set's PHV is recomputed under the problem's own mesh
        # anchor — one coordinator-side evaluation, outside the (fully
        # worker-consumed) search budget.
        ctx = problem.context(problem.evaluator())
        merged = merge_results(results, ctx=ctx)
    else:
        merged = merge_results(results)   # identity passthrough (W=1 pin)

    extra = dict(merged.extra)
    extra["n_workers"] = int(cfg.n_workers)
    extra["executor"] = cfg.executor
    extra["sync_every"] = int(cfg.sync_every)
    extra["worker_seeds"] = list(worker_seeds)
    extra["worker_failures"] = failure_rows
    extra["pool_rebuilds"] = dist_info.get("pool_rebuilds", 0)
    extra["resumed_from_round"] = dist_info.get("resumed_from_round")
    extra["checkpoint"] = dist_info.get("checkpoint")
    exhausted = merged.exhausted
    if budget.max_evals is not None and merged.n_evals >= budget.max_evals:
        exhausted = True
    if budget.max_calls is not None and merged.n_calls >= budget.max_calls:
        exhausted = True
    if partial:
        extra["partial"] = True
        exhausted = True

    return dataclasses.replace(
        merged,
        optimizer="stage_dist",
        problem=problem.to_json(),
        budget=budget.to_json(),
        config=dataclasses.asdict(cfg),
        wall_s=wall_s,
        extra=extra,
        exhausted=exhausted,
    )
