"""``repro.dist`` — distributed multi-start MOO-STAGE (DESIGN.md §8).

Shards one global ``(NocProblem, Budget)`` across W workers
(:mod:`~repro.dist.plan`), executes each shard as a pure JSON-boundary
function (:mod:`~repro.dist.worker` — in-process, process pool, or
per-JAX-device), merges the worker ``RunResult``s by worker-order-
independent Pareto union (:mod:`~repro.dist.merge`), and optionally pools
surrogate training rows between rounds (:mod:`~repro.dist.sync`).

Entry point: :func:`run_dist` — registered in the optimizer registry as
``"stage_dist"`` (``repro.noc run --optimizer stage_dist --workers K``).

Fault tolerance: a worker that raises is recorded in the merged result's
``extra["worker_failures"]`` and the coordinator returns the Pareto union
of the survivors; only a run with *zero* surviving workers raises.
"""

from __future__ import annotations

import dataclasses
import time

from repro.noc.api import Budget, NocProblem, RunResult

from .merge import merge_results, merged_pareto
from .plan import Shard, plan_shards, round_seed, spawn_seeds, split_evenly
from .sync import n_rounds, run_synced
from .worker import EXECUTORS, check_executor, execute_shards, run_shard

__all__ = [
    "EXECUTORS", "Shard", "check_executor", "execute_shards",
    "merge_results", "merged_pareto", "n_rounds", "plan_shards",
    "round_seed", "run_dist", "run_shard", "run_synced", "spawn_seeds",
    "split_evenly",
]


def _stage_config_json(cfg) -> dict:
    """The worker-side ``StageBatchConfig`` overrides carried by a
    :class:`~repro.noc.optimizers.StageDistConfig`."""
    return {
        "n_starts": cfg.n_starts, "iters_max": cfg.iters_max,
        "n_swaps": cfg.n_swaps, "n_link_moves": cfg.n_link_moves,
        "max_local_steps": cfg.max_local_steps,
        "forest_kwargs": cfg.forest_kwargs,
        "forest_backend": cfg.forest_backend,
    }


def run_dist(problem: NocProblem, budget: Budget, cfg) -> RunResult:
    """Coordinate one distributed multi-start run; returns the merged
    :class:`RunResult` (optimizer ``"stage_dist"``).

    ``cfg`` is a :class:`repro.noc.optimizers.StageDistConfig` (read by
    attribute — this module never imports the registry, the registry
    imports us lazily). With ``sync_every == 0`` every worker runs its
    whole shard independently (one ``stage_batch`` registry run each);
    with ``sync_every > 0`` the run proceeds in surrogate-sync rounds
    (see :mod:`repro.dist.sync`).

    The W=1 ``serial`` run is the identity: one shard carrying the root
    seed and the full budget through the same ``api.run`` path a direct
    registry ``stage_batch`` call takes — byte-identical payload, pinned
    by tests/test_dist.py.
    """
    from . import worker as _worker  # attribute lookup at call time so
    #                                  monkeypatched run_shard is honored

    check_executor(cfg.executor)
    t0 = time.perf_counter()
    shards = plan_shards(problem, budget, cfg.n_workers)

    if cfg.sync_every > 0:
        results, failure_rows = run_synced(problem, budget, cfg)
    else:
        stage_cfg = _stage_config_json(cfg)
        tasks = [(s.problem.to_json(), s.budget.to_json(), s.budget.seed,
                  stage_cfg, s.worker_id) for s in shards]
        raw, failures = _worker.execute_shards(
            _worker.run_shard, tasks, cfg.executor)
        results = [RunResult.from_json(raw[i]) for i in sorted(raw)]
        failure_rows = [[shards[i].worker_id, 0, msg]
                        for i, msg in sorted(failures.items())]

    if not results:
        raise RuntimeError(
            f"all {cfg.n_workers} workers failed: {failure_rows}")

    if len(results) > 1:
        # The merged set's PHV is recomputed under the problem's own mesh
        # anchor — one coordinator-side evaluation, outside the (fully
        # worker-consumed) search budget.
        ctx = problem.context(problem.evaluator())
        merged = merge_results(results, ctx=ctx)
    else:
        merged = merge_results(results)   # identity passthrough (W=1 pin)

    extra = dict(merged.extra)
    extra["n_workers"] = int(cfg.n_workers)
    extra["executor"] = cfg.executor
    extra["sync_every"] = int(cfg.sync_every)
    extra["worker_seeds"] = [s.budget.seed for s in shards]
    extra["worker_failures"] = failure_rows
    exhausted = merged.exhausted
    if budget.max_evals is not None and merged.n_evals >= budget.max_evals:
        exhausted = True
    if budget.max_calls is not None and merged.n_calls >= budget.max_calls:
        exhausted = True

    return dataclasses.replace(
        merged,
        optimizer="stage_dist",
        problem=problem.to_json(),
        budget=budget.to_json(),
        config=dataclasses.asdict(cfg),
        wall_s=time.perf_counter() - t0,
        extra=extra,
        exhausted=exhausted,
    )
