"""Logical-axis sharding policy for the launch/train/serve substrate.

Models annotate activations with *logical* axis names
(``pshard(x, ("batch", "seq", "embed"))``); this module owns the single
mapping from those names to physical mesh axes, plus the parameter / batch /
KV-cache PartitionSpec builders every jit entry point shards with.

Everything funnels through :func:`_fit`, which enforces the two invariants a
GSPMD spec must satisfy: a mesh axis is used at most once per spec, and a
tensor dim is only sharded when the mesh-axis product divides it (non-divisible
dims silently fall back to replication — the whisper vocab of 51865 shards
over nothing on a 16-wide model axis, by design, not by crash).

The module is import-cheap: no jax device state is touched at import time
(``repro.dist`` worker processes import this package before configuring
their backend).
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: logical axis -> mesh axes tried in order (absent mesh axes are skipped).
#: "batch" spans the full data-parallel extent (pod x data on the 2-pod
#: mesh); tensor-parallel logical axes all map to "model".
_DEFAULT_LOGICAL = (
    ("batch", ("pod", "data")),
    ("seq", ()),
    ("embed", ()),
    ("mlp", ("model",)),
    ("vocab", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("heads_flat", ("model",)),
    ("experts", ("model",)),
)


@dataclasses.dataclass(frozen=True)
class Policy:
    """One sharding policy = microbatching + FSDP axes + the logical map."""

    microbatches: int = 1
    grad_compress: bool = False
    #: mesh axes parameters are FSDP-sharded over ("" = replicate weights).
    fsdp_axes: tuple = ("data",)
    #: ((logical_name, (mesh_axis, ...)), ...) — override via with_logical().
    logical: tuple = _DEFAULT_LOGICAL

    def axes_for(self, name) -> tuple:
        if name is None:
            return ()
        for key, axes in self.logical:
            if key == name:
                return tuple(axes)
        return ()

    def with_logical(self, **overrides) -> "Policy":
        """Replace logical-axis mappings, e.g. ``with_logical(seq=("model",))``
        for Megatron-style sequence sharding or ``with_logical(experts=())``
        to replicate expert weights."""
        table = dict(self.logical)
        for key, axes in overrides.items():
            table[key] = tuple(axes)
        return dataclasses.replace(self, logical=tuple(table.items()))


def default_policy_for(kind: str) -> Policy:
    """Registry defaults per step kind (the dry-run / roofline cells)."""
    if kind == "train":
        return Policy(microbatches=16)
    # Inference: FSDP would all-gather weights every step — replicate
    # instead and lean on TP; no microbatching.
    return Policy(microbatches=1, fsdp_axes=())


# --------------------------------------------------------------------- fit
def _fit(mesh: Mesh, dim: int, axes, used: set) -> tuple:
    """Longest usable prefix of ``axes`` that legally shards a dim of size
    ``dim``: drops axes missing from the mesh or already used in this spec,
    then backs off from the right until the axis-size product divides
    ``dim``. Returns () (replicate) when nothing fits. Mutates ``used``."""
    avail = [a for a in axes if a in mesh.shape and a not in used]
    while avail:
        prod = 1
        for a in avail:
            prod *= mesh.shape[a]
        if prod > 1 and dim % prod == 0:
            used.update(avail)
            return tuple(avail)
        avail.pop()
    return ()


def _entry(axes: tuple):
    """PartitionSpec entry for a fitted axis tuple."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def spec_from_logical(mesh: Mesh, policy: Policy, shape, logical) -> P:
    """Build a PartitionSpec for ``shape`` from per-dim logical names."""
    used: set = set()
    parts = [_entry(_fit(mesh, shape[i], policy.axes_for(name), used))
             for i, name in enumerate(logical)]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def named(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (jit in/out_shardings)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def activation_shard_fn(mesh: Mesh, policy: Policy):
    """The callback installed via ``models.common.activation_sharding``:
    maps a logical annotation to ``with_sharding_constraint``."""

    def shard(x, logical):
        spec = spec_from_logical(mesh, policy, x.shape, logical)
        if not any(spec):
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


# ----------------------------------------------------------------- params
#: weight-name -> (tp_logical, tp_dim_from_right). TP goes on the dim the
#: matmul contracts *out of* (column-parallel for up-projections, row-
#: parallel for down-projections), so forward needs no weight collectives
#: beyond the FSDP all-gather.
_TP_RULES = {
    "wq": ("heads_flat", 1), "wk": ("kv_heads", 1), "wv": ("kv_heads", 1),
    "wo": ("heads_flat", 2),
    "w1": ("mlp", 1), "w3": ("mlp", 1), "w2": ("mlp", 2),
    "in_proj": ("heads_flat", 1), "out_proj": ("heads_flat", 2),
    "embed": ("vocab", 2), "head": ("vocab", 1),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _param_spec(mesh: Mesh, policy: Policy, path, leaf) -> P:
    name = _leaf_name(path)
    shape = leaf.shape
    nd = len(shape)
    stacked = any(getattr(e, "key", None) == "layers" for e in path)
    parts = [None] * nd
    used: set = set()
    if nd >= 2:
        rule = _TP_RULES.get(name)
        # MoE expert weights carry a leading experts dim: (e, d, f) or
        # stacked (L, e, d, f) — EP-shard the experts dim instead of TP.
        if rule and name in ("w1", "w2", "w3") and nd - int(stacked) == 3:
            e_dim = nd - 3
            parts[e_dim] = _entry(
                _fit(mesh, shape[e_dim], policy.axes_for("experts"), used))
        elif rule:
            logical, from_right = rule
            d = nd - from_right
            if d >= int(stacked):  # never shard the scan-stacked layer dim
                parts[d] = _entry(
                    _fit(mesh, shape[d], policy.axes_for(logical), used))
        # FSDP: shard the largest still-replicated non-layer dim over the
        # data axes (ZeRO-3 style; all-gathered around use).
        if policy.fsdp_axes:
            cand = [i for i in range(int(stacked), nd) if parts[i] is None]
            cand.sort(key=lambda i: -shape[i])
            for i in cand:
                axes = _fit(mesh, shape[i], policy.fsdp_axes, used)
                if axes:
                    parts[i] = _entry(axes)
                    break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_specs(mesh: Mesh, policy: Policy, params_like):
    """PartitionSpec tree for a parameter pytree (abstract or concrete)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _param_spec(mesh, policy, path, leaf), params_like)


# ------------------------------------------------------------------ batch
def _batch_spec(mesh: Mesh, policy: Policy, leaf) -> P:
    shape = leaf.shape
    if not shape:
        return P()
    logical = ["batch"] + ["seq" if i == 1 else None
                           for i in range(1, len(shape))]
    return spec_from_logical(mesh, policy, shape, logical)


def batch_specs(mesh: Mesh, policy: Policy, batch_like):
    """Batch pytree specs: dim 0 over the data extent, dim 1 over the seq
    axes (replicated unless the policy opts into sequence sharding)."""
    return jax.tree.map(lambda leaf: _batch_spec(mesh, policy, leaf),
                        batch_like)


def cache_specs(mesh: Mesh, policy: Policy, cfg, cache_like):
    """KV/SSM cache specs: leaves are layer-stacked ``(L, B, ...)`` — layer
    dim replicated (it is lax.scan's carry axis), batch over the data
    extent, and the kv-head dim (dim -2 of 4+-d attention caches) over the
    tensor-parallel axes."""

    def spec(leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        if nd < 2:
            return P()  # pos scalar etc.
        logical = [None] * nd
        logical[1] = "batch"
        if nd >= 4:
            logical[nd - 2] = "kv_heads"
        return spec_from_logical(mesh, policy, shape, logical)

    return jax.tree.map(spec, cache_like)
