"""Pareto-union merge of worker :class:`~repro.noc.api.RunResult`s.

The coordinator's correctness contract (DESIGN.md §8): the merged result
must be a pure function of the *set* of worker results, never of the order
they arrived in — process pools complete out of order, and a merge that
depended on completion order would make distributed runs unreproducible.

Three mechanisms deliver that:

* **Canonical pre-sort** (``ParetoSet.canonical_union``) — all (design,
  objectives) pairs from all inputs are deduplicated and sorted by
  (objective row, design key) before the non-domination mask runs.
  ``pareto_mask`` keeps the *first* of exact-duplicate rows, so without
  the pre-sort the surviving design among tied rows would depend on
  input order.
* **Worker-id-ordered histories** — convergence histories concatenate in
  worker-id order (not arrival order), with per-worker spans recorded in
  ``extra["history_spans"]`` as ``[worker_id, start, stop]`` rows. A
  result that is itself a merge carries its spans through (offset), so
  nested merges flatten associatively.
* **Singleton passthrough** — ``merge_results([r])`` returns ``r``'s
  payload unchanged (idempotence; also what pins the W=1 serial run to
  byte-identical ``stage_batch`` output).

Accounting is summed (``n_evals``/``n_calls``), ``wall_s`` is the max
(workers run concurrently), and ``exhausted`` is the OR — one worker
tripping its shard budget marks the merged run exhausted.

Header fields (``optimizer``/``problem``/``budget``/``config``) are taken
from the lowest-worker-id input (order-independent, like everything
else); the coordinator that called the merge owns them and overwrites
them with the global run's identity.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.local_search import ParetoSet
from repro.core.pareto import PhvContext
from repro.noc.api import RunResult


def _worker_spans(res: RunResult) -> list[list[int]]:
    """History spans of one input: carried through from a previous merge
    if present, else one span covering the whole history, tagged with the
    result's ``extra["worker_id"]``. A multi-input merge REQUIRES the
    tag — falling back to list position would make the merged history
    depend on arrival order, the exact nondeterminism this module
    exists to prevent."""
    spans = res.extra.get("history_spans")
    if spans:
        return [[int(w), int(a), int(b)] for w, a, b in spans]
    if "worker_id" not in res.extra:
        raise ValueError(
            "merge_results inputs must carry extra['worker_id'] (or "
            "history_spans from a previous merge); untagged results would "
            "make the merged history depend on input order")
    return [[int(res.extra["worker_id"]), 0,
             int(np.asarray(res.history).shape[0])]]


def merge_results(results: list[RunResult],
                  ctx: PhvContext | None = None) -> RunResult:
    """Merge worker ``RunResult``s by Pareto union.

    Deterministic in the *set* of inputs: any permutation of ``results``
    yields bit-identical merged designs, objectives, history, and
    accounting. ``ctx`` (optional) recomputes the merged set's PHV into
    ``extra["phv"]``; without it the PHV diagnostic is omitted (workers'
    own PHVs are per-shard, not comparable to the union's).
    """
    if not results:
        raise ValueError("merge_results needs at least one RunResult")
    if len(results) == 1:
        return dataclasses.replace(results[0])

    obj_idx = results[0].obj_idx
    problem0 = json.dumps(results[0].problem, sort_keys=True)
    for r in results[1:]:
        if r.obj_idx != obj_idx:
            raise ValueError(
                f"cannot merge results with different objective subsets: "
                f"{r.obj_idx} vs {obj_idx}")
        if json.dumps(r.problem, sort_keys=True) != problem0:
            raise ValueError("cannot merge results of different problems")

    # ---------------------------------------------------- Pareto union
    # ParetoSet.canonical_union dedups identical (objectives, design)
    # pairs across inputs (merging overlapping results is idempotent) and
    # canonical-sorts before the non-domination mask so its keep-first
    # tie-breaking is order-independent.
    union = ParetoSet.canonical_union(
        [r.pareto_set() for r in results], obj_idx)
    designs, objs = union.designs, union.objs

    # ------------------------------------------ histories, tagged + sorted
    tagged = [(tuple(w for w, _, _ in _worker_spans(r)), r)
              for r in results]
    flat = [w for ws, _ in tagged for w in ws]
    if len(flat) != len(set(flat)):
        raise ValueError(
            f"worker ids must be unique across merged results, got {flat}")
    tagged.sort(key=lambda t: t[0])
    hist_parts: list[np.ndarray] = []
    spans: list[list[int]] = []
    offset = 0
    for _, r in tagged:
        h = np.asarray(r.history, dtype=np.float64).reshape(-1, 4)
        for w, a, b in _worker_spans(r):
            spans.append([w, offset + a, offset + b])
        hist_parts.append(h)
        offset += h.shape[0]
    history = (np.concatenate(hist_parts, axis=0) if hist_parts
               else np.zeros((0, 4)))

    # --------------------------------------------------------- diagnostics
    workers = [
        {"worker_id": w[0], "optimizer": r.optimizer,
         "n_evals": int(r.n_evals), "n_calls": int(r.n_calls),
         "pareto_size": len(r.designs), "exhausted": bool(r.exhausted),
         "phv": float(r.extra.get("phv", float("nan")))}
        for w, r in tagged
    ]
    extra: dict = {"history_spans": spans, "workers": workers}
    if ctx is not None:
        extra["phv"] = ctx.phv(objs)

    # Header fields come from the lowest-worker-id input (not list
    # position — the merge must be a pure function of the input *set*);
    # a coordinator overwrites them with the global run's identity anyway.
    head = tagged[0][1]
    return RunResult(
        optimizer=head.optimizer,
        problem=head.problem,
        budget=head.budget,
        config=head.config,
        obj_idx=obj_idx,
        designs=designs,
        objs=objs,
        n_evals=sum(int(r.n_evals) for r in results),
        n_calls=sum(int(r.n_calls) for r in results),
        wall_s=max(float(r.wall_s) for r in results),
        history=history,
        extra=extra,
        exhausted=any(bool(r.exhausted) for r in results),
    )


def merged_pareto(results: list[RunResult]) -> ParetoSet:
    """The merged Pareto set alone (no accounting) — convenience for
    callers that only need the union front. Pure canonical union: works
    on untagged results too (no history to order)."""
    if not results:
        raise ValueError("merged_pareto needs at least one RunResult")
    return ParetoSet.canonical_union(
        [r.pareto_set() for r in results], results[0].obj_idx)
