"""Per-round surrogate + front sync across workers (DESIGN.md §8–§9).

``stage_batch`` shares one surrogate and one global front across its K
in-process chains; this module generalizes both tricks across
*processes*. The run is cut into rounds of ``sync_every`` STAGE
iterations:

1. every worker runs ``sync_every`` iterations of its chains
   (:func:`repro.dist.worker.run_shard_round`) and checkpoints the
   ``(X, y)`` surrogate training rows its trajectories produced plus the
   designs its chains would restart from;
2. the coordinator pools all workers' rows into one shared training set
   and all workers' Pareto sets into one pooled front;
3. the next round resumes every worker's chains from their checkpointed
   starts with the pooled rows fitted into a warm surrogate
   (``stage_batch(train_init=...)``) and the pooled front seeded as the
   global set (``global_init=``) — each worker's meta-search is steered
   by what *every* worker learned (DAgger across the fleet), and each
   chain maximizes *marginal* PHV over the fleet's whole front instead
   of re-finding another worker's tradeoffs.

Budget accounting is cumulative and remainder-exact: the global
``max_evals`` splits across workers, each worker's share splits across
its first ``ceil(iters_max / sync_every)`` rounds, and round r may spend
up to its cumulative slice minus what the worker actually spent — search
drivers check budgets *before* a dispatch, so charging cumulatively
bounds a worker at shard budget + one dispatch total instead of + one
dispatch per round, and hands budget an early-converged round left to
the rounds after it. Once the planned rounds are done, the coordinator
keeps dispatching **extra rounds** (fresh ``sync_every``-iteration
resumptions) while eval budget remains and the previous round still made
search progress — the eval budget is the contract, iteration counts are
per-round structure; without this, every worker that converges in the
final planned round would strand its leftover budget. Each round runs on
a fresh per-round evaluator (process workers cannot carry evaluator
state between rounds), so each round's mesh-anchor evaluation is paid
inside its slice, like any other evaluation.

All of the per-round planning/pooling state lives in
:class:`repro.dist.state.SyncRunState` (build_round / absorb_round /
snapshot / restore) — :func:`run_synced` is the single-machine driver of
that protocol, and :mod:`repro.noc.server` drives many machines over one
shared fleet. The refactor is behavior-preserving: the PR 5/6
determinism and interrupt/resume pins hold bit-for-bit.

Resilience (DESIGN.md §9): dispatches carry per-shard deadlines and
bounded reseeded retries (``cfg.shard_timeout_s`` / ``max_retries`` /
``retry_backoff_s`` threaded into :func:`repro.dist.worker.
execute_shards`); payloads are structurally validated before pooling; a
worker whose attempts are exhausted in round r is dropped from later
rounds (its earlier rounds' results still merge); every failed attempt
is reported as a structured record. With ``cfg.checkpoint_dir`` set, the
coordinator persists its complete state after every round
(:class:`repro.dist.ckpt.RoundCheckpointer`, atomic tmp → fsync →
rename) and ``cfg.resume=True`` restores it — an interrupted-then-
resumed run is byte-identical to the uninterrupted one. Scripted faults
(``cfg.faults``) exercise all of it deterministically.
"""

from __future__ import annotations

from repro.noc.api import Budget, NocProblem, RunResult

from .ckpt import RoundCheckpointer
from .faults import CoordinatorKilled, FaultInjector
# Re-exported for back-compat: these lived here before the state-machine
# extraction and are part of the module's public surface.
from .state import (ROUND_TAG_STRIDE, TRAJECTORY_FIELDS,  # noqa: F401
                    SyncRunState, n_rounds, reseed_round_args)

_reseed_round_args = reseed_round_args  # legacy private alias


def validate_round_payload(payload) -> None:
    """Structural check on a worker's round payload before it is pooled —
    the coordinator's defense against corrupt/truncated returns (an
    injected ``corrupt`` fault lands here, phase ``"validate"``)."""
    if not isinstance(payload, dict):
        raise ValueError(f"round payload must be a dict, "
                         f"got {type(payload).__name__}")
    missing = {"result", "x_train", "y_train", "next_starts"} - set(payload)
    if missing:
        raise ValueError(f"round payload missing keys {sorted(missing)}")
    result = payload["result"]
    if not isinstance(result, dict) or not {"designs", "objs",
                                            "n_evals"} <= set(result):
        raise ValueError("round payload 'result' is not a RunResult JSON")


def run_synced(problem: NocProblem, budget: Budget, cfg,
               ) -> tuple[list[RunResult], list[dict], dict]:
    """Execute the round-based synced run; returns ``(results, failures,
    info)`` where ``results`` are one RunResult per surviving (worker,
    round) — history-tagged ``worker_id * ROUND_TAG_STRIDE + round`` so
    the merge orders histories by worker then round — ``failures`` are
    structured per-attempt records (worker_id, round, attempt, phase,
    error, traceback), and ``info`` carries resilience diagnostics
    (pool_rebuilds, checkpoint stats, resumed_from_round).

    ``cfg`` is the :class:`repro.noc.optimizers.StageDistConfig` (only
    its fields are read; no import, so repro.dist never imports the
    registry at module scope)."""
    from . import worker as _worker

    sm = SyncRunState(problem, budget, cfg)

    faults = tuple(getattr(cfg, "faults", ()) or ())
    injector = FaultInjector(faults=faults) if faults else None
    timeout_s = getattr(cfg, "shard_timeout_s", None)
    max_retries = int(getattr(cfg, "max_retries", 0) or 0)
    backoff_s = float(getattr(cfg, "retry_backoff_s", 0.0) or 0.0)

    # ------------------------------------------------------ checkpointing
    ckpt: RoundCheckpointer | None = None
    if getattr(cfg, "checkpoint_dir", None):
        ckpt = RoundCheckpointer(cfg.checkpoint_dir)
        if getattr(cfg, "resume", False):
            try:
                sm.restore(ckpt.load_round())
            except ValueError as exc:
                raise ValueError(
                    f"checkpoint in {cfg.checkpoint_dir!r}: {exc}") from exc

    info: dict = {"pool_rebuilds": 0, "resumed_from_round": sm.resumed_from,
                  "checkpoint": None}

    # One pool for every round: spawn children pay their interpreter +
    # JAX import once; a broken pool is killed and rebuilt by
    # execute_shards, charging the in-flight shards a retry.
    with _worker.shard_pool(cfg.executor, cfg.n_workers) as pool:
        try:
            while not sm.done:
                r = sm.next_round
                built = sm.build_round(r)
                if built is None:
                    cont = False          # the round decided: run over
                elif not built[0]:
                    cont = sm.skip_round(r)
                else:
                    tasks, dispatched = built
                    round_results, round_failures = _worker.execute_shards(
                        _worker.run_shard_round, tasks, cfg.executor,
                        pool=pool,
                        meta=[(wid, r) for wid in dispatched],
                        timeout_s=timeout_s, max_retries=max_retries,
                        backoff_s=backoff_s, retry_args=reseed_round_args,
                        injector=injector, validate=validate_round_payload)
                    cont = sm.absorb_round(r, dispatched, round_results,
                                           round_failures)
                if ckpt is not None:
                    ckpt.save_round(r, sm.snapshot(done=not cont))
                if injector is not None and injector.kills_coordinator(r):
                    saved = "saved" if ckpt is not None else "NOT saved"
                    raise CoordinatorKilled(
                        f"injected coordinator kill after round {r} "
                        f"(checkpoint {saved})")
                if not cont:
                    break
        finally:
            if isinstance(pool, _worker.ShardPool):
                info["pool_rebuilds"] = pool.rebuilds
    if ckpt is not None:
        info["checkpoint"] = {"dir": ckpt.dir, "n_saves": ckpt.n_saves,
                              "save_s": ckpt.save_s,
                              "rounds_on_disk": ckpt.rounds()}

    return sm.results, sm.failures, info
