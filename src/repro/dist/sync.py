"""Per-round surrogate + front sync across workers (DESIGN.md §8–§9).

``stage_batch`` shares one surrogate and one global front across its K
in-process chains; this module generalizes both tricks across
*processes*. The run is cut into rounds of ``sync_every`` STAGE
iterations:

1. every worker runs ``sync_every`` iterations of its chains
   (:func:`repro.dist.worker.run_shard_round`) and checkpoints the
   ``(X, y)`` surrogate training rows its trajectories produced plus the
   designs its chains would restart from;
2. the coordinator pools all workers' rows into one shared training set
   and all workers' Pareto sets into one pooled front;
3. the next round resumes every worker's chains from their checkpointed
   starts with the pooled rows fitted into a warm surrogate
   (``stage_batch(train_init=...)``) and the pooled front seeded as the
   global set (``global_init=``) — each worker's meta-search is steered
   by what *every* worker learned (DAgger across the fleet), and each
   chain maximizes *marginal* PHV over the fleet's whole front instead
   of re-finding another worker's tradeoffs.

Budget accounting is cumulative and remainder-exact: the global
``max_evals`` splits across workers, each worker's share splits across
its first ``ceil(iters_max / sync_every)`` rounds, and round r may spend
up to its cumulative slice minus what the worker actually spent — search
drivers check budgets *before* a dispatch, so charging cumulatively
bounds a worker at shard budget + one dispatch total instead of + one
dispatch per round, and hands budget an early-converged round left to
the rounds after it. Once the planned rounds are done, the coordinator
keeps dispatching **extra rounds** (fresh ``sync_every``-iteration
resumptions) while eval budget remains and the previous round still made
search progress — the eval budget is the contract, iteration counts are
per-round structure; without this, every worker that converges in the
final planned round would strand its leftover budget. Each round runs on
a fresh per-round evaluator (process workers cannot carry evaluator
state between rounds), so each round's mesh-anchor evaluation is paid
inside its slice, like any other evaluation.

Resilience (DESIGN.md §9): dispatches carry per-shard deadlines and
bounded reseeded retries (``cfg.shard_timeout_s`` / ``max_retries`` /
``retry_backoff_s`` threaded into :func:`repro.dist.worker.
execute_shards`); payloads are structurally validated before pooling; a
worker whose attempts are exhausted in round r is dropped from later
rounds (its earlier rounds' results still merge); every failed attempt
is reported as a structured record. With ``cfg.checkpoint_dir`` set, the
coordinator persists its complete state after every round
(:class:`repro.dist.ckpt.RoundCheckpointer`, atomic tmp → fsync →
rename) and ``cfg.resume=True`` restores it — an interrupted-then-
resumed run is byte-identical to the uninterrupted one. Scripted faults
(``cfg.faults``) exercise all of it deterministically.
"""

from __future__ import annotations

import numpy as np

from repro.core.local_search import ParetoSet
from repro.noc.api import Budget, NocProblem, RunResult, design_to_json

from .ckpt import RoundCheckpointer
from .faults import CoordinatorKilled, FaultInjector
from .plan import plan_shards, retry_seed, round_seed, split_evenly

#: history tags are ``worker_id * ROUND_TAG_STRIDE + round`` — unique per
#: (worker, round) and worker-major when sorted. Also the hard cap on
#: rounds (unreachable in practice: every dispatched round costs >= 1
#: evaluation, so rounds are bounded by the eval budget long before it).
ROUND_TAG_STRIDE = 100_000

#: config fields that shape the search trajectory — the run identity a
#: resume must match. Deliberately excludes the knobs that may legally
#: differ between the interrupted and the resuming invocation: executor
#: (where shards run, not what they compute), fault scripts (the resume
#: drops the kill), timeout/retry tuning, and checkpoint_dir/resume
#: themselves.
TRAJECTORY_FIELDS = ("n_workers", "sync_every", "iters_max", "n_starts",
                     "n_swaps", "n_link_moves", "max_local_steps",
                     "forest_kwargs", "forest_backend")


def n_rounds(iters_max: int, sync_every: int) -> int:
    """Planned sync rounds: ceil(iters_max / sync_every). Extra
    budget-draining rounds may follow (see the module docstring)."""
    if sync_every < 1:
        raise ValueError(f"sync_every must be >= 1, got {sync_every}")
    return -(-iters_max // sync_every)


def validate_round_payload(payload) -> None:
    """Structural check on a worker's round payload before it is pooled —
    the coordinator's defense against corrupt/truncated returns (an
    injected ``corrupt`` fault lands here, phase ``"validate"``)."""
    if not isinstance(payload, dict):
        raise ValueError(f"round payload must be a dict, "
                         f"got {type(payload).__name__}")
    missing = {"result", "x_train", "y_train", "next_starts"} - set(payload)
    if missing:
        raise ValueError(f"round payload missing keys {sorted(missing)}")
    result = payload["result"]
    if not isinstance(result, dict) or not {"designs", "objs",
                                            "n_evals"} <= set(result):
        raise ValueError("round payload 'result' is not a RunResult JSON")


def _reseed_round_args(orig_args: tuple, attempt: int) -> tuple:
    """Retry dispatch for attempt ``attempt``: same shard, fresh
    trajectory — only the seed (arg 2, which ``run_shard_round`` folds
    into the budget) changes, via :func:`repro.dist.plan.retry_seed`."""
    return (orig_args[:2] + (retry_seed(orig_args[2], attempt),)
            + orig_args[3:])


def run_synced(problem: NocProblem, budget: Budget, cfg,
               ) -> tuple[list[RunResult], list[dict], dict]:
    """Execute the round-based synced run; returns ``(results, failures,
    info)`` where ``results`` are one RunResult per surviving (worker,
    round) — history-tagged ``worker_id * ROUND_TAG_STRIDE + round`` so
    the merge orders histories by worker then round — ``failures`` are
    structured per-attempt records (worker_id, round, attempt, phase,
    error, traceback), and ``info`` carries resilience diagnostics
    (pool_rebuilds, checkpoint stats, resumed_from_round).

    ``cfg`` is the :class:`repro.noc.optimizers.StageDistConfig` (only
    its fields are read; no import, so repro.dist never imports the
    registry at module scope)."""
    from . import worker as _worker

    R = n_rounds(cfg.iters_max, cfg.sync_every)
    shards = plan_shards(problem, budget, cfg.n_workers)
    round_evals = {s.worker_id: split_evenly(s.budget.max_evals, R)
                   for s in shards}
    round_calls = {s.worker_id: split_evenly(s.budget.max_calls, R)
                   for s in shards}
    shard_budget = {s.worker_id: s.budget for s in shards}
    spent_evals = {s.worker_id: 0 for s in shards}
    spent_calls = {s.worker_id: 0 for s in shards}
    stage_cfg = {
        "n_starts": cfg.n_starts, "n_swaps": cfg.n_swaps,
        "n_link_moves": cfg.n_link_moves,
        "max_local_steps": cfg.max_local_steps,
        "forest_kwargs": cfg.forest_kwargs,
        "forest_backend": cfg.forest_backend,
    }
    problem_json = problem.to_json()
    plan_id = {f: getattr(cfg, f) for f in TRAJECTORY_FIELDS}

    faults = tuple(getattr(cfg, "faults", ()) or ())
    injector = FaultInjector(faults=faults) if faults else None
    timeout_s = getattr(cfg, "shard_timeout_s", None)
    max_retries = int(getattr(cfg, "max_retries", 0) or 0)
    backoff_s = float(getattr(cfg, "retry_backoff_s", 0.0) or 0.0)

    pooled_x: list[list[float]] = []
    pooled_y: list[float] = []
    # The pooled front: the Pareto union of everything any worker found
    # so far, fed back as each next round's global_init.
    pooled_front: dict | None = None
    # Round-0 starts mirror stage_batch's chain diversification across
    # the whole fleet: global chain j (worker i, chain k) starts from the
    # mesh perturbed by 2·j random moves, drawn from the root seed.
    # Without this every worker's chain 0 would re-explore the mesh basin
    # W times over — exactly the duplicated work sharding must avoid.
    from repro.core.problem import sample_neighbors

    start_rng = np.random.default_rng(budget.seed)
    base = problem.mesh()
    starts_by_wid: dict[int, list[dict] | None] = {}
    for s in shards:
        chain_starts = []
        for k in range(cfg.n_starts):
            j = s.worker_id * cfg.n_starts + k
            d = base
            for _ in range(2 * j):
                nb = sample_neighbors(problem.spec, d, start_rng, 1, 1)
                if nb:
                    d = nb[int(start_rng.integers(len(nb)))]
            chain_starts.append(design_to_json(d))
        starts_by_wid[s.worker_id] = chain_starts
    alive = [s.worker_id for s in shards]
    results: list[RunResult] = []
    failures: list[dict] = []

    # ------------------------------------------------------ checkpointing
    ckpt: RoundCheckpointer | None = None
    resumed_from: int | None = None
    start_round = 0
    restored_done = False
    if getattr(cfg, "checkpoint_dir", None):
        ckpt = RoundCheckpointer(cfg.checkpoint_dir)
        if getattr(cfg, "resume", False):
            state = ckpt.load_round()
            if (state["problem"] != problem_json
                    or state["budget"] != budget.to_json()
                    or state["plan"] != plan_id):
                raise ValueError(
                    f"checkpoint in {cfg.checkpoint_dir!r} belongs to a "
                    "different run (problem/budget/trajectory-config "
                    "mismatch); refusing to resume")
            alive = [int(w) for w in state["alive"]]
            spent_evals = {int(w): int(v)
                           for w, v in state["spent_evals"].items()}
            spent_calls = {int(w): int(v)
                           for w, v in state["spent_calls"].items()}
            starts_by_wid = {int(w): v
                             for w, v in state["starts_by_wid"].items()}
            pooled_x = state["pooled_x"]
            pooled_y = state["pooled_y"]
            pooled_front = state["pooled_front"]
            results = [RunResult.from_json(j) for j in state["results"]]
            failures = list(state["failures"])
            resumed_from = int(state["round"])
            start_round = resumed_from + 1
            restored_done = bool(state.get("done", False))

    def _snapshot(done: bool) -> dict:
        """Complete coordinator state after a round — everything
        :func:`run_synced` mutates, plus the run identity. ``done``
        records whether the run had decided to stop (a resume must not
        dispatch extra rounds the uninterrupted run would not have)."""
        return {
            "problem": problem_json,
            "budget": budget.to_json(),
            "plan": plan_id,
            "done": bool(done),
            "alive": list(alive),
            "spent_evals": {str(w): v for w, v in spent_evals.items()},
            "spent_calls": {str(w): v for w, v in spent_calls.items()},
            "starts_by_wid": {str(w): v for w, v in starts_by_wid.items()},
            "pooled_x": pooled_x,
            "pooled_y": pooled_y,
            "pooled_front": pooled_front,
            "results": [rr.to_json() for rr in results],
            "failures": failures,
        }

    def _room(wid: int, r: int) -> tuple[int | None, int | None]:
        """Cumulative remaining (evals, calls) for worker ``wid`` at
        round ``r``; extra rounds (r >= R) draw on the full shard."""
        def one(slices, spent, total):
            if total is None:
                return None
            cum = total if r >= R else sum(slices[wid][:r + 1])
            return max(0, cum - spent[wid])
        return (one(round_evals, spent_evals, shard_budget[wid].max_evals),
                one(round_calls, spent_calls, shard_budget[wid].max_calls))

    def _one_round(r: int, pool) -> bool:
        """Dispatch round ``r``; returns False when the run is done."""
        nonlocal alive, pooled_front
        planned = r < R
        if not planned and budget.max_evals is None:
            return False  # extra rounds only drain a finite eval budget
        iters_r = (min(cfg.sync_every, cfg.iters_max - r * cfg.sync_every)
                   if planned else cfg.sync_every)
        tasks = []
        dispatched = []
        round_cfg = dict(stage_cfg, iters_max=iters_r)
        for wid in alive:
            evals_r, calls_r = _room(wid, r)
            if evals_r == 0 or calls_r == 0:
                continue  # budget fully consumed by earlier rounds
            b = Budget(max_evals=evals_r, max_calls=calls_r,
                       seed=round_seed(shard_budget[wid].seed, r))
            starts = starts_by_wid[wid]
            if not planned and pooled_front and pooled_front["designs"]:
                # Extra rounds intensify: restart every chain from an
                # elite of the pooled front (cycled across workers and
                # rounds for coverage) instead of the meta/random restarts
                # the worker checkpointed — late budget is better spent
                # polishing the union front than opening new basins, which
                # is exactly where the single-process driver's chains sit
                # by this point of a run.
                elite = pooled_front["designs"]
                starts = [elite[(wid + k * cfg.n_workers + (r - R))
                                % len(elite)]
                          for k in range(cfg.n_starts)]
            dispatched.append(wid)
            tasks.append((
                problem_json, b.to_json(), b.seed,
                round_cfg,
                wid * ROUND_TAG_STRIDE + r,        # unique history tag
                starts,
                pooled_x or None, pooled_y or None,
                pooled_front,
            ))
        if not dispatched:
            # Planned round with every alive worker's cumulative slice
            # already overspent (one big dispatch can overshoot a small
            # slice): skip forward — later rounds' larger cumulative
            # targets reopen room. In extra rounds room IS the whole
            # remaining shard, so nobody-dispatchable means truly done.
            return planned
        round_results, round_failures = _worker.execute_shards(
            _worker.run_shard_round, tasks, cfg.executor, pool=pool,
            meta=[(wid, r) for wid in dispatched],
            timeout_s=timeout_s, max_retries=max_retries,
            backoff_s=backoff_s, retry_args=_reseed_round_args,
            injector=injector, validate=validate_round_payload)

        # Every failed attempt is reported; a worker is dropped only if
        # it exhausted its attempts (index absent from round_results).
        dropped = []
        for idx in sorted(round_failures):
            failures.extend(round_failures[idx])
            if idx not in round_results:
                dropped.append(dispatched[idx])
        # Pool in sorted (worker) order — the shared training set and
        # front must be independent of worker completion order for the
        # next round to be deterministic.
        round_spent = 0
        for idx in sorted(round_results):
            wid = dispatched[idx]
            payload = round_results[idx]
            rr = RunResult.from_json(payload["result"])
            spent_evals[wid] += int(rr.n_evals)
            spent_calls[wid] += int(rr.n_calls)
            round_spent += int(rr.n_evals)
            results.append(rr)
            pooled_x.extend(payload["x_train"])
            pooled_y.extend(payload["y_train"])
            if payload["next_starts"]:
                starts_by_wid[wid] = payload["next_starts"]
        alive = [w for w in alive if w not in dropped]
        # Refresh the pooled front from every surviving result so far
        # (workers echo the injected front back inside their global sets,
        # so rebuilding from scratch is a pure union, no double counting).
        front = ParetoSet.empty()
        for rr in results:
            front = front.merged_with(list(rr.designs),
                                      np.asarray(rr.objs, dtype=np.float64),
                                      rr.obj_idx)
        pooled_front = {
            "designs": [design_to_json(d) for d in front.designs],
            "objs": np.asarray(front.objs, dtype=np.float64).tolist(),
        }
        # An unplanned round that spent only its mesh anchors made no
        # search progress — further rounds would loop on anchors forever.
        if not planned and round_spent <= len(dispatched):
            return False
        return True

    info: dict = {"pool_rebuilds": 0, "resumed_from_round": resumed_from,
                  "checkpoint": None}

    # One pool for every round: spawn children pay their interpreter +
    # JAX import once; a broken pool is killed and rebuilt by
    # execute_shards, charging the in-flight shards a retry.
    with _worker.shard_pool(cfg.executor, cfg.n_workers) as pool:
        try:
            r = start_round
            while not restored_done and alive and r < ROUND_TAG_STRIDE:
                cont = _one_round(r, pool)
                if ckpt is not None:
                    ckpt.save_round(r, _snapshot(done=not cont))
                if injector is not None and injector.kills_coordinator(r):
                    saved = "saved" if ckpt is not None else "NOT saved"
                    raise CoordinatorKilled(
                        f"injected coordinator kill after round {r} "
                        f"(checkpoint {saved})")
                if not cont:
                    break
                r += 1
        finally:
            if isinstance(pool, _worker.ShardPool):
                info["pool_rebuilds"] = pool.rebuilds
    if ckpt is not None:
        info["checkpoint"] = {"dir": ckpt.dir, "n_saves": ckpt.n_saves,
                              "save_s": ckpt.save_s,
                              "rounds_on_disk": ckpt.rounds()}

    return results, failures, info
