"""Crash-safe sync-round checkpoints for the distributed coordinator
(DESIGN.md §9).

A multi-round ``stage_dist`` run accumulates everything it paid for —
pooled surrogate rows, the union Pareto front, per-worker restart
designs, budget accounting, the failure ledger — at the coordinator. A
coordinator crash between rounds used to lose all of it. The sync-round
boundary is the natural snapshot point (workers are stateless between
rounds; every mutable of :func:`repro.dist.sync.run_synced` lives on the
coordinator right there), so after each round the full coordinator state
is persisted as one JSON file via the same atomic tmp → fsync → rename
protocol :mod:`repro.ckpt` uses for training state — a crashed save can
never shadow a good round, and stale ``tmp.*`` leftovers are swept on
open.

Files are ``round_<r>.json``. Each is self-contained (cumulative state,
not a delta) so resume needs only the latest; older rounds are kept as a
small safety window (``keep``) and gc'd beyond it. Every file embeds the
run identity (problem / budget / trajectory-shaping config fields) so a
resume against the wrong run fails loudly instead of merging two
unrelated searches.
"""

from __future__ import annotations

import json
import os
import re
import time

from repro.ckpt import atomic_write_json, sweep_stale_tmp

_ROUND_RE = re.compile(r"^round_(\d+)\.json$")

#: bump when the state schema changes incompatibly; resume refuses
#: checkpoints from another format instead of misreading them.
ROUND_STATE_FORMAT = 1


class RoundCheckpointer:
    """Atomic per-round coordinator state store.

    ``save_s``/``n_saves`` accumulate the wall time spent inside saves —
    the `stage_dist_ckpt_4w` bench row reports them as per-round
    checkpoint overhead (target: <2% of round wall time)."""

    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        sweep_stale_tmp(directory)
        self.save_s = 0.0
        self.n_saves = 0

    # ------------------------------------------------------------ queries
    def rounds(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _ROUND_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_round(self) -> int | None:
        rounds = self.rounds()
        return rounds[-1] if rounds else None

    def _path(self, round_idx: int) -> str:
        return os.path.join(self.dir, f"round_{round_idx:06d}.json")

    # --------------------------------------------------------------- save
    def save_round(self, round_idx: int, state: dict) -> None:
        t0 = time.perf_counter()
        payload = dict(state)
        payload["format"] = ROUND_STATE_FORMAT
        payload["round"] = int(round_idx)
        atomic_write_json(self._path(round_idx), payload)
        for stale in self.rounds()[: -self.keep]:
            try:
                os.remove(self._path(stale))
            except OSError:
                pass
        self.save_s += time.perf_counter() - t0
        self.n_saves += 1

    # ------------------------------------------------------------ restore
    def load_round(self, round_idx: int | None = None) -> dict:
        """Load round ``round_idx`` (default: latest). Raises
        ``FileNotFoundError`` when the directory holds no round — a
        ``resume=True`` run against an empty directory is a caller
        mistake, not a silent fresh start."""
        round_idx = self.latest_round() if round_idx is None else round_idx
        if round_idx is None:
            raise FileNotFoundError(
                f"no round checkpoints in {self.dir!r}; nothing to resume")
        with open(self._path(round_idx)) as fh:
            state = json.load(fh)
        fmt = state.get("format")
        if fmt != ROUND_STATE_FORMAT:
            raise ValueError(
                f"checkpoint {self._path(round_idx)!r} has format {fmt!r}; "
                f"this coordinator reads format {ROUND_STATE_FORMAT}")
        return state
