"""Shard planning for distributed multi-start MOO-STAGE (DESIGN.md §8).

One global ``(NocProblem, Budget)`` pair is split into W worker shards.
Each shard is again a plain ``(problem, budget, seed)`` triple — the same
serializable boundary :mod:`repro.noc.api` defines for a single run — so a
shard can execute anywhere a :func:`repro.dist.worker.run_shard` call can
be dispatched (in-process, a subprocess, another host).

Two invariants the test suite pins:

* **Remainder-exact budgets** — :func:`split_evenly` distributes
  ``total`` over ``k`` parts such that the parts sum to exactly ``total``
  (low indices absorb the remainder). Σ worker ``max_evals`` therefore
  equals the global ``max_evals``; no evaluation budget is silently
  created or destroyed by sharding.
* **Identity at W=1** — a single-shard plan passes the root seed and the
  full budget through unchanged, which is what makes
  ``stage_dist(executor="serial", n_workers=1)`` reproduce a registry
  ``stage_batch`` run bit-for-bit. For W>1 the per-worker seeds are
  derived from the root seed via ``numpy.random.SeedSequence.spawn`` —
  statistically independent streams, deterministic in the root seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.noc.api import Budget, NocProblem


def split_evenly(total: int | None, k: int) -> list[int | None]:
    """Split ``total`` into ``k`` non-negative parts summing exactly to
    ``total`` (parts ``i < total % k`` get one extra). ``None`` (no limit)
    splits into ``k`` ``None``s."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if total is None:
        return [None] * k
    if total < 0:
        raise ValueError(f"total must be >= 0, got {total}")
    base, rem = divmod(total, k)
    return [base + (1 if i < rem else 0) for i in range(k)]


def spawn_seeds(root_seed: int, n_workers: int) -> list[int]:
    """Per-worker seeds derived from ``root_seed``.

    W=1 is the identity plan (the root seed passes through — the W=1
    serial-equivalence pin depends on this); W>1 spawns independent
    ``SeedSequence`` children and folds each into one Python int."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    if n_workers == 1:
        return [int(root_seed)]
    children = np.random.SeedSequence(root_seed).spawn(n_workers)
    return [int(c.generate_state(1, np.uint32)[0]) for c in children]


def round_seed(worker_seed: int, round_idx: int) -> int:
    """Deterministic per-(worker, sync round) seed. Round 0 is the worker
    seed itself (so the no-sync path and round 0 of a synced run share
    streams); later rounds fold the round index through a SeedSequence."""
    if round_idx == 0:
        return int(worker_seed)
    ss = np.random.SeedSequence([int(worker_seed), int(round_idx)])
    return int(ss.generate_state(1, np.uint32)[0])


#: spawn-key tag distinguishing retry streams from round streams: without
#: it ``retry_seed(s, k)`` would collide with ``round_seed(s, k)`` and a
#: retried round-0 dispatch would replay round k's trajectory.
_RETRY_TAG = 0x52455452  # "RETR"


def retry_seed(dispatch_seed: int, attempt: int) -> int:
    """Deterministic per-attempt seed for a retried shard dispatch.

    Attempt 0 is the dispatch seed itself (the no-fault path is
    untouched); attempt ``a`` >= 1 folds the attempt index through a
    tagged SeedSequence — a retried shard samples a *different*
    trajectory rather than deterministically replaying the inputs that
    just crashed or hung (DESIGN.md §9)."""
    if attempt < 0:
        raise ValueError(f"attempt must be >= 0, got {attempt}")
    if attempt == 0:
        return int(dispatch_seed)
    ss = np.random.SeedSequence([int(dispatch_seed), _RETRY_TAG, int(attempt)])
    return int(ss.generate_state(1, np.uint32)[0])


@dataclasses.dataclass(frozen=True)
class Shard:
    """One worker's unit of work: (problem, budget) with the worker's own
    seed folded into the budget. Everything here JSON-serializes, so a
    shard crosses a process (or host) boundary as three small dicts."""

    worker_id: int
    problem: NocProblem
    budget: Budget

    def to_json(self) -> dict:
        return {"worker_id": self.worker_id,
                "problem": self.problem.to_json(),
                "budget": self.budget.to_json()}


def plan_shards(problem: NocProblem, budget: Budget,
                n_workers: int) -> list[Shard]:
    """Split one global ``(problem, budget)`` into ``n_workers`` shards.

    ``max_evals`` and ``max_calls`` are divided remainder-exactly
    (Σ shard budget == global budget); seeds come from
    :func:`spawn_seeds`. Every shard shares the problem object — it is
    immutable and serialized once per dispatch."""
    evals = split_evenly(budget.max_evals, n_workers)
    calls = split_evenly(budget.max_calls, n_workers)
    seeds = spawn_seeds(budget.seed, n_workers)
    return [
        Shard(worker_id=i, problem=problem,
              budget=Budget(max_evals=evals[i], max_calls=calls[i],
                            seed=seeds[i]))
        for i in range(n_workers)
    ]
