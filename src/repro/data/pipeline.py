"""Deterministic synthetic LM data pipeline.

No external datasets exist offline, so the pipeline synthesizes a LEARNABLE
token stream: a seeded random bigram automaton (each token has a fixed
likely successor, followed with prob ``determinism``; otherwise uniform).
The achievable cross-entropy floor is known in closed form, which gives the
trainer a real convergence signal to test against.

The pipeline is STATELESS AND RESUMABLE: batch(step) depends only on
(seed, step), so checkpoint/restart and elastic re-sharding never need data-
loader state — the paper-side analogue of gem5 trace replay determinism.
Documents are packed end-to-end with a BOS separator and an attention-
irrelevant loss mask over the BOS positions."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    determinism: float = 0.9
    mean_doc_len: int = 384
    bos: int = 0


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Fixed bigram successor table (the learnable structure).
        self.successor = rng.integers(1, cfg.vocab, size=cfg.vocab)

    def entropy_floor(self) -> float:
        """Achievable mean CE in nats for a perfect model of the automaton."""
        p = self.cfg.determinism
        v = self.cfg.vocab
        # successor with prob p (+ uniform leak), every other token uniform.
        p_succ = p + (1 - p) / v
        rest = (1 - p) / v
        return float(-(p_succ * np.log(p_succ) + (v - 1) * rest * np.log(rest)))

    def _stream(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Packed documents: BOS then bigram-automaton tokens."""
        out = np.empty(n + 1, dtype=np.int32)
        i = 0
        while i < n + 1:
            doc_len = max(2, int(rng.exponential(self.cfg.mean_doc_len)))
            out[i] = self.cfg.bos
            cur = int(rng.integers(1, self.cfg.vocab))
            j = i + 1
            while j < min(i + doc_len, n + 1):
                out[j] = cur
                leak = rng.random() >= self.cfg.determinism
                cur = int(rng.integers(1, self.cfg.vocab)) if leak \
                    else int(self.successor[cur])
                j += 1
            i = j
        return out

    def batch(self, step: int) -> dict:
        """{"tokens", "targets", "mask"} — (B, S) int32 / float mask."""
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        stream = self._stream(rng, c.global_batch * c.seq_len)
        toks = stream[:-1].reshape(c.global_batch, c.seq_len)
        tgts = stream[1:].reshape(c.global_batch, c.seq_len)
        mask = (tgts != c.bos).astype(np.float32)
        return {"tokens": toks, "targets": tgts, "mask": mask}

    def frames_batch(self, step: int, d_model: int, target_len: int) -> dict:
        """Enc-dec variant: stub frame embeddings + token targets."""
        c = self.cfg
        rng = np.random.default_rng((c.seed, 7, step))
        base = self.batch(step)
        frames = rng.standard_normal(
            (c.global_batch, c.seq_len, d_model)).astype(np.float32)
        return {
            "frames": frames,
            "tokens": base["tokens"][:, :target_len],
            "targets": base["targets"][:, :target_len],
            "mask": base["mask"][:, :target_len],
        }
