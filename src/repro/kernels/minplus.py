"""Batched min-plus matrix product — the APSP inner loop of design evaluation.

Distance squaring D <- D (min,+) D is the optimizer's routing hot spot
(paper-side: every candidate design needs all-pairs shortest paths). On GPU
this is typically written scatter/relaxation style (Bellman-Ford); the
TPU-native formulation is a *blocked dense* min-plus matmul: VMEM tiles of
A-rows and B-columns, with the k-dimension as the innermost sequential grid
axis accumulating ``minimum`` into the output block (the same revisiting
pattern as an MXU matmul k-loop, but on the VPU — min of sums has no MXU
lowering).

Block sizes keep the (bm, bk, bn) broadcast intermediate within VMEM:
128 x 32 x 128 x 4 B = 2 MiB. The grid is therefore already k-blocked and
memory-safe at the spec_large/spec_1024 tiers (DESIGN.md §13) — no (N, N, N)
intermediate ever materializes; the jnp fallback gets the same property from
``routing.min_plus_blocked`` above ``routing.DENSE_NMAX``.

This module is the ``backend="pallas"`` implementation behind
core.routing.apsp_batched / routing_tables_batched; core.evaluate.Evaluator
threads its batched candidate APSP through that switch (``"auto"`` selects
this kernel on TPU, the jnp oracle elsewhere; ``interpret=True`` runs it on
CPU for tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INF = 1.0e9


def _minplus_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    a = a_ref[0]  # (bm, bk)
    b = b_ref[0]  # (bk, bn)
    cand = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
    o_ref[0] = jnp.minimum(o_ref[0], cand)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def minplus(
    a: jax.Array,  # (B, N, N)
    b: jax.Array,  # (B, N, N)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 32,
    interpret: bool = False,
) -> jax.Array:
    """out[b, i, j] = min_k a[b, i, k] + b[b, k, j]. Pads N with +INF rows
    (neutral for min-plus) to hardware-aligned tiles."""
    bsz, n, _ = a.shape
    bm, bn, bk = (min(block_m, n), min(block_n, n), min(block_k, n))
    # Pad to multiples of the block sizes (and >= (8, 128) f32 TPU tiles when
    # the matrix is large enough to care).
    def _pad_to(x, m):
        return (x + m - 1) // m * m

    npad = max(_pad_to(n, bm), _pad_to(n, bn), _pad_to(n, bk))
    if npad != n:
        pad = ((0, 0), (0, npad - n), (0, npad - n))
        a = jnp.pad(a, pad, constant_values=INF)
        b = jnp.pad(b, pad, constant_values=INF)

    grid = (bsz, npad // bm, npad // bn, npad // bk)
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b_, i, j, k: (b_, i, k),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, bk, bn), lambda b_, i, j, k: (b_, k, j),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b_, i, j, k: (b_, i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, npad, npad), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, :n, :n]


def apsp(cost: jax.Array, n_iters: int, *, interpret: bool = False) -> jax.Array:
    """Batched APSP by repeated min-plus squaring of (B, N, N) costs."""
    d = cost
    for _ in range(n_iters):
        d = minplus(d, d, interpret=interpret)
    return d
