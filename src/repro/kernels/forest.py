"""Blocked forest-traversal — the MOO-STAGE surrogate's inference hot loop.

The bagged-CART surrogate is queried for whole sampled neighborhoods every
meta-search step (paper §5.2 / Alg. 2 line 9), so forest inference is the
inner loop of every optimizer run. The flat struct-of-arrays forest
(core/forest.py) packs per-tree ``threshold`` / ``feature`` / ``child`` /
``value`` arrays into padded (T, M) tensors with self-looping leaves; a
predict is then ``depth`` rounds of three gathers per (tree, sample) pair.

The TPU-native formulation here mirrors kernels/minplus: the grid runs over
*batch blocks* only, while the node tensors use constant index maps, so
they are resident in VMEM across every grid step and the per-level gathers
for all T trees fuse into one kernel body (no per-level HBM round trips —
the jnp twin re-gathers from device memory each level). ``depth`` is static
and the level loop fully unrolls.

VMEM budget: node tensors are (T, M) f32/int32 x 5 (threshold, feature,
2M-wide child, value) — a 24-tree depth-9 forest is ~0.5 MiB — plus one
(block_b, F) x-block and a (T, block_b) pointer block: far under the
~16 MiB/core limit for every forest the repo trains.

This module is the ``backend="pallas"`` implementation behind
core.forest.RegressionForest.predict; ``resolve_forest_backend("auto")``
selects it on TPU, and ``interpret=True`` runs it through the Pallas
interpreter on CPU (tests, CI smoke).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: default batch-block size; callers that want to bound jit retraces pad
#: their batch to a BLOCK_B multiple *outside* the jitted entry point.
BLOCK_B = 128


def _forest_kernel(thr_ref, feat_ref, child_ref, value_ref, x_ref, o_ref,
                   *, depth: int):
    """One batch block: advance all (tree, sample) node pointers ``depth``
    levels. Leaves self-loop (and their features are clamped to 0), so no
    leaf masking is needed and every pointer advances the same number of
    steps — the same trick as the numpy/jnp twins."""
    thr = thr_ref[...]        # (T, M) f32
    feat = feat_ref[...]      # (T, M) int32, leaf-safe (>= 0)
    child = child_ref[...]    # (T, 2M) int32: [2i] = left, [2i+1] = right
    xb = x_ref[...]           # (block_b, F) f32
    t = thr.shape[0]
    bb = xb.shape[0]
    idx = jnp.zeros((t, bb), jnp.int32)  # all pairs start at the root
    for _ in range(depth):
        node_thr = jnp.take_along_axis(thr, idx, axis=1)     # (T, bb)
        node_feat = jnp.take_along_axis(feat, idx, axis=1)   # (T, bb)
        # x gather: xv[t, b] = xb[b, node_feat[t, b]]
        xv = jnp.take_along_axis(xb, node_feat.T, axis=1).T  # (T, bb)
        go_right = (xv > node_thr).astype(jnp.int32)
        idx = jnp.take_along_axis(child, idx * 2 + go_right, axis=1)
    vals = jnp.take_along_axis(value_ref[...], idx, axis=1)  # (T, bb)
    o_ref[0, :] = jnp.mean(vals, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("depth", "block_b", "interpret"))
def forest_predict(
    threshold: jax.Array,  # (T, M) f32
    feature: jax.Array,    # (T, M) int32, leaf features clamped to 0
    child: jax.Array,      # (T, 2M) int32 interleaved (left, right) pairs
    value: jax.Array,      # (T, M) f32
    x: jax.Array,          # (B, F) f32, already normalized
    *,
    depth: int,
    block_b: int = BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    """(B,) forest mean over T trees. Pads B up to a ``block_b`` multiple
    (padded rows traverse garbage and are sliced off); child pointers are
    per-tree-local, so padded node tails (self-looping, feature -1 -> 0 in
    ``feature``) are never reached from a real root."""
    b, _ = x.shape
    t, m = threshold.shape
    bp = (b + block_b - 1) // block_b * block_b
    if bp != b:
        x = jnp.pad(x, ((0, bp - b), (0, 0)))

    grid = (bp // block_b,)
    full = lambda i: (0, 0)  # node tensors: one block, VMEM-resident
    out = pl.pallas_call(
        functools.partial(_forest_kernel, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((t, m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((t, 2 * m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((t, m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((block_b, x.shape[1]), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, block_b), lambda i: (0, i),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((1, bp), jnp.float32),
        interpret=interpret,
    )(threshold.astype(jnp.float32), feature.astype(jnp.int32),
      child.astype(jnp.int32), value.astype(jnp.float32),
      x.astype(jnp.float32))
    return out[0, :b]
