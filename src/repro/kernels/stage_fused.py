"""Fused meta-search scoring tail — normalize → forest traverse → argmax.

The fused meta-greedy step (core/fused.py) featurizes a whole padded
neighborhood on device and then needs only two scalars back: the index of
the best candidate and its surrogate value. The jnp tail materializes the
(B,) value vector in HBM and ships it to the host for the argmax; this
kernel keeps the reduction on-chip — each grid step scores one batch block
against the VMEM-resident forest (same node layout and traversal as
kernels/forest) and folds its block max into a revisited (1, 1) running
best, so the whole neighborhood round-trips exactly eight bytes.

Tie-breaking matches ``np.argmax`` (first max): within a block,
``jnp.argmax`` takes the first; across blocks, the strict ``>`` update
keeps the earlier block's winner. Rows at or beyond ``n_real`` (the
block-multiple padding added outside the jit) are masked to -inf, so a
padding row can never win. ``n_real`` rides in as a (1, 1) array rather
than a static — the real neighborhood size varies per step and must not
key the jit cache (the padded shape does).

This is the ``meta_backend="fused-pallas"`` implementation; TPU-only, with
``interpret=True`` running it on CPU for conformance tests, and the same
fall-back-to-jnp-on-device-failure contract as kernels/forest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: batch-block size; core.fused pads the neighborhood to a multiple of this
#: *outside* the jitted entry point (the PR-4 retrace-bounding trick).
BLOCK_B = 128


def _score_kernel(thr_ref, feat_ref, child_ref, value_ref, xm_ref, xs_ref,
                  nreal_ref, x_ref, oval_ref, oarg_ref, *, depth: int):
    """One batch block: normalize, traverse all (tree, sample) pointers
    ``depth`` levels (self-looping leaves — kernels/forest), reduce the
    block to (max value, argmax) and fold into the running best."""
    i = pl.program_id(0)
    xb = (x_ref[...] - xm_ref[...]) / xs_ref[...]   # (bb, F) f32
    thr = thr_ref[...]                              # (T, M)
    feat = feat_ref[...]
    child = child_ref[...]
    t = thr.shape[0]
    bb = xb.shape[0]
    idx = jnp.zeros((t, bb), jnp.int32)
    for _ in range(depth):
        node_thr = jnp.take_along_axis(thr, idx, axis=1)
        node_feat = jnp.take_along_axis(feat, idx, axis=1)
        xv = jnp.take_along_axis(xb, node_feat.T, axis=1).T
        go_right = (xv > node_thr).astype(jnp.int32)
        idx = jnp.take_along_axis(child, idx * 2 + go_right, axis=1)
    vals = jnp.mean(jnp.take_along_axis(value_ref[...], idx, axis=1),
                    axis=0, keepdims=True)          # (1, bb)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (1, bb), 1) + i * bb
    vals = jnp.where(ridx < nreal_ref[0, 0], vals, -jnp.inf)
    blk_val = jnp.max(vals)
    blk_arg = jnp.argmax(vals[0]).astype(jnp.int32) + i * bb

    @pl.when(i == 0)
    def _():
        oval_ref[0, 0] = -jnp.inf
        oarg_ref[0, 0] = 0

    better = blk_val > oval_ref[0, 0]
    oarg_ref[0, 0] = jnp.where(better, blk_arg, oarg_ref[0, 0])
    oval_ref[0, 0] = jnp.where(better, blk_val, oval_ref[0, 0])


@functools.partial(jax.jit,
                   static_argnames=("depth", "block_b", "interpret"))
def score_block_max(
    threshold: jax.Array,  # (T, M) f32
    feature: jax.Array,    # (T, M) int32, leaf features clamped to 0
    child: jax.Array,      # (T, 2M) int32 interleaved (left, right)
    value: jax.Array,      # (T, M) f32
    xm: jax.Array,         # (1, F) f32 feature means
    xs: jax.Array,         # (1, F) f32 feature stds
    x: jax.Array,          # (B, F) f32 raw features, B a block_b multiple
    n_real: jax.Array,     # (1, 1) int32 — rows >= n_real are padding
    *,
    depth: int,
    block_b: int = BLOCK_B,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(best value, best row index) over the first ``n_real`` rows."""
    b, f = x.shape
    if b % block_b:
        raise ValueError(
            f"batch {b} must be pre-padded to a multiple of {block_b} "
            "outside the jit (core.fused.MetaScorer._encode does this)")
    t, m = threshold.shape
    grid = (b // block_b,)
    full = lambda i: (0, 0)  # constant maps: VMEM-resident across the grid
    oval, oarg = pl.pallas_call(
        functools.partial(_score_kernel, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((t, m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((t, 2 * m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((t, m), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, f), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, f), full, memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1), full, memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, f), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), full, memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), full, memory_space=pltpu.SMEM),
        ],
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(threshold.astype(jnp.float32), feature.astype(jnp.int32),
      child.astype(jnp.int32), value.astype(jnp.float32),
      xm.astype(jnp.float32), xs.astype(jnp.float32),
      jnp.asarray(n_real, jnp.int32).reshape(1, 1),
      x.astype(jnp.float32))
    return oval[0, 0], oarg[0, 0]
