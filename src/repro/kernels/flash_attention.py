"""Fused attention (flash) forward kernel — causal / sliding-window / full.

Online-softmax tiling for TPU: q blocks stream over kv blocks with the kv
axis innermost in the grid; running (m, l, acc) state lives in VMEM scratch
and the output block is written on the final kv step. GQA is expressed in
the BlockSpec index maps (q head h reads kv head h // group) so no head
replication ever materializes in HBM.

Used by models/attention.py on TPU for train/prefill; the pure-jnp oracle
(kernels/ref.py) is the CPU path and the backward recomputation (ops.py
wires this kernel as a custom_vjp whose bwd re-runs the reference)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, n_k_blocks: int):
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)   # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)   # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)   # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                              # (bq, bk)

    q_pos = pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], k.shape[0]), 0
    )
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (q.shape[0], k.shape[0]), 1
    )
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    # Fully-masked rows (can happen under windowing) contribute nothing.
    p = jnp.where(mask, p, 0.0)
    l_new = l_scr[...][:, 0] * alpha + jnp.sum(p, axis=1)
    acc = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]
    acc_scr[...] = acc

    @pl.when(kj == n_k_blocks - 1)
    def _finalize():
        l = l_scr[...][:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, KH, Sk, D)
    v: jax.Array,   # (B, KH, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kh, sk, _ = k.shape
    assert h % kh == 0, "GQA requires q heads to be a multiple of kv heads"
    group = h // kh
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, "pad sequence to block multiples"
    n_k_blocks = sk // bk
    scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_k_blocks=n_k_blocks,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // bq, n_k_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, kj: (b_, h_, qi, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, kj: (b_, h_ // group, kj, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, kj: (b_, h_ // group, kj, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, qi, kj: (b_, h_, qi, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
