"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function is shape-compatible with its kernel counterpart; tests sweep
shapes/dtypes and assert_allclose kernel(interpret=True) against these."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ minplus
def minplus_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(B, N, N) min-plus product, batched."""
    return jnp.min(a[:, :, :, None] + b[:, None, :, :], axis=2)


# ------------------------------------------------------- link-util walk
def walk_accumulate_np(nh, f, delay, *, max_hops: int):
    """Pure-numpy scalar-loop oracle: walk each (src, dst) pair one hop at
    a time exactly as the routing recurrence defines it. Third corner of
    the link-util conformance triangle (numpy / jnp / Pallas-interpret),
    mirroring minplus/forest."""
    import numpy as np

    nh = np.asarray(nh)
    f = np.asarray(f, np.float32)
    delay = np.asarray(delay, np.float32)
    n = nh.shape[0]
    hops = np.zeros((n, n), np.float32)
    dsum = np.zeros((n, n), np.float32)
    util = np.zeros((n, n), np.float32)
    visits = np.zeros((n,), np.float32)
    for i in range(n):
        for j in range(n):
            cur = i
            for _ in range(max_hops):
                if cur == j:
                    break
                nxt = int(nh[cur, j])
                util[cur, nxt] += f[i, j]
                visits[cur] += f[i, j]
                dsum[i, j] += delay[cur, nxt]
                hops[i, j] += 1.0
                cur = nxt
    visits += f.sum(axis=0)  # dst router traversal at completion
    return hops, dsum, util, visits


def walk_accumulate_ref(nh, f, delay, *, max_hops: int):
    """Scatter-add formulation (the GPU-natural port) — reuses the routing
    walk and adapts output dtypes to the kernel contract."""
    from repro.core.routing import walk_paths

    hops, dsum, util, visits, _ = walk_paths(
        jnp.asarray(nh, jnp.int32), jnp.asarray(delay, jnp.float32),
        jnp.asarray(f, jnp.float32), max_hops,
    )
    return hops.astype(jnp.float32), dsum, util, visits


# ---------------------------------------------------------------- attention
def attention_ref(
    q: jax.Array,   # (B, H, Sq, D)
    k: jax.Array,   # (B, KH, Sk, D)
    v: jax.Array,   # (B, KH, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    logit_dtype=jnp.float32,
) -> jax.Array:
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    group = h // kh
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(logit_dtype),
                   kx.astype(logit_dtype)) * (d ** -0.5)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(logit_dtype)).astype(q.dtype)


# ---------------------------------------------------------------------- ssd
def ssd_ref(x, dt, a, b, c, d, return_state: bool = False):
    """Sequential SSD recurrence — the ground-truth scan.

    x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N), d (H,). Returns (B,S,H,P)
    (plus the final state (B,H,N,P) when ``return_state``).
    """
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    bsz, s, h, p = x.shape
    n = b.shape[-1]

    def step(h_state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * a[None, :])                      # (B,H)
        upd = jnp.einsum("bn,bhp->bhnp", bt, xt * dtt[..., None])
        h_state = decay[..., None, None] * h_state + upd       # (B,H,N,P)
        yt = jnp.einsum("bn,bhnp->bhp", ct, h_state)
        return h_state, yt

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = (jnp.moveaxis(ys, 0, 1) + d[None, None, :, None] * xf).astype(x.dtype)
    return (y, h_final) if return_state else y


def ssd_chunked_ref(x, dt, a, b, c, d, *, chunk: int = 64,
                    return_state: bool = False):
    """Chunk-parallel jnp formulation (same math as the kernel, XLA-fused) —
    this is the differentiable path models use when the kernel is off."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    nc = s // chunk
    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    la = dtf * a[None, None, None, :]                    # (B,C,Q,H)
    sc = jnp.cumsum(la, axis=2)                          # inclusive cumsum
    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    g = jnp.einsum("bcqn,bckn->bcqk", cf, bf)
    w = (g[:, :, :, :, None]
         * jnp.exp(sc[:, :, :, None, :] - sc[:, :, None, :, :])
         * dtf[:, :, None, :, :]
         * tril[None, None, :, :, None])                 # (B,C,Q,K,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", w, xf)

    # Chunk summary states and their prefix scan.
    to_end = jnp.exp(sc[:, :, -1:, :] - sc) * dtf        # (B,C,Q,H)
    chunk_state = jnp.einsum("bcqn,bcqhp->bchnp", bf, xf * to_end[..., None])
    chunk_decay = jnp.exp(sc[:, :, -1, :])               # (B,C,H)

    def scan_chunks(h_prev, inp):
        st, dec = inp                                     # (B,H,N,P), (B,H)
        h_new = dec[..., None, None] * h_prev + st
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    h_final, h_befores = jax.lax.scan(
        scan_chunks, h0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_befores = jnp.moveaxis(h_befores, 0, 1)             # (B,C,H,N,P)
    cexp = cf[:, :, :, None, :] * jnp.exp(sc)[..., None]  # (B,C,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", cexp, h_befores)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    y = (y + d[None, None, :, None] * x.astype(jnp.float32)).astype(x.dtype)
    return (y, h_final) if return_state else y
