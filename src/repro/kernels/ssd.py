"""Mamba-2 SSD (state-space duality) chunked-scan kernel.

The SSD recurrence

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t outer x_t
    y_t = C_t . h_t + D_h * x_t

is evaluated chunk-parallel (Dao & Gu, arXiv:2405.21060): within a chunk of
Q timesteps everything is dense linear algebra on the MXU (the "dual"
attention-like form), and only a (N_state x P) chunk-summary state crosses
chunk boundaries. The chunk axis is the innermost (sequential) grid axis;
the carried state lives in a VMEM scratch buffer that is reset whenever the
(batch, head) grid coordinates change.

Decay weights use log-space cumulative sums realized as a lower-triangular
ones matmul (cumsum has no native TPU-Pallas lowering), and all exponents
are <= 0 by construction (A < 0), so the kernel is numerically stable in
f32. ngroups = 1 (B/C shared across heads), matching our model config.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    a = a_ref[0]                                   # scalar A_h (negative)
    bmat = b_ref[0].astype(jnp.float32)            # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)            # (Q, N)
    d_skip = d_ref[0]                              # scalar D_h

    la = dt * a                                    # (Q,) log decay, <= 0
    # Inclusive cumsum via lower-triangular ones matmul (MXU).
    q = chunk
    tril = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)).astype(jnp.float32)
    s = jnp.dot(tril, la[:, None], preferred_element_type=jnp.float32)[:, 0]

    # Intra-chunk ("dual" attention form).
    g = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # (Q, Q)
    decay = jnp.exp(s[:, None] - s[None, :])
    w = g * decay * dt[None, :] * tril
    y = jnp.dot(w, x, preferred_element_type=jnp.float32)          # (Q, P)

    # Inter-chunk: contribution of the carried state.
    h_prev = state_scr[...]                                        # (N, P)
    y = y + jnp.dot(cmat * jnp.exp(s)[:, None], h_prev,
                    preferred_element_type=jnp.float32)

    # State update for the next chunk.
    to_end = jnp.exp(s[q - 1] - s) * dt                            # (Q,)
    state_scr[...] = (
        jnp.exp(s[q - 1]) * h_prev
        + jnp.dot((bmat * to_end[:, None]).T, x,
                  preferred_element_type=jnp.float32)
    )

    y_ref[0, :, 0, :] = (y + d_skip * x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)   positive step sizes
    a: jax.Array,    # (H,)        negative decay rates
    b: jax.Array,    # (B, S, N)   input projections (ngroups=1)
    c: jax.Array,    # (B, S, N)   output projections
    d: jax.Array,    # (H,)        skip connection
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, "pad sequence to a chunk multiple"
    nchunks = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, h, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c_: (b_, c_, h_, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c_: (b_, c_, h_),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c_: (b_, c_, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda b_, h_, c_: (b_, c_, h_, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a.astype(jnp.float32), b, c, d.astype(jnp.float32))
