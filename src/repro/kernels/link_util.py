"""Path-walk accumulation (Eq. 2 / Eq. 1 / Eq. 8 terms) as one-hot MXU matmuls.

The scatter-add formulation (core/routing.walk_paths) is the natural GPU
port; TPUs have no fast scatter, so this kernel re-expresses the walk as
dense one-hot linear algebra — the DESIGN.md §4 hardware adaptation:

  for each destination d (grid axis):
    C_0 = I                      (N sources x N positions, one-hot "cursor")
    M[v, u] = [nh[v, d] == u]    (next-hop transition matrix, one-hot)
    per hop t:
      C_{t+1} = C_t @ M                                (MXU)
      util   += (C_t * w_t)^T @ C_{t+1}                (MXU; w_t = f masked by done)
      delay_d += rowsum((C_t @ delay) * C_{t+1})       (MXU + VPU)
      hops_d  += 1 - done,   visits += w_t @ C_t       (VPU)

``nh`` must be self-absorbing at the destination (nh[d, d] = d), which
core/routing.next_hop guarantees — finished pairs then accumulate zero
because w_t is masked by done = C_t[:, d].

All per-destination working state (C, M: N x N f32) lives in VMEM; with
N = 64 that is 16 KiB per buffer. The destination axis is the (sequential)
grid; util/visits blocks are revisited and accumulated across it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _walk_kernel(nh_col_ref, f_col_ref, delay_ref, util_ref, hops_ref,
                 dsum_ref, visits_ref, *, max_hops: int, n: int):
    d = pl.program_id(0)

    @pl.when(d == 0)
    def _init():
        util_ref[...] = jnp.zeros_like(util_ref)
        visits_ref[...] = jnp.zeros_like(visits_ref)

    nh_col = nh_col_ref[...][:, 0]            # (N,) int32: nh[:, d]
    f_col = f_col_ref[...][:, 0]              # (N,) f32:  f[:, d]
    delay = delay_ref[...]                    # (N, N)

    iota_u = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    m = (nh_col[:, None] == iota_u).astype(jnp.float32)      # (N, N)
    c = (jax.lax.broadcasted_iota(jnp.int32, (n, n), 0) == iota_u
         ).astype(jnp.float32)                               # identity

    def body(_, carry):
        c, util, hops, dsum, visits = carry
        done = c[:, d]                                        # (N,)
        w = f_col * (1.0 - done)
        cn = jnp.dot(c, m, preferred_element_type=jnp.float32)
        util = util + jnp.dot((c * w[:, None]).T, cn,
                              preferred_element_type=jnp.float32)
        step_delay = jnp.sum(
            jnp.dot(c, delay, preferred_element_type=jnp.float32) * cn, axis=1
        )
        dsum = dsum + (1.0 - done) * step_delay
        hops = hops + (1.0 - done)
        visits = visits + jnp.dot(w[None, :], c,
                                  preferred_element_type=jnp.float32)[0]
        return cn, util, hops, dsum, visits

    c, util_acc, hops, dsum, visits_acc = jax.lax.fori_loop(
        0, max_hops, body,
        (c, jnp.zeros((n, n), jnp.float32), jnp.zeros((n,), jnp.float32),
         jnp.zeros((n,), jnp.float32), jnp.zeros((n,), jnp.float32)),
    )
    util_ref[...] += util_acc
    visits_ref[...] += visits_acc[None, :]
    hops_ref[...] = hops[:, None]
    dsum_ref[...] = dsum[:, None]


@functools.partial(jax.jit, static_argnames=("max_hops", "interpret"))
def walk_accumulate(
    nh: jax.Array,      # (N, N) int32 next hops
    f: jax.Array,       # (N, N) f32 slot traffic
    delay: jax.Array,   # (N, N) f32 per-edge wire delay
    *,
    max_hops: int,
    interpret: bool = False,
):
    """Returns (hops, delay_sums, util, visits) matching
    core/routing.walk_paths (visits includes the destination router)."""
    n = nh.shape[0]
    kernel = functools.partial(_walk_kernel, max_hops=max_hops, n=n)
    util, hops, dsum, visits = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((n, 1), lambda d: (0, d), memory_space=pltpu.VMEM),
            pl.BlockSpec((n, 1), lambda d: (0, d), memory_space=pltpu.VMEM),
            pl.BlockSpec((n, n), lambda d: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_specs=[
            pl.BlockSpec((n, n), lambda d: (0, 0), memory_space=pltpu.VMEM),
            pl.BlockSpec((n, 1), lambda d: (0, d), memory_space=pltpu.VMEM),
            pl.BlockSpec((n, 1), lambda d: (0, d), memory_space=pltpu.VMEM),
            pl.BlockSpec((1, n), lambda d: (0, 0), memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.float32),   # util (directed)
            jax.ShapeDtypeStruct((n, n), jnp.float32),   # hops
            jax.ShapeDtypeStruct((n, n), jnp.float32),   # delay sums
            jax.ShapeDtypeStruct((1, n), jnp.float32),   # visits
        ],
        interpret=interpret,
    )(nh.astype(jnp.int32), f.astype(jnp.float32), delay.astype(jnp.float32))
    visits = visits[0] + jnp.sum(f, axis=0)  # destination router traversal
    return hops, dsum, util, visits
