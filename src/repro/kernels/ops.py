"""jit'd public wrappers around the Pallas kernels with backend dispatch.

On TPU the Pallas kernels run compiled; on CPU (this container) the same
entry points fall back to the pure-jnp references (or interpret mode when
explicitly requested) so the whole framework runs everywhere. Training uses
custom_vjp wrappers whose backward pass recomputes via the reference
formulation (flash-style recompute — no O(S^2) residuals are saved)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import link_util as _lu
from . import minplus as _mp
from . import ref as _ref
from . import ssd as _ssd


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------------------ minplus
def minplus(a, b, *, use_kernel: bool | None = None, interpret: bool = False):
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if use_kernel or interpret:
        return _mp.minplus(a, b, interpret=interpret or not on_tpu())
    return _ref.minplus_ref(a, b)


def apsp(cost, n_iters: int, **kw):
    d = cost
    for _ in range(n_iters):
        d = minplus(d, d, **kw)
    return d


# ---------------------------------------------------------------- link util
def walk_accumulate(nh, f, delay, *, max_hops: int,
                    use_kernel: bool | None = None, interpret: bool = False):
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if use_kernel or interpret:
        return _lu.walk_accumulate(
            nh, f, delay, max_hops=max_hops,
            interpret=interpret or not on_tpu(),
        )
    return _ref.walk_accumulate_ref(nh, f, delay, max_hops=max_hops)


# ---------------------------------------------------------------- attention
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attention_kernel_trainable(q, k, v, causal, window):
    return _fa.flash_attention(q, k, v, causal=causal, window=window)


def _attn_fwd(q, k, v, causal, window):
    return _attention_kernel_trainable(q, k, v, causal, window), (q, k, v)


def _attn_bwd(causal, window, res, g):
    q, k, v = res
    # Recompute-based backward through the reference (no saved logits).
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.attention_ref(
            q_, k_, v_, causal=causal, window=window
        ),
        q, k, v,
    )
    return vjp(g)


_attention_kernel_trainable.defvjp(_attn_fwd, _attn_bwd)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              use_kernel: bool | None = None):
    """Fused attention with GQA: q (B,H,S,D), k/v (B,KH,S,D)."""
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _attention_kernel_trainable(q, k, v, causal, window)
    return _ref.attention_ref(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------- ssd
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _ssd_kernel_trainable(x, dt, a, b, c, d, chunk):
    return _ssd.ssd(x, dt, a, b, c, d, chunk=chunk)


def _ssd_fwd(x, dt, a, b, c, d, chunk):
    return _ssd_kernel_trainable(x, dt, a, b, c, d, chunk), (x, dt, a, b, c, d)


def _ssd_bwd(chunk, res, g):
    x, dt, a, b, c, d = res
    _, vjp = jax.vjp(
        lambda *args: _ref.ssd_chunked_ref(*args, chunk=chunk),
        x, dt, a, b, c, d,
    )
    return vjp(g)


_ssd_kernel_trainable.defvjp(_ssd_fwd, _ssd_bwd)


def ssd(x, dt, a, b, c, d, *, chunk: int = 64,
        use_kernel: bool | None = None, return_state: bool = False):
    """Mamba-2 SSD: x (B,S,H,P), dt (B,S,H), a (H,), b/c (B,S,N), d (H,).
    ``return_state`` also returns the final (B,H,N,P) state (prefill path;
    always served by the chunked reference — state extraction is not part
    of the training-kernel contract)."""
    if return_state:
        if x.shape[1] % chunk == 0 and x.shape[1] > chunk:
            return _ref.ssd_chunked_ref(x, dt, a, b, c, d, chunk=chunk,
                                        return_state=True)
        return _ref.ssd_ref(x, dt, a, b, c, d, return_state=True)
    use_kernel = on_tpu() if use_kernel is None else use_kernel
    if use_kernel:
        return _ssd_kernel_trainable(x, dt, a, b, c, d, chunk)
    if x.shape[1] % chunk == 0 and x.shape[1] > chunk:
        return _ref.ssd_chunked_ref(x, dt, a, b, c, d, chunk=chunk)
    return _ref.ssd_ref(x, dt, a, b, c, d)
