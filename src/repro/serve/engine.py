"""Batched serving engine: prefill a batch of prompts, then step-decode with
greedy sampling. Static batch (continuous batching would slot new requests
into finished rows; the cache layout here — batch-major, position cursor per
engine — is the layout that supports it, noted as future work)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..dist import sharding as shd
from ..models.model import Model
from ..models.common import activation_sharding


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    max_len: int = 256


class Engine:
    def __init__(self, model: Model, mesh: Mesh, policy: shd.Policy,
                 params, cfg: ServeConfig):
        self.model = model
        self.mesh = mesh
        self.policy = policy
        self.params = params
        self.cfg = cfg
        act = shd.activation_shard_fn(mesh, policy)

        def decode(params, cache, token):
            with activation_sharding(act):
                logits, cache = model.decode_step(params, cache, token)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            return nxt[:, None], cache

        self._decode = jax.jit(decode, donate_argnums=(1,))

    def generate(self, prompts: np.ndarray) -> np.ndarray:
        """prompts (B, S_prompt) int32 -> (B, max_new_tokens)."""
        b, s = prompts.shape
        max_len = max(self.cfg.max_len, s + self.cfg.max_new_tokens)
        with self.mesh:
            # Prefill: feed the prompt, take the next-token argmax.
            logits, cache = self.model.prefill(
                self.params, jnp.asarray(prompts), max_len)
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
            out = [np.asarray(tok)]
            for _ in range(self.cfg.max_new_tokens - 1):
                tok, cache = self._decode(self.params, cache, tok)
                out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)
