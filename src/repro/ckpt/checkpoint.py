"""Fault-tolerant checkpointing: atomic, async, elastic.

* ATOMIC — state is serialized to ``<dir>/tmp.<step>``, fsynced, then
  renamed to ``step_<N>.npz``; a crashed save can never shadow a good one
  and partial files are ignored on restore.
* ASYNC — saves run on a background thread; the trainer never blocks on
  I/O (wait() joins at shutdown).
* ELASTIC — checkpoints store LOGICAL arrays (no device layout); restore
  device_puts each leaf against the *current* mesh's shardings, so a run may
  resume on a different pod count / mesh shape than it was saved from. On a
  true multi-host deployment each host would write its address-space shards
  (process-local slices of jax.Array); the format and protocol here are the
  single-process projection of that.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)\.npz$")


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = False):
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device -> host copy
        self.wait()
        fut = self._pool.submit(self._write, step, host_leaves)
        self._pending = fut
        if blocking:
            self.wait()

    def _write(self, step: int, leaves: list[np.ndarray]):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:08d}.npz")
        with open(tmp, "wb") as fh:
            np.savez(fh, **{f"leaf_{i}": a for i, a in enumerate(leaves)})
            fh.flush()
            os.fsync(fh.fileno())
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"step_{s:08d}.npz"))
            except OSError:
                pass

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``tree_like`` (values or
        ShapeDtypeStructs). If ``shardings`` (same-structure tree of
        jax.sharding.Sharding) is given, device_put against it — this is the
        elastic-resume path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}.npz")
        data = np.load(path)
        leaves, treedef = _flatten(tree_like)
        loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            shard_leaves = treedef.flatten_up_to(shardings)
            loaded = [jax.device_put(a, s)
                      for a, s in zip(loaded, shard_leaves)]
        return treedef.unflatten(loaded), step
