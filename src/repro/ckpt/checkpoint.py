"""Fault-tolerant checkpointing: atomic, async, elastic.

* ATOMIC — state is serialized to ``<dir>/tmp.<step>``, fsynced, then
  renamed to ``step_<N>.npz``; a crashed save can never shadow a good one
  and partial files are ignored on restore.
* ASYNC — saves run on a background thread; the trainer never blocks on
  I/O (wait() joins at shutdown).
* ELASTIC — checkpoints store LOGICAL arrays (no device layout); restore
  device_puts each leaf against the *current* mesh's shardings, so a run may
  resume on a different pod count / mesh shape than it was saved from. On a
  true multi-host deployment each host would write its address-space shards
  (process-local slices of jax.Array); the format and protocol here are the
  single-process projection of that.
"""

from __future__ import annotations

import concurrent.futures as cf
import json
import os
import re

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)\.npz$")


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def sweep_stale_tmp(directory: str) -> list[str]:
    """Remove ``tmp.*`` files — the orphans of a save that died between
    write and rename. Safe because every atomic writer here renames its
    tmp away before another save can start; only call this when no save
    targeting ``directory`` is in flight (manager init, post-rename gc).
    Returns the removed names (for logging/tests)."""
    removed = []
    for name in os.listdir(directory):
        if name.startswith("tmp."):
            try:
                os.remove(os.path.join(directory, name))
                removed.append(name)
            except OSError:
                pass
    return removed


def atomic_replace(path: str, write_fn, mode: str = "wb") -> None:
    """The crash-safe write protocol: serialize to ``tmp.<name>`` in the
    target's directory, flush + fsync, then atomically rename over
    ``path``. A crash at any point leaves either the old file or a stale
    ``tmp.*`` (swept by :func:`sweep_stale_tmp`) — never a partial file
    under the final name."""
    directory = os.path.dirname(os.path.abspath(path))
    tmp = os.path.join(directory, f"tmp.{os.path.basename(path)}")
    with open(tmp, mode) as fh:
        write_fn(fh)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


def atomic_write_json(path: str, obj) -> None:
    """JSON flavor of :func:`atomic_replace` — what `repro.dist`'s round
    checkpoints use (DESIGN.md §9)."""
    atomic_replace(path, lambda fh: json.dump(obj, fh), mode="w")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        # A previous process that died between write and rename leaves a
        # tmp.<step> forever; restore already ignores it, but the disk
        # leak compounds across crash-loops — sweep on open.
        sweep_stale_tmp(directory)
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: cf.Future | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = False):
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device -> host copy
        self.wait()
        fut = self._pool.submit(self._write, step, host_leaves)
        self._pending = fut
        if blocking:
            self.wait()

    def _write(self, step: int, leaves: list[np.ndarray]):
        final = os.path.join(self.dir, f"step_{step:08d}.npz")
        atomic_replace(
            final,
            lambda fh: np.savez(fh, **{f"leaf_{i}": a
                                       for i, a in enumerate(leaves)}))
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"step_{s:08d}.npz"))
            except OSError:
                pass
        # Runs on the save thread strictly after our own tmp was renamed
        # away, and saves are serialized (save() waits for the pending
        # write) — any tmp.* here is a dead prior process's leak.
        sweep_stale_tmp(self.dir)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``tree_like`` (values or
        ShapeDtypeStructs). If ``shardings`` (same-structure tree of
        jax.sharding.Sharding) is given, device_put against it — this is the
        elastic-resume path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}.npz")
        data = np.load(path)
        leaves, treedef = _flatten(tree_like)
        loaded = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            shard_leaves = treedef.flatten_up_to(shardings)
            loaded = [jax.device_put(a, s)
                      for a, s in zip(loaded, shard_leaves)]
        return treedef.unflatten(loaded), step
