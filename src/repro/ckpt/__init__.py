from .checkpoint import (CheckpointManager, atomic_replace, atomic_write_json,
                         sweep_stale_tmp)

__all__ = ["CheckpointManager", "atomic_replace", "atomic_write_json",
           "sweep_stale_tmp"]
