"""whisper-base [audio] — enc-dec, 6L encoder + 6L decoder, d_model=512,
8H (kv=8), d_ff=2048, vocab=51865. The conv/mel frontend is a STUB:
input_specs() provides precomputed frame embeddings (task rules).
[arXiv:2212.04356; unverified]

Decoder context for train/prefill shapes is capped at 448 tokens (whisper's
max target length); the shape's seq_len drives the AUDIO frame axis."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64, frontend="audio_stub",
)

SMOKE = CONFIG.scaled(n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, head_dim=16, d_ff=128, vocab=256)
