"""gemma3-1b [dense] — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding-window attention, 128k context, tied embeddings.
[hf:google/gemma-3-1b-pt; unverified]"""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab=262144, head_dim=256,
    sliding_window=512, global_every=6,      # 5 local : 1 global
    tie_embeddings=True, rope_theta=1e6,
)

SMOKE = CONFIG.scaled(n_layers=6, d_model=64, n_heads=4, n_kv_heads=1,
                      head_dim=16, d_ff=128, vocab=512, sliding_window=8)
