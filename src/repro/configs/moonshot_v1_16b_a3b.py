"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (kv=16, MHA) vocab=163840,
MoE: 64 experts, top-6, per-expert d_ff=1408 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B; hf]"""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=163840, head_dim=128,
    n_experts=64, top_k=6, moe_d_ff=1408, rope_theta=5e6,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, vocab=256, n_experts=8, top_k=2,
                      moe_d_ff=32)
