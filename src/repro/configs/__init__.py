"""The 10 assigned architectures (exact public configs), the 4 input shapes,
and input_specs() ShapeDtypeStruct builders for the dry-run."""

from .registry import (ARCH_NAMES, SHAPES, applicable, cell_status,
                       get_config, input_specs)
from .shapes import Shape

__all__ = ["ARCH_NAMES", "SHAPES", "Shape", "applicable", "cell_status",
           "get_config", "input_specs"]
