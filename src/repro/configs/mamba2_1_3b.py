"""mamba2-1.3b [ssm] — 48L d_model=2048, attention-free SSD
(state-space duality), ssm_state=128, vocab=50280.
[arXiv:2405.21060; unverified]"""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv_heads=1,  # attn-free
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_heads=64, ssm_head_dim=64,        # expand=2 -> d_in=4096
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, vocab=256,
                      ssm_state=16, ssm_heads=4, ssm_head_dim=32)
