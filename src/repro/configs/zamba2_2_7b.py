"""zamba2-2.7b [hybrid] — 54L d_model=2560, Mamba2 backbone (ssm_state=64)
with ONE shared attention+MLP block applied every 6 layers (9 sites,
32H MHA, d_ff=10240), vocab=32000.  [arXiv:2411.15242; hf]

Simplification noted in DESIGN.md: the shared block is a standard
attn+MLP residual block (Zamba2 concatenates the original embedding input;
we keep the residual form — systems-equivalent compute/communication)."""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_heads=80, ssm_head_dim=64,   # expand=2 -> d_in=5120
    attn_every=6, rope_theta=1e4,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
                      head_dim=16, d_ff=128, vocab=256,
                      ssm_state=16, ssm_heads=4, ssm_head_dim=32,
                      attn_every=2)
