"""Architecture registry + input_specs() (ShapeDtypeStruct stand-ins).

input_specs() never allocates: every entry is a jax.ShapeDtypeStruct with
weak-type-correct dtypes, shardable along the logical axes the distribution
layer expects. The dry-run lowers against these directly."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig
from . import (chameleon_34b, deepseek_coder_33b, gemma3_1b, mamba2_1_3b,
               mistral_large_123b, moonshot_v1_16b_a3b, qwen3_moe_30b_a3b,
               whisper_base, yi_6b, zamba2_2_7b)
from .shapes import SHAPES, WHISPER_MAX_TARGET, Shape, applicable, cell_status

_MODULES = {
    "mistral-large-123b": mistral_large_123b,
    "gemma3-1b": gemma3_1b,
    "deepseek-coder-33b": deepseek_coder_33b,
    "yi-6b": yi_6b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "zamba2-2.7b": zamba2_2_7b,
    "mamba2-1.3b": mamba2_1_3b,
    "whisper-base": whisper_base,
    "chameleon-34b": chameleon_34b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = _MODULES[name]
    return mod.SMOKE if smoke else mod.CONFIG


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStruct pytree for the step function of (cfg, shape).

    train   -> {"tokens", "targets"} (+ "frames" for enc-dec)
    prefill -> {"tokens"} (+ "frames")
    decode  -> {"cache": <init_cache specs>, "token"}
    """
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        tgt = min(WHISPER_MAX_TARGET, s)
        if shape.kind == "train":
            return {
                "frames": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, tgt), jnp.int32),
                "targets": _sds((b, tgt), jnp.int32),
            }
        if shape.kind == "prefill":
            return {
                "frames": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, 8), jnp.int32),
            }
        # decode: self cache of tgt, cross cache of s (audio frames)
        from ..models import encdec
        cache = jax.eval_shape(
            lambda: encdec.init_cache(cfg, b, tgt, s, jnp.bfloat16))
        return {"cache": cache, "token": _sds((b, 1), jnp.int32)}

    if shape.kind == "train":
        return {
            "tokens": _sds((b, s), jnp.int32),
            "targets": _sds((b, s), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": _sds((b, s), jnp.int32)}
    # decode: one new token against a seq_len cache.
    from ..models import transformer
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s, jnp.bfloat16))
    return {"cache": cache, "token": _sds((b, 1), jnp.int32)}


__all__ = [
    "ARCH_NAMES", "SHAPES", "applicable", "cell_status", "get_config",
    "input_specs",
]
