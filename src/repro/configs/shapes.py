"""Assigned input shapes and the (arch x shape) cell matrix.

  train_4k     seq_len=4096    global_batch=256  (training)
  prefill_32k  seq_len=32768   global_batch=32   (inference prefill)
  decode_32k   seq_len=32768   global_batch=128  (decode: ONE new token
                                                  against a seq_len KV cache)
  long_500k    seq_len=524288  global_batch=1    (long-context decode)

long_500k requires sub-quadratic attention: it RUNS for the SSM/hybrid archs
(constant-size state) and is SKIPPED for pure full-attention archs — the
skip list and rationale live in DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}

# Archs whose decode state is O(1) in context length (SSD state / hybrid).
SUBQUADRATIC = ("mamba2-1.3b", "zamba2-2.7b")

# Whisper's decoder target length is capped (the audio axis carries seq_len).
WHISPER_MAX_TARGET = 448


def applicable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in SUBQUADRATIC
    return True


def all_cells(arch_names) -> list[tuple[str, str]]:
    """Every (arch, shape) cell; inapplicable cells are listed with skip
    reasons by cell_status()."""
    return [(a, s) for a in arch_names for s in SHAPES]


def cell_status(arch_name: str, shape_name: str) -> str:
    if applicable(arch_name, shape_name):
        return "run"
    return "skip: full quadratic attention cannot serve a 512k context " \
           "(task rules; DESIGN.md §6)"
