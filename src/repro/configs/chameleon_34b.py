"""chameleon-34b [vlm] — early-fusion VLM: 48L d_model=8192 64H (GQA kv=8)
d_ff=22016 over a fused text+VQ-image token vocab of 65536. The VQ-VAE image
tokenizer is a STUB: input_specs() provides fused token ids (task rules).
[arXiv:2405.09818; unverified]"""

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536, head_dim=128, frontend="vq_stub",
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=256)
