"""Command-line driver for the unified NoC optimization API.

    PYTHONPATH=src python -m repro.noc run --spec tiny --app BFS \
        --optimizer stage --max-evals 500 --out run.json
    PYTHONPATH=src python -m repro.noc run --smoke
    PYTHONPATH=src python -m repro.noc compare --spec tiny --app BFS \
        --optimizers stage,amosa,nsga2 --max-evals 600
    PYTHONPATH=src python -m repro.noc agnostic --spec 16 --apps BFS,BP,CD

``run`` executes one optimizer and prints (optionally saves) a RunResult;
``compare`` runs several optimizers on one problem at an equal budget;
``agnostic`` reproduces the Fig. 9 cross-execution study. Optimizer config
overrides are ``--set key=value`` (repeatable; values parsed as Python
literals, e.g. ``--set iters_max=3 --set forest_kwargs={'n_trees':8}``).
"""

from __future__ import annotations

import argparse
import ast
import sys

import numpy as np

from .api import Budget, NocProblem, RunResult, named_spec, run
from .optimizers import optimizer_names


def _parse_overrides(pairs: list[str]) -> dict:
    out = {}
    for p in pairs:
        if "=" not in p:
            raise SystemExit(f"--set expects key=value, got {p!r}")
        k, v = p.split("=", 1)
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v  # bare string (e.g. --set rank_backend=numpy)
    return out


def _problem_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--spec", default="tiny",
                    help="system spec: tiny|16|36|64 (default tiny)")
    ap.add_argument("--app", default="BFS", help="application traffic")
    ap.add_argument("--avg", default=None,
                    help="comma-separated apps; use their aggregated traffic "
                         "instead of --app (leave-one-out AVG construction)")
    ap.add_argument("--traffic", default=None,
                    help="explicit traffic spec, overriding --app/--avg: "
                         "model:<arch>:<phase> derives traffic from a model "
                         "config (repro.workloads; e.g. "
                         "model:qwen3-moe-30b-a3b:serve.decode), any other "
                         "value is an application name")
    ap.add_argument("--case", default="case3",
                    help="objective case (case1..case5, default case3)")
    ap.add_argument("--backend", default="auto",
                    help="routing backend auto|jnp|pallas (default auto)")
    ap.add_argument("--forest-backend", default="auto",
                    help="surrogate inference backend auto|numpy|jnp|pallas "
                         "(default auto; pallas falls back to jnp off-TPU)")


def _budget_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--max-evals", type=int, default=None)
    ap.add_argument("--max-calls", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)


def parse_traffic_arg(value: str):
    """``model:<arch>:<phase>`` -> a model-scenario dict; anything else is
    an application name (validated by NocProblem)."""
    if value.startswith("model:"):
        _, _, rest = value.partition(":")
        arch, sep, phase = rest.partition(":")
        spec = {"model": arch}
        if sep:
            spec["phase"] = phase
        return spec
    return value


def _build_problem(args) -> NocProblem:
    if getattr(args, "traffic", None):
        traffic = parse_traffic_arg(args.traffic)
    elif args.avg:
        traffic = tuple(args.avg.split(","))
    else:
        traffic = args.app
    return NocProblem(spec=named_spec(args.spec), traffic=traffic,
                      case=args.case, backend=args.backend,
                      forest_backend=args.forest_backend)


def _summary_line(res: RunResult) -> str:
    return (f"{res.optimizer}: pareto={len(res.designs)} "
            f"best_edp={res.best_edp():.4g} phv={res.phv():.4f} "
            f"evals={res.n_evals} calls={res.n_calls} "
            f"wall={res.wall_s:.1f}s"
            + (" [budget exhausted]" if res.exhausted else ""))


# --------------------------------------------------------------------------
# Subcommands
# --------------------------------------------------------------------------
def cmd_run(args) -> int:
    if args.smoke:
        # Fixed tiny end-to-end exercise of the whole API surface: registry
        # run under a shared Budget, JSON round trip, budget accounting.
        problem = NocProblem(spec=named_spec("tiny"), traffic="BFS")
        res = run(problem, "stage", budget=Budget(max_evals=120, seed=0),
                  config={"iters_max": 2, "n_swaps": 4, "n_link_moves": 4,
                          "max_local_steps": 5})
        back = RunResult.from_json(res.to_json())
        if not np.array_equal(np.asarray(back.objs), np.asarray(res.objs)):
            print("smoke FAILED: RunResult JSON round trip changed objectives")
            return 1
        if res.n_evals > 120 + 4 * 2 * 2:  # one lockstep round of overshoot
            print(f"smoke FAILED: budget not enforced (evals={res.n_evals})")
            return 1
        if not args.quiet:
            print(_summary_line(res))
        print("smoke ok")
        return 0

    problem = _build_problem(args)
    budget = Budget(max_evals=args.max_evals, max_calls=args.max_calls,
                    seed=args.seed)
    overrides = _parse_overrides(args.set)
    if args.workers is not None:
        if args.optimizer != "stage_dist":
            raise SystemExit(
                f"--workers only applies to --optimizer stage_dist "
                f"(got {args.optimizer!r})")
        overrides["n_workers"] = args.workers
    if (args.checkpoint_dir or args.resume) \
            and args.optimizer != "stage_dist":
        raise SystemExit(
            f"--checkpoint-dir/--resume only apply to --optimizer "
            f"stage_dist (got {args.optimizer!r})")
    if args.resume and not args.checkpoint_dir:
        raise SystemExit("--resume requires --checkpoint-dir")
    res = run(problem, args.optimizer, budget=budget,
              config=overrides or None,
              checkpoint_dir=args.checkpoint_dir, resume=args.resume)
    if not args.quiet:
        print(_summary_line(res))
        for d_obj in np.asarray(res.objs):
            print("  objs: " + " ".join(f"{v:.5g}" for v in d_obj))
    if args.out:
        res.save(args.out)
        if not args.quiet:
            print(f"saved {args.out}")
    return 0


def cmd_compare(args) -> int:
    problem = _build_problem(args)
    budget = Budget(max_evals=args.max_evals, max_calls=args.max_calls,
                    seed=args.seed)
    names = args.optimizers.split(",")
    overrides = _parse_overrides(args.set)
    if unknown := set(overrides) - set(names):
        raise SystemExit(
            f"--set keys {sorted(unknown)} match none of the requested "
            f"optimizers {names}")
    results: dict[str, RunResult] = {}
    for name in names:
        # Fresh evaluator per optimizer: equal budgets, independent counters.
        results[name] = run(problem, name, budget=budget,
                            config=overrides.get(name))
        print(_summary_line(results[name]))
    best = min(results, key=lambda n: results[n].best_edp())
    print(f"best final EDP: {best} ({results[best].best_edp():.4g})")
    if args.out:
        import json

        with open(args.out, "w") as fh:
            json.dump({n: r.to_json() for n, r in results.items()}, fh)
        print(f"saved {args.out}")
    return 0


def cmd_agnostic(args) -> int:
    from repro.core.agnostic import (OptimizeBudget, run_agnostic_study,
                                     summarize)
    from repro.core.traffic import APP_NAMES

    spec = named_spec(args.spec)
    apps = tuple(args.apps.split(",")) if args.apps else APP_NAMES[:4]
    budget = OptimizeBudget(iters_max=args.iters, n_swaps=args.moves,
                            n_link_moves=args.moves,
                            max_local_steps=args.local_steps, seed=args.seed)
    res = run_agnostic_study(spec, apps, args.case, budget)
    hdr = "          " + " ".join(f"{a:>6s}" for a in apps)
    print("normalized EDP (row: NoC optimized for; col: app executed):")
    print(hdr)
    for i, a in enumerate(apps):
        print(f"{a:>8s}  " + " ".join(f"{v:6.3f}" for v in res["table"][i]))
    print(f"{'AVG':>8s}  " + " ".join(f"{v:6.3f}" for v in res["avg_row"]))
    s = summarize(res)
    print(f"single-app degradation: avg "
          f"{s['app_specific_avg_degradation']*100:.1f}%, worst "
          f"{s['app_specific_worst_degradation']*100:.1f}%; AVG NoC: avg "
          f"{s['avg_noc_degradation']*100:.1f}%, worst "
          f"{s['avg_noc_worst']*100:.1f}%")
    return 0


def cmd_serve(args) -> int:
    import json as _json

    from .server import NocService, ServiceConfig, serve_stdio

    faults = tuple(_json.loads(args.faults)) if args.faults else ()
    cfg = ServiceConfig(
        n_workers=args.workers, executor=args.executor,
        journal_dir=args.journal_dir, max_queue=args.max_queue,
        max_inflight_per_tenant=args.tenant_cap,
        shard_timeout_s=args.shard_timeout, max_retries=args.max_retries,
        retry_backoff_s=args.retry_backoff, cache=not args.no_cache,
        keep_completed=args.keep_completed, faults=faults)
    serve_stdio(NocService(cfg))
    return 0


# --------------------------------------------------------------------------
def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.noc",
        description="Unified NoC optimization driver (DESIGN.md §7)")
    sub = ap.add_subparsers(dest="command", required=True)

    ap_run = sub.add_parser("run", help="run one optimizer on one problem")
    _problem_args(ap_run)
    _budget_args(ap_run)
    ap_run.add_argument("--optimizer", default="stage",
                        help=f"one of {', '.join(optimizer_names())}")
    ap_run.add_argument("--set", action="append", default=[],
                        metavar="KEY=VALUE", help="optimizer config override")
    ap_run.add_argument("--workers", type=int, default=None,
                        help="stage_dist worker count (shorthand for "
                             "--set n_workers=K; shards the budget, merges "
                             "by Pareto union)")
    ap_run.add_argument("--checkpoint-dir", default=None,
                        help="stage_dist only: persist coordinator state "
                             "after every sync round (crash-safe atomic "
                             "writes; requires --set sync_every>=1)")
    ap_run.add_argument("--resume", action="store_true",
                        help="stage_dist only: restore the latest round "
                             "from --checkpoint-dir and continue")
    ap_run.add_argument("--out", default=None, help="save RunResult JSON")
    ap_run.add_argument("--smoke", action="store_true",
                        help="fixed tiny self-check (CI tier-1)")
    ap_run.add_argument("--quiet", action="store_true")
    ap_run.set_defaults(fn=cmd_run)

    ap_cmp = sub.add_parser("compare",
                            help="run several optimizers at equal budget")
    _problem_args(ap_cmp)
    _budget_args(ap_cmp)
    ap_cmp.add_argument("--optimizers", default="stage,amosa,nsga2")
    ap_cmp.add_argument("--set", action="append", default=[],
                        metavar="NAME=CONFIG_DICT",
                        help="per-optimizer config dict, e.g. "
                             "--set \"amosa={'alpha':0.9}\"")
    ap_cmp.add_argument("--out", default=None, help="save all RunResults")
    ap_cmp.set_defaults(fn=cmd_compare)

    ap_ag = sub.add_parser("agnostic",
                           help="Fig. 9 application-agnostic cross table")
    ap_ag.add_argument("--spec", default="16")
    ap_ag.add_argument("--apps", default=None,
                       help="comma-separated (default: first 4)")
    ap_ag.add_argument("--case", default="case3")
    ap_ag.add_argument("--iters", type=int, default=2)
    ap_ag.add_argument("--moves", type=int, default=10)
    ap_ag.add_argument("--local-steps", type=int, default=12)
    ap_ag.add_argument("--seed", type=int, default=0)
    ap_ag.set_defaults(fn=cmd_agnostic)

    ap_srv = sub.add_parser(
        "serve",
        help="multi-tenant optimization service (stdio JSON lines; "
             "DESIGN.md §10)")
    ap_srv.add_argument("--journal-dir", default=None,
                        help="crash-safe request journal directory; "
                             "restarting against it resumes in-flight "
                             "requests (omit = no persistence)")
    ap_srv.add_argument("--workers", type=int, default=4,
                        help="shared fleet size (default 4)")
    ap_srv.add_argument("--executor", default="serial",
                        help="serial|process|jax (default serial)")
    ap_srv.add_argument("--max-queue", type=int, default=16,
                        help="bound on live requests (backpressure)")
    ap_srv.add_argument("--tenant-cap", type=int, default=2,
                        help="per-tenant in-flight request cap")
    ap_srv.add_argument("--shard-timeout", type=float, default=None,
                        help="per-shard wall deadline, seconds")
    ap_srv.add_argument("--max-retries", type=int, default=1)
    ap_srv.add_argument("--retry-backoff", type=float, default=0.0)
    ap_srv.add_argument("--no-cache", action="store_true",
                        help="disable the canonical-key result cache")
    ap_srv.add_argument("--keep-completed", type=int, default=4,
                        help="completed requests whose round checkpoints "
                             "are kept (older ones gc'd)")
    ap_srv.add_argument("--faults", default=None,
                        help="JSON fault script (chaos drills; see "
                             "repro.dist.faults)")
    ap_srv.set_defaults(fn=cmd_serve)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
