"""``python -m repro.noc`` — dispatch to the CLI (repro.noc.cli)."""

import sys

from .cli import main

if __name__ == "__main__":
    sys.exit(main())
