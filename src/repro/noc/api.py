"""Unified NoC-optimization API: problem / budget / result (DESIGN.md §7).

One serializable boundary for every optimizer in the repo:

  * :class:`NocProblem` — spec + traffic + objective case + routing backend.
  * :class:`Budget` — evaluation / dispatch budget + seed, enforced
    uniformly for every optimizer (the :class:`BudgetedEvaluator` guard
    backstops drivers that predate per-driver ``max_evals`` support).
  * :class:`RunResult` — Pareto designs + full objective rows, the
    convergence history, eval/dispatch accounting, and optimizer
    diagnostics; JSON ``save``/``load`` round-trips bit-exactly.
  * :func:`run` — the one entry point: resolve an optimizer by registry
    name (see :mod:`repro.noc.optimizers`), enforce the budget, record the
    run, return a :class:`RunResult`.

This boundary is what the ROADMAP's distributed multi-start item shards
across hosts: a (problem, budget, seed) triple fully specifies a worker's
run, and RunResults merge by Pareto union.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable

import numpy as np

from repro.core.evaluate import Evaluator
from repro.core.local_search import ParetoSet, SearchHistory
from repro.core.objectives import CASES, N_OBJ
from repro.core.pareto import PhvContext
from repro.core.problem import Design, SystemSpec
from repro.core.traffic import (APPLICATIONS, TrafficValidationError,
                                avg_traffic, traffic_matrix)

SPEC_NAMES = ("tiny", "16", "36", "64")


def named_spec(name: str) -> SystemSpec:
    """Resolve one of the paper's systems by short name ("tiny"/"16"/"36"/"64")."""
    from repro.core import problem as _p

    specs = {"tiny": _p.spec_tiny, "16": _p.spec_16, "36": _p.spec_36,
             "64": _p.spec_64}
    if name not in specs:
        raise ValueError(f"unknown spec {name!r}; choose from {SPEC_NAMES}")
    return specs[name]()


# --------------------------------------------------------------------------
# Problem
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class NocProblem:
    """One NoC design problem: what is optimized, on which traffic.

    ``traffic`` is one of:
      * an application name (see ``repro.core.traffic.APP_NAMES``),
      * a sequence of application names — their aggregated (AVG) traffic,
        the leave-one-out construction of the agnostic study (§6.4),
      * a model scenario ``{"model": arch, "phase": phase, "mesh": [d, m]}``
        — traffic derived from a real model config by ``repro.workloads``
        (DESIGN.md §11; ``phase`` defaults to "train.fwd", ``mesh`` to the
        `derive_mesh` default, and both are resolved at construction so
        every spelling of a scenario hashes identically), or
      * an explicit (N, N) flit-rate matrix.

    Every variant is validated at construction (unknown app/model/phase
    names, non-tiling meshes, and non-finite / negative / zero-sum / wrongly
    shaped matrices raise ``TrafficValidationError``), so the server rejects
    bad requests at admission instead of crashing a worker.

    ``case`` selects the objective subset (``repro.core.objectives.CASES``);
    ``backend`` selects the batched-APSP routing backend (core.routing);
    ``forest_backend`` selects the surrogate inference backend for the
    learning-based optimizers (core.forest.FOREST_BACKENDS — the forest
    backend triangle, DESIGN.md §4.4; ignored by the non-learning
    baselines).

    Equality/hashing go through the canonical JSON form (the generated
    dataclass ``__eq__`` would crash on ndarray traffic), so problems can
    key caches and dedup sets in a distributed fan-out.
    """

    spec: SystemSpec
    traffic: Any = "BFS"
    case: str = "case3"
    backend: str = "auto"
    forest_backend: str = "auto"

    def __post_init__(self):
        from repro.core.forest import check_forest_backend

        if self.case not in CASES:
            raise ValueError(
                f"unknown case {self.case!r}; choose from {tuple(CASES)}")
        check_forest_backend(self.forest_backend)
        object.__setattr__(self, "traffic", self._validate_traffic())

    def _validate_traffic(self):
        """Validate + canonicalize ``traffic``; raises TrafficValidationError."""
        t = self.traffic
        if isinstance(t, dict):
            # deferred: repro.workloads pulls in the model-config registry
            from repro.workloads import normalize_model_traffic

            return normalize_model_traffic(self.spec, t)
        if isinstance(t, str):
            if t not in APPLICATIONS:
                raise TrafficValidationError(
                    f"unknown application {t!r}; known: "
                    f"{', '.join(APPLICATIONS)}")
            return t
        if isinstance(t, (list, tuple)) and t and isinstance(t[0], str):
            unknown = [a for a in t if a not in APPLICATIONS]
            if unknown:
                raise TrafficValidationError(
                    f"unknown applications {unknown}; known: "
                    f"{', '.join(APPLICATIONS)}")
            return tuple(t)
        try:
            arr = np.asarray(t, dtype=np.float64)
        except (TypeError, ValueError) as e:
            raise TrafficValidationError(
                f"traffic matrix is not numeric: {e}") from e
        n = self.spec.n_tiles
        if arr.shape != (n, n):
            raise TrafficValidationError(
                f"traffic matrix shape {arr.shape} != ({n}, {n}) for this "
                "spec")
        if not np.all(np.isfinite(arr)):
            raise TrafficValidationError(
                "traffic matrix has non-finite entries")
        if np.any(arr < 0):
            raise TrafficValidationError(
                "traffic matrix has negative entries")
        if arr.sum() <= 0:
            raise TrafficValidationError("traffic matrix sums to zero")
        return arr

    def _canonical(self) -> str:
        # Cached: the dataclass is frozen, and re-serializing a 64-tile
        # traffic matrix per dict lookup would make problem keys expensive.
        c = self.__dict__.get("_canon")
        if c is None:
            c = json.dumps(self.to_json(), sort_keys=True)
            object.__setattr__(self, "_canon", c)
        return c

    def __eq__(self, other) -> bool:
        if not isinstance(other, NocProblem):
            return NotImplemented
        return self._canonical() == other._canonical()

    def __hash__(self) -> int:
        return hash(self._canonical())

    # ------------------------------------------------------------ builders
    def traffic_matrix(self) -> np.ndarray:
        t = self.traffic
        if isinstance(t, str):
            return traffic_matrix(self.spec, t)
        if isinstance(t, dict):
            from repro.workloads import scenario_matrix

            return scenario_matrix(self.spec, t["model"], t["phase"],
                                   mesh=t["mesh"])
        if isinstance(t, (list, tuple)) and t and isinstance(t[0], str):
            return avg_traffic(self.spec, list(t))
        return np.asarray(t, dtype=np.float64)

    def evaluator(self, **kwargs) -> Evaluator:
        return Evaluator(self.spec, self.traffic_matrix(),
                         backend=self.backend, **kwargs)

    def mesh(self) -> Design:
        return self.spec.mesh_design()

    def context(self, ev: Evaluator, *,
                phv_backend: str = "host") -> PhvContext:
        """PHV context normalized by the mesh design (costs one evaluation
        — the same construction every legacy driver used).

        ``phv_backend`` is a context knob (not a problem field — problems
        hash by canonical JSON): ``"jnp"`` opts the batched chain-step
        scorer into the f32 device twin (see :class:`PhvContext`)."""
        return PhvContext(ev(self.mesh()), CASES[self.case],
                          phv_backend=phv_backend)

    @property
    def obj_idx(self) -> tuple[int, ...]:
        return CASES[self.case]

    # --------------------------------------------------------------- (de)ser
    def to_json(self) -> dict:
        t = self.traffic
        if isinstance(t, str):
            traffic: Any = {"app": t}
        elif isinstance(t, dict):
            traffic = {"model": t["model"], "phase": t["phase"],
                       "mesh": list(t["mesh"])}
        elif isinstance(t, (list, tuple)) and t and isinstance(t[0], str):
            traffic = {"avg": list(t)}
        else:
            traffic = {"matrix": np.asarray(t, dtype=np.float64).tolist()}
        return {"spec": dataclasses.asdict(self.spec), "traffic": traffic,
                "case": self.case, "backend": self.backend,
                "forest_backend": self.forest_backend}

    @staticmethod
    def from_json(obj: dict) -> "NocProblem":
        t = obj["traffic"]
        if "app" in t:
            traffic: Any = t["app"]
        elif "model" in t:
            traffic = {k: t[k] for k in ("model", "phase", "mesh") if k in t}
        elif "avg" in t:
            traffic = tuple(t["avg"])
        else:
            traffic = np.asarray(t["matrix"], dtype=np.float64)
        return NocProblem(spec=SystemSpec(**obj["spec"]), traffic=traffic,
                          case=obj["case"], backend=obj.get("backend", "auto"),
                          forest_backend=obj.get("forest_backend", "auto"))


# --------------------------------------------------------------------------
# Budget
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Budget:
    """Uniform search budget: objective evaluations, XLA dispatches, seed.

    ``max_evals``/``max_calls`` are absolute with respect to the
    evaluator's ``n_evals``/``n_calls`` counters — the exact accounting the
    legacy drivers use, which makes registry runs and legacy calls agree at
    equal budgets. :func:`run` creates a fresh evaluator by default, so the
    budget covers the whole run including the mesh evaluation that anchors
    the PHV context; pass a fresh ``ev=`` if you override it.
    """

    max_evals: int | None = None
    max_calls: int | None = None
    seed: int = 0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(obj: dict) -> "Budget":
        return Budget(**obj)


class BudgetExhausted(RuntimeError):
    """Raised by :class:`BudgetedEvaluator` when a dispatch would start past
    the budget. :func:`run` catches it and returns the best-so-far result."""


class BudgetedEvaluator:
    """Evaluator proxy enforcing a :class:`Budget` before every dispatch.

    Drivers with native ``max_evals`` checks stop themselves at exactly the
    same threshold, so for them the guard can only fire on their very first
    dispatch (issued before their own loop-top check) — i.e. only when the
    budget was already spent at entry, where an empty result is accurate —
    and never alters a legacy-identical run. It backstops drivers without
    native budget support (e.g. PCBB) and enforces ``max_calls`` uniformly.
    """

    def __init__(self, ev: Evaluator, budget: Budget):
        self._ev = ev
        self._budget = budget

    def _check(self) -> None:
        b = self._budget
        if b.max_evals is not None and self._ev.n_evals >= b.max_evals:
            raise BudgetExhausted(
                f"evaluation budget exhausted ({self._ev.n_evals}/"
                f"{b.max_evals} evals)")
        if b.max_calls is not None and self._ev.n_calls >= b.max_calls:
            raise BudgetExhausted(
                f"dispatch budget exhausted ({self._ev.n_calls}/"
                f"{b.max_calls} calls)")

    # Mirror the Evaluator surface; everything funnels through batch_aux.
    def batch_aux(self, designs: list[Design]):
        if designs:
            self._check()
        return self._ev.batch_aux(designs)

    def batch(self, designs: list[Design]) -> np.ndarray:
        return self.batch_aux(designs)[0]

    def batch_moves(self, moves) -> np.ndarray:
        # Must be mirrored here, not left to __getattr__: the raw
        # evaluator's batch_moves dispatches internally (its delta path
        # never calls back through this proxy's batch), so delegation
        # would silently skip the budget check.
        ms = moves if isinstance(moves, (list, tuple)) else [moves]
        if any(len(m) for m in ms):
            self._check()
        return self._ev.batch_moves(moves)

    def __call__(self, d: Design) -> np.ndarray:
        return self.batch([d])[0]

    def edp(self, d: Design) -> float:
        self._check()
        return self._ev.edp(d)

    def __getattr__(self, name: str):
        return getattr(self._ev, name)


# --------------------------------------------------------------------------
# Recording
# --------------------------------------------------------------------------
class RunRecorder(SearchHistory):
    """SearchHistory that also keeps the Pareto set of recorded designs
    (fallback result when the budget guard fires mid-driver) and streams an
    optional per-record telemetry callback.

    ``keep_pareto`` gates the per-record Pareto merge: an unbudgeted run
    can never hit the guard, so it skips the upkeep entirely (the merge is
    a pareto_mask over the accumulated set per recorded evaluation)."""

    def __init__(self, ev, ctx: PhvContext,
                 callback: Callable[[dict], None] | None = None,
                 track_phv: bool = False, keep_pareto: bool = True):
        super().__init__(ev, ctx, track_phv=track_phv)
        self.pareto = ParetoSet.empty()
        self.callback = callback
        self.keep_pareto = keep_pareto

    def record(self, ev, d: Design, objs: np.ndarray):
        super().record(ev, d, objs)
        if self.keep_pareto:
            self.pareto = self.pareto.merged_with(
                [d], np.asarray(objs, dtype=np.float64)[None],
                self.ctx.obj_idx)
        if self.callback is not None:
            wall, n_evals, best_edp, phv = self.rows[-1]
            self.callback({"n_evals": int(n_evals), "n_calls": int(ev.n_calls),
                           "best_edp": float(best_edp), "wall_s": float(wall),
                           "phv": float(phv)})


# --------------------------------------------------------------------------
# Design / result serialization
# --------------------------------------------------------------------------
def design_to_json(d: Design) -> dict:
    """Compact JSON form: placement permutation + upper-triangular links."""
    iu = np.triu_indices(d.adj.shape[0], 1)
    on = d.adj[iu]
    links = np.stack([iu[0][on], iu[1][on]], axis=1)
    return {"perm": d.perm.tolist(), "links": links.tolist()}


def _encode_floats(arr: np.ndarray) -> list:
    """Nested lists with RFC-8259-safe floats: NaN -> None, +/-inf ->
    "inf"/"-inf" (json.dump would otherwise emit bare ``NaN`` tokens —
    e.g. the history's phv column when ``track_phv`` is off — which strict
    parsers reject)."""
    def enc(x):
        if isinstance(x, list):
            return [enc(v) for v in x]
        if x != x:  # NaN
            return None
        if x == float("inf"):
            return "inf"
        if x == float("-inf"):
            return "-inf"
        return x

    return enc(np.asarray(arr, dtype=np.float64).tolist())


def _decode_floats(obj, shape_cols: int) -> np.ndarray:
    def dec(x):
        if isinstance(x, list):
            return [dec(v) for v in x]
        if x is None:
            return float("nan")
        if x == "inf":
            return float("inf")
        if x == "-inf":
            return float("-inf")
        return float(x)

    return np.asarray(dec(obj), dtype=np.float64).reshape(-1, shape_cols)


def design_from_json(obj: dict) -> Design:
    perm = np.asarray(obj["perm"], dtype=np.int32)
    n = perm.shape[0]
    adj = np.zeros((n, n), dtype=bool)
    for a, b in obj["links"]:
        adj[a, b] = adj[b, a] = True
    return Design(perm=perm, adj=adj)


@dataclasses.dataclass
class RunResult:
    """Outcome of one optimizer run through the unified API.

    ``designs``/``objs`` are the optimizer's final Pareto set (full
    ``N_OBJ``-dim objective rows; non-domination holds under ``obj_idx``).
    ``history`` is the SearchHistory array — rows of (wall_s, n_evals,
    best_edp_so_far, phv-or-nan). ``extra`` carries optimizer-specific
    diagnostics (convergence flags, PHV, eval errors, ...).
    """

    optimizer: str
    problem: dict
    budget: dict
    config: dict
    obj_idx: tuple[int, ...]
    designs: list[Design]
    objs: np.ndarray
    n_evals: int
    n_calls: int
    wall_s: float
    history: np.ndarray
    extra: dict = dataclasses.field(default_factory=dict)
    #: the run stopped on (or fully consumed) its budget — either the
    #: guard fired mid-driver or the evaluator counters reached the limits.
    exhausted: bool = False

    # ------------------------------------------------------------ queries
    def pareto_set(self) -> ParetoSet:
        return ParetoSet(list(self.designs), np.asarray(self.objs))

    def best_edp(self) -> float:
        """Best analytic network EDP proxy (lat x energy) on the Pareto set."""
        if len(self.designs) == 0:
            return float("inf")
        o = np.asarray(self.objs)
        return float(np.min(o[:, 2] * o[:, 3]))

    def phv(self) -> float:
        v = self.extra.get("phv")
        return float(v) if v is not None else float("nan")

    # --------------------------------------------------------------- (de)ser
    def to_json(self) -> dict:
        return {
            "optimizer": self.optimizer,
            "problem": self.problem,
            "budget": self.budget,
            # config may carry user-supplied numpy scalars / non-finite
            # floats via dict overrides — sanitize like extra.
            "config": _jsonable(self.config),
            "obj_idx": list(self.obj_idx),
            "designs": [design_to_json(d) for d in self.designs],
            "objs": _encode_floats(self.objs),
            "n_evals": int(self.n_evals),
            "n_calls": int(self.n_calls),
            "wall_s": float(self.wall_s),
            "history": _encode_floats(self.history),
            "extra": _jsonable(self.extra),
            "exhausted": bool(self.exhausted),
        }

    @staticmethod
    def from_json(obj: dict) -> "RunResult":
        return RunResult(
            optimizer=obj["optimizer"],
            problem=obj["problem"],
            budget=obj["budget"],
            config=obj["config"],
            obj_idx=tuple(obj["obj_idx"]),
            designs=[design_from_json(d) for d in obj["designs"]],
            objs=_decode_floats(obj["objs"], N_OBJ),
            n_evals=obj["n_evals"],
            n_calls=obj["n_calls"],
            wall_s=obj["wall_s"],
            history=_decode_floats(obj["history"], 4),
            extra=_decode_jsonable(obj.get("extra", {})),
            exhausted=obj.get("exhausted", False),
        )

    def save(self, path) -> None:
        with open(path, "w") as fh:
            # allow_nan=False: guarantee strict-parser-compatible output
            # (non-finite floats are already encoded by _encode_floats).
            json.dump(self.to_json(), fh, allow_nan=False)

    @staticmethod
    def load(path) -> "RunResult":
        with open(path) as fh:
            return RunResult.from_json(json.load(fh))


def _jsonable(obj):
    """Deep-convert numpy scalars/arrays and tuples to JSON-native types;
    non-finite floats get the same strict-JSON encoding as the arrays."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return _jsonable(obj.tolist())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        return _encode_floats(np.asarray(float(obj)))
    return obj


def _decode_jsonable(obj):
    """Inverse of :func:`_jsonable`'s non-finite encoding for the ``extra``
    diagnostics dict (float-centric by convention: adapters must not store
    genuine ``None`` or the literal strings "inf"/"-inf" in it)."""
    if isinstance(obj, dict):
        return {k: _decode_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode_jsonable(v) for v in obj]
    if obj is None:
        return float("nan")
    if obj == "inf":
        return float("inf")
    if obj == "-inf":
        return float("-inf")
    return obj


# --------------------------------------------------------------------------
# The entry point
# --------------------------------------------------------------------------
def run(
    problem: NocProblem,
    optimizer: str = "stage",
    budget: Budget | None = None,
    config: Any = None,
    callback: Callable[[dict], None] | None = None,
    *,
    ev: Evaluator | None = None,
    ctx: PhvContext | None = None,
    track_phv: bool = False,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> RunResult:
    """Run ``optimizer`` (a registry name — see ``repro.noc.optimizers``)
    on ``problem`` under ``budget``; returns a :class:`RunResult`.

    ``config`` is the optimizer's config dataclass, a dict of overrides for
    it, or None for defaults. ``callback`` streams one telemetry dict per
    recorded evaluation. ``ev``/``ctx`` inject a prebuilt evaluator/PHV
    context (advanced reuse — e.g. cross-evaluating many runs on one jitted
    evaluator); by default both are built fresh, exactly as the legacy
    drivers built them.

    ``checkpoint_dir``/``resume`` enable crash-safe per-round checkpoints
    for coordinator optimizers that support them (``stage_dist`` with
    ``sync_every >= 1`` — DESIGN.md §9): state is persisted atomically
    after every sync round, and ``resume=True`` restores the latest
    round and continues, byte-identical to the uninterrupted run.
    """
    from .optimizers import get_optimizer, make_config

    entry = get_optimizer(optimizer)
    budget = budget or Budget()
    cfg = make_config(entry, config)
    if checkpoint_dir is not None or resume:
        if not entry.owns_result or not hasattr(cfg, "checkpoint_dir"):
            raise ValueError(
                f"optimizer {entry.name!r} does not support checkpoint_dir/"
                "resume (round checkpoints are a coordinator feature)")
        updates: dict[str, Any] = {}
        if checkpoint_dir is not None:
            updates["checkpoint_dir"] = checkpoint_dir
        if resume:
            updates["resume"] = True
        # replace() re-runs __post_init__, so the knob combination is
        # validated exactly as if it had been in `config` to begin with.
        cfg = dataclasses.replace(cfg, **updates)

    if entry.owns_result:
        # Coordinator drivers (e.g. "stage_dist") run their evaluations on
        # evaluators this function cannot see — other processes or
        # devices — so they own accounting, history, and budget
        # enforcement and return a complete RunResult. The single-process
        # conveniences below cannot reach across that boundary.
        if ev is not None or ctx is not None:
            raise ValueError(
                f"optimizer {entry.name!r} owns its RunResult; ev=/ctx= "
                "injection is not supported (workers build their own)")
        if callback is not None or track_phv:
            raise ValueError(
                f"optimizer {entry.name!r} owns its RunResult; callback=/"
                "track_phv= are not supported across worker boundaries")
        return entry.run_fn(problem, budget, cfg, None, None, None)

    base_ev = ev if ev is not None else problem.evaluator()
    n_evals0, n_calls0 = base_ev.n_evals, base_ev.n_calls
    guarded = BudgetedEvaluator(base_ev, budget)
    # The fallback Pareto set is only worth maintaining when the guard can
    # fire with designs already recorded: under a pure max_evals budget,
    # native drivers admit the guard only on their first dispatch (nothing
    # recorded yet — the fallback would be empty regardless), so only a
    # max_calls limit or a driver without native budget support (PCBB)
    # justifies the per-record merge upkeep.
    guard_can_fire = (
        (budget.max_evals is not None and not entry.native_max_evals)
        or budget.max_calls is not None)

    recorder = None
    exhausted = False
    t0 = time.perf_counter()
    try:
        if ctx is None:
            # Through the guard: the PHV-anchoring mesh evaluation counts
            # against (and is forbidden by) a zero budget like any other.
            ctx = problem.context(guarded)
        recorder = RunRecorder(base_ev, ctx, callback=callback,
                               track_phv=track_phv,
                               keep_pareto=guard_can_fire)
        t0 = time.perf_counter()  # optimizer-only wall clock; setup excluded
        pareto, extra = entry.run_fn(problem, budget, cfg, guarded, ctx,
                                     recorder)
    except BudgetExhausted:
        pareto = recorder.pareto if recorder is not None else ParetoSet.empty()
        extra, exhausted = {}, True
    wall = time.perf_counter() - t0
    # Uniform semantics across drivers: a run that consumed its whole
    # budget reports exhausted=True whether its own check stopped it or the
    # guard did (a pre-spent evaluator + native check would otherwise
    # return an empty result flagged as a legitimate Pareto front).
    if budget.max_evals is not None and base_ev.n_evals >= budget.max_evals:
        exhausted = True
    if budget.max_calls is not None and base_ev.n_calls >= budget.max_calls:
        exhausted = True

    extra = dict(extra)
    extra.setdefault("phv",
                     ctx.phv(pareto.objs) if ctx is not None else 0.0)
    return RunResult(
        optimizer=entry.name,
        problem=problem.to_json(),
        budget=budget.to_json(),
        config=dataclasses.asdict(cfg),
        obj_idx=tuple(ctx.obj_idx) if ctx is not None else problem.obj_idx,
        designs=list(pareto.designs),
        objs=np.asarray(pareto.objs, dtype=np.float64),
        n_evals=base_ev.n_evals - n_evals0,
        n_calls=base_ev.n_calls - n_calls0,
        wall_s=wall,
        history=(recorder.as_array() if recorder is not None
                 else np.zeros((0, 4))),
        extra=extra,
        exhausted=exhausted,
    )
