"""Optimizer registry: every search driver in the repo behind one protocol.

Each entry pairs a string name with a per-optimizer config dataclass and an
adapter that invokes the underlying driver with **exactly** the legacy
argument set — a registry run at a given :class:`~repro.noc.api.Budget`
reproduces the legacy driver call bit-for-bit (same rng streams, same
evaluation accounting), which is what lets fig6/table2/fig9 route through
this layer without changing their numbers.

Adapters return ``(ParetoSet, extra)``; :func:`repro.noc.api.run` wraps
them with the budget guard and packages the :class:`RunResult`.

Registering a new optimizer::

    @register("my_opt", MyConfig)
    def _run_my_opt(problem, budget, cfg, ev, ctx, history):
        ...
        return pareto_set, {"my_diagnostic": 42}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core.amosa import amosa
from repro.core.forest import check_forest_backend
from repro.core.fused import check_meta_backend
from repro.core.local_search import ParetoSet, local_search_batch
from repro.core.nsga2 import nsga2
from repro.core.pcbb import pcbb
from repro.core.problem import random_design
from repro.core.stage import moo_stage, stage_batch

from .api import Budget, NocProblem


# --------------------------------------------------------------------------
# Per-optimizer configs
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StageConfig:
    """MOO-STAGE (Alg. 2) knobs — see :func:`repro.core.stage.moo_stage`.

    ``forest_backend`` overrides the problem's surrogate inference backend
    (``None`` inherits ``NocProblem.forest_backend``); ``meta_backend``
    selects the meta-search scoring path (core.fused.META_BACKENDS —
    ``"fused"`` is the one-dispatch-per-step device pipeline,
    ``"host"`` the legacy host-featurizing loop)."""

    iters_max: int = 12
    n_swaps: int = 24
    n_link_moves: int = 24
    max_local_steps: int = 10_000
    forest_kwargs: dict | None = None
    forest_backend: str | None = None
    meta_backend: str = "fused"

    def __post_init__(self):
        # Fail at config construction, not at the first surrogate refit
        # after the initial evaluation budget has already been spent.
        check_forest_backend(self.forest_backend, allow_none=True)
        check_meta_backend(self.meta_backend)


@dataclasses.dataclass(frozen=True)
class StageBatchConfig:
    """Multi-start MOO-STAGE — see :func:`repro.core.stage.stage_batch`.

    ``forest_backend`` overrides the problem's surrogate inference backend
    (``None`` inherits ``NocProblem.forest_backend``); ``meta_backend``
    selects the meta-search scoring path (core.fused.META_BACKENDS)."""

    n_starts: int = 4
    iters_max: int = 12
    n_swaps: int = 24
    n_link_moves: int = 24
    max_local_steps: int = 10_000
    forest_kwargs: dict | None = None
    forest_backend: str | None = None
    meta_backend: str = "fused"

    def __post_init__(self):
        check_forest_backend(self.forest_backend, allow_none=True)
        check_meta_backend(self.meta_backend)


@dataclasses.dataclass(frozen=True)
class StageDistConfig:
    """Distributed multi-start MOO-STAGE — see :func:`repro.dist.run_dist`.

    ``n_workers`` shards the global budget (remainder-exact; per-worker
    seeds spawned from the root seed); ``executor`` picks where shards
    run (``"serial"`` in-process, ``"process"`` spawn-based
    ``ProcessPoolExecutor``, ``"jax"`` one shard per JAX device,
    ``"spmd"`` in-order shards whose evaluator batches run as one
    multi-device shard_map program — repro.core.evaluate.spmd_scope);
    ``sync_every`` > 0 pools surrogate training rows across workers every
    that many STAGE iterations (0 = fully independent workers). The
    remaining knobs configure each worker's ``stage_batch`` run
    (``n_starts`` chains *per worker*, default 1 — W workers × 1 chain is
    the like-for-like peer of ``stage_batch(n_starts=W)``).

    Resilience knobs (DESIGN.md §9): ``shard_timeout_s`` is the per-shard
    wall-clock deadline (preemptive under ``process``, post-hoc for
    in-process executors); ``max_retries`` / ``retry_backoff_s`` bound
    the reseeded re-dispatches of a failed shard; ``checkpoint_dir``
    persists coordinator state after every sync round (atomic writes)
    and ``resume=True`` restores the latest round from it; ``faults`` is
    a deterministic fault script (see :mod:`repro.dist.faults`) for
    tests and chaos drills. All knobs are validated here, at
    construction — not mid-run after budget has been spent."""

    n_workers: int = 4
    executor: str = "serial"
    sync_every: int = 0
    n_starts: int = 1
    iters_max: int = 12
    n_swaps: int = 24
    n_link_moves: int = 24
    max_local_steps: int = 10_000
    forest_kwargs: dict | None = None
    forest_backend: str | None = None
    meta_backend: str = "fused"
    shard_timeout_s: float | None = None
    max_retries: int = 1
    retry_backoff_s: float = 0.0
    checkpoint_dir: str | None = None
    resume: bool = False
    faults: tuple = ()

    def __post_init__(self):
        from repro.dist.faults import check_faults
        from repro.dist.worker import check_executor

        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.sync_every < 0:
            raise ValueError(
                f"sync_every must be >= 0, got {self.sync_every}")
        check_executor(self.executor)
        check_forest_backend(self.forest_backend, allow_none=True)
        check_meta_backend(self.meta_backend)
        if self.shard_timeout_s is not None and self.shard_timeout_s <= 0:
            raise ValueError(f"shard_timeout_s must be > 0 or None, "
                             f"got {self.shard_timeout_s}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {self.retry_backoff_s}")
        if self.resume and not self.checkpoint_dir:
            raise ValueError("resume=True requires checkpoint_dir")
        if self.checkpoint_dir and self.sync_every < 1:
            raise ValueError(
                "checkpoint_dir requires sync_every >= 1 — round "
                "checkpoints exist at sync-round boundaries only")
        object.__setattr__(self, "faults", tuple(self.faults or ()))
        check_faults(self.faults)


@dataclasses.dataclass(frozen=True)
class AmosaConfig:
    """AMOSA baseline — see :func:`repro.core.amosa.amosa`."""

    t_max: float = 1.0
    t_min: float = 1e-4
    alpha: float = 0.92
    iters_per_temp: int = 40
    soft_limit: int = 40
    hard_limit: int = 24
    block_size: int = 1
    adaptive_block: bool = False
    block_max: int = 16


@dataclasses.dataclass(frozen=True)
class Nsga2Config:
    """NSGA-II baseline — see :func:`repro.core.nsga2.nsga2`."""

    pop_size: int = 32
    generations: int = 30
    p_mutate: float = 0.6
    rank_backend: str = "auto"


@dataclasses.dataclass(frozen=True)
class LocalConfig:
    """PHV-greedy local search (Alg. 1); ``n_starts`` > 1 runs lockstep
    chains (chain 0 from the mesh, the rest from random designs)."""

    n_starts: int = 1
    n_swaps: int = 24
    n_link_moves: int = 24
    max_steps: int = 10_000
    max_set: int = 24


@dataclasses.dataclass(frozen=True)
class PcbbConfig:
    """PCBB branch-and-bound baseline — see :func:`repro.core.pcbb.pcbb`.

    PCBB has no native ``max_evals``; the budget guard enforces it."""

    compensation: float = 0.15
    n_random_rollouts: int = 2
    link_descent_steps: int = 10
    max_expansions: int = 200_000


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OptimizerEntry:
    name: str
    config_cls: type
    run_fn: Callable[..., tuple[ParetoSet, dict]]
    #: the driver enforces Budget.max_evals itself (stops at the guard's
    #: exact threshold) — lets run() skip the fallback-Pareto upkeep.
    native_max_evals: bool = True
    #: the adapter returns a complete RunResult instead of (ParetoSet,
    #: extra) — for coordinators (e.g. "stage_dist") whose evaluations
    #: happen on evaluators run() cannot see (other processes/devices), so
    #: the driver must own accounting, history, and budget enforcement.
    owns_result: bool = False


OPTIMIZERS: dict[str, OptimizerEntry] = {}


def register(name: str, config_cls: type, *, native_max_evals: bool = True,
             owns_result: bool = False):
    """Decorator: add an adapter to the registry under ``name``."""

    def deco(fn):
        if name in OPTIMIZERS:
            raise ValueError(f"optimizer {name!r} already registered")
        OPTIMIZERS[name] = OptimizerEntry(name, config_cls, fn,
                                          native_max_evals, owns_result)
        return fn

    return deco


def optimizer_names() -> tuple[str, ...]:
    return tuple(sorted(OPTIMIZERS))


def get_optimizer(name: str) -> OptimizerEntry:
    if name not in OPTIMIZERS:
        raise ValueError(
            f"unknown optimizer {name!r}; registered: {optimizer_names()}")
    return OPTIMIZERS[name]


def make_config(entry: OptimizerEntry, config: Any):
    """Coerce None / dict-of-overrides / dataclass into the entry's config."""
    if config is None:
        return entry.config_cls()
    if isinstance(config, dict):
        return entry.config_cls(**config)
    if isinstance(config, entry.config_cls):
        return config
    raise TypeError(
        f"config for {entry.name!r} must be None, dict, or "
        f"{entry.config_cls.__name__}, got {type(config).__name__}")


# --------------------------------------------------------------------------
# Adapters
# --------------------------------------------------------------------------
@register("stage", StageConfig)
def _run_stage(problem: NocProblem, budget: Budget, cfg: StageConfig,
               ev, ctx, history) -> tuple[ParetoSet, dict]:
    res = moo_stage(
        problem.spec, ev, ctx, problem.mesh(), seed=budget.seed,
        iters_max=cfg.iters_max, n_swaps=cfg.n_swaps,
        n_link_moves=cfg.n_link_moves, max_local_steps=cfg.max_local_steps,
        forest_kwargs=cfg.forest_kwargs,
        forest_backend=(cfg.forest_backend if cfg.forest_backend is not None
                        else problem.forest_backend),
        meta_backend=cfg.meta_backend,
        history=history, max_evals=budget.max_evals,
    )
    return res.global_set, {
        "converged": res.converged,
        "n_local_searches": res.n_local_searches,
        "eval_errors": [[it, float(e)] for it, e in res.eval_errors],
    }


@register("stage_batch", StageBatchConfig)
def _run_stage_batch(problem: NocProblem, budget: Budget,
                     cfg: StageBatchConfig, ev, ctx, history
                     ) -> tuple[ParetoSet, dict]:
    res = stage_batch(
        problem.spec, problem.traffic_matrix(), n_starts=cfg.n_starts,
        seed=budget.seed, case=problem.case, iters_max=cfg.iters_max,
        n_swaps=cfg.n_swaps, n_link_moves=cfg.n_link_moves,
        max_local_steps=cfg.max_local_steps, forest_kwargs=cfg.forest_kwargs,
        forest_backend=(cfg.forest_backend if cfg.forest_backend is not None
                        else problem.forest_backend),
        meta_backend=cfg.meta_backend,
        max_evals=budget.max_evals, ev=ev, ctx=ctx, history=history,
    )
    return res.global_set, {
        "converged": res.converged,
        "n_local_searches": res.n_local_searches,
        "n_starts": res.n_starts,
        "eval_errors": [[it, float(e)] for it, e in res.eval_errors],
    }


@register("stage_dist", StageDistConfig, owns_result=True)
def _run_stage_dist(problem: NocProblem, budget: Budget,
                    cfg: StageDistConfig, ev, ctx, history):
    # Lazy import: repro.dist imports repro.noc.api at module scope; a
    # top-level import here would re-enter repro.dist mid-initialization
    # whenever repro.dist is imported first.
    from repro.dist import run_dist

    return run_dist(problem, budget, cfg)


@register("amosa", AmosaConfig)
def _run_amosa(problem: NocProblem, budget: Budget, cfg: AmosaConfig,
               ev, ctx, history) -> tuple[ParetoSet, dict]:
    archive = amosa(
        problem.spec, ev, ctx, problem.mesh(), seed=budget.seed,
        t_max=cfg.t_max, t_min=cfg.t_min, alpha=cfg.alpha,
        iters_per_temp=cfg.iters_per_temp, soft_limit=cfg.soft_limit,
        hard_limit=cfg.hard_limit, max_evals=budget.max_evals,
        history=history, block_size=cfg.block_size,
        adaptive_block=cfg.adaptive_block, block_max=cfg.block_max,
    )
    return archive, {}


@register("nsga2", Nsga2Config)
def _run_nsga2(problem: NocProblem, budget: Budget, cfg: Nsga2Config,
               ev, ctx, history) -> tuple[ParetoSet, dict]:
    ps = nsga2(
        problem.spec, ev, ctx, problem.mesh(), seed=budget.seed,
        pop_size=cfg.pop_size, generations=cfg.generations,
        p_mutate=cfg.p_mutate, max_evals=budget.max_evals, history=history,
        rank_backend=cfg.rank_backend,
    )
    return ps, {}


@register("local", LocalConfig)
def _run_local(problem: NocProblem, budget: Budget, cfg: LocalConfig,
               ev, ctx, history) -> tuple[ParetoSet, dict]:
    rng = np.random.default_rng(budget.seed)
    starts = [problem.mesh()]
    for _ in range(1, cfg.n_starts):
        starts.append(random_design(problem.spec, rng))
    results = local_search_batch(
        problem.spec, ev, ctx, starts, rng, n_swaps=cfg.n_swaps,
        n_link_moves=cfg.n_link_moves, max_steps=cfg.max_steps,
        max_set=cfg.max_set, history=history, max_evals=budget.max_evals,
    )
    merged = ParetoSet.empty()
    for res in results:
        merged = merged.merged_with(res.local.designs, res.local.objs,
                                    ctx.obj_idx)
    return merged, {
        "phv_per_chain": [float(r.phv) for r in results],
        "n_steps_per_chain": [int(r.n_steps) for r in results],
    }


@register("pcbb", PcbbConfig, native_max_evals=False)
def _run_pcbb(problem: NocProblem, budget: Budget, cfg: PcbbConfig,
              ev, ctx, history) -> tuple[ParetoSet, dict]:
    res = pcbb(
        problem.spec, ev, ctx, seed=budget.seed,
        compensation=cfg.compensation,
        n_random_rollouts=cfg.n_random_rollouts,
        link_descent_steps=cfg.link_descent_steps,
        max_expansions=cfg.max_expansions, history=history,
    )
    return res.pareto, {
        "nodes_expanded": res.nodes_expanded,
        "nodes_pruned": res.nodes_pruned,
        "best_scalarized_objs": np.asarray(res.best_objs,
                                           dtype=np.float64).tolist(),
    }
