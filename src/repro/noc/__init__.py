"""``repro.noc`` — the unified NoC optimization API (DESIGN.md §7).

Every optimizer in the repo (MOO-STAGE single/multi-start, AMOSA, NSGA-II,
PHV-greedy local search, PCBB) runs through one serializable boundary::

    from repro.noc import Budget, NocProblem, run, named_spec

    problem = NocProblem(spec=named_spec("16"), traffic="BFS", case="case3")
    result = run(problem, "stage", budget=Budget(max_evals=2000, seed=0))
    result.save("run.json")           # JSON round trip, resume/compare later

CLI: ``python -m repro.noc run|compare|agnostic`` (see repro.noc.cli).
"""

from .api import (Budget, BudgetedEvaluator, BudgetExhausted, NocProblem,
                  RunRecorder, RunResult, design_from_json, design_to_json,
                  named_spec, run)
from .optimizers import (OPTIMIZERS, AmosaConfig, LocalConfig, Nsga2Config,
                         OptimizerEntry, PcbbConfig, StageBatchConfig,
                         StageConfig, StageDistConfig, get_optimizer,
                         make_config, optimizer_names, register)
# Re-exported so the agnostic study is reachable from the unified surface
# (repro.core.agnostic imports repro.noc lazily inside functions — no cycle).
from repro.core.agnostic import (OptimizeBudget, optimize_for_traffic,
                                 run_agnostic_study, summarize, thermal_study)

__all__ = [
    "AmosaConfig", "Budget", "BudgetExhausted", "BudgetedEvaluator",
    "LocalConfig", "NocProblem", "Nsga2Config", "OPTIMIZERS",
    "OptimizeBudget", "OptimizerEntry", "PcbbConfig", "RunRecorder",
    "RunResult", "StageBatchConfig", "StageConfig", "StageDistConfig",
    "design_from_json",
    "design_to_json", "get_optimizer", "make_config", "named_spec",
    "optimize_for_traffic", "optimizer_names", "register",
    "run", "run_agnostic_study", "summarize", "thermal_study",
]
