"""Client facade + transports for the NoC-optimization service.

Two transports, one surface:

:class:`Client`
    Wraps an in-process :class:`~repro.noc.server.service.NocService` —
    zero serialization overhead beyond the pure-JSON request boundary
    itself. ``drain()`` pumps the wave loop to idle.
:class:`SubprocessClient`
    Spawns ``python -m repro.noc serve`` and speaks newline-delimited
    JSON over its stdin/stdout (:func:`serve_stdio` is the server side).
    The process boundary is what the crash tests need: ``kill()`` is a
    real SIGKILL, and constructing a new client against the same
    ``journal_dir`` exercises the service's recovery path for real.

Both return :class:`repro.noc.api.RunResult` objects from ``result()``
and plain dicts (the service's structured responses) everywhere else.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.noc.api import RunResult

from .service import NocService, ServiceConfig


class ServerDied(RuntimeError):
    """The subprocess transport lost its server mid-call (killed or
    crashed). Re-spawn against the same journal_dir to recover."""


class Client:
    """In-process client: the facade tests and benchmarks default to."""

    def __init__(self, service: NocService):
        self.service = service

    @classmethod
    def local(cls, **cfg_kwargs) -> "Client":
        """Build a service + client in one call (kwargs =
        :class:`ServiceConfig` fields)."""
        return cls(NocService(ServiceConfig(**cfg_kwargs)))

    def submit(self, problem_json, budget_json, config_json=None, *,
               tenant: str = "default", deadline_s: float | None = None,
               request_id: str | None = None) -> dict:
        return self.service.submit(
            problem_json, budget_json, config_json, tenant=tenant,
            deadline_s=deadline_s, request_id=request_id)

    def status(self, request_id: str | None = None) -> dict:
        return self.service.status(request_id)

    def result(self, request_id: str) -> RunResult | dict:
        return self.service.result(request_id)

    def cancel(self, request_id: str) -> dict:
        return self.service.cancel(request_id)

    def step(self) -> bool:
        return self.service.step()

    def drain(self) -> dict:
        return self.service.run_until_idle()

    def close(self) -> None:
        self.service.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc_info):
        self.close()
        return False


# --------------------------------------------------------------------------
# stdio protocol (server side) — newline-delimited JSON request/response
# --------------------------------------------------------------------------
def _handle(service: NocService, msg: dict) -> tuple[dict, bool]:
    """Dispatch one protocol message; returns (response, keep_running)."""
    op = msg.get("op")
    if op == "submit":
        return service.submit(
            msg.get("problem"), msg.get("budget"), msg.get("config"),
            tenant=msg.get("tenant", "default"),
            deadline_s=msg.get("deadline_s"),
            request_id=msg.get("request_id")), True
    if op == "status":
        return service.status(msg.get("id")), True
    if op == "result":
        res = service.result(msg.get("id"))
        if isinstance(res, RunResult):
            return {"result": res.to_json()}, True
        return res, True
    if op == "cancel":
        return service.cancel(msg.get("id")), True
    if op == "step":
        return {"live": service.step()}, True
    if op == "drain":
        return service.run_until_idle(), True
    if op == "shutdown":
        return {"ok": True}, False
    return {"error": {"code": "unknown_op",
                      "message": f"unknown op {op!r}"}}, True


def serve_stdio(service: NocService, stdin=None, stdout=None) -> None:
    """The ``python -m repro.noc serve`` loop: one JSON request per line
    in, one JSON response per line out, until EOF or a ``shutdown`` op.
    An injected ``kill_server`` fault propagates out of ``step``/
    ``drain`` and dies the process — exactly the crash the journal
    recovers from."""
    stdin = stdin if stdin is not None else sys.stdin
    stdout = stdout if stdout is not None else sys.stdout
    with service:
        for line in stdin:
            line = line.strip()
            if not line:
                continue
            try:
                msg = json.loads(line)
            except json.JSONDecodeError as exc:
                resp, keep = {"error": {"code": "bad_json",
                                        "message": str(exc)}}, True
            else:
                resp, keep = _handle(service, msg)
            stdout.write(json.dumps(resp) + "\n")
            stdout.flush()
            if not keep:
                break


# --------------------------------------------------------------------------
# subprocess transport (client side)
# --------------------------------------------------------------------------
class SubprocessClient:
    """Same surface as :class:`Client`, served by a spawned
    ``python -m repro.noc serve`` process over stdio JSON lines."""

    def __init__(self, journal_dir: str, *, n_workers: int = 4,
                 executor: str = "serial", max_queue: int = 16,
                 max_inflight_per_tenant: int = 2,
                 shard_timeout_s: float | None = None,
                 max_retries: int = 1, faults: tuple = ()):
        cmd = [sys.executable, "-m", "repro.noc", "serve",
               "--journal-dir", journal_dir,
               "--workers", str(int(n_workers)),
               "--executor", executor,
               "--max-queue", str(int(max_queue)),
               "--tenant-cap", str(int(max_inflight_per_tenant)),
               "--max-retries", str(int(max_retries))]
        if shard_timeout_s is not None:
            cmd += ["--shard-timeout", str(float(shard_timeout_s))]
        if faults:
            cmd += ["--faults", json.dumps(list(faults))]
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        self._proc = subprocess.Popen(
            cmd, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            text=True, env=env)

    # ------------------------------------------------------------ plumbing
    def _rpc(self, msg: dict) -> dict:
        proc = self._proc
        if proc.poll() is not None:
            raise ServerDied(f"server exited with code {proc.returncode}")
        try:
            proc.stdin.write(json.dumps(msg) + "\n")
            proc.stdin.flush()
            line = proc.stdout.readline()
        except (BrokenPipeError, OSError) as exc:
            raise ServerDied(f"server pipe broke: {exc}") from exc
        if not line:
            raise ServerDied(
                f"server died mid-call (exit code {proc.poll()})")
        return json.loads(line)

    # -------------------------------------------------------------- surface
    def submit(self, problem_json, budget_json, config_json=None, *,
               tenant: str = "default", deadline_s: float | None = None,
               request_id: str | None = None) -> dict:
        return self._rpc({"op": "submit", "problem": problem_json,
                          "budget": budget_json, "config": config_json,
                          "tenant": tenant, "deadline_s": deadline_s,
                          "request_id": request_id})

    def status(self, request_id: str | None = None) -> dict:
        return self._rpc({"op": "status", "id": request_id})

    def result(self, request_id: str) -> RunResult | dict:
        resp = self._rpc({"op": "result", "id": request_id})
        if "result" in resp:
            return RunResult.from_json(resp["result"])
        return resp

    def cancel(self, request_id: str) -> dict:
        return self._rpc({"op": "cancel", "id": request_id})

    def step(self) -> bool:
        return bool(self._rpc({"op": "step"})["live"])

    def drain(self) -> dict:
        return self._rpc({"op": "drain"})

    def kill(self) -> None:
        """SIGKILL the server — the crash-test seam. No flush, no
        goodbye; whatever the journal holds is what recovery gets."""
        self._proc.kill()
        self._proc.wait()

    def close(self) -> None:
        if self._proc.poll() is None:
            try:
                self._rpc({"op": "shutdown"})
            except ServerDied:
                pass
            self._proc.wait(timeout=30)
        for fh in (self._proc.stdin, self._proc.stdout):
            try:
                fh.close()
            except OSError:
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc_info):
        self.close()
        return False
