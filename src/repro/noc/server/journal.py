"""Crash-safe request journal for the NoC-optimization service
(DESIGN.md §10).

Layout, one directory per request under the journal root::

    <root>/req_<seq>/request.json     admission record + status
    <root>/req_<seq>/result.json      final RunResult (done/partial only)
    <root>/req_<seq>/rounds/          per-request RoundCheckpointer state

Every write goes through :func:`repro.ckpt.atomic_write_json` (tmp →
fsync → rename), so a server killed mid-write leaves either the old
record or a stale ``tmp.*`` — never a torn file. Stale tmps are swept on
open, in the root *and* in every request directory (the PR 6 sweep,
applied with parity to the journal). The journal is the service's whole
recovery story: a restarted server lists it, re-queues ``queued``
requests, restores ``running`` ones from their round checkpoints, and
reloads ``done``/``partial`` results into the cache — replaying nothing
that already completed.

Completed requests keep their ``request.json``/``result.json`` forever
(they are the cache), but their ``rounds/`` checkpoints are dead weight
once the result exists — :meth:`RequestJournal.gc_completed` keeps the
last ``keep_completed`` requests' rounds as a debugging window and
deletes the rest.
"""

from __future__ import annotations

import json
import os
import re
import shutil

from repro.ckpt import atomic_write_json, sweep_stale_tmp

_REQ_RE = re.compile(r"^req_(\d+)$")

#: bump when the request-record schema changes incompatibly.
REQUEST_FORMAT = 1

#: request lifecycle states. ``queued`` and ``running`` survive a server
#: restart as live work; the rest are terminal.
STATUSES = ("queued", "running", "done", "partial", "error", "cancelled")
TERMINAL = ("done", "partial", "error", "cancelled")


class RequestJournal:
    """Atomic per-request record + checkpoint store."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        sweep_stale_tmp(directory)
        for seq in self.seqs():
            # Parity with the checkpoint dirs: a crash between a request
            # record's tmp write and its rename leaves the orphan here.
            sweep_stale_tmp(self.req_dir(seq))

    # --------------------------------------------------------------- paths
    def req_dir(self, seq: int) -> str:
        return os.path.join(self.dir, f"req_{int(seq):06d}")

    def rounds_dir(self, seq: int) -> str:
        return os.path.join(self.req_dir(seq), "rounds")

    def _request_path(self, seq: int) -> str:
        return os.path.join(self.req_dir(seq), "request.json")

    def _result_path(self, seq: int) -> str:
        return os.path.join(self.req_dir(seq), "result.json")

    # ------------------------------------------------------------- queries
    def seqs(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _REQ_RE.match(name)
            if m and os.path.isdir(os.path.join(self.dir, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def next_seq(self) -> int:
        seqs = self.seqs()
        return (seqs[-1] + 1) if seqs else 0

    # --------------------------------------------------------------- write
    def save_request(self, rec: dict) -> None:
        """Persist the admission record (atomic). ``rec`` must carry
        ``seq`` and a valid ``status``; ``format`` is stamped here."""
        status = rec.get("status")
        if status not in STATUSES:
            raise ValueError(f"status must be one of {STATUSES}, "
                             f"got {status!r}")
        seq = int(rec["seq"])
        os.makedirs(self.req_dir(seq), exist_ok=True)
        payload = dict(rec)
        payload["format"] = REQUEST_FORMAT
        atomic_write_json(self._request_path(seq), payload)

    def save_result(self, seq: int, result_json: dict) -> None:
        # The result may be committed before the request record (it is
        # the commit point — see NocService._finalize) — make the dir.
        os.makedirs(self.req_dir(seq), exist_ok=True)
        atomic_write_json(self._result_path(seq), result_json)

    # ---------------------------------------------------------------- read
    def load_request(self, seq: int) -> dict:
        with open(self._request_path(seq)) as fh:
            rec = json.load(fh)
        fmt = rec.get("format")
        if fmt != REQUEST_FORMAT:
            raise ValueError(
                f"request record {self._request_path(seq)!r} has format "
                f"{fmt!r}; this service reads format {REQUEST_FORMAT}")
        return rec

    def load_result(self, seq: int) -> dict | None:
        path = self._result_path(seq)
        if not os.path.exists(path):
            return None
        with open(path) as fh:
            return json.load(fh)

    def load_all(self) -> list[dict]:
        """Every request record, seq order — the recovery scan. A request
        directory whose ``request.json`` never made it to disk (crash
        between mkdir and the atomic rename) is skipped: nothing was
        admitted, there is nothing to resume."""
        out = []
        for seq in self.seqs():
            try:
                out.append(self.load_request(seq))
            except FileNotFoundError:
                continue
        return out

    # ----------------------------------------------------------------- gc
    def gc_completed(self, keep: int = 4) -> list[int]:
        """Delete the ``rounds/`` checkpoints of terminal requests beyond
        the newest ``keep`` (records and results are kept — they are the
        cache). Returns the gc'd seqs, for logging/tests."""
        if keep < 0:
            raise ValueError(f"keep must be >= 0, got {keep}")
        done = [int(rec["seq"]) for rec in self.load_all()
                if rec.get("status") in TERMINAL]
        removed = []
        for seq in done[: max(0, len(done) - keep)]:
            rounds = self.rounds_dir(seq)
            if os.path.isdir(rounds):
                shutil.rmtree(rounds, ignore_errors=True)
                removed.append(seq)
        return removed
