"""The multi-tenant NoC-optimization service core (DESIGN.md §10).

:class:`NocService` accepts many concurrent ``(NocProblem, Budget)``
requests and multiplexes them over ONE shared worker fleet. Each
admitted request is a :class:`repro.dist.state.SyncRunState` — the same
resumable round state machine ``stage_dist`` runs on — and the service
is a deterministic *wave pump*: each :meth:`step` builds the next round
of every running request, dispatches all of them as a single
:func:`repro.dist.worker.execute_shards` wave over the fleet, then
routes the results back and absorbs them per request. Requests at
different rounds interleave freely (the worker-order-independent Pareto
union makes cross-request ordering irrelevant), a slow or faulted
request delays only its own rounds' slots, and the whole service is
single-threaded and deterministic — chaos tests replay exactly.

Robustness layers (the spine of this module):

* admission control + backpressure — :mod:`.admission`; checked before
  any state is allocated, rejections are structured errors.
* per-request deadlines — ``deadline_s`` is a wall-clock budget metered
  across waves (and across server restarts, via the journal); an
  overdue or cancelled request is finalized as its best-so-far front
  with ``extra["partial"] = True`` instead of an error, and its fleet
  slots are reclaimed (its rounds simply stop being built).
* fleet supervision — per-shard deadlines, bounded reseeded retries and
  spawn-pool rebuild are the PR 6 ``execute_shards`` machinery, applied
  per wave; a failed shard charges the owning request's ledger (wave
  meta tags are ``seq * ROUND_TAG_STRIDE + worker_id``, so concurrent
  requests at the same round never alias) and never stalls other
  tenants.
* crash-safe journal — every request's admission record and per-round
  checkpoint hit disk (atomically) before the wave is acknowledged; a
  killed-and-restarted service resumes every in-flight request from its
  last round and replays nothing completed (:meth:`NocService.recover`
  runs in the constructor).
* result cache — completed results are deduplicated on the canonical
  request key; a duplicate request is served at submit time with
  ``n_evals == 0`` (the original paid the evals) and
  ``extra["cache_hit"] = True``.

Service-level fault kinds (``reject_admission`` / ``slow_tenant`` /
``kill_server`` — :mod:`repro.dist.faults`) act at the matching seams,
making every one of those layers deterministically testable.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time

from repro.dist import package_dist_result
from repro.dist.ckpt import RoundCheckpointer
from repro.dist.faults import (FAULT_KINDS, FaultInjector, ServerKilled,
                               check_faults)
from repro.dist.state import (ROUND_TAG_STRIDE, SyncRunState,
                              reseed_round_args)
from repro.dist.sync import validate_round_payload
from repro.dist.worker import ShardPool, check_executor, shard_pool
from repro.noc.api import Budget, NocProblem, RunResult
from repro.noc.optimizers import StageDistConfig

from .admission import (AdmissionRejected, canonical_request_key,
                        normalize_config, validate_request)
from .journal import TERMINAL, RequestJournal


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Fleet + policy knobs of one :class:`NocService`.

    ``n_workers`` is the fleet size (process-pool slots; also the default
    shard count a request is planned across). ``max_queue`` bounds the
    live (queued + running) request count — the backpressure knob — and
    ``max_inflight_per_tenant`` keeps one tenant from occupying the
    whole queue. ``shard_timeout_s`` / ``max_retries`` /
    ``retry_backoff_s`` apply per wave to every tenant's dispatches
    (fleet policy, not request policy). ``faults`` is a deterministic
    chaos script: worker kinds act at the shard boundary, service kinds
    at the admission/wave seams."""

    n_workers: int = 4
    executor: str = "serial"
    journal_dir: str | None = None
    max_queue: int = 16
    max_inflight_per_tenant: int = 2
    shard_timeout_s: float | None = None
    max_retries: int = 1
    retry_backoff_s: float = 0.0
    cache: bool = True
    keep_completed: int = 4
    faults: tuple = ()

    def __post_init__(self):
        check_executor(self.executor)
        object.__setattr__(self, "faults", tuple(self.faults or ()))
        check_faults(self.faults)
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_inflight_per_tenant < 1:
            raise ValueError(f"max_inflight_per_tenant must be >= 1, "
                             f"got {self.max_inflight_per_tenant}")
        if self.shard_timeout_s is not None and self.shard_timeout_s <= 0:
            raise ValueError(f"shard_timeout_s must be > 0 or None, "
                             f"got {self.shard_timeout_s}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {self.retry_backoff_s}")
        if self.keep_completed < 0:
            raise ValueError(
                f"keep_completed must be >= 0, got {self.keep_completed}")


class _Request:
    """One tenant request: journal record + live state machine."""

    def __init__(self, rec: dict, problem: NocProblem, budget: Budget,
                 cfg: StageDistConfig):
        self.rec = rec
        self.problem = problem
        self.budget = budget
        self.cfg = cfg
        self.sm: SyncRunState | None = None
        self.ckpt: RoundCheckpointer | None = None
        self.result: RunResult | None = None

    @property
    def status(self) -> str:
        return self.rec["status"]

    @property
    def live(self) -> bool:
        return self.rec["status"] in ("queued", "running")


class NocService:
    """Long-running multi-tenant optimization service (see module doc).

    Single-threaded by design: :meth:`submit`/:meth:`cancel` mutate
    request state, :meth:`step` advances every running request by one
    sync round via one fleet wave. The stdio/CLI front end
    (:mod:`repro.noc.server.client`) pumps :meth:`step` between
    protocol messages; in-process users call :meth:`run_until_idle`.
    """

    def __init__(self, cfg: ServiceConfig):
        self.cfg = cfg
        self.injector = (FaultInjector(faults=cfg.faults)
                         if cfg.faults else None)
        self.journal = (RequestJournal(cfg.journal_dir)
                        if cfg.journal_dir else None)
        self._requests: dict[str, _Request] = {}
        self._cache: dict[str, RunResult] = {}
        self._wave = 0
        self._stack = contextlib.ExitStack()
        self._pool = self._stack.enter_context(
            shard_pool(cfg.executor, cfg.n_workers))
        self.recover()

    # ------------------------------------------------------------ recovery
    def recover(self) -> None:
        """Rebuild service state from the journal (no-op without one):
        terminal requests feed the cache, ``queued`` requests re-queue,
        ``running`` requests restore their round checkpoints. A request
        whose ``result.json`` exists but whose status never flipped
        terminal (crash in the finalize window) is adopted as completed —
        the result write is the commit point, so nothing replays."""
        if self.journal is None:
            return
        for rec in self.journal.load_all():
            rid = rec["id"]
            req = _Request(rec, NocProblem.from_json(rec["problem"]),
                           Budget.from_json(rec["budget"]),
                           StageDistConfig(**rec["config"]))
            self._requests[rid] = req
            result_json = self.journal.load_result(int(rec["seq"]))
            if result_json is not None:
                req.result = RunResult.from_json(result_json)
                if rec["status"] not in TERMINAL:
                    # Crash between result write and status flip.
                    rec["status"] = ("partial"
                                     if req.result.extra.get("partial")
                                     else "done")
                    self.journal.save_request(rec)
                if self.cfg.cache and not req.result.extra.get("partial") \
                        and not req.result.extra.get("cache_hit"):
                    self._cache.setdefault(rec["key"], req.result)
                continue
            if rec["status"] in TERMINAL:
                continue                       # error/cancelled: nothing to do
            if rec["status"] == "running":
                self._start(req)
                if req.ckpt is not None and req.ckpt.rounds():
                    req.sm.restore(req.ckpt.load_round())
                # else: admitted but died before round 0 saved — the
                # fresh state machine re-runs it from scratch, which is
                # byte-identical (nothing of it ever reached a result).

    def _start(self, req: _Request) -> None:
        """queued -> running: build the state machine + its checkpointer."""
        req.sm = SyncRunState(req.problem, req.budget, req.cfg)
        if self.journal is not None:
            req.ckpt = RoundCheckpointer(
                self.journal.rounds_dir(int(req.rec["seq"])))
        req.rec["status"] = "running"
        self._persist(req)

    def _persist(self, req: _Request) -> None:
        if self.journal is not None:
            self.journal.save_request(req.rec)

    # ------------------------------------------------------------ admission
    def submit(self, problem_json, budget_json, config_json=None, *,
               tenant: str = "default", deadline_s: float | None = None,
               request_id: str | None = None) -> dict:
        """Admit one request; returns ``{"id", "status", "cache_hit"}``
        or ``{"error": {"code", "message"}}`` — never raises for a bad
        request (the structured-error contract)."""
        tenant = str(tenant)
        seq = (self.journal.next_seq() if self.journal is not None
               else len(self._requests))
        rid = str(request_id) if request_id is not None else f"req_{seq:06d}"
        if rid in self._requests:
            return AdmissionRejected(
                "duplicate_id", f"request id {rid!r} already exists"
            ).to_json()
        if self.injector is not None:
            inj = self.injector.rejects_admission(tenant, rid)
            if inj is not None:
                return AdmissionRejected(
                    "injected_rejection",
                    f"admission rejected by fault script ({inj})").to_json()
        live = [r for r in self._requests.values() if r.live]
        if len(live) >= self.cfg.max_queue:
            return AdmissionRejected(
                "queue_full",
                f"service queue is full ({len(live)}/{self.cfg.max_queue} "
                "live requests); retry after a drain").to_json()
        if sum(1 for r in live if r.rec["tenant"] == tenant) \
                >= self.cfg.max_inflight_per_tenant:
            return AdmissionRejected(
                "tenant_cap",
                f"tenant {tenant!r} already has "
                f"{self.cfg.max_inflight_per_tenant} requests in flight"
            ).to_json()
        if deadline_s is not None and float(deadline_s) <= 0:
            return AdmissionRejected(
                "invalid_deadline",
                f"deadline_s must be > 0 or None, got {deadline_s}").to_json()
        try:
            problem, budget, rcfg = validate_request(
                problem_json, budget_json, config_json)
        except AdmissionRejected as exc:
            return exc.to_json()
        cfg = normalize_config(
            rcfg, executor=self.cfg.executor,
            shard_timeout_s=self.cfg.shard_timeout_s,
            max_retries=self.cfg.max_retries,
            retry_backoff_s=self.cfg.retry_backoff_s)
        key = canonical_request_key(problem, budget, cfg)
        rec = {
            "id": rid, "seq": int(seq), "tenant": tenant,
            "status": "queued", "problem": problem.to_json(),
            "budget": budget.to_json(),
            "config": dataclasses.asdict(cfg),
            "deadline_s": (float(deadline_s)
                           if deadline_s is not None else None),
            "key": key, "wall_spent_s": 0.0, "error": None,
        }
        req = _Request(rec, problem, budget, cfg)
        self._requests[rid] = req

        if self.cfg.cache and key in self._cache:
            # Duplicate request: served at the door. The cached result's
            # designs/front are returned verbatim; the eval/call charge
            # is zeroed because THIS request spent none (the original
            # request's ledger holds the real cost).
            hit = self._cache[key]
            req.result = dataclasses.replace(
                hit, n_evals=0, n_calls=0, wall_s=0.0,
                extra=dict(hit.extra, cache_hit=True))
            rec["status"] = "done"
            if self.journal is not None:
                self.journal.save_result(int(seq), req.result.to_json())
            self._persist(req)
            return {"id": rid, "status": "done", "cache_hit": True}

        self._persist(req)
        return {"id": rid, "status": "queued", "cache_hit": False}

    # ------------------------------------------------------------- queries
    def status(self, request_id: str | None = None) -> dict:
        if request_id is None:
            counts: dict[str, int] = {}
            for req in self._requests.values():
                counts[req.status] = counts.get(req.status, 0) + 1
            return {"requests": len(self._requests), "by_status": counts,
                    "wave": self._wave, "cache_entries": len(self._cache)}
        req = self._requests.get(str(request_id))
        if req is None:
            return AdmissionRejected(
                "unknown_request", f"no request {request_id!r}").to_json()
        return {"id": req.rec["id"], "tenant": req.rec["tenant"],
                "status": req.status,
                "rounds_done": req.sm.next_round if req.sm else 0,
                "wall_spent_s": req.rec["wall_spent_s"],
                "error": req.rec.get("error")}

    def result(self, request_id: str) -> RunResult | dict:
        """The finished :class:`RunResult`, or a structured error dict
        for unknown/unfinished/errored requests."""
        req = self._requests.get(str(request_id))
        if req is None:
            return AdmissionRejected(
                "unknown_request", f"no request {request_id!r}").to_json()
        if req.result is None:
            code = ("request_failed" if req.status in ("error", "cancelled")
                    else "not_finished")
            return AdmissionRejected(
                code, f"request {request_id!r} is {req.status}: "
                      f"{req.rec.get('error') or 'no result available'}"
            ).to_json()
        return req.result

    def cancel(self, request_id: str) -> dict:
        """Cancel a live request: queued requests terminate immediately,
        running ones finalize as their partial best-so-far front. Fleet
        slots are reclaimed — the next wave simply no longer builds its
        rounds."""
        req = self._requests.get(str(request_id))
        if req is None:
            return AdmissionRejected(
                "unknown_request", f"no request {request_id!r}").to_json()
        if not req.live:
            return self.status(request_id)
        if req.sm is None:                     # queued: nothing ran yet
            req.rec["status"] = "cancelled"
            req.rec["error"] = "cancelled before dispatch"
            self._persist(req)
        else:
            self._finalize(req, partial=True, note="cancelled")
        return self.status(request_id)

    # ---------------------------------------------------------- wave pump
    def step(self) -> bool:
        """Advance every running request by one sync round via one fleet
        wave; returns whether any request is still live. Deterministic:
        requests advance in admission order, shards in worker order."""
        wave = self._wave
        self._wave += 1
        t0 = time.perf_counter()

        for req in list(self._requests.values()):
            if req.status == "queued":
                self._start(req)
        running = [r for r in self._requests.values()
                   if r.status == "running"]

        # Deadlines are checked before building: an overdue request's
        # slots go to the tenants that still have time.
        for req in running:
            dl = req.rec.get("deadline_s")
            if dl is not None and req.rec["wall_spent_s"] >= dl:
                self._finalize(req, partial=True, note="deadline")
        running = [r for r in running if r.status == "running"]

        tasks: list[tuple] = []
        meta: list[tuple[int, int]] = []
        spans: list[tuple[_Request, int, list[int], int, int]] = []
        for req in running:
            sm = req.sm
            if sm.done:
                self._finalize(req)
                continue
            r = sm.next_round
            built = sm.build_round(r)
            if built is None:
                self._save_round(req, r, done=True)
                self._finalize(req)
                continue
            req_tasks, dispatched = built
            if not req_tasks:
                cont = sm.skip_round(r)
                self._save_round(req, r, done=not cont)
                if not cont:
                    self._finalize(req)
                continue
            lo = len(tasks)
            tasks.extend(req_tasks)
            seq = int(req.rec["seq"])
            meta.extend((seq * ROUND_TAG_STRIDE + wid, r)
                        for wid in dispatched)
            spans.append((req, r, dispatched, lo, len(tasks)))

        if tasks:
            from repro.dist import worker as _worker

            results, failures = _worker.execute_shards(
                _worker.run_shard_round, tasks, self.cfg.executor,
                pool=self._pool, meta=meta,
                timeout_s=self.cfg.shard_timeout_s,
                max_retries=self.cfg.max_retries,
                backoff_s=self.cfg.retry_backoff_s,
                retry_args=reseed_round_args,
                injector=self._wave_injector(spans, wave),
                validate=validate_round_payload)
            elapsed = time.perf_counter() - t0
            for req, r, dispatched, lo, hi in spans:
                req_results = {i - lo: results[i]
                               for i in results if lo <= i < hi}
                req_failures = {}
                for i in failures:
                    if not lo <= i < hi:
                        continue
                    recs = []
                    for rec in failures[i]:
                        rec = dict(rec)
                        # Untag the wave id back to the fleet worker id —
                        # the request's ledger speaks worker terms.
                        rec["worker_id"] = int(
                            rec["worker_id"]) % ROUND_TAG_STRIDE
                        recs.append(rec)
                    req_failures[i - lo] = recs
                cont = req.sm.absorb_round(r, dispatched, req_results,
                                           req_failures)
                req.rec["wall_spent_s"] = (
                    float(req.rec["wall_spent_s"]) + elapsed)
                self._save_round(req, r, done=not cont)
                self._persist(req)
                if not cont:
                    self._finalize(req)

        if self.injector is not None and self.injector.kills_server(wave):
            raise ServerKilled(
                f"injected server kill after wave {wave} (journal and "
                "round checkpoints saved; restart against the same "
                "journal_dir resumes)")
        return any(r.live for r in self._requests.values())

    def run_until_idle(self, max_waves: int = ROUND_TAG_STRIDE) -> dict:
        """Pump :meth:`step` until no request is live; returns the
        service-level :meth:`status` summary."""
        waves = 0
        while self.step():
            waves += 1
            if waves >= max_waves:
                raise RuntimeError(
                    f"service did not drain within {max_waves} waves")
        return self.status()

    # ------------------------------------------------------------ internals
    def _save_round(self, req: _Request, r: int, *, done: bool) -> None:
        if req.ckpt is not None:
            req.ckpt.save_round(r, req.sm.snapshot(done=done))

    def _wave_injector(self, spans, wave: int) -> FaultInjector | None:
        """The wave's shard-boundary injector: worker-kind faults from
        the service script pass through (their ``worker_id``, when set,
        matches the *tagged* wave id ``seq * ROUND_TAG_STRIDE + wid``);
        ``slow_tenant`` faults expand into per-dispatch hangs for the
        matched tenant's shards in this wave."""
        faults = [f for f in self.cfg.faults if f["kind"] in FAULT_KINDS]
        if self.injector is not None:
            for req, r, dispatched, _lo, _hi in spans:
                delay = self.injector.slow_tenant_delay(
                    req.rec["tenant"], req.rec["id"], wave)
                if delay > 0:
                    seq = int(req.rec["seq"])
                    faults.extend(
                        {"kind": "hang",
                         "worker_id": seq * ROUND_TAG_STRIDE + wid,
                         "round": r, "attempt": 0, "hang_s": delay}
                        for wid in dispatched)
        return FaultInjector(faults=tuple(faults)) if faults else None

    def _finalize(self, req: _Request, *, partial: bool = False,
                  note: str | None = None) -> None:
        """Merge a request's absorbed rounds into its final RunResult and
        commit it. Write order is the crash-recovery contract: result
        first (the commit point), then the status flip, then cache + gc —
        a crash between any two steps is healed by :meth:`recover`."""
        sm = req.sm
        dist_info = {
            "pool_rebuilds": (self._pool.rebuilds
                              if isinstance(self._pool, ShardPool) else 0),
            "resumed_from_round": sm.resumed_from if sm else None,
            "checkpoint": None,
        }
        if req.ckpt is not None:
            dist_info["checkpoint"] = {
                "dir": req.ckpt.dir, "n_saves": req.ckpt.n_saves,
                "save_s": req.ckpt.save_s,
                "rounds_on_disk": req.ckpt.rounds()}
        try:
            res = package_dist_result(
                req.problem, req.budget, req.cfg,
                sm.results if sm else [], sm.failures if sm else [],
                dist_info,
                [s.budget.seed for s in sm.shards] if sm else [],
                float(req.rec["wall_spent_s"]), partial=partial)
        except RuntimeError as exc:       # every worker failed, not partial
            req.rec["status"] = "error"
            req.rec["error"] = str(exc)
            self._persist(req)
            return
        if note is not None:
            res = dataclasses.replace(res, extra=dict(res.extra, note=note))
        req.result = res
        if self.journal is not None:
            self.journal.save_result(int(req.rec["seq"]), res.to_json())
        req.rec["status"] = "partial" if partial else "done"
        if note is not None:
            req.rec["error"] = note
        self._persist(req)
        if self.cfg.cache and not partial:
            # Partial results are deadline/cancel artifacts — caching
            # them would serve a truncated front to a full-budget twin.
            self._cache.setdefault(req.rec["key"], res)
        if self.journal is not None:
            self.journal.gc_completed(self.cfg.keep_completed)

    def shutdown(self) -> None:
        self._stack.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc_info):
        self.shutdown()
        return False
