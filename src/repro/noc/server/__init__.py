"""``repro.noc.server`` — fault-tolerant multi-tenant optimization
service (DESIGN.md §10).

One shared worker fleet, many concurrent ``(NocProblem, Budget)``
requests, multiplexed at sync-round granularity over the pure-JSON shard
boundary::

    from repro.noc.server import Client

    with Client.local(n_workers=4, journal_dir="journal/") as c:
        ack = c.submit(problem.to_json(), Budget(max_evals=400).to_json(),
                       tenant="alice")
        c.drain()
        front = c.result(ack["id"])        # RunResult

CLI: ``python -m repro.noc serve --journal-dir D`` (stdio JSON lines;
:class:`SubprocessClient` is the matching client transport).

The robustness contract — admission control, backpressure, per-request
deadlines with ``partial`` degradation, fleet supervision, crash-safe
journal + recovery, canonical-key result cache — lives in
:mod:`.service`, :mod:`.admission`, and :mod:`.journal`.
"""

from .admission import (AdmissionRejected, canonical_request_key,
                        normalize_config, validate_request)
from .client import Client, ServerDied, SubprocessClient, serve_stdio
from .journal import RequestJournal
from .service import NocService, ServiceConfig

__all__ = [
    "AdmissionRejected", "Client", "NocService", "RequestJournal",
    "ServerDied", "ServiceConfig", "SubprocessClient",
    "canonical_request_key", "normalize_config", "serve_stdio",
    "validate_request",
]
