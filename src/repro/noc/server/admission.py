"""Admission control for the NoC-optimization service (DESIGN.md §10).

Everything a request can get wrong is rejected HERE, at the door, as a
structured error — never by crashing a worker after fleet budget was
spent on it. Three layers:

* **validation** — the submitted ``(problem, budget, config)`` JSON is
  deserialized through the same canonicalizing ``from_json`` paths the
  shard boundary uses; anything that does not round-trip is an
  ``invalid_problem`` / ``invalid_budget`` / ``invalid_config``
  rejection carrying the parse error. Config keys the service owns
  (checkpointing, fault scripts) are rejected explicitly rather than
  silently dropped.
* **backpressure** — a bounded request queue (``queue_full``) and a
  per-tenant in-flight cap (``tenant_cap``), both checked before any
  state is allocated.
* **canonical request keys** — :func:`canonical_request_key` hashes the
  canonicalized problem/budget JSON plus the trajectory-shaping config
  fields (:data:`repro.dist.state.TRAJECTORY_FIELDS`). Two requests get
  the same key iff they would produce the same result: dict ordering,
  float spelling (``2`` vs ``2.0`` both parse to the same float), and
  omitted back-compat defaults all hash identically, while a different
  seed (inside the budget) or any trajectory knob does not. The key is
  the result-cache identity — a duplicate request costs zero evals.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.core.traffic import TrafficValidationError
from repro.dist.state import TRAJECTORY_FIELDS
from repro.noc.api import Budget, NocProblem
from repro.noc.optimizers import StageDistConfig

#: request-config keys owned by the service: checkpoints live under the
#: service journal, fault scripts come from the ServiceConfig, and
#: executor placement is a fleet property. A request naming any of these
#: is confused about the contract — reject loudly.
SERVICE_OWNED_KEYS = ("checkpoint_dir", "resume", "faults", "executor")


class AdmissionRejected(ValueError):
    """A request the service refuses to run, with a machine-readable
    ``code`` — the structured-error contract of the admission layer."""

    def __init__(self, code: str, message: str):
        super().__init__(message)
        self.code = str(code)

    def to_json(self) -> dict:
        return {"error": {"code": self.code, "message": str(self)}}


def validate_request(problem_json, budget_json, config_json=None,
                     ) -> tuple[NocProblem, Budget, StageDistConfig]:
    """Deserialize and canonicalize one request, or raise
    :class:`AdmissionRejected` with the layer that failed.

    The returned config is NOT yet fleet-normalized (executor, resilience
    knobs) — that is the service's job; this only proves the request is
    well-formed enough to ever run."""
    if not isinstance(problem_json, dict):
        raise AdmissionRejected(
            "invalid_problem",
            f"problem must be a JSON object, got {type(problem_json).__name__}")
    try:
        problem = NocProblem.from_json(problem_json)
    except TrafficValidationError as exc:
        # bad traffic content (NaN/negative/zero-sum matrix, unknown
        # model/phase/app name, non-tiling mesh) — distinct from a
        # structurally malformed problem so clients can tell them apart.
        raise AdmissionRejected("invalid_traffic", str(exc))
    except Exception as exc:  # noqa: BLE001 — anything malformed lands here
        raise AdmissionRejected(
            "invalid_problem",
            f"problem does not deserialize: {type(exc).__name__}: {exc}")
    if not isinstance(budget_json, dict):
        raise AdmissionRejected(
            "invalid_budget",
            f"budget must be a JSON object, got {type(budget_json).__name__}")
    try:
        budget = Budget.from_json(budget_json)
    except Exception as exc:  # noqa: BLE001
        raise AdmissionRejected(
            "invalid_budget",
            f"budget does not deserialize: {type(exc).__name__}: {exc}")
    if budget.max_evals is None and budget.max_calls is None:
        raise AdmissionRejected(
            "invalid_budget",
            "service requests must be bounded: set max_evals and/or "
            "max_calls (an unbounded request would hold fleet slots forever)")
    config_json = config_json or {}
    if not isinstance(config_json, dict):
        raise AdmissionRejected(
            "invalid_config",
            f"config must be a JSON object, got {type(config_json).__name__}")
    owned = [k for k in SERVICE_OWNED_KEYS if k in config_json]
    if owned:
        raise AdmissionRejected(
            "invalid_config",
            f"config keys {owned} are service-owned (checkpointing, fault "
            "policy, and executor placement are fleet properties); remove "
            "them from the request")
    try:
        cfg = StageDistConfig(**config_json)
    except Exception as exc:  # noqa: BLE001
        raise AdmissionRejected(
            "invalid_config",
            f"config rejected: {type(exc).__name__}: {exc}")
    return problem, budget, cfg


def _canon(x):
    """Numeric canonicalization for the cache key: an integral float
    (``120.0``, ``1.2e2``) hashes like the int ``120`` — JSON spelling
    must not split the cache. Fractional floats are stable already
    (json.dumps emits the shortest round-trip repr)."""
    if isinstance(x, float) and x.is_integer():
        return int(x)
    if isinstance(x, dict):
        return {k: _canon(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_canon(v) for v in x]
    return x


def canonical_request_key(problem: NocProblem, budget: Budget,
                          cfg: StageDistConfig) -> str:
    """The result-cache identity of a request (stable sha256 hex digest).

    Hashes the *canonicalized* JSON (``to_json`` after ``from_json`` has
    filled back-compat defaults), serialized with sorted keys — so dict
    ordering and float spelling in the submitted text cannot split the
    cache — plus exactly the config fields that shape the search
    trajectory. Fleet knobs (executor, deadlines, retries) change where
    and how fast a request runs, never what it returns, and are
    deliberately excluded; the seed is inside the budget."""
    ident = _canon({
        "problem": problem.to_json(),
        "budget": budget.to_json(),
        "plan": {f: getattr(cfg, f) for f in TRAJECTORY_FIELDS},
    })
    blob = json.dumps(ident, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def normalize_config(cfg: StageDistConfig, *, executor: str,
                     shard_timeout_s: float | None, max_retries: int,
                     retry_backoff_s: float) -> StageDistConfig:
    """Fleet-normalize an admitted request config: placement and
    resilience knobs come from the service, ``sync_every`` is clamped to
    >= 1 (the service multiplexes requests at sync-round granularity —
    an unsynced request would hold its slots for the whole run), and the
    service-owned fields are forced to their inert values."""
    return dataclasses.replace(
        cfg, executor=executor, sync_every=max(1, cfg.sync_every),
        shard_timeout_s=shard_timeout_s, max_retries=max_retries,
        retry_backoff_s=retry_backoff_s,
        checkpoint_dir=None, resume=False, faults=())
