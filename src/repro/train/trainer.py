"""Fault-tolerant training loop.

Restart semantics: state (params/opt/err) checkpoints atomically; the data
pipeline is stateless in the step index; so resume = restore latest + replay
from that step — no data-loader state, no RNG state files. A run killed at
any point reproduces the uninterrupted loss trajectory (tested).

Straggler mitigation: a per-step deadline watchdog (EMA of step time x
tolerance). On a real fleet the hook triggers the controller (re-shard away
from the slow host / restart it); here the hook records the event and the
trainer keeps going — the detection path is what is exercised."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh

from ..ckpt.checkpoint import CheckpointManager
from ..data.pipeline import DataConfig, SyntheticLM
from ..dist import sharding as shd
from ..models.model import Model
from . import optimizer
from .train_step import make_train_fns


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    straggler_tolerance: float = 3.0   # x EMA step time
    ema_alpha: float = 0.2


class Trainer:
    def __init__(self, model: Model, mesh: Mesh, policy: shd.Policy,
                 opt_cfg: optimizer.OptConfig, data: SyntheticLM,
                 cfg: TrainConfig,
                 straggler_hook: Callable[[int, float, float], None] | None = None):
        self.model = model
        self.mesh = mesh
        self.policy = policy
        self.data = data
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.straggler_hook = straggler_hook or (lambda *a: None)
        self.straggler_events: list[tuple[int, float, float]] = []

        init_state, jitted_step, state_specs = make_train_fns(
            model, mesh, policy, opt_cfg)
        self._init_state = init_state
        self._make_step = jitted_step
        self._state_specs = state_specs
        self.losses: list[tuple[int, float]] = []

    # ------------------------------------------------------------ running
    def _initial_state(self):
        """Restore-from-latest if possible (elastic: re-shard to the current
        mesh), else fresh init."""
        abstract = jax.eval_shape(
            self._init_state, jax.random.PRNGKey(self.cfg.seed))
        specs = self._state_specs(abstract)
        shardings = shd.named(self.mesh, specs)
        if self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(abstract, shardings=shardings)
            return state, step
        with self.mesh:
            # init lands directly on the step function's shardings — avoids a
            # re-compile on the second step (and shards large inits).
            init = jax.jit(self._init_state, out_shardings=shardings)
            return init(jax.random.PRNGKey(self.cfg.seed)), 0

    def run(self, until_step: int | None = None,
            crash_at: int | None = None) -> dict:
        """Train to ``until_step`` (or cfg.steps). ``crash_at`` simulates an
        unclean node failure right after that step (for restart tests)."""
        until = self.cfg.steps if until_step is None else until_step
        state, start = self._initial_state()
        batch0 = self.data.batch(0)
        step_fn = self._make_step(
            jax.eval_shape(lambda: state), jax.eval_shape(lambda: batch0))

        ema = None
        first_measured = True
        with self.mesh:
            for step in range(start, until):
                t0 = time.perf_counter()
                batch = self.data.batch(step)
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0

                if first_measured:
                    # step 0 includes XLA compilation — never let it into the
                    # straggler baseline.
                    first_measured = False
                elif ema is None:
                    ema = dt
                elif dt > self.cfg.straggler_tolerance * ema:
                    self.straggler_events.append((step, dt, ema))
                    self.straggler_hook(step, dt, ema)
                    ema = ema  # do not pollute the EMA with the outlier
                else:
                    ema = (1 - self.cfg.ema_alpha) * ema + self.cfg.ema_alpha * dt

                self.losses.append((step, loss))
                if (step + 1) % self.cfg.ckpt_every == 0 or step + 1 == until:
                    self.ckpt.save(step + 1, state)
                if crash_at is not None and step + 1 >= crash_at:
                    # Simulated hard failure: no final checkpoint, no cleanup.
                    return {"crashed_at": step + 1, "losses": self.losses}

        self.ckpt.save(until, state, blocking=True)
        return {
            "final_step": until,
            "losses": self.losses,
            "final_loss": self.losses[-1][1] if self.losses else None,
            "straggler_events": self.straggler_events,
            "state": state,
        }
