"""Sharding-aware AdamW (hand-rolled; no optax offline) + LR schedules.

Optimizer state mirrors the parameter pytree leaf-for-leaf, so whatever
PartitionSpec a parameter carries, its moments inherit it — FSDP sharding of
optimizer state costs one tree_map."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    ))


def apply(cfg: OptConfig, params: Any, grads: Any, state: dict):
    """One AdamW step with global-norm clipping. Returns (params, state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
