"""Step builders: jitted, sharded train / prefill / decode steps.

make_train_step composes: microbatch gradient accumulation (lax.scan —
overlaps each microbatch's collectives with the next one's compute under the
XLA latency-hiding scheduler), remat (per-layer, set in the model config),
optional int8 gradient compression with error feedback, AdamW, and the
activation/parameter sharding rules from repro.dist.sharding. The returned
callables are what the trainer, the serving engine, and the multi-pod
dry-run lower."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist import sharding as shd
from ..models.common import activation_sharding
from ..models.model import Model
from . import grad_compress, optimizer


def make_train_fns(model: Model, mesh: Mesh, policy: shd.Policy,
                   opt_cfg: optimizer.OptConfig):
    """Returns (init_state_fn, step_fn, state_shardings_fn).

    state = {"params", "opt", "err"?}; step(state, batch) -> (state, metrics).
    """
    cfg = model.cfg
    act_fn = shd.activation_shard_fn(mesh, policy)

    def init_state(key):
        params = model.init(key)
        state = {"params": params, "opt": optimizer.init_state(params)}
        if policy.grad_compress:
            state["err"] = grad_compress.init_error(params)
        return state

    def state_specs(state_like):
        pspecs = shd.param_specs(mesh, policy, state_like["params"])
        out = {
            "params": pspecs,
            "opt": {
                "m": pspecs,
                "v": pspecs,
                "step": P(),
            },
        }
        if "err" in state_like:
            out["err"] = pspecs
        return out

    def loss_fn(params, batch):
        with activation_sharding(act_fn):
            return model.loss(params, batch)

    def grads_of(params, batch):
        k = policy.microbatches
        if k <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mb = jax.tree.map(
            lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

        def acc(carry, mbatch):
            loss_sum, g_sum = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mbatch)
            return (loss_sum + loss,
                    jax.tree.map(jnp.add, g_sum, g)), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros), mb)
        inv = 1.0 / k
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        new_state = dict(state)
        if policy.grad_compress:
            # int8 + error feedback (see grad_compress.py for the wire-level
            # shard_map form; here the quantization semantics apply in-graph).
            def q(g, e):
                _, _, new_e = grad_compress.quantize(g, e)
                deq = g.astype(jnp.float32) + e - new_e
                return deq, new_e
            flat_g, tdef = jax.tree.flatten(grads)
            flat_e = tdef.flatten_up_to(state["err"])
            pairs = [q(g, e) for g, e in zip(flat_g, flat_e)]
            grads = tdef.unflatten([p[0] for p in pairs])
            new_state["err"] = tdef.unflatten([p[1] for p in pairs])
        params, opt, stats = optimizer.apply(
            opt_cfg, state["params"], grads, state["opt"])
        new_state["params"] = params
        new_state["opt"] = opt
        return new_state, {"loss": loss, **stats}

    def jitted_step(state_like, batch_like):
        sspecs = state_specs(state_like)
        bspecs = shd.batch_specs(mesh, policy, batch_like)
        return jax.jit(
            step,
            in_shardings=(shd.named(mesh, sspecs), shd.named(mesh, bspecs)),
            out_shardings=(shd.named(mesh, sspecs), None),
            donate_argnums=(0,),
        )

    return init_state, jitted_step, state_specs


def make_prefill_fn(model: Model, mesh: Mesh, policy: shd.Policy):
    cfg = model.cfg
    act_fn = shd.activation_shard_fn(mesh, policy)

    def prefill(params, batch):
        with activation_sharding(act_fn):
            if cfg.family == "encdec":
                return model.prefill(params, batch["frames"],
                                     batch["tokens"],
                                     batch["tokens"].shape[1] + 64)
            return model.prefill(params, batch["tokens"],
                                 batch["tokens"].shape[1])

    def jitted(params_like, batch_like):
        pspecs = shd.param_specs(mesh, policy, params_like)
        bspecs = shd.batch_specs(mesh, policy, batch_like)
        return jax.jit(
            prefill,
            in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, bspecs)),
        )

    return jitted


def make_decode_fn(model: Model, mesh: Mesh, policy: shd.Policy):
    cfg = model.cfg
    act_fn = shd.activation_shard_fn(mesh, policy)

    def decode(params, cache, token):
        with activation_sharding(act_fn):
            return model.decode_step(params, cache, token)

    def jitted(params_like, cache_like, token_like):
        pspecs = shd.param_specs(mesh, policy, params_like)
        cspecs = shd.cache_specs(mesh, policy, cfg, cache_like)
        tspec = shd.batch_specs(mesh, policy, token_like)
        return jax.jit(
            decode,
            in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, cspecs),
                          shd.named(mesh, tspec)),
            out_shardings=(None, shd.named(mesh, cspecs)),
            donate_argnums=(1,),
        )

    return jitted
