from .optimizer import OptConfig
from .trainer import TrainConfig, Trainer
from .train_step import make_decode_fn, make_prefill_fn, make_train_fns
__all__ = ["OptConfig", "TrainConfig", "Trainer", "make_decode_fn",
           "make_prefill_fn", "make_train_fns"]
