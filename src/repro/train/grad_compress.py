"""int8 gradient compression with error feedback (1-bit-Adam-family trick).

Per-tensor symmetric int8 quantization of gradients before the data-parallel
reduction, with the quantization residual fed back into the next step — the
standard convergence-preserving construction. On an int8-collective-capable
runtime the all-reduce payload drops 4x (f32) / 2x (bf16); the roofline
credit is applied to the collective term in EXPERIMENTS.md §Perf.

In-jit usage: quantize -> psum(int32) -> dequantize inside shard_map over
the DP axes (see train_step.py). On this single-process container the psum
is over a size-1 axis, but the lowering is identical — the multi-pod dry-run
shows the int32 all-reduce in the compiled HLO."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """g + err -> (int8 q, f32 scale, new residual)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads: Any, err: Any, axis_names) -> tuple[Any, Any]:
    """Quantize each gradient leaf, all-reduce the int8 payload (as int32
    accumulators, the standard wire format), dequantize, and return the
    averaged gradients + updated error-feedback buffers.

    Must run inside shard_map with ``axis_names`` bound."""
    n_dev = 1
    for ax in axis_names:
        n_dev *= jax.lax.axis_size(ax)

    def leaf(g, e):
        q, scale, new_e = quantize(g, e)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_names)
        # every shard contributes its own scale; average the dequantized sum
        scale_sum = jax.lax.psum(scale, axis_names)
        # upper bound reconstruction: use mean scale for the summed payload
        deq = acc.astype(jnp.float32) * (scale_sum / n_dev)
        return deq / n_dev, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
