"""Application-agnostic NoC design studies (paper §6.4-§6.5, Figs. 9-11).

For every application, optimize (i) an application-specific NoC on its own
traffic and (ii) an 'AVG' NoC on the aggregated leave-one-out traffic of the
*other* applications. Then cross-execute: every NoC runs every application
and its EDP is normalized to that application's own application-specific
NoC. The paper's claim: the AVG NoC's degradation is ~1-2%."""

from __future__ import annotations

import dataclasses

import numpy as np

from .evaluate import Evaluator
from .objectives import make_consts, peak_temperature_celsius
from .problem import Design, SystemSpec
from .traffic import APP_NAMES, avg_traffic, traffic_matrix


@dataclasses.dataclass
class OptimizeBudget:
    """Reduced-budget knobs for the container (paper ran hours on a Xeon).

    Legacy bundle kept for existing call sites; :meth:`to_noc` splits it
    into the unified API's ``(Budget, StageConfig)`` pair."""

    iters_max: int = 4
    n_swaps: int = 16
    n_link_moves: int = 16
    max_local_steps: int = 40
    seed: int = 0

    def to_noc(self):
        """(repro.noc.Budget, repro.noc.StageConfig) for this bundle."""
        from repro.noc import Budget, StageConfig

        return (Budget(seed=self.seed),
                StageConfig(iters_max=self.iters_max, n_swaps=self.n_swaps,
                            n_link_moves=self.n_link_moves,
                            max_local_steps=self.max_local_steps))


def pick_min_edp(ev: Evaluator, designs: list[Design],
                 objs: np.ndarray) -> tuple[Design, np.ndarray]:
    """The paper characterizes each Pareto set by its best network EDP
    (§6.1); select that representative solution."""
    edps = objs[:, 2] * objs[:, 3]
    j = int(np.argmin(edps))
    return designs[j], objs[j]


def optimize_for_traffic(
    spec: SystemSpec,
    f: np.ndarray,
    case: str = "case3",
    budget: OptimizeBudget | None = None,
) -> tuple[Design, np.ndarray, Evaluator]:
    """Thin wrapper over the unified ``repro.noc`` API: run MOO-STAGE on
    one traffic matrix and return the min-EDP representative design (the
    per-application optimization step of the agnostic study)."""
    from repro.noc import NocProblem, run as noc_run

    budget = budget or OptimizeBudget()
    noc_budget, stage_cfg = budget.to_noc()
    problem = NocProblem(spec=spec, traffic=f, case=case)
    ev = problem.evaluator()
    res = noc_run(problem, "stage", budget=noc_budget, config=stage_cfg,
                  ev=ev)
    d, o = pick_min_edp(ev, res.designs, np.asarray(res.objs))
    return d, o, ev


def run_agnostic_study(
    spec: SystemSpec,
    apps: tuple[str, ...] = APP_NAMES,
    case: str = "case3",
    budget: OptimizeBudget | None = None,
    include_avg: bool = True,
) -> dict:
    """Returns the Fig. 9/11 cross table.

    result['table'][i, j]: EDP of NoC_i running app_j, normalized by the EDP
    of app_j's own NoC running app_j. result['avg_row'][j]: same for the
    leave-one-out AVG NoC of app_j."""
    budget = budget or OptimizeBudget()
    evs = {a: Evaluator(spec, traffic_matrix(spec, a)) for a in apps}
    designs: dict[str, Design] = {}
    for a in apps:
        d, _, _ = optimize_for_traffic(spec, traffic_matrix(spec, a), case, budget)
        designs[a] = d

    def edp_of(d: Design, app: str) -> float:
        return evs[app].edp(d)

    diag = {a: edp_of(designs[a], a) for a in apps}
    table = np.zeros((len(apps), len(apps)))
    for i, ai in enumerate(apps):
        for j, aj in enumerate(apps):
            table[i, j] = edp_of(designs[ai], aj) / diag[aj]

    out = dict(apps=apps, table=table, designs=designs)
    if include_avg:
        avg_row = np.zeros(len(apps))
        avg_designs = {}
        for j, aj in enumerate(apps):
            rest = [x for x in apps if x != aj]
            d, _, _ = optimize_for_traffic(spec, avg_traffic(spec, rest), case, budget)
            avg_designs[aj] = d
            avg_row[j] = edp_of(d, aj) / diag[aj]
        out["avg_row"] = avg_row
        out["avg_designs"] = avg_designs
    return out


def summarize(result: dict) -> dict:
    """Average / worst degradation of off-diagonal and AVG rows (the numbers
    the paper quotes: e.g. 64-tile Case-3: 3.2% avg / 9.8% worst; AVG 1.1%)."""
    t = result["table"]
    off = t[~np.eye(t.shape[0], dtype=bool)]
    out = dict(
        app_specific_avg_degradation=float(off.mean() - 1.0),
        app_specific_worst_degradation=float(off.max() - 1.0),
    )
    if "avg_row" in result:
        out["avg_noc_degradation"] = float(result["avg_row"].mean() - 1.0)
        out["avg_noc_worst"] = float(result["avg_row"].max() - 1.0)
    return out


def thermal_study(
    spec: SystemSpec,
    app: str,
    budget: OptimizeBudget | None = None,
) -> dict:
    """Fig. 10: Cases 3 (perf-only), 4 (thermal-only), 5 (joint) compared on
    latency proxy, EDP, and peak temperature (deg C)."""
    budget = budget or OptimizeBudget()
    f = traffic_matrix(spec, app)
    consts = make_consts(spec)
    out = {}
    for case in ("case3", "case4", "case5"):
        d, o, ev = optimize_for_traffic(spec, f, case, budget)
        out[case] = dict(
            design=d,
            objs=o,
            edp=ev.edp(d),
            latency=float(o[2]),
            energy=float(o[3]),
            temp_metric=float(o[4]),
            peak_celsius=peak_temperature_celsius(consts, d.perm),
        )
    return out
