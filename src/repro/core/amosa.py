"""AMOSA — Archived Multi-Objective Simulated Annealing (Bandyopadhyay et
al. [10]), the paper's primary baseline (§6.1).

Implements the standard acceptance logic based on the *amount of domination*

    Δdom(a, b) = Π_{i: f_i differs}  |f_i(a) - f_i(b)| / R_i

(objectives normalized by the PHV context so R_i is the mesh-design scale),
with an archive kept non-dominated and thinned to the hard limit by
crowding-distance when it exceeds the soft limit (stand-in for AMOSA's
clustering step; noted in DESIGN.md §5)."""

from __future__ import annotations

import numpy as np

from .evaluate import Evaluator
from .local_search import ParetoSet, SearchHistory
from .pareto import PhvContext, dominates, pareto_mask
from .problem import Design, SystemSpec, sample_neighbors


def _delta_dom(a: np.ndarray, b: np.ndarray) -> float:
    d = np.abs(a - b)
    d = d[d > 1e-15]
    return float(np.prod(d)) if d.size else 0.0


def _crowding_thin(objs: np.ndarray, keep: int) -> np.ndarray:
    """Indices of `keep` rows with largest crowding distance."""
    n, m = objs.shape
    if n <= keep:
        return np.arange(n)
    crowd = np.zeros(n)
    for j in range(m):
        order = np.argsort(objs[:, j], kind="stable")
        rng_j = objs[order[-1], j] - objs[order[0], j] + 1e-12
        crowd[order[0]] = crowd[order[-1]] = np.inf
        crowd[order[1:-1]] += (objs[order[2:], j] - objs[order[:-2], j]) / rng_j
    return np.argsort(-crowd, kind="stable")[:keep]


def amosa(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    d0: Design,
    seed: int = 0,
    *,
    t_max: float = 1.0,
    t_min: float = 1e-4,
    alpha: float = 0.92,
    iters_per_temp: int = 40,
    soft_limit: int = 40,
    hard_limit: int = 24,
    max_evals: int | None = None,
    history: SearchHistory | None = None,
) -> ParetoSet:
    rng = np.random.default_rng(seed)
    history = history or SearchHistory(ev, ctx)

    cur = d0
    cur_obj = ev(cur)
    history.record(ev, cur, cur_obj)
    archive = ParetoSet.empty().merged_with([cur], cur_obj[None], ctx.obj_idx)

    temp = t_max
    while temp > t_min:
        for _ in range(iters_per_temp):
            if max_evals is not None and ev.n_evals >= max_evals:
                return archive
            cands = sample_neighbors(spec, cur, rng, 1, 1)
            if not cands:
                continue
            new = cands[rng.integers(len(cands))]
            new_obj = ev(new)
            history.record(ev, new, new_obj)

            a_n = ctx.normalize(new_obj)
            a_c = ctx.normalize(cur_obj)
            arch_n = ctx.normalize(archive.objs)

            dom_new_by = [
                i for i in range(arch_n.shape[0]) if dominates(arch_n[i], a_n)
            ]
            if dominates(a_c, a_n):
                # Case 1: current dominates new — probabilistic acceptance.
                ddoms = [_delta_dom(arch_n[i], a_n) for i in dom_new_by]
                ddoms.append(_delta_dom(a_c, a_n))
                davg = float(np.mean(ddoms))
                if rng.random() < 1.0 / (1.0 + np.exp(min(davg / max(temp, 1e-9), 50.0))):
                    cur, cur_obj = new, new_obj
            elif dom_new_by:
                # Case 2a: new dominated by archive points.
                davg = float(np.mean([_delta_dom(arch_n[i], a_n) for i in dom_new_by]))
                if rng.random() < 1.0 / (1.0 + np.exp(min(davg / max(temp, 1e-9), 50.0))):
                    cur, cur_obj = new, new_obj
            else:
                # Case 2b/3: new is non-dominated w.r.t. archive (it may
                # dominate some archive members) — accept and archive it.
                cur, cur_obj = new, new_obj
                archive = archive.merged_with([new], new_obj[None], ctx.obj_idx)
                if len(archive.designs) > soft_limit:
                    keep = _crowding_thin(
                        ctx.normalize(archive.objs), hard_limit
                    )
                    archive = ParetoSet(
                        [archive.designs[i] for i in keep], archive.objs[keep]
                    )
        temp *= alpha
    return archive
