"""AMOSA — Archived Multi-Objective Simulated Annealing (Bandyopadhyay et
al. [10]), the paper's primary baseline (§6.1).

Implements the standard acceptance logic based on the *amount of domination*

    Δdom(a, b) = Π_{i: f_i differs}  |f_i(a) - f_i(b)| / R_i

(objectives normalized by the PHV context so R_i is the mesh-design scale),
with an archive kept non-dominated and thinned to the hard limit by
crowding-distance when it exceeds the soft limit (stand-in for AMOSA's
clustering step; noted in DESIGN.md §5).

Candidate scoring is batched two ways: the per-candidate archive scan
(dominance test + Δdom against every archive member) is one vectorized
numpy pass instead of a Python loop, and with ``block_size > 1`` neighbor
proposals are evaluated speculatively in blocks through
``Evaluator.batch`` — the SA chain consumes pre-evaluated candidates one
by one while the current design is unchanged and discards the rest of the
block on acceptance (the chain itself stays exactly sequential). The
default is ``block_size=1``: discarded speculative evaluations count
against ``max_evals``, so eval-budgeted baseline comparisons (Table 2 /
Fig. 6) keep the sequential chain's exact accounting; raise it when
wall-clock matters more than the budget bookkeeping.

``adaptive_block=True`` reclaims most of the speculation waste: the block
shrinks (halves) every time a proposal is accepted — while acceptance is
hot, speculated candidates are usually discarded — and grows (doubles, up
to ``block_max``) after a full block is consumed without an acceptance, as
the cooling chain settles into long rejection runs where speculation is
nearly free. Blocks are additionally clipped to the remaining ``max_evals``
budget, so an adaptive run never evaluates past its budget."""

from __future__ import annotations

import numpy as np

from .evaluate import Evaluator
from .local_search import ParetoSet, SearchHistory
from .pareto import PhvContext, dominates, pareto_mask
from .problem import Design, SystemSpec, sample_neighbors


def _delta_dom(a: np.ndarray, b: np.ndarray) -> float:
    d = np.abs(a - b)
    d = d[d > 1e-15]
    return float(np.prod(d)) if d.size else 0.0


def _delta_dom_rows(arch: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-wise Δdom(arch[i], b) — the vectorized form of
    :func:`_delta_dom` (filling ignored coords with 1.0 keeps the product
    bit-equal; rows with no differing coordinate score 0.0)."""
    d = np.abs(arch - b[None, :])
    differs = d > 1e-15
    prod = np.prod(np.where(differs, d, 1.0), axis=1)
    return np.where(differs.any(axis=1), prod, 0.0)


def _crowding_thin(objs: np.ndarray, keep: int) -> np.ndarray:
    """Indices of `keep` rows with largest crowding distance."""
    n, m = objs.shape
    if n <= keep:
        return np.arange(n)
    crowd = np.zeros(n)
    for j in range(m):
        order = np.argsort(objs[:, j], kind="stable")
        rng_j = objs[order[-1], j] - objs[order[0], j] + 1e-12
        crowd[order[0]] = crowd[order[-1]] = np.inf
        crowd[order[1:-1]] += (objs[order[2:], j] - objs[order[:-2], j]) / rng_j
    return np.argsort(-crowd, kind="stable")[:keep]


def amosa(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    d0: Design,
    seed: int = 0,
    *,
    t_max: float = 1.0,
    t_min: float = 1e-4,
    alpha: float = 0.92,
    iters_per_temp: int = 40,
    soft_limit: int = 40,
    hard_limit: int = 24,
    max_evals: int | None = None,
    history: SearchHistory | None = None,
    block_size: int = 1,
    adaptive_block: bool = False,
    block_max: int = 16,
) -> ParetoSet:
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    rng = np.random.default_rng(seed)
    history = history or SearchHistory(ev, ctx)

    cur = d0
    cur_obj = ev(cur)
    history.record(ev, cur, cur_obj)
    archive = ParetoSet.empty().merged_with([cur], cur_obj[None], ctx.obj_idx)
    block: list[tuple[Design, np.ndarray]] = []
    # Adaptive mode starts from the configured block_size (default 1) and
    # moves within [1, block_max] as the acceptance rate evolves.
    cur_block = min(block_size, block_max) if adaptive_block else block_size
    rejects_in_row = 0  # consecutive rejections since the last acceptance

    temp = t_max
    while temp > t_min:
        for _ in range(iters_per_temp):
            if max_evals is not None and ev.n_evals >= max_evals:
                return archive
            if not block:
                # Speculatively evaluate a block of neighbors of ``cur`` in
                # one padded batch; they stay valid proposals until ``cur``
                # changes (acceptance clears the block below).
                bs = cur_block
                if max_evals is not None:
                    bs = min(bs, max_evals - ev.n_evals)  # never overshoot
                props: list[Design] = []
                for _ in range(bs):
                    cands = sample_neighbors(spec, cur, rng, 1, 1)
                    if cands:
                        props.append(cands[rng.integers(len(cands))])
                if not props:
                    continue
                objs = ev.batch(props)
                for d, o in zip(props, objs):
                    history.record(ev, d, o)
                block = list(zip(props, objs))
            new, new_obj = block.pop(0)

            a_n = ctx.normalize(new_obj)
            a_c = ctx.normalize(cur_obj)
            arch_n = ctx.normalize(archive.objs)

            # Vectorized archive scan: which members dominate the candidate,
            # and their amounts of domination — one pass, no Python loop.
            dom_new_by = np.flatnonzero(
                np.all(arch_n <= a_n, axis=1) & np.any(arch_n < a_n, axis=1))
            accepted = False
            if dominates(a_c, a_n):
                # Case 1: current dominates new — probabilistic acceptance.
                ddoms = np.append(_delta_dom_rows(arch_n[dom_new_by], a_n),
                                  _delta_dom(a_c, a_n))
                davg = float(np.mean(ddoms))
                if rng.random() < 1.0 / (1.0 + np.exp(min(davg / max(temp, 1e-9), 50.0))):
                    cur, cur_obj = new, new_obj
                    accepted = True
            elif dom_new_by.size:
                # Case 2a: new dominated by archive points.
                davg = float(np.mean(_delta_dom_rows(arch_n[dom_new_by], a_n)))
                if rng.random() < 1.0 / (1.0 + np.exp(min(davg / max(temp, 1e-9), 50.0))):
                    cur, cur_obj = new, new_obj
                    accepted = True
            else:
                # Case 2b/3: new is non-dominated w.r.t. archive (it may
                # dominate some archive members) — accept and archive it.
                cur, cur_obj = new, new_obj
                accepted = True
                archive = archive.merged_with([new], new_obj[None], ctx.obj_idx)
                if len(archive.designs) > soft_limit:
                    keep = _crowding_thin(
                        ctx.normalize(archive.objs), hard_limit
                    )
                    archive = ParetoSet(
                        [archive.designs[i] for i in keep], archive.objs[keep]
                    )
            if accepted:
                block.clear()  # remaining proposals are stale neighbors
                rejects_in_row = 0
                if adaptive_block:
                    # Acceptance is hot: speculated evals mostly get thrown
                    # away, so shrink the next block.
                    cur_block = max(1, cur_block // 2)
            else:
                rejects_in_row += 1
                if adaptive_block and rejects_in_row >= cur_block:
                    # A full block survived without acceptance — the chain
                    # is cooling; speculate deeper next time.
                    cur_block = min(block_max, cur_block * 2)
                    rejects_in_row = 0
        temp *= alpha
    return archive
