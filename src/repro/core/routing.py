"""Routing model: all-pairs shortest paths + deterministic path walking (JAX).

The paper routes on irregular topologies with ALASH (layered shortest-path)
[40]. For the *analytical* objectives only a deterministic single path per
(src, dst) is needed (Eq. 1 note: "the path ... is determined by the routing
algorithm"), so we model routing as minimum-latency shortest path with
lexicographic tie-breaking:

    cost(hop over link (a, b)) = r + d_ab     (router stages + wire delay)

APSP is computed by min-plus matrix squaring — O(log N) dense min-plus
matmuls, which is the TPU-friendly formulation (see kernels/minplus for the
Pallas version; the jnp path here is the oracle). Next hops follow the
Bellman condition nh[i,j] = argmin_m cost[i,m] + dist[m,j], and a vectorized
walk over all N^2 pairs accumulates, in one pass:

  * per-pair hop count h_ij and wire delay d_ij     (Eq. 1),
  * f-weighted directed link utilization U          (Eq. 2),
  * f-weighted router visit counts                  (Eq. 8).

Routing backends
----------------
The batched entry points (:func:`apsp_batched`,
:func:`routing_tables_batched`) accept ``backend``:

  * ``"jnp"``    — vmapped jnp min-plus squaring; the oracle and the CPU
                   execution path. Materializes an (N, N, N) broadcast per
                   design.
  * ``"pallas"`` — the blocked VMEM-tiled kernel in kernels/minplus; the
                   TPU hot path of core.evaluate.Evaluator. ``interpret=True``
                   runs it through the Pallas interpreter on CPU (tests).
  * ``"auto"``   — ``"pallas"`` on TPU, ``"jnp"`` elsewhere (the default).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

INF = 1.0e9

BACKENDS = ("auto", "jnp", "pallas")


def apsp_iters(n_tiles: int) -> int:
    """Min-plus squaring iterations guaranteeing APSP convergence for an
    N-node graph (single source of truth for the analytical evaluator and
    the flit simulator's host-side tables)."""
    return math.ceil(math.log2(n_tiles)) + 1


def resolve_backend(backend: str | None = None) -> str:
    """Resolve ``backend`` (or the default ``"auto"``) to a concrete one."""
    b = backend if backend is not None else "auto"
    if b not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {b!r}")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "jnp"
    return b


def min_plus(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(N,N) min-plus product: out[i,j] = min_k a[i,k] + b[k,j]."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def apsp(cost: jnp.ndarray, n_iters: int) -> jnp.ndarray:
    """All-pairs shortest path distances by repeated min-plus squaring.

    ``cost`` must have 0 on the diagonal and INF for absent edges.
    ``n_iters >= ceil(log2(N))`` guarantees convergence."""
    def body(_, d):
        return min_plus(d, d)

    return jax.lax.fori_loop(0, n_iters, body, cost)


def next_hop(cost: jnp.ndarray, dist: jnp.ndarray) -> jnp.ndarray:
    """nh[i, j] = first-index argmin_m (cost[i, m] + dist[m, j]).

    Deterministic single-path routing: ties break toward the lowest slot
    index (stands in for ALASH's layered escape-path determinism)."""
    n = cost.shape[0]
    # scores[i, m, j]: go from i to neighbor m then shortest to j. Staying
    # put (m == i, cost 0) must not be a candidate hop.
    step_cost = jnp.where(jnp.eye(n, dtype=bool), INF, cost)
    scores = step_cost[:, :, None] + dist[None, :, :]
    nh = jnp.argmin(scores, axis=1).astype(jnp.int32)  # (N, N)
    eye = jnp.arange(n, dtype=jnp.int32)
    # For i == j route nowhere (stay).
    return jnp.where(jnp.eye(n, dtype=bool), eye[:, None], nh)


@partial(jax.jit, static_argnames=("max_hops",))
def walk_paths(
    nh: jnp.ndarray,          # (N, N) int32 next hops
    link_delay: jnp.ndarray,  # (N, N) wire delay per directed edge
    f: jnp.ndarray,           # (N, N) traffic between SLOTS (already permuted)
    max_hops: int,
):
    """Walk every (src, dst) pair simultaneously for ``max_hops`` steps.

    Returns (hops, delay, util, visits, all_done):
      hops   (N, N) — links on the path i->j
      delay  (N, N) — sum of wire delays along the path
      util   (N, N) — f-weighted directed link usage  (Eq. 2 accumulation)
      visits (N,)   — f-weighted router traversals (src router included at
                      each step; dst router added at completion)  (Eq. 8)
      all_done ()   — bool: every pair reached its destination
    """
    n = nh.shape[0]
    src = jnp.arange(n, dtype=jnp.int32)[:, None] * jnp.ones((1, n), jnp.int32)
    dst = jnp.arange(n, dtype=jnp.int32)[None, :] * jnp.ones((n, 1), jnp.int32)

    def body(_, carry):
        cur, hops, delay, util, visits = carry
        done = cur == dst
        nxt = nh[cur, dst]
        w = jnp.where(done, 0.0, f)
        util = util.at[cur, nxt].add(w)
        visits = visits.at[cur].add(w)
        delay = delay + jnp.where(done, 0.0, link_delay[cur, nxt])
        hops = hops + jnp.where(done, 0, 1)
        cur = jnp.where(done, cur, nxt)
        return cur, hops, delay, util, visits

    cur0 = src
    hops0 = jnp.zeros((n, n), jnp.int32)
    delay0 = jnp.zeros((n, n), jnp.float32)
    util0 = jnp.zeros((n, n), jnp.float32)
    visits0 = jnp.zeros((n,), jnp.float32)
    cur, hops, delay, util, visits = jax.lax.fori_loop(
        0, max_hops, body, (cur0, hops0, delay0, util0, visits0)
    )
    all_done = jnp.all(cur == dst)
    # Destination router traversal (h hops -> h+1 routers).
    visits = visits + f.sum(axis=0)
    return hops, delay, util, visits, all_done


def routing_tables(cost: jnp.ndarray, n_iters: int):
    """Convenience: (dist, next_hop) from a hop-cost matrix."""
    dist = apsp(cost, n_iters)
    return dist, next_hop(cost, dist)


# ----------------------------------------------------------------- batched
@partial(jax.jit, static_argnames=("n_iters",))
def _apsp_batched_jnp(cost: jnp.ndarray, n_iters: int) -> jnp.ndarray:
    return jax.vmap(lambda c: apsp(c, n_iters))(cost)


_next_hop_batched = jax.jit(jax.vmap(next_hop))


def apsp_batched(
    cost: jnp.ndarray,  # (B, N, N)
    n_iters: int,
    *,
    backend: str | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched APSP over a stack of cost matrices on the selected backend."""
    if resolve_backend(backend) == "pallas":
        from ..kernels import minplus as _minplus  # deferred: keeps core importable sans kernels

        return _minplus.apsp(cost, n_iters, interpret=interpret)
    return _apsp_batched_jnp(cost, n_iters)


def routing_tables_batched(
    cost: jnp.ndarray,  # (B, N, N)
    n_iters: int,
    *,
    backend: str | None = None,
    interpret: bool = False,
):
    """Batched (dist, next_hop). APSP runs on ``backend``; the argmin-based
    next-hop extraction is cheap and always runs on the jnp path."""
    dist = apsp_batched(cost, n_iters, backend=backend, interpret=interpret)
    return dist, _next_hop_batched(cost, dist)
