"""Routing model: all-pairs shortest paths + deterministic path walking (JAX).

The paper routes on irregular topologies with ALASH (layered shortest-path)
[40]. For the *analytical* objectives only a deterministic single path per
(src, dst) is needed (Eq. 1 note: "the path ... is determined by the routing
algorithm"), so we model routing as minimum-latency shortest path with
lexicographic tie-breaking:

    cost(hop over link (a, b)) = r + d_ab     (router stages + wire delay)

APSP is computed by min-plus matrix squaring — O(log N) dense min-plus
matmuls, which is the TPU-friendly formulation (see kernels/minplus for the
Pallas version; the jnp path here is the oracle). Next hops follow the
Bellman condition nh[i,j] = argmin_m cost[i,m] + dist[m,j], and a vectorized
walk over all N^2 pairs accumulates, in one pass:

  * per-pair hop count h_ij and wire delay d_ij     (Eq. 1),
  * f-weighted directed link utilization U          (Eq. 2),
  * f-weighted router visit counts                  (Eq. 8).

Routing backends
----------------
The batched entry points (:func:`apsp_batched`,
:func:`routing_tables_batched`) accept ``backend``:

  * ``"jnp"``    — vmapped jnp min-plus squaring; the oracle and the CPU
                   execution path. Materializes an (N, N, N) broadcast per
                   design.
  * ``"pallas"`` — the blocked VMEM-tiled kernel in kernels/minplus; the
                   TPU hot path of core.evaluate.Evaluator. ``interpret=True``
                   runs it through the Pallas interpreter on CPU (tests).
  * ``"auto"``   — ``"pallas"`` on TPU, ``"jnp"`` elsewhere (the default).
"""

from __future__ import annotations

import math
from functools import lru_cache, partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INF = 1.0e9

BACKENDS = ("auto", "jnp", "pallas")

#: Largest N served by the one-shot (N, N, N) broadcast formulations of
#: min-plus and next-hop extraction (256³ f32 = 64 MiB transient). Above it
#: the k-/j-blocked paths run instead — bit-equal (min/argmin over exactly
#: the same f32 sums; every finite path cost is a small integer, exactly
#: representable), but never materializing an (N, N, N) intermediate.
DENSE_NMAX = 256

#: Transient budget for one blocked (N, block, N) broadcast slab.
_BLOCK_BUDGET_BYTES = 128 << 20


def _pow2_block(n: int, budget_bytes: int = _BLOCK_BUDGET_BYTES,
                lo: int = 4, hi: int = 128) -> int:
    """Largest power-of-two block b with n·b·n f32 <= ``budget_bytes``
    (clamped to [lo, hi]) — the k/j block width of the memory-safe paths."""
    b = max(1, budget_bytes // (4 * n * n))
    b = 1 << (b.bit_length() - 1)
    return int(min(hi, max(lo, b)))


def apsp_iters(n_tiles: int) -> int:
    """Min-plus squaring iterations guaranteeing APSP convergence for an
    N-node graph (single source of truth for the analytical evaluator and
    the flit simulator's host-side tables)."""
    return math.ceil(math.log2(n_tiles)) + 1


def resolve_backend(backend: str | None = None) -> str:
    """Resolve ``backend`` (or the default ``"auto"``) to a concrete one."""
    b = backend if backend is not None else "auto"
    if b not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {b!r}")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "jnp"
    return b


def min_plus(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(N,N) min-plus product: out[i,j] = min_k a[i,k] + b[k,j].

    One-shot broadcast — materializes an (N, N, N) intermediate, so it is
    only dispatched for N <= DENSE_NMAX (see :func:`min_plus_blocked`)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def min_plus_blocked(a: jnp.ndarray, b: jnp.ndarray,
                     block_k: int | None = None) -> jnp.ndarray:
    """k-blocked min-plus product: bit-equal to :func:`min_plus` (minimum is
    exact and associative; blocking only reorders the reduction) with an
    (N, block_k, N) transient instead of (N, N, N)."""
    n = a.shape[-1]
    bk = min(n, block_k if block_k is not None else _pow2_block(n))
    nb = -(-n // bk)
    pad = nb * bk - n
    # INF-padded phantom k's can't win the min: INF + x >= INF in f32
    # round-to-nearest, and every real entry is bounded by the diagonal-zero
    # term at INF = 1e9.
    a_p = jnp.pad(a, ((0, 0), (0, pad)), constant_values=INF)
    b_p = jnp.pad(b, ((0, pad), (0, 0)), constant_values=INF)

    def body(acc, k0):
        ab = jax.lax.dynamic_slice_in_dim(a_p, k0, bk, axis=1)   # (N, bk)
        bb = jax.lax.dynamic_slice_in_dim(b_p, k0, bk, axis=0)   # (bk, N)
        acc = jnp.minimum(acc, jnp.min(ab[:, :, None] + bb[None, :, :],
                                       axis=1))
        return acc, None

    init = jnp.full((n, n), INF, dtype=a.dtype)
    out, _ = jax.lax.scan(body, init, jnp.arange(nb, dtype=jnp.int32) * bk)
    return out


def apsp(cost: jnp.ndarray, n_iters: int) -> jnp.ndarray:
    """All-pairs shortest path distances by repeated min-plus squaring.

    ``cost`` must have 0 on the diagonal and INF for absent edges.
    ``n_iters >= ceil(log2(N))`` guarantees convergence. Above DENSE_NMAX
    tiles the k-blocked product runs instead of the one-shot broadcast —
    identical results, memory-safe at 1024+ tiles."""
    n = cost.shape[-1]
    mp = min_plus if n <= DENSE_NMAX else min_plus_blocked

    def body(_, d):
        return mp(d, d)

    return jax.lax.fori_loop(0, n_iters, body, cost)


def next_hop(cost: jnp.ndarray, dist: jnp.ndarray) -> jnp.ndarray:
    """nh[i, j] = first-index argmin_m (cost[i, m] + dist[m, j]).

    Deterministic single-path routing: ties break toward the lowest slot
    index (stands in for ALASH's layered escape-path determinism)."""
    n = cost.shape[0]
    # scores[i, m, j]: go from i to neighbor m then shortest to j. Staying
    # put (m == i, cost 0) must not be a candidate hop.
    step_cost = jnp.where(jnp.eye(n, dtype=bool), INF, cost)
    if n <= DENSE_NMAX:
        scores = step_cost[:, :, None] + dist[None, :, :]
        nh = jnp.argmin(scores, axis=1).astype(jnp.int32)  # (N, N)
    else:
        # j-blocked: per destination block an (N, N, bj) score slab. argmin
        # over axis 1 is independent per j-column, so blocking over j is
        # bit-equal to the one-shot form (same first-index tie-breaking).
        bj = min(n, _pow2_block(n))
        nb = -(-n // bj)
        pad = nb * bj - n
        dist_p = jnp.pad(dist, ((0, 0), (0, pad)), constant_values=INF)

        def body(_, j0):
            db = jax.lax.dynamic_slice_in_dim(dist_p, j0, bj, axis=1)
            sc = step_cost[:, :, None] + db[None, :, :]      # (N, N, bj)
            return None, jnp.argmin(sc, axis=1).astype(jnp.int32)

        _, cols = jax.lax.scan(body, None,
                               jnp.arange(nb, dtype=jnp.int32) * bj)
        nh = jnp.moveaxis(cols, 0, 1).reshape(n, nb * bj)[:, :n]
    eye = jnp.arange(n, dtype=jnp.int32)
    # For i == j route nowhere (stay).
    return jnp.where(jnp.eye(n, dtype=bool), eye[:, None], nh)


@partial(jax.jit, static_argnames=("max_hops",))
def walk_paths(
    nh: jnp.ndarray,          # (N, N) int32 next hops
    link_delay: jnp.ndarray,  # (N, N) wire delay per directed edge
    f: jnp.ndarray,           # (N, N) traffic between SLOTS (already permuted)
    max_hops: int,
):
    """Walk every (src, dst) pair simultaneously for ``max_hops`` steps.

    Returns (hops, delay, util, visits, all_done):
      hops   (N, N) — links on the path i->j
      delay  (N, N) — sum of wire delays along the path
      util   (N, N) — f-weighted directed link usage  (Eq. 2 accumulation)
      visits (N,)   — f-weighted router traversals (src router included at
                      each step; dst router added at completion)  (Eq. 8)
      all_done ()   — bool: every pair reached its destination
    """
    n = nh.shape[0]
    src = jnp.arange(n, dtype=jnp.int32)[:, None] * jnp.ones((1, n), jnp.int32)
    dst = jnp.arange(n, dtype=jnp.int32)[None, :] * jnp.ones((n, 1), jnp.int32)

    def body(_, carry):
        cur, hops, delay, util, visits = carry
        done = cur == dst
        nxt = nh[cur, dst]
        w = jnp.where(done, 0.0, f)
        util = util.at[cur, nxt].add(w)
        visits = visits.at[cur].add(w)
        delay = delay + jnp.where(done, 0.0, link_delay[cur, nxt])
        hops = hops + jnp.where(done, 0, 1)
        cur = jnp.where(done, cur, nxt)
        return cur, hops, delay, util, visits

    cur0 = src
    hops0 = jnp.zeros((n, n), jnp.int32)
    delay0 = jnp.zeros((n, n), jnp.float32)
    util0 = jnp.zeros((n, n), jnp.float32)
    visits0 = jnp.zeros((n,), jnp.float32)
    cur, hops, delay, util, visits = jax.lax.fori_loop(
        0, max_hops, body, (cur0, hops0, delay0, util0, visits0)
    )
    all_done = jnp.all(cur == dst)
    # Destination router traversal (h hops -> h+1 routers).
    visits = visits + f.sum(axis=0)
    return hops, delay, util, visits, all_done


def routing_tables(cost: jnp.ndarray, n_iters: int):
    """Convenience: (dist, next_hop) from a hop-cost matrix."""
    dist = apsp(cost, n_iters)
    return dist, next_hop(cost, dist)


# ----------------------------------------------------------------- batched
@partial(jax.jit, static_argnames=("n_iters",))
def _apsp_batched_jnp(cost: jnp.ndarray, n_iters: int) -> jnp.ndarray:
    return jax.vmap(lambda c: apsp(c, n_iters))(cost)


_next_hop_batched = jax.jit(jax.vmap(next_hop))


def apsp_batched(
    cost: jnp.ndarray,  # (B, N, N)
    n_iters: int,
    *,
    backend: str | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched APSP over a stack of cost matrices on the selected backend."""
    if resolve_backend(backend) == "pallas":
        from ..kernels import minplus as _minplus  # deferred: keeps core importable sans kernels

        return _minplus.apsp(cost, n_iters, interpret=interpret)
    return _apsp_batched_jnp(cost, n_iters)


def routing_tables_batched(
    cost: jnp.ndarray,  # (B, N, N)
    n_iters: int,
    *,
    backend: str | None = None,
    interpret: bool = False,
):
    """Batched (dist, next_hop). APSP runs on ``backend``; the argmin-based
    next-hop extraction is cheap and always runs on the jnp path."""
    dist = apsp_batched(cost, n_iters, backend=backend, interpret=interpret)
    return dist, _next_hop_batched(cost, dist)


# ----------------------------------------------------- host mirrors + deltas
# Exact numpy twins of the device tables, the substrate of incremental
# per-move evaluation (Evaluator.batch_moves). Bit-parity with the jnp path
# rests on integer exactness: every edge cost is a small integer held in f32
# (router stages + integer wire/TSV delay), so every finite path cost is an
# integer far below 2^24 and every f32 sum/min is exact; unreachable entries
# are exactly INF = 1e9 (itself f32-exact, and 1e9 + small rounds to >= 1e9),
# so *any* correct shortest-path scheme — device min-plus squaring, host
# blocked squaring, or bounded Bellman relaxation — lands on the same bits.


def min_plus_np(a: np.ndarray, b: np.ndarray,
                block_k: int | None = None) -> np.ndarray:
    """(M,N)x(N,N) min-plus product on host, k-blocked, dtype-preserving
    (f32 in -> f32 out, bit-equal to the device formulations; the delta
    path also runs it on the f64 tie-broken tables)."""
    a = np.asarray(a)
    b = np.asarray(b)
    dt = np.result_type(a, b, np.float32)
    m, n = a.shape
    bk = min(n, block_k if block_k is not None else
             _pow2_block(max(int(math.isqrt(m * n)), 1)))
    out = np.full((m, b.shape[1]), INF, dtype=dt)
    for k0 in range(0, n, bk):
        ab = a[:, k0:k0 + bk]                       # (M, bk)
        bb = b[k0:k0 + bk, :]                       # (bk, N)
        np.minimum(out, (ab[:, :, None] + bb[None, :, :]).min(axis=1),
                   out=out)
    return out


def apsp_np(cost: np.ndarray, n_iters: int) -> np.ndarray:
    """Host APSP by blocked min-plus squaring; on f32 input bit-equal to
    :func:`apsp` (dtype-preserving like :func:`min_plus_np`)."""
    d = np.asarray(cost)
    if d.dtype != np.float64:
        d = d.astype(np.float32)
    for _ in range(n_iters):
        d = min_plus_np(d, d)
    return d


def next_hop_np(cost: np.ndarray, dist: np.ndarray,
                rows: np.ndarray | None = None) -> np.ndarray:
    """Host next-hop extraction, j-blocked; bit-equal to :func:`next_hop`
    (numpy argmin and jnp argmin share first-index tie-breaking).

    ``rows`` restricts the computation to a subset of source rows (the
    delta path rebuilds only touched rows); the diagonal rule nh[i,i] = i
    is applied for whatever rows are produced."""
    cost = np.asarray(cost, dtype=np.float32)
    dist = np.asarray(dist, dtype=np.float32)
    n = cost.shape[0]
    step = np.where(np.eye(n, dtype=bool), np.float32(INF), cost)
    if rows is not None:
        step = step[rows]
    m = step.shape[0]
    nh = np.empty((m, n), dtype=np.int32)
    bj = min(n, _pow2_block(max(int(math.isqrt(m * n)), 1)))
    for j0 in range(0, n, bj):
        sc = step[:, :, None] + dist[None, :, j0:j0 + bj]  # (m, N, bj)
        nh[:, j0:j0 + bj] = sc.argmin(axis=1).astype(np.int32)
    ridx = np.arange(n, dtype=np.int32) if rows is None \
        else np.asarray(rows, dtype=np.int32)
    nh[np.arange(m), ridx] = ridx   # i == j: stay
    return nh


# Tie-breaking perturbations. Shortest paths on NoC meshes are massively
# degenerate (every monotone route ties), which makes "does some shortest
# path use this edge?" a uselessly large dirty test for incremental updates.
# The delta path therefore carries a SHADOW metric with a deterministic
# per-edge perturbation eps in (0, 2^-12): perturbed shortest paths are
# (almost surely) unique, so the dirty set shrinks to pairs whose UNIQUE
# perturbed path uses the edge — a near-minimal superset of the truly
# changed pairs. The shadow is exact integer arithmetic in disguise: edge
# weights are integers < 2^21 plus eps = r·2^-30 (r < 2^18), so any simple
# path's value needs <= 51 mantissa bits — exact in f64 — and its eps-sum
# stays < 1, so floor(perturbed distance) IS the true f32 distance (a path
# with smaller integer weight wins by >= 1 > any eps-sum).
_EPS_SCALE = 2.0 ** -30
_EPS_BITS = 18


@lru_cache(maxsize=8)
def _tie_eps(n: int) -> np.ndarray:
    """(N, N) f32 symmetric per-edge tie-breakers, a fixed deterministic
    function of the slot-pair (NOT of any design), so delta-updated shadow
    tables stay consistent across arbitrary move chains."""
    rng = np.random.default_rng(0x3D0C ^ n)
    r = rng.integers(1, 1 << _EPS_BITS, size=(n, n)).astype(np.float64)
    eps = np.triu(r * _EPS_SCALE, 1)
    return (eps + eps.T).astype(np.float32)


def _nh_cols_sparse(cost: np.ndarray, dist: np.ndarray,
                    cols: np.ndarray) -> np.ndarray:
    """(N, |cols|) next hops for destination columns ``cols``, computed
    from the directed edge list (O(E·C) instead of the dense O(N²·C)).

    Exact full-argmin semantics: for reachable entries only neighbors can
    win (non-neighbor scores are >= INF after f32 rounding, and INF never
    rounds down), and within-group edge order is (i, m) row-major — the
    same first-index tie-break. Entries whose best neighbor score reaches
    INF (disconnected pairs, where the oracle's argmin can land on a
    non-neighbor through INF-rounding ties) are re-done densely."""
    cost = np.asarray(cost, dtype=np.float32)
    dist = np.asarray(dist, dtype=np.float32)
    n = cost.shape[0]
    cols = np.asarray(cols, dtype=np.int64)
    off = ~np.eye(n, dtype=bool)
    ea, eb = np.nonzero((cost < INF / 2) & off)
    if ea.size == 0 or np.unique(ea).size < n:
        # Isolated node(s): no neighbor group to reduce over — dense path.
        return next_hop_np(cost, dist)[:, cols]
    starts = np.searchsorted(ea, np.arange(n))
    w = cost[ea, eb]
    inf32 = np.float32(INF)
    eidx = np.arange(ea.size, dtype=np.int64)
    out = np.empty((n, cols.size), dtype=np.int32)
    bc = max(1, (32 << 20) // (8 * max(ea.size, 1)))
    for j0 in range(0, cols.size, bc):
        js = cols[j0:j0 + bc]
        sc = w[:, None] + dist[eb[:, None], js[None, :]]      # (E, C)
        gmin = np.minimum.reduceat(sc, starts, axis=0)        # (N, C)
        first = np.minimum.reduceat(
            np.where(sc == gmin[ea], eidx[:, None], ea.size), starts, axis=0)
        nhc = eb[first].astype(np.int32)
        bad = gmin >= inf32
        if bad.any():
            bi, bj = np.nonzero(bad)
            step = np.where(off, cost, inf32)
            nhc[bi, bj] = (step[bi] + dist[:, js[bj]].T).argmin(
                axis=1).astype(np.int32)
        out[:, j0:j0 + bc] = nhc
    return out


class HostTables(NamedTuple):
    """Cached host routing state for one adjacency: hop-cost matrix, APSP
    distances, next hops, plus the f64 tie-broken shadow (cost_t, dist_t)
    that powers the incremental delta — the unit of Evaluator's cache."""

    cost: np.ndarray    # (N, N) f32: 0 diag, router+wire on edges, INF absent
    dist: np.ndarray    # (N, N) f32 shortest-path distances
    nh: np.ndarray      # (N, N) int32 first-index-argmin next hops
    cost_t: np.ndarray  # (N, N) f64 cost + per-edge tie-breaker
    dist_t: np.ndarray  # (N, N) f64 perturbed distances; floor == dist

    @property
    def nbytes(self) -> int:
        return (self.cost.nbytes + self.dist.nbytes + self.nh.nbytes
                + self.cost_t.nbytes + self.dist_t.nbytes)


def host_tables(cost: np.ndarray, n_iters: int) -> HostTables:
    """Full host recompute — the delta path's fallback and seed. One f64
    APSP on the tie-broken costs yields both metrics: dist = floor(dist_t)
    (exact — see the shadow-metric note above), bit-equal to the f32
    oracle."""
    cost = np.ascontiguousarray(cost, dtype=np.float32)
    n = cost.shape[0]
    edge = (cost < INF / 2) & ~np.eye(n, dtype=bool)
    cost_t = cost.astype(np.float64)
    cost_t[edge] += _tie_eps(n).astype(np.float64)[edge]
    dist_t = apsp_np(cost_t, n_iters)
    dist = np.floor(dist_t).astype(np.float32)
    return HostTables(cost, dist, next_hop_np(cost, dist), cost_t, dist_t)


def delta_link_move(
    t: HostTables,
    rem: tuple[int, int],
    add: tuple[int, int],
    w_add: float,
    *,
    max_dirty_frac: float = 0.5,
    max_iters: int | None = None,
) -> HostTables | None:
    """Incremental tables after moving one undirected link: remove edge
    ``rem``, add edge ``add`` with hop cost ``w_add``. Bit-equal to a full
    recompute on the new cost matrix, or ``None`` when the delta bound is
    exceeded (too many touched rows/columns, or the relaxation cap is hit)
    and the caller must fall back to :func:`host_tables`.

    Three exact phases, the first two on the f64 shadow metric (unique
    perturbed shortest paths — see the tie-breaker note above):

    1. *Removal.* A pair (i, j) lengthens only if its unique perturbed
       shortest path used the removed edge: dist_t[i,j] == dist_t[i,a] +
       w_t + dist_t[b,j] (either orientation) — on tie-degenerate meshes
       this dirty set is tiny (the edge's unique-path betweenness), where
       the unperturbed test would flag most of the matrix. Dirty entries
       re-converge by sparse Jacobi–Bellman relaxation on the new shadow
       cost (an (E, N) gather per sweep, E = #dirty entries): they restart
       at INF while clean entries keep their (still exact) base value as
       the upper bound; every iterate stays >= the true distance, so the
       fixpoint is the true distance, in <= N-1 sweeps.
    2. *Addition.* A shortest path uses a new edge at most once, so
       dist'' = min(dist', dist'[:,c] + w_t + dist'[e,:], and symmetric) in
       closed form. The true f32 distances then drop out as
       floor(dist_t) — exact, bit-equal to the oracle.
    3. *Next hops.* nh[i,j] = argmin_m step[i,m] + dist[m,j] (f32 metric)
       can only change where its inputs changed: rows {a, b, c, e} (their
       step-cost row changed) and columns j whose f32 dist column changed.
       Everything else is an argmin over bit-identical arrays — unchanged
       by construction, including first-index ties."""
    n = t.cost.shape[0]
    a, b = int(rem[0]), int(rem[1])
    c, e = int(add[0]), int(add[1])
    eps = _tie_eps(n)
    cost2 = t.cost.copy()
    cost2[a, b] = cost2[b, a] = np.float32(INF)
    cost2[c, e] = cost2[e, c] = np.float32(w_add)
    w2t = np.float64(np.float32(w_add)) + np.float64(eps[c, e])
    cost2_t = t.cost_t.copy()
    cost2_t[a, b] = cost2_t[b, a] = np.float64(INF)
    cost2_t[c, e] = cost2_t[e, c] = w2t

    # Phase 1 — removal (shadow metric).
    dt = t.dist_t
    w_rem_t = t.cost_t[a, b]
    via_ab = dt[:, a:a + 1] + (w_rem_t + dt[b])[None, :]
    via_ba = dt[:, b:b + 1] + (w_rem_t + dt[a])[None, :]
    dirty = (dt == via_ab) | (dt == via_ba)
    di, dj = np.nonzero(dirty)
    dt2 = dt
    if di.size:
        # Entry bound: the dirty count is the removed edge's unique-path
        # betweenness. The byte term caps the (E, N) gather slab.
        if di.size > min(max_dirty_frac * n * n, (256 << 20) // (8 * n)):
            return None
        cap = max_iters if max_iters is not None else n
        dt2 = dt.copy()
        dt2[di, dj] = np.float64(INF)
        cost_cols = np.ascontiguousarray(cost2_t[:, dj].T)   # (E, N)
        cur = dt2[di, dj]
        for _ in range(cap):
            cand = (dt2[di, :] + cost_cols).min(axis=1)
            nd = np.minimum(cur, cand)
            if np.array_equal(nd, cur):
                break
            dt2[di, dj] = nd
            cur = nd
        else:
            return None

    # Phase 2 — addition (closed form; 1e9 + x never rounds below 1e9, so
    # unreachable-through-the-new-edge candidates can't fake a finite path).
    via_c = dt2[:, c:c + 1] + (w2t + dt2[e])[None, :]
    via_e = dt2[:, e:e + 1] + (w2t + dt2[c])[None, :]
    dt3 = np.minimum(dt2, np.minimum(via_c, via_e))
    dist3 = np.floor(dt3).astype(np.float32)

    # Phase 3 — targeted next-hop rebuild (f32 metric): full rows for the
    # four endpoints (their step-cost row changed), changed columns via the
    # sparse edge-list argmin (O(E·C); a long added link can genuinely
    # shortcut many pairs, so C is not assumed small).
    changed_cols = np.flatnonzero((dist3 != t.dist).any(axis=0))
    nh2 = t.nh.copy()
    touched_rows = np.unique(np.array([a, b, c, e], dtype=np.int32))
    nh2[touched_rows] = next_hop_np(cost2, dist3, rows=touched_rows)
    if changed_cols.size:
        nh2[:, changed_cols] = _nh_cols_sparse(cost2, dist3, changed_cols)
        nh2[changed_cols, changed_cols] = changed_cols.astype(np.int32)
    return HostTables(cost2, dist3, nh2, cost2_t, dt3)
