"""NSGA-II (Deb et al. [9]) — secondary baseline (the paper cites it as the
canonical GA-based MOO; AMOSA was shown superior in [10], we include both).

Variation operators respect the design space: crossover recombines the two
parents' tile placements (cycle-style repair to stay a permutation) and
takes a random mix of their planar links (repaired to the exact link
budget); mutation applies the paper's neighbor moves. Evaluation is batched
through the jitted Evaluator — a full population is scored per XLA call.

Selection scoring (nondominated rank + crowding) is itself array-shaped:
the numpy implementation is the oracle and a jit-compiled jnp twin
(``backend="jnp"``) fuses the O(n²·m) dominance tensor, the front-peeling
loop, and the per-objective crowding sweeps into one XLA call per
population. Duplicate objective rows are tie-broken deterministically by
index (first copy ranks first), which keeps the dominance relation acyclic
— a front always exists and genuinely dominated points can never share a
rank with a dominator."""

from __future__ import annotations

import numpy as np

from .evaluate import Evaluator
from .local_search import ParetoSet, SearchHistory
from .pareto import PhvContext
from .problem import Design, SystemSpec, sample_neighbors

RANK_BACKENDS = ("auto", "numpy", "jnp")


def resolve_rank_backend(backend: str | None = None) -> str:
    b = backend if backend is not None else "auto"
    if b not in RANK_BACKENDS:
        raise ValueError(f"backend must be one of {RANK_BACKENDS}, got {b!r}")
    if b == "auto":
        import jax

        b = "jnp" if jax.default_backend() in ("tpu", "gpu") else "numpy"
    return b


def _dominance(objs: np.ndarray):
    """dom[i, j]: i dominates j, with exact-duplicate rows ordered by index
    (the first copy dominates later copies). The relation stays acyclic:
    along any would-be cycle the rows must be equal, and equal rows are
    ordered by strictly increasing index."""
    n = objs.shape[0]
    le = np.all(objs[:, None, :] <= objs[None, :, :], axis=-1)
    lt = np.any(objs[:, None, :] < objs[None, :, :], axis=-1)
    idx = np.arange(n)
    dup = le & ~lt & (idx[:, None] < idx[None, :])
    return (le & lt) | dup


def _fast_nondominated_rank(objs: np.ndarray) -> np.ndarray:
    dom = _dominance(objs)
    n = objs.shape[0]
    n_dom = dom.sum(axis=0)  # how many dominate j
    rank = np.full(n, -1)
    r = 0
    remaining = np.ones(n, dtype=bool)
    while remaining.any():
        front = remaining & (n_dom == 0)
        assert front.any(), "dominance relation must be acyclic"
        rank[front] = r
        n_dom = n_dom - dom[front].sum(axis=0)
        remaining &= ~front
        r += 1
    return rank


def _crowding(objs: np.ndarray) -> np.ndarray:
    n, m = objs.shape
    crowd = np.zeros(n)
    for j in range(m):
        order = np.argsort(objs[:, j], kind="stable")
        rng_j = objs[order[-1], j] - objs[order[0], j] + 1e-12
        crowd[order[0]] = crowd[order[-1]] = np.inf
        if n > 2:
            crowd[order[1:-1]] += (objs[order[2:], j] - objs[order[:-2], j]) / rng_j
    return crowd


def _rank_crowd_jnp_fn():
    """Jitted (rank, crowding) twin of the numpy pair. Peeling runs as a
    fori_loop (at most n fronts); the whole selection scoring is one fused
    XLA program per population shape."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(objs):
        n, m = objs.shape
        le = jnp.all(objs[:, None, :] <= objs[None, :, :], axis=-1)
        lt = jnp.any(objs[:, None, :] < objs[None, :, :], axis=-1)
        idx = jnp.arange(n)
        dom = (le & lt) | (le & ~lt & (idx[:, None] < idx[None, :]))

        def body(r, state):
            rank, n_dom = state
            front = (rank < 0) & (n_dom == 0)
            rank = jnp.where(front, r, rank)
            n_dom = n_dom - (dom & front[:, None]).sum(axis=0)
            return rank, n_dom

        rank, _ = jax.lax.fori_loop(
            0, n, body, (jnp.full(n, -1, jnp.int32), dom.sum(axis=0)))

        crowd = jnp.zeros(n)
        for j in range(m):
            order = jnp.argsort(objs[:, j])  # stable by default in jax
            col = objs[order, j]
            rng_j = col[-1] - col[0] + 1e-12
            contrib = jnp.zeros(n)
            if n > 2:
                contrib = contrib.at[order[1:-1]].set(
                    (col[2:] - col[:-2]) / rng_j)
            crowd = crowd + contrib
            crowd = crowd.at[order[0]].set(jnp.inf).at[order[-1]].set(jnp.inf)
        return rank, crowd

    return run


_RANK_CROWD_JNP = None


def rank_and_crowding(objs: np.ndarray, backend: str | None = None):
    """(rank, crowding) for one population on the selected backend."""
    if resolve_rank_backend(backend) == "jnp":
        global _RANK_CROWD_JNP
        if _RANK_CROWD_JNP is None:
            _RANK_CROWD_JNP = _rank_crowd_jnp_fn()
        rank, crowd = _RANK_CROWD_JNP(np.asarray(objs, np.float32))
        return np.asarray(rank), np.asarray(crowd, np.float64)
    return _fast_nondominated_rank(objs), _crowding(objs)


def _crossover(spec: SystemSpec, a: Design, b: Design,
               rng: np.random.Generator) -> Design:
    n = spec.n_tiles
    # Placement: copy a then graft a random segment of b, repairing to a perm.
    child = a.perm.copy()
    lo, hi = sorted(rng.choice(n, size=2, replace=False))
    seg = b.perm[lo:hi]
    rest = [c for c in a.perm if c not in set(seg.tolist())]
    child[lo:hi] = seg
    child[:lo] = rest[:lo]
    child[hi:] = rest[lo:]
    # Links: union, keep budget many (prefer common links).
    iu = np.triu_indices(n, 1)
    both = a.adj[iu] & b.adj[iu]
    either = (a.adj[iu] | b.adj[iu]) & ~both
    need = spec.n_planar_links - int(both.sum())
    pick = np.flatnonzero(either)
    rng.shuffle(pick)
    sel = both.copy()
    sel[pick[:need]] = True
    adj = np.zeros((n, n), dtype=bool)
    adj[iu[0][sel], iu[1][sel]] = True
    return Design(perm=child.astype(np.int32), adj=adj | adj.T)


def nsga2(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    d0: Design,
    seed: int = 0,
    *,
    pop_size: int = 32,
    generations: int = 30,
    p_mutate: float = 0.6,
    max_evals: int | None = None,
    history: SearchHistory | None = None,
    rank_backend: str = "auto",
) -> ParetoSet:
    rng = np.random.default_rng(seed)
    history = history or SearchHistory(ev, ctx)
    rank_backend = resolve_rank_backend(rank_backend)

    pop = [d0]
    while len(pop) < pop_size:
        nb = sample_neighbors(spec, d0, rng, 2, 2)
        pop.append(nb[rng.integers(len(nb))] if nb else d0.copy())
    objs = ev.batch(pop)
    for d, o in zip(pop, objs):
        history.record(ev, d, o)

    for _ in range(generations):
        if max_evals is not None and ev.n_evals >= max_evals:
            break
        sub = objs[:, list(ctx.obj_idx)]
        rank, crowd = rank_and_crowding(sub, rank_backend)

        def tournament():
            i, j = rng.integers(len(pop), size=2)
            if rank[i] < rank[j] or (rank[i] == rank[j] and crowd[i] > crowd[j]):
                return pop[i]
            return pop[j]

        children: list[Design] = []
        while len(children) < pop_size:
            c = _crossover(spec, tournament(), tournament(), rng)
            if rng.random() < p_mutate:
                nb = sample_neighbors(spec, c, rng, 1, 1)
                if nb:
                    c = nb[rng.integers(len(nb))]
            children.append(c)
        child_objs = ev.batch(children)
        for d, o in zip(children, child_objs):
            history.record(ev, d, o)

        # Environmental selection over parents + children.
        union = pop + children
        uobjs = np.vstack([objs, child_objs])
        sub = uobjs[:, list(ctx.obj_idx)]
        rank, crowd = rank_and_crowding(sub, rank_backend)
        order = np.lexsort((-crowd, rank))
        keep = order[:pop_size]
        pop = [union[i] for i in keep]
        objs = uobjs[keep]

    return ParetoSet.empty().merged_with(pop, objs, ctx.obj_idx)
