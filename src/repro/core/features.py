"""Cheap structural design features for the STAGE evaluation function.

STAGE's Eval must be much cheaper than a local search (paper §5.2), so the
features are O(N^2) numpy reads of the design itself — no routing, no
objective evaluation:

  geometry of the placement (where each core class sits, depth from sink),
  link structure (per-layer counts, lengths, degrees), and
  proximity structure between communicating classes (CPU/GPU vs LLC).

These are exactly the quantities the paper's qualitative analysis (§6.3,
Fig. 7/12: "LLCs in middle layers", "links concentrate near LLCs") says
predict design quality.
"""

from __future__ import annotations

from functools import lru_cache

import numpy as np

from .problem import CPU, GPU, LLC, Design, SystemSpec

FEATURE_NAMES = (
    "llc_mean_layer", "llc_std_layer", "cpu_mean_layer", "gpu_mean_layer",
    "power_depth", "col_power_std",
    "links_layer_entropy", "link_len_mean", "link_len_std",
    "deg_mean", "deg_std", "deg_max",
    "llc_deg_mean", "cpu_llc_dist", "gpu_llc_dist", "llc_link_frac",
)


def design_features(spec: SystemSpec, d: Design) -> np.ndarray:
    """(F,) float feature vector — see FEATURE_NAMES."""
    coords = spec.coords
    layer = coords[:, 0].astype(np.float64)
    types = spec.core_types[d.perm]
    power = spec.core_power[d.perm]
    k = spec.n_layers

    is_cpu, is_llc, is_gpu = types == CPU, types == LLC, types == GPU

    # Placement geometry.
    llc_mean_layer = layer[is_llc].mean() / k
    llc_std_layer = layer[is_llc].std() / k
    cpu_mean_layer = layer[is_cpu].mean() / k
    gpu_mean_layer = layer[is_gpu].mean() / k
    power_depth = float((power * layer).sum() / (power.sum() * k))
    col = coords[:, 1] * spec.ny + coords[:, 2]
    col_power = np.bincount(col, weights=power, minlength=spec.tiles_per_layer)
    col_power_std = float(col_power.std() / (col_power.mean() + 1e-9))

    # Link structure.
    iu = np.triu_indices(spec.n_tiles, 1)
    link_mask = d.adj[iu]
    link_layers = layer[iu[0]][link_mask]
    counts = np.bincount(link_layers.astype(int), minlength=k).astype(np.float64)
    p = counts / counts.sum()
    links_layer_entropy = float(-(p * np.log(p + 1e-12)).sum() / np.log(k))
    lens = spec.manhattan[iu][link_mask]
    link_len_mean = float(lens.mean())
    link_len_std = float(lens.std())
    full = d.adj | spec.vertical_adj
    deg = full.sum(1).astype(np.float64)
    llc_deg_mean = float(deg[is_llc].mean())

    # Class-proximity (geometric stand-in for routed hop distance).
    man = spec.manhattan + 1.0 * np.abs(layer[:, None] - layer[None, :])
    def class_dist(a, b):
        return float(man[np.ix_(a, b)].mean())
    cpu_llc = class_dist(np.flatnonzero(is_cpu), np.flatnonzero(is_llc))
    gpu_llc = class_dist(np.flatnonzero(is_gpu), np.flatnonzero(is_llc))

    # Fraction of planar links with an LLC endpoint (paper Fig. 7 insight).
    llc_slots = is_llc
    ends_llc = llc_slots[iu[0]] | llc_slots[iu[1]]
    llc_link_frac = float((ends_llc & link_mask).sum() / max(link_mask.sum(), 1))

    return np.array([
        llc_mean_layer, llc_std_layer, cpu_mean_layer, gpu_mean_layer,
        power_depth, col_power_std,
        links_layer_entropy, link_len_mean, link_len_std,
        float(deg.mean()), float(deg.std()), float(deg.max()),
        llc_deg_mean, cpu_llc, gpu_llc, llc_link_frac,
    ])


@lru_cache(maxsize=16)
def _batch_consts(spec: SystemSpec) -> dict:
    """Spec-static quantities for the batched extractor (one per spec)."""
    layer = spec.coords[:, 0].astype(np.float64)
    k = spec.n_layers
    iu0, iu1 = np.triu_indices(spec.n_tiles, 1)
    col = spec.coords[:, 1] * spec.ny + spec.coords[:, 2]
    col_onehot = np.zeros((spec.n_tiles, spec.tiles_per_layer))
    col_onehot[np.arange(spec.n_tiles), col] = 1.0
    link_layer = layer[iu0].astype(int)
    layer_onehot = np.zeros((iu0.shape[0], k))
    layer_onehot[np.arange(iu0.shape[0]), link_layer] = 1.0
    man2 = spec.manhattan + 1.0 * np.abs(layer[:, None] - layer[None, :])
    return {
        "layer": layer, "k": k, "iu0": iu0, "iu1": iu1,
        "col_onehot": col_onehot, "layer_onehot": layer_onehot,
        "lens": spec.manhattan[iu0, iu1], "man2": man2,
        "vert_deg": spec.vertical_adj.sum(1).astype(np.float64),
        "is_cpu": spec.core_types == CPU,
        "is_llc": spec.core_types == LLC,
        "is_gpu": spec.core_types == GPU,
    }


def _masked_mean_std(x: np.ndarray, mask: np.ndarray):
    """Mean/std of ``x`` (broadcast row) over each row of boolean ``mask``."""
    cnt = mask.sum(1)
    m1 = (x * mask).sum(1) / cnt
    m2 = (x * x * mask).sum(1) / cnt
    return m1, np.sqrt(np.maximum(m2 - m1 * m1, 0.0))


def design_features_batch(spec: SystemSpec, designs: list[Design]) -> np.ndarray:
    """(B, F) feature matrix — the vectorized form of
    :func:`design_features`, one numpy pass over the whole batch (the
    MOO-STAGE meta-search scores entire neighborhoods per step).

    Agrees with the scalar extractor to float round-off (sums are taken in a
    different order); pinned by tests."""
    c = _batch_consts(spec)
    b = len(designs)
    if b == 0:
        return np.zeros((0, len(FEATURE_NAMES)))
    perms = np.stack([d.perm for d in designs])          # (B, N)
    adjs = np.stack([d.adj for d in designs])            # (B, N, N)
    layer, k = c["layer"], c["k"]
    is_cpu = c["is_cpu"][perms]
    is_llc = c["is_llc"][perms]
    is_gpu = c["is_gpu"][perms]
    power = spec.core_power[perms]

    # Placement geometry.
    llc_mean_layer, llc_std_layer = _masked_mean_std(layer[None, :], is_llc)
    cpu_mean_layer = (layer * is_cpu).sum(1) / is_cpu.sum(1)
    gpu_mean_layer = (layer * is_gpu).sum(1) / is_gpu.sum(1)
    power_depth = (power * layer).sum(1) / (power.sum(1) * k)
    col_power = power @ c["col_onehot"]                  # (B, P)
    col_power_std = col_power.std(1) / (col_power.mean(1) + 1e-9)

    # Link structure.
    link_mask = adjs[:, c["iu0"], c["iu1"]]              # (B, E)
    counts = link_mask.astype(np.float64) @ c["layer_onehot"]
    p = counts / counts.sum(1, keepdims=True)
    links_layer_entropy = -(p * np.log(p + 1e-12)).sum(1) / np.log(k)
    link_len_mean, link_len_std = _masked_mean_std(c["lens"][None, :], link_mask)
    deg = adjs.sum(2) + c["vert_deg"][None, :]           # (B, N)
    llc_deg_mean = (deg * is_llc).sum(1) / is_llc.sum(1)

    # Class-proximity (geometric stand-in for routed hop distance).
    man2 = c["man2"]
    n_cpu_llc = is_cpu.sum(1) * is_llc.sum(1)
    cpu_llc = np.einsum("bi,ij,bj->b", is_cpu + 0.0, man2, is_llc + 0.0) / n_cpu_llc
    gpu_llc = np.einsum("bi,ij,bj->b", is_gpu + 0.0, man2, is_llc + 0.0) / (
        is_gpu.sum(1) * is_llc.sum(1))

    # Fraction of planar links with an LLC endpoint (paper Fig. 7 insight).
    ends_llc = is_llc[:, c["iu0"]] | is_llc[:, c["iu1"]]
    llc_link_frac = (ends_llc & link_mask).sum(1) / np.maximum(link_mask.sum(1), 1)

    return np.stack([
        llc_mean_layer / k, llc_std_layer / k, cpu_mean_layer / k,
        gpu_mean_layer / k, power_depth, col_power_std,
        links_layer_entropy, link_len_mean, link_len_std,
        deg.mean(1), deg.std(1), deg.max(1),
        llc_deg_mean, cpu_llc, gpu_llc, llc_link_frac,
    ], axis=1)
