"""Cheap structural design features for the STAGE evaluation function.

STAGE's Eval must be much cheaper than a local search (paper §5.2), so the
features are O(N^2) numpy reads of the design itself — no routing, no
objective evaluation:

  geometry of the placement (where each core class sits, depth from sink),
  link structure (per-layer counts, lengths, degrees), and
  proximity structure between communicating classes (CPU/GPU vs LLC).

These are exactly the quantities the paper's qualitative analysis (§6.3,
Fig. 7/12: "LLCs in middle layers", "links concentrate near LLCs") says
predict design quality.
"""

from __future__ import annotations

import numpy as np

from .problem import CPU, GPU, LLC, Design, SystemSpec

FEATURE_NAMES = (
    "llc_mean_layer", "llc_std_layer", "cpu_mean_layer", "gpu_mean_layer",
    "power_depth", "col_power_std",
    "links_layer_entropy", "link_len_mean", "link_len_std",
    "deg_mean", "deg_std", "deg_max",
    "llc_deg_mean", "cpu_llc_dist", "gpu_llc_dist", "llc_link_frac",
)


def design_features(spec: SystemSpec, d: Design) -> np.ndarray:
    """(F,) float feature vector — see FEATURE_NAMES."""
    coords = spec.coords
    layer = coords[:, 0].astype(np.float64)
    types = spec.core_types[d.perm]
    power = spec.core_power[d.perm]
    k = spec.n_layers

    is_cpu, is_llc, is_gpu = types == CPU, types == LLC, types == GPU

    # Placement geometry.
    llc_mean_layer = layer[is_llc].mean() / k
    llc_std_layer = layer[is_llc].std() / k
    cpu_mean_layer = layer[is_cpu].mean() / k
    gpu_mean_layer = layer[is_gpu].mean() / k
    power_depth = float((power * layer).sum() / (power.sum() * k))
    col = coords[:, 1] * spec.ny + coords[:, 2]
    col_power = np.bincount(col, weights=power, minlength=spec.tiles_per_layer)
    col_power_std = float(col_power.std() / (col_power.mean() + 1e-9))

    # Link structure.
    iu = np.triu_indices(spec.n_tiles, 1)
    link_mask = d.adj[iu]
    link_layers = layer[iu[0]][link_mask]
    counts = np.bincount(link_layers.astype(int), minlength=k).astype(np.float64)
    p = counts / counts.sum()
    links_layer_entropy = float(-(p * np.log(p + 1e-12)).sum() / np.log(k))
    lens = spec.manhattan[iu][link_mask]
    link_len_mean = float(lens.mean())
    link_len_std = float(lens.std())
    full = d.adj | spec.vertical_adj
    deg = full.sum(1).astype(np.float64)
    llc_deg_mean = float(deg[is_llc].mean())

    # Class-proximity (geometric stand-in for routed hop distance).
    man = spec.manhattan + 1.0 * np.abs(layer[:, None] - layer[None, :])
    def class_dist(a, b):
        return float(man[np.ix_(a, b)].mean())
    cpu_llc = class_dist(np.flatnonzero(is_cpu), np.flatnonzero(is_llc))
    gpu_llc = class_dist(np.flatnonzero(is_gpu), np.flatnonzero(is_llc))

    # Fraction of planar links with an LLC endpoint (paper Fig. 7 insight).
    llc_slots = is_llc
    ends_llc = llc_slots[iu[0]] | llc_slots[iu[1]]
    llc_link_frac = float((ends_llc & link_mask).sum() / max(link_mask.sum(), 1))

    return np.array([
        llc_mean_layer, llc_std_layer, cpu_mean_layer, gpu_mean_layer,
        power_depth, col_power_std,
        links_layer_entropy, link_len_mean, link_len_std,
        float(deg.mean()), float(deg.std()), float(deg.max()),
        llc_deg_mean, cpu_llc, gpu_llc, llc_link_frac,
    ])
