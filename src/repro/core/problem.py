"""3D heterogeneous NoC design problem (paper §4).

A candidate design ``d`` is (paper §4.2.5):
  * a *tile placement* ``perm``: perm[slot] = core_id — which core sits at which
    3D grid slot, and
  * a *planar-link adjacency* ``adj``: a symmetric (N, N) boolean matrix holding
    exactly ``spec.n_planar_links`` intra-layer links (the link budget of the
    equivalent 3D mesh). Vertical TSV links are fixed by the geometry.

Neighbor moves (paper §5.1 / §6.2): swap two tiles (any layers), or reposition
exactly one planar link (to any other same-layer tile pair).

Core ids are grouped by type: CPUs ``[0, C)``, LLCs ``[C, C+M)``, GPUs
``[C+M, N)``. Layer ``k = 0`` is the layer closest to the heat sink.
"""

from __future__ import annotations

import dataclasses
from functools import cached_property, lru_cache

import numpy as np

CPU, LLC, GPU = 0, 1, 2

# Per-core power (W) used by the thermal model (Eq. 5). 3D-ICE/McPAT are not
# available offline; these follow the paper's qualitative ordering (GPUs are
# the high-power cores, LLCs the coolest — §6.5).
CORE_POWER = {CPU: 2.0, LLC: 0.8, GPU: 3.0}


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """Static description of a 3D heterogeneous manycore system."""

    nx: int
    ny: int
    n_layers: int
    n_cpu: int
    n_llc: int
    n_gpu: int
    router_stages: int = 3          # paper §6.1: standard three-stage router
    max_hops: int = 24              # path-walk bound; designs needing more are invalid

    def __post_init__(self):
        if self.n_cpu + self.n_llc + self.n_gpu != self.n_tiles:
            raise ValueError(
                f"core counts {self.n_cpu}+{self.n_llc}+{self.n_gpu} != "
                f"tiles {self.n_tiles}"
            )

    # ---------------------------------------------------------------- sizes
    @property
    def n_tiles(self) -> int:
        return self.nx * self.ny * self.n_layers

    @property
    def tiles_per_layer(self) -> int:
        return self.nx * self.ny

    @property
    def n_planar_links(self) -> int:
        """Link budget = planar links of the same-size 3D mesh (paper §4.2.5)."""
        return (self.nx * (self.ny - 1) + self.ny * (self.nx - 1)) * self.n_layers

    @property
    def n_vertical_links(self) -> int:
        return self.tiles_per_layer * (self.n_layers - 1)

    @property
    def n_links(self) -> int:
        return self.n_planar_links + self.n_vertical_links

    # ----------------------------------------------------------- geometry
    @cached_property
    def coords(self) -> np.ndarray:
        """(N, 3) int array of (layer, x, y) per slot. Slot index is
        layer-major then row-major: slot = k * nx * ny + x * ny + y."""
        out = np.zeros((self.n_tiles, 3), dtype=np.int32)
        s = 0
        for k in range(self.n_layers):
            for x in range(self.nx):
                for y in range(self.ny):
                    out[s] = (k, x, y)
                    s += 1
        return out

    @cached_property
    def layer_of_slot(self) -> np.ndarray:
        return self.coords[:, 0].copy()

    @cached_property
    def vertical_adj(self) -> np.ndarray:
        """(N, N) bool — fixed TSV links between vertically adjacent slots."""
        n = self.n_tiles
        v = np.zeros((n, n), dtype=bool)
        tpl = self.tiles_per_layer
        for s in range(n - tpl):
            v[s, s + tpl] = v[s + tpl, s] = True
        return v

    @cached_property
    def planar_pair_mask(self) -> np.ndarray:
        """(N, N) bool — slot pairs that MAY carry a planar link (same layer).

        The paper places no regularity constraint: any same-layer pair is a
        legal planar link (long links cost more delay/energy — Eqs. 1, 9)."""
        same_layer = self.layer_of_slot[:, None] == self.layer_of_slot[None, :]
        return same_layer & ~np.eye(self.n_tiles, dtype=bool)

    @cached_property
    def manhattan(self) -> np.ndarray:
        """(N, N) float planar Manhattan distance (tile pitches) per slot pair."""
        c = self.coords[:, 1:].astype(np.float64)
        return np.abs(c[:, None, :] - c[None, :, :]).sum(-1)

    @cached_property
    def link_delay(self) -> np.ndarray:
        """(N, N) per-hop wire delay d (cycles): planar = Manhattan length,
        vertical TSV = 1 (TSVs are short/fast — paper §1)."""
        d = np.where(self.planar_pair_mask, self.manhattan, 0.0)
        d = np.where(self.vertical_adj, 1.0, d)
        return d.astype(np.float64)

    # --------------------------------------------------------------- cores
    @cached_property
    def core_types(self) -> np.ndarray:
        """(N,) int — type of core id i (ids grouped CPU | LLC | GPU)."""
        return np.array(
            [CPU] * self.n_cpu + [LLC] * self.n_llc + [GPU] * self.n_gpu,
            dtype=np.int32,
        )

    @cached_property
    def core_power(self) -> np.ndarray:
        return np.array([CORE_POWER[t] for t in self.core_types], dtype=np.float64)

    # ------------------------------------------------------ initial design
    def mesh_design(self) -> "Design":
        """The 3D-mesh starting design (paper §6.3: all searches start from a
        3D mesh with uniformly distributed links)."""
        n = self.n_tiles
        adj = np.zeros((n, n), dtype=bool)
        for s in range(n):
            k, x, y = self.coords[s]
            if y + 1 < self.ny:
                adj[s, s + 1] = adj[s + 1, s] = True
            if x + 1 < self.nx:
                adj[s, s + self.ny] = adj[s + self.ny, s] = True
        n_links = int(np.triu(adj).sum())
        if n_links != self.n_planar_links:
            raise RuntimeError(
                f"mesh link budget mismatch: built {n_links}, "
                f"expected {self.n_planar_links}")
        return Design(perm=np.arange(n, dtype=np.int32), adj=adj)


# Paper's two evaluation systems (§6.1, §6.4).
def spec_64() -> SystemSpec:
    """64 tiles: 8 CPUs, 16 LLCs, 40 GPUs in four 4x4 layers."""
    return SystemSpec(nx=4, ny=4, n_layers=4, n_cpu=8, n_llc=16, n_gpu=40)


def spec_36() -> SystemSpec:
    """36 tiles: 4 CPUs, 8 LLCs, 24 GPUs in four 3x3 layers."""
    return SystemSpec(nx=3, ny=3, n_layers=4, n_cpu=4, n_llc=8, n_gpu=24)


def spec_tiny() -> SystemSpec:
    """8 tiles (two 2x2 layers): 1 CPU, 2 LLCs, 5 GPUs — for tests/PCBB."""
    return SystemSpec(nx=2, ny=2, n_layers=2, n_cpu=1, n_llc=2, n_gpu=5, max_hops=8)


def spec_16() -> SystemSpec:
    """16 tiles (two 2x4 layers): 2 CPUs, 4 LLCs, 10 GPUs — small benches."""
    return SystemSpec(nx=2, ny=4, n_layers=2, n_cpu=2, n_llc=4, n_gpu=10, max_hops=12)


# Scale tiers beyond the paper (ROADMAP "scale the design space"): the
# CPU/LLC/GPU mix keeps the paper's 1:2:5 ratio; max_hops grows with the
# network diameter (path-walk bound, not a routing constraint).
def spec_large() -> SystemSpec:
    """256 tiles: 32 CPUs, 64 LLCs, 160 GPUs in four 8x8 layers — the
    interactive-speed target of the incremental delta evaluator."""
    return SystemSpec(nx=8, ny=8, n_layers=4, n_cpu=32, n_llc=64, n_gpu=160,
                      max_hops=48)


def spec_1024() -> SystemSpec:
    """1024 tiles: 128 CPUs, 256 LLCs, 640 GPUs in four 16x16 layers — the
    stretch tier; exercises the k-blocked dense path (memory-safe APSP)."""
    return SystemSpec(nx=16, ny=16, n_layers=4, n_cpu=128, n_llc=256,
                      n_gpu=640, max_hops=96)


@dataclasses.dataclass
class Design:
    """A candidate design: tile placement + planar link adjacency."""

    perm: np.ndarray   # (N,) int32, perm[slot] = core id
    adj: np.ndarray    # (N, N) bool, symmetric planar links

    def copy(self) -> "Design":
        return Design(self.perm.copy(), self.adj.copy())

    def key(self) -> bytes:
        """Hashable identity (used for de-dup in search trajectories)."""
        return self.perm.tobytes() + np.packbits(self.adj).tobytes()

    # ------------------------------------------------------------- moves
    # Move validation raises real exceptions (not ``assert``): asserts are
    # stripped under ``python -O``, which would let an invalid move silently
    # corrupt the link budget / placement permutation.
    def swap_tiles(self, a: int, b: int) -> "Design":
        if a == b:
            raise ValueError(f"swap_tiles: slots must differ, got {a} twice")
        d = self.copy()
        d.perm[a], d.perm[b] = d.perm[b], d.perm[a]
        return d

    def move_link(self, rem: tuple[int, int], add: tuple[int, int]) -> "Design":
        d = self.copy()
        (a, b), (c, e) = rem, add
        if a == b or c == e:
            raise ValueError(f"move_link: self-links are invalid "
                             f"(rem={rem}, add={add})")
        if not d.adj[a, b]:
            raise ValueError(f"move_link: removing non-existent link {rem}")
        d.adj[a, b] = d.adj[b, a] = False
        if d.adj[c, e]:
            raise ValueError(f"move_link: adding already-present link {add}")
        d.adj[c, e] = d.adj[e, c] = True
        return d


@lru_cache(maxsize=16)
def _triu_pairs(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Cached upper-triangle index pair (iu0, iu1) for an n-tile spec."""
    iu = np.triu_indices(n, 1)
    return iu[0], iu[1]


def existing_planar_links(spec: SystemSpec, adj: np.ndarray) -> list[tuple[int, int]]:
    iu0, iu1 = _triu_pairs(spec.n_tiles)
    mask = adj[iu0, iu1]
    return list(zip(iu0[mask].tolist(), iu1[mask].tolist()))


def absent_planar_pairs(spec: SystemSpec, adj: np.ndarray) -> list[tuple[int, int]]:
    iu0, iu1 = _triu_pairs(spec.n_tiles)
    ok = spec.planar_pair_mask[iu0, iu1] & ~adj[iu0, iu1]
    return list(zip(iu0[ok].tolist(), iu1[ok].tolist()))


@dataclasses.dataclass
class NeighborMoves:
    """A sampled neighborhood in *move* form: every candidate is the base
    design plus exactly one move (a tile swap or a single-link reposition).

    The fused meta-search (core.fused) scores the whole neighborhood on
    device from this representation — (B, 2) move index arrays instead of B
    materialized ``Design`` objects with their (N, N) adjacency copies — and
    only the argmax winner is ever materialized. ``materialize_all`` is the
    legacy form; :func:`sample_neighbors` is exactly that, so move-order and
    rng-stream parity between the two paths is structural, not tested-for."""

    base: Design
    swaps: np.ndarray      # (S, 2) int32 slot pairs, candidate i = swap i
    rem: np.ndarray        # (L, 2) int32 removed link endpoints (triu order)
    add: np.ndarray        # (L, 2) int32 added link endpoints (triu order)

    def __len__(self) -> int:
        return self.swaps.shape[0] + self.rem.shape[0]

    def materialize(self, j: int) -> Design:
        """Build candidate ``j`` (same order as :func:`sample_neighbors`:
        swaps first, then link moves) — with full move validation."""
        s = self.swaps.shape[0]
        if j < s:
            return self.base.swap_tiles(int(self.swaps[j, 0]),
                                        int(self.swaps[j, 1]))
        k = j - s
        return self.base.move_link(
            (int(self.rem[k, 0]), int(self.rem[k, 1])),
            (int(self.add[k, 0]), int(self.add[k, 1])))

    def materialize_all(self) -> list[Design]:
        return [self.materialize(j) for j in range(len(self))]


def sample_neighbor_moves(
    spec: SystemSpec,
    d: Design,
    rng: np.random.Generator,
    n_swaps: int,
    n_link_moves: int,
) -> NeighborMoves:
    """Sample a neighborhood as :class:`NeighborMoves` (no ``Design``
    construction). This IS the neighborhood sampler — ``sample_neighbors``
    materializes its output — so the same (rng state, base, knobs) yields
    the same candidates in the same order under either representation."""
    n = spec.n_tiles
    # Uniform ordered distinct pairs, drawn in one vectorized shot (the
    # same per-pair distribution as choice(n, 2, replace=False), without
    # n_swaps generator round-trips — the sampler is on the fused meta
    # step's critical path). No-op swaps (identical core ids) are skipped,
    # as before.
    a = rng.integers(0, n, size=n_swaps)
    b = rng.integers(0, n - 1, size=n_swaps)
    b = b + (b >= a)
    keep = d.perm[a] != d.perm[b]
    swaps = np.stack([a[keep], b[keep]], axis=1).astype(np.int32)
    iu0, iu1 = _triu_pairs(n)
    present = d.adj[iu0, iu1].astype(bool)
    link_idx = np.flatnonzero(present)
    hole_idx = np.flatnonzero(spec.planar_pair_mask[iu0, iu1] & ~present)
    rem = add = np.zeros((0, 2), np.int32)
    if link_idx.size and hole_idx.size:
        ri = link_idx[rng.integers(0, link_idx.size, size=n_link_moves)]
        ai = hole_idx[rng.integers(0, hole_idx.size, size=n_link_moves)]
        rem = np.stack([iu0[ri], iu1[ri]], axis=1).astype(np.int32)
        add = np.stack([iu0[ai], iu1[ai]], axis=1).astype(np.int32)
    return NeighborMoves(base=d, swaps=swaps.reshape(-1, 2),
                         rem=rem, add=add)


def sample_neighbors(
    spec: SystemSpec,
    d: Design,
    rng: np.random.Generator,
    n_swaps: int,
    n_link_moves: int,
) -> list[Design]:
    """Sample neighbor designs: tile swaps + single-planar-link repositions.

    The paper's greedy step evaluates the full neighborhood; that is O(N^2)
    swaps + O(L * P) link moves. We evaluate a uniform sample per step (the
    sample size is a knob; with n large enough the argmax matches the full
    neighborhood with high probability) — all candidates are scored in ONE
    vmapped/jitted batch (DESIGN.md §4.1)."""
    return sample_neighbor_moves(spec, d, rng, n_swaps, n_link_moves
                                 ).materialize_all()


def all_neighbors(spec: SystemSpec, d: Design) -> list[Design]:
    """Full neighborhood (exact Alg. 1 argmax) — only viable for small specs."""
    out = []
    n = spec.n_tiles
    for a in range(n):
        for b in range(a + 1, n):
            if d.perm[a] != d.perm[b]:
                out.append(d.swap_tiles(a, b))
    links = existing_planar_links(spec, d.adj)
    holes = absent_planar_pairs(spec, d.adj)
    for r in links:
        for h in holes:
            out.append(d.move_link(r, h))
    return out


def random_design(spec: SystemSpec, rng: np.random.Generator) -> Design:
    """Uniform random valid design (random restart / rand(D) in Alg. 2)."""
    perm = rng.permutation(spec.n_tiles).astype(np.int32)
    iu = _triu_pairs(spec.n_tiles)
    cand = np.flatnonzero(spec.planar_pair_mask[iu])
    pick = rng.choice(cand, size=spec.n_planar_links, replace=False)
    adj = np.zeros((spec.n_tiles, spec.n_tiles), dtype=bool)
    adj[iu[0][pick], iu[1][pick]] = True
    return Design(perm=perm, adj=adj | adj.T)
