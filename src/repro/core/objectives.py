"""Analytical design objectives — Eqs. 1-10 of the paper, in JAX.

Five objectives, all minimized (paper Eq. 11):

    index 0  umean  — mean expected link utilization, Eq. 3   (throughput proxy)
    index 1  ustd   — std of link utilization,        Eq. 4   (throughput proxy)
    index 2  lat    — average CPU<->LLC latency,      Eq. 1
    index 3  energy — router + link energy,           Eqs. 8-10
    index 4  temp   — thermal metric T,               Eqs. 5-7

The models only need *relative* fidelity — "accurate in determining which
designs are better relative to one another" (paper §4.2.5) — so the physical
constants below are documented stand-ins for the paper's 3D-ICE / PrimePower
calibration (tools unavailable offline; DESIGN.md §5).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import routing
from .problem import SystemSpec

OBJ_NAMES = ("umean", "ustd", "lat", "energy", "temp")
N_OBJ = len(OBJ_NAMES)

# Optimization cases (paper §6.2 and §6.5), as objective-index tuples.
CASES: dict[str, tuple[int, ...]] = {
    "case1": (0, 1),            # {U, sigma}
    "case2": (0, 1, 2),         # + Lat
    "case3": (0, 1, 2, 3),      # + E        ("network efficiency / perf")
    "case4": (4,),              # {T}        (thermal-only)
    "case5": (0, 1, 2, 3, 4),   # + T        (joint perf-thermal)
}

# ----------------------------------------------------------------- constants
E_ROUTER_PORT = 1.0     # router logic energy per flit per port (rel. pJ), Eq. 8
E_PLANAR_MM = 0.6       # planar wire energy per flit per tile pitch,     Eq. 9
E_VERTICAL = 0.3        # TSV energy per flit,                            Eq. 9
R_LAYER = 0.25          # vertical thermal resistance R_j (K/W),          Eq. 5
R_BASE = 2.0            # base-layer thermal resistance R_b (K/W),        Eq. 5
T_AMBIENT = 45.0        # coolant/ambient reference (deg C), reporting only


class SpecConsts(NamedTuple):
    """Static per-spec arrays, device-resident for the jitted evaluator."""

    vadj: jnp.ndarray          # (N, N) bool vertical links
    link_delay: jnp.ndarray    # (N, N) wire delay
    manhattan: jnp.ndarray     # (N, N) planar length
    core_types: jnp.ndarray    # (Ncores,) int
    core_power: jnp.ndarray    # (Ncores,) float
    column: jnp.ndarray        # (N,) column (single-tile-stack) id per slot
    layer: jnp.ndarray         # (N,) layer id per slot (0 = at the sink)
    n_cpu: int
    n_llc: int
    router_stages: int
    max_hops: int
    n_links: int
    apsp_iters: int
    n_columns: int
    n_layers: int


@functools.lru_cache(maxsize=64)
def make_consts(spec: SystemSpec) -> SpecConsts:
    col = spec.coords[:, 1] * spec.ny + spec.coords[:, 2]
    return SpecConsts(
        vadj=jnp.asarray(spec.vertical_adj),
        link_delay=jnp.asarray(spec.link_delay, jnp.float32),
        manhattan=jnp.asarray(spec.manhattan, jnp.float32),
        core_types=jnp.asarray(spec.core_types),
        core_power=jnp.asarray(spec.core_power, jnp.float32),
        column=jnp.asarray(col, jnp.int32),
        layer=jnp.asarray(spec.layer_of_slot, jnp.int32),
        n_cpu=spec.n_cpu,
        n_llc=spec.n_llc,
        router_stages=spec.router_stages,
        max_hops=spec.max_hops,
        n_links=spec.n_links,
        apsp_iters=routing.apsp_iters(spec.n_tiles),
        n_columns=spec.tiles_per_layer,
        n_layers=spec.n_layers,
    )


def design_cost(c: SpecConsts, adj: jnp.ndarray) -> jnp.ndarray:
    """(N, N) hop-cost matrix of a design: router pipeline + wire delay on
    present links, INF on absent ones, 0 on the diagonal. The batched
    evaluator stacks these and runs APSP through the selected routing
    backend (core.routing.routing_tables_batched)."""
    n = adj.shape[-1]
    full_adj = adj | c.vadj
    cost = jnp.where(full_adj, c.router_stages + c.link_delay, routing.INF)
    return jnp.where(jnp.eye(n, dtype=bool), 0.0, cost)


def design_cost_np(spec: SystemSpec, adj: np.ndarray) -> np.ndarray:
    """Host twin of :func:`design_cost` — bit-identical f32 hop costs (the
    entries are small integers, exact in f32 on both paths). Shared by the
    flit simulator's table builder and Evaluator's incremental delta path."""
    n = spec.n_tiles
    full_adj = np.asarray(adj, dtype=bool) | spec.vertical_adj
    cost = np.where(
        full_adj,
        np.float32(spec.router_stages) + spec.link_delay.astype(np.float32),
        np.float32(routing.INF),
    ).astype(np.float32)
    np.fill_diagonal(cost, np.float32(0.0))
    return cost


def evaluate_design(
    c: SpecConsts,
    perm: jnp.ndarray,   # (N,) slot -> core id
    adj: jnp.ndarray,    # (N, N) bool planar links
    f: jnp.ndarray,      # (Ncores, Ncores) traffic between CORES
):
    """All five objectives + validity for one design. jit/vmap friendly.

    Single-design reference path: routing tables are computed inline with
    the jnp oracle. The Evaluator hot loop instead batches APSP across the
    candidate set (optionally on the Pallas backend) and calls
    :func:`evaluate_with_tables`."""
    cost = design_cost(c, adj)
    dist, nh = routing.routing_tables(cost, c.apsp_iters)
    return evaluate_with_tables(c, perm, adj, f, dist, nh)


def evaluate_with_tables(
    c: SpecConsts,
    perm: jnp.ndarray,   # (N,) slot -> core id
    adj: jnp.ndarray,    # (N, N) bool planar links
    f: jnp.ndarray,      # (Ncores, Ncores) traffic between CORES
    dist: jnp.ndarray,   # (N, N) APSP distances for this design
    nh: jnp.ndarray,     # (N, N) int32 next hops for this design
):
    """Objectives given precomputed routing tables (Eqs. 1-10)."""
    n = perm.shape[0]
    full_adj = adj | c.vadj
    # Traffic between SLOTS under this placement.
    f_slots = f[perm][:, perm] * (1.0 - jnp.eye(n))

    # ---- routing ---------------------------------------------------- Eq. 1
    hops, delay, util_d, visits, all_done = routing.walk_paths(
        nh, c.link_delay, f_slots.astype(jnp.float32), c.max_hops
    )
    connected = jnp.all(dist < routing.INF / 2) & all_done

    # ---- Eq. 1: CPU<->LLC latency ------------------------------------------
    slot_type = c.core_types[perm]                       # type at each slot
    is_cpu = slot_type == 0
    is_llc = slot_type == 1
    pair_cpu_llc = (is_cpu[:, None] & is_llc[None, :]) | (
        is_llc[:, None] & is_cpu[None, :]
    )
    lat_terms = (c.router_stages * hops + delay) * f_slots
    lat = jnp.sum(jnp.where(pair_cpu_llc, lat_terms, 0.0)) / (
        c.n_cpu * c.n_llc
    )

    # ---- Eqs. 2-4: link-utilization mean / std -----------------------------
    # U_k for an undirected link = traffic in both directions.
    util_u = util_d + util_d.T
    upper = jnp.triu(jnp.ones((n, n), dtype=bool), 1)
    link_mask = full_adj & upper
    umean = jnp.sum(jnp.where(link_mask, util_u, 0.0)) / c.n_links
    uvar = jnp.sum(jnp.where(link_mask, (util_u - umean) ** 2, 0.0)) / c.n_links
    ustd = jnp.sqrt(uvar + 1e-12)

    # ---- Eqs. 8-10: energy --------------------------------------------------
    degree = jnp.sum(full_adj, axis=1) + 1               # +1 local port
    e_router = E_ROUTER_PORT * jnp.sum(visits * degree)
    planar = adj & ~c.vadj
    e_planar = E_PLANAR_MM * jnp.sum(
        jnp.where(planar, util_u * c.manhattan, 0.0)
    ) / 2.0  # each undirected link counted twice in the (N,N) sum
    e_vert = E_VERTICAL * jnp.sum(jnp.where(c.vadj, util_u, 0.0)) / 2.0
    energy = e_router + e_planar + e_vert

    # ---- Eqs. 5-7: thermal --------------------------------------------------
    power_slot = c.core_power[perm]
    p_stack = jnp.zeros((c.n_columns, c.n_layers), jnp.float32)
    p_stack = p_stack.at[c.column, c.layer].add(power_slot)
    # layer index i counted 1..K from the sink -> weight i*R_LAYER + R_BASE.
    i_idx = jnp.arange(1, c.n_layers + 1, dtype=jnp.float32)
    weighted = p_stack * (i_idx * R_LAYER + R_BASE)[None, :]
    t_nk = jnp.cumsum(weighted, axis=1)                  # Eq. 5 (T_{n,k})
    dT_k = jnp.max(t_nk, axis=0) - jnp.min(t_nk, axis=0)  # Eq. 6
    temp = jnp.max(t_nk) * jnp.max(dT_k)                 # Eq. 7

    objs = jnp.stack([umean, ustd, lat, energy, temp])
    objs = jnp.where(connected, objs, jnp.full((N_OBJ,), routing.INF))

    # Network-wide average packet latency (all pairs, f-weighted) — used for
    # the paper's network-EDP metric (§6.1), not as a search objective.
    total_f = jnp.sum(f_slots) + 1e-12
    net_lat = jnp.sum((c.router_stages * hops + delay) * f_slots) / total_f
    aux = {"connected": connected, "net_lat": net_lat}
    return objs, aux


def peak_temperature_celsius(c: SpecConsts, perm: np.ndarray) -> float:
    """Reporting helper (Fig. 10c): peak core temperature in deg C."""
    power_slot = np.asarray(c.core_power)[np.asarray(perm)]
    p = np.zeros((c.n_columns, c.n_layers))
    np.add.at(p, (np.asarray(c.column), np.asarray(c.layer)), power_slot)
    i_idx = np.arange(1, c.n_layers + 1)
    t_nk = np.cumsum(p * (i_idx * R_LAYER + R_BASE)[None, :], axis=1)
    return float(T_AMBIENT + t_nk.max())
