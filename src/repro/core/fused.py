"""Device-resident meta-search scoring — one dispatch per greedy step.

The legacy meta-search step (stage._meta_greedy) materializes every
neighborhood candidate as a ``Design`` (a (N, N) adjacency copy each),
featurizes the batch on the host (features.design_features_batch), and only
then reaches the device for the forest traversal. On spec-sized problems
the host featurization dominates the step (~2 ms of the ~2.9 ms step at
N=64) and the per-candidate ``Design`` construction is pure overhead: the
argmax discards all but one candidate.

This module restructures the step around *moves* (problem.NeighborMoves):
the jitted :func:`_score_moves` takes the base design as a permutation plus
a planar-link-mask vector and the neighborhood as (B,) move-index arrays,
and applies move → featurize → normalize → flat-forest traversal entirely
on device — one XLA dispatch per greedy step. Only the winning move is ever
materialized, on the host, after the accept test.

Shape discipline (the PR-4 retrace-bounding trick): batches are padded to a
power of two OUTSIDE the jit with identity moves (swap slot 0 with itself;
remove+add the scratch link column E), so the jit cache keys on the padded
shape. Identity rows reproduce the base design bit-exactly, so they score
exactly the base value and can never win an accept test (strict ``>``);
the host argmax additionally only looks at the real prefix.

Feature math mirrors features.design_features_batch exactly, in f32 (the
same precision the forest's jnp/pallas twins traverse at). Per-slot type
masks are float 0/1, so gathers and the class-proximity terms become
matmuls; the link mask uses a scratch column so swap rows and link rows
share one fixed-shape scatter.
"""

from __future__ import annotations

from functools import lru_cache, partial

import numpy as np

from .features import _batch_consts
from .forest import RegressionForest, resolve_forest_backend
from .problem import Design, NeighborMoves, SystemSpec

META_BACKENDS = ("host", "fused", "fused-pallas")


def check_meta_backend(backend: str | None, *, allow_none: bool = False) -> None:
    if backend is None and allow_none:
        return
    if backend not in META_BACKENDS:
        raise ValueError(
            f"meta_backend must be one of {META_BACKENDS}, got {backend!r}")


@lru_cache(maxsize=8)
def _fused_consts(spec: SystemSpec):
    """Spec-static device tensors for the fused featurizer (one per spec),
    plus the host-side (N, N) → edge-index map used to encode link moves."""
    import jax.numpy as jnp

    c = _batch_consts(spec)
    n = spec.n_tiles
    e = c["iu0"].shape[0]
    eid = np.full((n, n), -1, np.int32)
    eid[c["iu0"], c["iu1"]] = np.arange(e, dtype=np.int32)
    eid[c["iu1"], c["iu0"]] = np.arange(e, dtype=np.int32)
    # Per-slot incident-edge table: inc_edges[x] lists the n-1 triu edge
    # ids touching slot x, other_slot[x] the opposite endpoint of each —
    # the swap-delta features walk these O(N) rows instead of all E edges.
    inc_edges = np.empty((n, n - 1), np.int32)
    other_slot = np.empty((n, n - 1), np.int32)
    for x in range(n):
        mask = (c["iu0"] == x) | (c["iu1"] == x)
        ids = np.flatnonzero(mask)
        inc_edges[x] = ids
        other_slot[x] = np.where(c["iu0"][ids] == x,
                                 c["iu1"][ids], c["iu0"][ids])
    # _ext arrays carry a scratch tail entry (edge E -> zero weight, node
    # n) so identity-padded rows produce exact-zero deltas.
    f32 = jnp.float32
    lens = np.asarray(c["lens"], np.float32)
    loh = np.asarray(c["layer_onehot"], np.float32)
    # Host-side twins for the per-step base-design scalars: every one is an
    # exact small integer in f32 (lens are integer Manhattan distances, the
    # link mask is 0/1), so numpy and XLA produce bitwise-equal values and
    # the ~0.2 ms the base-scalar block cost as device ops becomes ~30 us
    # of host arithmetic per step.
    host = {
        "lens": lens,
        "lens2": (lens * lens).astype(np.float32),
        "loh": loh,
        "is_llc": np.asarray(c["is_llc"], np.float32),
        "iu0": np.asarray(c["iu0"]),
        "iu1": np.asarray(c["iu1"]),
        "n": n,
    }
    dev = {
        "layer": jnp.asarray(c["layer"], f32),
        "col_onehot": jnp.asarray(c["col_onehot"], f32),
        "layer_onehot": jnp.asarray(loh),
        "lens": jnp.asarray(lens),
        "lens_ext": jnp.asarray(np.append(lens, 0.0).astype(np.float32)),
        "loh_ext": jnp.asarray(
            np.vstack([loh, np.zeros((1, loh.shape[1]), np.float32)])),
        "man2": jnp.asarray(c["man2"], f32),
        "vert_deg": jnp.asarray(c["vert_deg"], f32),
        "iu0": jnp.asarray(c["iu0"], jnp.int32),
        "iu1": jnp.asarray(c["iu1"], jnp.int32),
        "iu0_ext": jnp.asarray(
            np.append(c["iu0"], n).astype(np.int32)),
        "iu1_ext": jnp.asarray(
            np.append(c["iu1"], n).astype(np.int32)),
        "inc_edges": jnp.asarray(inc_edges),
        "other_slot": jnp.asarray(other_slot),
        "eid_safe": jnp.asarray(np.maximum(eid, 0)),
        "is_cpu": jnp.asarray(c["is_cpu"], f32),
        "is_llc": jnp.asarray(c["is_llc"], f32),
        "is_gpu": jnp.asarray(c["is_gpu"], f32),
        "power": jnp.asarray(spec.core_power, f32),
    }
    return dev, host, eid, e


def _fused_features(c: dict, base_perm, base_lm, base_scalars,
                    sa, sb, er, ea):
    """(B, F) f32 features for base+move candidates — traceable body.

    ``sa``/``sb`` are swap slot pairs (identity when equal); ``er``/``ea``
    are removed/added edge indices in triu order, with the scratch sentinel
    ``E`` for non-link rows. The formulas transliterate
    features.design_features_batch (FEATURE_NAMES order).

    Every link-mask feature is computed INCREMENTALLY: the caller supplies
    the base-design scalars (``base_scalars``, built by
    ``MetaScorer._base_state`` in host numpy — every entry is an exact
    small integer in f32, so host and device agree bitwise), and this body
    only computes per-candidate deltas in O(B*N) — a swap touches no
    links, a link move touches exactly one removed and one added edge, so
    no (B, E) array is ever materialized (the full-mask variants of
    ``deg`` and the LLC link fraction dominated the whole program at
    E ~ N^2/2). Identity-padded rows hit the scratch edge/node and produce
    exact-zero deltas, keeping the padding contract bitwise."""
    import jax.numpy as jnp

    counts0, sums0, llc_slot0, ends0_ext, deg0 = base_scalars
    s1_0, s2_0, lm_cnt, s_llc0 = sums0[0], sums0[1], sums0[2], sums0[3]
    bsz = sa.shape[0]
    n = base_perm.shape[0]
    rows = jnp.arange(bsz)
    layer = c["layer"]
    k = float(c["layer_onehot"].shape[1])

    # ---------------------------------------------- perm-side (O(B*N))
    perms = jnp.broadcast_to(base_perm, (bsz, n))
    pa, pb = base_perm[sa], base_perm[sb]
    perms = perms.at[rows, sa].set(pb).at[rows, sb].set(pa)

    is_cpu = c["is_cpu"][perms]
    is_llc = c["is_llc"][perms]
    is_gpu = c["is_gpu"][perms]
    power = c["power"][perms]

    def mstats_masked(x_row, mask):
        cnt = mask.sum(1)
        m1 = (mask * x_row).sum(1) / cnt
        m2 = (mask * x_row * x_row).sum(1) / cnt
        return m1, jnp.sqrt(jnp.maximum(m2 - m1 * m1, 0.0))

    llc_mean, llc_std = mstats_masked(layer, is_llc)
    cpu_mean = (layer * is_cpu).sum(1) / is_cpu.sum(1)
    gpu_mean = (layer * is_gpu).sum(1) / is_gpu.sum(1)
    power_depth = (power * layer).sum(1) / (power.sum(1) * k)
    col_power = power @ c["col_onehot"]
    col_power_std = col_power.std(1) / (col_power.mean(1) + 1e-9)

    # ------------------------------------------- link-move deltas (O(B*K))
    counts = counts0[None, :] - c["loh_ext"][er] + c["loh_ext"][ea]
    p = counts / counts.sum(1, keepdims=True)
    entropy = -(p * jnp.log(p + 1e-12)).sum(1) / np.log(k)
    s1 = s1_0 - c["lens_ext"][er] + c["lens_ext"][ea]
    s2 = (s2_0 - c["lens_ext"][er] ** 2 + c["lens_ext"][ea] ** 2)
    len_mean = s1 / lm_cnt
    len_std = jnp.sqrt(jnp.maximum(s2 / lm_cnt - len_mean * len_mean, 0.0))

    # deg: one (B, 4) scatter per dispatch onto a scratch-node column
    # (both endpoints of the removed edge -1, of the added edge +1).
    didx = jnp.stack([c["iu0_ext"][er], c["iu1_ext"][er],
                      c["iu0_ext"][ea], c["iu1_ext"][ea]], axis=1)
    dupd = jnp.broadcast_to(
        jnp.asarray([-1.0, -1.0, 1.0, 1.0], deg0.dtype), (bsz, 4))
    deg = (jnp.broadcast_to(deg0, (bsz, n + 1))
           .at[rows[:, None], didx].add(dupd))[:, :n] + c["vert_deg"]
    llc_deg_mean = (deg * is_llc).sum(1) / is_llc.sum(1)

    # LLC link fraction: link rows move one edge's base end-flag out/in;
    # swap rows re-flag the <= 2(N-1) edges incident to the swapped slots.
    # The (sa, sb) edge appears in both incident walks with a spurious
    # -|la - lb| total (its true delta is zero: max is symmetric), which
    # the last term cancels; identity rows zero out termwise.
    la, lb = llc_slot0[sa], llc_slot0[sb]

    def swap_end_delta(x, v_old, v_new):
        eids = c["inc_edges"][x]                               # (B, N-1)
        lo = llc_slot0[c["other_slot"][x]]
        w = base_lm[eids]
        return ((jnp.maximum(v_new[:, None], lo)
                 - jnp.maximum(v_old[:, None], lo)) * w).sum(1)

    s_llc = (s_llc0
             - ends0_ext[er] + ends0_ext[ea]
             + swap_end_delta(sa, la, lb) + swap_end_delta(sb, lb, la)
             + jnp.abs(la - lb) * base_lm[c["eid_safe"][sa, sb]])
    llc_link_frac = s_llc / jnp.maximum(lm_cnt, 1.0)

    n_llc = is_llc.sum(1)
    cpu_llc = ((is_cpu @ c["man2"]) * is_llc).sum(1) / (is_cpu.sum(1) * n_llc)
    gpu_llc = ((is_gpu @ c["man2"]) * is_llc).sum(1) / (is_gpu.sum(1) * n_llc)

    return jnp.stack([
        llc_mean / k, llc_std / k, cpu_mean / k, gpu_mean / k,
        power_depth, col_power_std,
        entropy, len_mean, len_std,
        deg.mean(1), deg.std(1), deg.max(1),
        llc_deg_mean, cpu_llc, gpu_llc, llc_link_frac,
    ], axis=1)


_SCORE_JIT = None
_FEAT_JIT = None


def _score_moves_fn():
    """Build the jitted move→featurize→normalize→traverse pipeline lazily
    (importing core.fused must not initialize jax)."""
    import jax

    from .forest import flat_forest_eval

    @partial(jax.jit, static_argnames=("depth", "n_trees", "n_nodes"))
    def run(c, thrfeat, child, value, xm, xs,
            base_perm, base_lm, base_scalars, sa, sb, er, ea,
            *, depth, n_trees, n_nodes):
        feats = _fused_features(c, base_perm, base_lm, base_scalars,
                                sa, sb, er, ea)
        xn = (feats - xm) / xs
        return flat_forest_eval(thrfeat, child, value, xn,
                                depth, n_trees, n_nodes)

    return run


class MetaScorer:
    """Per-(spec, fitted forest) scorer for the fused meta-greedy step.

    Holds the device-resident spec constants and forest tensors; each
    :meth:`score_moves` call is one XLA dispatch over the whole padded
    neighborhood. ``backend="fused-pallas"`` routes the
    normalize→traverse→argmax tail through the Pallas kernel in
    kernels/stage_fused (TPU, or ``interpret=True`` for CPU testing) with
    the same on-failure fallback contract as the forest's pallas path —
    featurization stays jnp either way."""

    def __init__(self, spec: SystemSpec, model: RegressionForest, *,
                 backend: str = "fused", interpret: bool = False):
        import jax.numpy as jnp

        check_meta_backend(backend)
        if backend == "host":
            raise ValueError("MetaScorer is the device path; use "
                             "stage._meta_greedy_host for backend='host'")
        import jax

        global _SCORE_JIT, _FEAT_JIT
        if _SCORE_JIT is None:
            _SCORE_JIT = _score_moves_fn()
        if _FEAT_JIT is None:
            _FEAT_JIT = jax.jit(_fused_features)
        self._feat_jit = _FEAT_JIT
        self.spec = spec
        self.c, self._h, self._eid, self._e = _fused_consts(spec)
        self._iu0, self._iu1 = self._h["iu0"], self._h["iu1"]
        (self.thrfeat, self.child, self.value), \
            (self.depth, self.n_trees, self.n_nodes) = model.jnp_tensors()
        self.xm = jnp.asarray(model._xm, jnp.float32)
        self.xs = jnp.asarray(model._xs, jnp.float32)
        # resolve once: "fused-pallas" off-TPU without interpret falls back
        # to the jnp tail exactly like forest backend "pallas" does.
        self.pallas = (backend == "fused-pallas" and resolve_forest_backend(
            "pallas", interpret=interpret) == "pallas")
        self.interpret = interpret
        self._pallas_nodes = None
        if self.pallas:
            # the kernel traverses the (T, M) layout (kernels/forest), not
            # the flat complex packing the jnp tail gathers from.
            fl = model._flat
            t, m = fl["feature"].shape
            child2 = np.empty((t, 2 * m), np.int32)
            child2[:, 0::2] = fl["left"]
            child2[:, 1::2] = fl["right"]
            self._pallas_nodes = (
                jnp.asarray(fl["threshold"], jnp.float32),
                jnp.asarray(np.maximum(fl["feature"], 0), jnp.int32),
                jnp.asarray(child2),
                jnp.asarray(fl["value"], jnp.float32),
            )

    # ------------------------------------------------------------- encoding
    def _encode(self, moves: NeighborMoves) -> tuple:
        """Pad the neighborhood to a fixed shape and encode it as move-index
        arrays (identity rows fill the tail)."""
        s = moves.swaps.shape[0]
        b = len(moves)
        if self.pallas:
            from ..kernels import stage_fused as _sf
            pad = -(-max(b, 1) // _sf.BLOCK_B) * _sf.BLOCK_B
        else:
            pad = 1 << max(0, (b - 1).bit_length())
        sa = np.zeros(pad, np.int32)
        sb = np.zeros(pad, np.int32)
        er = np.full(pad, self._e, np.int32)
        ea = np.full(pad, self._e, np.int32)
        sa[:s] = moves.swaps[:, 0]
        sb[:s] = moves.swaps[:, 1]
        er[s:b] = self._eid[moves.rem[:, 0], moves.rem[:, 1]]
        ea[s:b] = self._eid[moves.add[:, 0], moves.add[:, 1]]
        return sa, sb, er, ea

    def _base_state(self, d: Design) -> tuple:
        """(base_perm, base_lm, base_scalars) — all plain numpy: the jit's
        C++ argument path converts host arrays far cheaper than an eager
        jnp.asarray per array per step, and the base-design link scalars
        are exact small integers in f32 (integer Manhattan lens, 0/1 mask)
        so host numpy reproduces the device values bitwise while skipping
        ~0.2 ms of tiny XLA ops per step."""
        h = self._h
        n = h["n"]
        lm = d.adj[self._iu0, self._iu1].astype(np.float32)
        counts0 = lm @ h["loh"]                                  # (K,)
        llc_slot0 = h["is_llc"][d.perm]                          # (N,)
        ends0 = np.maximum(llc_slot0[self._iu0], llc_slot0[self._iu1])
        sums0 = np.array([h["lens"] @ lm, h["lens2"] @ lm,
                          lm.sum(), ends0 @ lm], np.float32)
        ends0_ext = np.append(ends0, np.float32(0.0))
        deg0 = (np.bincount(self._iu0, weights=lm, minlength=n + 1)
                + np.bincount(self._iu1, weights=lm, minlength=n + 1)
                ).astype(np.float32)
        scalars = (counts0, sums0, llc_slot0, ends0_ext, deg0)
        return d.perm.astype(np.int32, copy=False), lm, scalars

    # -------------------------------------------------------------- scoring
    def score_base(self, d: Design) -> float:
        """Eval(d) — the fused twin of predict(features([d]))[0]."""
        base_perm, base_lm, scalars = self._base_state(d)
        one = np.zeros(1, np.int32)
        vals = _SCORE_JIT(self.c, self.thrfeat, self.child, self.value,
                          self.xm, self.xs, base_perm, base_lm, scalars,
                          one, one, np.full(1, self._e, np.int32),
                          np.full(1, self._e, np.int32),
                          depth=self.depth, n_trees=self.n_trees,
                          n_nodes=self.n_nodes)
        return float(vals[0])

    def score_moves(self, moves: NeighborMoves) -> tuple[int, float]:
        """(argmax j, Eval of candidate j) over the neighborhood — one
        device dispatch. Tie-break matches np.argmax (first max)."""
        b = len(moves)
        base_perm, base_lm, scalars = self._base_state(moves.base)
        sa, sb, er, ea = self._encode(moves)
        if self.pallas:
            from ..kernels import stage_fused as _sf

            feats = self._feat_jit(self.c, base_perm, base_lm, scalars,
                                   sa, sb, er, ea)
            try:
                vj, j = _sf.score_block_max(
                    *self._pallas_nodes, self.xm.reshape(1, -1),
                    self.xs.reshape(1, -1), feats,
                    np.array([[b]], np.int32), depth=self.depth,
                    interpret=self.interpret)
                return int(j), float(vj)
            except Exception:
                if self.interpret:
                    raise
                # same never-crash-mid-search contract as forest pallas:
                # fall through to the jnp tail for this and later calls.
                self.pallas = False
        vals = _SCORE_JIT(self.c, self.thrfeat, self.child, self.value,
                          self.xm, self.xs, base_perm, base_lm, scalars,
                          sa, sb, er, ea, depth=self.depth,
                          n_trees=self.n_trees, n_nodes=self.n_nodes)
        # transfer the whole padded vector and slice on the host — an eager
        # device-side vals[:b] would dispatch a second XLA op per step.
        vals = np.asarray(vals)[:b]
        j = int(np.argmax(vals))
        return j, float(vals[j])
