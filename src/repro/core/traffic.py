"""Synthetic heterogeneous traffic, calibrated to the paper's §3 study.

gem5-gpu full-system traces are not available offline, so we *simulate the
data gate*: a parametric generator that reproduces every statistic the paper
reports about its measured traffic (Figs. 1–2):

  * one CPU "master core" contributes the majority of CPU traffic;
  * GPU<->LLC traffic is near-uniform (well-parallelized kernels) and large;
  * >80% of total traffic touches an LLC (many-to-few);
  * CPU<->GPU and GPU<->GPU traffic is negligible;
  * application-specific variation exists but is second-order.

Each of the paper's ten applications (Table 1) gets a seed + mild parameter
jitter (LLC popularity skew, CPU/GPU intensity ratio, master-core share), so
cross-application similarity/variation mirrors the paper's observation that
traffic is architecture- rather than application-dominated.

Units are relative flits/cycle; each matrix is normalized to sum to 1 and
scaled by a per-application injection intensity.
"""

from __future__ import annotations

import numpy as np

from .problem import CPU, GPU, LLC, SystemSpec


class TrafficValidationError(ValueError):
    """A traffic specification failed validation — unknown application or
    model/phase name, a mesh that does not tile the GPU pool, or an explicit
    matrix that is non-square / non-finite / negative / all-zero. Raised at
    problem-construction time so bad requests are rejected at admission
    instead of crashing a worker mid-run."""

# Paper Table 1 applications. The intensity scalar is a relative injection
# rate (flits/cycle) used by netsim and EDP; values span the moderate range
# typical of Rodinia-class workloads.
APPLICATIONS: dict[str, dict] = {
    "BP":  dict(seed=101, intensity=0.48, llc_skew=0.25, master_share=0.72, cpu_frac=0.055),
    "BFS": dict(seed=102, intensity=0.62, llc_skew=0.35, master_share=0.78, cpu_frac=0.070),
    "CDN": dict(seed=103, intensity=0.70, llc_skew=0.20, master_share=0.70, cpu_frac=0.045),
    "GAU": dict(seed=104, intensity=0.44, llc_skew=0.30, master_share=0.75, cpu_frac=0.060),
    "HS":  dict(seed=105, intensity=0.55, llc_skew=0.22, master_share=0.74, cpu_frac=0.050),
    "LEN": dict(seed=106, intensity=0.66, llc_skew=0.18, master_share=0.71, cpu_frac=0.040),
    "LUD": dict(seed=107, intensity=0.50, llc_skew=0.28, master_share=0.76, cpu_frac=0.065),
    "NW":  dict(seed=108, intensity=0.40, llc_skew=0.32, master_share=0.80, cpu_frac=0.075),
    "KNN": dict(seed=109, intensity=0.58, llc_skew=0.24, master_share=0.73, cpu_frac=0.055),
    "PF":  dict(seed=110, intensity=0.52, llc_skew=0.26, master_share=0.77, cpu_frac=0.060),
}

APP_NAMES = tuple(APPLICATIONS)


def traffic_matrix(spec: SystemSpec, app: str) -> np.ndarray:
    """(N_cores, N_cores) relative flit rates f_ij for ``app`` on ``spec``.

    f[i, j] is directed traffic from core i to core j (requests one way,
    responses the other; both are generated)."""
    p = APPLICATIONS[app]
    rng = np.random.default_rng(p["seed"] + 7919 * spec.n_tiles)
    n = spec.n_tiles
    C, M, G = spec.n_cpu, spec.n_llc, spec.n_gpu
    cpus = np.arange(0, C)
    llcs = np.arange(C, C + M)
    gpus = np.arange(C + M, n)

    f = np.zeros((n, n), dtype=np.float64)

    # LLC popularity: mildly skewed (address interleaving is not perfect).
    pop = rng.dirichlet(np.full(M, 1.0 / max(p["llc_skew"], 1e-3)))
    pop = 0.5 * pop + 0.5 / M  # keep near-uniform, per Fig. 1

    # --- GPU <-> LLC: near-uniform many-to-few, dominates total traffic.
    gpu_w = 1.0 + 0.15 * rng.standard_normal(G).clip(-2, 2)  # per-GPU jitter
    gpu_w = np.maximum(gpu_w, 0.2)
    for gi, g in enumerate(gpus):
        for mi, m in enumerate(llcs):
            req = gpu_w[gi] * pop[mi]
            f[g, m] += req           # read requests / writebacks
            f[m, g] += 2.0 * req     # response data (cache lines are wider)

    # --- CPU <-> LLC: small share, master core dominates (paper §3).
    cpu_w = np.full(C, (1.0 - p["master_share"]) / max(C - 1, 1))
    cpu_w[0] = p["master_share"]
    for ci, c in enumerate(cpus):
        for mi, m in enumerate(llcs):
            req = cpu_w[ci] * pop[mi]
            f[c, m] += req
            f[m, c] += 2.0 * req

    # --- negligible CORE-CORE traffic (coherence, atomics, launch control).
    for c in cpus:
        for g in gpus:
            t = rng.uniform(0.1, 0.5)
            f[c, g] += t
            f[g, c] += t
    for _ in range(G):
        a, b = rng.choice(gpus, size=2, replace=False)
        f[a, b] += rng.uniform(0.05, 0.2)

    # Normalize blocks to hit target shares: LLC-involved >= ~80% (Fig. 2).
    llc_mask = np.zeros((n, n), dtype=bool)
    llc_mask[llcs, :] = True
    llc_mask[:, llcs] = True
    core_core = f * ~llc_mask
    llc_traffic = f * llc_mask
    cpu_rows = np.zeros((n, n), dtype=bool)
    cpu_rows[cpus, :] = True
    cpu_rows[:, cpus] = True
    cpu_llc = llc_traffic * cpu_rows
    gpu_llc = llc_traffic * ~cpu_rows

    core_share = 1.0 - rng.uniform(0.82, 0.93)      # CORE-CORE share (Fig. 2)
    cpu_frac = p["cpu_frac"]                         # CPU-LLC share of total

    def _norm(x, target):
        s = x.sum()
        return x * (target / s) if s > 0 else x

    f = (
        _norm(gpu_llc, 1.0 - core_share - cpu_frac)
        + _norm(cpu_llc, cpu_frac)
        + _norm(core_core, core_share)
    )
    return f * p["intensity"]


def avg_traffic(spec: SystemSpec, apps: list[str]) -> np.ndarray:
    """Aggregated traffic profile (paper §6.4 'AVG'): per-app matrices are
    normalized to unit sum, then averaged — so no single heavy app dominates."""
    mats = []
    for a in apps:
        m = traffic_matrix(spec, a)
        mats.append(m / m.sum())
    out = np.mean(mats, axis=0)
    mean_intensity = float(np.mean([APPLICATIONS[a]["intensity"] for a in apps]))
    return out * mean_intensity


def traffic_stats(spec: SystemSpec, f: np.ndarray) -> dict:
    """The §3 statistics (used by tests + EXPERIMENTS.md validation)."""
    C, M = spec.n_cpu, spec.n_llc
    n = spec.n_tiles
    llcs = slice(C, C + M)
    llc_mask = np.zeros((n, n), dtype=bool)
    llc_mask[llcs, :] = True
    llc_mask[:, llcs] = True
    total = f.sum()
    cpu_out = f[:C, llcs].sum(axis=1)
    return dict(
        llc_share=float((f * llc_mask).sum() / total),
        core_core_share=float((f * ~llc_mask).sum() / total),
        master_cpu_share=float(cpu_out[0] / max(cpu_out.sum(), 1e-12)),
        gpu_llc_cv=float(
            np.std(f[C + M :, llcs].sum(axis=1)) / np.mean(f[C + M :, llcs].sum(axis=1))
        ),
    )
