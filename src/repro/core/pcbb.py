"""PCBB — priority & compensation-factor-oriented branch and bound (Wu et
al. [12]), adapted for 3D heterogeneous NoC design exactly as the paper
describes (§6.1):

  1. branching in two stages — node (tile) placement first, then link
     placement;
  2. bounds estimated by ROLL-OUT: the partial design is virtually completed
     with well-known mapping strategies (greedy, random, small-world) and the
     best completion's scalarized objective is the branch bound;
  3. objectives combined into a single scalarized metric;
  4. a branch is pruned only if its bound is worse than the incumbent even
     after the compensation factor (bound-estimation-error allowance).

Branching is over core TYPES per slot (cores of a type are interchangeable),
visited in slot order; the link stage is a bounded greedy descent from the
mesh link set. PCBB does systematic enumeration, so it is only tractable for
small systems (the paper itself reports ~141x MOO-STAGE's time at 64 tiles;
we run it at 8-16 tiles and report the scaling, DESIGN.md §5)."""

from __future__ import annotations

import dataclasses

import numpy as np

from .evaluate import Evaluator
from .local_search import ParetoSet, SearchHistory
from .pareto import PhvContext
from .problem import CPU, GPU, LLC, Design, SystemSpec


def _scalarize(ctx: PhvContext, objs: np.ndarray) -> float:
    return float(ctx.normalize(objs).mean())


@dataclasses.dataclass
class PcbbResult:
    best: Design
    best_objs: np.ndarray
    pareto: ParetoSet
    nodes_expanded: int
    nodes_pruned: int


def _complete_greedy(spec: SystemSpec, types: list[int], counts: dict[int, int],
                     rng: np.random.Generator) -> np.ndarray:
    """Greedy completion: LLCs to middle layers, CPUs near LLCs, GPUs to the
    sink (the placement heuristics the paper's Figs. 7/12 identify)."""
    n = spec.n_tiles
    remaining = {t: c for t, c in counts.items()}
    out_types = list(types)
    mid = (spec.n_layers - 1) / 2.0
    slots = list(range(len(types), n))
    # Score slots: LLC prefers middle layers, GPU prefers sink (layer 0).
    for s in slots:
        k = spec.coords[s][0]
        prefs = sorted(
            [(abs(k - mid), LLC), (k, GPU), (abs(k - mid) + 0.5, CPU)]
        )
        placed = False
        for _, t in prefs:
            if remaining.get(t, 0) > 0:
                out_types.append(t)
                remaining[t] -= 1
                placed = True
                break
        if not placed:
            raise RuntimeError(f"greedy completion ran out of cores at slot {s}")
    return _types_to_perm(spec, out_types)


def _complete_random(spec: SystemSpec, types: list[int], counts: dict[int, int],
                     rng: np.random.Generator) -> np.ndarray:
    pool = sum(([t] * c for t, c in counts.items()), [])
    rng.shuffle(pool)
    return _types_to_perm(spec, list(types) + pool)


def _types_to_perm(spec: SystemSpec, types: list[int]) -> np.ndarray:
    """Convert a per-slot type list into a concrete core-id permutation."""
    nxt = {CPU: 0, LLC: spec.n_cpu, GPU: spec.n_cpu + spec.n_llc}
    perm = np.zeros(spec.n_tiles, dtype=np.int32)
    for s, t in enumerate(types):
        perm[s] = nxt[t]
        nxt[t] += 1
    return perm


def _smallworld_adj(spec: SystemSpec, rng: np.random.Generator) -> np.ndarray:
    """Mesh links with a few rewired long-range shortcuts (small-world [5])."""
    d = spec.mesh_design()
    from .problem import absent_planar_pairs, existing_planar_links
    links = existing_planar_links(spec, d.adj)
    holes = absent_planar_pairs(spec, d.adj)
    adj = d.adj.copy()
    for _ in range(max(1, spec.n_planar_links // 8)):
        r = links[rng.integers(len(links))]
        a = holes[rng.integers(len(holes))]
        if adj[r[0], r[1]] and not adj[a[0], a[1]]:
            adj[r[0], r[1]] = adj[r[1], r[0]] = False
            adj[a[0], a[1]] = adj[a[1], a[0]] = True
    return adj


def pcbb(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    seed: int = 0,
    *,
    compensation: float = 0.15,
    n_random_rollouts: int = 2,
    link_descent_steps: int = 10,
    max_expansions: int = 200_000,
    history: SearchHistory | None = None,
) -> PcbbResult:
    rng = np.random.default_rng(seed)
    history = history or SearchHistory(ev, ctx)
    mesh_adj = spec.mesh_design().adj
    counts0 = {CPU: spec.n_cpu, LLC: spec.n_llc, GPU: spec.n_gpu}

    best_scal = np.inf
    best_design: Design | None = None
    best_objs: np.ndarray | None = None
    pareto = ParetoSet.empty()
    expanded = pruned = 0

    def bound_of(types: list[int], counts: dict[int, int]) -> float:
        """Roll-out bound: best scalarized completion (greedy/random/SW)."""
        perms = [_complete_greedy(spec, types, counts, rng)]
        for _ in range(n_random_rollouts):
            perms.append(_complete_random(spec, types, counts, rng))
        designs = [Design(p, mesh_adj.copy()) for p in perms]
        designs.append(Design(perms[0], _smallworld_adj(spec, rng)))
        objs = ev.batch(designs)
        scals = [_scalarize(ctx, o) for o in objs]
        j = int(np.argmin(scals))
        nonlocal pareto
        pareto = pareto.merged_with([designs[j]], objs[j][None], ctx.obj_idx)
        for d, o in zip(designs, objs):
            history.record(ev, d, o)
        return scals[j]

    def link_stage(perm: np.ndarray) -> tuple[Design, np.ndarray, float]:
        """Second branching stage, collapsed to a bounded greedy descent over
        link repositions (full link enumeration is astronomically large —
        paper §6.3 C(C(16,2)*4, 96))."""
        from .problem import sample_neighbors
        d = Design(perm, mesh_adj.copy())
        o = ev(d)
        s = _scalarize(ctx, o)
        for _ in range(link_descent_steps):
            cands = [c for c in sample_neighbors(spec, d, rng, 0, 8)]
            if not cands:
                break
            objs = ev.batch(cands)
            scals = np.array([_scalarize(ctx, x) for x in objs])
            j = int(np.argmin(scals))
            if scals[j] >= s:
                break
            d, o, s = cands[j], objs[j], scals[j]
            history.record(ev, d, o)
        return d, o, s

    # Priority: branch higher-prominence types first (LLCs carry >80% of the
    # traffic — §3 — then CPUs, then GPUs).
    type_order = [LLC, CPU, GPU]

    stack: list[tuple[list[int], dict[int, int]]] = [([], counts0)]
    while stack:
        types, counts = stack.pop()
        if expanded >= max_expansions:
            break
        expanded += 1
        if len(types) == spec.n_tiles:
            d, o, s = link_stage(_types_to_perm(spec, types))
            pareto = pareto.merged_with([d], o[None], ctx.obj_idx)
            if s < best_scal:
                best_scal, best_design, best_objs = s, d, o
            continue
        children = []
        for t in type_order:
            if counts.get(t, 0) <= 0:
                continue
            nc = dict(counts)
            nc[t] -= 1
            nt = types + [t]
            b = bound_of(nt, nc)
            # Compensation-adjusted pruning (paper §6.1 / [12]).
            if best_scal < np.inf and b > best_scal * (1.0 + compensation):
                pruned += 1
                continue
            children.append((b, nt, nc))
        # Depth-first, most promising child last (popped first).
        for b, nt, nc in sorted(children, key=lambda z: -z[0]):
            stack.append((nt, nc))

    if best_design is None:
        raise RuntimeError(
            "PCBB found no complete design — raise max_expansions "
            f"(expanded {expanded}, pruned {pruned})")
    return PcbbResult(best_design, best_objs, pareto, expanded, pruned)
