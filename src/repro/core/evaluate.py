"""Batched design evaluation — the optimizer's compute hot loop.

The paper evaluates candidates one at a time on a Xeon; we reformulate the
whole objective stack (routing + Eqs. 1-10) as a fixed-shape JAX program and
evaluate entire neighborhoods in one jitted, vmapped batch (DESIGN.md §4).

The routing hot spot (batched APSP) is threaded through the backend switch
in core.routing: ``Evaluator(spec, f, backend="auto"|"jnp"|"pallas")``. On
TPU the blocked Pallas min-plus kernel (kernels/minplus.apsp) serves the
whole candidate batch without materializing the (N, N, N) jnp broadcast per
design; the jnp path is the oracle and the CPU execution path. The rest of
the objective stack (path walk + Eqs. 1-10) stays one jitted vmap over the
batch, consuming the precomputed (dist, next-hop) tables.
"""

from __future__ import annotations

import contextlib
import contextvars
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import routing
from .objectives import (N_OBJ, SpecConsts, design_cost, evaluate_with_tables,
                         make_consts)
from .problem import Design, SystemSpec

#: ambient SPMD mesh — set via :func:`spmd_scope`; Evaluators constructed
#: inside the scope run their batch pipeline as one shard_map program over
#: it (the same contextvar-at-construction pattern as repro.dist.worker's
#: cooperative deadline).
_SPMD_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_core_spmd_mesh", default=None)


@contextlib.contextmanager
def spmd_scope(mesh):
    """Evaluators constructed inside this scope shard their candidate
    batches across ``mesh`` (a 1-D jax.sharding.Mesh): cost build → batched
    APSP → objective walk run as ONE multi-device program per dispatch,
    each device serving batch/ndev candidates. This is how the distributed
    executor (repro.dist.worker, ``executor="spmd"``) turns a chain batch
    into a single multi-device dispatch instead of per-device processes."""
    token = _SPMD_MESH.set(mesh)
    try:
        yield
    finally:
        _SPMD_MESH.reset(token)


def make_spmd_mesh():
    """1-D mesh over every visible device (axis ``"dev"``)."""
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()), ("dev",))


class Evaluator:
    """Jitted batched evaluator for a fixed (spec, traffic) pair.

    Batches are padded to the next power of two to bound recompiles.

    ``backend`` selects the batched-APSP implementation (see core.routing):
    ``"auto"`` (default) resolves to the Pallas kernel on TPU and jnp
    elsewhere. ``interpret=True`` forces the Pallas kernel through the
    interpreter — CPU-only correctness testing of the TPU path."""

    def __init__(self, spec: SystemSpec, f: np.ndarray, *,
                 backend: str = "auto", interpret: bool = False,
                 max_batch: int | None = 256):
        self.spec = spec
        self.backend = routing.resolve_backend(backend)
        self.interpret = interpret
        self.max_batch = max_batch  # chunk bound for the (B, N, N, N) APSP broadcast
        self.consts: SpecConsts = make_consts(spec)
        self.f = jnp.asarray(f, jnp.float32)
        self._cost_fn = jax.jit(jax.vmap(partial(design_cost, self.consts)))
        self._eval_fn = jax.jit(
            jax.vmap(partial(evaluate_with_tables, self.consts),
                     in_axes=(0, 0, None, 0, 0))
        )
        self.mesh = _SPMD_MESH.get()
        self._spmd_fn = (self._build_spmd_fn() if self.mesh is not None
                         else None)
        self.n_evals = 0  # evaluation counter (search-cost accounting)
        self.n_calls = 0  # XLA dispatches (batching-efficiency accounting)

    def _build_spmd_fn(self):
        """One jitted shard_map program for the whole batch pipeline: each
        device runs cost → APSP → objective walk on its batch shard; the
        traffic matrix rides in replicated. Numerically identical to the
        single-device path — sharding the batch axis splits independent
        per-design programs, it reorders no reductions."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        consts, backend, interpret = self.consts, self.backend, self.interpret

        def local_fn(perms, adjs, f):
            costs = jax.vmap(partial(design_cost, consts))(adjs)
            dist, nh = routing.routing_tables_batched(
                costs, consts.apsp_iters, backend=backend,
                interpret=interpret)
            return jax.vmap(partial(evaluate_with_tables, consts),
                            in_axes=(0, 0, None, 0, 0))(
                perms, adjs, f, dist, nh)

        p = P(self.mesh.axis_names[0])
        return jax.jit(shard_map(local_fn, mesh=self.mesh,
                                 in_specs=(p, p, P()), out_specs=(p, p)))

    # ------------------------------------------------------------- single
    def __call__(self, d: Design) -> np.ndarray:
        return self.batch([d])[0]

    # -------------------------------------------------------------- batch
    def batch(self, designs: list[Design]) -> np.ndarray:
        """(B, 5) objective rows; invalid designs come back as +INF rows."""
        return self.batch_aux(designs)[0]

    def batch_aux(self, designs: list[Design]) -> tuple[np.ndarray, dict]:
        if not designs:
            return np.zeros((0, N_OBJ)), {"net_lat": np.zeros((0,))}
        if self.max_batch is not None and len(designs) > self.max_batch:
            # Bound the transient (chunk, N, N, N) min-plus broadcast when a
            # multi-chain driver concatenates many neighborhoods.
            outs, auxes = zip(*(
                self.batch_aux(designs[i:i + self.max_batch])
                for i in range(0, len(designs), self.max_batch)))
            return (np.concatenate(outs, axis=0),
                    {k: np.concatenate([a[k] for a in auxes], axis=0)
                     for k in auxes[0]})
        b = len(designs)
        pad = 1 << max(0, (b - 1).bit_length())
        if self._spmd_fn is not None:
            # shard_map needs the batch divisible by the device count; pad
            # further (still outside the jit — same shape-cache discipline).
            ndev = self.mesh.devices.size
            if pad % ndev:
                pad = -(-pad // ndev) * ndev
        perms = np.stack([d.perm for d in designs] + [designs[-1].perm] * (pad - b))
        adjs = np.stack([d.adj for d in designs] + [designs[-1].adj] * (pad - b))
        perms_j, adjs_j = jnp.asarray(perms), jnp.asarray(adjs)
        if self._spmd_fn is not None:
            objs, aux = self._spmd_fn(perms_j, adjs_j, self.f)
        else:
            costs = self._cost_fn(adjs_j)
            dist, nh = routing.routing_tables_batched(
                costs, self.consts.apsp_iters,
                backend=self.backend, interpret=self.interpret)
            objs, aux = self._eval_fn(perms_j, adjs_j, self.f, dist, nh)
        self.n_evals += b
        self.n_calls += 1
        aux = {k: np.asarray(v[:b]) for k, v in aux.items()}
        return np.asarray(objs[:b], dtype=np.float64), aux

    # ---------------------------------------------------------------- EDP
    def edp(self, d: Design) -> float:
        """Network EDP = network latency x network energy (paper §6.1; the
        analytic variant — core/netsim.py provides the simulated one)."""
        objs, aux = self.batch_aux([d])
        return float(aux["net_lat"][0] * objs[0, 3])
