"""Batched design evaluation — the optimizer's compute hot loop.

The paper evaluates candidates one at a time on a Xeon; we reformulate the
whole objective stack (routing + Eqs. 1-10) as a fixed-shape JAX program and
evaluate entire neighborhoods in one jitted, vmapped batch (DESIGN.md §4).

The routing hot spot (batched APSP) is threaded through the backend switch
in core.routing: ``Evaluator(spec, f, backend="auto"|"jnp"|"pallas")``. On
TPU the blocked Pallas min-plus kernel (kernels/minplus.apsp) serves the
whole candidate batch without materializing the (N, N, N) jnp broadcast per
design; the jnp path is the oracle and the CPU execution path. The rest of
the objective stack (path walk + Eqs. 1-10) stays one jitted vmap over the
batch, consuming the precomputed (dist, next-hop) tables.
"""

from __future__ import annotations

import contextlib
import contextvars
from collections import OrderedDict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import routing
from .objectives import (N_OBJ, SpecConsts, design_cost, design_cost_np,
                         evaluate_with_tables, make_consts)
from .problem import Design, NeighborMoves, SystemSpec

DELTA_MODES = ("auto", "on", "off")

#: ``delta="auto"`` switches move evaluation to incremental host tables at
#: this tile count. Below it (all paper specs: 8-64 tiles) the dense jitted
#: batch is faster than any host round-trip and stays the only path.
DELTA_AUTO_MIN_TILES = 128

#: Transient budget for one batched-APSP dispatch — bounds the (B, N, N, N)
#: (or k-blocked) broadcast by shrinking the chunk size as N grows.
_BATCH_BUDGET_BYTES = 512 << 20

#: ambient SPMD mesh — set via :func:`spmd_scope`; Evaluators constructed
#: inside the scope run their batch pipeline as one shard_map program over
#: it (the same contextvar-at-construction pattern as repro.dist.worker's
#: cooperative deadline).
_SPMD_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_core_spmd_mesh", default=None)


@contextlib.contextmanager
def spmd_scope(mesh):
    """Evaluators constructed inside this scope shard their candidate
    batches across ``mesh`` (a 1-D jax.sharding.Mesh): cost build → batched
    APSP → objective walk run as ONE multi-device program per dispatch,
    each device serving batch/ndev candidates. This is how the distributed
    executor (repro.dist.worker, ``executor="spmd"``) turns a chain batch
    into a single multi-device dispatch instead of per-device processes."""
    token = _SPMD_MESH.set(mesh)
    try:
        yield
    finally:
        _SPMD_MESH.reset(token)


def make_spmd_mesh():
    """1-D mesh over every visible device (axis ``"dev"``)."""
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()), ("dev",))


class Evaluator:
    """Jitted batched evaluator for a fixed (spec, traffic) pair.

    Batches are padded to the next power of two to bound recompiles.

    ``backend`` selects the batched-APSP implementation (see core.routing):
    ``"auto"`` (default) resolves to the Pallas kernel on TPU and jnp
    elsewhere. ``interpret=True`` forces the Pallas kernel through the
    interpreter — CPU-only correctness testing of the TPU path."""

    def __init__(self, spec: SystemSpec, f: np.ndarray, *,
                 backend: str = "auto", interpret: bool = False,
                 max_batch: int | None = 256, delta: str = "auto",
                 table_cache_bytes: int = 256 << 20):
        if delta not in DELTA_MODES:
            raise ValueError(f"delta must be one of {DELTA_MODES}, got {delta!r}")
        self.spec = spec
        self.backend = routing.resolve_backend(backend)
        self.interpret = interpret
        n = spec.n_tiles
        if max_batch is not None:
            # Chunk bound for the batched-APSP transient: at 64 tiles a
            # 256-design chunk broadcasts 256 MiB; at 256+ tiles the same
            # chunk would be gigabytes, so the bound shrinks with N.
            per = 4 * n * n * (n if n <= routing.DENSE_NMAX
                               else routing._pow2_block(n))
            max_batch = max(1, min(max_batch, _BATCH_BUDGET_BYTES // per))
        self.max_batch = max_batch
        self.consts: SpecConsts = make_consts(spec)
        self.f = jnp.asarray(f, jnp.float32)
        self._cost_fn = jax.jit(jax.vmap(partial(design_cost, self.consts)))
        self._eval_fn = jax.jit(
            jax.vmap(partial(evaluate_with_tables, self.consts),
                     in_axes=(0, 0, None, 0, 0))
        )
        self.mesh = _SPMD_MESH.get()
        self._spmd_fn = (self._build_spmd_fn() if self.mesh is not None
                         else None)
        # Incremental move evaluation (batch_moves): swap candidates reuse
        # the base design's tables verbatim (adjacency is slot-keyed, a swap
        # only permutes cores); link moves get an O(N²) table delta
        # (routing.delta_link_move) instead of a full APSP. Forced off under
        # SPMD — the shard_map pipeline recomputes tables on device.
        self.delta_mode = delta
        self.delta_on = (self._spmd_fn is None
                         and (delta == "on" or (delta == "auto"
                              and n >= DELTA_AUTO_MIN_TILES)))
        self._tab_cache: OrderedDict[bytes, routing.HostTables] = OrderedDict()
        self._tab_cache_nbytes = 0
        self._tab_cache_max_bytes = int(table_cache_bytes)
        self.delta_stats = {"swap": 0, "delta": 0, "fallback": 0,
                            "table_hits": 0, "table_misses": 0}
        self.n_evals = 0  # evaluation counter (search-cost accounting)
        self.n_calls = 0  # XLA dispatches (batching-efficiency accounting)

    def _build_spmd_fn(self):
        """One jitted shard_map program for the whole batch pipeline: each
        device runs cost → APSP → objective walk on its batch shard; the
        traffic matrix rides in replicated. Numerically identical to the
        single-device path — sharding the batch axis splits independent
        per-design programs, it reorders no reductions."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        consts, backend, interpret = self.consts, self.backend, self.interpret

        def local_fn(perms, adjs, f):
            costs = jax.vmap(partial(design_cost, consts))(adjs)
            dist, nh = routing.routing_tables_batched(
                costs, consts.apsp_iters, backend=backend,
                interpret=interpret)
            return jax.vmap(partial(evaluate_with_tables, consts),
                            in_axes=(0, 0, None, 0, 0))(
                perms, adjs, f, dist, nh)

        p = P(self.mesh.axis_names[0])
        return jax.jit(shard_map(local_fn, mesh=self.mesh,
                                 in_specs=(p, p, P()), out_specs=(p, p)))

    # ------------------------------------------------------------- single
    def __call__(self, d: Design) -> np.ndarray:
        return self.batch([d])[0]

    # -------------------------------------------------------------- batch
    def batch(self, designs: list[Design]) -> np.ndarray:
        """(B, 5) objective rows; invalid designs come back as +INF rows."""
        return self.batch_aux(designs)[0]

    def batch_aux(self, designs: list[Design]) -> tuple[np.ndarray, dict]:
        if not designs:
            return np.zeros((0, N_OBJ)), {"net_lat": np.zeros((0,))}
        if self.max_batch is not None and len(designs) > self.max_batch:
            # Bound the transient (chunk, N, N, N) min-plus broadcast when a
            # multi-chain driver concatenates many neighborhoods.
            outs, auxes = zip(*(
                self.batch_aux(designs[i:i + self.max_batch])
                for i in range(0, len(designs), self.max_batch)))
            return (np.concatenate(outs, axis=0),
                    {k: np.concatenate([a[k] for a in auxes], axis=0)
                     for k in auxes[0]})
        b = len(designs)
        pad = 1 << max(0, (b - 1).bit_length())
        if self._spmd_fn is not None:
            # shard_map needs the batch divisible by the device count; pad
            # further (still outside the jit — same shape-cache discipline).
            ndev = self.mesh.devices.size
            if pad % ndev:
                pad = -(-pad // ndev) * ndev
        perms = np.stack([d.perm for d in designs] + [designs[-1].perm] * (pad - b))
        adjs = np.stack([d.adj for d in designs] + [designs[-1].adj] * (pad - b))
        perms_j, adjs_j = jnp.asarray(perms), jnp.asarray(adjs)
        if self._spmd_fn is not None:
            objs, aux = self._spmd_fn(perms_j, adjs_j, self.f)
        else:
            costs = self._cost_fn(adjs_j)
            dist, nh = routing.routing_tables_batched(
                costs, self.consts.apsp_iters,
                backend=self.backend, interpret=self.interpret)
            objs, aux = self._eval_fn(perms_j, adjs_j, self.f, dist, nh)
        self.n_evals += b
        self.n_calls += 1
        aux = {k: np.asarray(v[:b]) for k, v in aux.items()}
        return np.asarray(objs[:b], dtype=np.float64), aux

    # -------------------------------------------------------------- moves
    def batch_moves(self, moves) -> np.ndarray:
        """(B, 5) objective rows for one or more :class:`NeighborMoves`
        neighborhoods (rows concatenate in neighborhood order, candidates in
        ``materialize`` order: swaps, then link moves).

        With deltas off this is exactly ``batch(materialize_all())`` — same
        numerics, same dispatch/eval accounting. With deltas on, routing
        tables come from the host cache: swaps reuse the base tables
        unchanged, link moves pay one O(N²) incremental update
        (full host recompute as fallback), and only the objective walk runs
        on device. Both paths are bit-equal — see routing's host-mirror
        exactness note."""
        mvs = [moves] if isinstance(moves, NeighborMoves) else list(moves)
        mvs = [m for m in mvs if len(m)]
        if not mvs:
            return np.zeros((0, N_OBJ))
        if not self.delta_on:
            return self.batch([d for m in mvs for d in m.materialize_all()])
        perms, adjs, dists, nhs = [], [], [], []
        for mv in mvs:
            t0 = self._host_tables(mv.base)
            for s in range(mv.swaps.shape[0]):
                a, b = int(mv.swaps[s, 0]), int(mv.swaps[s, 1])
                p = mv.base.perm.copy()
                p[a], p[b] = p[b], p[a]
                perms.append(p)
                adjs.append(mv.base.adj)
                dists.append(t0.dist)
                nhs.append(t0.nh)
                self.delta_stats["swap"] += 1
            for k in range(mv.rem.shape[0]):
                rem = (int(mv.rem[k, 0]), int(mv.rem[k, 1]))
                add = (int(mv.add[k, 0]), int(mv.add[k, 1]))
                t = self._moved_tables(t0, rem, add)
                adj2 = mv.base.adj.copy()
                adj2[rem[0], rem[1]] = adj2[rem[1], rem[0]] = False
                adj2[add[0], add[1]] = adj2[add[1], add[0]] = True
                perms.append(mv.base.perm)
                adjs.append(adj2)
                dists.append(t.dist)
                nhs.append(t.nh)
        return self._eval_from_tables(perms, adjs, dists, nhs)

    def note_accept(self, mv: NeighborMoves, j: int) -> None:
        """Tell the evaluator candidate ``j`` of ``mv`` was accepted: cache
        the winner's host tables (one delta from the already-cached base) so
        the next step's neighborhood starts from a cache hit. No-op when
        deltas are off or the winner is a swap (same adjacency)."""
        if not self.delta_on:
            return
        s = mv.swaps.shape[0]
        if j < s:
            return
        k = j - s
        rem = (int(mv.rem[k, 0]), int(mv.rem[k, 1]))
        add = (int(mv.add[k, 0]), int(mv.add[k, 1]))
        adj2 = mv.base.adj.copy()
        adj2[rem[0], rem[1]] = adj2[rem[1], rem[0]] = False
        adj2[add[0], add[1]] = adj2[add[1], add[0]] = True
        key = np.packbits(adj2).tobytes()
        if key in self._tab_cache:
            self._tab_cache.move_to_end(key)
            return
        t = self._moved_tables(self._host_tables(mv.base), rem, add)
        self._tab_put(key, t)

    def _host_tables(self, base: Design) -> routing.HostTables:
        key = np.packbits(base.adj).tobytes()
        t = self._tab_cache.get(key)
        if t is not None:
            self._tab_cache.move_to_end(key)
            self.delta_stats["table_hits"] += 1
            return t
        self.delta_stats["table_misses"] += 1
        t = routing.host_tables(design_cost_np(self.spec, base.adj),
                                self.consts.apsp_iters)
        self._tab_put(key, t)
        return t

    def _moved_tables(self, t0: routing.HostTables, rem, add
                      ) -> routing.HostTables:
        w = (np.float32(self.spec.router_stages)
             + np.float32(self.spec.link_delay[add[0], add[1]]))
        t = routing.delta_link_move(t0, rem, add, w)
        if t is None:
            self.delta_stats["fallback"] += 1
            cost2 = t0.cost.copy()
            cost2[rem[0], rem[1]] = cost2[rem[1], rem[0]] = np.float32(routing.INF)
            cost2[add[0], add[1]] = cost2[add[1], add[0]] = w
            return routing.host_tables(cost2, self.consts.apsp_iters)
        self.delta_stats["delta"] += 1
        return t

    def _tab_put(self, key: bytes, t: routing.HostTables) -> None:
        old = self._tab_cache.pop(key, None)
        if old is not None:
            self._tab_cache_nbytes -= old.nbytes
        self._tab_cache[key] = t
        self._tab_cache_nbytes += t.nbytes
        while (self._tab_cache_nbytes > self._tab_cache_max_bytes
               and len(self._tab_cache) > 1):
            _, evicted = self._tab_cache.popitem(last=False)
            self._tab_cache_nbytes -= evicted.nbytes

    def _eval_from_tables(self, perms, adjs, dists, nhs) -> np.ndarray:
        """Dispatch the objective walk over candidates with precomputed
        routing tables — chunked by ``max_batch``, padded to the next power
        of two (the same shape-cache discipline as ``batch_aux``); the same
        eval/dispatch counters apply."""
        out = []
        step = self.max_batch if self.max_batch is not None else len(perms)
        for i in range(0, len(perms), step):
            b = len(perms[i:i + step])
            pad = 1 << max(0, (b - 1).bit_length())
            sl = slice(i, i + b)
            tail = pad - b
            pj = jnp.asarray(np.stack(perms[sl] + [perms[i + b - 1]] * tail))
            aj = jnp.asarray(np.stack(adjs[sl] + [adjs[i + b - 1]] * tail))
            dj = jnp.asarray(np.stack(dists[sl] + [dists[i + b - 1]] * tail))
            nj = jnp.asarray(np.stack(nhs[sl] + [nhs[i + b - 1]] * tail))
            objs, _ = self._eval_fn(pj, aj, self.f, dj, nj)
            self.n_evals += b
            self.n_calls += 1
            out.append(np.asarray(objs[:b], dtype=np.float64))
        return np.concatenate(out, axis=0)

    # ---------------------------------------------------------------- EDP
    def edp(self, d: Design) -> float:
        """Network EDP = network latency x network energy (paper §6.1; the
        analytic variant — core/netsim.py provides the simulated one)."""
        objs, aux = self.batch_aux([d])
        return float(aux["net_lat"][0] * objs[0, 3])
