"""Batched design evaluation — the optimizer's compute hot loop.

The paper evaluates candidates one at a time on a Xeon; we reformulate the
whole objective stack (routing + Eqs. 1-10) as a fixed-shape JAX program and
evaluate entire neighborhoods in one jitted, vmapped batch (DESIGN.md §4).
On TPU the two inner hot spots can be served by Pallas kernels
(kernels/minplus, kernels/link_util); the jnp path is the reference and the
CPU execution path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .objectives import N_OBJ, SpecConsts, evaluate_design, make_consts
from .problem import Design, SystemSpec


class Evaluator:
    """Jitted batched evaluator for a fixed (spec, traffic) pair.

    Batches are padded to the next power of two to bound recompiles."""

    def __init__(self, spec: SystemSpec, f: np.ndarray):
        self.spec = spec
        self.consts: SpecConsts = make_consts(spec)
        self.f = jnp.asarray(f, jnp.float32)
        self._batched = jax.jit(
            jax.vmap(partial(evaluate_design, self.consts), in_axes=(0, 0, None))
        )
        self.n_evals = 0  # evaluation counter (search-cost accounting)

    # ------------------------------------------------------------- single
    def __call__(self, d: Design) -> np.ndarray:
        return self.batch([d])[0]

    # -------------------------------------------------------------- batch
    def batch(self, designs: list[Design]) -> np.ndarray:
        """(B, 5) objective rows; invalid designs come back as +INF rows."""
        return self.batch_aux(designs)[0]

    def batch_aux(self, designs: list[Design]) -> tuple[np.ndarray, dict]:
        if not designs:
            return np.zeros((0, N_OBJ)), {"net_lat": np.zeros((0,))}
        b = len(designs)
        pad = 1 << max(0, (b - 1).bit_length())
        perms = np.stack([d.perm for d in designs] + [designs[-1].perm] * (pad - b))
        adjs = np.stack([d.adj for d in designs] + [designs[-1].adj] * (pad - b))
        objs, aux = self._batched(jnp.asarray(perms), jnp.asarray(adjs), self.f)
        self.n_evals += b
        aux = {k: np.asarray(v[:b]) for k, v in aux.items()}
        return np.asarray(objs[:b], dtype=np.float64), aux

    # ---------------------------------------------------------------- EDP
    def edp(self, d: Design) -> float:
        """Network EDP = network latency x network energy (paper §6.1; the
        analytic variant — core/netsim.py provides the simulated one)."""
        objs, aux = self.batch_aux([d])
        return float(aux["net_lat"][0] * objs[0, 3])
