"""Algorithm 1 — PHV-greedy local search.

From a starting design, repeatedly evaluate a (sampled) neighborhood in one
batched JAX call, move to the neighbor maximizing PHV(S_local ∪ {d}), and
stop when the best neighbor no longer improves the PHV. Returns the local
non-dominated set, the search trajectory, and the last design (Alg. 1's
(S_local, S_traj, d_last))."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .evaluate import Evaluator
from .pareto import PhvContext, pareto_mask
from .problem import Design, SystemSpec, sample_neighbors


@dataclasses.dataclass
class ParetoSet:
    """A set of designs + their (full 5-dim) objective rows, non-dominated
    under the active objective subset."""

    designs: list[Design]
    objs: np.ndarray  # (n, 5)

    @staticmethod
    def empty() -> "ParetoSet":
        return ParetoSet([], np.zeros((0, 5)))

    def sub(self, obj_idx) -> np.ndarray:
        return self.objs[:, list(obj_idx)] if len(self.designs) else self.objs

    def merged_with(self, designs: list[Design], objs: np.ndarray,
                    obj_idx) -> "ParetoSet":
        alld = self.designs + list(designs)
        allo = np.vstack([self.objs, np.atleast_2d(objs)]) if alld else self.objs
        mask = pareto_mask(allo[:, list(obj_idx)])
        return ParetoSet([d for d, m in zip(alld, mask) if m], allo[mask])

    def keys(self) -> set[bytes]:
        return {d.key() for d in self.designs}


@dataclasses.dataclass
class LocalResult:
    local: ParetoSet
    traj: list[Design]
    traj_objs: np.ndarray
    d_last: Design
    phv: float
    n_steps: int


def local_search(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    d_start: Design,
    rng: np.random.Generator,
    *,
    n_swaps: int = 24,
    n_link_moves: int = 24,
    max_steps: int = 10_000,
    max_set: int = 24,
    history: "SearchHistory | None" = None,
) -> LocalResult:
    start_objs = ev(d_start)
    s_local = ParetoSet.empty().merged_with([d_start], start_objs[None], ctx.obj_idx)
    traj = [d_start]
    traj_objs = [start_objs]
    d_curr = d_start
    phv_curr = ctx.phv(s_local.objs)

    steps = 0
    for steps in range(1, max_steps + 1):
        cands = sample_neighbors(spec, d_curr, rng, n_swaps, n_link_moves)
        if not cands:
            break
        objs = ev.batch(cands)
        # argmax_d PHV(S_local ∪ {d}) — Alg. 1 line 3, scored for the whole
        # neighborhood in one batched exclusive-contribution pass.
        phvs = ctx.phv_with_batch(s_local.objs, objs)
        j = int(np.argmax(phvs))
        if phvs[j] <= phv_curr + 1e-12:
            break
        d_curr = cands[j]
        s_local = s_local.merged_with([d_curr], objs[j][None], ctx.obj_idx)
        if len(s_local.designs) > max_set:
            # Bound the PHV working set (crowding thinning, as AMOSA bounds
            # its archive) — HSO cost grows fast with set size.
            from .amosa import _crowding_thin
            keep = _crowding_thin(
                ctx.normalize(s_local.objs), max_set * 2 // 3)
            s_local = ParetoSet(
                [s_local.designs[i] for i in keep], s_local.objs[keep])
        phv_curr = phvs[j]
        traj.append(d_curr)
        traj_objs.append(objs[j])
        if history is not None:
            history.record(ev, d_curr, objs[j])

    return LocalResult(
        local=s_local,
        traj=traj,
        traj_objs=np.stack(traj_objs),
        d_last=d_curr,
        phv=phv_curr,
        n_steps=steps,
    )


class SearchHistory:
    """Convergence trace: (wall time, #evaluations, best-so-far EDP, PHV).

    Used by the Fig. 6 / Table 2 benchmarks to compare optimizers on equal
    footing (both wall-clock and evaluation count). PHV per record is
    expensive (recursive HSO); it is only computed when ``track_phv``."""

    def __init__(self, ev: Evaluator, ctx: PhvContext,
                 track_phv: bool = False):
        self.t0 = time.perf_counter()
        self.ctx = ctx
        self.track_phv = track_phv
        self.rows: list[tuple[float, int, float, float]] = []
        self.best_edp = np.inf
        self._pareto_objs = np.zeros((0, 5))

    def record(self, ev: Evaluator, d: Design, objs: np.ndarray):
        edp = float(objs[2] * objs[3])  # cpu-llc latency x energy (analytic)
        self.best_edp = min(self.best_edp, edp)
        phv = np.nan
        if self.track_phv:
            self._pareto_objs = np.vstack([self._pareto_objs, objs[None]])
            mask = pareto_mask(self._pareto_objs[:, list(self.ctx.obj_idx)])
            self._pareto_objs = self._pareto_objs[mask]
            phv = self.ctx.phv(self._pareto_objs)
        self.rows.append(
            (time.perf_counter() - self.t0, ev.n_evals, self.best_edp, phv)
        )

    def as_array(self) -> np.ndarray:
        return np.asarray(self.rows, dtype=np.float64).reshape(-1, 4)
