"""Algorithm 1 — PHV-greedy local search.

From a starting design, repeatedly evaluate a (sampled) neighborhood in one
batched JAX call, move to the neighbor maximizing PHV(S_local ∪ {d}), and
stop when the best neighbor no longer improves the PHV. Returns the local
non-dominated set, the search trajectory, and the last design (Alg. 1's
(S_local, S_traj, d_last)).

:func:`local_search_batch` runs K chains in lockstep: per step, every live
chain samples its neighborhood and ALL candidates go through one
``Evaluator.batch`` call (one padded XLA dispatch serves every chain), then
each chain takes its own greedy PHV step. ``local_search`` is the K=1
special case."""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .evaluate import Evaluator
from .pareto import ParetoArchive, PhvContext
from .problem import Design, SystemSpec, sample_neighbor_moves


@dataclasses.dataclass
class ParetoSet:
    """A set of designs + their (full 5-dim) objective rows, non-dominated
    under the active objective subset."""

    designs: list[Design]
    objs: np.ndarray  # (n, 5)

    @staticmethod
    def empty() -> "ParetoSet":
        return ParetoSet([], np.zeros((0, 5)))

    def sub(self, obj_idx) -> np.ndarray:
        return self.objs[:, list(obj_idx)] if len(self.designs) else self.objs

    def merged_with(self, designs: list[Design], objs: np.ndarray,
                    obj_idx) -> "ParetoSet":
        """Pareto union with new (design, objective-row) pairs.

        Incremental: ``self`` is by construction an already-non-dominated,
        deduplicated front (every ParetoSet is produced by a previous merge
        under the same ``obj_idx``), so it seeds a :class:`ParetoArchive`
        unchecked and only the *new* rows pay an O(front·k) insertion each
        — no O(n²·k) dominance cube. Output rows keep stacked order
        (surviving old rows, then accepted new rows), byte-identical to the
        historical ``pareto_mask`` implementation."""
        alld = self.designs + list(designs)
        if not alld:
            return ParetoSet.empty()
        allo = np.vstack([self.objs, np.atleast_2d(objs)])
        sub = allo[:, list(obj_idx)]
        n_old = len(self.designs)
        arch = ParetoArchive.from_front(sub[:n_old], tags=range(n_old))
        keep = np.zeros(len(alld), dtype=bool)
        keep[:n_old] = True
        for i in range(n_old, len(alld)):
            ok, evicted = arch.insert(sub[i], tag=i)
            keep[i] = ok
            for t in evicted:
                keep[t] = False
        return ParetoSet([d for d, m in zip(alld, keep) if m], allo[keep])

    @staticmethod
    def canonical_union(sets: "list[ParetoSet]", obj_idx) -> "ParetoSet":
        """Order-independent Pareto union: a pure function of the input
        *set* of (design, objectives) pairs — any permutation of ``sets``
        (or of the rows inside them) yields bit-identical output.

        ``merged_with`` accumulates in arrival order, and ``pareto_mask``
        keeps the *first* of exact-tied rows, so which tied design
        survives depends on that order. Here all pairs are deduplicated
        and canonically sorted by (objective row, design key) before the
        mask runs — the determinism a distributed merge needs when worker
        results arrive in pool-completion order (repro.dist.merge)."""
        pairs: dict[tuple, tuple] = {}
        for ps in sets:
            objs = np.asarray(ps.objs, dtype=np.float64)
            for d, o in zip(ps.designs, objs):
                pairs.setdefault((tuple(o.tolist()), d.key()), (d, o))
        if not pairs:
            return ParetoSet.empty()
        order = sorted(pairs)
        designs = [pairs[k][0] for k in order]
        objs = np.stack([pairs[k][1] for k in order])
        sub = objs[:, list(obj_idx)]
        arch = ParetoArchive(sub.shape[1])
        keep = np.zeros(len(order), dtype=bool)
        for i in range(len(order)):
            ok, evicted = arch.insert(sub[i], tag=i)
            keep[i] = ok
            for t in evicted:
                keep[t] = False
        return ParetoSet([d for d, m in zip(designs, keep) if m], objs[keep])

    def keys(self) -> set[bytes]:
        return {d.key() for d in self.designs}


@dataclasses.dataclass
class LocalResult:
    local: ParetoSet
    traj: list[Design]
    traj_objs: np.ndarray
    d_last: Design
    phv: float
    n_steps: int


def local_search(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    d_start: Design,
    rng: np.random.Generator,
    *,
    n_swaps: int = 24,
    n_link_moves: int = 24,
    max_steps: int = 10_000,
    max_set: int = 24,
    history: "SearchHistory | None" = None,
    max_evals: int | None = None,
) -> LocalResult:
    return local_search_batch(
        spec, ev, ctx, [d_start], rng,
        n_swaps=n_swaps, n_link_moves=n_link_moves, max_steps=max_steps,
        max_set=max_set, history=history, max_evals=max_evals,
    )[0]


class _Chain:
    """Mutable per-chain state for the lockstep driver."""

    __slots__ = ("s_local", "traj", "traj_objs", "d_curr", "phv", "active",
                 "n_steps")

    def __init__(self, d0: Design, objs0: np.ndarray, ctx: PhvContext,
                 seed_set: "ParetoSet | None" = None):
        base = seed_set if seed_set is not None else ParetoSet.empty()
        self.s_local = base.merged_with([d0], objs0[None], ctx.obj_idx)
        self.traj = [d0]
        self.traj_objs = [objs0]
        self.d_curr = d0
        self.phv = ctx.phv(self.s_local.objs)
        self.active = True
        self.n_steps = 0


def local_search_batch(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    starts: list[Design],
    rng: np.random.Generator,
    *,
    n_swaps: int = 24,
    n_link_moves: int = 24,
    max_steps: int = 10_000,
    max_set: int = 24,
    history: "SearchHistory | None" = None,
    max_evals: int | None = None,
    seed_set: "ParetoSet | None" = None,
) -> list[LocalResult]:
    """K PHV-greedy local searches advanced in lockstep (one padded
    ``Evaluator.batch`` call per step serves every live chain). With a
    single start this IS ``local_search`` — the rng stream, greedy argmax,
    and thinning are identical. ``max_evals`` stops launching new steps once
    the evaluator's counter crosses the budget (multi-start accounting).

    ``seed_set`` (e.g. the global non-dominated set of a multi-start driver)
    pre-populates every chain's working set, so each chain greedily maximizes
    its *marginal* PHV over what is already known — chains coordinate toward
    complementary regions instead of re-finding the same tradeoffs."""
    from .amosa import _crowding_thin

    start_objs = ev.batch(starts)
    chains = [_Chain(d0, o, ctx, seed_set) for d0, o in zip(starts, start_objs)]

    for step in range(1, max_steps + 1):
        if max_evals is not None and ev.n_evals >= max_evals:
            break
        move_lists: list = []
        for ch in chains:
            if not ch.active:
                move_lists.append(None)
                continue
            ch.n_steps = step
            # Neighborhoods stay in move form: the evaluator can serve them
            # from incremental table deltas (Evaluator.batch_moves — at
            # spec_large scale each candidate costs an O(N²) table update
            # instead of a full APSP), and only the per-chain winning move
            # is ever materialized as a Design.
            mv = sample_neighbor_moves(spec, ch.d_curr, rng, n_swaps,
                                       n_link_moves)
            if not len(mv):
                ch.active = False
                move_lists.append(None)
                continue
            move_lists.append(mv)
        live = [mv for mv in move_lists if mv is not None]
        if not live:
            break
        objs_all = ev.batch_moves(live)
        ofs = 0
        for ch, mv in zip(chains, move_lists):
            if mv is None:
                continue
            objs = objs_all[ofs:ofs + len(mv)]
            ofs += len(mv)
            if not ch.active:
                continue
            # argmax_d PHV(S_local ∪ {d}) — Alg. 1 line 3, scored for the
            # whole neighborhood in one batched exclusive-contribution pass.
            phvs = ctx.phv_with_batch(ch.s_local.objs, objs)
            j = int(np.argmax(phvs))
            if phvs[j] <= ch.phv + 1e-12:
                ch.active = False
                continue
            ch.d_curr = mv.materialize(j)
            ev.note_accept(mv, j)
            ch.s_local = ch.s_local.merged_with([ch.d_curr], objs[j][None],
                                                ctx.obj_idx)
            ch.phv = phvs[j]
            if len(ch.s_local.designs) > max_set:
                # Bound the PHV working set (crowding thinning, as AMOSA
                # bounds its archive) — HSO cost grows fast with set size.
                keep = _crowding_thin(
                    ctx.normalize(ch.s_local.objs), max_set * 2 // 3)
                ch.s_local = ParetoSet(
                    [ch.s_local.designs[i] for i in keep],
                    ch.s_local.objs[keep])
                # Re-anchor the greedy bar to the thinned set: candidates are
                # scored against it, so keeping the pre-thinning PHV would
                # set an unattainable bar and stall the chain.
                ch.phv = ctx.phv(ch.s_local.objs)
            ch.traj.append(ch.d_curr)
            ch.traj_objs.append(objs[j])
            if history is not None:
                history.record(ev, ch.d_curr, objs[j])
        if not any(ch.active for ch in chains):
            break

    return [
        LocalResult(
            local=ch.s_local,
            traj=ch.traj,
            traj_objs=np.stack(ch.traj_objs),
            d_last=ch.d_curr,
            phv=ch.phv,
            n_steps=ch.n_steps,
        )
        for ch in chains
    ]


class SearchHistory:
    """Convergence trace: (wall time, #evaluations, best-so-far EDP, PHV).

    Used by the Fig. 6 / Table 2 benchmarks to compare optimizers on equal
    footing (both wall-clock and evaluation count). PHV per record is
    expensive (recursive HSO); it is only computed when ``track_phv``."""

    def __init__(self, ev: Evaluator, ctx: PhvContext,
                 track_phv: bool = False):
        self.t0 = time.perf_counter()
        self.ctx = ctx
        self.track_phv = track_phv
        self.rows: list[tuple[float, int, float, float]] = []
        self.best_edp = np.inf
        # Incremental best-so-far front: each record pays one O(front·k)
        # archive insertion instead of rebuilding pareto_mask's O(n²·k)
        # dominance cube over the accumulated rows. Tags carry the full
        # 5-dim rows (the archive itself only sees the active subset).
        self._arch = ParetoArchive(len(ctx.obj_idx))

    def record(self, ev: Evaluator, d: Design, objs: np.ndarray):
        edp = float(objs[2] * objs[3])  # cpu-llc latency x energy (analytic)
        self.best_edp = min(self.best_edp, edp)
        phv = np.nan
        if self.track_phv:
            full = np.asarray(objs, dtype=np.float64).copy()
            self._arch.insert(full[list(self.ctx.obj_idx)], tag=full)
            phv = self.ctx.phv(np.stack(self._arch.tags))
        self.rows.append(
            (time.perf_counter() - self.t0, ev.n_evals, self.best_edp, phv)
        )

    def as_array(self) -> np.ndarray:
        return np.asarray(self.rows, dtype=np.float64).reshape(-1, 4)
