"""Device twin of the batched hypervolume scorer (core.pareto).

The PHV-greedy chain step (local_search) scores a whole candidate batch
with ``PhvContext.phv_with_batch`` — host-side recursive HSO per surviving
candidate. This module reformulates that scorer as a fixed-shape jitted
program so a chain step's scoring can run as one device dispatch: the
Pareto set rides in padded to a fixed row count with a validity mask, and
the HSO recursion becomes a *masked* recursion on the (static) objective
count — masked rows are pinned at the reference point, where they dominate
nothing and contribute zero volume, so no data-dependent filtering or
compaction is ever needed inside the jit.

Shape discipline (PR-4): the set rows pad to ``max_set`` and the candidate
batch to a power of two OUTSIDE the jit, so the cache keys on (S, B, m)
quanta. The m >= 3 slab recursion vmaps the (m-1)-dimensional volume over
prefix masks of the x-sorted set — O(S^2) slabs for m=3 at S <= 32 is tiny
next to the objective evaluation the chain step already paid for.

Precision contract: this twin computes in f32 (device default). The host
scorer is f64, and the chain accept test uses a 1e-12 epsilon that f32
cannot resolve near convergence — so the twin is an OPT-IN backend
(``PhvContext(phv_backend="jnp")``), conformance-tested against the host
oracle to f32 tolerances, and the default stays host-exact.
"""

from __future__ import annotations

import numpy as np


def _hv_masked(pts, mask, ref):
    """Hypervolume of the masked rows of ``pts`` w.r.t. ``ref`` (traceable).

    ``pts`` (S, m) must already be clipped to ``ref``; masked-out rows are
    replaced by ``ref`` itself (zero contribution). The recursion is on the
    static trailing-dimension count, exactly mirroring pareto._hso: 1-D
    closed form, 2-D staircase, m >= 3 x-sorted slabs — but with every
    data-dependent set size replaced by masking."""
    import jax
    import jax.numpy as jnp

    m = pts.shape[1]
    p = jnp.where(mask[:, None], pts, ref[None, :])
    if m == 1:
        return jnp.maximum(ref[0] - p[:, 0].min(), 0.0)
    order = jnp.argsort(p[:, 0], stable=True)
    p = p[order]
    x = p[:, 0]
    x_hi = jnp.concatenate([x[1:], ref[:1]])
    if m == 2:
        ymin = jax.lax.cummin(p[:, 1])
        return ((x_hi - x) * (ref[1] - ymin)).sum()
    s = p.shape[0]
    prefix = jnp.tril(jnp.ones((s, s), bool))  # prefix[i] = sorted rows 0..i
    sub = jax.vmap(lambda msk: _hv_masked(p[:, 1:], msk, ref[1:]))(prefix)
    return ((x_hi - x) * sub).sum()


def _phv_batch_fn():
    """Build the jitted batched scorer lazily (no jax at import)."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def run(setp, smask, cands, ref):
        # HV(S ∪ {c}) = HV(S) + box(c) − HV(S clipped into box(c)); covered
        # candidates collapse to HV(S) — the same exclusive-contribution
        # identity as pareto.hypervolume_with_batch, vmapped over c.
        c = jnp.minimum(cands, ref)
        box = jnp.prod(jnp.maximum(ref - c, 0.0), axis=1)
        sp = jnp.minimum(setp, ref)
        base = _hv_masked(sp, smask, ref)
        le = (sp[None, :, :] <= c[:, None, :]).all(2) & smask[None, :]
        covered = le.any(1)
        vol_sub = jax.vmap(
            lambda ci: _hv_masked(jnp.maximum(sp, ci), smask, ref))(c)
        return jnp.where(covered | (box <= 0), base, base + box - vol_sub)

    return run


_PHV_JIT = None


def hypervolume_with_batch_jnp(points: np.ndarray, cands: np.ndarray,
                               ref: np.ndarray, *,
                               max_set: int = 32) -> np.ndarray:
    """Device twin of :func:`pareto.hypervolume_with_batch` — (B,) array of
    HV(points ∪ {c}) in f32. Pads the set to ``max_set`` quanta and the
    batch to a power of two outside the jit."""
    import jax.numpy as jnp

    global _PHV_JIT
    if _PHV_JIT is None:
        _PHV_JIT = _phv_batch_fn()
    pts = np.atleast_2d(np.asarray(points, np.float32))
    cnd = np.atleast_2d(np.asarray(cands, np.float32))
    ref32 = np.asarray(ref, np.float32)
    m = ref32.shape[0]
    s = pts.shape[0] if pts.size else 0
    sp = max(max_set, 1 << max(0, (s - 1).bit_length())) if s else max_set
    setp = np.broadcast_to(ref32, (sp, m)).copy()
    if s:
        setp[:s] = pts
    smask = np.zeros(sp, bool)
    smask[:s] = True
    b = cnd.shape[0]
    bp = 1 << max(0, (b - 1).bit_length())
    cp = np.broadcast_to(ref32, (bp, m)).copy()
    cp[:b] = cnd
    out = _PHV_JIT(jnp.asarray(setp), jnp.asarray(smask),
                   jnp.asarray(cp), jnp.asarray(ref32))
    return np.asarray(out[:b], np.float64)
