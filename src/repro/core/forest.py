"""Regression forest (bagged CART) — the paper's base learner for Eval.

sklearn is unavailable offline; this is a compact numpy implementation. The
paper notes any quick, sufficiently expressive regressor works (§5.2).
Trees use variance-reduction splits, bootstrap bagging, and per-split
feature subsampling.

Inference is the MOO-STAGE hot path (the surrogate is queried for whole
sampled neighborhoods every meta-search step), so after fitting, the forest
is flattened into struct-of-arrays form: per-tree ``feature`` / ``threshold``
/ ``left`` / ``right`` / ``value`` arrays packed into one padded (T, M)
tensor. ``predict`` traverses all trees for all samples in one vectorized
pass — a (T, B) node-pointer array advanced ``depth`` times with flat
gathers — with a backend switch mirroring core.routing:

  * ``"numpy"``  — the oracle; bit-equal to the recursive traversal
    (``predict_reference``), pinned by golden tests.
  * ``"jnp"``    — jit-compiled float32 traversal (``lax.fori_loop`` over
    depth), batch-padded to a power of two so meta-search can fuse scoring;
    agrees with numpy up to f32 threshold rounding.
  * ``"pallas"`` — the blocked VMEM-resident traversal kernel in
    kernels/forest (grid over batch blocks, node tensors pinned across the
    grid). TPU only; ``interpret=True`` runs it on CPU (tests); requesting
    it on a CPU/GPU host without interpret falls back to jnp with a
    one-time warning (same contract as core.routing's backend switch —
    never fail inside jit because of the host platform).
  * ``"auto"``   — ``"pallas"`` on TPU, ``"jnp"`` on GPU, numpy/jnp by
    batch size on CPU (DESIGN.md §4.4).
"""

from __future__ import annotations

import warnings
from functools import partial

import numpy as np

FOREST_BACKENDS = ("auto", "numpy", "jnp", "pallas")

_PALLAS_FALLBACK_WARNED = False
#: set after an on-device kernel failure — resolution then routes every
#: non-interpret pallas request (including auto-on-TPU) to jnp so one
#: Mosaic lowering failure cannot crash every subsequent surrogate predict.
_PALLAS_DISABLED = False


def check_forest_backend(backend: str | None, *,
                         allow_none: bool = False) -> None:
    """Shared membership check for every forest_backend knob (the forest
    itself, resolution, NocProblem, the stage configs) — one error
    message, one maintenance site. ``allow_none`` admits the configs'
    "inherit the problem's knob" sentinel."""
    if backend is None and allow_none:
        return
    if backend not in FOREST_BACKENDS:
        raise ValueError(
            f"forest_backend must be one of {FOREST_BACKENDS}, "
            f"got {backend!r}")


def resolve_forest_backend(backend: str | None = None,
                           batch: int | None = None,
                           interpret: bool = False) -> str:
    """Resolve ``backend`` (default ``"auto"``) to a concrete one.

    ``auto`` picks the Pallas kernel on TPU and jnp on GPU; on CPU it picks
    numpy for small (neighborhood-sized) batches, where per-call dispatch
    dominates, and the jitted jnp traversal for large ones. An explicit
    ``"pallas"`` on a host without a TPU resolves to ``"jnp"`` with a
    one-time warning unless ``interpret`` is set (the interpreter runs the
    kernel anywhere)."""
    global _PALLAS_FALLBACK_WARNED
    b = backend if backend is not None else "auto"
    check_forest_backend(b)
    if b == "auto":
        import jax

        platform = jax.default_backend()
        if platform == "tpu":
            b = "pallas"
        elif platform == "gpu":
            b = "jnp"
        else:
            b = "numpy" if batch is not None and batch < 512 else "jnp"
    if b == "pallas" and not interpret:
        import jax

        if _PALLAS_DISABLED:
            b = "jnp"
        elif jax.default_backend() != "tpu":
            if not _PALLAS_FALLBACK_WARNED:
                warnings.warn(
                    "forest backend 'pallas' requires a TPU (or "
                    "interpret=True); falling back to 'jnp' on "
                    f"{jax.default_backend()!r}", stacklevel=2)
                _PALLAS_FALLBACK_WARNED = True
            b = "jnp"
    return b


class _Tree:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = 0.0


def _build(x, y, rng, depth, max_depth, min_leaf, n_feat_try):
    node = _Tree()
    node.value = float(y.mean())
    if depth >= max_depth or y.shape[0] < 2 * min_leaf or np.ptp(y) < 1e-12:
        return node
    n, f = x.shape
    best = (None, None, np.inf)
    for feat in rng.choice(f, size=min(n_feat_try, f), replace=False):
        xs = x[:, feat]
        order = np.argsort(xs, kind="stable")
        xs_s, y_s = xs[order], y[order]
        # candidate split points between distinct neighbor values
        csum = np.cumsum(y_s)
        csq = np.cumsum(y_s**2)
        tot, tot2 = csum[-1], csq[-1]
        idx = np.arange(min_leaf, n - min_leaf)
        if idx.size == 0:
            continue
        valid = xs_s[idx] < xs_s[idx + 1] - 1e-15
        idx = idx[valid]
        if idx.size == 0:
            continue
        nl = idx + 1.0
        nr = n - nl
        sse = (csq[idx] - csum[idx] ** 2 / nl) + (
            (tot2 - csq[idx]) - (tot - csum[idx]) ** 2 / nr
        )
        j = int(np.argmin(sse))
        if sse[j] < best[2]:
            thr = 0.5 * (xs_s[idx[j]] + xs_s[idx[j] + 1])
            best = (int(feat), float(thr), float(sse[j]))
    if best[0] is None:
        return node
    node.feature, node.threshold = best[0], best[1]
    mask = x[:, node.feature] <= node.threshold
    node.left = _build(x[mask], y[mask], rng, depth + 1, max_depth, min_leaf, n_feat_try)
    node.right = _build(x[~mask], y[~mask], rng, depth + 1, max_depth, min_leaf, n_feat_try)
    return node


def _predict_tree(node: _Tree, x: np.ndarray) -> np.ndarray:
    out = np.empty(x.shape[0])
    stack = [(node, np.arange(x.shape[0]))]
    while stack:
        nd, idx = stack.pop()
        if nd.left is None:
            out[idx] = nd.value
            continue
        mask = x[idx, nd.feature] <= nd.threshold
        stack.append((nd.left, idx[mask]))
        stack.append((nd.right, idx[~mask]))
    return out


def _flatten_tree(root: _Tree):
    """Preorder struct-of-arrays form of one tree.

    Leaves get ``feature = -1`` and self-loop children, so traversal past a
    leaf is the identity and every sample can be advanced the same (max)
    number of steps."""
    feature: list[int] = []
    threshold: list[float] = []
    left: list[int] = []
    right: list[int] = []
    value: list[float] = []
    depth = 0

    def rec(node: _Tree, d: int) -> int:
        nonlocal depth
        depth = max(depth, d)
        i = len(feature)
        feature.append(-1 if node.left is None else node.feature)
        threshold.append(node.threshold)
        value.append(node.value)
        left.append(i)
        right.append(i)
        if node.left is not None:
            left[i] = rec(node.left, d + 1)
            right[i] = rec(node.right, d + 1)
        return i

    rec(root, 0)
    return (np.asarray(feature, np.int32), np.asarray(threshold, np.float64),
            np.asarray(left, np.int32), np.asarray(right, np.int32),
            np.asarray(value, np.float64), depth)


def flat_forest_eval(thrfeat, child, value, xn, depth, n_trees, n_nodes):
    """Traceable flat traversal body — (B,) forest mean from the packed
    ``jnp_tensors()`` layout and an already-normalized f32 batch.

    Works on the (T*B,)-flattened node-pointer layout: every (tree, sample)
    pair advances one int32 pointer per level via three 1-D gathers. Leaves
    self-loop, so no leaf masking is needed and the loop fully unrolls
    (``depth`` must be a Python int). Shared by the standalone jitted
    predict below and the fused meta-search pipeline (core.fused), which
    inlines it after its on-device featurization."""
    import jax.numpy as jnp

    def g(a, idx):
        # All pointers are in bounds by construction (children stay inside
        # their tree, leaf features are clamped to 0) — skipping the default
        # index clamping roughly halves the gather cost on CPU.
        return a.at[idx].get(mode="promise_in_bounds")

    # thrfeat packs (threshold, feature) as one complex64 per node, so a
    # level costs 3 gathers instead of 4 (features are tiny ints — exact
    # as f32 imag parts).
    b, f = xn.shape
    xnf = xn.reshape(-1)
    idx = jnp.repeat(jnp.arange(n_trees, dtype=jnp.int32) * n_nodes, b)
    cols = jnp.tile(jnp.arange(b, dtype=jnp.int32) * f, n_trees)
    for _ in range(depth):
        tf = g(thrfeat, idx)
        fi = jnp.imag(tf).astype(jnp.int32)
        xv = g(xnf, fi + cols)
        go_right = (xv > jnp.real(tf)).astype(jnp.int32)
        idx = g(child, (idx * 2) + go_right)
    return g(value, idx).reshape(n_trees, b).mean(axis=0)


def _predict_flat_jnp_fn():
    """Build the jitted flat traversal lazily so importing the forest never
    forces a jax initialization."""
    import jax

    @partial(jax.jit, static_argnames=("depth", "n_trees", "n_nodes"))
    def run(thrfeat, child, value, xn, depth, n_trees, n_nodes):
        return flat_forest_eval(thrfeat, child, value, xn,
                                depth, n_trees, n_nodes)

    return run


_JITTED_FLAT = None


class RegressionForest:
    def __init__(self, n_trees: int = 24, max_depth: int = 9,
                 min_leaf: int = 3, seed: int = 0, backend: str = "auto"):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.backend = backend
        check_forest_backend(backend)  # fail fast, but don't touch jax
        self.rng = np.random.default_rng(seed)
        self.trees: list[_Tree] = []
        self._xm = self._xs = None
        self._flat = None        # packed (T, M) numpy tensors
        self._flat_jnp = None    # f32 device copies, built on first jnp call
        self._flat_pallas = None  # kernel-layout copies, first pallas call

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RegressionForest":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        self._xm = x.mean(0)
        self._xs = x.std(0) + 1e-9
        xn = (x - self._xm) / self._xs
        n = x.shape[0]
        n_feat_try = max(1, int(np.ceil(np.sqrt(x.shape[1]))) + 1)
        self.trees = []
        for _ in range(self.n_trees):
            idx = self.rng.integers(0, n, size=n)
            self.trees.append(
                _build(xn[idx], y[idx], self.rng, 0, self.max_depth,
                       self.min_leaf, n_feat_try)
            )
        self._pack()
        return self

    # ------------------------------------------------------------ flattening
    def _pack(self):
        flats = [_flatten_tree(t) for t in self.trees]
        t = len(flats)
        m = max(f[0].shape[0] for f in flats)
        feature = np.full((t, m), -1, np.int32)
        threshold = np.zeros((t, m), np.float64)
        left = np.tile(np.arange(m, dtype=np.int32), (t, 1))
        right = left.copy()
        value = np.zeros((t, m), np.float64)
        depth = 0
        for i, (fe, th, le, ri, va, de) in enumerate(flats):
            k = fe.shape[0]
            feature[i, :k] = fe
            threshold[i, :k] = th
            left[i, :k] = le
            right[i, :k] = ri
            value[i, :k] = va
            depth = max(depth, de)
        # Flat-absolute children (child[2i] = left, child[2i+1] = right) let
        # the traversal do one gather per step; leaves self-loop, so samples
        # that arrive early just spin in place — no leaf masking needed, and
        # leaf features are clamped to 0 so the x-gather stays in bounds.
        offs = (np.arange(t, dtype=np.int64) * m)[:, None]
        child = np.empty((t, m, 2), np.int64)
        child[:, :, 0] = left + offs
        child[:, :, 1] = right + offs
        self._flat = {
            "feature": feature, "threshold": threshold,
            "left": left, "right": right, "value": value,
            "child_flat": child.reshape(-1),
            "feat_safe_flat": np.maximum(feature, 0).astype(np.int64).reshape(-1),
            "threshold_flat": threshold.reshape(-1),
            "value_flat": value.reshape(-1),
            "depth": depth, "n_nodes": m,
        }
        self._flat_jnp = None
        self._flat_pallas = None

    # -------------------------------------------------------------- predict
    def _normalize(self, x: np.ndarray) -> np.ndarray:
        x = np.atleast_2d(np.asarray(x, np.float64))
        return (x - self._xm) / self._xs

    def predict(self, x: np.ndarray, backend: str | None = None,
                interpret: bool = False) -> np.ndarray:
        """(B,) forest mean via the flat vectorized traversal.

        ``interpret`` only affects the pallas backend: it runs the blocked
        kernel through the Pallas interpreter so the TPU code path is
        exercised on CPU (tests, CI smoke)."""
        xn = self._normalize(x)
        b = resolve_forest_backend(backend if backend is not None else self.backend,
                                   batch=xn.shape[0], interpret=interpret)
        if b == "pallas":
            return self._predict_pallas(xn, interpret=interpret)
        if b == "jnp":
            return self._predict_jnp(xn)
        return self._predict_numpy(xn)

    def predict_reference(self, x: np.ndarray) -> np.ndarray:
        """Recursive per-tree traversal — the original implementation, kept
        as the golden oracle for the flat paths."""
        xn = self._normalize(x)
        return np.mean([_predict_tree(t, xn) for t in self.trees], axis=0)

    def _predict_numpy(self, xn: np.ndarray) -> np.ndarray:
        """Flat vectorized traversal: node pointers advanced ``depth`` times
        with 1-D ``np.take`` gathers. Bit-equal to the recursive reference
        (same f64 compares, same ``np.mean`` over the tree axis).

        Small batches (the meta-search neighborhood path) use one (T, B)
        pointer block — 4 gathers per level total; big batches iterate per
        tree so the gather working set stays cache-resident."""
        fl = self._flat
        t, m, depth = len(self.trees), fl["n_nodes"], fl["depth"]
        b = xn.shape[0]
        feat = fl["feat_safe_flat"]
        thr = fl["threshold_flat"]
        child = fl["child_flat"]
        xnf = np.ascontiguousarray(xn).ravel()
        cols = np.arange(b, dtype=np.int64) * xn.shape[1]
        if b <= 1024:
            idx = (np.arange(t, dtype=np.int64) * m)[:, None] + np.zeros(
                (1, b), np.int64)
            for _ in range(depth):
                fi = np.take(feat, idx)
                xv = np.take(xnf, fi + cols[None, :])
                go_right = np.take(thr, idx) < xv
                idx = np.take(child, (idx << 1) + go_right)
            return np.take(fl["value_flat"], idx).mean(axis=0)
        vals = np.empty((t, b))
        for ti in range(t):
            idx = np.full(b, ti * m, np.int64)
            for _ in range(depth):
                fi = np.take(feat, idx)
                xv = np.take(xnf, fi + cols)
                go_right = np.take(thr, idx) < xv
                idx = np.take(child, (idx << 1) + go_right)
            vals[ti] = np.take(fl["value_flat"], idx)
        return np.mean(vals, axis=0)

    def jnp_tensors(self):
        """Cached f32 device tensors of the flat forest, plus its static
        shape key: ``(thrfeat, child, value), (depth, n_trees, n_nodes)``.

        This is the packing `_predict_jnp` traverses; it is public so the
        fused meta-search (core.fused) can inline the same traversal inside
        its own jitted featurize→score pipeline without round-tripping
        features through the host."""
        import jax.numpy as jnp

        if self._flat_jnp is None:
            fl = self._flat
            thrfeat = (fl["threshold_flat"].astype(np.float32) +
                       1j * fl["feat_safe_flat"].astype(np.float32))
            self._flat_jnp = (
                jnp.asarray(thrfeat.astype(np.complex64)),
                jnp.asarray(fl["child_flat"], jnp.int32),
                jnp.asarray(fl["value_flat"], jnp.float32),
            )
        fl = self._flat
        return self._flat_jnp, (fl["depth"], len(self.trees), fl["n_nodes"])

    def _predict_jnp(self, xn: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        global _JITTED_FLAT
        if _JITTED_FLAT is None:
            _JITTED_FLAT = _predict_flat_jnp_fn()
        self.jnp_tensors()
        b = xn.shape[0]
        pad = 1 << max(0, (b - 1).bit_length())  # bound recompiles
        xp = np.zeros((pad, xn.shape[1]), np.float32)
        xp[:b] = xn
        fl = self._flat
        out = _JITTED_FLAT(*self._flat_jnp, jnp.asarray(xp),
                           depth=fl["depth"], n_trees=len(self.trees),
                           n_nodes=fl["n_nodes"])
        return np.asarray(out[:b], np.float64)

    def _predict_pallas(self, xn: np.ndarray, interpret: bool = False) -> np.ndarray:
        """Blocked Pallas traversal (kernels/forest): per-tree-local node
        tensors resident in VMEM, grid over batch blocks. Branch decisions
        match the jnp twin exactly (same f32 compares); both agree with the
        f64 numpy oracle up to f32 threshold rounding."""
        import jax.numpy as jnp

        from ..kernels import forest as _forest  # deferred: keeps core importable sans kernels

        if self._flat_pallas is None:
            fl = self._flat
            t, m = fl["feature"].shape
            child = np.empty((t, 2 * m), np.int32)
            child[:, 0::2] = fl["left"]
            child[:, 1::2] = fl["right"]
            self._flat_pallas = (
                jnp.asarray(fl["threshold"], jnp.float32),
                jnp.asarray(np.maximum(fl["feature"], 0), jnp.int32),
                jnp.asarray(child),
                jnp.asarray(fl["value"], jnp.float32),
            )
        # Pad the batch to a block multiple *outside* the jitted call so
        # the jit cache keys on the quantized shape — one compile per
        # forest shape, not one per raw neighborhood size (the same
        # retrace-bounding trick as _predict_jnp's power-of-two padding).
        b = xn.shape[0]
        bp = -(-b // _forest.BLOCK_B) * _forest.BLOCK_B
        xp = np.zeros((bp, xn.shape[1]), np.float32)
        xp[:b] = xn
        try:
            out = _forest.forest_predict(
                *self._flat_pallas, jnp.asarray(xp),
                depth=self._flat["depth"], interpret=interpret)[:b]
        except Exception as e:
            if interpret:
                raise
            # On-device escape hatch: if Mosaic rejects the kernel on real
            # hardware, disable it for the process and serve the jnp twin —
            # "auto" must never crash an optimizer run mid-search.
            global _PALLAS_DISABLED
            if not _PALLAS_DISABLED:
                warnings.warn(
                    "pallas forest kernel failed on this device "
                    f"({type(e).__name__}: {e}); disabling it and falling "
                    "back to 'jnp' for the rest of the process",
                    stacklevel=2)
                _PALLAS_DISABLED = True
            return self._predict_jnp(xn)
        return np.asarray(out, np.float64)
