"""Regression forest (bagged CART) — the paper's base learner for Eval.

sklearn is unavailable offline; this is a compact numpy implementation. The
paper notes any quick, sufficiently expressive regressor works (§5.2).
Trees use variance-reduction splits, bootstrap bagging, and per-split
feature subsampling.
"""

from __future__ import annotations

import numpy as np


class _Tree:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = 0.0


def _build(x, y, rng, depth, max_depth, min_leaf, n_feat_try):
    node = _Tree()
    node.value = float(y.mean())
    if depth >= max_depth or y.shape[0] < 2 * min_leaf or np.ptp(y) < 1e-12:
        return node
    n, f = x.shape
    best = (None, None, np.inf)
    for feat in rng.choice(f, size=min(n_feat_try, f), replace=False):
        xs = x[:, feat]
        order = np.argsort(xs, kind="stable")
        xs_s, y_s = xs[order], y[order]
        # candidate split points between distinct neighbor values
        csum = np.cumsum(y_s)
        csq = np.cumsum(y_s**2)
        tot, tot2 = csum[-1], csq[-1]
        idx = np.arange(min_leaf, n - min_leaf)
        if idx.size == 0:
            continue
        valid = xs_s[idx] < xs_s[idx + 1] - 1e-15
        idx = idx[valid]
        if idx.size == 0:
            continue
        nl = idx + 1.0
        nr = n - nl
        sse = (csq[idx] - csum[idx] ** 2 / nl) + (
            (tot2 - csq[idx]) - (tot - csum[idx]) ** 2 / nr
        )
        j = int(np.argmin(sse))
        if sse[j] < best[2]:
            thr = 0.5 * (xs_s[idx[j]] + xs_s[idx[j] + 1])
            best = (int(feat), float(thr), float(sse[j]))
    if best[0] is None:
        return node
    node.feature, node.threshold = best[0], best[1]
    mask = x[:, node.feature] <= node.threshold
    node.left = _build(x[mask], y[mask], rng, depth + 1, max_depth, min_leaf, n_feat_try)
    node.right = _build(x[~mask], y[~mask], rng, depth + 1, max_depth, min_leaf, n_feat_try)
    return node


def _predict_tree(node: _Tree, x: np.ndarray) -> np.ndarray:
    out = np.empty(x.shape[0])
    stack = [(node, np.arange(x.shape[0]))]
    while stack:
        nd, idx = stack.pop()
        if nd.left is None:
            out[idx] = nd.value
            continue
        mask = x[idx, nd.feature] <= nd.threshold
        stack.append((nd.left, idx[mask]))
        stack.append((nd.right, idx[~mask]))
    return out


class RegressionForest:
    def __init__(self, n_trees: int = 24, max_depth: int = 9,
                 min_leaf: int = 3, seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.rng = np.random.default_rng(seed)
        self.trees: list[_Tree] = []
        self._xm = self._xs = None

    def fit(self, x: np.ndarray, y: np.ndarray) -> "RegressionForest":
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        self._xm = x.mean(0)
        self._xs = x.std(0) + 1e-9
        xn = (x - self._xm) / self._xs
        n = x.shape[0]
        n_feat_try = max(1, int(np.ceil(np.sqrt(x.shape[1]))) + 1)
        self.trees = []
        for _ in range(self.n_trees):
            idx = self.rng.integers(0, n, size=n)
            self.trees.append(
                _build(xn[idx], y[idx], self.rng, 0, self.max_depth,
                       self.min_leaf, n_feat_try)
            )
        return self

    def predict(self, x: np.ndarray) -> np.ndarray:
        x = np.atleast_2d(np.asarray(x, np.float64))
        xn = (x - self._xm) / self._xs
        return np.mean([_predict_tree(t, xn) for t in self.trees], axis=0)
