"""Algorithm 2 — MOO-STAGE.

Iterates: Local search (Alg. 1, PHV-greedy) → merge into the global
non-dominated set → learn Eval : features(d) ↦ PHV(local_search(d)) from all
past trajectories (aggregated training set, DAgger-style) → Meta search
(greedy ascent on Eval from d_last) to choose the next restart; random
restart when the meta search cannot move (Alg. 2 lines 9-13).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .evaluate import Evaluator
from .features import design_features
from .forest import RegressionForest
from .local_search import (LocalResult, ParetoSet, SearchHistory, local_search)
from .pareto import PhvContext
from .problem import Design, SystemSpec, random_design, sample_neighbors


@dataclasses.dataclass
class StageResult:
    global_set: ParetoSet
    history: SearchHistory
    eval_errors: list[tuple[int, float]]   # (iteration, |Eval(d_start) - actual PHV|/PHV)
    n_local_searches: int
    converged: bool


def _meta_greedy(
    spec: SystemSpec,
    model: RegressionForest,
    d_from: Design,
    rng: np.random.Generator,
    *,
    n_swaps: int,
    n_link_moves: int,
    max_steps: int = 30,
) -> Design:
    """Greedy ascent on the learned Eval (Alg. 2 line 9). Uses only cheap
    structural features — no objective evaluations are spent here."""
    d_curr = d_from
    v_curr = float(model.predict(design_features(spec, d_curr)[None])[0])
    for _ in range(max_steps):
        cands = sample_neighbors(spec, d_curr, rng, n_swaps, n_link_moves)
        if not cands:
            break
        feats = np.stack([design_features(spec, c) for c in cands])
        vals = model.predict(feats)
        j = int(np.argmax(vals))
        if vals[j] <= v_curr + 1e-12:
            break
        d_curr, v_curr = cands[j], float(vals[j])
    return d_curr


def moo_stage(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    d0: Design,
    seed: int = 0,
    *,
    iters_max: int = 12,
    n_swaps: int = 24,
    n_link_moves: int = 24,
    max_local_steps: int = 10_000,
    forest_kwargs: dict | None = None,
    history: SearchHistory | None = None,
) -> StageResult:
    rng = np.random.default_rng(seed)
    history = history or SearchHistory(ev, ctx)
    s_global = ParetoSet.empty()
    x_train: list[np.ndarray] = []
    y_train: list[float] = []
    eval_errors: list[tuple[int, float]] = []
    model: RegressionForest | None = None
    d_start = d0
    converged = False

    for it in range(iters_max):
        predicted = (
            float(model.predict(design_features(spec, d_start)[None])[0])
            if model is not None
            else None
        )
        res: LocalResult = local_search(
            spec, ev, ctx, d_start, rng,
            n_swaps=n_swaps, n_link_moves=n_link_moves,
            max_steps=max_local_steps, history=history,
        )
        if predicted is not None and res.phv > 0:
            eval_errors.append((it, abs(predicted - res.phv) / res.phv))

        # Merge local set into global set (Alg. 2 lines 3-4).
        merged = s_global.merged_with(
            res.local.designs, res.local.objs, ctx.obj_idx
        )
        new_keys = merged.keys() - s_global.keys()
        local_keys = res.local.keys()
        s_global = merged
        if not (new_keys & local_keys):
            # Local search contributed nothing new — converged (lines 5-6).
            converged = True
            break

        # Aggregate training examples: every trajectory design is labeled
        # with the PHV its local search achieved (line 7).
        for d in res.traj:
            x_train.append(design_features(spec, d))
            y_train.append(res.phv)

        fk = forest_kwargs or {}
        model = RegressionForest(seed=seed + it, **fk).fit(
            np.stack(x_train), np.asarray(y_train)
        )

        d_restart = _meta_greedy(
            spec, model, res.d_last, rng,
            n_swaps=n_swaps, n_link_moves=n_link_moves,
        )
        if d_restart.key() == res.d_last.key():
            d_start = random_design(spec, rng)          # lines 10-11
        else:
            d_start = d_restart                          # line 13

    return StageResult(
        global_set=s_global,
        history=history,
        eval_errors=eval_errors,
        n_local_searches=it + 1,
        converged=converged,
    )
