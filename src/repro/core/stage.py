"""Algorithm 2 — MOO-STAGE.

Iterates: Local search (Alg. 1, PHV-greedy) → merge into the global
non-dominated set → learn Eval : features(d) ↦ PHV(local_search(d)) from all
past trajectories (aggregated training set, DAgger-style) → Meta search
(greedy ascent on Eval from d_last) to choose the next restart; random
restart when the meta search cannot move (Alg. 2 lines 9-13).

The whole loop is array-shaped: feature extraction is batched
(:func:`repro.core.features.design_features_batch`), the surrogate scores a
whole sampled neighborhood per meta step in ONE flat-forest ``predict``
call, and :func:`stage_batch` runs K restart chains in lockstep so every
candidate evaluation in the expensive phase goes through the evaluator's
batched APSP/objective path in shared, padded XLA dispatches."""

from __future__ import annotations

import dataclasses

import numpy as np

from .evaluate import Evaluator
from .features import design_features_batch
from .forest import RegressionForest
from .fused import MetaScorer, check_meta_backend
from .local_search import (LocalResult, ParetoSet, SearchHistory,
                           local_search, local_search_batch)
from .pareto import PhvContext
from .problem import (Design, SystemSpec, random_design,
                      sample_neighbor_moves, sample_neighbors)


def _merge_forest_kwargs(forest_kwargs: dict | None,
                         forest_backend: str | None) -> dict:
    """Surrogate construction kwargs with the backend knob folded in; an
    explicit ``backend`` inside ``forest_kwargs`` wins over the knob."""
    fk = dict(forest_kwargs or {})
    if forest_backend is not None:
        fk.setdefault("backend", forest_backend)
    return fk


@dataclasses.dataclass
class StageResult:
    global_set: ParetoSet
    history: SearchHistory
    eval_errors: list[tuple[int, float]]   # (iteration, |Eval(d_start) - actual PHV|/PHV)
    n_local_searches: int
    converged: bool


@dataclasses.dataclass
class StageBatchResult:
    """Multi-start MOO-STAGE outcome: one global Pareto set merged across
    all K chains plus the usual diagnostics.

    ``x_train``/``y_train`` are the surrogate training rows collected by
    THIS call only (``train_init`` rows are not echoed back), and
    ``next_starts`` are the designs the driver would have restarted from
    next — together they are the checkpoint a distributed coordinator
    pools between sync rounds (repro.dist.sync)."""

    global_set: ParetoSet
    history: SearchHistory
    eval_errors: list[tuple[int, float]]
    n_local_searches: int
    n_starts: int
    n_evals: int
    converged: bool
    x_train: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 0)))
    y_train: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,)))
    next_starts: list[Design] = dataclasses.field(default_factory=list)


def _meta_greedy_host(
    spec: SystemSpec,
    model: RegressionForest,
    d_from: Design,
    rng: np.random.Generator,
    *,
    n_swaps: int,
    n_link_moves: int,
    max_steps: int = 30,
) -> Design:
    """The legacy host-side meta step: materialize every candidate as a
    ``Design``, featurize the batch on the host, then one flat-forest
    ``predict``. Kept as the ``meta_backend="host"`` arm and the parity
    oracle for the fused path."""
    d_curr = d_from
    v_curr = float(model.predict(design_features_batch(spec, [d_curr]))[0])
    for _ in range(max_steps):
        cands = sample_neighbors(spec, d_curr, rng, n_swaps, n_link_moves)
        if not cands:
            break
        vals = model.predict(design_features_batch(spec, cands))
        j = int(np.argmax(vals))
        if vals[j] <= v_curr + 1e-12:
            break
        d_curr, v_curr = cands[j], float(vals[j])
    return d_curr


def _meta_greedy(
    spec: SystemSpec,
    model: RegressionForest,
    d_from: Design,
    rng: np.random.Generator,
    *,
    n_swaps: int,
    n_link_moves: int,
    max_steps: int = 30,
    backend: str = "fused",
    scorer: MetaScorer | None = None,
) -> Design:
    """Greedy ascent on the learned Eval (Alg. 2 line 9). Uses only cheap
    structural features — no objective evaluations are spent here.

    ``backend="fused"`` (default) runs each step as ONE device dispatch:
    the neighborhood stays in move form (problem.NeighborMoves) and
    move-apply → featurize → normalize → forest traversal happen inside a
    single jit (core.fused); only the winning move is materialized.
    ``"fused-pallas"`` additionally routes the scoring tail through the
    kernels/stage_fused Pallas kernel (TPU); ``"host"`` is the legacy
    host-featurizing loop. All arms consume the identical rng stream and
    accept with the same strict ``vals[j] > v_curr + 1e-12`` test, so the
    accepted-move sequences agree across backends up to f32-vs-f64 forest
    threshold rounding (pinned by tests/test_fused.py).

    ``scorer`` reuses an already-built :class:`~repro.core.fused.MetaScorer`
    for this model (the multi-chain driver scores every chain's restart
    against one fitted forest)."""
    check_meta_backend(backend)
    if backend == "host":
        return _meta_greedy_host(
            spec, model, d_from, rng, n_swaps=n_swaps,
            n_link_moves=n_link_moves, max_steps=max_steps)
    sc = scorer if scorer is not None else MetaScorer(
        spec, model, backend=backend)
    d_curr = d_from
    v_curr = sc.score_base(d_curr)
    for _ in range(max_steps):
        moves = sample_neighbor_moves(spec, d_curr, rng, n_swaps,
                                      n_link_moves)
        if not len(moves):
            break
        j, vj = sc.score_moves(moves)
        if vj <= v_curr + 1e-12:
            break
        d_curr, v_curr = moves.materialize(j), vj
    return d_curr


def moo_stage(
    spec: SystemSpec,
    ev: Evaluator,
    ctx: PhvContext,
    d0: Design,
    seed: int = 0,
    *,
    iters_max: int = 12,
    n_swaps: int = 24,
    n_link_moves: int = 24,
    max_local_steps: int = 10_000,
    forest_kwargs: dict | None = None,
    forest_backend: str | None = None,
    meta_backend: str = "fused",
    history: SearchHistory | None = None,
    max_evals: int | None = None,
) -> StageResult:
    """Single-start MOO-STAGE. ``max_evals`` bounds the total objective
    evaluations (absolute w.r.t. ``ev.n_evals``, same accounting as
    :func:`stage_batch`); ``None`` keeps the legacy unbudgeted behavior.
    ``forest_backend`` selects the surrogate inference backend
    (core.forest.FOREST_BACKENDS; ``None`` keeps the forest's ``"auto"``);
    ``meta_backend`` selects the meta-search scoring path
    (core.fused.META_BACKENDS — see :func:`_meta_greedy`)."""
    check_meta_backend(meta_backend)
    rng = np.random.default_rng(seed)
    history = history or SearchHistory(ev, ctx)
    s_global = ParetoSet.empty()
    x_train: list[np.ndarray] = []
    y_train: list[float] = []
    eval_errors: list[tuple[int, float]] = []
    model: RegressionForest | None = None
    d_start = d0
    converged = False
    n_local = 0

    for it in range(iters_max):
        if max_evals is not None and ev.n_evals >= max_evals:
            break
        predicted = (
            float(model.predict(design_features_batch(spec, [d_start]))[0])
            if model is not None
            else None
        )
        res: LocalResult = local_search(
            spec, ev, ctx, d_start, rng,
            n_swaps=n_swaps, n_link_moves=n_link_moves,
            max_steps=max_local_steps, history=history, max_evals=max_evals,
        )
        n_local += 1
        if predicted is not None and res.phv > 0:
            eval_errors.append((it, abs(predicted - res.phv) / res.phv))

        # Merge local set into global set (Alg. 2 lines 3-4).
        merged = s_global.merged_with(
            res.local.designs, res.local.objs, ctx.obj_idx
        )
        new_keys = merged.keys() - s_global.keys()
        local_keys = res.local.keys()
        s_global = merged
        if not (new_keys & local_keys):
            # Local search contributed nothing new — converged (lines 5-6).
            converged = True
            break

        # Aggregate training examples: every trajectory design is labeled
        # with the PHV its local search achieved (line 7).
        x_train.extend(design_features_batch(spec, res.traj))
        y_train.extend([res.phv] * len(res.traj))

        fk = _merge_forest_kwargs(forest_kwargs, forest_backend)
        model = RegressionForest(seed=seed + it, **fk).fit(
            np.stack(x_train), np.asarray(y_train)
        )

        d_restart = _meta_greedy(
            spec, model, res.d_last, rng,
            n_swaps=n_swaps, n_link_moves=n_link_moves,
            backend=meta_backend,
        )
        if d_restart.key() == res.d_last.key():
            d_start = random_design(spec, rng)          # lines 10-11
        else:
            d_start = d_restart                          # line 13

    return StageResult(
        global_set=s_global,
        history=history,
        eval_errors=eval_errors,
        n_local_searches=n_local,
        converged=converged,
    )


def stage_batch(
    spec: SystemSpec,
    f: np.ndarray,
    n_starts: int = 4,
    seed: int = 0,
    *,
    case: str = "case3",
    backend: str = "auto",
    delta: str = "auto",
    iters_max: int = 12,
    n_swaps: int = 24,
    n_link_moves: int = 24,
    max_local_steps: int = 10_000,
    forest_kwargs: dict | None = None,
    forest_backend: str | None = None,
    meta_backend: str = "fused",
    max_evals: int | None = None,
    ev: Evaluator | None = None,
    ctx: PhvContext | None = None,
    history: SearchHistory | None = None,
    d0: Design | None = None,
    starts: list[Design] | None = None,
    train_init: tuple[np.ndarray, np.ndarray] | None = None,
    global_init: ParetoSet | None = None,
    checkpoint_restarts: bool = False,
) -> StageBatchResult:
    """Multi-start MOO-STAGE: K restart chains advanced in lockstep.

    All chains share one evaluator (their per-step neighborhoods are
    concatenated into single batched APSP + objective dispatches via
    :func:`local_search_batch`), one global non-dominated set, and one
    aggregated Eval training set — every chain's trajectories teach the one
    surrogate, which then steers every chain's next restart (cross-chain
    DAgger). Chain 0 starts from ``d0`` (default: the 3D mesh, §6.3); chain
    i starts from the mesh perturbed by 2·i random neighbor moves — diverse
    basins without wasting budget on uniformly random (far-from-mesh)
    starting designs.

    ``max_evals`` bounds the total objective-evaluation budget across all
    chains (checked per lockstep step), making equal-budget comparisons
    against the single-start driver direct. ``delta`` is Evaluator's
    incremental-move-evaluation mode (``"auto"`` enables host table deltas
    at DELTA_AUTO_MIN_TILES+ tiles, e.g. spec_large; the paper specs keep
    the dense jitted path). ``forest_backend`` selects the
    shared surrogate's inference backend (core.forest.FOREST_BACKENDS;
    ``None`` keeps the forest's ``"auto"``).

    ``starts`` overrides the mesh-perturbation start construction with
    explicit per-chain designs (len must equal ``n_starts``);
    ``train_init`` is an ``(X, y)`` pair of surrogate training rows fitted
    into a model *before* the first iteration; ``global_init`` seeds the
    global non-dominated set (its designs cost no evaluations — their
    objective rows ride along), so chains greedily maximize *marginal*
    PHV over what other workers already found. Together they let a
    round-based coordinator (repro.dist.sync) resume K chains with a
    pooled cross-worker surrogate and front. ``checkpoint_restarts``
    additionally refits the surrogate on convergence (an eval-free meta
    search) so ``next_starts`` holds genuine restart designs instead of
    the already-locally-optimal ``d_last``s. All default to
    None/False, leaving the single-call behavior (and its
    seeded-determinism pin) unchanged.
    """
    from .objectives import CASES

    if n_starts < 1:
        raise ValueError(f"n_starts must be >= 1, got {n_starts}")
    check_meta_backend(meta_backend)
    rng = np.random.default_rng(seed)
    if ev is None:
        ev = Evaluator(spec, f, backend=backend, delta=delta)
    if ctx is None:
        ctx = PhvContext(ev(spec.mesh_design()), CASES[case])
    history = history or SearchHistory(ev, ctx)

    if starts is None:
        base = d0 or spec.mesh_design()
        starts = [base]
        for i in range(1, n_starts):
            d = base
            for _ in range(2 * i):  # chain i: 2·i random moves away from base
                nb = sample_neighbors(spec, d, rng, 1, 1)
                if nb:
                    d = nb[int(rng.integers(len(nb)))]
            starts.append(d)
    else:
        if len(starts) != n_starts:
            raise ValueError(
                f"explicit starts must have n_starts={n_starts} designs, "
                f"got {len(starts)}")
        starts = list(starts)

    s_global = global_init if global_init is not None else ParetoSet.empty()
    x_train: list[np.ndarray] = []
    y_train: list[float] = []
    eval_errors: list[tuple[int, float]] = []
    fk = _merge_forest_kwargs(forest_kwargs, forest_backend)
    x_init = y_init = None
    model: RegressionForest | None = None
    if train_init is not None:
        x_init = np.asarray(train_init[0], dtype=np.float64)
        y_init = np.asarray(train_init[1], dtype=np.float64)
        if x_init.shape[0] != y_init.shape[0]:
            raise ValueError("train_init X and y row counts differ")
        if x_init.shape[0]:
            # Warm surrogate: seeded past the per-iteration range (it <
            # iters_max) so the entry fit never collides with a refit seed.
            model = RegressionForest(seed=seed + iters_max, **fk).fit(
                x_init, y_init)
    converged = False
    n_local = 0
    next_starts = list(starts)

    for it in range(iters_max):
        if max_evals is not None and ev.n_evals >= max_evals:
            break
        predicted = (
            model.predict(design_features_batch(spec, starts))
            if model is not None
            else None
        )
        results = local_search_batch(
            spec, ev, ctx, starts, rng,
            n_swaps=n_swaps, n_link_moves=n_link_moves,
            max_steps=max_local_steps, history=history, max_evals=max_evals,
            seed_set=s_global if s_global.designs else None,
        )
        n_local += len(results)
        next_starts = [res.d_last for res in results]

        any_new = False
        for ci, res in enumerate(results):
            if predicted is not None and res.phv > 0:
                eval_errors.append((it, abs(float(predicted[ci]) - res.phv) / res.phv))
            merged = s_global.merged_with(
                res.local.designs, res.local.objs, ctx.obj_idx)
            if merged.keys() - s_global.keys():  # new keys can only be local
                any_new = True
            s_global = merged
            x_train.extend(design_features_batch(spec, res.traj))
            y_train.extend([res.phv] * len(res.traj))

        def _refit_and_restart():
            xs = np.stack(x_train)
            ys = np.asarray(y_train, dtype=np.float64)
            if x_init is not None and x_init.shape[0]:
                xs = np.vstack([x_init, xs])
                ys = np.concatenate([y_init, ys])
            m = RegressionForest(seed=seed + it, **fk).fit(xs, ys)
            # One scorer per refit, shared by every chain's meta search
            # (device-resident forest tensors transfer once, not K times).
            sc = (MetaScorer(spec, m, backend=meta_backend)
                  if meta_backend != "host" else None)
            new_starts = []
            for res in results:
                d_restart = _meta_greedy(
                    spec, m, res.d_last, rng,
                    n_swaps=n_swaps, n_link_moves=n_link_moves,
                    backend=meta_backend, scorer=sc,
                )
                if d_restart.key() == res.d_last.key():
                    new_starts.append(random_design(spec, rng))  # lines 10-11
                else:
                    new_starts.append(d_restart)                  # line 13
            return m, new_starts

        if not any_new:
            converged = True
            if checkpoint_restarts:
                # The meta search costs no objective evaluations — still
                # pick the restarts a continuing run would use, so a
                # resuming coordinator round (repro.dist.sync) doesn't
                # relaunch chains at their already-locally-optimal d_last
                # and instantly re-converge on budget it could have spent
                # exploring. Opt-in: callers that never read next_starts
                # (the registry driver, the benchmarks) skip the refit.
                _, next_starts = _refit_and_restart()
            break
        if max_evals is not None and ev.n_evals >= max_evals:
            break

        model, starts = _refit_and_restart()
        next_starts = list(starts)

    return StageBatchResult(
        global_set=s_global,
        history=history,
        eval_errors=eval_errors,
        n_local_searches=n_local,
        n_starts=n_starts,
        n_evals=ev.n_evals,
        converged=converged,
        x_train=(np.stack(x_train) if x_train else np.zeros((0, 0))),
        y_train=np.asarray(y_train, dtype=np.float64),
        next_starts=next_starts,
    )
