"""The paper's primary contribution: MOO-STAGE and the 3D heterogeneous NoC
design problem (objectives Eqs. 1-10, Algorithms 1-2), plus the AMOSA /
PCBB / NSGA-II baselines, the traffic study (§3) and the application-
agnostic design experiments (§6.4-6.5).

The same optimizer is re-targeted at pod-scale problems in repro.dist
(device layout on the ICI torus, sharding-policy auto-search)."""

from .evaluate import Evaluator
from .features import design_features, design_features_batch
from .forest import RegressionForest
from .local_search import (ParetoSet, SearchHistory, local_search,
                           local_search_batch)
from .objectives import CASES, N_OBJ, OBJ_NAMES
from .pareto import (ParetoArchive, PhvContext, dominates, hypervolume,
                     pareto_filter, pareto_mask)
from .problem import (CPU, GPU, LLC, Design, SystemSpec, random_design,
                      sample_neighbors, spec_16, spec_36, spec_64, spec_1024,
                      spec_large, spec_tiny)
from .stage import StageBatchResult, StageResult, moo_stage, stage_batch
from .traffic import APP_NAMES, APPLICATIONS, avg_traffic, traffic_matrix

__all__ = [
    "APP_NAMES", "APPLICATIONS", "CASES", "CPU", "Design", "Evaluator", "GPU",
    "LLC", "N_OBJ", "OBJ_NAMES", "ParetoArchive", "ParetoSet", "PhvContext",
    "RegressionForest", "SearchHistory", "StageBatchResult", "StageResult",
    "SystemSpec", "avg_traffic", "design_features", "design_features_batch",
    "dominates", "hypervolume", "local_search", "local_search_batch",
    "moo_stage", "pareto_filter", "pareto_mask", "random_design",
    "sample_neighbors", "spec_16", "spec_36", "spec_64", "spec_1024",
    "spec_large", "spec_tiny", "stage_batch", "traffic_matrix",
]
