"""Flit-level network simulator — the Garnet stand-in (DESIGN.md §5).

Plays the role the paper assigns to cycle-accurate simulation (§4.2.2,
§6.1): an *independent* measurement of network throughput/latency used to
(a) validate the Ū/σ link-utilization throughput proxy (Fig. 4) and
(b) provide the "detailed simulation" latency in network-EDP numbers.

Model: single-flit packets; each directed link forwards 1 flit/cycle;
per-link FIFO queues; deterministic next-hop routing from core/routing
(the same tables the analytical objectives use); Bernoulli/Poisson
injection proportional to the application traffic matrix. Wormhole/VC
effects are abstracted away — saturation behaviour and relative ordering of
designs are what matter here, not absolute cycle counts."""

from __future__ import annotations

from collections import deque

import jax.numpy as jnp
import numpy as np

from . import routing
from .objectives import make_consts
from .problem import Design, SystemSpec


def _next_hops(spec: SystemSpec, d: Design) -> np.ndarray:
    c = make_consts(spec)
    full_adj = jnp.asarray(d.adj) | c.vadj
    n = spec.n_tiles
    cost = jnp.where(full_adj, c.router_stages + c.link_delay, routing.INF)
    cost = jnp.where(jnp.eye(n, dtype=bool), 0.0, cost)
    dist, nh = routing.routing_tables(cost, c.apsp_iters)
    return np.asarray(nh)


def simulate(
    spec: SystemSpec,
    d: Design,
    f: np.ndarray,
    *,
    perm_traffic: bool = True,
    inj_scale: float = 1.0,
    cycles: int = 3000,
    warmup: int = 500,
    seed: int = 0,
) -> dict:
    """Run the flit simulator; returns throughput (delivered flits/cycle),
    offered load, mean packet latency, and p99 latency."""
    rng = np.random.default_rng(seed)
    n = spec.n_tiles
    nh = _next_hops(spec, d)
    fs = f[d.perm][:, d.perm] if perm_traffic else f
    fs = fs * (1.0 - np.eye(n))
    rate = fs * inj_scale
    total_rate = rate.sum()

    # Pre-draw all injections: flit -> (cycle, src, dst).
    m = rng.poisson(total_rate * cycles)
    pairs_flat = rng.choice(n * n, size=m, p=(rate / total_rate).ravel())
    inj_cycle = rng.integers(0, cycles, size=m)
    order = np.argsort(inj_cycle, kind="stable")
    pairs_flat, inj_cycle = pairs_flat[order], inj_cycle[order]
    src_all, dst_all = np.divmod(pairs_flat, n)

    queues: dict[tuple[int, int], deque] = {}
    full_adj = d.adj | spec.vertical_adj
    for a in range(n):
        for b in range(n):
            if full_adj[a, b]:
                queues[(a, b)] = deque()
    edges = list(queues.keys())

    delivered = 0
    lat_sum = 0.0
    lats: list[int] = []
    ptr = 0
    for t in range(cycles):
        # 1 flit per link per cycle; each traversal also pays the router
        # pipeline (spec.router_stages, tracked per-flit via hop count).
        moved = []
        for (a, b) in edges:
            q = queues[(a, b)]
            if q:
                moved.append((b, q.popleft()))
        for b, (t0, dst, hops) in moved:
            if b == dst:
                if t >= warmup:
                    lat = (t - t0) + (hops + 1) * spec.router_stages
                    delivered += 1
                    lat_sum += lat
                    lats.append(lat)
            else:
                queues[(b, nh[b, dst])].append((t0, dst, hops + 1))

        while ptr < m and inj_cycle[ptr] == t:
            s, dd = int(src_all[ptr]), int(dst_all[ptr])
            queues[(s, nh[s, dd])].append((t, dd, 0))
            ptr += 1

    eff_cycles = cycles - warmup
    return dict(
        throughput=delivered / eff_cycles,
        offered=total_rate,
        mean_latency=(lat_sum / delivered) if delivered else np.inf,
        p99_latency=float(np.percentile(lats, 99)) if lats else np.inf,
        delivered=delivered,
    )


def saturation_throughput(
    spec: SystemSpec, d: Design, f: np.ndarray, *, seed: int = 0,
    scales=(4.0, 8.0, 16.0, 32.0), cycles: int = 2000,
) -> float:
    """Accepted throughput under heavy offered load (network saturation) —
    the quantity Fig. 4 plots against Ū and σ."""
    best = 0.0
    for s in scales:
        r = simulate(spec, d, f, inj_scale=s / max(f.sum(), 1e-9),
                     cycles=cycles, warmup=cycles // 4, seed=seed)
        best = max(best, r["throughput"])
    return best


def simulated_edp(spec: SystemSpec, d: Design, f: np.ndarray,
                  energy: float, *, seed: int = 0, cycles: int = 3000) -> float:
    """Network EDP with SIMULATED latency (paper §6.1's metric): mean packet
    latency at the application's native injection rate x network energy."""
    r = simulate(spec, d, f, cycles=cycles, seed=seed)
    return r["mean_latency"] * energy
