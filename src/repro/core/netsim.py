"""Flit-level network simulator — the Garnet stand-in (DESIGN.md §5).

Plays the role the paper assigns to cycle-accurate simulation (§4.2.2,
§6.1): an *independent* measurement of network throughput/latency used to
(a) validate the Ū/σ link-utilization throughput proxy (Fig. 4) and
(b) provide the "detailed simulation" latency in network-EDP numbers.

Model: single-flit packets; each directed link forwards 1 flit/cycle;
per-link FIFO queues; deterministic next-hop routing from core/routing
(the same tables the analytical objectives use); Bernoulli/Poisson
injection proportional to the application traffic matrix. Wormhole/VC
effects are abstracted away — saturation behaviour and relative ordering of
designs are what matter here, not absolute cycle counts.

Two engines implement the same cycle semantics:

  * :func:`simulate_batch` / :func:`simulate` — the production engine.
    Struct-of-arrays: every directed link is an edge index into flat ring
    buffers (one packed int64 per flit), and each cycle advances ALL edges
    of ALL batched simulations with a handful of NumPy ops. A batch is the
    cross product designs × injection scales × seeds, so next-hop tables
    (cached per (spec, design) — see :func:`_next_hops`) and the cycle loop
    are amortized across the whole sweep.
  * :func:`simulate_reference` — the original per-cycle, per-edge Python
    dict/deque loop, kept as the executable specification. The golden
    equivalence tests (tests/test_netsim.py) pin the vectorized engine to
    it: same seed -> identical delivered counts and latency statistics.

Enqueue ordering matches the reference loop exactly: within one cycle,
forwarded flits enter their target queue in source-edge order (edges sorted
by (a, b)), followed by freshly injected flits in draw order.
"""

from __future__ import annotations

from collections import OrderedDict, deque

import numpy as np

from . import routing as _routing
from .routing import apsp_iters
from .problem import Design, SystemSpec

INF = 1.0e9

# --------------------------------------------------------------------------
# Next-hop tables (host-side NumPy, float32 to mirror the jnp oracle)
# --------------------------------------------------------------------------

# LRU cache of routing tables keyed by (spec, design identity). Saves the
# per-injection-scale (and per-seed) APSP rebuild that used to dominate
# ``saturation_throughput`` — the tables only depend on (spec, design).
# Bounded by accumulated BYTES, not entry count: each entry holds O(N²)
# arrays ((N, N) int64 edge_id alone is 128 MiB at 4096 tiles), so a
# count-only bound silently grows unbounded with N. The count bound stays
# as a backstop for tiny specs.
_NH_CACHE: "OrderedDict[tuple, dict]" = OrderedDict()
_NH_CACHE_MAX = 512
_NH_CACHE_MAX_BYTES = 256 << 20
_nh_cache_nbytes = 0


def clear_caches() -> None:
    """Drop cached routing tables (tests / memory pressure)."""
    global _nh_cache_nbytes
    _NH_CACHE.clear()
    _nh_cache_nbytes = 0


def _apsp_np(cost: np.ndarray, n_iters: int) -> np.ndarray:
    """Batched (D, N, N) APSP, float32 NumPy — delegates per design to
    routing.apsp_np (k-blocked min-plus squaring): bit-equal to the device
    oracle AND to the historical (D, N, N, N) broadcast here, without its
    N³ transient (memory-safe at 1024+ tiles)."""
    return np.stack([_routing.apsp_np(c, n_iters) for c in cost])


def _tables_np(cost: np.ndarray, n_iters: int):
    """(dist, next_hop) for a (D, N, N) stack of hop-cost matrices."""
    dist = _apsp_np(cost, n_iters)
    nh = np.stack([_routing.next_hop_np(c, dd)
                   for c, dd in zip(cost, dist)])
    return dist, nh


def _design_tables(spec: SystemSpec, d: Design) -> dict:
    """Cached routing/edge tables for the engine. Keyed on the link
    topology only — placement (perm) moves don't change the tables, so
    swap-move trajectories all hit one entry."""
    key = (spec, np.packbits(d.adj).tobytes())
    hit = _NH_CACHE.get(key)
    if hit is not None:
        _NH_CACHE.move_to_end(key)
        return hit
    n = spec.n_tiles
    full_adj = d.adj | spec.vertical_adj
    # Pure-NumPy mirror of objectives.make_consts' routing inputs: keeps the
    # host-side simulator free of JAX dispatch/compile latency.
    link_delay = spec.link_delay.astype(np.float32)
    cost = np.where(full_adj, np.float32(spec.router_stages) + link_delay,
                    np.float32(INF))
    np.fill_diagonal(cost, 0.0)
    dist, nh = _tables_np(cost[None], apsp_iters(n))
    nh = nh[0]
    # Directed edge list in (a, b) row-major order — the reference loop's
    # dict insertion order, which fixes intra-cycle enqueue ordering.
    ea, eb = np.nonzero(full_adj)
    edge_id = np.full((n, n), -1, dtype=np.int64)
    edge_id[ea, eb] = np.arange(ea.size)
    entry = dict(nh=nh, edge_b=eb.astype(np.int64), edge_id=edge_id,
                 n_edges=int(ea.size), reach=dist[0] < INF / 2)
    entry["nbytes"] = sum(v.nbytes for v in entry.values()
                          if isinstance(v, np.ndarray))
    global _nh_cache_nbytes
    _NH_CACHE[key] = entry
    _nh_cache_nbytes += entry["nbytes"]
    while len(_NH_CACHE) > 1 and (
            len(_NH_CACHE) > _NH_CACHE_MAX
            or _nh_cache_nbytes > _NH_CACHE_MAX_BYTES):
        _, old = _NH_CACHE.popitem(last=False)
        _nh_cache_nbytes -= old["nbytes"]
    return entry


def _next_hops(spec: SystemSpec, d: Design) -> np.ndarray:
    """(N, N) int32 next-hop table (cached per (spec, design))."""
    return _design_tables(spec, d)["nh"]


# --------------------------------------------------------------------------
# Injection draws (identical RNG sequence to the reference loop)
# --------------------------------------------------------------------------

def _draw_injections(n: int, rate: np.ndarray, cycles: int, seed: int):
    """Pre-draw flit injections: (cycle, src, dst), sorted by cycle.

    Zero offered traffic is valid (idle network) — the reference
    implementation used to divide by rate.sum() and crash."""
    total_rate = float(rate.sum())
    if total_rate <= 0.0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, 0.0
    rng = np.random.default_rng(seed)
    m = rng.poisson(total_rate * cycles)
    pairs_flat = rng.choice(n * n, size=m, p=(rate / total_rate).ravel())
    inj_cycle = rng.integers(0, cycles, size=m)
    order = np.argsort(inj_cycle, kind="stable")
    pairs_flat, inj_cycle = pairs_flat[order], inj_cycle[order]
    src, dst = np.divmod(pairs_flat, n)
    return (inj_cycle.astype(np.int64), src.astype(np.int64),
            dst.astype(np.int64), total_rate)


# --------------------------------------------------------------------------
# Vectorized engine
# --------------------------------------------------------------------------

# Flit record packed into one int64: (t0 << 32) | (dst << 16) | hops.
_DST_SHIFT = 16
_T0_SHIFT = 32
_HOP_MASK = (1 << _DST_SHIFT) - 1
_DST_MASK = (1 << (_T0_SHIFT - _DST_SHIFT)) - 1


def _grow(buf: np.ndarray, head: np.ndarray, cap: int, new_cap: int):
    """Double ring-buffer capacity: unroll each ring so head == 0."""
    ne = head.size
    idx = (head[:, None] + np.arange(cap)[None, :]) & (cap - 1)
    new = np.zeros((ne, new_cap), dtype=buf.dtype)
    new[:, :cap] = np.take_along_axis(buf.reshape(ne, cap), idx, axis=1)
    head[:] = 0
    return new.reshape(-1), new_cap


# Below this many flits in a cycle, scalar Python beats the fixed overhead
# of the vectorized pass (~40 NumPy dispatches); both paths execute the
# identical algorithm on the same ring buffers.
_SCALAR_MAX = 16


def _run_sims(sims: list[dict], n: int, router_stages: int,
              cycles: int, warmup: int) -> list[dict]:
    """Advance a batch of independent simulations cycle-by-cycle.

    Each ``sims[i]`` carries its design tables and pre-drawn injections;
    all per-link FIFO state lives in flat arrays indexed by the global edge
    id ``sim * E + local_edge``, so one pass of NumPy ops per cycle moves
    every flit of every simulation. Near-idle cycles take a scalar fast
    path over the same buffers."""
    n_sims = len(sims)
    e_max = max(s["tables"]["n_edges"] for s in sims)
    ne = n_sims * e_max

    # Per-global-edge constants.
    edge_b = np.zeros(ne, dtype=np.int64)       # head-node of the edge
    edge_tab = np.zeros(ne, dtype=np.int64)     # offset into nh/eid stacks
    edge_sim = np.zeros(ne, dtype=np.int64)
    edge_base = np.zeros(ne, dtype=np.int64)    # sim * e_max
    nh_stack = np.concatenate(
        [s["tables"]["nh"].ravel().astype(np.int64) for s in sims])
    eid_stack = np.concatenate(
        [s["tables"]["edge_id"].ravel() for s in sims])
    for i, s in enumerate(sims):
        t = s["tables"]
        lo = i * e_max
        edge_b[lo:lo + t["n_edges"]] = t["edge_b"]
        edge_tab[lo:lo + e_max] = i * n * n
        edge_sim[lo:lo + e_max] = i
        edge_base[lo:lo + e_max] = lo

    # Injections: per-sim streams merged, stably sorted by cycle (per-sim
    # draw order is preserved for equal cycles; cross-sim interleaving is
    # irrelevant — edge namespaces are disjoint).
    inj_c, inj_tgt, inj_val = [], [], []
    for i, s in enumerate(sims):
        ic, src, dst = s["inj_cycle"], s["inj_src"], s["inj_dst"]
        t = s["tables"]
        nxt = t["nh"][src, dst].astype(np.int64)
        inj_c.append(ic)
        inj_tgt.append(i * e_max + t["edge_id"][src, nxt])
        inj_val.append((ic << _T0_SHIFT) | (dst << _DST_SHIFT))
    inj_c = np.concatenate(inj_c) if inj_c else np.zeros(0, np.int64)
    order = np.argsort(inj_c, kind="stable")
    inj_c = inj_c[order]
    inj_tgt = np.concatenate(inj_tgt)[order]
    inj_val = np.concatenate(inj_val)[order]
    inj_off = np.searchsorted(inj_c, np.arange(cycles + 1))

    cap = 8
    buf = np.zeros(ne * cap, dtype=np.int64)
    head = np.zeros(ne, dtype=np.int64)
    cnt = np.zeros(ne, dtype=np.int64)

    rs = np.int64(router_stages)
    rs_i = int(router_stages)
    lat_chunks: list[np.ndarray] = []
    sim_chunks: list[np.ndarray] = []
    lat_scalar: list[int] = []
    sim_scalar: list[int] = []
    in_flight = 0
    empty = np.zeros(0, dtype=np.int64)

    for t in range(cycles):
        lo, hi = int(inj_off[t]), int(inj_off[t + 1])
        if in_flight == 0 and lo == hi:
            continue

        if in_flight + (hi - lo) <= _SCALAR_MAX:
            # ---- scalar fast path (few flits: Python beats dispatch) -----
            moved = []
            for e in np.flatnonzero(cnt).tolist():
                h = int(head[e])
                moved.append((e, int(buf[e * cap + h])))
                head[e] = (h + 1) & (cap - 1)
                cnt[e] -= 1
            in_flight -= len(moved)
            for e, val in moved:
                dst = (val >> _DST_SHIFT) & _DST_MASK
                bn = int(edge_b[e])
                if bn == dst:
                    if t >= warmup:
                        lat_scalar.append((t - (val >> _T0_SHIFT)) +
                                          ((val & _HOP_MASK) + 1) * rs_i)
                        sim_scalar.append(int(edge_sim[e]))
                    continue
                tab = int(edge_tab[e])
                nxt = int(nh_stack[tab + bn * n + dst])
                tgt = int(edge_base[e]) + int(eid_stack[tab + bn * n + nxt])
                c = int(cnt[tgt])
                while c >= cap:
                    buf, cap = _grow(buf, head, cap, cap * 2)
                buf[tgt * cap + ((int(head[tgt]) + c) & (cap - 1))] = val + 1
                cnt[tgt] = c + 1
                in_flight += 1
            for j in range(lo, hi):
                tgt = int(inj_tgt[j])
                c = int(cnt[tgt])
                while c >= cap:
                    buf, cap = _grow(buf, head, cap, cap * 2)
                buf[tgt * cap + ((int(head[tgt]) + c) & (cap - 1))] = \
                    int(inj_val[j])
                cnt[tgt] = c + 1
                in_flight += 1
            continue

        # -- pop the head flit of every non-empty link queue ---------------
        if in_flight:
            act = np.flatnonzero(cnt)
            h = head[act]
            val = buf[act * cap + h]
            head[act] = (h + 1) & (cap - 1)
            cnt[act] -= 1
            in_flight -= act.size
            dst = (val >> _DST_SHIFT) & _DST_MASK
            bn = edge_b[act]
            deliv = bn == dst
            if deliv.any():
                fwd = ~deliv
                if t >= warmup:
                    lat = ((t - (val >> _T0_SHIFT)) +
                           ((val & _HOP_MASK) + 1) * rs)[deliv]
                    lat_chunks.append(lat)
                    sim_chunks.append(edge_sim[act[deliv]])
                act, val, dst, bn = act[fwd], val[fwd], dst[fwd], bn[fwd]
            # -- forwarded flits: next queue via this sim's tables ---------
            if act.size:
                tab = edge_tab[act]
                nxt = nh_stack[tab + bn * n + dst]
                tgt = edge_base[act] + eid_stack[tab + bn * n + nxt]
                fval = val + 1  # hops live in the low bits
            else:
                tgt, fval = empty, empty
        else:
            tgt, fval = empty, empty

        # -- enqueue: forwarded (source-edge order) then injections --------
        if lo != hi:
            tgt = np.concatenate([tgt, inj_tgt[lo:hi]])
            fval = np.concatenate([fval, inj_val[lo:hi]])
        if tgt.size:
            order = np.argsort(tgt, kind="stable")
            ts = tgt[order]
            ar = np.arange(ts.size)
            newgrp = np.empty(ts.size, dtype=bool)
            newgrp[0] = True
            np.not_equal(ts[1:], ts[:-1], out=newgrp[1:])
            k = ar - np.maximum.accumulate(np.where(newgrp, ar, 0))
            c0 = cnt[ts]
            need = int((c0 + k).max()) + 1
            while need > cap:
                buf, cap = _grow(buf, head, cap, cap * 2)
            buf[ts * cap + ((head[ts] + c0 + k) & (cap - 1))] = fval[order]
            # Duplicate-index assignment is applied in index order, so the
            # last write per group (largest k) sets the final queue length.
            cnt[ts] = c0 + k + 1
            in_flight += ts.size

    # ------------------------------------------------------------- stats
    eff = cycles - warmup
    if lat_scalar:
        lat_chunks.append(np.asarray(lat_scalar, np.int64))
        sim_chunks.append(np.asarray(sim_scalar, np.int64))
    lat_all = (np.concatenate(lat_chunks) if lat_chunks
               else np.zeros(0, np.int64))
    sim_all = (np.concatenate(sim_chunks) if sim_chunks
               else np.zeros(0, np.int64))
    delivered = np.bincount(sim_all, minlength=n_sims)
    lat_sum = np.bincount(sim_all, weights=lat_all, minlength=n_sims)
    order = np.argsort(sim_all, kind="stable")
    bounds = np.searchsorted(sim_all[order], np.arange(n_sims + 1))
    out = []
    for i, s in enumerate(sims):
        dcount = int(delivered[i])
        lats = lat_all[order[bounds[i]:bounds[i + 1]]]
        out.append(dict(
            throughput=dcount / eff,
            offered=s["offered"],
            mean_latency=(lat_sum[i] / dcount) if dcount else np.inf,
            p99_latency=float(np.percentile(lats, 99)) if dcount else np.inf,
            delivered=dcount,
        ))
    return out


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------

def simulate_batch(
    spec: SystemSpec,
    designs: list[Design],
    f: np.ndarray,
    *,
    scales=(1.0,),
    seeds=(0,),
    perm_traffic: bool = True,
    cycles: int = 3000,
    warmup: int = 500,
) -> dict:
    """Simulate the cross product ``designs x scales x seeds`` in one batch.

    ``scales`` are injection-scale multipliers applied to ``f`` (the
    ``inj_scale`` of :func:`simulate`); ``seeds`` are RNG seeds. Next-hop
    tables are built (and cached) once per design, and every simulation
    advances in the same vectorized cycle loop.

    Returns a dict of arrays, each of shape (len(designs), len(scales),
    len(seeds)): ``throughput``, ``offered``, ``mean_latency``,
    ``p99_latency``, ``delivered``.
    """
    n = spec.n_tiles
    shape = (len(designs), len(scales), len(seeds))
    keys = ("throughput", "offered", "mean_latency", "p99_latency",
            "delivered")
    if 0 in shape:
        return {k: np.zeros(shape) for k in keys}
    sims = []
    for di, d in enumerate(designs):
        tables = _design_tables(spec, d)
        fs = f[d.perm][:, d.perm] if perm_traffic else f
        fs = fs * (1.0 - np.eye(n))
        # Fail loudly on unroutable traffic (the reference loop KeyErrors);
        # silently mis-indexing the ring buffers would corrupt other sims.
        if not tables["reach"][fs > 0].all():
            raise ValueError(
                f"designs[{di}] is disconnected for its offered traffic: "
                "some (src, dst) pairs with f > 0 have no route")
        for s in scales:
            rate = fs * s
            for seed in seeds:
                ic, src, dst, total = _draw_injections(n, rate, cycles, seed)
                sims.append(dict(tables=tables, inj_cycle=ic, inj_src=src,
                                 inj_dst=dst, offered=total))
    results = _run_sims(sims, n, spec.router_stages, cycles, warmup)
    return {k: np.asarray([r[k] for r in results]).reshape(shape)
            for k in keys}


def simulate(
    spec: SystemSpec,
    d: Design,
    f: np.ndarray,
    *,
    perm_traffic: bool = True,
    inj_scale: float = 1.0,
    cycles: int = 3000,
    warmup: int = 500,
    seed: int = 0,
) -> dict:
    """Run the flit simulator; returns throughput (delivered flits/cycle),
    offered load, mean packet latency, and p99 latency.

    Thin wrapper over :func:`simulate_batch` with a single (design, scale,
    seed) — semantics (and, per seed, results) identical to
    :func:`simulate_reference`."""
    r = simulate_batch(spec, [d], f, scales=(inj_scale,), seeds=(seed,),
                       perm_traffic=perm_traffic, cycles=cycles,
                       warmup=warmup)
    out = {k: v[0, 0, 0] for k, v in r.items()}
    out["delivered"] = int(out["delivered"])
    out["throughput"] = float(out["throughput"])
    out["offered"] = float(out["offered"])
    out["mean_latency"] = float(out["mean_latency"])
    return out


def simulate_reference(
    spec: SystemSpec,
    d: Design,
    f: np.ndarray,
    *,
    perm_traffic: bool = True,
    inj_scale: float = 1.0,
    cycles: int = 3000,
    warmup: int = 500,
    seed: int = 0,
) -> dict:
    """The original per-cycle, per-edge Python loop — kept as the executable
    specification the vectorized engine is tested against. Do not use in hot
    paths."""
    n = spec.n_tiles
    nh = _next_hops(spec, d)
    fs = f[d.perm][:, d.perm] if perm_traffic else f
    fs = fs * (1.0 - np.eye(n))
    rate = fs * inj_scale
    inj_cycle, src_all, dst_all, total_rate = _draw_injections(
        n, rate, cycles, seed)
    m = inj_cycle.size

    queues: dict[tuple[int, int], deque] = {}
    full_adj = d.adj | spec.vertical_adj
    for a in range(n):
        for b in range(n):
            if full_adj[a, b]:
                queues[(a, b)] = deque()
    edges = list(queues.keys())

    delivered = 0
    lat_sum = 0.0
    lats: list[int] = []
    ptr = 0
    for t in range(cycles):
        # 1 flit per link per cycle; each traversal also pays the router
        # pipeline (spec.router_stages, tracked per-flit via hop count).
        moved = []
        for (a, b) in edges:
            q = queues[(a, b)]
            if q:
                moved.append((b, q.popleft()))
        for b, (t0, dst, hops) in moved:
            if b == dst:
                if t >= warmup:
                    lat = (t - t0) + (hops + 1) * spec.router_stages
                    delivered += 1
                    lat_sum += lat
                    lats.append(lat)
            else:
                queues[(b, nh[b, dst])].append((t0, dst, hops + 1))

        while ptr < m and inj_cycle[ptr] == t:
            s, dd = int(src_all[ptr]), int(dst_all[ptr])
            queues[(s, nh[s, dd])].append((t, dd, 0))
            ptr += 1

    eff_cycles = cycles - warmup
    return dict(
        throughput=delivered / eff_cycles,
        offered=total_rate,
        mean_latency=(lat_sum / delivered) if delivered else np.inf,
        p99_latency=float(np.percentile(lats, 99)) if lats else np.inf,
        delivered=delivered,
    )


def saturation_throughput(
    spec: SystemSpec, d: Design, f: np.ndarray, *, seed: int = 0,
    scales=(4.0, 8.0, 16.0, 32.0), cycles: int = 2000,
) -> float:
    """Accepted throughput under heavy offered load (network saturation) —
    the quantity Fig. 4 plots against Ū and σ. One batched call sweeping
    all injection scales (next-hop tables are built once)."""
    return float(saturation_throughput_batch(
        spec, [d], f, seed=seed, scales=scales, cycles=cycles)[0])


def saturation_throughput_batch(
    spec: SystemSpec, designs: list[Design], f: np.ndarray, *, seed: int = 0,
    scales=(4.0, 8.0, 16.0, 32.0), cycles: int = 2000,
) -> np.ndarray:
    """(len(designs),) saturation throughput — the whole designs x scales
    sweep runs as one :func:`simulate_batch` call."""
    inj = [s / max(f.sum(), 1e-9) for s in scales]
    r = simulate_batch(spec, designs, f, scales=inj, seeds=(seed,),
                       cycles=cycles, warmup=cycles // 4)
    return r["throughput"][:, :, 0].max(axis=1)


def simulated_edp(spec: SystemSpec, d: Design, f: np.ndarray,
                  energy: float, *, seed: int = 0, cycles: int = 3000) -> float:
    """Network EDP with SIMULATED latency (paper §6.1's metric): mean packet
    latency at the application's native injection rate x network energy.
    Routing tables are cached per (spec, design) like every other entry
    point."""
    r = simulate(spec, d, f, cycles=cycles, seed=seed)
    return r["mean_latency"] * energy
