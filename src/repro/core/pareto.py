"""Pareto dominance + hypervolume (PHV) utilities.

PHV follows "Hypervolume by Slicing Objectives" (While et al. [36], cited by
the paper §5.1): recursively slice along one objective and aggregate
(m-1)-dimensional hypervolumes. All objectives are MINIMIZED; the
hypervolume is measured against an upper reference point ``ref`` and only
counts the region dominated by the set and bounded by ``ref``.

Two hot-path accelerations for the greedy PHV argmax (Alg. 1 line 3):

  * the HSO recursion bottoms out in a closed-form vectorized 2-D
    staircase (:func:`_hv2d`) instead of recursing to 1-D slabs, and
  * :func:`hypervolume_with_batch` scores PHV(S ∪ {d}) for a whole batch
    of candidates at once via exclusive contributions — one vectorized
    dominance test knocks out every candidate already covered by S, and
    survivors only pay an HSO over S clipped into the candidate's box.
"""

from __future__ import annotations

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a ≺ b (a dominates b) under minimization — paper §5.1."""
    return bool(np.all(a <= b) and np.any(a < b))


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows. Duplicate rows: first one kept."""
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    if n == 0:
        return np.zeros((0,), dtype=bool)
    le = np.all(pts[:, None, :] <= pts[None, :, :], axis=-1)
    lt = np.any(pts[:, None, :] < pts[None, :, :], axis=-1)
    dom = le & lt  # dom[i, j]: i dominates j
    mask = ~dom.any(axis=0)
    # Deduplicate exact ties (keep first). Keys canonicalize signed zeros:
    # -0.0 == 0.0 numerically (the rows co-dominate, neither knocks the
    # other out above), but their byte patterns differ — without `+ 0.0`
    # both would survive as "distinct" front points.
    if mask.sum() > 1:
        idx = np.flatnonzero(mask)
        seen: set[bytes] = set()
        for i in idx:
            k = (pts[i] + 0.0).tobytes()
            if k in seen:
                mask[i] = False
            else:
                seen.add(k)
    return mask


class ParetoArchive:
    """Incremental non-dominated archive (minimization, keep-first ties).

    :func:`pareto_mask` rebuilds an O(n²·k) dominance cube on every union;
    this archive maintains the front under *insertion*: each insert costs
    one vectorized O(front·k) pass, pruned further by a sorted view of the
    first objective (a dominator of ``p`` must satisfy ``q[0] <= p[0]``, a
    point dominated by ``p`` must satisfy ``q[0] >= p[0]``, so only the
    matching prefix/suffix of the sorted front is compared).

    Semantics match ``pareto_mask`` exactly: a candidate equal to a
    surviving member is rejected (keep-first dedup — signed zeros compare
    equal numerically, so the archive never had the ``-0.0`` byte-key bug),
    a dominated candidate is rejected, and an accepted candidate evicts the
    members it dominates. Surviving points are reported in **insertion
    order**, which is what makes :meth:`ParetoSet.merged_with
    <repro.core.local_search.ParetoSet>` built on top byte-identical to the
    historical stacked-``pareto_mask`` implementation.

    ``tag`` is an arbitrary caller id carried with each point (a row index,
    a design), returned by :meth:`insert` with the evicted members.
    """

    __slots__ = ("n_obj", "_pts", "_tags", "_k0s", "_sidx")

    def __init__(self, n_obj: int):
        self.n_obj = int(n_obj)
        self._pts = np.zeros((0, self.n_obj), dtype=np.float64)
        self._tags: list = []
        # Sorted view: _k0s is pts[:, 0] sorted ascending; _sidx[r] is the
        # row index (into _pts / _tags) at sorted position r.
        self._k0s = np.zeros((0,), dtype=np.float64)
        self._sidx = np.zeros((0,), dtype=np.int64)

    def __len__(self) -> int:
        return self._pts.shape[0]

    @property
    def points(self) -> np.ndarray:
        """(m, k) front rows, in insertion order."""
        return self._pts

    @property
    def tags(self) -> list:
        """Caller tags, aligned with :attr:`points`."""
        return self._tags

    @classmethod
    def from_front(cls, pts: np.ndarray, tags=None) -> "ParetoArchive":
        """Seed from rows that are already a mutually non-dominated,
        deduplicated front (e.g. a previous archive's output). The rows are
        trusted — no pairwise checks are run."""
        pts = np.atleast_2d(np.asarray(pts, dtype=np.float64)) + 0.0
        arch = cls(pts.shape[-1])
        if pts.size:
            arch._pts = pts.copy()
            arch._tags = (list(tags) if tags is not None
                          else list(range(pts.shape[0])))
            arch._sidx = np.argsort(pts[:, 0], kind="stable").astype(np.int64)
            arch._k0s = pts[arch._sidx, 0]
        return arch

    def insert(self, p: np.ndarray, tag=None) -> tuple[bool, list]:
        """Insert one point. Returns ``(accepted, evicted_tags)``:
        ``accepted`` is False when ``p`` is dominated by (or equal to) a
        member; ``evicted_tags`` lists the members ``p`` knocked out."""
        p = np.asarray(p, dtype=np.float64).reshape(self.n_obj) + 0.0
        m = self._pts.shape[0]
        evicted: list = []
        if m:
            # Prefix (k0 <= p0): the only rows that can dominate/equal p.
            hi = int(np.searchsorted(self._k0s, p[0], side="right"))
            if hi:
                pre = self._sidx[:hi]
                if bool(np.all(self._pts[pre] <= p, axis=1).any()):
                    return False, []
            # Suffix (k0 >= p0): the only rows p can dominate.
            lo = int(np.searchsorted(self._k0s, p[0], side="left"))
            suf = self._sidx[lo:]
            if suf.size:
                out = suf[np.all(p <= self._pts[suf], axis=1)]
                if out.size:
                    evicted = self._remove_rows(np.sort(out))
        row = self._pts.shape[0]
        self._pts = np.vstack([self._pts, p[None]])
        self._tags.append(tag)
        pos = int(np.searchsorted(self._k0s, p[0], side="right"))
        self._k0s = np.insert(self._k0s, pos, p[0])
        self._sidx = np.insert(self._sidx, pos, row)
        return True, evicted

    def _remove_rows(self, rows: np.ndarray) -> list:
        """Drop front rows (sorted ascending row indices) and remap the
        sorted view. O(front). Returns the evicted tags."""
        evicted = [self._tags[r] for r in rows]
        keep = np.ones(self._pts.shape[0], dtype=bool)
        keep[rows] = False
        remap = np.cumsum(keep) - 1       # old row -> new row (kept rows)
        self._pts = self._pts[keep]
        self._tags = [t for t, k in zip(self._tags, keep) if k]
        skeep = keep[self._sidx]
        self._sidx = remap[self._sidx[skeep]]
        self._k0s = self._k0s[skeep]
        return evicted

    def insert_many(self, pts: np.ndarray, tags=None) -> list:
        """Insert rows in order; returns the accepted tags (in insertion
        order — note later rows may still evict earlier ones)."""
        pts = np.atleast_2d(np.asarray(pts, dtype=np.float64))
        accepted = []
        for i, p in enumerate(pts):
            tag = tags[i] if tags is not None else i
            ok, _ = self.insert(p, tag)
            if ok:
                accepted.append(tag)
        return accepted


def pareto_filter(points: np.ndarray) -> np.ndarray:
    return np.asarray(points)[pareto_mask(points)]


def hypervolume(points: np.ndarray, ref: np.ndarray) -> float:
    """Hypervolume (minimization) of ``points`` w.r.t. upper bound ``ref``.

    Points at or beyond ``ref`` in any coordinate contribute only their
    clipped part. Implemented as recursive HSO with memo on the first axis.
    """
    pts = np.asarray(points, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    if pts.size == 0:
        return 0.0
    pts = np.minimum(pts, ref)  # clip (degenerate slices contribute 0 width)
    pts = pareto_filter(pts)
    return _hso(pts, ref)


def _hv2d(pts: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-D hypervolume: one sort + a vectorized staircase sweep.

    Handles dominated/duplicate points (zero-width or covered steps); the
    inputs must already be clipped to ``ref``."""
    order = np.argsort(pts[:, 0], kind="stable")
    x = pts[order, 0]
    ymin = np.minimum.accumulate(pts[order, 1])
    x_hi = np.empty_like(x)
    x_hi[:-1] = x[1:]
    x_hi[-1] = ref[0]
    return float(np.sum((x_hi - x) * (ref[1] - ymin)))


def _hso(pts: np.ndarray, ref: np.ndarray) -> float:
    m = ref.shape[0]
    if pts.shape[0] == 0:
        return 0.0
    if m == 1:
        return float(max(0.0, ref[0] - pts[:, 0].min()))
    if m == 2:
        return _hv2d(pts, ref)
    order = np.argsort(pts[:, 0], kind="stable")
    pts = pts[order]
    vol = 0.0
    n = pts.shape[0]
    for i in range(n):
        x_lo = pts[i, 0]
        x_hi = pts[i + 1, 0] if i + 1 < n else ref[0]
        width = x_hi - x_lo
        if width <= 0.0:
            continue
        slab = pts[: i + 1, 1:]
        if m > 3:  # 2-D slabs go straight to the staircase
            slab = pareto_filter(slab)
        vol += width * _hso(slab, ref[1:])
    return float(vol)


def hypervolume_with_batch(points: np.ndarray, cands: np.ndarray,
                           ref: np.ndarray) -> np.ndarray:
    """HV(points ∪ {c}) for every row ``c`` of ``cands`` — the batched form
    of the greedy argmax_d PHV(S ∪ {d}) scoring step (Alg. 1 line 3).

    Exact: HV(S ∪ {c}) = HV(S) + exclusive contribution of ``c``, where the
    exclusive contribution is Vol(box(c, ref)) minus the hypervolume of S
    clipped into that box. Candidates covered by S (some s <= c) are
    eliminated by one vectorized dominance test and cost nothing."""
    pts = np.asarray(points, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    cands = np.atleast_2d(np.asarray(cands, dtype=np.float64))
    c = np.minimum(cands, ref)
    box = np.prod(np.maximum(ref - c, 0.0), axis=1)
    if pts.size == 0:
        return box.copy()
    pts = pareto_filter(np.minimum(pts, ref))
    base = _hso(pts, ref)
    out = np.full(c.shape[0], base)
    covered = np.any(np.all(pts[None, :, :] <= c[:, None, :], axis=2), axis=1)
    for i in np.flatnonzero(~covered & (box > 0)):
        clipped = np.maximum(pts, c[i])
        vol_sub = _hso(clipped[pareto_mask(clipped)], ref)
        out[i] = base + (box[i] - vol_sub)
    return out


PHV_BACKENDS = ("host", "jnp")


class PhvContext:
    """Fixed normalization for PHV across one optimization run.

    Objectives are divided by the starting (3D-mesh) design's objective
    values, so every search for a given (spec, traffic, case) shares one
    scale; the reference point is ``ref_scale`` in those units (designs worse
    than ``ref_scale``x mesh contribute zero volume).

    ``phv_backend`` selects the batched scorer behind
    :meth:`phv_with_batch` (the chain-step hot path): ``"host"`` (default)
    is the exact f64 HSO here; ``"jnp"`` routes through the jitted f32
    device twin (core.phv_jnp) — one XLA dispatch per chain step instead of
    a per-survivor host recursion. The twin is OPT-IN because f32 cannot
    resolve the chain accept test's 1e-12 epsilon near convergence (its
    conformance bound is ~1e-5 relative); scalar entry points (``phv``,
    ``phv_with``) always stay host-exact."""

    def __init__(self, mesh_objs: np.ndarray, obj_idx: tuple[int, ...],
                 ref_scale: float = 1.6, phv_backend: str = "host"):
        if phv_backend not in PHV_BACKENDS:
            raise ValueError(
                f"phv_backend must be one of {PHV_BACKENDS}, "
                f"got {phv_backend!r}")
        self.obj_idx = tuple(obj_idx)
        self.phv_backend = phv_backend
        base = np.asarray(mesh_objs, dtype=np.float64)[list(obj_idx)]
        base = np.where(base <= 0, 1.0, base)
        self.base = base
        self.ref = np.full(len(obj_idx), ref_scale, dtype=np.float64)

    def normalize(self, objs: np.ndarray) -> np.ndarray:
        o = np.asarray(objs, dtype=np.float64)
        sel = o[..., list(self.obj_idx)]
        return sel / self.base

    def phv(self, objs: np.ndarray) -> float:
        """PHV of a set of (full 5-dim) objective rows under this context."""
        if objs.size == 0:
            return 0.0
        return hypervolume(self.normalize(np.atleast_2d(objs)), self.ref)

    def phv_with(self, set_objs: np.ndarray, extra: np.ndarray) -> float:
        """PHV(S ∪ {d}) — Alg. 1 line 3."""
        ext = np.atleast_2d(extra)
        if set_objs.size == 0:
            return self.phv(ext)
        return self.phv(np.vstack([np.atleast_2d(set_objs), ext]))

    def phv_with_batch(self, set_objs: np.ndarray,
                       extras: np.ndarray) -> np.ndarray:
        """(B,) array of PHV(S ∪ {d_b}) for a batch of candidate rows —
        one call scores a whole neighborhood (Alg. 1 line 3) instead of B
        recursive-HSO invocations."""
        ext = self.normalize(np.atleast_2d(extras))
        if set_objs.size == 0:
            setn = np.zeros((0, len(self.obj_idx)))
        else:
            setn = self.normalize(np.atleast_2d(set_objs))
        if self.phv_backend == "jnp" and len(self.obj_idx) <= 4:
            # m = 5 would vmap an O(S^3) masked recursion — past the twin's
            # win; no active case uses it, so it stays host-served.
            from .phv_jnp import hypervolume_with_batch_jnp

            return hypervolume_with_batch_jnp(setn, ext, self.ref)
        return hypervolume_with_batch(setn, ext, self.ref)
