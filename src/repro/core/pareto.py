"""Pareto dominance + hypervolume (PHV) utilities.

PHV follows "Hypervolume by Slicing Objectives" (While et al. [36], cited by
the paper §5.1): recursively slice along one objective and aggregate
(m-1)-dimensional hypervolumes. All objectives are MINIMIZED; the
hypervolume is measured against an upper reference point ``ref`` and only
counts the region dominated by the set and bounded by ``ref``.
"""

from __future__ import annotations

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a ≺ b (a dominates b) under minimization — paper §5.1."""
    return bool(np.all(a <= b) and np.any(a < b))


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows. Duplicate rows: first one kept."""
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    if n == 0:
        return np.zeros((0,), dtype=bool)
    le = np.all(pts[:, None, :] <= pts[None, :, :], axis=-1)
    lt = np.any(pts[:, None, :] < pts[None, :, :], axis=-1)
    dom = le & lt  # dom[i, j]: i dominates j
    mask = ~dom.any(axis=0)
    # Deduplicate exact ties (keep first).
    if mask.sum() > 1:
        idx = np.flatnonzero(mask)
        seen: set[bytes] = set()
        for i in idx:
            k = pts[i].tobytes()
            if k in seen:
                mask[i] = False
            else:
                seen.add(k)
    return mask


def pareto_filter(points: np.ndarray) -> np.ndarray:
    return np.asarray(points)[pareto_mask(points)]


def hypervolume(points: np.ndarray, ref: np.ndarray) -> float:
    """Hypervolume (minimization) of ``points`` w.r.t. upper bound ``ref``.

    Points at or beyond ``ref`` in any coordinate contribute only their
    clipped part. Implemented as recursive HSO with memo on the first axis.
    """
    pts = np.asarray(points, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    if pts.size == 0:
        return 0.0
    pts = np.minimum(pts, ref)  # clip (degenerate slices contribute 0 width)
    pts = pareto_filter(pts)
    return _hso(pts, ref)


def _hso(pts: np.ndarray, ref: np.ndarray) -> float:
    m = ref.shape[0]
    if pts.shape[0] == 0:
        return 0.0
    if m == 1:
        return float(max(0.0, ref[0] - pts[:, 0].min()))
    order = np.argsort(pts[:, 0], kind="stable")
    pts = pts[order]
    vol = 0.0
    n = pts.shape[0]
    for i in range(n):
        x_lo = pts[i, 0]
        x_hi = pts[i + 1, 0] if i + 1 < n else ref[0]
        width = x_hi - x_lo
        if width <= 0.0:
            continue
        slab = pareto_filter(pts[: i + 1, 1:])
        vol += width * _hso(slab, ref[1:])
    return float(vol)


class PhvContext:
    """Fixed normalization for PHV across one optimization run.

    Objectives are divided by the starting (3D-mesh) design's objective
    values, so every search for a given (spec, traffic, case) shares one
    scale; the reference point is ``ref_scale`` in those units (designs worse
    than ``ref_scale``x mesh contribute zero volume)."""

    def __init__(self, mesh_objs: np.ndarray, obj_idx: tuple[int, ...],
                 ref_scale: float = 1.6):
        self.obj_idx = tuple(obj_idx)
        base = np.asarray(mesh_objs, dtype=np.float64)[list(obj_idx)]
        base = np.where(base <= 0, 1.0, base)
        self.base = base
        self.ref = np.full(len(obj_idx), ref_scale, dtype=np.float64)

    def normalize(self, objs: np.ndarray) -> np.ndarray:
        o = np.asarray(objs, dtype=np.float64)
        sel = o[..., list(self.obj_idx)]
        return sel / self.base

    def phv(self, objs: np.ndarray) -> float:
        """PHV of a set of (full 5-dim) objective rows under this context."""
        if objs.size == 0:
            return 0.0
        return hypervolume(self.normalize(np.atleast_2d(objs)), self.ref)

    def phv_with(self, set_objs: np.ndarray, extra: np.ndarray) -> float:
        """PHV(S ∪ {d}) — Alg. 1 line 3."""
        ext = np.atleast_2d(extra)
        if set_objs.size == 0:
            return self.phv(ext)
        return self.phv(np.vstack([np.atleast_2d(set_objs), ext]))
