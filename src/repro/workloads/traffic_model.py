"""Model-derived NoC traffic: collective volumes -> (N, N) flit-rate matrices.

`traffic_from_model(cfg, mapping, phase)` turns one (architecture x
execution phase) scenario into the same kind of directed core-to-core
traffic matrix `core.traffic` synthesizes for the paper's Rodinia-class
apps — so LLM-era workloads flow through the evaluator, the optimizers,
the server, and the agnostic study unchanged.

Volume accounting (per phase, all in bytes before normalization):

  * tensor-parallel activation all-reduces ride a bidirectional ring over
    each data replica's model group (2(k-1)/k per ring all-reduce);
  * MoE dispatch+combine is an all-to-all over the model group — the
    GPU<->GPU block structure the paper's traffic never had;
  * FSDP weight all-gathers (training) ride a ring over each model rank's
    data group; grad-sync is an f32 ring all-reduce over the same group;
  * parameter/optimizer/KV-cache traffic goes GPU <-> its home LLC bank
    (reads are response-heavy, writes request-heavy, mirroring the 1:2
    request:response split of `core.traffic`);
  * serving decode reads the whole KV context from the home banks every
    step — the many-to-few LLC-read pattern; SSM/hybrid archs read a
    constant-size SSD state instead (no KV growth);
  * a master host CPU feeds inputs and drains metrics (the §3 "master
    core" analogue), with faint background control on the other CPUs.

The result is normalized to unit sum and scaled by a per-phase injection
intensity — exactly the `core/traffic.py` relative flits/cycle convention
— and is fully deterministic (no RNG anywhere).
"""

from __future__ import annotations

import numpy as np

from repro.configs.registry import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES
from repro.core.problem import SystemSpec
from repro.core.traffic import TrafficValidationError

from .mapping import Mapping, WorkloadMesh, derive_mesh, place_model

# ------------------------------------------------------------------ phases
#: every phase a scenario can name; training phases use the train_4k shape,
#: serving phases the 32k prefill/decode shapes (configs/shapes.py).
PHASES = ("train.fwd", "train.bwd", "train.grad_sync",
          "serve.prefill", "serve.decode")

PHASE_SHAPE = {
    "train.fwd": "train_4k",
    "train.bwd": "train_4k",
    "train.grad_sync": "train_4k",
    "serve.prefill": "prefill_32k",
    "serve.decode": "decode_32k",
}

#: relative injection intensity (flits/cycle scale), in the same 0.40-0.70
#: band as the paper apps so EDP magnitudes stay comparable. grad_sync and
#: decode are the burstiest phases (pure communication / memory-bound).
PHASE_INTENSITY = {
    "train.fwd": 0.50,
    "train.bwd": 0.58,
    "train.grad_sync": 0.66,
    "serve.prefill": 0.54,
    "serve.decode": 0.62,
}

BYTES_ACT = 2.0     # bf16 activations / streamed weights / KV entries
BYTES_GRAD = 4.0    # f32 gradient + optimizer payloads
BYTES_TOKEN = 4.0   # int32 token ids
SPILL_FRAC = 0.25   # fraction of per-block residuals spilled to the LLC
WEIGHT_STREAM = 0.25  # serving: fraction of the weight shard streamed/step

SCENARIO_SEP = ":"

#: every (model x phase) scenario addressable by string, "arch:phase".
PHASE_APP_NAMES = tuple(f"{a}{SCENARIO_SEP}{p}"
                        for a in ARCH_NAMES for p in PHASES)


def scenario_name(arch: str, phase: str) -> str:
    return f"{arch}{SCENARIO_SEP}{phase}"


def parse_scenario(name: str) -> tuple[str, str]:
    """Split "arch:phase" (arch names contain no ':')."""
    arch, sep, phase = name.partition(SCENARIO_SEP)
    if not sep:
        raise TrafficValidationError(
            f"scenario {name!r} is not of the form '<arch>:<phase>'")
    check_scenario(arch, phase)
    return arch, phase


def check_scenario(arch: str, phase: str) -> None:
    if arch not in ARCH_NAMES:
        raise TrafficValidationError(
            f"unknown model {arch!r}; known: {', '.join(ARCH_NAMES)}")
    if phase not in PHASES:
        raise TrafficValidationError(
            f"unknown phase {phase!r}; known: {', '.join(PHASES)}")


# ------------------------------------------------- per-arch volume helpers
def _tp_allreduces(cfg) -> int:
    """Activation all-reduces over the model group per forward pass."""
    if cfg.family == "moe":
        return cfg.n_layers                      # attn out; MLP is all-to-all
    if cfg.family == "ssm":
        return cfg.n_layers                      # out_proj only
    if cfg.family == "hybrid":
        sites = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        return cfg.n_layers + 2 * sites          # mamba blocks + shared attn
    if cfg.family == "encdec":
        return 2 * cfg.encoder_layers + 3 * cfg.n_layers   # self+cross+mlp
    return 2 * cfg.n_layers                      # dense/vlm: attn + mlp


def _attention_sites(cfg) -> int:
    """KV-cache-bearing attention layers (0 for pure SSM)."""
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
    if cfg.family == "encdec":
        return 2 * cfg.n_layers                  # self + cross caches
    return cfg.n_layers


def _n_blocks(cfg) -> int:
    return cfg.n_layers + cfg.encoder_layers


def _kv_bytes_per_token(cfg) -> float:
    """KV bytes appended per token across the whole model (pre-TP-shard)."""
    return 2.0 * _attention_sites(cfg) * cfg.n_kv_heads * \
        cfg.resolved_head_dim * BYTES_ACT


def _state_bytes(cfg) -> float:
    """Recurrent SSD state per sequence (SSM/hybrid; 0 otherwise)."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    return cfg.n_layers * cfg.ssm_heads * cfg.ssm_state * \
        cfg.ssm_head_dim * BYTES_ACT


# ------------------------------------------------------ flow accumulation
def _ring_edges(ids):
    ids = list(ids)
    if len(ids) < 2:
        return []
    return [(ids[i], ids[(i + 1) % len(ids)]) for i in range(len(ids))]


def _add_allreduce_ring(f, ids, nbytes):
    """Bidirectional ring all-reduce of an ``nbytes`` buffer over ``ids``:
    each participant transmits 2(k-1)/k * nbytes, split over both ring
    directions (reduce-scatter one way, all-gather the other)."""
    k = len(ids)
    if k < 2 or nbytes <= 0:
        return
    per_dir = (k - 1) / k * nbytes
    for a, b in _ring_edges(ids):
        f[a, b] += per_dir
        f[b, a] += per_dir


def _add_allgather_ring(f, ids, nbytes):
    """Ring all-gather of a buffer whose *gathered* size is ``nbytes``:
    each participant transmits (k-1)/k * nbytes, split over directions."""
    k = len(ids)
    if k < 2 or nbytes <= 0:
        return
    per_dir = (k - 1) / (2.0 * k) * nbytes
    for a, b in _ring_edges(ids):
        f[a, b] += per_dir
        f[b, a] += per_dir


def _add_all2all(f, ids, remote_bytes_per_rank):
    """All-to-all where each rank sends ``remote_bytes_per_rank`` off-chip
    total, spread uniformly over the other k-1 peers (full bipartite
    GPU<->GPU block — the MoE dispatch signature)."""
    k = len(ids)
    if k < 2 or remote_bytes_per_rank <= 0:
        return
    per_pair = remote_bytes_per_rank / (k - 1)
    for a in ids:
        for b in ids:
            if a != b:
                f[a, b] += per_pair


def _add_home(f, gpu, llc, read_bytes=0.0, write_bytes=0.0):
    """GPU <-> home-LLC: reads are response-heavy (req up, lines down),
    writes request-heavy (lines up, acks down) — the 1:4 control:data
    split keeps both directions nonzero like `core.traffic`'s 1:2."""
    f[gpu, llc] += 0.25 * read_bytes + write_bytes
    f[llc, gpu] += read_bytes + 0.25 * write_bytes


def _add_host(f, mapping: Mapping, in_bytes_per_gpu: float):
    """Master-CPU input/metric loop + faint background control CPUs."""
    master = mapping.master_cpu
    gpus = mapping.gpu_ids.ravel()
    llcs = mapping.llc_ids
    for g in gpus:
        f[master, g] += in_bytes_per_gpu
        f[g, master] += 0.10 * in_bytes_per_gpu
    # master stages the batch out of the LLC banks first
    total_in = in_bytes_per_gpu * len(gpus)
    for m in llcs:
        _add_home(f, master, m, read_bytes=total_in / len(llcs))
    # non-master CPUs: OS/control background, ~2% of the master volume
    bg = 0.02 * total_in / max(len(llcs), 1)
    for c in mapping.cpu_ids:
        if c == master:
            continue
        for m in llcs:
            f[c, m] += 0.25 * bg
            f[m, c] += bg


# --------------------------------------------------------------- generator
def traffic_from_model(cfg, mapping: Mapping, phase: str) -> np.ndarray:
    """(N, N) directed relative flit rates for ``cfg`` in ``phase``,
    placed by ``mapping``. Deterministic; normalized to sum to the
    per-phase intensity with a zero diagonal (`core/traffic.py` rules)."""
    if phase not in PHASES:
        raise TrafficValidationError(
            f"unknown phase {phase!r}; known: {', '.join(PHASES)}")
    shape = SHAPES[PHASE_SHAPE[phase]]
    dp, tp = mapping.mesh.data, mapping.mesh.model
    n = mapping.n_cpu + mapping.n_llc + mapping.n_gpu
    f = np.zeros((n, n), dtype=np.float64)

    d = cfg.d_model
    P = float(cfg.param_count())
    shard_bytes = P / (dp * tp) * BYTES_ACT     # FSDP-stored shard (train)
    if shape.kind == "decode":
        toks = shape.global_batch / dp          # one token/seq/step
    else:
        toks = shape.global_batch * shape.seq_len / dp
    act = toks * d * BYTES_ACT                  # one activation buffer/shard
    n_ar = _tp_allreduces(cfg)
    a2a_remote = 0.0
    if cfg.family == "moe" and cfg.top_k:
        a2a_remote = 2.0 * cfg.n_layers * toks * cfg.top_k * d * \
            BYTES_ACT * (tp - 1) / max(tp, 1)

    model_groups = [mapping.gpu_ids[di, :] for di in range(dp)]
    data_groups = [mapping.gpu_ids[:, mi] for mi in range(tp)]

    def home_each(read=0.0, write=0.0):
        for di in range(dp):
            for mi in range(tp):
                _add_home(f, mapping.gpu_ids[di, mi],
                          mapping.home_llc[di, mi], read, write)

    if phase == "train.fwd":
        for g in model_groups:
            _add_allreduce_ring(f, g, n_ar * act)
            _add_all2all(f, g, a2a_remote)
        for g in data_groups:
            _add_allgather_ring(f, g, P / tp * BYTES_ACT)
        # residual spill: one activation buffer per block, SPILL_FRAC evicted
        home_each(read=shard_bytes,
                  write=SPILL_FRAC * _n_blocks(cfg) * act)
        _add_host(f, mapping, toks * BYTES_TOKEN)

    elif phase == "train.bwd":
        for g in model_groups:
            _add_allreduce_ring(f, g, 2.0 * n_ar * act)   # dgrad + wgrad
            _add_all2all(f, g, 2.0 * a2a_remote)
        for g in data_groups:
            _add_allgather_ring(f, g, P / tp * BYTES_ACT)  # re-gather weights
        home_each(read=shard_bytes + SPILL_FRAC * _n_blocks(cfg) * act)
        _add_host(f, mapping, 0.10 * toks * BYTES_TOKEN)   # loss/metrics only

    elif phase == "train.grad_sync":
        for g in data_groups:
            _add_allreduce_ring(f, g, P / tp * BYTES_GRAD)
        # optimizer: read (m, v), write (m, v, params) at the home bank
        opt = P / (dp * tp) * BYTES_GRAD
        home_each(read=2.0 * opt, write=3.0 * opt)
        _add_host(f, mapping, 64.0 * BYTES_TOKEN)          # control beat

    elif phase == "serve.prefill":
        for g in model_groups:
            _add_allreduce_ring(f, g, n_ar * act)
            _add_all2all(f, g, a2a_remote)
        kv_write = _kv_bytes_per_token(cfg) / tp * toks
        state_write = _state_bytes(cfg) / tp * (shape.global_batch / dp)
        home_each(read=WEIGHT_STREAM * P / tp * BYTES_ACT,
                  write=kv_write + state_write)
        _add_host(f, mapping, toks * BYTES_TOKEN)

    else:  # serve.decode
        batch_d = shape.global_batch / dp
        for g in model_groups:
            _add_allreduce_ring(f, g, n_ar * batch_d * d * BYTES_ACT)
            _add_all2all(f, g, a2a_remote)
        kv_read = _kv_bytes_per_token(cfg) / tp * shape.seq_len * batch_d
        state = _state_bytes(cfg) / tp * batch_d
        weight = WEIGHT_STREAM * float(cfg.active_param_count()) / tp * \
            BYTES_ACT
        home_each(read=kv_read + state + weight,
                  write=_kv_bytes_per_token(cfg) / tp * batch_d + state)
        _add_host(f, mapping, batch_d * 2.0 * BYTES_TOKEN)

    np.fill_diagonal(f, 0.0)
    total = f.sum()
    if not np.isfinite(total) or total <= 0:
        raise TrafficValidationError(
            f"scenario {cfg.name}:{phase} produced a degenerate matrix "
            f"(sum={total})")
    return f / total * PHASE_INTENSITY[phase]


# ------------------------------------------------------- registry surface
def scenario_matrix(spec: SystemSpec, arch: str, phase: str,
                    mesh=None) -> np.ndarray:
    """Build the (N, N) matrix for "arch:phase" on ``spec``. ``mesh`` is an
    optional (data, model) pair; omitted -> `derive_mesh`'s default."""
    check_scenario(arch, phase)
    cfg = get_config(arch)
    if mesh is None:
        wmesh = derive_mesh(cfg, spec.n_gpu)
    else:
        try:
            wmesh = WorkloadMesh(int(mesh[0]), int(mesh[1]))
        except (TypeError, ValueError, IndexError) as e:
            raise TrafficValidationError(
                f"mesh must be a (data, model) pair of positive ints, "
                f"got {mesh!r}") from e
    try:
        mapping = place_model(spec, wmesh)
    except ValueError as e:
        raise TrafficValidationError(str(e)) from e
    return traffic_from_model(cfg, mapping, phase)


def normalize_model_traffic(spec: SystemSpec, t: dict) -> dict:
    """Validate and canonicalize a ``{"model": ...}`` traffic spec.

    Resolves an omitted mesh to the `derive_mesh` default so explicit and
    implicit spellings of the same scenario hash identically. Raises
    `TrafficValidationError` on unknown names or non-tiling meshes."""
    extra = set(t) - {"model", "phase", "mesh"}
    if extra:
        raise TrafficValidationError(
            f"unknown model-traffic keys {sorted(extra)}; "
            "allowed: model, phase, mesh")
    arch = t.get("model")
    phase = t.get("phase", "train.fwd")
    if not isinstance(arch, str):
        raise TrafficValidationError("model-traffic spec needs a 'model' name")
    check_scenario(arch, phase)
    cfg = get_config(arch)
    mesh = t.get("mesh")
    if mesh is None:
        wmesh = derive_mesh(cfg, spec.n_gpu)
    else:
        if (not isinstance(mesh, (list, tuple)) or len(mesh) != 2
                or not all(isinstance(v, int) and not isinstance(v, bool)
                           and v >= 1 for v in mesh)):
            raise TrafficValidationError(
                f"mesh must be a [data, model] pair of positive ints, "
                f"got {mesh!r}")
        wmesh = WorkloadMesh(int(mesh[0]), int(mesh[1]))
    if wmesh.n_shards != spec.n_gpu:
        raise TrafficValidationError(
            f"mesh {wmesh.data}x{wmesh.model} = {wmesh.n_shards} shards "
            f"does not tile the {spec.n_gpu}-GPU pool of this spec")
    return {"model": arch, "phase": phase,
            "mesh": (wmesh.data, wmesh.model)}
