"""Placing a model's logical mesh onto a SystemSpec's heterogeneous tiles.

The serving stack describes *logical* parallelism — a (data, model) device
mesh (`repro.launch.mesh`), with expert parallelism riding the model axis
(the launch-layer default: "EP over 'model', batch over 'data'"). The NoC
problem describes *physical* cores: CPUs `[0, C)`, LLCs `[C, C+M)`, GPUs
`[C+M, N)` (`repro.core.problem`). This module is the bridge:

  * every (data, model) shard of the logical mesh is hosted by one GPU
    core (row-major: shard (d, m) -> GPU index d*model + m);
  * every shard gets a *home LLC* — the bank holding its parameter shard,
    optimizer state, and KV-cache pages (round-robin over the LLC banks by
    shard index, the address-interleaving stand-in);
  * CPU 0 is the master host core (input pipeline + optimizer driver, the
    §3 "master core" analogue); remaining CPUs carry background control.

Traffic matrices built on top of a :class:`Mapping`
(`repro.workloads.traffic_model`) are in CORE-ID space — the evaluator's
placement permutation decides which physical slot each core occupies, so
one mapping serves every candidate design.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.problem import SystemSpec


@dataclasses.dataclass(frozen=True)
class WorkloadMesh:
    """Logical 2D device mesh (data x model); EP rides the model axis."""

    data: int
    model: int

    def __post_init__(self):
        if self.data < 1 or self.model < 1:
            raise ValueError(
                f"mesh axes must be >= 1, got data={self.data} "
                f"model={self.model}")

    @property
    def n_shards(self) -> int:
        return self.data * self.model

    def to_json(self) -> list:
        return [self.data, self.model]


#: model-parallel degree is capped (wider TP than 8 buys little and the
#: paper-scale GPU pools are small); the real bound is head count.
TP_CAP = 8


def derive_mesh(cfg, n_gpu: int) -> WorkloadMesh:
    """Deterministic default mesh for ``cfg`` on an ``n_gpu``-tile pool.

    The model axis is the largest divisor of ``n_gpu`` not exceeding
    min(TP_CAP, shardable heads) — attention heads for transformers,
    SSD heads for Mamba-family configs; the data axis takes the rest.
    """
    heads = max(int(cfg.n_heads), int(getattr(cfg, "ssm_heads", 0) or 0), 1)
    cap = max(1, min(TP_CAP, heads))
    tp = max(d for d in range(1, cap + 1) if n_gpu % d == 0)
    return WorkloadMesh(data=n_gpu // tp, model=tp)


@dataclasses.dataclass(frozen=True)
class Mapping:
    """A placed model: logical shards bound to physical core ids."""

    mesh: WorkloadMesh
    n_cpu: int
    n_llc: int
    n_gpu: int
    gpu_ids: np.ndarray    # (data, model) int — GPU core id hosting shard
    home_llc: np.ndarray   # (data, model) int — LLC core id homing shard
    master_cpu: int        # host-loop master core id (always 0)

    @property
    def cpu_ids(self) -> np.ndarray:
        return np.arange(self.n_cpu)

    @property
    def llc_ids(self) -> np.ndarray:
        return np.arange(self.n_cpu, self.n_cpu + self.n_llc)


def place_model(spec: SystemSpec, mesh: WorkloadMesh) -> Mapping:
    """Bind every (data, model) shard to a GPU core and a home LLC bank.

    Raises ``ValueError`` when the mesh does not tile the GPU pool exactly
    — a shard without a host core has no physical traffic interpretation.
    """
    if mesh.n_shards != spec.n_gpu:
        raise ValueError(
            f"mesh {mesh.data}x{mesh.model} = {mesh.n_shards} shards does "
            f"not tile the {spec.n_gpu}-GPU pool of this spec")
    C, M = spec.n_cpu, spec.n_llc
    idx = np.arange(mesh.n_shards).reshape(mesh.data, mesh.model)
    gpu_ids = C + M + idx
    home_llc = C + (idx % M)
    return Mapping(mesh=mesh, n_cpu=C, n_llc=M, n_gpu=spec.n_gpu,
                   gpu_ids=gpu_ids, home_llc=home_llc, master_cpu=0)
