"""Phase-sequenced traces and trace-level design scoring.

A real workload is not one static matrix: training beats fwd -> bwd ->
grad-sync, serving beats prefill -> decode, and each phase has its own
traffic structure and duration share. A :class:`PhaseTrace` names that
sequence; `phase_weighted_edp` scores a candidate NoC over the whole trace
(duration-weighted mean of per-phase network EDP) instead of a single
matrix, and `trace_link_report` gives the phase-weighted per-link
utilization profile — the production consumer of the
`kernels/link_util.py` path-walk kernel (`kernels.ops.walk_accumulate`
dispatches kernel vs. jnp reference; tier-1 covers the kernel in
interpret mode against a numpy oracle, see tests/test_kernels.py).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import routing
from repro.core.evaluate import Evaluator
from repro.core.objectives import design_cost, make_consts
from repro.core.problem import Design, SystemSpec
from repro.core.traffic import TrafficValidationError
from repro.kernels import ops

from .traffic_model import check_scenario, scenario_matrix

# ------------------------------------------------------------------- traces
@dataclasses.dataclass(frozen=True)
class Phase:
    """One leg of a trace: a scenario phase plus its duration share."""

    name: str      # e.g. "train.fwd"
    weight: float  # relative duration (cycles spent in this phase)


@dataclasses.dataclass(frozen=True)
class PhaseTrace:
    arch: str
    workload: str                 # "training" | "serving"
    phases: tuple[Phase, ...]

    @property
    def total_weight(self) -> float:
        return sum(p.weight for p in self.phases)

    def scenario_names(self) -> tuple[str, ...]:
        return tuple(f"{self.arch}:{p.name}" for p in self.phases)


#: duration shares: bwd costs ~2x fwd (dgrad + wgrad); grad-sync is a short
#: pure-communication burst; decode steps dominate a serving request's life.
TRACE_PHASES = {
    "training": (("train.fwd", 1.0), ("train.bwd", 2.0),
                 ("train.grad_sync", 0.5)),
    "serving": (("serve.prefill", 1.0), ("serve.decode", 4.0)),
}

WORKLOADS = tuple(TRACE_PHASES)


def trace_for(arch: str, workload: str = "training") -> PhaseTrace:
    if workload not in TRACE_PHASES:
        raise TrafficValidationError(
            f"unknown workload {workload!r}; known: {', '.join(WORKLOADS)}")
    phases = tuple(Phase(n, w) for n, w in TRACE_PHASES[workload])
    for p in phases:
        check_scenario(arch, p.name)
    return PhaseTrace(arch=arch, workload=workload, phases=phases)


def trace_matrices(spec: SystemSpec, trace: PhaseTrace,
                   mesh=None) -> list[tuple[Phase, np.ndarray]]:
    return [(p, scenario_matrix(spec, trace.arch, p.name, mesh=mesh))
            for p in trace.phases]


# ---------------------------------------------------------------- scoring
#: evaluators are jit-carrying objects — reuse them per (spec, scenario).
_EV_CACHE: dict = {}


def evaluator_for(spec: SystemSpec, arch: str, phase: str, mesh=None,
                  backend: str = "auto") -> Evaluator:
    key = (spec, arch, phase, tuple(mesh) if mesh is not None else None,
           backend)
    ev = _EV_CACHE.get(key)
    if ev is None:
        f = scenario_matrix(spec, arch, phase, mesh=mesh)
        ev = _EV_CACHE[key] = Evaluator(spec, f, backend=backend)
    return ev


def phase_weighted_edp(spec: SystemSpec, design: Design, trace: PhaseTrace,
                       *, mesh=None, backend: str = "auto") -> dict:
    """Duration-weighted network EDP of ``design`` over ``trace``.

    Returns ``{"edp", "per_phase": {phase: edp}, "weights": {phase: w}}`` —
    ``edp`` is sum(w_p * edp_p) / sum(w_p), the trace-level analogue of the
    single-matrix `Evaluator.edp`."""
    per_phase, weights = {}, {}
    acc = 0.0
    for p in trace.phases:
        ev = evaluator_for(spec, trace.arch, p.name, mesh=mesh,
                           backend=backend)
        e = ev.edp(design)
        per_phase[p.name] = e
        weights[p.name] = p.weight
        acc += p.weight * e
    return {"edp": acc / trace.total_weight, "per_phase": per_phase,
            "weights": weights}


# ------------------------------------------------------------- link report
def trace_link_report(spec: SystemSpec, design: Design, trace: PhaseTrace,
                      *, mesh=None, use_kernel: bool | None = None,
                      interpret: bool = False) -> dict:
    """Phase-weighted per-link utilization of ``design`` under ``trace``.

    Each phase's traffic is walked along the design's routing paths with
    `kernels.ops.walk_accumulate` (Pallas path-walk kernel on TPU /
    interpret, jnp reference elsewhere); directed utilizations are folded
    to undirected links and blended by phase duration. Returns::

        {"util": (N, N) phase-weighted undirected link utilization,
         "visits": (N,) phase-weighted router traversals,
         "max_link": ((a, b), value), "mean": float, "std": float}
    """
    consts = make_consts(spec)
    n = spec.n_tiles
    adj = jnp.asarray(design.adj, bool)
    cost = design_cost(consts, adj)
    dist, nh = routing.routing_tables(cost, consts.apsp_iters)
    perm = np.asarray(design.perm)
    eye = 1.0 - np.eye(n)

    util_acc = np.zeros((n, n))
    visits_acc = np.zeros((n,))
    for p, f in trace_matrices(spec, trace, mesh=mesh):
        f_slots = np.asarray(f)[perm][:, perm] * eye
        _, _, util, visits = ops.walk_accumulate(
            nh, jnp.asarray(f_slots, jnp.float32), consts.link_delay,
            max_hops=consts.max_hops, use_kernel=use_kernel,
            interpret=interpret)
        w = p.weight / trace.total_weight
        util_d = np.asarray(util, np.float64)
        util_acc += w * (util_d + util_d.T)
        visits_acc += w * np.asarray(visits, np.float64)

    link_mask = np.triu(np.asarray(adj | consts.vadj), 1)
    present = util_acc[link_mask.astype(bool)]
    flat = np.where(link_mask, util_acc, 0.0)
    a, b = np.unravel_index(int(np.argmax(flat)), flat.shape)
    return {
        "util": util_acc,
        "visits": visits_acc,
        "max_link": ((int(a), int(b)), float(flat[a, b])),
        "mean": float(present.mean()) if present.size else 0.0,
        "std": float(present.std()) if present.size else 0.0,
    }
