"""repro.workloads — model-derived NoC traffic (DESIGN.md §11).

Derives (N, N) flit-rate matrices from the repo's real model configs:
`mapping` places a logical (data, model) mesh onto a SystemSpec's
heterogeneous tiles, `traffic_model` turns sharded collective volumes into
per-phase matrices in the `core/traffic.py` convention, `phases` sequences
them into traces with phase-weighted scoring, and `study` cross-executes
paper-app-optimized NoCs against LLM traffic (and vice versa).

Every (model x phase) scenario is addressable by string ("arch:phase",
see `PHASE_APP_NAMES`), through `NocProblem(traffic={"model": ...})`, and
through the CLI as ``--traffic model:<arch>:<phase>``.
"""

from .mapping import Mapping, WorkloadMesh, derive_mesh, place_model
from .phases import (Phase, PhaseTrace, WORKLOADS, evaluator_for,
                     phase_weighted_edp, trace_for, trace_link_report,
                     trace_matrices)
from .study import (LLM_STUDY_SCENARIOS, format_cross_table,
                    run_cross_workload_study)
from .traffic_model import (PHASE_APP_NAMES, PHASE_INTENSITY, PHASES,
                            check_scenario, normalize_model_traffic,
                            parse_scenario, scenario_matrix, scenario_name,
                            traffic_from_model)

__all__ = [
    "LLM_STUDY_SCENARIOS", "Mapping", "PHASES", "PHASE_APP_NAMES",
    "PHASE_INTENSITY", "Phase", "PhaseTrace", "WORKLOADS", "WorkloadMesh",
    "check_scenario", "derive_mesh", "evaluator_for", "format_cross_table",
    "normalize_model_traffic", "parse_scenario", "phase_weighted_edp",
    "place_model", "run_cross_workload_study", "scenario_matrix",
    "scenario_name", "trace_for", "trace_link_report", "trace_matrices",
    "traffic_from_model",
]
