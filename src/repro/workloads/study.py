"""Cross-workload agnostic study: paper apps vs. model-derived traffic.

The paper's application-agnostic claim (§6.4: a NoC optimized on an
aggregate of a few apps loses only 1-2% EDP on unseen ones) was measured
on ten Rodinia-class traces whose traffic is structurally alike
(near-uniform many-to-few GPU<->LLC). LLM phase traffic is not alike —
MoE all-to-all puts mass on GPU<->GPU, decode concentrates reads on home
LLC banks. `run_cross_workload_study` asks the question directly: optimize
NoCs per scenario plus two aggregates (AVG over paper apps, AVG over LLM
scenarios), cross-execute everything, and report how far a
paper-apps-optimized NoC degrades on LLM traffic (and vice versa).
"""

from __future__ import annotations

import numpy as np

from repro.core.agnostic import OptimizeBudget, optimize_for_traffic
from repro.core.evaluate import Evaluator
from repro.core.problem import SystemSpec
from repro.core.traffic import APPLICATIONS, traffic_matrix

from .traffic_model import (PHASE_INTENSITY, parse_scenario, scenario_matrix)

#: curated scenario set spanning the structures the paper corpus lacks:
#: dense-transformer training, pure-communication grad-sync, MoE training
#: (all-to-all), and memory-bound serving decode (many-to-few LLC reads).
LLM_STUDY_SCENARIOS = (
    "yi-6b:train.fwd",
    "mistral-large-123b:train.grad_sync",
    "qwen3-moe-30b-a3b:train.fwd",
    "moonshot-v1-16b-a3b:train.fwd",
    "yi-6b:serve.decode",
    "qwen3-moe-30b-a3b:serve.decode",
)

AVG_PAPER = "AVG:paper"
AVG_LLM = "AVG:llm"


def _avg_of(mats: list[np.ndarray], intensities: list[float]) -> np.ndarray:
    """Aggregate per `core.traffic.avg_traffic`: unit-normalize each matrix,
    mean, then rescale by the mean intensity."""
    unit = [m / m.sum() for m in mats]
    return np.mean(unit, axis=0) * float(np.mean(intensities))


def run_cross_workload_study(
    spec: SystemSpec,
    paper_apps: tuple[str, ...] = ("BP", "BFS", "LUD", "NW"),
    llm_scenarios: tuple[str, ...] = LLM_STUDY_SCENARIOS,
    case: str = "case3",
    budget: OptimizeBudget | None = None,
    mesh=None,
) -> dict:
    """Cross-execution table over paper apps + LLM scenarios + aggregates.

    result['table'][i, j]: EDP of NoC_i on workload_j, normalized to the
    EDP of workload_j's own NoC (diagonal == 1 for single workloads).
    Rows include AVG:paper and AVG:llm — NoCs optimized on each corpus's
    aggregate, evaluated everywhere; their cross-corpus rows are the
    generalization-gap measurement."""
    budget = budget or OptimizeBudget()

    mats: dict[str, np.ndarray] = {}
    for a in paper_apps:
        mats[a] = traffic_matrix(spec, a)
    for s in llm_scenarios:
        arch, phase = parse_scenario(s)
        mats[s] = scenario_matrix(spec, arch, phase, mesh=mesh)

    workloads = tuple(paper_apps) + tuple(llm_scenarios)
    mats[AVG_PAPER] = _avg_of(
        [mats[a] for a in paper_apps],
        [APPLICATIONS[a]["intensity"] for a in paper_apps])
    mats[AVG_LLM] = _avg_of(
        [mats[s] for s in llm_scenarios],
        [PHASE_INTENSITY[parse_scenario(s)[1]] for s in llm_scenarios])

    rows = workloads + (AVG_PAPER, AVG_LLM)
    evs = {w: Evaluator(spec, mats[w]) for w in workloads}
    designs = {}
    for r in rows:
        d, _, _ = optimize_for_traffic(spec, mats[r], case, budget)
        designs[r] = d

    diag = {w: evs[w].edp(designs[w]) for w in workloads}
    table = np.zeros((len(rows), len(workloads)))
    for i, r in enumerate(rows):
        for j, w in enumerate(workloads):
            table[i, j] = evs[w].edp(designs[r]) / diag[w]

    n_paper = len(paper_apps)
    paper_cols = slice(0, n_paper)
    llm_cols = slice(n_paper, len(workloads))
    i_avg_paper = rows.index(AVG_PAPER)
    i_avg_llm = rows.index(AVG_LLM)
    summary = {
        # a paper-apps NoC, judged on LLM traffic (the headline gap)
        "paper_on_llm_avg": float(table[i_avg_paper, llm_cols].mean() - 1.0),
        "paper_on_llm_worst": float(table[i_avg_paper, llm_cols].max() - 1.0),
        # and the mirror image
        "llm_on_paper_avg": float(table[i_avg_llm, paper_cols].mean() - 1.0),
        "llm_on_paper_worst": float(table[i_avg_llm, paper_cols].max() - 1.0),
        # each corpus's aggregate on its own corpus (the paper's §6.4 claim)
        "paper_on_paper_avg": float(table[i_avg_paper, paper_cols].mean() - 1.0),
        "llm_on_llm_avg": float(table[i_avg_llm, llm_cols].mean() - 1.0),
    }
    return dict(rows=rows, workloads=workloads, table=table,
                designs=designs, summary=summary)


def format_cross_table(result: dict) -> str:
    """Human-readable cross table (benchmarks/fig9_agnostic --workloads llm)."""
    rows, cols, t = result["rows"], result["workloads"], result["table"]
    w = max(len(r) for r in rows) + 2
    cw = max(max((len(c) for c in cols), default=8), 6) + 1
    lines = [" " * w + "".join(f"{c:>{cw}}" for c in cols)]
    for i, r in enumerate(rows):
        lines.append(f"{r:<{w}}" +
                     "".join(f"{t[i, j]:>{cw}.3f}" for j in range(len(cols))))
    s = result["summary"]
    lines.append("")
    lines.append(
        f"paper-apps NoC on LLM traffic: avg +{s['paper_on_llm_avg']:.1%} "
        f"/ worst +{s['paper_on_llm_worst']:.1%}")
    lines.append(
        f"LLM NoC on paper traffic:      avg +{s['llm_on_paper_avg']:.1%} "
        f"/ worst +{s['llm_on_paper_worst']:.1%}")
    return "\n".join(lines)
