"""Model zoo: functional JAX implementations of every assigned architecture
family (dense GQA, MoE, Mamba-2/SSD, hybrid, encoder-decoder, early-fusion
VLM). See models/model.py for the unified interface."""

from .common import ModelConfig, activation_sharding, pshard
from .model import Model, build

__all__ = ["Model", "ModelConfig", "activation_sharding", "build", "pshard"]
