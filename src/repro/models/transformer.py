"""Decoder-only LM assembly for dense / MoE / SSM / hybrid / VLM families.

Depth is always a lax.scan over stacked layer params (O(1) HLO in depth),
with jax.checkpoint around the scanned body when cfg.remat. Layer-index-
dependent behaviour (gemma3's 5:1 local:global windows) rides the scan as a
per-layer xs array (traced window width -> one uniform code path). The
zamba2-style hybrid nests scans: outer over "sites" (shared attention block
+ its KV cache), inner over the mamba sublayers between sites."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .attention import attn_decode, attn_full, init_attn_layer
from .common import (ModelConfig, cross_entropy, init_dense, pshard,
                     rms_norm, scan_layers)
from .mamba2 import (init_mamba_layer, mamba_decode, mamba_full,
                     mamba_init_state)
from .moe import init_moe_layer, moe_ffn

AUX_LOSS_COEF = 0.01


# ------------------------------------------------------------------- init
def init_mlp_layer(cfg: ModelConfig, key) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w1": init_dense(ks[0], (d, f), dtype=cfg.dtype),
        "w3": init_dense(ks[1], (d, f), dtype=cfg.dtype),
        "w2": init_dense(ks[2], (f, d), dtype=cfg.dtype),
    }


def _init_block(cfg: ModelConfig, key) -> dict:
    """One decoder block of the family's repeating unit."""
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if cfg.family in ("dense", "vlm"):
        return {
            "norm1": jnp.zeros((d,), cfg.dtype),
            "attn": init_attn_layer(cfg, ks[0]),
            "norm2": jnp.zeros((d,), cfg.dtype),
            "mlp": init_mlp_layer(cfg, ks[1]),
        }
    if cfg.family == "moe":
        return {
            "norm1": jnp.zeros((d,), cfg.dtype),
            "attn": init_attn_layer(cfg, ks[0]),
            "norm2": jnp.zeros((d,), cfg.dtype),
            "moe": init_moe_layer(cfg, ks[1]),
        }
    if cfg.family in ("ssm", "hybrid"):
        return {
            "norm1": jnp.zeros((d,), cfg.dtype),
            "mamba": init_mamba_layer(cfg, ks[0]),
        }
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, cfg.n_layers + 4)
    blocks = [_init_block(cfg, ks[i]) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params = {
        "embed": init_dense(ks[-1], (cfg.vocab, cfg.d_model), dtype=cfg.dtype),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.dtype),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["head"] = init_dense(ks[-2], (cfg.d_model, cfg.vocab),
                                    dtype=cfg.dtype)
    if cfg.family == "hybrid":
        params["shared"] = {
            "norm1": jnp.zeros((cfg.d_model,), cfg.dtype),
            "attn": init_attn_layer(cfg, ks[-3]),
            "norm2": jnp.zeros((cfg.d_model,), cfg.dtype),
            "mlp": init_mlp_layer(cfg, ks[-4]),
        }
    return params


# ---------------------------------------------------------------- helpers
def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    cd = cfg.compute_dtype
    h = jax.nn.silu(x @ p["w1"].astype(cd)) * (x @ p["w3"].astype(cd))
    h = pshard(h, ("batch", "seq", "mlp"))
    return h @ p["w2"].astype(cd)


def _window_schedule(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer attention window (0 = full) as a scan-carried xs array."""
    idx = jnp.arange(cfg.n_layers)
    if cfg.sliding_window and cfg.global_every:
        is_global = (idx + 1) % cfg.global_every == 0
        return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)
    if cfg.sliding_window:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    return jnp.zeros((cfg.n_layers,), jnp.int32)


def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    return pshard(x * (cfg.d_model ** 0.5), ("batch", "seq", None))


def _logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    out = x @ head.astype(cfg.compute_dtype)
    return pshard(out, ("batch", "seq", "vocab"))


# ------------------------------------------------------------ full forward
def forward_full(cfg: ModelConfig, params: dict, tokens: jax.Array,
                 *, collect_cache: bool = False):
    """Full-sequence forward. Returns (hidden, aux_loss, caches or None).

    caches: attention (k, v) stacked (L, B, S, KH, Dh) for attn families; for
    hybrid, per-site stacks; unused for pure SSM prefill (decode re-runs the
    sequence through mamba states via prefill_states)."""
    x = _embed(cfg, params, tokens)
    windows = _window_schedule(cfg)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, layer_in):
            x, aux = carry
            p, w = layer_in
            h, (k, v) = attn_full(
                cfg, p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), window=w
            )
            x = x + h
            z = rms_norm(x, p["norm2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, a = moe_ffn(cfg, p["moe"], z)
                aux = aux + a
            else:
                y = mlp(cfg, p["mlp"], z)
            x = pshard(x + y, ("batch", "seq", None))
            return (x, aux), (k, v) if collect_cache else None

        body = jax.checkpoint(body) if cfg.remat else body
        (x, aux), kv = scan_layers(
            body, (x, jnp.zeros((), jnp.float32)), (params["layers"], windows),
            unroll=cfg.unroll_layers,
        )
        return x, aux, kv

    if cfg.family == "ssm":
        def body(carry, p):
            x = carry
            h = mamba_full(cfg, p["mamba"],
                           rms_norm(x, p["norm1"], cfg.norm_eps),
                           return_state=collect_cache)
            h, st = h if collect_cache else (h, None)
            x = pshard(x + h, ("batch", "seq", None))
            return x, (st if collect_cache else None)

        body = jax.checkpoint(body) if cfg.remat else body
        x, states = scan_layers(body, x, params["layers"],
                                unroll=cfg.unroll_layers)
        return x, jnp.zeros((), jnp.float32), states

    if cfg.family == "hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape(n_sites, cfg.attn_every, *a.shape[1:]),
            params["layers"],
        )
        shared = params["shared"]

        def inner(x, p):
            h = mamba_full(cfg, p["mamba"],
                           rms_norm(x, p["norm1"], cfg.norm_eps),
                           return_state=collect_cache)
            h, st = h if collect_cache else (h, None)
            x = pshard(x + h, ("batch", "seq", None))
            return x, (st if collect_cache else None)

        def outer_fixed(x, site_params):
            x, states = scan_layers(inner, x, site_params,
                                    unroll=cfg.unroll_layers)
            h, (k, v) = attn_full(
                cfg, shared["attn"],
                rms_norm(x, shared["norm1"], cfg.norm_eps), window=0,
            )
            x = x + h
            x = x + mlp(cfg, shared["mlp"],
                        rms_norm(x, shared["norm2"], cfg.norm_eps))
            return pshard(x, ("batch", "seq", None)), \
                ((k, v, states) if collect_cache else None)

        of = jax.checkpoint(outer_fixed) if cfg.remat else outer_fixed
        x, kv = scan_layers(of, x, grouped, unroll=cfg.unroll_layers)
        return x, jnp.zeros((), jnp.float32), kv

    raise ValueError(cfg.family)


# ------------------------------------------------------------------- loss
def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    x, aux, _ = forward_full(cfg, params, batch["tokens"])
    logits = _logits(cfg, params, x)
    ce = cross_entropy(logits, batch["targets"], batch.get("mask"))
    return ce + AUX_LOSS_COEF * aux


# ------------------------------------------------------------------ decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    kv = lambda: jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd),
                           dtype)
    if cfg.family in ("dense", "vlm", "moe"):
        return {"k": kv(), "v": kv(), "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        st = mamba_init_state(cfg, batch)
        return {
            "conv": jnp.zeros((cfg.n_layers, *st["conv"].shape), jnp.float32),
            "ssm": jnp.zeros((cfg.n_layers, *st["ssm"].shape), jnp.float32),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        st = mamba_init_state(cfg, batch)
        return {
            "conv": jnp.zeros((cfg.n_layers, *st["conv"].shape), jnp.float32),
            "ssm": jnp.zeros((cfg.n_layers, *st["ssm"].shape), jnp.float32),
            "k": jnp.zeros((n_sites, batch, max_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((n_sites, batch, max_len, cfg.n_kv_heads, hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    x = _embed(cfg, params, tokens)
    pos = cache["pos"]
    windows = _window_schedule(cfg)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(x, layer_in):
            p, w, ck, cv = layer_in
            h, nk, nv = attn_decode(
                cfg, p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                ck, cv, pos, window=w,
            )
            x = x + h
            z = rms_norm(x, p["norm2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = moe_ffn(cfg, p["moe"], z)
            else:
                y = mlp(cfg, p["mlp"], z)
            return x + y, (nk, nv)

        x, (nk, nv) = scan_layers(
            body, x, (params["layers"], windows, cache["k"], cache["v"]),
            unroll=cfg.unroll_layers,
        )
        new_cache = {"k": nk, "v": nv, "pos": pos + 1}

    elif cfg.family == "ssm":
        def body(x, layer_in):
            p, conv, ssm = layer_in
            y, st = mamba_decode(
                cfg, p["mamba"], rms_norm(x, p["norm1"], cfg.norm_eps),
                {"conv": conv, "ssm": ssm},
            )
            return x + y, (st["conv"], st["ssm"])

        x, (nconv, nssm) = scan_layers(
            body, x, (params["layers"], cache["conv"], cache["ssm"]),
            unroll=cfg.unroll_layers,
        )
        new_cache = {"conv": nconv, "ssm": nssm, "pos": pos + 1}

    elif cfg.family == "hybrid":
        n_sites = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape(n_sites, cfg.attn_every, *a.shape[1:]),
            params["layers"],
        )
        gconv = cache["conv"].reshape(n_sites, cfg.attn_every,
                                      *cache["conv"].shape[1:])
        gssm = cache["ssm"].reshape(n_sites, cfg.attn_every,
                                    *cache["ssm"].shape[1:])
        shared = params["shared"]

        def inner(x, layer_in):
            p, conv, ssm = layer_in
            y, st = mamba_decode(
                cfg, p["mamba"], rms_norm(x, p["norm1"], cfg.norm_eps),
                {"conv": conv, "ssm": ssm},
            )
            return x + y, (st["conv"], st["ssm"])

        def outer(x, site_in):
            p, conv, ssm, ck, cv = site_in
            x, (nconv, nssm) = scan_layers(inner, x, (p, conv, ssm),
                                           unroll=cfg.unroll_layers)
            h, nk, nv = attn_decode(
                cfg, shared["attn"], rms_norm(x, shared["norm1"], cfg.norm_eps),
                ck, cv, pos, window=0,
            )
            x = x + h
            x = x + mlp(cfg, shared["mlp"],
                        rms_norm(x, shared["norm2"], cfg.norm_eps))
            return x, (nconv, nssm, nk, nv)

        x, (nconv, nssm, nk, nv) = scan_layers(
            outer, x, (grouped, gconv, gssm, cache["k"], cache["v"]),
            unroll=cfg.unroll_layers,
        )
        new_cache = {
            "conv": nconv.reshape(cache["conv"].shape),
            "ssm": nssm.reshape(cache["ssm"].shape),
            "k": nk, "v": nv, "pos": pos + 1,
        }
    else:
        raise ValueError(cfg.family)

    return _logits(cfg, params, x), new_cache


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array,
            max_len: int) -> tuple[jax.Array, dict]:
    """Run the context once, returning last-position logits + a decode cache
    sized max_len. (Attention families reuse the forward K/V; SSM families
    replay tokens through decode steps is avoided — we rebuild states with a
    scan over the sequence.)"""
    b, s = tokens.shape
    if cfg.family in ("dense", "vlm", "moe"):
        x, _, kv = forward_full(cfg, params, tokens, collect_cache=True)
        k, v = kv  # (L, B, S, KH, Dh)
        cache = init_cache(cfg, b, max_len, dtype=k.dtype)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, 0, 0, 0, 0))
        cache["pos"] = jnp.asarray(s, jnp.int32)
        return _logits(cfg, params, x[:, -1:, :]), cache

    # SSM / hybrid: ONE full-sequence pass; the SSD chunked form hands back
    # the final recurrent state per layer (O(S) instead of an S-step decode
    # scan — see EXPERIMENTS.md §Perf, ssm-prefill).
    cache = init_cache(cfg, b, max_len)
    x, _, collected = forward_full(cfg, params, tokens, collect_cache=True)
    if cfg.family == "ssm":
        states = collected
        cache["conv"] = states["conv"].astype(cache["conv"].dtype)
        cache["ssm"] = states["ssm"]
    else:  # hybrid: (k, v, per-site mamba states)
        k, v, states = collected
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        cache["conv"] = states["conv"].reshape(cache["conv"].shape).astype(
            cache["conv"].dtype)
        cache["ssm"] = states["ssm"].reshape(cache["ssm"].shape)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return _logits(cfg, params, x[:, -1:, :]), cache
