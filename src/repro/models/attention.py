"""GQA attention with RoPE — train/prefill (full-sequence) and decode
(single-token against a KV cache) paths.

The full-sequence path can use the Pallas flash kernel on TPU (static
window); the jnp path supports *traced* per-layer windows (gemma3's 5:1
local:global pattern inside one lax.scan). Decode always uses the jnp path
(one query token; attention is a (1, S) contraction)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .common import ModelConfig, init_dense, pshard, rope

FULL_WINDOW = 1 << 30  # sentinel "no window" as a dynamic-mask width


def _effective_window(window) -> jax.Array:
    """Window width as a dynamic mask bound; 0 means full attention whether
    the width is a python int or a traced per-layer scalar."""
    if isinstance(window, int):
        return jnp.asarray(window if window > 0 else FULL_WINDOW, jnp.int32)
    return jnp.where(window > 0, window, FULL_WINDOW).astype(jnp.int32)


def init_attn_layer(cfg: ModelConfig, key) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], (d, cfg.n_heads * hd), dtype=cfg.dtype),
        "wk": init_dense(ks[1], (d, cfg.n_kv_heads * hd), dtype=cfg.dtype),
        "wv": init_dense(ks[2], (d, cfg.n_kv_heads * hd), dtype=cfg.dtype),
        "wo": init_dense(ks[3], (cfg.n_heads * hd, d), dtype=cfg.dtype),
    }


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = cfg.compute_dtype
    q = (x @ p["wq"].astype(cd)).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"].astype(cd)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"].astype(cd)).reshape(b, s, cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = pshard(q, ("batch", "seq", "heads", None))
    k = pshard(k, ("batch", "seq", "kv_heads", None))
    return q, k, v


def attn_full(cfg: ModelConfig, p: dict, x: jax.Array, *,
              window, causal: bool = True, positions=None) -> tuple:
    """Full-sequence attention. ``window`` may be a python int (0 = full) or
    a traced scalar (dynamic local/global patterns). Returns (y, (k, v))."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(cfg, p, x, positions)

    static_window = isinstance(window, int)
    if static_window and kops.on_tpu() and s % 128 == 0:
        y = kops.attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal=causal, window=(window or None),
        ).swapaxes(1, 2)
    else:
        w = _effective_window(window)
        qh = q.swapaxes(1, 2).astype(jnp.float32)          # (B,H,S,D)
        kh = k.swapaxes(1, 2).astype(jnp.float32)
        vh = v.swapaxes(1, 2).astype(jnp.float32)
        group = cfg.n_heads // cfg.n_kv_heads
        kh = jnp.repeat(kh, group, axis=1)
        vh = jnp.repeat(vh, group, axis=1)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) * (qh.shape[-1] ** -0.5)
        qp = jnp.arange(s)[:, None]
        kp = jnp.arange(s)[None, :]
        mask = (kp <= qp) if causal else jnp.ones((s, s), bool)
        mask = mask & (kp > qp - w)
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        y = jnp.einsum("bhqk,bhkd->bhqd", probs, vh).swapaxes(1, 2)

    y = y.reshape(b, s, -1).astype(cfg.compute_dtype)
    y = pshard(y, ("batch", "seq", None))
    return y @ p["wo"].astype(cfg.compute_dtype), (k, v)


def attn_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache_k, cache_v,
                pos: jax.Array, *, window=0) -> tuple:
    """One-token decode. x (B,1,D); cache_k/v (B, S_max, KH, Dh); pos ()
    current write index. Returns (y, new_k, new_v)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, positions)

    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))

    s_max = cache_k.shape[1]
    group = cfg.n_heads // cfg.n_kv_heads
    qh = q[:, 0].astype(jnp.float32)                       # (B, H, Dh)
    kh = cache_k.astype(jnp.float32)                       # (B, S, KH, Dh)
    w = _effective_window(window)
    kp = jnp.arange(s_max, dtype=jnp.int32)
    valid = (kp <= pos) & (kp > pos - w)
    # Fold GQA: reshape q heads into (KH, group) and contract against the
    # cache without materializing repeated KV heads.
    qg = qh.reshape(b, cfg.n_kv_heads, group, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, kh) * (hd ** -0.5)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    vh = cache_v.astype(jnp.float32)
    y = jnp.einsum("bkgs,bskd->bkgd", probs, vh).reshape(b, 1, -1)
    y = y.astype(cfg.compute_dtype)
    return y @ p["wo"].astype(cfg.compute_dtype), cache_k, cache_v
