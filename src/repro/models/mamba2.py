"""Mamba-2 block (SSD) — full-sequence (train/prefill) and stateful decode.

Structure follows arXiv:2405.21060 (ngroups = 1): in_proj -> (z | x | B | C
| dt), short causal depthwise conv over (x, B, C), softplus dt, SSD core
(kernels/ops.ssd: Pallas chunked kernel on TPU, chunked jnp elsewhere),
gated RMSNorm, out_proj. Decode carries (conv window, SSM state) — O(1)
memory per token, which is why the SSM archs run the long_500k shape."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .common import ModelConfig, init_dense, pshard, rms_norm


def _dims(cfg: ModelConfig):
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_in = h * p
    conv_dim = d_in + 2 * n
    return h, p, n, d_in, conv_dim


def init_mamba_layer(cfg: ModelConfig, key) -> dict:
    h, p_, n, d_in, conv_dim = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "in_proj": init_dense(ks[0], (d, 2 * d_in + 2 * n + h), dtype=cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_dim))
                   * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "a_log": jnp.log(jnp.linspace(1.0, float(h), h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((d_in,), cfg.dtype),
        "out_proj": init_dense(ks[4], (d_in, d), dtype=cfg.dtype),
    }


def _split_proj(cfg, proj):
    h, p_, n, d_in, _ = _dims(cfg)
    z, xs, bmat, cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    return z, xs, bmat, cmat, dt


def mamba_full(cfg: ModelConfig, p: dict, x: jax.Array,
               return_state: bool = False):
    """x (B, S, D) -> (B, S, D); with ``return_state``, also the decode
    state {"conv", "ssm"} after the last position (the prefill path —
    O(S) work instead of an S-step decode scan)."""
    h, p_, n, d_in, conv_dim = _dims(cfg)
    b, s, d = x.shape
    cd = cfg.compute_dtype

    proj = x @ p["in_proj"].astype(cd)
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)        # (B,S,conv)

    # Causal depthwise conv, width K.
    k = cfg.conv_width
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + s, :] * p["conv_w"].astype(cd)[i][None, None, :]
        for i in range(k)
    ) + p["conv_b"].astype(cd)
    conv = jax.nn.silu(conv)
    xs, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    ssd_out = kops.ssd(
        xs.reshape(b, s, h, p_).astype(jnp.float32), dt, a,
        bmat.astype(jnp.float32), cmat.astype(jnp.float32), p["d_skip"],
        chunk=min(64, s), return_state=return_state,
    )
    y, final_ssm = ssd_out if return_state else (ssd_out, None)
    y = y.reshape(b, s, d_in).astype(cd)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    y = pshard(y, ("batch", "seq", "heads_flat"))
    out = y @ p["out_proj"].astype(cd)
    if not return_state:
        return out
    conv_state = pad[:, s : s + k - 1, :].astype(jnp.float32)  # last K-1 raw
    return out, {"conv": conv_state, "ssm": final_ssm}


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    h, p_, n, d_in, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, n, p_), jnp.float32),
    }


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array, state: dict) -> tuple:
    """x (B, 1, D); returns (y (B,1,D), new_state)."""
    h, p_, n, d_in, conv_dim = _dims(cfg)
    b = x.shape[0]
    cd = cfg.compute_dtype

    proj = x[:, 0] @ p["in_proj"].astype(cd)                # (B, ...)
    z, xs, bmat, cmat, dt = _split_proj(cfg, proj)
    xbc = jnp.concatenate([xs, bmat, cmat], axis=-1)        # (B, conv)

    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # (B,K,conv)
    conv = jnp.einsum("bkc,kc->bc", window.astype(cd), p["conv_w"].astype(cd))
    conv = jax.nn.silu(conv + p["conv_b"].astype(cd))
    xs, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    a = -jnp.exp(p["a_log"])                                       # (H,)
    decay = jnp.exp(dt * a[None, :])                               # (B, H)
    xh = xs.reshape(b, h, p_).astype(jnp.float32)
    upd = jnp.einsum("bn,bhp->bhnp", bmat.astype(jnp.float32),
                     xh * dt[..., None])
    ssm = decay[..., None, None] * state["ssm"] + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), ssm)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(b, d_in).astype(cd)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(cd))[:, None, :]
    return out, {"conv": window[:, 1:, :].astype(state["conv"].dtype), "ssm": ssm}
