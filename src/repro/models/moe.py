"""Top-k Mixture-of-Experts FFN (GShard/Switch-style capacity dispatch).

Dispatch/combine are dense one-hot einsums — the TPU-idiomatic formulation
(MXU matmuls; no scatter). Tokens are processed in groups so the dispatch
tensor (g, s, e, c) stays VMEM/HBM-friendly, and the expert dimension of
both the stacked expert weights and every dispatch intermediate carries the
'experts' logical axis — expert parallelism falls out of the sharding rules
(all-to-all inserted by GSPMD), which is exactly the many-to-few traffic the
paper's NoC objectives target (DESIGN.md §4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense, pshard

GROUP_SIZE = 1024  # tokens per dispatch group


def init_moe_layer(cfg: ModelConfig, key) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], (d, e), dtype=jnp.float32),
        "w1": init_dense(ks[1], (e, d, f), scale_axis=1, dtype=cfg.dtype),
        "w3": init_dense(ks[2], (e, d, f), scale_axis=1, dtype=cfg.dtype),
        "w2": init_dense(ks[3], (e, f, d), scale_axis=1, dtype=cfg.dtype),
    }


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y, aux_loss). Load-balancing aux loss per GShard."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cd = cfg.compute_dtype

    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    g_size = min(GROUP_SIZE, t)
    n_groups = t // g_size
    xg = tokens[: n_groups * g_size].reshape(n_groups, g_size, d)
    xg = pshard(xg, ("batch", None, None))

    # Router (f32 for numerics).
    logits = xg.astype(jnp.float32) @ p["router"]            # (g, s, e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (g, s, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (mean prob * mean assignment per expert).
    me = jnp.mean(probs, axis=(0, 1))                        # (e,)
    assign = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32).sum(2)  # (g,s,e)
    ce = jnp.mean(assign, axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    capacity = int(max(k, round(g_size * k / e * cfg.capacity_factor)))
    sel = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)   # (g, s, k, e)
    # Position of each (token, choice) within its expert's buffer.
    flat_sel = sel.reshape(n_groups, g_size * k, e)
    pos = jnp.cumsum(flat_sel, axis=1) - 1.0                 # (g, s*k, e)
    pos = pos.reshape(n_groups, g_size, k, e)
    within = (pos < capacity) & (sel > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    # dispatch (g, s, e, c): token -> expert buffer slot.
    dispatch = jnp.einsum("gske,gskec->gsec", sel, pos_oh * within[..., None])
    combine = jnp.einsum("gske,gskec->gsec",
                         sel * gate_vals[..., None], pos_oh * within[..., None])
    dispatch = pshard(dispatch.astype(cd), ("batch", None, "experts", None))
    combine = pshard(combine.astype(cd), ("batch", None, "experts", None))

    # Expert buffers and the expert FFN (stacked einsum over e).
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg.astype(cd))
    xe = pshard(xe, ("experts", "batch", None, None))
    h = jnp.einsum("egcd,edf->egcf", xe, p["w1"].astype(cd))
    hg = jnp.einsum("egcd,edf->egcf", xe, p["w3"].astype(cd))
    h = jax.nn.silu(h) * hg
    ye = jnp.einsum("egcf,efd->egcd", h, p["w2"].astype(cd))
    ye = pshard(ye, ("experts", "batch", None, None))

    yg = jnp.einsum("gsec,egcd->gsd", combine, ye)
    y = yg.reshape(-1, d)
    if y.shape[0] < t:  # ragged tail (never happens for our shapes)
        y = jnp.concatenate([y, tokens[y.shape[0]:]], axis=0)
    return y.reshape(b, s, d).astype(cd), aux.astype(jnp.float32)
