"""Whisper-style encoder-decoder backbone.

The conv/mel audio frontend is a STUB per the task rules: input_specs()
hands the encoder precomputed frame embeddings (B, S_enc, D). The encoder is
bidirectional attention + MLP; the decoder adds causal self-attention and
cross-attention to the encoder output. Decode caches self-attn K/V per layer
plus the (fixed) cross-attn K/V computed once from the encoder output."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attn_decode, attn_full, init_attn_layer
from .common import ModelConfig, cross_entropy, init_dense, pshard, rms_norm
from .transformer import init_mlp_layer, mlp


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, cfg.encoder_layers + cfg.n_layers + 4)
    d = cfg.d_model

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": jnp.zeros((d,), cfg.dtype),
            "attn": init_attn_layer(cfg, k1),
            "norm2": jnp.zeros((d,), cfg.dtype),
            "mlp": init_mlp_layer(cfg, k2),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": jnp.zeros((d,), cfg.dtype),
            "self_attn": init_attn_layer(cfg, k1),
            "norm_x": jnp.zeros((d,), cfg.dtype),
            "cross_attn": init_attn_layer(cfg, k2),
            "norm2": jnp.zeros((d,), cfg.dtype),
            "mlp": init_mlp_layer(cfg, k3),
        }

    enc = [enc_block(ks[i]) for i in range(cfg.encoder_layers)]
    dec = [dec_block(ks[cfg.encoder_layers + i]) for i in range(cfg.n_layers)]
    return {
        "embed": init_dense(ks[-1], (cfg.vocab, d), dtype=cfg.dtype),
        "head": init_dense(ks[-2], (d, cfg.vocab), dtype=cfg.dtype),
        "enc_norm": jnp.zeros((d,), cfg.dtype),
        "final_norm": jnp.zeros((d,), cfg.dtype),
        "encoder": jax.tree.map(lambda *x: jnp.stack(x), *enc),
        "decoder": jax.tree.map(lambda *x: jnp.stack(x), *dec),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames (B, S_enc, D) from the stub frontend -> encoder states."""
    x = frames.astype(cfg.compute_dtype)
    x = pshard(x, ("batch", "seq", None))

    def body(x, p):
        h, _ = attn_full(cfg, p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                         window=0, causal=False)
        x = x + h
        x = x + mlp(cfg, p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
        return pshard(x, ("batch", "seq", None)), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attend(cfg, p, x, enc_k, enc_v):
    """Cross attention with precomputed encoder K/V (no positional rotation)."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = cfg.compute_dtype
    q = (x @ p["wq"].astype(cd)).reshape(b, s, cfg.n_heads, hd)
    group = cfg.n_heads // cfg.n_kv_heads
    qh = q.swapaxes(1, 2).astype(jnp.float32)
    kh = jnp.repeat(enc_k.swapaxes(1, 2).astype(jnp.float32), group, axis=1)
    vh = jnp.repeat(enc_v.swapaxes(1, 2).astype(jnp.float32), group, axis=1)
    a = jax.nn.softmax(
        jnp.einsum("bhqd,bhkd->bhqk", qh, kh) * (hd ** -0.5), axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", a, vh).swapaxes(1, 2).reshape(b, s, -1)
    return y.astype(cd) @ p["wo"].astype(cd)


def _enc_kv(cfg, p, enc):
    b, s, _ = enc.shape
    hd = cfg.resolved_head_dim
    cd = cfg.compute_dtype
    k = (enc @ p["wk"].astype(cd)).reshape(b, s, cfg.n_kv_heads, hd)
    v = (enc @ p["wv"].astype(cd)).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


def decode_full(cfg: ModelConfig, params: dict, tokens: jax.Array,
                enc: jax.Array) -> jax.Array:
    """Teacher-forced decoder pass -> logits (B, S_dec, V)."""
    x = params["embed"][tokens].astype(cfg.compute_dtype) * (cfg.d_model ** 0.5)
    x = pshard(x, ("batch", "seq", None))

    def body(x, p):
        h, _ = attn_full(cfg, p["self_attn"],
                         rms_norm(x, p["norm1"], cfg.norm_eps), window=0)
        x = x + h
        ek, ev = _enc_kv(cfg, p["cross_attn"], enc)
        x = x + _cross_attend(cfg, p["cross_attn"],
                              rms_norm(x, p["norm_x"], cfg.norm_eps), ek, ev)
        x = x + mlp(cfg, p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
        return pshard(x, ("batch", "seq", None)), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return pshard(x @ params["head"].astype(cfg.compute_dtype),
                  ("batch", "seq", "vocab"))


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    enc = encode(cfg, params, batch["frames"])
    logits = decode_full(cfg, params, batch["tokens"], enc)
    return cross_entropy(logits, batch["targets"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int,
               dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "ek": jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, hd), dtype),
        "ev": jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: dict, frames: jax.Array,
            tokens: jax.Array, max_len: int) -> tuple[jax.Array, dict]:
    """Encode + teacher-forced context pass; caches cross K/V and self K/V."""
    enc = encode(cfg, params, frames)
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len, enc.shape[1],
                       dtype=cfg.compute_dtype)

    # Cross K/V once per layer (scan over stacked decoder params).
    def kv_body(_, p):
        return None, _enc_kv(cfg, p["cross_attn"], enc)

    _, (ek, ev) = jax.lax.scan(kv_body, None, params["decoder"])
    cache["ek"], cache["ev"] = ek.astype(cache["ek"].dtype), ev.astype(cache["ev"].dtype)

    logits = None
    for i in range(s):  # context is short for enc-dec serving; step decode
        logits, cache = decode_step(cfg, params, cache, tokens[:, i : i + 1])
    return logits, cache


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    x = params["embed"][tokens].astype(cfg.compute_dtype) * (cfg.d_model ** 0.5)
    pos = cache["pos"]

    def body(x, layer_in):
        p, ck, cv, ek, ev = layer_in
        h, nk, nv = attn_decode(
            cfg, p["self_attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
            ck, cv, pos, window=0,
        )
        x = x + h
        x = x + _cross_attend(cfg, p["cross_attn"],
                              rms_norm(x, p["norm_x"], cfg.norm_eps),
                              ek.astype(cfg.compute_dtype),
                              ev.astype(cfg.compute_dtype))
        x = x + mlp(cfg, p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps))
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["ek"], cache["ev"])
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"].astype(cfg.compute_dtype)
    new_cache = dict(cache, k=nk, v=nv, pos=pos + 1)
    return logits, new_cache
