"""Unified model interface: build(cfg) -> Model with init / loss / prefill /
decode_step, used identically by the trainer, the serving engine, and the
multi-pod dry-run."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .common import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], jax.Array]
    prefill: Callable[..., tuple]
    decode_step: Callable[[Any, dict, jax.Array], tuple]
    init_cache: Callable[..., dict]

    def abstract_params(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_params(cfg, key),
            loss=lambda p, b: encdec.loss_fn(cfg, p, b),
            prefill=lambda p, frames, tokens, max_len: encdec.prefill(
                cfg, p, frames, tokens, max_len),
            decode_step=lambda p, cache, tok: encdec.decode_step(
                cfg, p, cache, tok),
            init_cache=lambda batch, max_len, enc_len=0, dtype=jnp.bfloat16:
                encdec.init_cache(cfg, batch, max_len, enc_len, dtype),
        )
    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_params(cfg, key),
        loss=lambda p, b: transformer.loss_fn(cfg, p, b),
        prefill=lambda p, tokens, max_len: transformer.prefill(
            cfg, p, tokens, max_len),
        decode_step=lambda p, cache, tok: transformer.decode_step(
            cfg, p, cache, tok),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16:
            transformer.init_cache(cfg, batch, max_len, dtype),
    )
