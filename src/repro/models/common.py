"""Shared model substrate: config, init, norms, RoPE, losses, and the
logical-axis sharding hook every layer uses.

Models are hand-rolled functional JAX (param pytrees + pure apply fns); all
depth iteration uses lax.scan over stacked layer params so compile time and
HLO size are O(1) in depth (88-layer configs lower in seconds)."""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes every assigned architecture (configs/<id>.py)."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # Attention pattern.
    sliding_window: int = 0        # 0 -> full attention
    global_every: int = 0          # gemma3: layer l is global iff (l+1) % global_every == 0
    # MoE.
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba-2 / SSD).
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    conv_width: int = 4
    # Hybrid (zamba2-style): one SHARED attention block every attn_every layers.
    attn_every: int = 0
    # Encoder-decoder (whisper-style).
    encoder_layers: int = 0
    # Frontend stubs ([audio]/[vlm] — the task specifies backbone-only).
    frontend: str = ""             # "" | "audio_stub" | "vq_stub"
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: Any = jnp.float32       # parameter dtype
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    unroll_layers: bool = False    # python-loop depth (roofline per-layer deltas)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    def param_count(self) -> int:
        """Analytic parameter count (roofline MODEL_FLOPS uses this)."""
        d, v = self.d_model, self.vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.family != "encdec" else 1)
        head = d * v
        total = emb + head + d  # + final norm
        def attn_params():
            return d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + \
                hd * self.n_heads * d + 2 * d
        def mlp_params(ff):
            return 3 * d * ff
        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
        elif self.family == "moe":
            per = attn_params() + 2 * d + d * self.n_experts \
                + self.n_experts * 3 * d * self.moe_d_ff
            total += self.n_layers * per
        elif self.family == "ssm":
            total += self.n_layers * (self._mamba_params() + d)
        elif self.family == "hybrid":
            total += self.n_layers * (self._mamba_params() + d)
            total += attn_params() + mlp_params(self.d_ff) + 2 * d  # shared block
        elif self.family == "encdec":
            total += self.encoder_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            # decoder layers add cross attention
            total += self.n_layers * (2 * attn_params() + mlp_params(self.d_ff) + 3 * d)
        return int(total)

    def _mamba_params(self) -> int:
        h, p, n = self.ssm_heads, self.ssm_head_dim, self.ssm_state
        d_in = h * p
        d = self.d_model
        # in_proj -> (z, x, B, C, dt) ; out_proj ; conv over (x,B,C) ; A, D, norm
        return d * (2 * d_in + 2 * n + h) + d_in * d + \
            self.conv_width * (d_in + 2 * n) + 2 * h + d_in

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (6*N_active*D flops rule)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense_per_layer = (
            d * self.resolved_head_dim * (self.n_heads + 2 * self.n_kv_heads)
            + self.resolved_head_dim * self.n_heads * d + 2 * d
            + d * self.n_experts
        )
        act_moe = self.top_k * 3 * d * self.moe_d_ff
        return int(
            self.vocab * d * 2 + d
            + self.n_layers * (dense_per_layer + act_moe)
        )


# --------------------------------------------------------------- sharding hook
class _Policy(threading.local):
    fn: Callable[[jax.Array, tuple], jax.Array] | None = None


_POLICY = _Policy()


@contextlib.contextmanager
def activation_sharding(fn: Callable[[jax.Array, tuple], jax.Array]):
    """Install an activation-sharding callback: models call
    ``pshard(x, ('batch', 'seq', 'embed'))`` on layer boundaries and the
    distribution layer (repro.dist.sharding) maps logical axes to the mesh."""
    prev = _POLICY.fn
    _POLICY.fn = fn
    try:
        yield
    finally:
        _POLICY.fn = prev


def pshard(x: jax.Array, logical: tuple) -> jax.Array:
    if _POLICY.fn is None:
        return x
    return _POLICY.fn(x, logical)


def scan_layers(body, init, xs, *, unroll: bool = False):
    """lax.scan over stacked layer params, or a python loop when ``unroll``
    (the roofline analysis needs per-layer HLO deltas — collectives inside a
    while body appear once in the text regardless of trip count)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


# ------------------------------------------------------------------- layers
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def init_dense(key, shape, scale_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[scale_axis]
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy in f32. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
