"""CI guard: compare a fresh ``BENCH_netsim.json`` against the committed
baseline (``benchmarks/BENCH_baseline.json``) and exit nonzero when any
tracked kernel slowed down by more than the threshold (default 1.5x).

Usage::

    PYTHONPATH=src python -m benchmarks.check_regression            # compare
    PYTHONPATH=src python -m benchmarks.check_regression --run      # bench first
    PYTHONPATH=src python -m benchmarks.check_regression --threshold 2.0

Keys present in the baseline but missing from the fresh run fail (a kernel
silently dropped out of the bench is itself a regression); keys only in the
fresh run are ignored (new kernels get picked up when the baseline is
re-committed)."""

from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE = os.path.join(_HERE, "BENCH_baseline.json")
FRESH = os.path.join(os.path.dirname(_HERE), "BENCH_netsim.json")

#: timing keys guarded against slowdowns (all microseconds, lower = better).
#: The forest rows track each backend separately — the min-of-backends
#: headline key would hide one backend regressing while the other stays fast.
#: The reference paths are tracked too (ROADMAP: extend as kernels land) —
#: they are the oracles every speedup is quoted against, and a silently
#: slowed oracle inflates every reported speedup.
TRACKED = (
    "vectorized_cold_us",
    "vectorized_warm_us",
    "reference_us",
    "batch_us_per_sim",
    "forest_predict_4k_numpy_us",
    "forest_predict_4k_jnp_us",
    "forest_reference_4k_us",
    "forest_pallas_4k_us",
    "forest_pallas_interp_512_us",
    "stage_meta_search_us_per_step",
    "stage_fused_us_per_step",
    "stage_dist_4w_us",
    "stage_spmd_2w_us",
    "stage_dist_ckpt_4w_us",
    "serve_submit_overhead_us",
    "serve_8req_4w_us",
    "traffic_model_gen_us",
    "agnostic_llm_cross_us",
    "apsp_delta_256_us",
    "pareto_insert_1k_us",
)


def compare(baseline: dict, fresh: dict, threshold: float = 1.5,
            tracked=TRACKED) -> list[str]:
    """List of human-readable regression descriptions (empty = pass)."""
    problems = []
    for key in tracked:
        if key not in baseline:
            continue  # baseline predates this kernel
        if key not in fresh:
            problems.append(f"{key}: missing from fresh run")
            continue
        ratio = fresh[key] / baseline[key]
        if ratio > threshold:
            problems.append(
                f"{key}: {fresh[key]:.0f}us vs baseline {baseline[key]:.0f}us "
                f"({ratio:.2f}x > {threshold:.2f}x)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--fresh", default=FRESH)
    ap.add_argument("--threshold", type=float, default=1.5)
    ap.add_argument("--run", action="store_true",
                    help="run kernel_bench first to produce the fresh json")
    args = ap.parse_args(argv)

    if args.run:
        from . import kernel_bench
        kernel_bench.main()

    if not os.path.exists(args.fresh):
        print(f"fresh bench json not found at {args.fresh}; "
              "run `python -m benchmarks.check_regression --run` or "
              "`python -m benchmarks.run kernel_bench` first")
        return 2
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)

    problems = compare(baseline, fresh, args.threshold)
    if problems:
        print("REGRESSIONS:")
        for p in problems:
            print("  " + p)
        return 1
    print(f"ok: {len([k for k in TRACKED if k in baseline])} tracked kernels "
          f"within {args.threshold:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
