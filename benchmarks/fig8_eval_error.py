"""Fig. 8 — prediction error of the learned evaluation function Eval across
MOO-STAGE iterations (paper: <5% after a few hours; we report the error
trajectory under the container budget).

Forest scoring runs through the flat struct-of-arrays ``predict``
(``forest_backend`` picks numpy/jnp/auto — see core.forest)."""

from __future__ import annotations

import numpy as np

from repro.core.stage import moo_stage

from .common import Timer, problem, row, spec_16, spec_36


def main(reduced: bool = False, backend: str = "auto",
         forest_backend: str = "auto") -> None:
    spec = spec_16() if reduced else spec_36()
    for case in ("case1", "case2", "case3"):
        ev, ctx, mesh = problem(spec, "BFS", case, backend=backend)
        with Timer() as t:
            res = moo_stage(spec, ev, ctx, mesh, seed=0,
                            iters_max=5 if reduced else 10,
                            n_swaps=10, n_link_moves=10,
                            max_local_steps=20 if reduced else 60,
                            forest_kwargs={"backend": forest_backend})
        errs = [e for _, e in res.eval_errors]
        if errs:
            detail = (f"first_err={errs[0]:.3f};last_err={errs[-1]:.3f};"
                      f"mean_err={np.mean(errs):.3f};n={len(errs)}")
        else:
            detail = "n=0(converged_before_second_restart)"
        row(f"fig8_{case}", t.dt / max(ev.n_evals, 1) * 1e6, detail)


if __name__ == "__main__":
    main()
