"""Table 2 — MOO-STAGE speed-up over AMOSA (all 10 applications; 2/3/4-obj
cases) and over PCBB (2-obj, small system where branch-and-bound is
tractable at all).

Speed-up metric: evaluations AMOSA needs to first reach within 3% of
MOO-STAGE's best EDP, divided by the evaluations MOO-STAGE used to reach
its best (the paper's T_AMOSA / T_MOO-STAGE protocol, Fig. 6 discussion).

Every optimizer runs through the unified ``repro.noc`` registry (equal
:class:`~repro.noc.Budget` per comparison; the adapters reproduce the
legacy driver calls exactly, so numbers match the pre-registry wiring at
fixed seeds). Forest scoring runs through the flat struct-of-arrays
``predict``; a ``table2_multistart`` row additionally compares the batched
K-chain driver (``stage_batch``) against the single-start run at equal
evaluation budget."""

from __future__ import annotations

import numpy as np

from repro.core import APP_NAMES
from repro.noc import Budget, NocProblem, run as noc_run

from .common import Timer, row, spec_16, spec_36, spec_tiny


def evals_to_reach(history: np.ndarray, target: float) -> float:
    ok = history[:, 2] <= target
    return float(history[ok, 1].min()) if ok.any() else np.inf


def speedup(spec, app: str, case: str, stage_budget: int,
            amosa_budget: int, seed: int = 0,
            backend: str = "auto", forest_backend: str = "auto") -> float:
    problem = NocProblem(spec=spec, traffic=app, case=case, backend=backend)
    r_stage = noc_run(
        problem, "stage", budget=Budget(seed=seed),
        config=dict(iters_max=6, n_swaps=12, n_link_moves=12,
                    max_local_steps=stage_budget,
                    forest_kwargs={"backend": forest_backend}))
    if r_stage.history.size == 0:
        return np.nan
    best = r_stage.history[:, 2].min()
    evals_stage = evals_to_reach(r_stage.history, best)

    r_amosa = noc_run(
        problem, "amosa", budget=Budget(max_evals=amosa_budget, seed=seed),
        config=dict(t_max=1.0, t_min=1e-4, alpha=0.92, iters_per_temp=40))
    evals_amosa = evals_to_reach(r_amosa.history, best * 1.03)
    if not np.isfinite(evals_amosa):
        evals_amosa = amosa_budget  # lower bound: never reached
    return evals_amosa / max(evals_stage, 1.0)


def main(reduced: bool = False, backend: str = "auto") -> None:
    spec = spec_16() if reduced else spec_36()
    apps = APP_NAMES[:3] if reduced else APP_NAMES
    cases = {"case1": "two-obj", "case2": "three-obj", "case3": "four-obj"}
    for case, label in cases.items():
        sps = []
        with Timer() as t:
            for app in apps:
                sps.append(speedup(spec, app, case,
                                   stage_budget=50 if reduced else 120,
                                   amosa_budget=1500 if reduced else 4000,
                                   backend=backend))
        sps = [s for s in sps if np.isfinite(s)]
        row(f"table2_amosa_{label}", t.dt / max(len(apps), 1) * 1e6,
            f"mean_speedup={np.mean(sps):.1f}x;min={np.min(sps):.1f};"
            f"max={np.max(sps):.1f};apps={len(sps)}")

    # Batched multi-start vs single start at equal evaluation budget: the
    # K=4 lockstep driver should match or beat one chain's global PHV.
    spec_m = spec_tiny()
    problem_m = NocProblem(spec=spec_m, traffic="BFS", backend=backend)
    # Multi-start pays off once chains can reach their basins' local sets;
    # the tiny spec is cheap enough to keep the full budget even reduced.
    budget = 2000
    cfg = dict(iters_max=30, n_swaps=8, n_link_moves=8, max_local_steps=1000)
    with Timer() as t:
        r1 = noc_run(problem_m, "stage_batch",
                     budget=Budget(max_evals=budget, seed=0),
                     config=dict(n_starts=1, **cfg))
        r4 = noc_run(problem_m, "stage_batch",
                     budget=Budget(max_evals=budget, seed=0),
                     config=dict(n_starts=4, **cfg))
    p1, p4 = r1.phv(), r4.phv()
    row("table2_multistart", t.dt * 1e6,
        f"phv_1start={p1:.4f};phv_4start={p4:.4f};ratio={p4/max(p1,1e-12):.3f};"
        f"budget={budget};evals={r1.n_evals}+{r4.n_evals}")

    # PCBB: tractable only at the tiny system (paper: 141x at 64 tiles).
    spec_p = spec_tiny()
    problem_p = NocProblem(spec=spec_p, traffic="BFS", case="case1",
                           backend=backend)
    r_stage = noc_run(problem_p, "stage", budget=Budget(seed=0),
                      config=dict(iters_max=4, n_swaps=8, n_link_moves=8,
                                  max_local_steps=25))
    stage_evals = r_stage.n_evals
    r_pcbb = noc_run(problem_p, "pcbb", budget=Budget(seed=0),
                     config=dict(max_expansions=2000))
    # wall_s times the optimizers only (setup/jit excluded, as the legacy
    # wiring kept them outside the Timer) — the ratio compares search work.
    row("table2_pcbb_two-obj", r_pcbb.wall_s * 1e6,
        f"pcbb_evals={r_pcbb.n_evals};stage_evals={stage_evals};"
        f"eval_ratio={r_pcbb.n_evals/max(stage_evals,1):.1f}x;"
        f"wall_ratio={r_pcbb.wall_s/max(r_stage.wall_s,1e-9):.1f}x")


if __name__ == "__main__":
    main()
