"""Table 2 — MOO-STAGE speed-up over AMOSA (all 10 applications; 2/3/4-obj
cases) and over PCBB (2-obj, small system where branch-and-bound is
tractable at all).

Speed-up metric: evaluations AMOSA needs to first reach within 3% of
MOO-STAGE's best EDP, divided by the evaluations MOO-STAGE used to reach
its best (the paper's T_AMOSA / T_MOO-STAGE protocol, Fig. 6 discussion).

Forest scoring runs through the flat struct-of-arrays ``predict``; a
``table2_multistart`` row additionally compares the batched K-chain driver
(``stage_batch``) against the single-start run at equal evaluation
budget."""

from __future__ import annotations

import numpy as np

from repro.core import APP_NAMES, traffic_matrix
from repro.core.amosa import amosa
from repro.core.local_search import SearchHistory
from repro.core.pcbb import pcbb
from repro.core.stage import moo_stage, stage_batch

from .common import Timer, problem, row, spec_16, spec_36, spec_tiny


def evals_to_reach(hist: SearchHistory, target: float) -> float:
    arr = hist.as_array()
    ok = arr[:, 2] <= target
    return float(arr[ok, 1].min()) if ok.any() else np.inf


def speedup(spec, app: str, case: str, stage_budget: int,
            amosa_budget: int, seed: int = 0,
            backend: str = "auto", forest_backend: str = "auto") -> float:
    ev, ctx, mesh = problem(spec, app, case, backend=backend)
    h_stage = SearchHistory(ev, ctx)
    moo_stage(spec, ev, ctx, mesh, seed=seed, iters_max=6, n_swaps=12,
              n_link_moves=12, max_local_steps=stage_budget, history=h_stage,
              forest_kwargs={"backend": forest_backend})
    arr = h_stage.as_array()
    if arr.size == 0:
        return np.nan
    best = arr[:, 2].min()
    evals_stage = evals_to_reach(h_stage, best)

    ev2, ctx2, mesh2 = problem(spec, app, case, backend=backend)
    h_amosa = SearchHistory(ev2, ctx2)
    amosa(spec, ev2, ctx2, mesh2, seed=seed, t_max=1.0, t_min=1e-4,
          alpha=0.92, iters_per_temp=40, max_evals=amosa_budget,
          history=h_amosa)
    evals_amosa = evals_to_reach(h_amosa, best * 1.03)
    if not np.isfinite(evals_amosa):
        evals_amosa = amosa_budget  # lower bound: never reached
    return evals_amosa / max(evals_stage, 1.0)


def main(reduced: bool = False, backend: str = "auto") -> None:
    spec = spec_16() if reduced else spec_36()
    apps = APP_NAMES[:3] if reduced else APP_NAMES
    cases = {"case1": "two-obj", "case2": "three-obj", "case3": "four-obj"}
    for case, label in cases.items():
        sps = []
        with Timer() as t:
            for app in apps:
                sps.append(speedup(spec, app, case,
                                   stage_budget=50 if reduced else 120,
                                   amosa_budget=1500 if reduced else 4000,
                                   backend=backend))
        sps = [s for s in sps if np.isfinite(s)]
        row(f"table2_amosa_{label}", t.dt / max(len(apps), 1) * 1e6,
            f"mean_speedup={np.mean(sps):.1f}x;min={np.min(sps):.1f};"
            f"max={np.max(sps):.1f};apps={len(sps)}")

    # Batched multi-start vs single start at equal evaluation budget: the
    # K=4 lockstep driver should match or beat one chain's global PHV.
    spec_m = spec_tiny()
    f_m = traffic_matrix(spec_m, "BFS")
    # Multi-start pays off once chains can reach their basins' local sets;
    # the tiny spec is cheap enough to keep the full budget even reduced.
    budget = 2000
    with Timer() as t:
        r1 = stage_batch(spec_m, f_m, n_starts=1, seed=0, iters_max=30,
                         n_swaps=8, n_link_moves=8, max_local_steps=1000,
                         max_evals=budget, backend=backend)
        r4 = stage_batch(spec_m, f_m, n_starts=4, seed=0, iters_max=30,
                         n_swaps=8, n_link_moves=8, max_local_steps=1000,
                         max_evals=budget, backend=backend)
    ctx_m = r1.history.ctx
    p1 = ctx_m.phv(r1.global_set.objs)
    p4 = ctx_m.phv(r4.global_set.objs)
    row("table2_multistart", t.dt * 1e6,
        f"phv_1start={p1:.4f};phv_4start={p4:.4f};ratio={p4/max(p1,1e-12):.3f};"
        f"budget={budget};evals={r1.n_evals}+{r4.n_evals}")

    # PCBB: tractable only at the tiny system (paper: 141x at 64 tiles).
    spec_p = spec_tiny()
    ev, ctx, mesh = problem(spec_p, "BFS", "case1")
    h = SearchHistory(ev, ctx)
    with Timer() as t_stage:
        moo_stage(spec_p, ev, ctx, mesh, seed=0, iters_max=4, n_swaps=8,
                  n_link_moves=8, max_local_steps=25, history=h)
    stage_evals = ev.n_evals
    ev2, ctx2, _ = problem(spec_p, "BFS", "case1")
    with Timer() as t_pcbb:
        res = pcbb(spec_p, ev2, ctx2, seed=0, max_expansions=2000)
    row("table2_pcbb_two-obj", t_pcbb.dt * 1e6,
        f"pcbb_evals={ev2.n_evals};stage_evals={stage_evals};"
        f"eval_ratio={ev2.n_evals/max(stage_evals,1):.1f}x;"
        f"wall_ratio={t_pcbb.dt/max(t_stage.dt,1e-9):.1f}x")


if __name__ == "__main__":
    main()
