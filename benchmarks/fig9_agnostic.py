"""Figs. 9 & 11 — application-agnostic NoC design, paper apps and beyond.

Every application's NoC is cross-evaluated on every other application and
on the leave-one-out AVG NoC; normalized EDP degradation is the paper's
headline number (64-tile: 3.2% avg single-app, 1.1% AVG; 36-tile: 3.8% /
1.8%; Fig. 11 repeats this under joint perf-thermal objectives).

``--workloads llm`` runs the study the paper could not: paper apps and
model-derived LLM phase traffic (repro.workloads, DESIGN.md §11)
cross-executed against each other, reporting how far a paper-apps-AVG NoC
degrades on LLM traffic and vice versa.

The per-application optimizations route through the unified ``repro.noc``
API (``optimize_for_traffic`` is a thin wrapper over the "stage" registry
entry); the CLI twin is ``python -m repro.noc agnostic``."""

from __future__ import annotations

import numpy as np

from repro.core import APP_NAMES, spec_16, spec_36
from repro.noc import OptimizeBudget, run_agnostic_study, summarize

from .common import Timer, row


def main(reduced: bool = False, workloads: str = "paper") -> None:
    if workloads == "llm":
        return main_llm(reduced)
    spec = spec_16() if reduced else spec_36()
    apps = APP_NAMES[:4] if reduced else APP_NAMES
    budget = OptimizeBudget(
        iters_max=2 if reduced else 4,
        n_swaps=10, n_link_moves=10,
        max_local_steps=12 if reduced else 40,
    )
    for case, tag in (("case3", "fig9_perf"), ("case5", "fig11_joint")):
        with Timer() as t:
            res = run_agnostic_study(spec, apps, case, budget)
        s = summarize(res)
        row(tag, t.dt / len(apps) * 1e6,
            f"single_app_avg_deg={s['app_specific_avg_degradation']*100:.1f}%;"
            f"single_app_worst={s['app_specific_worst_degradation']*100:.1f}%;"
            f"avg_noc_deg={s['avg_noc_degradation']*100:.1f}%;"
            f"avg_noc_worst={s['avg_noc_worst']*100:.1f}%")


def main_llm(reduced: bool = False) -> None:
    from repro.workloads import (LLM_STUDY_SCENARIOS, format_cross_table,
                                 run_cross_workload_study)

    spec = spec_16() if reduced else spec_36()
    paper_apps = APP_NAMES[:2] if reduced else APP_NAMES[:4]
    scenarios = (LLM_STUDY_SCENARIOS[::2] if reduced
                 else LLM_STUDY_SCENARIOS)
    budget = OptimizeBudget(
        iters_max=2 if reduced else 4,
        n_swaps=10, n_link_moves=10,
        max_local_steps=12 if reduced else 40,
    )
    with Timer() as t:
        res = run_cross_workload_study(spec, paper_apps, scenarios,
                                       "case3", budget)
    print(format_cross_table(res))
    s = res["summary"]
    n_workloads = len(paper_apps) + len(scenarios)
    row("fig9_llm_cross", t.dt / n_workloads * 1e6,
        f"paper_on_llm_avg=+{s['paper_on_llm_avg']*100:.1f}%;"
        f"paper_on_llm_worst=+{s['paper_on_llm_worst']*100:.1f}%;"
        f"llm_on_paper_avg=+{s['llm_on_paper_avg']*100:.1f}%;"
        f"paper_on_paper_avg=+{s['paper_on_paper_avg']*100:.1f}%")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--workloads", default="paper", choices=("paper", "llm"))
    a = ap.parse_args()
    main(reduced=a.reduced, workloads=a.workloads)
