"""Figs. 9 & 11 — application-agnostic NoC design.

Every application's NoC is cross-evaluated on every other application and
on the leave-one-out AVG NoC; normalized EDP degradation is the paper's
headline number (64-tile: 3.2% avg single-app, 1.1% AVG; 36-tile: 3.8% /
1.8%; Fig. 11 repeats this under joint perf-thermal objectives).

The per-application optimizations route through the unified ``repro.noc``
API (``optimize_for_traffic`` is a thin wrapper over the "stage" registry
entry); the CLI twin is ``python -m repro.noc agnostic``."""

from __future__ import annotations

import numpy as np

from repro.core import APP_NAMES, spec_16, spec_36
from repro.noc import OptimizeBudget, run_agnostic_study, summarize

from .common import Timer, row


def main(reduced: bool = False) -> None:
    spec = spec_16() if reduced else spec_36()
    apps = APP_NAMES[:4] if reduced else APP_NAMES
    budget = OptimizeBudget(
        iters_max=2 if reduced else 4,
        n_swaps=10, n_link_moves=10,
        max_local_steps=12 if reduced else 40,
    )
    for case, tag in (("case3", "fig9_perf"), ("case5", "fig11_joint")):
        with Timer() as t:
            res = run_agnostic_study(spec, apps, case, budget)
        s = summarize(res)
        row(tag, t.dt / len(apps) * 1e6,
            f"single_app_avg_deg={s['app_specific_avg_degradation']*100:.1f}%;"
            f"single_app_worst={s['app_specific_worst_degradation']*100:.1f}%;"
            f"avg_noc_deg={s['avg_noc_degradation']*100:.1f}%;"
            f"avg_noc_worst={s['avg_noc_worst']*100:.1f}%")


if __name__ == "__main__":
    main()
