"""Benchmark aggregator — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. Reduced budgets keep the full
suite tractable on the CPU container; each module's main() accepts
reduced=False for the full-budget variants reported in EXPERIMENTS.md."""

from __future__ import annotations

import sys
import time


def main() -> None:
    reduced = "--full" not in sys.argv
    # Routing backend for the search benchmarks (fig4/fig8/table2):
    # --backend=jnp|pallas|auto. Validated up front so a typo fails fast
    # instead of surfacing as per-module ERROR rows.
    # --only=<module>[,<module>...] restricts the run (e.g. --only=kernel_bench).
    backend = "auto"
    only: set[str] | None = None
    for arg in sys.argv[1:]:
        if arg.startswith("--backend="):
            backend = arg.split("=", 1)[1]
        if arg.startswith("--only="):
            only = set(arg.split("=", 1)[1].split(","))
    from repro.core import routing
    routing.resolve_backend(backend)  # raises ValueError on typos
    print(f"# repro benchmarks (reduced={reduced}, backend={backend})")
    print("name,us_per_call,derived")
    t0 = time.perf_counter()

    from . import (fig4_throughput_model, fig6_convergence, fig8_eval_error,
                   fig9_agnostic, fig10_thermal, kernel_bench, noc_cli,
                   roofline_bench, table2_speedup)

    takes_backend = (fig4_throughput_model, fig8_eval_error, table2_speedup)
    mods = [kernel_bench, noc_cli, fig4_throughput_model, fig6_convergence,
            table2_speedup, fig8_eval_error, fig9_agnostic,
            fig10_thermal, roofline_bench]
    names = {m.__name__.rsplit(".", 1)[-1] for m in mods}
    if only is not None and (unknown := only - names):
        raise SystemExit(f"--only names unknown modules: {sorted(unknown)}; "
                         f"available: {sorted(names)}")
    for mod in mods:
        name = mod.__name__.rsplit(".", 1)[-1]
        if only is not None and name not in only:
            continue
        t = time.perf_counter()
        kwargs = {"backend": backend} if mod in takes_backend else {}
        try:
            mod.main(reduced=reduced, **kwargs)
        except Exception as e:  # pragma: no cover — keep the suite running
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
        print(f"# {name} took {time.perf_counter()-t:.1f}s", flush=True)

    print(f"# total {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
