"""Roofline excerpts for the benchmark run: re-derives the three roofline
terms for two representative cells via subprocess (the 512-device dry-run
environment must not leak into this process's JAX). Full tables:
``python -m repro.launch.roofline --all`` and EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import Timer, row

CELLS = [("whisper-base", "train_4k"), ("gemma3-1b", "decode_32k")]


def main(reduced: bool = False) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    for arch, shape in CELLS:
        with Timer() as t:
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.roofline",
                 "--arch", arch, "--shape", shape],
                capture_output=True, text=True, env=env, timeout=900,
            )
        ok = proc.returncode == 0
        path = os.path.join("experiments", "roofline",
                            f"{arch}__{shape}__pod16x16.json")
        detail = "FAILED"
        if ok and os.path.exists(path):
            with open(path) as fh:
                c = json.load(fh)
            detail = (f"dominant={c['dominant']};"
                      f"compute_s={c['compute_s']:.2e};"
                      f"memory_s={c['memory_s']:.2e};"
                      f"collective_s={c['collective_s']:.2e};"
                      f"roofline_frac={c['roofline_fraction']:.2f}")
        row(f"roofline_{arch}_{shape}", t.dt * 1e6, detail)


if __name__ == "__main__":
    main()
