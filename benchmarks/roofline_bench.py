"""Roofline excerpts for the benchmark run: re-derives the three roofline
terms for two representative cells via subprocess (the 512-device dry-run
environment must not leak into this process's JAX). Full tables:
``python -m repro.launch.roofline --all`` and EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import Timer, row

CELLS = [("whisper-base", "train_4k"), ("gemma3-1b", "decode_32k")]


def main(reduced: bool = False) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    if reduced:
        # Smoke: the import chain through repro.dist.sharding must hold —
        # this row failing loudly is the guard against the PR-9 breakage
        # (launch/roofline importing a displaced sharding module) coming
        # back. Full cells are subprocess-lowered minutes each; the
        # reduced suite only proves the entry point is runnable.
        with Timer() as t:
            import repro.launch.roofline  # noqa: F401
            import repro.dist.sharding  # noqa: F401
            proc = subprocess.run(
                [sys.executable, "-c",
                 "import repro.launch.roofline, repro.launch.perf, "
                 "repro.launch.dryrun"],
                capture_output=True, text=True, env=env, timeout=300,
            )
        if proc.returncode != 0:
            raise RuntimeError(
                "roofline import smoke failed:\n" + proc.stderr[-2000:])
        row("roofline_import_smoke", t.dt * 1e6, "ok")
        return
    for arch, shape in CELLS:
        with Timer() as t:
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.roofline",
                 "--arch", arch, "--shape", shape],
                capture_output=True, text=True, env=env, timeout=900,
            )
        ok = proc.returncode == 0
        path = os.path.join("experiments", "roofline",
                            f"{arch}__{shape}__pod16x16.json")
        detail = "FAILED"
        if ok and os.path.exists(path):
            with open(path) as fh:
                c = json.load(fh)
            detail = (f"dominant={c['dominant']};"
                      f"compute_s={c['compute_s']:.2e};"
                      f"memory_s={c['memory_s']:.2e};"
                      f"collective_s={c['collective_s']:.2e};"
                      f"roofline_frac={c['roofline_fraction']:.2f}")
        row(f"roofline_{arch}_{shape}", t.dt * 1e6, detail)


if __name__ == "__main__":
    main()
