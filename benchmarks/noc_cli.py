"""noc_cli_smoke — time the unified ``repro.noc`` CLI end to end.

Runs ``python -m repro.noc run --smoke`` in-process: one registry run of
MOO-STAGE on the tiny spec under a shared Budget, a RunResult JSON round
trip, and the budget-accounting check. Guards the whole unified-API
dispatch path (problem build → evaluator jit → registry → serialization)
against breakage and gross slowdowns."""

from __future__ import annotations

from .common import Timer, row


def main(reduced: bool = False) -> None:
    from repro.noc import cli

    with Timer() as t:
        rc = cli.main(["run", "--smoke", "--quiet"])
    if rc != 0:
        raise RuntimeError(f"repro.noc run --smoke failed (rc={rc})")
    row("noc_cli_smoke", t.dt * 1e6, f"rc={rc}")


if __name__ == "__main__":
    main()
