"""Fig. 4 — validation of the throughput proxy (Eqs. 3-4).

Designs visited while optimizing Case 1 ({U, sigma}) are replayed through
the independent flit-level simulator; throughput must correlate inversely
with U-bar and sigma (the paper's 'monotonic increase' claim)."""

from __future__ import annotations

import numpy as np

from repro.core import netsim, random_design, sample_neighbors
from repro.core.local_search import local_search

from .common import Timer, problem, row, spec_16, spec_36


def spearman(a, b):
    ra = np.argsort(np.argsort(a))
    rb = np.argsort(np.argsort(b))
    n = len(a)
    return 1 - 6 * np.sum((ra - rb) ** 2) / (n * (n ** 2 - 1))


def main(reduced: bool = False, apps=("BFS", "HS"),
         backend: str = "auto") -> None:
    spec = spec_16() if reduced else spec_36()
    rng = np.random.default_rng(0)
    for app in apps:
        ev, ctx, mesh = problem(spec, app, "case1", backend=backend)
        # Visit designs the way the paper does: a case-1 optimization run.
        res = local_search(spec, ev, ctx, mesh, rng, n_swaps=8,
                           n_link_moves=8, max_steps=8 if reduced else 15)
        designs = res.traj + [random_design(spec, rng) for _ in range(4)]
        objs = ev.batch(designs)
        ok = np.isfinite(objs).all(1)
        designs = [d for d, m in zip(designs, ok) if m]
        objs = objs[ok]
        f = ev.f
        with Timer() as t:
            # One batched designs x scales simulator call (tables built
            # once per design, all sims advanced in the same cycle loop).
            ths = netsim.saturation_throughput_batch(
                spec, designs, np.asarray(f), scales=(8.0, 16.0),
                cycles=600 if reduced else 1200)
        rho_mean = spearman(-objs[:, 0], ths)
        rho_std = spearman(-objs[:, 1], ths)
        row(f"fig4_{app}", t.dt / max(len(designs), 1) * 1e6,
            f"rho(-umean;thr)={rho_mean:.2f};rho(-ustd;thr)={rho_std:.2f};"
            f"n={len(designs)}")


if __name__ == "__main__":
    main()
